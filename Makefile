# Build-time artifact generation for the `pjrt` feature (§5.5 / App. C):
# lower the JAX/Pallas kernels to HLO text once, at build time — Python
# never runs on the Rust hot path. Requires jax; see python/compile/aot.py.
#
# The artifacts land at <repo>/artifacts, where the Rust side looks for
# them (CARGO_MANIFEST_DIR/artifacts).

.PHONY: artifacts clean-artifacts bench-service

artifacts:
	cd python/compile && python3 aot.py --out ../../artifacts

clean-artifacts:
	rm -rf artifacts

# Service-layer perf trajectory: jobs/sec, cache hit rate and per-device
# utilization through the `service` subsystem; emits BENCH_service.json.
bench-service:
	cargo bench --bench service_throughput
