# Build-time artifact generation for the `pjrt` feature (§5.5 / App. C):
# lower the JAX/Pallas kernels to HLO text once, at build time — Python
# never runs on the Rust hot path. Requires jax; see python/compile/aot.py.
#
# The artifacts land at <repo>/artifacts, where the Rust side looks for
# them (CARGO_MANIFEST_DIR/artifacts).

.PHONY: artifacts clean-artifacts bench-service

artifacts:
	cd python/compile && python3 aot.py --out ../../artifacts

clean-artifacts:
	rm -rf artifacts

# Service-layer perf trajectory: jobs/sec, cache hit rate and per-device
# utilization through the `service` subsystem; emits BENCH_service.json.
bench-service:
	cargo bench --bench service_throughput

# Run the service bench and promote its output as the committed gate
# baseline (scripts/bench_gate.py compares CI runs against it and fails
# on a >2x throughput regression; a `measured: false` baseline is a
# bootstrap placeholder that disables the comparison).
.PHONY: bench-baseline bench-gate
bench-baseline: bench-service
	@python3 -c "import json; d=json.load(open('BENCH_service.json')); \
	  print('promoted measured baseline: cold %.2f jobs/s, warm %.2f jobs/s' \
	  % (d['cold_jobs_per_sec'], d['warm_jobs_per_sec']))"
	@echo "commit BENCH_service.json to update the gate baseline"

# Local mirror of the CI gate step.
bench-gate:
	cp BENCH_service.json /tmp/bench_baseline.json
	$(MAKE) bench-service
	python3 scripts/bench_gate.py --baseline /tmp/bench_baseline.json --current BENCH_service.json
