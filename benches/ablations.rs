//! Ablation study over KernelFoundry's three mechanisms (§3): disable
//! gradient-informed evolution, disable meta-prompting, and sweep the
//! selection strategies — quantifying each component's contribution on
//! the representative L2 set (not a paper table; the design-choice
//! analysis DESIGN.md §4 calls out).

use kernelfoundry::config::FoundryConfig;
use kernelfoundry::coordinator::EvolutionEngine;
use kernelfoundry::eval::ExecBackend;
use kernelfoundry::experiments::ExperimentScale;
use kernelfoundry::hwsim::DeviceProfile;
use kernelfoundry::metrics::{aggregate, TaskResult};
use kernelfoundry::selection::Strategy;
use kernelfoundry::tasks::catalog;

fn run_variant(label: &str, mutate: impl Fn(&mut FoundryConfig), iters: usize) {
    let mut results: Vec<TaskResult> = Vec::new();
    for task in catalog::kernelbench_l2() {
        let mut config = FoundryConfig::paper_defaults();
        config.evolution.max_generations = iters;
        mutate(&mut config);
        let mut engine = EvolutionEngine::new(
            config,
            task.clone(),
            ExecBackend::HwSim(DeviceProfile::b580()),
        );
        results.push(engine.run(false).task_result());
    }
    let agg = aggregate(&results);
    println!(
        "{label:<42} correct {:.2}  fast2 {:>3.0}%  avg {:.3}  geom {:.3}",
        agg.correct_rate,
        agg.fast_2 * 100.0,
        agg.avg_speedup,
        agg.geom_speedup
    );
}

fn main() {
    let scale = ExperimentScale::from_env();
    let iters = scale.iterations(40);
    println!("## ablations — repr. L2, B580, {iters} iterations\n");
    let start = std::time::Instant::now();

    run_variant("full system", |_| {}, iters);
    run_variant(
        "- gradient-informed evolution",
        |c| c.gradients_enabled = false,
        iters,
    );
    run_variant(
        "- meta-prompt co-evolution",
        |c| c.meta_prompt.enabled = false,
        iters,
    );
    run_variant(
        "- both (archive-only QD)",
        |c| {
            c.gradients_enabled = false;
            c.meta_prompt.enabled = false;
        },
        iters,
    );
    for strat in [
        Strategy::Uniform,
        Strategy::FitnessProportionate,
        Strategy::Island,
    ] {
        run_variant(
            &format!("selection = {}", strat.name()),
            move |c| c.evolution.selection = strat,
            iters,
        );
    }
    println!("\n[ablations completed in {:.1}s]", start.elapsed().as_secs_f64());
}
