//! Distributed-framework scaling bench (Fig. 4 / §3.6): candidate
//! evaluation throughput vs number of execution workers, and the
//! compile-worker early-reject benefit.

use kernelfoundry::dist::{ClusterConfig, WorkerPool};
use kernelfoundry::hwsim::DeviceProfile;
use kernelfoundry::ir::{Defect, DefectKind, KernelGenome, MemoryPattern};
use kernelfoundry::tasks::catalog;
use std::sync::atomic::Ordering;

fn batch(task_id: &str, n: usize, defect_every: usize) -> Vec<KernelGenome> {
    (0..n)
        .map(|i| {
            let mut g = KernelGenome::direct_translation(task_id);
            g.id = i as u64;
            g.mem = MemoryPattern::from_level(i % 4);
            g.params.slm_pad = true;
            if defect_every > 0 && i % defect_every == 0 {
                g.defects.push(Defect { kind: DefectKind::SyntaxError, severity: 1.0 });
            }
            g
        })
        .collect()
}

fn main() {
    let task = catalog::find_task("1_Conv2D_ReLU_BiasAdd").unwrap();
    let n = 256;
    println!("## dist_throughput — {n} candidates, task {}\n", task.id);
    println!("{:>8} {:>8} {:>10} {:>12} {:>10}", "compile", "exec", "time [s]", "cand/s", "rejected");
    let mut base_rate = 0.0;
    for (nc, ne) in [(1, 1), (1, 2), (2, 4), (2, 8), (4, 16)] {
        let pool = WorkerPool::new(ClusterConfig {
            compile_workers: nc,
            exec_workers: ne,
            device: DeviceProfile::b580(),
            queue_capacity: 64,
            seed: 5,
        });
        let genomes = batch(&task.id, n, 9);
        let start = std::time::Instant::now();
        let records = pool.evaluate_batch(&task, genomes);
        let dt = start.elapsed().as_secs_f64();
        assert_eq!(records.len(), n);
        let rate = n as f64 / dt;
        if ne == 1 {
            base_rate = rate;
        }
        println!(
            "{:>8} {:>8} {:>10.3} {:>12.1} {:>10}",
            nc,
            ne,
            dt,
            rate,
            pool.metrics.compile_rejected.load(Ordering::Relaxed)
        );
    }
    println!("\nspeedup at 16 exec workers vs 1: see cand/s column (base {base_rate:.1}/s)");
}
