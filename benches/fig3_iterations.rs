//! Regenerates **Figure 3**: improvement over iterations (cumulative
//! best speedup), Ours vs OpenEvolve, mean over the representative L2
//! set. Emits the full per-iteration series as CSV for plotting.

use kernelfoundry::experiments::{fig3_series, ExperimentScale};

fn main() {
    let scale = ExperimentScale::from_env();
    let start = std::time::Instant::now();
    let out = fig3_series(scale);
    out.print();
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/fig3_iterations.csv", &out.per_task_csv).ok();
    println!("(series CSV -> results/fig3_iterations.csv)");
    println!("\n[fig3_iterations completed in {:.1}s]", start.elapsed().as_secs_f64());
}
