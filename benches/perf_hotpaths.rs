//! L3 hot-path microbenchmarks (EXPERIMENTS.md §Perf): archive ops,
//! gradient estimation, behavioral classification, prompt assembly,
//! hwsim evaluation, and the full evolution-loop overhead split.

use kernelfoundry::archive::{Elite, MapElites};
use kernelfoundry::classify;
use kernelfoundry::config::FoundryConfig;
use kernelfoundry::coordinator::EvolutionEngine;
use kernelfoundry::eval::{EvalPipeline, ExecBackend};
use kernelfoundry::gradient::GradientEstimator;
use kernelfoundry::hwsim::{kernel_cost, DeviceProfile};
use kernelfoundry::ir::{render_sycl, KernelGenome, MemoryPattern};
use kernelfoundry::prompts::{EvolvablePrompt, PromptBuilder};
use kernelfoundry::tasks::catalog;
use kernelfoundry::transitions::{Outcome, Transition, TransitionTracker};
use kernelfoundry::util::rng::Rng;
use std::time::Instant;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // Warmup.
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let start = Instant::now();
    for _ in 0..iters {
        f();
    }
    let dt = start.elapsed().as_secs_f64();
    let per = dt / iters as f64;
    let rate = 1.0 / per;
    println!("{name:<44} {:>12.3} µs/op {:>14.0} op/s", per * 1e6, rate);
    rate
}

fn main() {
    println!("## perf_hotpaths — L3 microbenchmarks\n");
    let task = catalog::find_task("99_Matmul_GELU_Softmax").unwrap();
    let dev = DeviceProfile::b580();
    let mut rng = Rng::new(1);

    // Archive insert + select.
    let mut archive = MapElites::new(4);
    let genome = KernelGenome::direct_translation(&task.id);
    bench("archive::insert", 200_000, || {
        let coords = [rng.below(4), rng.below(4), rng.below(4)];
        archive.insert(Elite {
            genome: genome.clone(),
            coords,
            fitness: rng.f64(),
            speedup: 1.0,
            runtime_ms: 1.0,
            iteration: 0,
        });
    });

    // Gradient estimation over a full buffer.
    let mut tracker = TransitionTracker::new(256);
    for i in 0..256 {
        tracker.record(Transition {
            parent_coords: [rng.below(4), rng.below(4), rng.below(4)],
            child_coords: [rng.below(4), rng.below(4), rng.below(4)],
            parent_fitness: rng.f64(),
            child_fitness: rng.f64(),
            outcome: Outcome::Improvement,
            iteration: i,
        });
    }
    let est = GradientEstimator::default();
    bench("gradient::estimate (256-deep buffer)", 20_000, || {
        let _ = est.estimate(&tracker, &archive, [1, 1, 1], 256);
    });
    bench("gradient::sampling_weights (full archive)", 2_000, || {
        let _ = est.sampling_weights(&tracker, &archive, 256);
    });

    // Renderer + classifier.
    let mut g = KernelGenome::direct_translation(&task.id);
    g.mem = MemoryPattern::MultiLevel;
    g.params.reg_block = 4;
    g.params.prefetch = true;
    let src = render_sycl(&g);
    bench("ir::render_sycl", 50_000, || {
        let _ = render_sycl(&g);
    });
    bench("classify::classify_source", 50_000, || {
        let _ = classify::classify_source(&src);
    });

    // Prompt assembly.
    let builder = PromptBuilder::default();
    let evolvable = EvolvablePrompt::default();
    bench("prompts::build (no history)", 20_000, || {
        let _ = builder.build(&task, &evolvable, None, None, None, &[], "Intel Arc B580");
    });

    // hwsim cost model + full pipeline evaluation.
    bench("hwsim::kernel_cost", 500_000, || {
        let _ = kernel_cost(&task, &g, &dev);
    });
    let mut pipeline = EvalPipeline::new(task.clone(), ExecBackend::HwSim(dev.clone()), 3);
    let clean = {
        let mut c = g.clone();
        c.params.slm_pad = true;
        c
    };
    bench("eval::pipeline.evaluate (full record)", 2_000, || {
        let _ = pipeline.evaluate(&clean);
    });

    // Whole-loop throughput: evaluations/second through the engine.
    let mut config = FoundryConfig::paper_defaults();
    config.evolution.max_generations = 20;
    config.evolution.population = 8;
    let start = Instant::now();
    let mut engine = EvolutionEngine::new(config, task.clone(), ExecBackend::HwSim(dev));
    let report = engine.run(false);
    let dt = start.elapsed().as_secs_f64();
    println!(
        "\nevolution loop: {} evaluations in {:.2}s = {:.0} eval/s end-to-end",
        report.evaluations,
        dt,
        report.evaluations as f64 / dt
    );
}
