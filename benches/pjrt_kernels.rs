//! Real-artifact benchmarks: every AOT-compiled Pallas variant timed
//! through the PJRT CPU client with the App. B.2 harness — the L1/L2
//! perf half of EXPERIMENTS.md §Perf. Skips cleanly when `make
//! artifacts` has not run.

use kernelfoundry::eval::{BenchConfig, Benchmarker};
use kernelfoundry::runtime::{Manifest, PjrtRuntime};
use std::path::Path;

fn main() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("pjrt_kernels: artifacts/ not built (run `make artifacts`); skipping");
        return;
    }
    let manifest = Manifest::load(&dir).expect("manifest");
    let mut rt = PjrtRuntime::cpu().expect("PJRT CPU client");
    println!("## pjrt_kernels — real artifact timings ({})\n", rt.platform());
    println!("{:<28} {:<24} {:>12} {:>10}", "task", "artifact", "time [ms]", "vs ref");

    let bench = Benchmarker::new(BenchConfig::quick());
    for task in manifest.tasks() {
        let reference = manifest.reference_for(&task).unwrap().clone();
        rt.execute(&reference).expect("reference runs");
        let mut time_of = |art: &kernelfoundry::runtime::ArtifactInfo| {
            let art = art.clone();
            let mut src = |iters: usize| rt.time_batch(&art, iters).unwrap_or(f64::INFINITY);
            bench.run(&mut src).time_ms
        };
        let t_ref = time_of(&reference);
        println!("{:<28} {:<24} {:>12.4} {:>9.2}x", task, reference.name, t_ref, 1.0);
        for variant in manifest.variants_for(&task).into_iter().cloned().collect::<Vec<_>>() {
            let t = time_of(&variant);
            println!(
                "{:<28} {:<24} {:>12.4} {:>9.2}x",
                "", variant.name, t, t_ref / t
            );
        }
    }
}
