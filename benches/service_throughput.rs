//! Service-layer throughput bench: jobs/sec through the fleet
//! scheduler, the cache-hit fast path, and per-device utilization.
//!
//! Harness-free (`fn main()`), like every bench in this repo. Emits
//! `BENCH_service.json` so CI and later PRs can track the serving-path
//! perf trajectory (`make bench-service`).

use kernelfoundry::hwsim::DeviceProfile;
use kernelfoundry::service::{DeviceTarget, GuardConfig, JobSpec, KernelService, ServiceConfig};
use kernelfoundry::tasks::catalog;
use kernelfoundry::util::json::Json;
use std::time::{Duration, Instant};

const JOBS: usize = 6;

fn specs() -> Vec<JobSpec> {
    catalog::kernelbench_l1()
        .into_iter()
        .take(JOBS)
        .map(|task| {
            let mut spec = JobSpec::catalog(&task.id, "b580");
            // Fan out: every job runs on every fleet device.
            spec.device = DeviceTarget::FanOut;
            spec.iters = 3;
            spec.population = 2;
            spec.seed = 11;
            spec
        })
        .collect()
}

fn run_wave(service: &KernelService, label: &str) -> (f64, usize) {
    let start = Instant::now();
    let ids: Vec<u64> = specs()
        .into_iter()
        .map(|spec| service.submit(spec).expect("submit").job_id)
        .collect();
    let mut cached_units = 0;
    for id in ids {
        let job = service
            .wait(id, Duration::from_secs(120))
            .expect("job exists");
        assert!(job.state().finished(), "{label}: job {id} did not finish");
        cached_units += job
            .units
            .iter()
            .filter(|u| u.result.as_ref().map(|r| r.cached).unwrap_or(false))
            .count();
    }
    (start.elapsed().as_secs_f64(), cached_units)
}

fn main() {
    let devices = vec![DeviceProfile::lnl(), DeviceProfile::b580()];
    let n_devices = devices.len();
    let service = KernelService::start(ServiceConfig {
        devices,
        compile_workers: 1,
        exec_workers: 2,
        queue_capacity: 64,
        ..ServiceConfig::default()
    })
    .expect("service starts");

    println!("## service_throughput — {JOBS} fan-out jobs x {n_devices} devices\n");

    let (cold_s, cold_cached) = run_wave(&service, "cold");
    assert_eq!(cold_cached, 0, "cold wave must not hit the cache");
    let (warm_s, warm_cached) = run_wave(&service, "warm");
    assert_eq!(
        warm_cached,
        JOBS * n_devices,
        "warm wave must be served entirely from the cache"
    );

    // Guarded wave: a fresh service with the fault-tolerance guards on
    // (deadline timers, retry budget, circuit breakers) but no fault
    // plan — measures what the retry path costs when nothing fails.
    let guarded = KernelService::start(ServiceConfig {
        devices: vec![DeviceProfile::lnl(), DeviceProfile::b580()],
        compile_workers: 1,
        exec_workers: 2,
        queue_capacity: 64,
        guard: GuardConfig {
            max_retries: 3,
            unit_deadline: Some(Duration::from_secs(10)),
            trip_threshold: 3,
            ..GuardConfig::default()
        },
        ..ServiceConfig::default()
    })
    .expect("guarded service starts");
    let (guarded_s, guarded_cached) = run_wave(&guarded, "guarded");
    assert_eq!(guarded_cached, 0, "guarded wave runs cold on its own cache");
    guarded.stop();
    let retry_overhead_pct = (guarded_s - cold_s) / cold_s * 100.0;

    let stats = service.stats();
    let hit_rate = stats
        .get_path("cache.hit_rate")
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0);

    println!("{:>8} {:>10} {:>12} {:>12}", "wave", "time [s]", "jobs/s", "units/s");
    for (name, secs) in [("cold", cold_s), ("warm", warm_s), ("guarded", guarded_s)] {
        println!(
            "{:>8} {:>10.3} {:>12.1} {:>12.1}",
            name,
            secs,
            JOBS as f64 / secs,
            (JOBS * n_devices) as f64 / secs
        );
    }
    println!("\ncache hit rate: {hit_rate:.3}");
    println!("guard overhead on the happy path: {retry_overhead_pct:+.1}%");
    println!("fleet: {}", stats.get("fleet").unwrap().to_string_compact());

    let mut out = Json::obj();
    out.set("bench", "service_throughput")
        .set("measured", true)
        .set("jobs", JOBS)
        .set("devices", n_devices)
        .set("units", JOBS * n_devices)
        .set("cold_seconds", cold_s)
        .set("cold_jobs_per_sec", JOBS as f64 / cold_s)
        .set("warm_seconds", warm_s)
        .set("warm_jobs_per_sec", JOBS as f64 / warm_s)
        .set("guarded_seconds", guarded_s)
        .set("guarded_jobs_per_sec", JOBS as f64 / guarded_s)
        .set("retry_overhead_pct", retry_overhead_pct)
        .set("cache", stats.get("cache").unwrap().clone())
        .set("fleet", stats.get("fleet").unwrap().clone());
    std::fs::write("BENCH_service.json", out.to_string_pretty() + "\n")
        .expect("writing BENCH_service.json");
    println!("\nwrote BENCH_service.json");

    service.stop();
}
