//! Regenerates **Table 11** (App. G): the GPT-OSS-20B reproducibility
//! run on the representative L2 set (SYCL, LNL profile, population 4).
//! The weak open model should fail to find a correct kernel on a
//! substantial fraction of tasks (the paper: 7 of 20).

use kernelfoundry::experiments::{table11, ExperimentScale};

fn main() {
    let scale = ExperimentScale::from_env();
    let start = std::time::Instant::now();
    let out = table11(scale);
    out.print();
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/table11_gptoss.csv", &out.per_task_csv).ok();
    println!("(CSV -> results/table11_gptoss.csv)");
    println!("\n[table11_gptoss completed in {:.1}s]", start.elapsed().as_secs_f64());
}
