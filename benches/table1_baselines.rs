//! Regenerates **Table 1** (+ per-task Tables 7 & 8): baseline
//! comparison on CUDA-profile hardware (A6000) — Kernelsseum-like
//! repeated prompting, AI-CUDA-Engineer-like single-objective evolution,
//! Ours, and Ours + parameter optimization, over the representative
//! KernelBench L1/L2 sets and robust-kbench.
//!
//! Set `KF_BENCH_SCALE=quick` for a reduced run.

use kernelfoundry::experiments::{table1, ExperimentScale};

fn main() {
    let scale = ExperimentScale::from_env();
    let start = std::time::Instant::now();
    std::fs::create_dir_all("results").ok();
    for (i, out) in table1(scale).iter().enumerate() {
        out.print();
        let name = format!("results/table1_{}.csv", ["l1", "l2", "rkb"][i]);
        std::fs::write(&name, &out.per_task_csv).ok();
        println!("(per-task CSV -> {name})");
    }
    println!("\n[table1_baselines completed in {:.1}s]", start.elapsed().as_secs_f64());
}
