//! Regenerates **Table 2** (+ per-task Table 9): SYCL generation on the
//! B580 profile — Ours on the filtered KernelBench set (n = 111) and
//! Ours vs OpenEvolve on the representative L2 set at 10 and 40
//! iterations.

use kernelfoundry::experiments::{table2, ExperimentScale};

fn main() {
    let scale = ExperimentScale::from_env();
    let start = std::time::Instant::now();
    std::fs::create_dir_all("results").ok();
    for (i, out) in table2(scale).iter().enumerate() {
        out.print();
        let name = format!("results/table2_{}.csv", ["filtered", "l2"][i]);
        std::fs::write(&name, &out.per_task_csv).ok();
        println!("(per-task CSV -> {name})");
    }
    println!("\n[table2_sycl completed in {:.1}s]", start.elapsed().as_secs_f64());
}
