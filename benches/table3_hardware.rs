//! Regenerates **Table 3** (+ per-task Table 10): the §5.3
//! hardware-awareness crossover — kernels optimized on LNL vs B580,
//! benchmarked on both devices; reports hws, hws₁, hws₁.₅, avg/geom.

use kernelfoundry::experiments::{run_crossover, ExperimentScale};

fn main() {
    let scale = ExperimentScale::from_env();
    let start = std::time::Instant::now();
    let result = run_crossover(scale);
    println!("\n## Table 3 / Table 10 — hardware-awareness crossover (repr. L2)\n");
    println!("{}", result.markdown());
    println!(
        "LNL-optimized:  hws1 {:>4.0}%  hws1.5 {:>4.0}%  avg {:.3}  geom {:.3}",
        result.lnl.hws_1 * 100.0,
        result.lnl.hws_15 * 100.0,
        result.lnl.avg,
        result.lnl.geom
    );
    println!(
        "B580-optimized: hws1 {:>4.0}%  hws1.5 {:>4.0}%  avg {:.3}  geom {:.3}",
        result.b580.hws_1 * 100.0,
        result.b580.hws_15 * 100.0,
        result.b580.avg,
        result.b580.geom
    );
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/table3_crossover.csv", result.csv()).ok();
    println!("(per-task CSV -> results/table3_crossover.csv)");
    println!("\n[table3_hardware completed in {:.1}s]", start.elapsed().as_secs_f64());
}
