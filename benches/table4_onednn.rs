//! Regenerates **Table 4**: speedup of generated SYCL kernels over the
//! oneDNN-like vendor-library baseline on five operations, including the
//! custom-task inputs (initial implementation for concat+layernorm, user
//! guidance for the exp2 softmax).

use kernelfoundry::experiments::{table4, ExperimentScale};

fn main() {
    let scale = ExperimentScale::from_env();
    let start = std::time::Instant::now();
    let out = table4(scale);
    out.print();
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/table4_onednn.csv", &out.per_task_csv).ok();
    println!("(CSV -> results/table4_onednn.csv)");
    println!("\n[table4_onednn completed in {:.1}s]", start.elapsed().as_secs_f64());
}
