//! §5.3 hardware-awareness crossover on a few tasks: optimize the same
//! task independently for the LNL iGPU and the B580 dGPU, then swap the
//! kernels between devices and measure the hardware-speedup hws.
//!
//! ```bash
//! cargo run --release --example crossover_hw
//! ```

use kernelfoundry::config::FoundryConfig;
use kernelfoundry::coordinator::EvolutionEngine;
use kernelfoundry::eval::ExecBackend;
use kernelfoundry::hwsim::{kernel_cost, DeviceProfile};
use kernelfoundry::tasks::catalog;

fn main() {
    let lnl = DeviceProfile::lnl();
    let b580 = DeviceProfile::b580();
    let mut config = FoundryConfig::paper_defaults();
    config.evolution.max_generations = 20;
    config.evolution.population = 6;

    println!("== §5.3 crossover: LNL vs B580 ==");
    println!(
        "{:<45} {:>10} {:>10} {:>8}   {:>10} {:>10} {:>8}",
        "task", "LNL/nat", "LNL/for", "hws", "B580/for", "B580/nat", "hws"
    );

    for task_id in [
        "32_Conv2d_Scaling_Min",
        "82_Conv2d_Tanh_Scaling_BiasAdd_Max",
        "99_Matmul_GELU_Softmax",
        "17_Conv2d_InstanceNorm_Divide",
        "37_Matmul_Swish_Sum_GroupNorm",
    ] {
        let task = catalog::find_task(task_id).unwrap();
        let optimize_on = |dev: &DeviceProfile| {
            let mut c = config.clone();
            c.device = dev.name.to_string();
            let mut e = EvolutionEngine::new(c, task.clone(), ExecBackend::HwSim(dev.clone()));
            e.run(true).best.expect("correct kernel").genome
        };
        let k_lnl = optimize_on(&lnl);
        let k_b580 = optimize_on(&b580);

        let t = |g: &kernelfoundry::ir::KernelGenome, d: &DeviceProfile| {
            kernel_cost(&task, g, d).time_ms
        };
        let (ln, lf) = (t(&k_lnl, &lnl), t(&k_b580, &lnl));
        let (bf, bn) = (t(&k_lnl, &b580), t(&k_b580, &b580));
        println!(
            "{:<45} {:>9.3}ms {:>9.3}ms {:>7.3}x   {:>9.3}ms {:>9.3}ms {:>7.3}x",
            task_id,
            ln,
            lf,
            lf / ln,
            bf,
            bn,
            bf / bn
        );
    }
    println!("\nhws > 1 means the kernel optimized FOR the device beats the transplant —");
    println!("the paper's evidence that the search produces hardware-aware kernels.");
}
