//! Custom task input (App. C): define a task from a YAML config plus a
//! marker-annotated source file — the paper's "flexible user input layer
//! that supports kernel generation for a wide range of real-world use
//! cases beyond benchmarking".
//!
//! ```bash
//! cargo run --release --example custom_task
//! ```

use kernelfoundry::config::FoundryConfig;
use kernelfoundry::coordinator::EvolutionEngine;
use kernelfoundry::eval::ExecBackend;
use kernelfoundry::hwsim::DeviceProfile;
use kernelfoundry::tasks::custom;

const TASK_YAML: &str = "\
name: my_fused_norm
backward: false
workload:
  - op: norm
    elems: 8388608
    groups: 8192
  - op: elementwise
    elems: 8388608
    flops_per_elem: 4
    sfu_per_elem: 1
tests:
  command: pytest tests/test_my_fused_norm.py -q
evolution:
  max_generations: 16
";

const TASK_SOURCE: &str = "\
### KF:REFERENCE ###
def forward(x, gamma, beta):
    h = torch.layer_norm(x, x.shape[-1:], gamma, beta)
    return torch.nn.functional.gelu(h)
### KF:INSTRUCTIONS ###
Fuse the normalization and activation into a single pass; an online
normalization formulation is acceptable if numerics stay within 1e-2
relative error.
### KF:INITIAL_KERNEL ###
// starting point: coalesced but unfused translation
### KF:END ###
";

fn main() {
    // 1. Parse the App. C bundle.
    let bundle = custom::load_strings(TASK_YAML, TASK_SOURCE).expect("valid custom task");
    println!("== custom task: {} ==", bundle.spec.id);
    println!("reference:\n{}", bundle.reference_code);
    println!("user instructions: {:?}", bundle.spec.user_instructions);
    println!("pytest hook: {:?}", bundle.test_command);

    // 2. The task config's own hyperparameters override the defaults.
    let mut config = FoundryConfig::paper_defaults();
    config.apply_doc(&bundle.config);
    config.evolution.population = 6;
    println!(
        "evolution: {} generations (from task.yaml)",
        config.evolution.max_generations
    );

    // 3. Optimize — the initial kernel seeds the first prompt's parent.
    let mut engine = EvolutionEngine::new(
        config,
        bundle.spec.clone(),
        ExecBackend::HwSim(DeviceProfile::b580()),
    );
    if bundle.initial_kernel.is_some() {
        let mut init = kernelfoundry::ir::KernelGenome::direct_translation(&bundle.spec.id);
        init.mem = kernelfoundry::ir::MemoryPattern::Coalesced;
        engine.initial_genome = Some(init);
    }
    let report = engine.run(true);
    let best = report.best.expect("correct kernel");
    println!(
        "\nresult: {:.2}x over the eager baseline; the user instructions steered the model \
         toward the online reformulation (cell {:?})",
        best.speedup, best.coords
    );
    assert!(best.speedup > 1.0);
}
