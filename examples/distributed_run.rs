//! Distributed framework demo (§3.6, App. C, Fig. 4): compile workers +
//! execution workers behind a backpressured queue, with the database
//! recording every evaluation for reproducibility.
//!
//! ```bash
//! cargo run --release --example distributed_run
//! ```

use kernelfoundry::dist::{ClusterConfig, Database, DbRow, WorkerPool};
use kernelfoundry::hwsim::DeviceProfile;
use kernelfoundry::ir::{Defect, DefectKind, KernelGenome, MemoryPattern};
use kernelfoundry::tasks::catalog;
use std::sync::atomic::Ordering;

fn main() {
    let task = catalog::find_task("85_Conv2d_GroupNorm_Scale_MaxPool_Clamp").unwrap();

    // A candidate batch with a realistic defect mix.
    let genomes: Vec<KernelGenome> = (0..64)
        .map(|i| {
            let mut g = KernelGenome::direct_translation(&task.id);
            g.id = i;
            g.mem = MemoryPattern::from_level((i % 4) as usize);
            g.params.slm_pad = true;
            g.params.vec_width = 4;
            if i % 7 == 0 {
                g.defects.push(Defect { kind: DefectKind::SyntaxError, severity: 1.0 });
            }
            g
        })
        .collect();

    println!("== distributed evaluation: {} candidates ==", genomes.len());
    for (nc, ne) in [(1usize, 1usize), (2, 2), (2, 4), (4, 8)] {
        let pool = WorkerPool::new(ClusterConfig {
            compile_workers: nc,
            exec_workers: ne,
            device: DeviceProfile::b580(),
            queue_capacity: 32,
            seed: 9,
        });
        let start = std::time::Instant::now();
        let records = pool.evaluate_batch(&task, genomes.clone());
        let dt = start.elapsed().as_secs_f64();
        println!(
            "  {nc} compile + {ne} exec workers: {:>6.2}s ({:>6.1} cand/s) — {} compiled, {} rejected pre-GPU",
            dt,
            records.len() as f64 / dt,
            pool.metrics.compiled.load(Ordering::Relaxed),
            pool.metrics.compile_rejected.load(Ordering::Relaxed),
        );

        // Database server: persist everything (App. C worker type 4).
        if ne == 8 {
            let db = Database::new();
            for (i, rec) in records.iter().enumerate() {
                db.insert(DbRow::from_record("demo-run", "distributed", i, rec));
            }
            let path = std::env::temp_dir().join("kernelfoundry_demo.jsonl");
            db.save(&path).unwrap();
            println!(
                "  database: {} rows persisted to {} (inspect with `kernelfoundry report --db ...`)",
                db.len(),
                path.display()
            );
        }
    }
    println!("\nscaling exec workers shortens wall-clock while compile workers absorb rejects —");
    println!("the Fig. 4 topology: only execution workers would need GPUs.");
}
