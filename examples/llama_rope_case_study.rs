//! §5.5 case study — the END-TO-END DRIVER over the real three-layer
//! stack: Rust coordinator → AOT-compiled JAX/Pallas artifacts → PJRT.
//!
//! Reproduces the paper's Llama 3.2 rotary-positional-embedding
//! optimization: a custom task whose reference is apply_rotary_pos_emb
//! (unsqueeze + rotate-half); KernelFoundry evolves kernel genomes whose
//! variants are REAL Pallas kernels (compiled by `make artifacts`),
//! executed and ν-validated through the PJRT CPU client; finally the
//! full transformer-block forward is checked for model-level output
//! identity and timed with the optimized kernel in place.
//!
//! ```bash
//! make artifacts && cargo run --release --example llama_rope_case_study
//! ```

use kernelfoundry::config::FoundryConfig;
use kernelfoundry::coordinator::EvolutionEngine;
use kernelfoundry::eval::{check_correctness, ExecBackend};
use kernelfoundry::runtime::{Manifest, PjrtBackend, PjrtRuntime};
use kernelfoundry::tasks::catalog;
use std::path::Path;

fn main() -> kernelfoundry::util::error::Result<()> {
    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("artifacts not built — run `make artifacts` first");
        std::process::exit(2);
    }
    let manifest = Manifest::load(&artifacts)?;
    println!("== §5.5 case study: Llama RoPE on the real PJRT backend ==");
    println!("artifact library: {} artifacts, tasks {:?}", manifest.artifacts.len(), manifest.tasks());

    // ---- Phase 1: evolve the RoPE kernel on the REAL backend -------------
    let task = catalog::llama_rope_task();
    let backend = PjrtBackend::new(manifest.clone())?;
    let mut config = FoundryConfig::paper_defaults();
    config.evolution.max_generations = 10; // paper: correct in 2, 7.9x within 10
    config.evolution.population = 4;
    config.llm.models = vec!["gpt-4.1".to_string(), "gpt-5-mini".to_string()];
    let mut engine = EvolutionEngine::new(config, task, ExecBackend::Real(Box::new(backend)));
    let report = engine.run(false);

    let best = report.best.as_ref().expect("no correct kernel found");
    println!(
        "\nkernel-level result: correct kernel at iteration {:?}, best speedup {:.2}x \
         ({:.4} ms vs reference {:.4} ms) — all numerics validated with the ν-criterion \
         on real PJRT outputs",
        report.first_correct_iteration, best.speedup, best.time_ms, best.baseline_ms
    );
    println!("improvement curve:");
    for p in &report.series {
        println!("  iter {:>2}: {:.3}x", p.iteration, p.best_speedup);
    }

    // ---- Phase 2: model-level check (full transformer-block forward) ------
    println!("\nmodel-level verification: block_fwd_ref vs block_fwd_fused");
    let mut rt = PjrtRuntime::cpu()?;
    let block_ref = manifest.reference_for("block_fwd").expect("block_fwd_ref");
    let block_fused = &manifest.variants_for("block_fwd")[0];
    let out_ref = rt.execute(block_ref)?.concat();
    let out_fused = rt.execute(block_fused)?.concat();
    let rep = check_correctness(&out_ref, &out_fused);
    println!(
        "  outputs: {} elements, pass fraction {:.4}, max ν {:.2e}, cosine {:.6}",
        out_ref.len(),
        rep.pass_fraction,
        rep.max_nu,
        rep.cosine
    );
    assert!(rep.correct, "full model pass must yield identical results");

    // Forward-pass timing with the reference vs the optimized RoPE.
    let iters = 5;
    let t_ref = rt.time_batch(block_ref, iters)? / iters as f64;
    let t_fused = rt.time_batch(block_fused, iters)? / iters as f64;
    println!(
        "  block forward: reference {:.2} ms -> fused-RoPE {:.2} ms ({:+.1}% total time)",
        t_ref,
        t_fused,
        (t_fused / t_ref - 1.0) * 100.0
    );
    println!("\ncase study complete: evolution + real kernels + model-level identity all verified");
    Ok(())
}
