//! Quickstart: optimize one KernelBench task end to end with the public
//! API and inspect the result.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use kernelfoundry::config::FoundryConfig;
use kernelfoundry::coordinator::EvolutionEngine;
use kernelfoundry::eval::ExecBackend;
use kernelfoundry::hwsim::DeviceProfile;
use kernelfoundry::tasks::catalog;

fn main() {
    // 1. Pick a task (an L2 fusion pattern) and a target device profile.
    let task = catalog::find_task("1_Conv2D_ReLU_BiasAdd").expect("task exists");
    let device = DeviceProfile::b580();

    // 2. Configure: paper defaults (Table 6), shortened for a demo.
    let mut config = FoundryConfig::paper_defaults();
    config.evolution.max_generations = 20;
    config.evolution.population = 6;

    // 3. Run the evolutionary loop (+ templated parameter optimization).
    let mut engine = EvolutionEngine::new(config, task, ExecBackend::HwSim(device));
    let report = engine.run(true);

    // 4. Inspect.
    println!("== quickstart: {} ==", report.task_id);
    println!(
        "evaluated {} candidates ({} compile errors, {} incorrect)",
        report.evaluations, report.compile_errors, report.incorrect
    );
    let best = report.best.as_ref().expect("found a correct kernel");
    println!(
        "best: speedup {:.2}x over PyTorch-eager baseline (cell {:?}, model {})",
        best.speedup, best.coords, best.genome.produced_by
    );
    println!("improvement curve (cumulative best speedup):");
    for p in report.series.iter().step_by(4) {
        println!("  iter {:>3}: {:.3}x  [{} cells occupied]", p.iteration, p.best_speedup, p.cells_occupied);
    }
    println!("\ngenerated SYCL kernel:\n{}", best.source);
    assert!(best.speedup > 1.0);
}
