"""AOT compilation driver (Layer-2 -> artifacts).

Lowers every kernel variant (x parameter grid) and the transformer-block
forwards to HLO *text* + a manifest consumed by the rust runtime.

HLO text — NOT `.serialize()` — is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 (the version the published `xla` 0.1.6 crate links) rejects; the
text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Python runs ONCE at build time (`make artifacts`); the rust binary is
self-contained afterwards.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile import model
from compile.kernels import fused as k_fused
from compile.kernels import layernorm as k_ln
from compile.kernels import matmul as k_mm
from compile.kernels import reduction as k_red
from compile.kernels import ref
from compile.kernels import rope as k_rope


def to_hlo_text(fn, *example_args) -> str:
    """jit -> lower -> stablehlo -> XlaComputation -> HLO text."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def build_registry():
    """All artifacts: (name, task, role, params, fn, input shapes).

    `role` is 'reference' (baseline + expected-output source) or
    'variant'. Constants (weights, rotary tables) are closed over so the
    rust side only feeds deterministic normal tensors.
    """
    entries = []

    # ---- llama_rope (section 5.5) ----------------------------------------
    B, H, S, D = 2, model.HEADS, model.SEQ, model.HEAD_DIM
    cos, sin = k_rope.make_cos_sin(S, D)
    qk = [[B, H, S, D], [B, H, S, D]]
    entries.append(
        ("rope_ref", "llama_rope", "reference", {},
         lambda q, k: ref.rope(q, k, cos, sin), qk)
    )
    for bs in k_rope.SEQ_BLOCK_OPTIONS:
        entries.append(
            (f"rope_naive_bs{bs}", "llama_rope", "variant", {"bs": bs},
             lambda q, k, bs=bs: k_rope.rope_naive(q, k, cos, sin, bs=bs), qk)
        )
        entries.append(
            (f"rope_fused_bs{bs}", "llama_rope", "variant", {"bs": bs},
             lambda q, k, bs=bs: k_rope.rope_fused(q, k, cos, sin, bs=bs), qk)
        )

    # ---- softmax (Table 4 / reformulation) --------------------------------
    SM = [256, 512]
    entries.append(
        ("softmax_ref", "softmax_real", "reference", {},
         lambda x: (ref.softmax(x),), [SM])
    )
    for br in [8, 16]:
        entries.append(
            (f"softmax_twopass_br{br}", "softmax_real", "variant",
             {"br": br, "algo": "twopass"},
             lambda x, br=br: (k_sm_twopass(x, br),), [SM])
        )
        entries.append(
            (f"softmax_online_br{br}", "softmax_real", "variant",
             {"br": br, "algo": "online"},
             lambda x, br=br: (k_sm_online(x, br),), [SM])
        )

    # ---- matmul ------------------------------------------------------------
    MM = [[256, 256], [256, 256]]
    entries.append(
        ("matmul_ref", "matmul_real", "reference", {},
         lambda x, y: (ref.matmul(x, y),), MM)
    )
    for bm, bn in [(16, 16), (32, 32), (64, 64)]:
        entries.append(
            (f"matmul_bm{bm}_bn{bn}", "matmul_real", "variant",
             {"bm": bm, "bn": bn},
             lambda x, y, bm=bm, bn=bn: (k_mm.matmul(x, y, bm=bm, bn=bn),), MM)
        )

    # ---- concat + layernorm (Table 4 custom task) ---------------------------
    LN = [256, 256]
    gamma = jnp.ones((LN[1],), jnp.float32)
    beta = jnp.zeros((LN[1],), jnp.float32)
    entries.append(
        ("concat_ln_ref", "concat_layernorm_real", "reference", {},
         lambda x: (ref.concat_layernorm(x, gamma, beta),), [LN])
    )
    for br in [8, 16]:
        entries.append(
            (f"concat_ln_fused_br{br}", "concat_layernorm_real", "variant", {"br": br},
             lambda x, br=br: (k_ln.concat_layernorm(x, gamma, beta, br=br),), [LN])
        )

    # ---- fused elementwise chain ---------------------------------------------
    FE = [256, 512]
    key = jax.random.PRNGKey(3)
    bias = jax.random.normal(key, (FE[1],), jnp.float32)
    scale = jax.random.normal(jax.random.PRNGKey(4), (FE[1],), jnp.float32)
    entries.append(
        ("fused_chain_ref", "fused_chain_real", "reference", {},
         lambda x: (ref.bias_gelu_scale(x, bias, scale),), [FE])
    )
    entries.append(
        ("fused_chain_naive", "fused_chain_real", "variant", {"fused": 0},
         lambda x: (k_fused.bias_gelu_scale_naive(x, bias, scale),), [FE])
    )
    entries.append(
        ("fused_chain_fused", "fused_chain_real", "variant", {"fused": 1},
         lambda x: (k_fused.bias_gelu_scale_fused(x, bias, scale),), [FE])
    )

    # ---- sum reduction -----------------------------------------------------------
    RD = [256, 1024]
    entries.append(
        ("sum_reduce_ref", "sum_reduction_real", "reference", {},
         lambda x: (ref.sum_reduce(x),), [RD])
    )
    for br in [8, 16]:
        entries.append(
            (f"sum_reduce_br{br}", "sum_reduction_real", "variant", {"br": br},
             lambda x, br=br: (k_red.sum_reduce(x, br),), [RD])
        )

    # ---- transformer block forward (section 5.5 model-level check) ---------------
    params = model.init_params(0)
    X = [model.BATCH, model.SEQ, model.HIDDEN]
    entries.append(
        ("block_fwd_ref", "block_fwd", "reference", {},
         lambda x: model.block_forward_ref(x, params), [X])
    )
    entries.append(
        ("block_fwd_fused", "block_fwd", "variant", {"rope": "fused"},
         lambda x: model.block_forward_fused(x, params), [X])
    )
    return entries


# Late-bound wrappers so the registry closure stays readable.
def k_sm_twopass(x, br):
    from compile.kernels import softmax as k_sm
    return k_sm.softmax_twopass(x, br=br)


def k_sm_online(x, br):
    from compile.kernels import softmax as k_sm
    return k_sm.softmax_online(x, br=br)


def source_fingerprint() -> str:
    """Hash of the compile-path sources — lets `make artifacts` skip the
    (slow) lowering when nothing changed."""
    root = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for dirpath, _, files in sorted(os.walk(root)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(dirpath, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument("--only", default=None, help="comma-separated artifact names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    fingerprint = source_fingerprint()
    manifest_path = os.path.join(args.out, "manifest.json")
    if os.path.exists(manifest_path) and args.only is None:
        with open(manifest_path) as f:
            old = json.load(f)
        if old.get("fingerprint") == fingerprint:
            print(f"artifacts up to date (fingerprint {fingerprint[:12]}); skipping")
            return

    only = set(args.only.split(",")) if args.only else None
    manifest = {"fingerprint": fingerprint, "artifacts": {}}
    for name, task, role, params, fn, shapes in build_registry():
        if only and name not in only:
            continue
        example = [spec(s) for s in shapes]
        print(f"lowering {name} ({task}, {role}) ...", flush=True)
        text = to_hlo_text(fn, *example)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": fname,
            "task": task,
            "role": role,
            "params": params,
            "inputs": [{"shape": s, "seed": i + 1} for i, s in enumerate(shapes)],
        }
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(manifest['artifacts'])} artifacts + manifest to {args.out}")


if __name__ == "__main__":
    main()
