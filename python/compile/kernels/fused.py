"""Layer-1 Pallas kernels: fused elementwise chains (the L2 fusion
pattern: bias + GELU + scale)."""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

GELU_C = 0.7978845608028654


def _bias_kernel(x_ref, b_ref, o_ref):
    o_ref[...] = x_ref[...] + b_ref[...]


def _gelu_kernel(x_ref, o_ref):
    h = x_ref[...]
    o_ref[...] = 0.5 * h * (1.0 + jnp.tanh(GELU_C * (h + 0.044715 * h**3)))


def _scale_kernel(x_ref, s_ref, o_ref):
    o_ref[...] = x_ref[...] * s_ref[...]


def _fused_kernel(x_ref, b_ref, s_ref, o_ref):
    h = x_ref[...] + b_ref[...]
    g = 0.5 * h * (1.0 + jnp.tanh(GELU_C * (h + 0.044715 * h**3)))
    o_ref[...] = g * s_ref[...]


def _ew_call(kernel, out_rows, br, *args):
    rows, cols = args[0].shape
    assert rows % br == 0
    n_in = len(args)
    in_specs = []
    for a in args:
        if a.ndim == 2:
            in_specs.append(pl.BlockSpec((br, cols), lambda i: (i, 0)))
        else:
            in_specs.append(pl.BlockSpec((cols,), lambda i: (0,)))
    return pl.pallas_call(
        kernel,
        grid=(rows // br,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(*args)


@functools.partial(jax.jit, static_argnames=("br",))
def bias_gelu_scale_naive(x, bias, scale, br: int = 16):
    """Direct translation: three kernel launches, two intermediate
    tensors round-trip through memory."""
    h = _ew_call(_bias_kernel, None, br, x, bias)
    g = _ew_call(_gelu_kernel, None, br, h)
    return _ew_call(_scale_kernel, None, br, g, scale)


@functools.partial(jax.jit, static_argnames=("br",))
def bias_gelu_scale_fused(x, bias, scale, br: int = 16):
    """Fused single-pass kernel."""
    return _ew_call(_fused_kernel, None, br, x, bias, scale)


ROW_BLOCK_OPTIONS = [8, 16, 32, 64]
