"""Layer-1 Pallas kernels: layernorm and the fused concat(x, LN(x)) op
(the section 5.4 oneDNN comparison's custom-task kernel)."""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    o_ref[...] = (x - mu) * jax.lax.rsqrt(var + eps) * g_ref[...] + b_ref[...]


@functools.partial(jax.jit, static_argnames=("br",))
def layernorm(x, gamma, beta, br: int = 16, eps: float = 1e-5):
    rows, cols = x.shape
    assert rows % br == 0
    kernel = functools.partial(_layernorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
            pl.BlockSpec((cols,), lambda i: (0,)),
            pl.BlockSpec((cols,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(x, gamma, beta)


def _concat_ln_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    # Fused single pass: read x once, write [x, LN(x)] — the traffic
    # saving oneDNN's two separate primitives cannot achieve.
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    ln = (x - mu) * jax.lax.rsqrt(var + eps) * g_ref[...] + b_ref[...]
    o_ref[...] = jnp.concatenate([x, ln], axis=-1)


@functools.partial(jax.jit, static_argnames=("br",))
def concat_layernorm(x, gamma, beta, br: int = 16, eps: float = 1e-5):
    rows, cols = x.shape
    assert rows % br == 0
    kernel = functools.partial(_concat_ln_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
            pl.BlockSpec((cols,), lambda i: (0,)),
            pl.BlockSpec((cols,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, 2 * cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, 2 * cols), jnp.float32),
        interpret=True,
    )(x, gamma, beta)


ROW_BLOCK_OPTIONS = [8, 16, 32]
