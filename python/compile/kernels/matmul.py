"""Layer-1 Pallas kernel: tiled matmul.

Hardware adaptation (DESIGN.md, Hardware-Adaptation section): the paper's
SYCL SLM-tiled GEMM becomes a Pallas kernel whose BlockSpec expresses the
HBM<->VMEM schedule. Block sizes are the templated parameters (section
3.4) — `make_matmul(bm, bn)` is the dispatch grid the evaluation pipeline
sweeps.

interpret=True everywhere: the CPU PJRT client cannot run Mosaic
custom-calls; numerics are identical.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref):
    # One (bm, bn) output tile per program; K is kept resident (the
    # VMEM-friendly "small-K panel" schedule).
    o_ref[...] = jnp.dot(x_ref[...], y_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def matmul(x, y, bm: int = 32, bn: int = 32):
    """Tiled matmul via pallas_call; bm/bn are the tile parameters."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert m % bm == 0 and n % bn == 0, "shape must be divisible by tile"
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


#: Parameter grid exposed to the rust evaluation pipeline (section 3.4).
TILE_OPTIONS = [(16, 16), (32, 32), (64, 64), (32, 64)]
