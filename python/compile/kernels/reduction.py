"""Layer-1 Pallas kernel: row-sum reduction (Table 4 sum-reduction op)."""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sum_kernel(x_ref, o_ref):
    o_ref[...] = jnp.sum(x_ref[...], axis=-1)


@functools.partial(jax.jit, static_argnames=("br",))
def sum_reduce(x, br: int = 16):
    rows, cols = x.shape
    assert rows % br == 0
    return pl.pallas_call(
        _sum_kernel,
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.float32),
        interpret=True,
    )(x)


ROW_BLOCK_OPTIONS = [8, 16, 32]
