"""Pure-jnp reference oracles for every Pallas kernel (Layer-1
correctness ground truth).

Each function is the mathematical definition the corresponding Pallas
kernel must reproduce; pytest compares kernel outputs against these with
the paper's strict relative-precision criterion (see tests).
"""

import jax.numpy as jnp


def matmul(x, y):
    """Dense matmul in f32."""
    return jnp.matmul(x, y)


def softmax(x):
    """Row-wise softmax over the last axis (numerically stable 2-pass)."""
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def layernorm(x, gamma, beta, eps=1e-5):
    """Layer normalization over the last axis."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def concat_layernorm(x, gamma, beta, eps=1e-5):
    """Section 5.4 oneDNN comparison op: concat(x, layernorm(x))."""
    return jnp.concatenate([x, layernorm(x, gamma, beta, eps)], axis=-1)


def rotate_half(x):
    """Llama rotate-half: (-x2, x1) on the last-dim halves."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([-x2, x1], axis=-1)


def rope(q, k, cos, sin):
    """apply_rotary_pos_emb (section 5.5): unsqueeze + rotate-half.

    q, k: (B, H, S, D); cos, sin: (S, D) broadcast over batch and heads.
    """
    cos = cos[None, None, :, :]
    sin = sin[None, None, :, :]
    q_out = q * cos + rotate_half(q) * sin
    k_out = k * cos + rotate_half(k) * sin
    return q_out, k_out


def bias_gelu_scale(x, bias, scale):
    """L2-style fused elementwise chain: scale * gelu(x + bias)."""
    h = x + bias
    g = 0.5 * h * (1.0 + jnp.tanh(0.7978845608028654 * (h + 0.044715 * h**3)))
    return g * scale


def sum_reduce(x):
    """Sum over the last axis."""
    return jnp.sum(x, axis=-1)
