"""Layer-1 Pallas kernels: rotary positional embedding (section 5.5 case
study: the Llama 3.2 apply_rotary_pos_emb bottleneck).

Variants along the paper's optimization dimensions:

* `rope_naive` — direct translation: two separate kernel launches (one
  for q, one for k), materializing rotate_half.
* `rope_fused` — single fused kernel over q and k with the rotate-half
  expressed as in-register index arithmetic; seq-block parametric.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rope_one_kernel(x_ref, cos_ref, sin_ref, o_ref):
    x = x_ref[...]
    half = x.shape[-1] // 2
    rot = jnp.concatenate([-x[..., half:], x[..., :half]], axis=-1)
    o_ref[...] = x * cos_ref[...][None, None, :, :] + rot * sin_ref[...][None, None, :, :]


def _rope_call(x, cos, sin, bs: int):
    b, h, s, d = x.shape
    assert s % bs == 0
    return pl.pallas_call(
        _rope_one_kernel,
        grid=(s // bs,),
        in_specs=[
            pl.BlockSpec((b, h, bs, d), lambda i: (0, 0, i, 0)),
            pl.BlockSpec((bs, d), lambda i: (i, 0)),
            pl.BlockSpec((bs, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((b, h, bs, d), lambda i: (0, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), jnp.float32),
        interpret=True,
    )(x, cos, sin)


@functools.partial(jax.jit, static_argnames=("bs",))
def rope_naive(q, k, cos, sin, bs: int = 32):
    """Two separate launches — the PyTorch-eager-like shape."""
    return _rope_call(q, cos, sin, bs), _rope_call(k, cos, sin, bs)


def _rope_fused_kernel(q_ref, k_ref, cos_ref, sin_ref, qo_ref, ko_ref):
    cos = cos_ref[...][None, None, :, :]
    sin = sin_ref[...][None, None, :, :]
    for x_ref, o_ref in ((q_ref, qo_ref), (k_ref, ko_ref)):
        x = x_ref[...]
        half = x.shape[-1] // 2
        rot = jnp.concatenate([-x[..., half:], x[..., :half]], axis=-1)
        o_ref[...] = x * cos + rot * sin


@functools.partial(jax.jit, static_argnames=("bs",))
def rope_fused(q, k, cos, sin, bs: int = 32):
    """Single fused launch for q and k: cos/sin read once, both outputs
    written in one pass."""
    b, h, s, d = q.shape
    assert q.shape == k.shape and s % bs == 0
    return pl.pallas_call(
        _rope_fused_kernel,
        grid=(s // bs,),
        in_specs=[
            pl.BlockSpec((b, h, bs, d), lambda i: (0, 0, i, 0)),
            pl.BlockSpec((b, h, bs, d), lambda i: (0, 0, i, 0)),
            pl.BlockSpec((bs, d), lambda i: (i, 0)),
            pl.BlockSpec((bs, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((b, h, bs, d), lambda i: (0, 0, i, 0)),
            pl.BlockSpec((b, h, bs, d), lambda i: (0, 0, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, d), jnp.float32),
            jax.ShapeDtypeStruct((b, h, s, d), jnp.float32),
        ],
        interpret=True,
    )(q, k, cos, sin)


def make_cos_sin(seq: int, dim: int, base: float = 10000.0):
    """Llama-style rotary tables: cos/sin of shape (seq, dim)."""
    inv_freq = 1.0 / (base ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    t = jnp.arange(seq, dtype=jnp.float32)
    freqs = jnp.outer(t, inv_freq)
    emb = jnp.concatenate([freqs, freqs], axis=-1)
    return jnp.cos(emb), jnp.sin(emb)


SEQ_BLOCK_OPTIONS = [16, 32, 64]
