"""Layer-1 Pallas kernels: row softmax.

Two algorithmic variants matching the paper's d_algo dimension:

* `softmax_twopass` — the direct translation (d_algo level 0/1): max
  pass, then exp/sum/normalize.
* `softmax_online` — the reformulated algorithm (d_algo level 2): a
  single streaming pass with running max and exp2-based rescaling, the
  Flash-Attention-4-inspired formulation of section 5.4's user guidance.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LOG2E = 1.4426950408889634


def _twopass_kernel(x_ref, o_ref):
    x = x_ref[...]
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


def _online_kernel(x_ref, o_ref, *, chunk: int):
    """Streaming softmax: process the row in chunks, maintaining a
    running max and a running sum rescaled via exp2."""
    x = x_ref[...]
    n = x.shape[-1]
    n_chunks = n // chunk

    def body(i, carry):
        run_max, run_sum = carry
        sl = jax.lax.dynamic_slice_in_dim(x, i * chunk, chunk, axis=-1)
        new_max = jnp.maximum(run_max, jnp.max(sl, axis=-1, keepdims=True))
        # exp2-based rescaling reduces SFU pressure vs exp (section 5.4).
        run_sum = run_sum * jnp.exp2((run_max - new_max) * LOG2E) + jnp.sum(
            jnp.exp2((sl - new_max) * LOG2E), axis=-1, keepdims=True
        )
        return new_max, run_sum

    init = (
        jnp.full(x.shape[:-1] + (1,), -jnp.inf, dtype=x.dtype),
        jnp.zeros(x.shape[:-1] + (1,), dtype=x.dtype),
    )
    run_max, run_sum = jax.lax.fori_loop(0, n_chunks, body, init)
    o_ref[...] = jnp.exp2((x - run_max) * LOG2E) / run_sum


@functools.partial(jax.jit, static_argnames=("br",))
def softmax_twopass(x, br: int = 16):
    rows, cols = x.shape
    assert rows % br == 0
    return pl.pallas_call(
        _twopass_kernel,
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(x)


@functools.partial(jax.jit, static_argnames=("br", "chunk"))
def softmax_online(x, br: int = 16, chunk: int = 64):
    rows, cols = x.shape
    assert rows % br == 0 and cols % chunk == 0
    kernel = functools.partial(_online_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(x)


ROW_BLOCK_OPTIONS = [8, 16, 32]
CHUNK_OPTIONS = [32, 64, 128]
