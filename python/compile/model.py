"""Layer-2 JAX model: a small Llama-style transformer block.

The section 5.5 case study targets apply_rotary_pos_emb inside the
Llama 3.2 attention block. This module defines a scaled-down block whose
forward pass can be lowered with either the *reference* RoPE (pure jnp,
eager-shaped) or the *optimized* fused Pallas RoPE kernel — both lower to
HLO text consumed by the rust runtime, which verifies model-level output
identity and measures the forward-pass speedup.
"""

import jax
import jax.numpy as jnp

from compile.kernels import fused as k_fused
from compile.kernels import ref
from compile.kernels import rope as k_rope

# Scaled-down Llama-3.2-ish block dimensions (hidden 256, 4 heads,
# head_dim 64, seq 128, batch 2) — small enough for CPU interpret mode.
BATCH = 2
HEADS = 4
HEAD_DIM = 64
SEQ = 128
HIDDEN = HEADS * HEAD_DIM
FFN = 2 * HIDDEN


def init_params(seed: int = 0):
    """Deterministic block parameters."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 8)
    s = 0.02
    return {
        "wq": jax.random.normal(ks[0], (HIDDEN, HIDDEN), jnp.float32) * s,
        "wk": jax.random.normal(ks[1], (HIDDEN, HIDDEN), jnp.float32) * s,
        "wv": jax.random.normal(ks[2], (HIDDEN, HIDDEN), jnp.float32) * s,
        "wo": jax.random.normal(ks[3], (HIDDEN, HIDDEN), jnp.float32) * s,
        "w1": jax.random.normal(ks[4], (HIDDEN, FFN), jnp.float32) * s,
        "w2": jax.random.normal(ks[5], (FFN, HIDDEN), jnp.float32) * s,
        "gamma": jnp.ones((HIDDEN,), jnp.float32),
        "beta": jnp.zeros((HIDDEN,), jnp.float32),
    }


def _split_heads(x):
    b, s, _ = x.shape
    return x.reshape(b, s, HEADS, HEAD_DIM).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, s, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * d)


def block_forward(x, params, use_fused_rope: bool):
    """One transformer block forward: LN -> RoPE attention -> MLP.

    `use_fused_rope` switches between the reference rotate-half RoPE and
    the fused Pallas kernel; outputs must be numerically identical.
    """
    cos, sin = k_rope.make_cos_sin(SEQ, HEAD_DIM)
    h = ref.layernorm(x, params["gamma"], params["beta"])
    q = _split_heads(h @ params["wq"])
    k = _split_heads(h @ params["wk"])
    v = _split_heads(h @ params["wv"])

    if use_fused_rope:
        q, k = k_rope.rope_fused(q, k, cos, sin, bs=32)
    else:
        q, k = ref.rope(q, k, cos, sin)

    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(HEAD_DIM))
    attn = ref.softmax(scores.reshape(-1, SEQ)).reshape(scores.shape)
    ctx = _merge_heads(jnp.einsum("bhqk,bhkd->bhqd", attn, v))
    x = x + ctx @ params["wo"]

    # MLP with the fused bias-gelu-scale kernel path exercised via jnp
    # (kernel variants are AOT'd separately).
    m = x @ params["w1"]
    m = 0.5 * m * (1.0 + jnp.tanh(0.7978845608028654 * (m + 0.044715 * m**3)))
    return x + m @ params["w2"]


def block_forward_ref(x, params):
    return (block_forward(x, params, use_fused_rope=False),)


def block_forward_fused(x, params):
    return (block_forward(x, params, use_fused_rope=True),)


def example_input(seed: int = 1):
    key = jax.random.PRNGKey(seed)
    return jax.random.normal(key, (BATCH, SEQ, HIDDEN), jnp.float32)
