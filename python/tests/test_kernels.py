"""Layer-1 correctness: every Pallas kernel vs its pure-jnp oracle.

Uses hypothesis to sweep shapes and parameter grids (the paper's strict
relative-precision criterion nu < 0.01 on >= 99% of elements, section 4,
is asserted alongside plain allclose)."""

import sys
import os

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused as k_fused
from compile.kernels import layernorm as k_ln
from compile.kernels import matmul as k_mm
from compile.kernels import reduction as k_red
from compile.kernels import ref
from compile.kernels import rope as k_rope
from compile.kernels import softmax as k_sm

SETTINGS = settings(max_examples=8, deadline=None)


def nu_correct(expected, actual, nu_threshold=0.01, pass_fraction=0.99):
    """The paper's section 4 criterion."""
    e = np.asarray(expected, dtype=np.float64)
    a = np.asarray(actual, dtype=np.float64)
    nu = np.abs(e - a) / (np.abs(e) + 1e-8)
    return (nu < nu_threshold).mean() >= pass_fraction


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------- matmul

@SETTINGS
@given(
    m=st.sampled_from([32, 64, 128]),
    n=st.sampled_from([32, 64]),
    k=st.sampled_from([16, 64, 96]),
    tile=st.sampled_from([(16, 16), (32, 32)]),
)
def test_matmul_matches_ref(m, n, k, tile):
    bm, bn = tile
    if m % bm or n % bn:
        return
    x, y = rand(1, (m, k)), rand(2, (k, n))
    out = k_mm.matmul(x, y, bm=bm, bn=bn)
    np.testing.assert_allclose(out, ref.matmul(x, y), rtol=2e-5, atol=1e-5)
    assert nu_correct(ref.matmul(x, y), out)


# ---------------------------------------------------------------- softmax

@SETTINGS
@given(
    rows=st.sampled_from([16, 32, 64]),
    cols=st.sampled_from([64, 128, 256]),
    br=st.sampled_from([8, 16]),
)
def test_softmax_twopass(rows, cols, br):
    if rows % br:
        return
    x = rand(3, (rows, cols)) * 4.0
    out = k_sm.softmax_twopass(x, br=br)
    np.testing.assert_allclose(out, ref.softmax(x), rtol=1e-5, atol=1e-6)


@SETTINGS
@given(
    rows=st.sampled_from([16, 32]),
    cols=st.sampled_from([64, 128, 256]),
    chunk=st.sampled_from([32, 64]),
)
def test_softmax_online_reformulation(rows, cols, chunk):
    if cols % chunk:
        return
    x = rand(4, (rows, cols)) * 6.0  # wide range stresses the rescaling
    out = k_sm.softmax_online(x, br=8, chunk=chunk)
    np.testing.assert_allclose(out, ref.softmax(x), rtol=1e-4, atol=1e-6)
    assert nu_correct(ref.softmax(x), out)
    rowsums = jnp.sum(out, axis=-1)
    np.testing.assert_allclose(rowsums, jnp.ones_like(rowsums), rtol=1e-5)


# ---------------------------------------------------------------- layernorm

@SETTINGS
@given(rows=st.sampled_from([16, 32, 64]), cols=st.sampled_from([64, 128]))
def test_layernorm(rows, cols):
    x = rand(5, (rows, cols))
    gamma = rand(6, (cols,)) * 0.1 + 1.0
    beta = rand(7, (cols,)) * 0.1
    out = k_ln.layernorm(x, gamma, beta, br=8)
    np.testing.assert_allclose(out, ref.layernorm(x, gamma, beta), rtol=1e-4, atol=1e-5)


@SETTINGS
@given(rows=st.sampled_from([16, 32]), cols=st.sampled_from([64, 128]))
def test_concat_layernorm_fused(rows, cols):
    x = rand(8, (rows, cols))
    gamma = jnp.ones((cols,))
    beta = jnp.zeros((cols,))
    out = k_ln.concat_layernorm(x, gamma, beta, br=8)
    expect = ref.concat_layernorm(x, gamma, beta)
    assert out.shape == (rows, 2 * cols)
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-5)
    # First half is x verbatim (the concat's pass-through lane).
    np.testing.assert_allclose(out[:, :cols], x, rtol=1e-6)


# ---------------------------------------------------------------- rope

@SETTINGS
@given(
    seq=st.sampled_from([32, 64, 128]),
    dim=st.sampled_from([32, 64]),
    bs=st.sampled_from([16, 32]),
)
def test_rope_variants_match_ref(seq, dim, bs):
    if seq % bs:
        return
    q = rand(9, (2, 2, seq, dim))
    k = rand(10, (2, 2, seq, dim))
    cos, sin = k_rope.make_cos_sin(seq, dim)
    qr, kr = ref.rope(q, k, cos, sin)
    qn, kn = k_rope.rope_naive(q, k, cos, sin, bs=bs)
    qf, kf = k_rope.rope_fused(q, k, cos, sin, bs=bs)
    for got, want in [(qn, qr), (kn, kr), (qf, qr), (kf, kr)]:
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
        assert nu_correct(want, got)


def test_rope_preserves_norm():
    """Rotary embedding is a rotation: per-pair norms are preserved."""
    q = rand(11, (1, 1, 32, 64))
    cos, sin = k_rope.make_cos_sin(32, 64)
    qf, _ = k_rope.rope_fused(q, q, cos, sin, bs=16)
    # Norm over the rotated pairs (d/2 pairs of (x1, x2)).
    def pair_norms(x):
        half = x.shape[-1] // 2
        return x[..., :half] ** 2 + x[..., half:] ** 2
    np.testing.assert_allclose(pair_norms(qf), pair_norms(q), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------- fused chain

@SETTINGS
@given(rows=st.sampled_from([16, 32, 64]), cols=st.sampled_from([64, 128]))
def test_fused_chain_equals_naive_and_ref(rows, cols):
    x = rand(12, (rows, cols))
    bias = rand(13, (cols,))
    scale = rand(14, (cols,))
    want = ref.bias_gelu_scale(x, bias, scale)
    naive = k_fused.bias_gelu_scale_naive(x, bias, scale, br=8)
    fused = k_fused.bias_gelu_scale_fused(x, bias, scale, br=8)
    np.testing.assert_allclose(naive, want, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(fused, want, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(fused, naive, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------- reduction

@SETTINGS
@given(rows=st.sampled_from([16, 32, 64]), cols=st.sampled_from([128, 1024]))
def test_sum_reduce(rows, cols):
    x = rand(15, (rows, cols))
    out = k_red.sum_reduce(x, br=8)
    np.testing.assert_allclose(out, ref.sum_reduce(x), rtol=1e-4, atol=1e-3)


# ------------------------------------------------- strict-nu motivating case

def test_nu_criterion_rejects_absolute_tolerance_trap():
    """Small outputs with large relative error pass abs-tol 1e-2 but must
    fail the paper's nu criterion (section 4)."""
    y = np.full(1000, 1e-3, dtype=np.float32)
    yh = np.full(1000, 6e-3, dtype=np.float32)
    assert np.allclose(y, yh, atol=1e-2)  # the loose KernelBench check
    assert not nu_correct(y, yh)  # the paper's check
