"""Layer-2 model tests: transformer block shapes + fused-RoPE identity +
AOT manifest sanity."""

import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


@pytest.fixture(scope="module")
def params():
    return model.init_params(0)


@pytest.fixture(scope="module")
def x():
    return model.example_input(1)


def test_block_forward_shape(params, x):
    (out,) = model.block_forward_ref(x, params)
    assert out.shape == (model.BATCH, model.SEQ, model.HIDDEN)
    assert jnp.isfinite(out).all()


def test_fused_rope_is_model_level_identical(params, x):
    """The section 5.5 correctness protocol: a full model pass with the
    optimized kernel yields identical results."""
    (ref_out,) = model.block_forward_ref(x, params)
    (fused_out,) = model.block_forward_fused(x, params)
    np.testing.assert_allclose(fused_out, ref_out, rtol=1e-5, atol=1e-6)
    # Strict nu criterion as well.
    nu = np.abs(ref_out - fused_out) / (np.abs(ref_out) + 1e-8)
    assert (nu < 0.01).mean() >= 0.99


def test_params_deterministic():
    a = model.init_params(0)
    b = model.init_params(0)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def test_manifest_when_built():
    path = os.path.join(
        os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))),
        "artifacts",
        "manifest.json",
    )
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    with open(path) as f:
        manifest = json.load(f)
    arts = manifest["artifacts"]
    assert len(arts) >= 20
    tasks = {a["task"] for a in arts.values()}
    for t in ["llama_rope", "softmax_real", "matmul_real", "block_fwd"]:
        assert t in tasks
    # Every task has exactly one reference artifact.
    for t in tasks:
        refs = [a for a in arts.values() if a["task"] == t and a["role"] == "reference"]
        assert len(refs) == 1, t
    # Every artifact file exists and is non-trivial HLO text.
    base = os.path.dirname(path)
    for name, a in arts.items():
        p = os.path.join(base, a["file"])
        assert os.path.exists(p), name
        with open(p) as f:
            head = f.read(200)
        assert "HloModule" in head, name
