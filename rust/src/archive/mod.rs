//! MAP-Elites evolutionary archive (§3.2).
//!
//! Partitions the kernel solution space into a discrete grid over the
//! behavioral coordinates `(d_mem, d_algo, d_sync)` (4 bins each → 64
//! cells by default) and keeps the highest-fitness kernel (*elite*) per
//! occupied cell. Insertion replaces the incumbent only on strict fitness
//! improvement (or an empty cell), so "the archive cannot collapse because
//! each cell evolves independently".

use crate::classify::{cell_index, coords_of, Coords};
use crate::ir::KernelGenome;
use crate::util::json::Json;

/// One archived elite: genome plus its evaluation outcome.
#[derive(Debug, Clone)]
pub struct Elite {
    pub genome: KernelGenome,
    pub coords: Coords,
    pub fitness: f64,
    pub speedup: f64,
    pub runtime_ms: f64,
    /// Iteration at which this elite entered the archive.
    pub iteration: usize,
}

/// Result of an insertion attempt, mirroring the paper's transition
/// outcomes (§3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InsertOutcome {
    /// Candidate filled a previously-empty cell.
    NewCell,
    /// Candidate replaced the incumbent elite.
    Improved,
    /// Candidate was competitive (within tolerance) but did not update
    /// the archive.
    Neutral,
    /// Candidate was strictly worse.
    Rejected,
}

impl InsertOutcome {
    pub fn updated_archive(self) -> bool {
        matches!(self, InsertOutcome::NewCell | InsertOutcome::Improved)
    }
}

/// The MAP-Elites grid.
#[derive(Debug, Clone)]
pub struct MapElites {
    bins: usize,
    cells: Vec<Option<Elite>>,
    /// Relative fitness tolerance for classifying "neutral" outcomes.
    neutral_tolerance: f64,
    insertions: usize,
    attempts: usize,
}

impl MapElites {
    pub fn new(bins: usize) -> MapElites {
        MapElites {
            bins,
            cells: vec![None; bins * bins * bins],
            neutral_tolerance: 0.02,
            insertions: 0,
            attempts: 0,
        }
    }

    pub fn bins(&self) -> usize {
        self.bins
    }

    pub fn n_cells(&self) -> usize {
        self.cells.len()
    }

    /// Attempt to insert a candidate. Replaces the incumbent iff the cell
    /// is empty or the candidate's fitness is strictly higher.
    pub fn insert(&mut self, elite: Elite) -> InsertOutcome {
        self.attempts += 1;
        let idx = cell_index(elite.coords, self.bins);
        match &self.cells[idx] {
            None => {
                self.cells[idx] = Some(elite);
                self.insertions += 1;
                InsertOutcome::NewCell
            }
            Some(incumbent) => {
                if elite.fitness > incumbent.fitness {
                    self.cells[idx] = Some(elite);
                    self.insertions += 1;
                    InsertOutcome::Improved
                } else if elite.fitness >= incumbent.fitness * (1.0 - self.neutral_tolerance) {
                    InsertOutcome::Neutral
                } else {
                    InsertOutcome::Rejected
                }
            }
        }
    }

    pub fn get(&self, coords: Coords) -> Option<&Elite> {
        self.cells[cell_index(coords, self.bins)].as_ref()
    }

    pub fn occupied(&self) -> impl Iterator<Item = &Elite> {
        self.cells.iter().filter_map(|c| c.as_ref())
    }

    pub fn occupied_coords(&self) -> Vec<Coords> {
        self.cells
            .iter()
            .enumerate()
            .filter(|(_, c)| c.is_some())
            .map(|(i, _)| coords_of(i, self.bins))
            .collect()
    }

    /// Coordinates of empty cells (exploration targets for ∇E).
    pub fn empty_coords(&self) -> Vec<Coords> {
        self.cells
            .iter()
            .enumerate()
            .filter(|(_, c)| c.is_none())
            .map(|(i, _)| coords_of(i, self.bins))
            .collect()
    }

    pub fn n_occupied(&self) -> usize {
        self.cells.iter().filter(|c| c.is_some()).count()
    }

    /// Coverage: fraction of cells occupied.
    pub fn coverage(&self) -> f64 {
        self.n_occupied() as f64 / self.n_cells() as f64
    }

    /// QD-score: sum of elite fitnesses (standard quality-diversity metric).
    pub fn qd_score(&self) -> f64 {
        self.occupied().map(|e| e.fitness).sum()
    }

    /// The globally best elite.
    pub fn best(&self) -> Option<&Elite> {
        self.occupied()
            .max_by(|a, b| a.fitness.partial_cmp(&b.fitness).unwrap())
    }

    /// Maximum fitness in the archive (0.0 when empty) — `f_max` in eq. 3.
    pub fn f_max(&self) -> f64 {
        self.occupied().map(|e| e.fitness).fold(0.0, f64::max)
    }

    /// Cells whose elite fitness is below `threshold` — together with the
    /// empty cells these form the ∇E target set `E` (eq. 3).
    pub fn low_quality_coords(&self, threshold: f64) -> Vec<(Coords, f64)> {
        self.cells
            .iter()
            .enumerate()
            .filter_map(|(i, c)| c.as_ref().map(|e| (i, e.fitness)))
            .filter(|(_, f)| *f < threshold)
            .map(|(i, f)| (coords_of(i, self.bins), f))
            .collect()
    }

    pub fn stats(&self) -> ArchiveStats {
        ArchiveStats {
            occupied: self.n_occupied(),
            total_cells: self.n_cells(),
            qd_score: self.qd_score(),
            best_fitness: self.f_max(),
            best_speedup: self.best().map(|e| e.speedup).unwrap_or(0.0),
            insertions: self.insertions,
            attempts: self.attempts,
        }
    }

    pub fn to_json(&self) -> Json {
        let elites: Vec<Json> = self
            .occupied()
            .map(|e| {
                let mut o = Json::obj();
                o.set("coords", e.coords.to_vec())
                    .set("fitness", e.fitness)
                    .set("speedup", e.speedup)
                    .set("runtime_ms", e.runtime_ms)
                    .set("iteration", e.iteration)
                    .set("genome", e.genome.to_json());
                o
            })
            .collect();
        let mut o = Json::obj();
        o.set("bins", self.bins).set("elites", Json::Arr(elites));
        o
    }
}

/// Snapshot summary of archive health.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ArchiveStats {
    pub occupied: usize,
    pub total_cells: usize,
    pub qd_score: f64,
    pub best_fitness: f64,
    pub best_speedup: f64,
    pub insertions: usize,
    pub attempts: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn elite(coords: Coords, fitness: f64) -> Elite {
        Elite {
            genome: KernelGenome::direct_translation("t"),
            coords,
            fitness,
            speedup: fitness * 2.0,
            runtime_ms: 1.0,
            iteration: 0,
        }
    }

    #[test]
    fn empty_cell_accepts() {
        let mut a = MapElites::new(4);
        assert_eq!(a.insert(elite([0, 0, 0], 0.5)), InsertOutcome::NewCell);
        assert_eq!(a.n_occupied(), 1);
    }

    #[test]
    fn replacement_requires_strict_improvement() {
        let mut a = MapElites::new(4);
        a.insert(elite([1, 2, 3], 0.6));
        assert_eq!(a.insert(elite([1, 2, 3], 0.6)), InsertOutcome::Neutral);
        assert_eq!(a.insert(elite([1, 2, 3], 0.598)), InsertOutcome::Neutral);
        assert_eq!(a.insert(elite([1, 2, 3], 0.3)), InsertOutcome::Rejected);
        assert_eq!(a.get([1, 2, 3]).unwrap().fitness, 0.6);
        assert_eq!(a.insert(elite([1, 2, 3], 0.7)), InsertOutcome::Improved);
        assert_eq!(a.get([1, 2, 3]).unwrap().fitness, 0.7);
    }

    #[test]
    fn cells_are_independent() {
        let mut a = MapElites::new(4);
        a.insert(elite([0, 0, 0], 0.9));
        // A much worse kernel in a different cell is still accepted.
        assert_eq!(a.insert(elite([3, 3, 3], 0.11)), InsertOutcome::NewCell);
        assert_eq!(a.n_occupied(), 2);
    }

    #[test]
    fn qd_metrics() {
        let mut a = MapElites::new(4);
        a.insert(elite([0, 0, 0], 0.5));
        a.insert(elite([1, 0, 0], 0.7));
        assert_eq!(a.n_occupied(), 2);
        assert!((a.qd_score() - 1.2).abs() < 1e-12);
        assert_eq!(a.f_max(), 0.7);
        assert_eq!(a.best().unwrap().coords, [1, 0, 0]);
        assert!((a.coverage() - 2.0 / 64.0).abs() < 1e-12);
        assert_eq!(a.empty_coords().len(), 62);
    }

    #[test]
    fn low_quality_listing() {
        let mut a = MapElites::new(4);
        a.insert(elite([0, 0, 0], 0.2));
        a.insert(elite([2, 2, 2], 0.9));
        let low = a.low_quality_coords(0.5);
        assert_eq!(low.len(), 1);
        assert_eq!(low[0].0, [0, 0, 0]);
    }
}
