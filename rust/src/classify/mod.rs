//! Static behavioral classifier (§3.2).
//!
//! Assigns MAP-Elites behavioral coordinates `(d_mem, d_algo, d_sync)` to
//! a kernel by **weighted pattern matching on the source text** — the
//! paper computes coordinates "deterministically from generated code via
//! static pattern matching on SYCL and CUDA constructs, ensuring
//! reproducibility and reducing execution-time variability".
//!
//! The classifier implements the paper's *no-double-count* rule: "a kernel
//! using group_barrier for SLM synchronization receives credit in d_mem
//! for SLM usage, not additionally in d_sync for the same barrier" —
//! barriers annotated as tile-consistency barriers (or the only barrier in
//! an SLM kernel with no other coordination constructs) do not raise
//! `d_sync`.

use crate::ir::KernelGenome;

/// Behavioral coordinates in the 4×4×4 feature space.
pub type Coords = [usize; 3];

/// A scored pattern: if `pattern` occurs in the source, the candidate
/// level `level` gains `weight`.
struct Pattern {
    pattern: &'static str,
    level: usize,
    weight: f64,
}

const MEM_PATTERNS: &[Pattern] = &[
    // level 1: coalesced / vectorized
    Pattern { pattern: "sycl::vec<", level: 1, weight: 1.0 },
    Pattern { pattern: ".load(0,", level: 1, weight: 0.5 },
    Pattern { pattern: "float4", level: 1, weight: 1.0 },
    Pattern { pattern: "coalesced", level: 1, weight: 0.25 },
    // level 2: SLM tiling
    Pattern { pattern: "local_accessor", level: 2, weight: 1.5 },
    Pattern { pattern: "__shared__", level: 2, weight: 1.5 },
    Pattern { pattern: "tile_a[", level: 2, weight: 0.5 },
    // level 3: multi-level hierarchy
    Pattern { pattern: "register blocking", level: 3, weight: 1.0 },
    Pattern { pattern: "reg_acc", level: 3, weight: 1.0 },
    Pattern { pattern: ".prefetch(", level: 3, weight: 0.75 },
];

const ALGO_PATTERNS: &[Pattern] = &[
    Pattern { pattern: "fused_stage_", level: 1, weight: 1.0 },
    Pattern { pattern: "fused chain", level: 1, weight: 0.5 },
    Pattern { pattern: "single pass", level: 1, weight: 0.5 },
    Pattern { pattern: "running_max", level: 2, weight: 1.0 },
    Pattern { pattern: "online normalization", level: 2, weight: 1.0 },
    Pattern { pattern: "flash", level: 2, weight: 0.75 },
    Pattern { pattern: "hierarchical_stage", level: 3, weight: 1.5 },
    Pattern { pattern: "asymptotically fewer", level: 3, weight: 1.0 },
];

const SYNC_PATTERNS: &[Pattern] = &[
    Pattern { pattern: "group_barrier", level: 1, weight: 1.0 },
    Pattern { pattern: "barrier(sycl::access::fence_space", level: 1, weight: 1.0 },
    Pattern { pattern: "__syncthreads", level: 1, weight: 1.0 },
    Pattern { pattern: "get_sub_group", level: 2, weight: 1.0 },
    Pattern { pattern: "reduce_over_group(sg", level: 2, weight: 0.75 },
    Pattern { pattern: "select_from_group", level: 2, weight: 0.75 },
    Pattern { pattern: "shfl_down_sync", level: 2, weight: 1.0 },
    Pattern { pattern: "atomic_ref", level: 3, weight: 1.25 },
    Pattern { pattern: "atomicAdd", level: 3, weight: 1.25 },
    Pattern { pattern: "fetch_add", level: 3, weight: 0.5 },
];

/// Minimum accumulated weight for a level to be awarded.
const LEVEL_THRESHOLD: f64 = 0.75;

/// Classify kernel source into behavioral coordinates.
pub fn classify_source(src: &str) -> Coords {
    let d_mem = score_dimension(src, MEM_PATTERNS);
    let d_algo = score_dimension(src, ALGO_PATTERNS);
    let mut d_sync = score_dimension(src, SYNC_PATTERNS);

    // No-double-count rule: a barrier that exists only for SLM tile
    // consistency is credit for d_mem (SLM usage), not d_sync. We detect
    // this as: classified sync level 1 (barrier only), SLM in use, and
    // every barrier annotated as a tile-consistency barrier.
    if d_sync == 1 && uses_slm(src) && barriers_only_for_tiles(src) {
        d_sync = 0;
    }
    [d_mem, d_algo, d_sync]
}

/// Classify with a genome fallback: defective/truncated source may lose
/// its markers, in which case we fall back to the genome's intent (the
/// archive only inserts *correct* kernels, so this path is rare).
pub fn classify(genome: &KernelGenome, src: &str) -> Coords {
    let c = classify_source(src);
    if src.len() < 64 {
        genome.intended_coords()
    } else {
        c
    }
}

fn score_dimension(src: &str, patterns: &[Pattern]) -> usize {
    let mut weights = [0.0f64; 4];
    for p in patterns {
        if src.contains(p.pattern) {
            weights[p.level] += p.weight;
        }
    }
    // Highest level whose accumulated evidence clears the threshold.
    let mut level = 0;
    for (l, w) in weights.iter().enumerate() {
        if *w >= LEVEL_THRESHOLD {
            level = l;
        }
    }
    level
}

fn uses_slm(src: &str) -> bool {
    src.contains("local_accessor") || src.contains("__shared__")
}

fn barriers_only_for_tiles(src: &str) -> bool {
    let mut saw_any = false;
    for line in src.lines() {
        if line.contains("group_barrier") || line.contains("__syncthreads") {
            saw_any = true;
            if !line.contains("tile consistency") {
                return false;
            }
        }
    }
    saw_any
}

/// Flat cell index for coordinates in a `bins`-per-dimension grid.
pub fn cell_index(coords: Coords, bins: usize) -> usize {
    coords[0] * bins * bins + coords[1] * bins + coords[2]
}

/// Inverse of [`cell_index`].
pub fn coords_of(index: usize, bins: usize) -> Coords {
    [index / (bins * bins), (index / bins) % bins, index % bins]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{render_sycl, AlgoStructure, KernelGenome, MemoryPattern, SyncStrategy};

    fn genome(mem: usize, algo: usize, sync: usize) -> KernelGenome {
        let mut g = KernelGenome::direct_translation("t");
        g.mem = MemoryPattern::from_level(mem);
        g.algo = AlgoStructure::from_level(algo);
        g.sync = SyncStrategy::from_level(sync);
        if g.mem.level() >= 1 {
            g.params.vec_width = 4;
        }
        if g.mem.level() >= 3 {
            g.params.reg_block = 4;
            g.params.prefetch = true;
        }
        g
    }

    /// Renderer and classifier must agree across the whole 4×4×4 grid:
    /// the static analysis recovers the genome's intended coordinates.
    #[test]
    fn classifier_recovers_intended_coords_for_all_cells() {
        for mem in 0..4 {
            for algo in 0..4 {
                for sync in 0..4 {
                    let g = genome(mem, algo, sync);
                    let src = render_sycl(&g);
                    let got = classify(&g, &src);
                    assert_eq!(
                        got,
                        [mem, algo, sync],
                        "mismatch at ({mem},{algo},{sync}); source:\n{src}"
                    );
                }
            }
        }
    }

    #[test]
    fn no_double_count_slm_barrier() {
        // SLM kernel with sync=None renders a tile-consistency barrier;
        // it must NOT be credited to d_sync.
        let g = genome(2, 0, 0);
        let src = render_sycl(&g);
        assert!(src.contains("group_barrier"));
        assert_eq!(classify_source(&src), [2, 0, 0]);
    }

    #[test]
    fn explicit_barrier_is_counted() {
        let g = genome(2, 0, 1);
        let src = render_sycl(&g);
        assert_eq!(classify_source(&src), [2, 0, 1]);
    }

    #[test]
    fn cuda_constructs_recognized() {
        let cuda = "__shared__ float tile[16][16];\n__syncthreads();\nfloat4 v = reinterpret_cast<const float4*>(in)[i];\natomicAdd(&out[0], v.x);";
        let c = classify_source(cuda);
        assert_eq!(c[0], 2); // __shared__
        assert_eq!(c[2], 3); // atomicAdd outweighs the barrier
    }

    #[test]
    fn cell_index_roundtrip() {
        for idx in 0..64 {
            assert_eq!(cell_index(coords_of(idx, 4), 4), idx);
        }
    }

    #[test]
    fn plain_source_is_origin() {
        assert_eq!(classify_source("int main() { return 0; }"), [0, 0, 0]);
    }
}
