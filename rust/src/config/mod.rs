//! Configuration system.
//!
//! [`FoundryConfig`] carries every Table 6 hyperparameter plus the
//! experiment-level knobs (task set, device, language, models). Loadable
//! from YAML (the App. C custom-task config format) or JSON, with CLI
//! overrides.

use crate::selection::Strategy;
use crate::util::json::Json;
use crate::util::yamlite;

/// Evolution hyperparameters (Table 6 "Evolution" block).
#[derive(Debug, Clone)]
pub struct EvolutionConfig {
    /// Max generations (Table 6: 40, varies by experiment).
    pub max_generations: usize,
    /// Population per generation (Table 6: 8).
    pub population: usize,
    /// Selection strategy (Table 6: curiosity-driven).
    pub selection: Strategy,
    /// Archive dimensions (Table 6: 4 — 3 behavioral + fitness).
    pub archive_dims: usize,
    /// Bins per dimension (Table 6: 4).
    pub bins: usize,
    /// Transition buffer capacity.
    pub transition_capacity: usize,
    /// Island count / migration period for island selection.
    pub islands: usize,
    pub migration_period: usize,
}

impl Default for EvolutionConfig {
    fn default() -> EvolutionConfig {
        EvolutionConfig {
            max_generations: 40,
            population: 8,
            selection: Strategy::Curiosity,
            archive_dims: 4,
            bins: 4,
            transition_capacity: 256,
            islands: 4,
            migration_period: 5,
        }
    }
}

/// Evaluation hyperparameters (Table 6 "Evaluation" block).
#[derive(Debug, Clone)]
pub struct EvaluationConfig {
    /// Warmup iterations (Table 6: 10).
    pub warmup_iterations: usize,
    /// Timing iterations (Table 6: 100).
    pub timing_iterations: usize,
    /// Target speedup for fitness normalization (Table 6: 2.0×).
    pub target_speedup: f64,
}

impl Default for EvaluationConfig {
    fn default() -> EvaluationConfig {
        EvaluationConfig {
            warmup_iterations: 10,
            timing_iterations: 100,
            target_speedup: 2.0,
        }
    }
}

/// Meta-prompting hyperparameters (Table 6 "Meta-prompting" block).
#[derive(Debug, Clone)]
pub struct MetaPromptConfig {
    /// Prompt update frequency in generations (Table 6: every 10).
    pub update_every: usize,
    /// Max prompt mutations per update (Table 6: 3).
    pub max_mutations: usize,
    /// Prompt archive size (Table 6: 16).
    pub archive_size: usize,
    /// Master switch (ablations / OpenEvolve baseline disable it).
    pub enabled: bool,
}

impl Default for MetaPromptConfig {
    fn default() -> MetaPromptConfig {
        MetaPromptConfig {
            update_every: 10,
            max_mutations: 3,
            archive_size: 16,
            enabled: true,
        }
    }
}

/// LLM hyperparameters (Table 6 "LLM" block). Temperature/top-p are
/// carried for fidelity; the simulated models derive their stochasticity
/// from capability profiles.
#[derive(Debug, Clone)]
pub struct LlmConfig {
    pub temperature: f64,
    pub max_tokens: usize,
    pub top_p: f64,
    /// Ensemble member names (capability profiles).
    pub models: Vec<String>,
    /// Optional stronger model for the first iteration (App. B.4).
    pub first_iteration_model: Option<String>,
}

impl Default for LlmConfig {
    fn default() -> LlmConfig {
        LlmConfig {
            temperature: 0.3,
            max_tokens: 8000,
            top_p: 1.0,
            models: vec!["gpt-4.1".to_string(), "gpt-5-mini".to_string()],
            first_iteration_model: Some("sonnet-4.5".to_string()),
        }
    }
}

/// Top-level configuration.
#[derive(Debug, Clone, Default)]
pub struct FoundryConfig {
    pub evolution: EvolutionConfig,
    pub evaluation: EvaluationConfig,
    pub meta_prompt: MetaPromptConfig,
    pub llm: LlmConfig,
    /// Target device profile name (lnl / b580 / a6000).
    pub device: String,
    /// Kernel language (sycl / cuda / triton).
    pub language: String,
    /// Gradient-informed selection + hints (ablations disable).
    pub gradients_enabled: bool,
    /// Templated parameter-optimization iterations after evolution
    /// (§5.1: 2 iterations, best@8).
    pub param_opt_iterations: usize,
    pub param_opt_population: usize,
    /// Base RNG seed.
    pub seed: u64,
}

impl FoundryConfig {
    pub fn paper_defaults() -> FoundryConfig {
        FoundryConfig {
            device: "b580".to_string(),
            language: "sycl".to_string(),
            gradients_enabled: true,
            param_opt_iterations: 2,
            param_opt_population: 8,
            seed: 20260710,
            ..Default::default()
        }
    }

    /// Apply a parsed config document (YAML or JSON value) on top of the
    /// current values; unknown keys are ignored, present keys override.
    pub fn apply_doc(&mut self, doc: &Json) {
        let geti = |v: Option<&Json>| v.and_then(|x| x.as_usize());
        let getf = |v: Option<&Json>| v.and_then(|x| x.as_f64());
        let gets = |v: Option<&Json>| v.and_then(|x| x.as_str()).map(String::from);
        let getb = |v: Option<&Json>| v.and_then(|x| x.as_bool());

        if let Some(e) = doc.get("evolution") {
            if let Some(v) = geti(e.get("max_generations")) {
                self.evolution.max_generations = v;
            }
            if let Some(v) = geti(e.get("population")) {
                self.evolution.population = v;
            }
            if let Some(s) = gets(e.get("selection")) {
                if let Some(st) = Strategy::parse(&s) {
                    self.evolution.selection = st;
                }
            }
            if let Some(v) = geti(e.get("bins")) {
                self.evolution.bins = v;
            }
            if let Some(v) = geti(e.get("islands")) {
                self.evolution.islands = v;
            }
            if let Some(v) = geti(e.get("migration_period")) {
                self.evolution.migration_period = v;
            }
        }
        if let Some(e) = doc.get("evaluation") {
            if let Some(v) = getf(e.get("target_speedup")) {
                self.evaluation.target_speedup = v;
            }
            if let Some(v) = geti(e.get("warmup_iterations")) {
                self.evaluation.warmup_iterations = v;
            }
            if let Some(v) = geti(e.get("timing_iterations")) {
                self.evaluation.timing_iterations = v;
            }
        }
        if let Some(e) = doc.get("meta_prompting") {
            if let Some(v) = geti(e.get("update_every")) {
                self.meta_prompt.update_every = v;
            }
            if let Some(v) = geti(e.get("max_mutations")) {
                self.meta_prompt.max_mutations = v;
            }
            if let Some(v) = geti(e.get("archive_size")) {
                self.meta_prompt.archive_size = v;
            }
            if let Some(v) = getb(e.get("enabled")) {
                self.meta_prompt.enabled = v;
            }
        }
        if let Some(e) = doc.get("llm") {
            if let Some(v) = getf(e.get("temperature")) {
                self.llm.temperature = v;
            }
            if let Some(v) = geti(e.get("max_tokens")) {
                self.llm.max_tokens = v;
            }
            if let Some(models) = e.get("models").and_then(|m| m.as_arr()) {
                self.llm.models = models
                    .iter()
                    .filter_map(|m| m.as_str().map(String::from))
                    .collect();
            }
            if let Some(s) = gets(e.get("first_iteration_model")) {
                self.llm.first_iteration_model = Some(s);
            }
        }
        if let Some(s) = gets(doc.get("device")) {
            self.device = s;
        }
        if let Some(s) = gets(doc.get("language")) {
            self.language = s;
        }
        if let Some(b) = getb(doc.get("gradients_enabled")) {
            self.gradients_enabled = b;
        }
        if let Some(v) = geti(doc.get("param_opt_iterations")) {
            self.param_opt_iterations = v;
        }
        if let Some(v) = doc.get("seed").and_then(|x| x.as_i64()) {
            self.seed = v as u64;
        }
    }

    pub fn from_yaml(text: &str) -> Result<FoundryConfig, yamlite::YamlError> {
        let doc = yamlite::parse(text)?;
        let mut c = FoundryConfig::paper_defaults();
        c.apply_doc(&doc);
        Ok(c)
    }

    pub fn to_json(&self) -> Json {
        let mut evo = Json::obj();
        evo.set("max_generations", self.evolution.max_generations)
            .set("population", self.evolution.population)
            .set("selection", self.evolution.selection.name())
            .set("bins", self.evolution.bins);
        let mut o = Json::obj();
        o.set("evolution", evo)
            .set("device", self.device.as_str())
            .set("language", self.language.as_str())
            .set("seed", self.seed as f64)
            .set("target_speedup", self.evaluation.target_speedup);
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table 6 defaults, verbatim.
    #[test]
    fn table6_defaults() {
        let c = FoundryConfig::paper_defaults();
        assert_eq!(c.evolution.max_generations, 40);
        assert_eq!(c.evolution.population, 8);
        assert_eq!(c.evolution.selection, Strategy::Curiosity);
        assert_eq!(c.evolution.archive_dims, 4);
        assert_eq!(c.evolution.bins, 4);
        assert_eq!(c.evaluation.warmup_iterations, 10);
        assert_eq!(c.evaluation.timing_iterations, 100);
        assert_eq!(c.evaluation.target_speedup, 2.0);
        assert_eq!(c.meta_prompt.update_every, 10);
        assert_eq!(c.meta_prompt.max_mutations, 3);
        assert_eq!(c.meta_prompt.archive_size, 16);
        assert_eq!(c.llm.temperature, 0.3);
        assert_eq!(c.llm.max_tokens, 8000);
        assert_eq!(c.llm.top_p, 1.0);
        assert_eq!(c.param_opt_iterations, 2);
    }

    #[test]
    fn yaml_overrides() {
        let yaml = "\
evolution:
  max_generations: 10
  population: 4
  selection: island
device: lnl
llm:
  models: [o3-mini]
gradients_enabled: false
";
        let c = FoundryConfig::from_yaml(yaml).unwrap();
        assert_eq!(c.evolution.max_generations, 10);
        assert_eq!(c.evolution.population, 4);
        assert_eq!(c.evolution.selection, Strategy::Island);
        assert_eq!(c.device, "lnl");
        assert_eq!(c.llm.models, vec!["o3-mini"]);
        assert!(!c.gradients_enabled);
        // Untouched values keep defaults.
        assert_eq!(c.meta_prompt.update_every, 10);
    }

    #[test]
    fn bad_yaml_is_error() {
        assert!(FoundryConfig::from_yaml("nonsense without colon\n").is_err());
    }
}
