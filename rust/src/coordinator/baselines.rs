//! Baseline methods (§5.1–§5.2).
//!
//! * [`repeated_prompting`] — Kernelsseum-style: repeatedly prompt from
//!   scratch with only last-kernel feedback; no archive, no evolution.
//! * [`single_objective_evolve`] — AI-CUDA-Engineer-style: greedy
//!   evolutionary refinement of the single best kernel (population
//!   search, one objective, no quality-diversity).
//! * [`openevolve_like`] — OpenEvolve: a genuine evolutionary archive but
//!   with *generic* behavioral descriptors (code length), no
//!   kernel-specific dimensions, no gradient hints, no meta-prompting,
//!   no parameter optimization — the Table 2 comparison.

use super::report::{IterationPoint, RunReport};
use crate::archive::{Elite, MapElites};
use crate::config::FoundryConfig;
use crate::eval::{EvalOutcome, EvalPipeline, EvalRecord, ExecBackend};
use crate::prompts::{EvolvablePrompt, PromptBuilder};
use crate::simllm::{CapabilityProfile, Ensemble, SimLlm};
use crate::tasks::TaskSpec;
use crate::util::rng::Rng;

fn make_ensemble(config: &FoundryConfig, task: &TaskSpec) -> Ensemble {
    let seed = config.seed ^ super::engine::hash_str_pub(&task.id);
    let members: Vec<(SimLlm, f64)> = config
        .llm
        .models
        .iter()
        .enumerate()
        .map(|(i, name)| {
            let profile =
                CapabilityProfile::by_name(name).unwrap_or_else(|| CapabilityProfile::gpt_4_1());
            (SimLlm::new(profile, seed.wrapping_add(i as u64 * 101)), 1.0)
        })
        .collect();
    let first = config
        .llm
        .first_iteration_model
        .as_deref()
        .and_then(CapabilityProfile::by_name)
        .map(|p| SimLlm::new(p, seed ^ 0xf1));
    Ensemble::new(members, first, seed ^ 0xbb)
}

struct BaselineState {
    pipeline: EvalPipeline,
    ensemble: Ensemble,
    builder: PromptBuilder,
    best: Option<EvalRecord>,
    last: Option<EvalRecord>,
    series: Vec<IterationPoint>,
    next_id: u64,
    evaluations: usize,
    compile_errors: usize,
    incorrect: usize,
    first_correct: Option<usize>,
}

impl BaselineState {
    fn new(config: &FoundryConfig, task: &TaskSpec, backend: ExecBackend) -> BaselineState {
        let seed = config.seed ^ super::engine::hash_str_pub(&task.id);
        let builder = if config.language == "cuda" {
            PromptBuilder::cuda()
        } else {
            PromptBuilder::default()
        };
        let mut pipeline = EvalPipeline::new(task.clone(), backend, seed ^ 0x77);
        pipeline.target_speedup = config.evaluation.target_speedup;
        BaselineState {
            pipeline,
            ensemble: make_ensemble(config, task),
            builder,
            best: None,
            last: None,
            series: Vec::new(),
            next_id: 1,
            evaluations: 0,
            compile_errors: 0,
            incorrect: 0,
            first_correct: None,
        }
    }

    fn evaluate(&mut self, mut genome: crate::ir::KernelGenome, iteration: usize) -> EvalRecord {
        genome.id = self.next_id;
        self.next_id += 1;
        let rec = self.pipeline.evaluate(&genome);
        self.evaluations += 1;
        match rec.outcome {
            EvalOutcome::CompileError => self.compile_errors += 1,
            EvalOutcome::Incorrect => self.incorrect += 1,
            EvalOutcome::Correct => {
                if self.first_correct.is_none() {
                    self.first_correct = Some(iteration);
                }
                if self
                    .best
                    .as_ref()
                    .map(|b| rec.fitness > b.fitness || (rec.fitness == b.fitness && rec.speedup > b.speedup))
                    .unwrap_or(true)
                {
                    self.best = Some(rec.clone());
                }
            }
        }
        self.last = Some(rec.clone());
        rec
    }

    fn push_series(&mut self, iteration: usize, cells: usize) {
        self.series.push(IterationPoint {
            iteration,
            best_speedup: self.best.as_ref().map(|b| b.speedup).unwrap_or(0.0),
            best_fitness: self.best.as_ref().map(|b| b.fitness).unwrap_or(0.0),
            cells_occupied: cells,
        });
    }

    fn report(self, task: &TaskSpec, method: &str) -> RunReport {
        RunReport {
            task_id: task.id.clone(),
            method: method.to_string(),
            best: self.best,
            series: self.series,
            archive: None,
            first_correct_iteration: self.first_correct,
            evaluations: self.evaluations,
            compile_errors: self.compile_errors,
            incorrect: self.incorrect,
        }
    }
}

/// Kernelsseum-like repeated prompting: every iteration generates from
/// scratch with only the last kernel + log as context.
pub fn repeated_prompting(
    config: &FoundryConfig,
    task: &TaskSpec,
    backend: ExecBackend,
    iterations: usize,
) -> RunReport {
    let mut st = BaselineState::new(config, task, backend);
    let evolvable = EvolvablePrompt::generic();
    for it in 0..iterations {
        let hardware = st.pipeline.device_description();
        let prompt = st.builder.build(
            task,
            &evolvable,
            None, // no parent: always from scratch
            None, // no archive of top kernels
            st.last.as_ref(),
            &[],
            &hardware,
        );
        let candidates = st.ensemble.generate(&prompt, config.evolution.population, it);
        for g in candidates {
            st.evaluate(g, it);
        }
        st.push_series(it, 0);
    }
    st.report(task, "repeated-prompting")
}

/// AI-CUDA-Engineer-like single-objective evolution: the current best
/// kernel is always the parent; offspring replace it on improvement.
pub fn single_objective_evolve(
    config: &FoundryConfig,
    task: &TaskSpec,
    backend: ExecBackend,
    iterations: usize,
) -> RunReport {
    let mut st = BaselineState::new(config, task, backend);
    let evolvable = EvolvablePrompt::generic();
    for it in 0..iterations {
        let hardware = st.pipeline.device_description();
        let best = st.best.clone();
        let prompt = st.builder.build(
            task,
            &evolvable,
            best.as_ref(), // exploit the single best
            best.as_ref(),
            st.last.as_ref(),
            &[],
            &hardware,
        );
        let candidates = st.ensemble.generate(&prompt, config.evolution.population, it);
        for g in candidates {
            st.evaluate(g, it);
        }
        st.push_series(it, 0);
    }
    st.report(task, "single-objective-evolve")
}

/// OpenEvolve-like: a MAP-Elites archive over a *generic* descriptor
/// (source-code length buckets, as in Lehman et al.'s generic behavioral
/// descriptors) — diversity without kernel-domain structure, and no
/// gradient hints or meta-prompting.
pub fn openevolve_like(
    config: &FoundryConfig,
    task: &TaskSpec,
    backend: ExecBackend,
    iterations: usize,
) -> RunReport {
    let mut st = BaselineState::new(config, task, backend);
    let evolvable = EvolvablePrompt::generic();
    // Generic 1-D archive embedded in the 3-D grid: bucket by code length.
    let mut archive = MapElites::new(config.evolution.bins);
    let mut records: std::collections::HashMap<u64, EvalRecord> = std::collections::HashMap::new();
    let mut rng = Rng::with_stream(config.seed ^ 0x0e, 0x0e);
    for it in 0..iterations {
        let hardware = st.pipeline.device_description();
        let parent = {
            let occupied = archive.occupied_coords();
            if occupied.is_empty() {
                None
            } else {
                let c = *rng.choose(&occupied);
                archive
                    .get(c)
                    .map(|e| e.genome.id)
                    .and_then(|id| records.get(&id).cloned())
            }
        };
        let prompt = st.builder.build(
            task,
            &evolvable,
            parent.as_ref(),
            st.best.as_ref(),
            st.last.as_ref(),
            &[], // no gradient hints
            &hardware,
        );
        let candidates = st.ensemble.generate(&prompt, config.evolution.population, it);
        for g in candidates {
            let rec = st.evaluate(g, it);
            if rec.correct() {
                // Generic descriptor: source length bucket.
                let bucket = ((rec.source.len() / 1200).min(config.evolution.bins - 1), 0, 0);
                let coords = [bucket.0, 0, 0];
                archive.insert(Elite {
                    genome: rec.genome.clone(),
                    coords,
                    fitness: rec.fitness,
                    speedup: rec.speedup,
                    runtime_ms: rec.time_ms,
                    iteration: it,
                });
                let mut stored = rec.clone();
                stored.coords = coords;
                records.insert(stored.genome.id, stored);
            }
        }
        st.push_series(it, archive.n_occupied());
    }
    st.report(task, "openevolve")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwsim::DeviceProfile;
    use crate::tasks::catalog;

    fn cfg() -> FoundryConfig {
        let mut c = FoundryConfig::paper_defaults();
        c.evolution.population = 4;
        c
    }

    fn backend() -> ExecBackend {
        ExecBackend::HwSim(DeviceProfile::b580())
    }

    #[test]
    fn all_baselines_produce_reports() {
        let task = catalog::find_task("1_Conv2D_ReLU_BiasAdd").unwrap();
        let c = cfg();
        for (name, report) in [
            ("repeated-prompting", repeated_prompting(&c, &task, backend(), 8)),
            ("single-objective-evolve", single_objective_evolve(&c, &task, backend(), 8)),
            ("openevolve", openevolve_like(&c, &task, backend(), 8)),
        ] {
            assert_eq!(report.method, name);
            assert_eq!(report.series.len(), 8);
            assert!(report.evaluations >= 8);
        }
    }

    #[test]
    fn evolution_beats_repeated_prompting_on_fusion_task() {
        // On an L2 fusion task, search that exploits its own history
        // should find better kernels than stateless repeated prompting.
        let c = cfg();
        let mut wins = 0;
        for task_id in [
            "82_Conv2d_Tanh_Scaling_BiasAdd_Max",
            "46_Conv2d_Subtract_Tanh_Subtract_AvgPool",
            "21_Conv2d_Add_Scale_Sigmoid_GroupNorm",
        ] {
            let task = catalog::find_task(task_id).unwrap();
            let rp = repeated_prompting(&c, &task, backend(), 12);
            let ev = single_objective_evolve(&c, &task, backend(), 12);
            if ev.best_speedup() >= rp.best_speedup() {
                wins += 1;
            }
        }
        assert!(wins >= 2, "evolution won only {wins}/3");
    }
}
