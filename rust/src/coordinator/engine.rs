//! The KernelFoundry evolution engine.

use super::report::{IterationPoint, RunReport};
use crate::archive::{Elite, InsertOutcome, MapElites};
use crate::config::FoundryConfig;
use crate::dist::WorkerPool;
use crate::eval::{EvalOutcome, EvalPipeline, EvalRecord, ExecBackend};
use crate::gradient::{hints_for, GradientEstimator};
use crate::prompts::{EvolvablePrompt, MetaPrompter, Prompt, PromptArchive, PromptBuilder};
use crate::report::history::{SearchLog, SearchStatsRow};
use crate::selection::{IslandState, Selector};
use crate::simllm::{CapabilityProfile, Ensemble, SimLlm};
use crate::tasks::TaskSpec;
use crate::transitions::{Outcome, Transition, TransitionTracker};
use crate::util::rng::Rng;
use crate::util::textdiff;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// The full §3.1 loop bound to one task.
pub struct EvolutionEngine {
    pub config: FoundryConfig,
    pub task: TaskSpec,
    pub pipeline: EvalPipeline,
    pub archive: MapElites,
    pub tracker: TransitionTracker,
    pub selector: Selector,
    pub estimator: GradientEstimator,
    pub prompt_archive: PromptArchive,
    pub meta_prompter: MetaPrompter,
    pub ensemble: Ensemble,
    pub builder: PromptBuilder,
    /// All evaluation records by genome id (the run database).
    pub records: HashMap<u64, EvalRecord>,
    pub best: Option<EvalRecord>,
    pub last: Option<EvalRecord>,
    /// Recent records for the meta-prompter window.
    recent: Vec<EvalRecord>,
    series: Vec<IterationPoint>,
    current_prompt_id: u64,
    iteration: usize,
    next_genome_id: u64,
    first_correct_iteration: Option<usize>,
    compile_errors: usize,
    incorrect: usize,
    rng: Rng,
    /// Seed genome for custom tasks with an initial implementation.
    pub initial_genome: Option<crate::ir::KernelGenome>,
    /// Per-generation search-history sink (`--search-log`), shared by
    /// every engine in the process.
    search_log: Option<Arc<SearchLog>>,
    /// Run label stamped on search-history rows (the fleet's cache key,
    /// or a CLI run label).
    run_label: String,
    /// Cooperative cancellation flag (`--unit-deadline-ms` in the
    /// service): checked between generations by `run_distributed`.
    cancel: Option<Arc<AtomicBool>>,
}

impl EvolutionEngine {
    /// Build an engine from config (constructs ensemble + pipeline).
    pub fn new(config: FoundryConfig, task: TaskSpec, backend: ExecBackend) -> EvolutionEngine {
        let seed = config.seed ^ hash_str(&task.id);
        let members: Vec<(SimLlm, f64)> = config
            .llm
            .models
            .iter()
            .enumerate()
            .map(|(i, name)| {
                let profile = CapabilityProfile::by_name(name)
                    .unwrap_or_else(|| panic!("unknown model profile '{name}'"));
                (SimLlm::new(profile, seed.wrapping_add(i as u64 * 7919)), 1.0)
            })
            .collect();
        let first = config
            .llm
            .first_iteration_model
            .as_deref()
            .and_then(CapabilityProfile::by_name)
            .map(|p| SimLlm::new(p, seed ^ 0xf17));
        let ensemble = Ensemble::new(members, first, seed ^ 0xe5);

        let mut selector = Selector::new(config.evolution.selection);
        selector.islands = IslandState::new(
            config.evolution.islands,
            config.evolution.migration_period,
        );

        let builder = if config.language == "cuda" {
            PromptBuilder::cuda()
        } else {
            PromptBuilder::default()
        };

        let mut pipeline = EvalPipeline::new(task.clone(), backend, seed ^ 0x9e);
        pipeline.target_speedup = config.evaluation.target_speedup;

        EvolutionEngine {
            archive: MapElites::new(config.evolution.bins),
            tracker: TransitionTracker::new(config.evolution.transition_capacity),
            selector,
            estimator: GradientEstimator::default(),
            prompt_archive: PromptArchive::new(config.meta_prompt.archive_size),
            meta_prompter: MetaPrompter {
                max_mutations: config.meta_prompt.max_mutations,
            },
            ensemble,
            builder,
            records: HashMap::new(),
            best: None,
            last: None,
            recent: Vec::new(),
            series: Vec::new(),
            current_prompt_id: 0,
            iteration: 0,
            next_genome_id: 1,
            first_correct_iteration: None,
            compile_errors: 0,
            incorrect: 0,
            rng: Rng::with_stream(seed, 0xc0),
            initial_genome: None,
            search_log: None,
            run_label: String::new(),
            cancel: None,
            pipeline,
            task,
            config,
        }
    }

    /// Attach a per-generation search-history log: every finished
    /// generation appends one [`SearchStatsRow`] labeled `run` (the
    /// service fleet passes the unit's cache key so history rows join
    /// persisted cache rows; the CLI passes an equivalent label). Pure
    /// telemetry — appending never touches the engine RNG, so seeded
    /// runs stay bit-identical with or without a log.
    pub fn attach_search_log(&mut self, log: Arc<SearchLog>, run: &str) {
        self.search_log = Some(log);
        self.run_label = run.to_string();
    }

    fn hardware_desc(&self) -> String {
        self.pipeline.device_description()
    }

    fn current_evolvable(&self) -> EvolvablePrompt {
        self.prompt_archive
            .get(self.current_prompt_id)
            .map(|e| e.prompt.clone())
            .unwrap_or_default()
    }

    /// Assemble the generation prompt for this iteration.
    fn build_prompt(&mut self) -> Prompt {
        // Parent selection from the archive (None in the first
        // generations, before any correct kernel exists).
        let parent_rec = self
            .selector
            .select(&self.archive, &self.tracker, self.iteration, &mut self.rng)
            .and_then(|coords| self.archive.get(coords).map(|e| e.genome.id))
            .and_then(|id| self.records.get(&id).cloned());

        // Gradient-derived hints for the parent's cell (§3.3).
        let hints = if self.config.gradients_enabled {
            parent_rec
                .as_ref()
                .map(|p| {
                    let grad = self.estimator.estimate(
                        &self.tracker,
                        &self.archive,
                        p.coords,
                        self.iteration,
                    );
                    hints_for(p.coords, &grad)
                })
                .unwrap_or_default()
        } else {
            Vec::new()
        };

        let evolvable = self.current_evolvable();
        let hardware = self.hardware_desc();
        let mut prompt = self.builder.build(
            &self.task,
            &evolvable,
            parent_rec.as_ref(),
            self.best.as_ref(),
            self.last.as_ref(),
            &hints,
            &hardware,
        );
        // Custom tasks may seed an initial implementation (App. C) when
        // no parent exists yet.
        if prompt.parent.is_none() {
            if let Some(init) = &self.initial_genome {
                prompt.parent = Some(init.clone());
            }
        }
        prompt
    }

    /// Evaluate one candidate: pipeline + transition recording + archive
    /// insertion + bookkeeping.
    fn process_candidate(&mut self, mut genome: crate::ir::KernelGenome) -> EvalRecord {
        genome.id = self.next_genome_id;
        self.next_genome_id += 1;
        let record = self.pipeline.evaluate(&genome);
        self.absorb_record(record)
    }

    /// Fold an evaluation record — produced by the inline pipeline or by a
    /// distributed [`WorkerPool`] — into the evolutionary state: outcome
    /// counters, archive insertion, transition tracking, prompt credit and
    /// best-kernel bookkeeping. Returns the record for the caller's own
    /// bookkeeping. The genome id must already be assigned.
    pub fn absorb_record(&mut self, record: EvalRecord) -> EvalRecord {
        match record.outcome {
            EvalOutcome::CompileError => self.compile_errors += 1,
            EvalOutcome::Incorrect => self.incorrect += 1,
            EvalOutcome::Correct => {
                if self.first_correct_iteration.is_none() {
                    self.first_correct_iteration = Some(self.iteration);
                }
            }
        }

        // Archive insertion: only correct kernels become elites (§3.2).
        let insert_outcome = if record.correct() {
            let out = self.archive.insert(Elite {
                genome: record.genome.clone(),
                coords: record.coords,
                fitness: record.fitness,
                speedup: record.speedup,
                runtime_ms: record.time_ms,
                iteration: self.iteration,
            });
            out
        } else {
            InsertOutcome::Rejected
        };

        // Transition tracking (feedback from ALL outcomes, §3.1).
        if let Some(parent_id) = record.genome.parent_id {
            if let Some(parent) = self.records.get(&parent_id) {
                let delta = record.fitness - parent.fitness;
                self.tracker.record(Transition {
                    parent_coords: parent.coords,
                    child_coords: record.coords,
                    parent_fitness: parent.fitness,
                    child_fitness: record.fitness,
                    outcome: Outcome::from_insertion(insert_outcome, delta),
                    iteration: self.iteration,
                });
            }
        }

        // Prompt credit assignment (§3.5).
        self.prompt_archive
            .credit(self.current_prompt_id, record.fitness);

        if record.correct()
            && self
                .best
                .as_ref()
                .map(|b| record.fitness > b.fitness || (record.fitness == b.fitness && record.speedup > b.speedup))
                .unwrap_or(true)
        {
            self.best = Some(record.clone());
        }
        self.records.insert(record.genome.id, record.clone());
        self.recent.push(record.clone());
        if self.recent.len() > 64 {
            self.recent.remove(0);
        }
        record
    }

    /// One generation: build prompt, sample the population, evaluate all,
    /// then run the meta-prompt schedule.
    pub fn step(&mut self) {
        let prompt = self.build_prompt();
        self.prompt_archive.note_use(self.current_prompt_id);
        let candidates =
            self.ensemble
                .generate(&prompt, self.config.evolution.population, self.iteration);
        for genome in candidates {
            let record = self.process_candidate(genome);
            self.last = Some(record);
        }
        self.finish_generation();
    }

    /// One generation evaluated through a distributed [`WorkerPool`]
    /// (Fig. 4 / §3.6) instead of the inline pipeline: the whole
    /// population is submitted as one batch, compile workers early-reject
    /// defective candidates, and every record is folded back into the
    /// evolutionary state in submission order. The pool must be built for
    /// this engine's device and seeded with
    /// [`EvalPipeline::seed`](crate::eval::EvalPipeline::seed) so outcome
    /// classes match the inline path exactly.
    pub fn step_distributed(&mut self, pool: &WorkerPool) {
        let prompt = self.build_prompt();
        self.prompt_archive.note_use(self.current_prompt_id);
        let mut candidates =
            self.ensemble
                .generate(&prompt, self.config.evolution.population, self.iteration);
        for genome in candidates.iter_mut() {
            genome.id = self.next_genome_id;
            self.next_genome_id += 1;
        }
        let records = pool.evaluate_batch(&self.task, candidates);
        for record in records {
            let record = self.absorb_record(record);
            self.last = Some(record);
        }
        self.finish_generation();
    }

    /// Shared per-generation epilogue: island rotation, the §3.5
    /// meta-prompt schedule and the Fig. 3 series point.
    fn finish_generation(&mut self) {
        self.selector.islands.advance_generation();

        // Meta-prompt evolution every N generations (§3.5).
        if self.config.meta_prompt.enabled
            && self.iteration > 0
            && self.iteration % self.config.meta_prompt.update_every == 0
        {
            self.meta_prompt_update();
        }

        self.series.push(IterationPoint {
            iteration: self.iteration,
            best_speedup: self.best.as_ref().map(|b| b.speedup).unwrap_or(0.0),
            best_fitness: self.best.as_ref().map(|b| b.fitness).unwrap_or(0.0),
            cells_occupied: self.archive.n_occupied(),
        });
        self.record_search_telemetry();
        self.iteration += 1;
    }

    /// Publish per-generation search telemetry to the process-wide
    /// metrics registry: QD-score, archive coverage, best fitness and
    /// the mutation-acceptance rate (archive insertions / insertion
    /// attempts). Pure reads of archive state — never touches the
    /// engine RNG, so seeded runs stay bit-identical.
    fn record_search_telemetry(&self) {
        let stats = self.archive.stats();
        let obs = crate::obs::global();
        obs.gauge("kf_search_qd_score").set(stats.qd_score);
        obs.gauge("kf_search_best_fitness").set(stats.best_fitness);
        obs.gauge("kf_search_generation").set(self.iteration as f64 + 1.0);
        let coverage = if stats.total_cells > 0 {
            stats.occupied as f64 / stats.total_cells as f64
        } else {
            0.0
        };
        obs.gauge("kf_search_coverage").set(coverage);
        let acceptance = if stats.attempts > 0 {
            stats.insertions as f64 / stats.attempts as f64
        } else {
            0.0
        };
        obs.gauge("kf_search_acceptance_rate").set(acceptance);
        // Archive counters are cumulative over the run; mirror them with
        // a monotone ratchet so concurrent engines only push them up.
        obs.counter("kf_search_insertions_total")
            .set_to(stats.insertions as u64);
        obs.counter("kf_search_attempts_total")
            .set_to(stats.attempts as u64);

        // Persist the same snapshot as one search-history row, so the
        // gauges' last-value-only view survives the process and the
        // report layer can reconstruct full per-generation curves.
        if let Some(log) = &self.search_log {
            log.append(&SearchStatsRow {
                run: self.run_label.clone(),
                task_id: self.task.id.clone(),
                device: self.config.device.clone(),
                generation: self.iteration,
                qd_score: stats.qd_score,
                coverage,
                best_fitness: stats.best_fitness,
                best_speedup: stats.best_speedup,
                acceptance,
                insertions: stats.insertions,
                attempts: stats.attempts,
                occupied: stats.occupied,
                evaluations: self.records.len(),
                ts_ms: crate::obs::trace::now_ms(),
            });
        }
    }

    fn meta_prompt_update(&mut self) {
        let current = self.current_evolvable();
        if let Some(diff) = self
            .meta_prompter
            .propose_diff(&current, &self.recent, &self.task)
        {
            if let Ok(hunks) = textdiff::parse_hunks(&diff) {
                if let Ok(updated) = current.apply_diff(&hunks) {
                    let id = self
                        .prompt_archive
                        .add(updated, Some(self.current_prompt_id));
                    self.current_prompt_id = id;
                }
            }
        } else {
            // No diagnosis: fall back to the best-performing prompt.
            self.current_prompt_id = self.prompt_archive.best().id;
        }
    }

    /// §3.4 / §5.1 parameter-optimization phase: ask for templated
    /// kernels around the best solution ("applied only for 2 iterations,
    /// best@8").
    pub fn run_param_opt(&mut self) {
        for _ in 0..self.config.param_opt_iterations {
            let Some(best) = self.best.clone() else { return };
            let hardware = self.hardware_desc();
            let prompt = self.builder.build_templated(&self.task, &best, &hardware);
            let candidates = self.ensemble.generate(
                &prompt,
                self.config.param_opt_population,
                self.iteration,
            );
            for genome in candidates {
                let record = self.process_candidate(genome);
                self.last = Some(record);
            }
            self.iteration += 1;
        }
    }

    /// Run the configured number of generations (+ optional param-opt).
    pub fn run(&mut self, param_opt: bool) -> RunReport {
        for _ in 0..self.config.evolution.max_generations {
            self.step();
        }
        if param_opt {
            self.run_param_opt();
        }
        self.report("kernelfoundry")
    }

    /// Run the configured number of generations with every population
    /// batch evaluated through a distributed [`WorkerPool`] — the path the
    /// `service` subsystem's fleet lanes drive (§3.6 / Fig. 4).
    pub fn run_distributed(&mut self, pool: &WorkerPool) -> RunReport {
        for _ in 0..self.config.evolution.max_generations {
            if self
                .cancel
                .as_ref()
                .is_some_and(|c| c.load(Ordering::Relaxed))
            {
                break; // deadline exceeded: report what we have so far
            }
            self.step_distributed(pool);
        }
        self.report("kernelfoundry")
    }

    /// Attach a cooperative cancellation flag: `run_distributed` stops
    /// before the next generation once the flag is set (the caller
    /// decides whether the truncated report counts — the service's
    /// deadline path discards it and retries or quarantines the unit).
    pub fn attach_cancel(&mut self, flag: Arc<AtomicBool>) {
        self.cancel = Some(flag);
    }

    pub fn report(&self, method: &str) -> RunReport {
        RunReport {
            task_id: self.task.id.clone(),
            method: method.to_string(),
            best: self.best.clone(),
            series: self.series.clone(),
            archive: Some(self.archive.stats()),
            first_correct_iteration: self.first_correct_iteration,
            evaluations: self.records.len(),
            compile_errors: self.compile_errors,
            incorrect: self.incorrect,
        }
    }

    pub fn iteration(&self) -> usize {
        self.iteration
    }
}

/// FNV-1a string hash (shared with the baselines for matched seeding).
pub fn hash_str_pub(s: &str) -> u64 {
    hash_str(s)
}

fn hash_str(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwsim::DeviceProfile;
    use crate::tasks::catalog;

    fn quick_config() -> FoundryConfig {
        let mut c = FoundryConfig::paper_defaults();
        c.evolution.max_generations = 12;
        c.evolution.population = 4;
        c.meta_prompt.update_every = 4;
        c
    }

    fn engine_for(task_id: &str) -> EvolutionEngine {
        let task = catalog::find_task(task_id).unwrap();
        EvolutionEngine::new(
            quick_config(),
            task,
            ExecBackend::HwSim(DeviceProfile::b580()),
        )
    }

    #[test]
    fn run_finds_correct_kernel_and_improves() {
        let mut e = engine_for("1_Conv2D_ReLU_BiasAdd");
        let report = e.run(false);
        assert!(report.correct(), "no correct kernel found");
        assert!(report.best_speedup() > 1.0, "speedup {}", report.best_speedup());
        assert_eq!(report.series.len(), 12);
        // Cumulative best is monotone.
        for w in report.series.windows(2) {
            assert!(w[1].best_speedup >= w[0].best_speedup);
        }
        // Archive accumulated diversity.
        assert!(report.archive.unwrap().occupied >= 2);
    }

    #[test]
    fn param_opt_never_hurts() {
        let mut e = engine_for("99_Matmul_GELU_Softmax");
        let before = e.run(false).best_speedup();
        e.run_param_opt();
        let after = e.report("ours+po").best_speedup();
        assert!(after >= before * 0.999, "param opt regressed: {before} -> {after}");
    }

    #[test]
    fn meta_prompting_grows_prompt_archive() {
        let mut e = engine_for("99_Matmul_GELU_Softmax");
        e.run(false);
        assert!(e.prompt_archive.len() > 1, "meta-prompter never fired");
    }

    #[test]
    fn transitions_recorded() {
        let mut e = engine_for("17_Conv2d_InstanceNorm_Divide");
        e.run(false);
        assert!(e.tracker.total_recorded() > 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = engine_for("20_LeakyReLU").run(false).best_speedup();
        let b = engine_for("20_LeakyReLU").run(false).best_speedup();
        assert_eq!(a, b);
    }

    #[test]
    fn different_seeds_differ() {
        let mut c1 = quick_config();
        c1.seed = 1;
        let mut c2 = quick_config();
        c2.seed = 2;
        let task = catalog::find_task("20_LeakyReLU").unwrap();
        let r1 = EvolutionEngine::new(c1, task.clone(), ExecBackend::HwSim(DeviceProfile::b580())).run(false);
        let r2 = EvolutionEngine::new(c2, task, ExecBackend::HwSim(DeviceProfile::b580())).run(false);
        // Same task, different random trajectories (speedups may coincide but
        // evaluation mixes should differ).
        assert!(
            r1.compile_errors != r2.compile_errors
                || r1.incorrect != r2.incorrect
                || (r1.best_speedup() - r2.best_speedup()).abs() > 1e-9
        );
    }

    /// The service path: running the whole evolution through a
    /// WorkerPool produces a full, correct run report.
    #[test]
    fn run_distributed_finds_correct_kernel() {
        let task = catalog::find_task("1_Conv2D_ReLU_BiasAdd").unwrap();
        let mut e = EvolutionEngine::new(
            quick_config(),
            task,
            ExecBackend::HwSim(DeviceProfile::b580()),
        );
        let pool = crate::dist::WorkerPool::new(crate::dist::ClusterConfig {
            compile_workers: 2,
            exec_workers: 4,
            device: DeviceProfile::b580(),
            queue_capacity: 16,
            seed: e.pipeline.seed(),
        });
        let report = e.run_distributed(&pool);
        assert!(report.correct(), "distributed run found no correct kernel");
        assert_eq!(report.series.len(), 12);
        assert_eq!(report.evaluations, 12 * 4, "one record per candidate");
        assert!(report.best_speedup() > 1.0);
    }

    /// With a matched pool seed, the first generation (no feedback state
    /// yet) produces identical candidates and identical outcome classes
    /// inline and distributed — the dist determinism contract observed
    /// from the coordinator's side.
    #[test]
    fn first_distributed_generation_matches_inline_outcomes() {
        let task = catalog::find_task("20_LeakyReLU").unwrap();
        let mut inline_e = EvolutionEngine::new(
            quick_config(),
            task.clone(),
            ExecBackend::HwSim(DeviceProfile::b580()),
        );
        let mut dist_e = EvolutionEngine::new(
            quick_config(),
            task,
            ExecBackend::HwSim(DeviceProfile::b580()),
        );
        let pool = crate::dist::WorkerPool::new(crate::dist::ClusterConfig {
            compile_workers: 1,
            exec_workers: 2,
            device: DeviceProfile::b580(),
            queue_capacity: 4,
            seed: dist_e.pipeline.seed(),
        });
        inline_e.step();
        dist_e.step_distributed(&pool);
        assert_eq!(inline_e.records.len(), dist_e.records.len());
        for (id, inline_rec) in &inline_e.records {
            let dist_rec = dist_e.records.get(id).expect("same genome ids");
            assert_eq!(inline_rec.outcome, dist_rec.outcome, "genome {id}");
        }
    }

    /// Satellite-task test: an attached search log records one row per
    /// generation with the engine's run label, and attaching it leaves
    /// the seeded search trajectory bit-identical (telemetry is pure).
    #[test]
    fn search_log_covers_every_generation_without_perturbing_search() {
        let path = std::env::temp_dir()
            .join(format!("kf_engine_searchlog_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let plain = engine_for("20_LeakyReLU").run(false).best_speedup();

        let mut e = engine_for("20_LeakyReLU");
        let log = Arc::new(SearchLog::open(&path).unwrap());
        e.attach_search_log(log, "20_LeakyReLU|b580|sycl|s1|i12|p4");
        let logged = e.run(false).best_speedup();
        assert_eq!(plain, logged, "search log must not perturb the search");

        let rows = SearchLog::load(&path);
        assert_eq!(rows.len(), 12, "one row per generation");
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.generation, i);
            assert_eq!(row.run, "20_LeakyReLU|b580|sycl|s1|i12|p4");
            assert_eq!(row.task_id, "20_LeakyReLU");
            assert_eq!(row.device, "b580");
            assert!(row.coverage >= 0.0 && row.coverage <= 1.0);
        }
        // Curves are cumulative: QD-score and evaluations never shrink.
        for w in rows.windows(2) {
            assert!(w[1].qd_score >= w[0].qd_score);
            assert!(w[1].evaluations >= w[0].evaluations);
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn weak_model_fails_some_tasks() {
        let mut c = quick_config();
        c.llm.models = vec!["gpt-oss-20b".to_string()];
        c.llm.first_iteration_model = None;
        c.evolution.max_generations = 6;
        c.evolution.population = 2;
        let task = catalog::find_task("85_Conv2d_GroupNorm_Scale_MaxPool_Clamp").unwrap();
        let mut e = EvolutionEngine::new(c, task, ExecBackend::HwSim(DeviceProfile::lnl()));
        let report = e.run(false);
        // The weak model produces many failures (exact outcome varies by
        // seed; assert the failure channel is heavily exercised).
        assert!(report.compile_errors + report.incorrect > 3);
    }
}
