//! The evolutionary coordinator (§3.1): ties archive, gradients,
//! selection, prompts, code models and the evaluation pipeline into the
//! select → variate → evaluate → insert loop, with meta-prompt
//! co-evolution every N generations and the §3.4 parameter-optimization
//! phase.

pub mod baselines;
pub mod engine;
pub mod report;

pub use baselines::{openevolve_like, repeated_prompting, single_objective_evolve};
pub use engine::EvolutionEngine;
pub use report::{IterationPoint, RunReport};
