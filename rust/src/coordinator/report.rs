//! Run reports: everything a bench table or figure needs from one run.

use crate::archive::ArchiveStats;
use crate::eval::EvalRecord;
use crate::metrics::TaskResult;
use crate::util::json::Json;

/// One point of the Figure-3 improvement curve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IterationPoint {
    pub iteration: usize,
    /// Cumulative best speedup so far (0 until a correct kernel exists).
    pub best_speedup: f64,
    pub best_fitness: f64,
    /// Archive occupancy after this iteration.
    pub cells_occupied: usize,
}

/// Result of one evolutionary run on one task.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub task_id: String,
    pub method: String,
    /// Best correct kernel found (None if the run never produced one).
    pub best: Option<EvalRecord>,
    /// Per-iteration cumulative-best curve (Fig. 3).
    pub series: Vec<IterationPoint>,
    pub archive: Option<ArchiveStats>,
    /// Iteration index of the first correct kernel (§5.5 reports this).
    pub first_correct_iteration: Option<usize>,
    /// Total candidates evaluated.
    pub evaluations: usize,
    pub compile_errors: usize,
    pub incorrect: usize,
}

impl RunReport {
    pub fn best_speedup(&self) -> f64 {
        self.best.as_ref().map(|b| b.speedup).unwrap_or(0.0)
    }

    pub fn correct(&self) -> bool {
        self.best.is_some()
    }

    /// Convert to the metrics layer's per-task atom.
    pub fn task_result(&self) -> TaskResult {
        TaskResult {
            task_id: self.task_id.clone(),
            correct: self.correct(),
            speedup: self.best_speedup(),
            time_ms: self.best.as_ref().map(|b| b.time_ms).unwrap_or(0.0),
        }
    }

    /// Cumulative best speedup at iteration `i` (series lookup with
    /// clamping) — used for the "after 10 iterations" columns of Table 2.
    pub fn best_at_iteration(&self, i: usize) -> f64 {
        self.series
            .iter()
            .take_while(|p| p.iteration <= i)
            .map(|p| p.best_speedup)
            .fold(0.0, f64::max)
    }

    pub fn to_json(&self) -> Json {
        let series: Vec<Json> = self
            .series
            .iter()
            .map(|p| {
                let mut o = Json::obj();
                o.set("iteration", p.iteration)
                    .set("best_speedup", p.best_speedup)
                    .set("cells", p.cells_occupied);
                o
            })
            .collect();
        let mut o = Json::obj();
        o.set("task_id", self.task_id.as_str())
            .set("method", self.method.as_str())
            .set("correct", self.correct())
            .set("best_speedup", self.best_speedup())
            .set("evaluations", self.evaluations)
            .set("compile_errors", self.compile_errors)
            .set("incorrect", self.incorrect)
            .set("series", Json::Arr(series));
        if let Some(i) = self.first_correct_iteration {
            o.set("first_correct_iteration", i);
        }
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report_with_series(points: &[(usize, f64)]) -> RunReport {
        RunReport {
            task_id: "t".into(),
            method: "ours".into(),
            best: None,
            series: points
                .iter()
                .map(|(i, s)| IterationPoint {
                    iteration: *i,
                    best_speedup: *s,
                    best_fitness: 0.0,
                    cells_occupied: 0,
                })
                .collect(),
            archive: None,
            first_correct_iteration: None,
            evaluations: 0,
            compile_errors: 0,
            incorrect: 0,
        }
    }

    #[test]
    fn best_at_iteration_clamps() {
        let r = report_with_series(&[(0, 0.5), (1, 1.2), (2, 1.2), (3, 2.0)]);
        assert_eq!(r.best_at_iteration(0), 0.5);
        assert_eq!(r.best_at_iteration(1), 1.2);
        assert_eq!(r.best_at_iteration(2), 1.2);
        assert_eq!(r.best_at_iteration(99), 2.0);
    }

    #[test]
    fn json_roundtrips_core_fields() {
        let r = report_with_series(&[(0, 1.0)]);
        let j = r.to_json();
        assert_eq!(j.get("task_id").unwrap().as_str(), Some("t"));
        assert_eq!(j.get("correct").unwrap().as_bool(), Some(false));
    }
}
