//! The results database server (Fig. 4 worker type 4, App. C).
//!
//! Every evaluation — including compile failures — is persisted so runs
//! are reproducible and reportable after the fact. The store is an
//! append-only table of [`DbRow`]s with JSONL persistence via the in-repo
//! [`crate::util::json`] model (one compact JSON object per line), which
//! is what the `kernelfoundry report --db runs.jsonl` subcommand reads.
//!
//! [`Database`] uses interior mutability (a mutex around the row table) so
//! concurrent workers can insert through a shared reference, matching its
//! role as the single server many workers report to.

use crate::eval::{EvalOutcome, EvalRecord};
use crate::util::error::{Context, Error};
use crate::util::json::{self, Json};
use std::fs;
use std::path::Path;
use std::sync::Mutex;

/// Crash-tolerant JSONL reader shared by the results store and the
/// service job journal.
///
/// Both files are written with whole-line `O_APPEND` writes, so the only
/// corruption a crash can produce is a *torn final line* (the process
/// died mid-`write`). This loader parses each line with `parse_item`;
/// a line that fails is treated one of two ways:
///
/// * **last line of the file** — the torn-tail case: the file is
///   truncated back to the start of that line (so the next `O_APPEND`
///   write begins on a clean boundary instead of concatenating onto
///   garbage) and loading succeeds with what was readable;
/// * **any earlier line** — not explicable by a crash mid-append: a
///   hard error, never silent data loss.
///
/// Returns the parsed items plus the number of torn bytes dropped.
pub fn load_jsonl_tolerant<T>(
    path: &Path,
    mut parse_item: impl FnMut(&Json) -> Option<T>,
) -> Result<(Vec<T>, usize), Error> {
    let text =
        fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
    let total = text.len();
    let mut items = Vec::new();
    let mut pos = 0usize;
    let mut torn_at = None;
    for line in text.split_inclusive('\n') {
        let start = pos;
        pos += line.len();
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        match json::parse(trimmed).ok().and_then(|v| parse_item(&v)) {
            Some(item) => items.push(item),
            None if pos == total => {
                torn_at = Some(start);
                break;
            }
            None => {
                return Err(Error::msg(format!(
                    "{}: malformed JSONL at byte {start} followed by valid lines — \
                     mid-file corruption, not a torn tail; refusing to load",
                    path.display()
                )));
            }
        }
    }
    let mut dropped = 0;
    if let Some(offset) = torn_at {
        dropped = total - offset;
        let file = fs::OpenOptions::new()
            .write(true)
            .open(path)
            .with_context(|| format!("truncating torn tail of {}", path.display()))?;
        file.set_len(offset as u64)
            .with_context(|| format!("truncating torn tail of {}", path.display()))?;
        crate::log_warn!(
            "{}: dropped {dropped} torn trailing bytes (crash mid-append)",
            path.display()
        );
    }
    Ok((items, dropped))
}

/// One persisted evaluation: the App. C database schema.
#[derive(Debug, Clone, PartialEq)]
pub struct DbRow {
    /// Run identifier (groups rows of one experiment).
    pub run: String,
    /// Method that produced the kernel (e.g. `kernelfoundry`, `openevolve`).
    pub method: String,
    /// Evaluation index within the run.
    pub idx: usize,
    /// Task the kernel implements.
    pub task_id: String,
    /// Genome id within the run (0 = unassigned).
    pub genome_id: u64,
    /// Model of the ensemble that produced the kernel.
    pub produced_by: String,
    /// Outcome class: `compile_error` | `incorrect` | `correct`.
    pub outcome: String,
    /// Behavioral coordinates assigned by the classifier.
    pub coords: [usize; 3],
    /// §3.2 fitness.
    pub fitness: f64,
    /// Speedup over the eager baseline (0 unless correct).
    pub speedup: f64,
    /// Measured kernel time, ms (0 unless correct).
    pub time_ms: f64,
    /// Eager baseline time, ms.
    pub baseline_ms: f64,
}

fn outcome_name(o: EvalOutcome) -> &'static str {
    match o {
        EvalOutcome::CompileError => "compile_error",
        EvalOutcome::Incorrect => "incorrect",
        EvalOutcome::Correct => "correct",
    }
}

impl DbRow {
    /// Build a row from one evaluation record.
    pub fn from_record(run: &str, method: &str, idx: usize, rec: &EvalRecord) -> DbRow {
        DbRow {
            run: run.to_string(),
            method: method.to_string(),
            idx,
            task_id: rec.genome.task_id.clone(),
            genome_id: rec.genome.id,
            produced_by: rec.genome.produced_by.clone(),
            outcome: outcome_name(rec.outcome).to_string(),
            coords: rec.coords,
            fitness: rec.fitness,
            speedup: rec.speedup,
            time_ms: rec.time_ms,
            baseline_ms: rec.baseline_ms,
        }
    }

    /// Serialize to the JSONL object form.
    ///
    /// Non-finite metric values (a real backend can report an infinite
    /// baseline on failure) are clamped to the largest finite f64 — the
    /// JSON model would otherwise emit `null`, and a single such row
    /// would make the whole file unloadable.
    pub fn to_json(&self) -> Json {
        fn finite(v: f64) -> f64 {
            if v.is_finite() {
                v
            } else if v.is_nan() {
                0.0
            } else if v > 0.0 {
                f64::MAX
            } else {
                f64::MIN
            }
        }
        let mut o = Json::obj();
        o.set("run", self.run.as_str())
            .set("method", self.method.as_str())
            .set("idx", self.idx)
            .set("task_id", self.task_id.as_str())
            // As a string: u64 ids above 2^53 would lose precision in a
            // JSON double, and save/load must round-trip exactly.
            .set("genome_id", self.genome_id.to_string())
            .set("produced_by", self.produced_by.as_str())
            .set("outcome", self.outcome.as_str())
            .set("coords", self.coords.to_vec())
            .set("fitness", finite(self.fitness))
            .set("speedup", finite(self.speedup))
            .set("time_ms", finite(self.time_ms))
            .set("baseline_ms", finite(self.baseline_ms));
        o
    }

    /// Parse a row back from its JSON object form.
    pub fn from_json(v: &Json) -> Option<DbRow> {
        let coords_arr = v.get("coords")?.as_arr()?;
        if coords_arr.len() != 3 {
            return None;
        }
        let coords = [
            coords_arr[0].as_usize()?,
            coords_arr[1].as_usize()?,
            coords_arr[2].as_usize()?,
        ];
        Some(DbRow {
            run: v.get("run")?.as_str()?.to_string(),
            method: v.get("method")?.as_str()?.to_string(),
            idx: v.get("idx")?.as_usize()?,
            task_id: v.get("task_id")?.as_str()?.to_string(),
            genome_id: v.get("genome_id")?.as_str()?.parse().ok()?,
            produced_by: v.get("produced_by")?.as_str()?.to_string(),
            outcome: v.get("outcome")?.as_str()?.to_string(),
            coords,
            fitness: v.get("fitness")?.as_f64()?,
            speedup: v.get("speedup")?.as_f64()?,
            time_ms: v.get("time_ms")?.as_f64()?,
            baseline_ms: v.get("baseline_ms")?.as_f64()?,
        })
    }

    /// Whether the row records a numerically-correct kernel.
    pub fn is_correct(&self) -> bool {
        self.outcome == "correct"
    }
}

/// The append-only results store.
#[derive(Debug, Default)]
pub struct Database {
    rows: Mutex<Vec<DbRow>>,
}

impl Database {
    /// Create an empty database.
    pub fn new() -> Database {
        Database::default()
    }

    /// Append one row (callable through a shared reference, so concurrent
    /// workers can report into one server).
    pub fn insert(&self, row: DbRow) {
        self.rows.lock().unwrap().push(row);
    }

    /// Number of rows.
    pub fn len(&self) -> usize {
        self.rows.lock().unwrap().len()
    }

    /// Whether the database holds no rows.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of every row.
    pub fn rows(&self) -> Vec<DbRow> {
        self.rows.lock().unwrap().clone()
    }

    /// Persist every row as JSONL (one compact object per line).
    pub fn save(&self, path: &Path) -> Result<(), Error> {
        let rows = self.rows.lock().unwrap();
        let mut out = String::with_capacity(rows.len() * 160);
        for row in rows.iter() {
            out.push_str(&row.to_json().to_string_compact());
            out.push('\n');
        }
        fs::write(path, out).with_context(|| format!("writing database {}", path.display()))
    }

    /// Load a JSONL file, appending its rows; returns how many rows were
    /// added. Blank lines are skipped; malformed lines are errors.
    pub fn load(&self, path: &Path) -> Result<usize, Error> {
        let text = fs::read_to_string(path)
            .with_context(|| format!("reading database {}", path.display()))?;
        let mut loaded = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let v = json::parse(line)
                .with_context(|| format!("{}:{}", path.display(), lineno + 1))?;
            let row = DbRow::from_json(&v).with_context(|| {
                format!("{}:{}: not a database row", path.display(), lineno + 1)
            })?;
            loaded.push(row);
        }
        let n = loaded.len();
        self.rows.lock().unwrap().extend(loaded);
        Ok(n)
    }

    /// Crash-tolerant variant of [`Database::load`] for stores written
    /// by whole-line appends (the service result cache): a torn final
    /// line is truncated away via [`load_jsonl_tolerant`] instead of
    /// failing the load; mid-file corruption is still an error. Returns
    /// (rows added, torn bytes dropped).
    pub fn load_tolerant(&self, path: &Path) -> Result<(usize, usize), Error> {
        let (rows, dropped) = load_jsonl_tolerant(path, DbRow::from_json)?;
        let n = rows.len();
        self.rows.lock().unwrap().extend(rows);
        Ok((n, dropped))
    }

    /// Whether any row's `run` key equals `run` — the existence check
    /// behind the service's exactly-once commit slots (a slot's row is
    /// appended at most once, even across crash + replay).
    pub fn contains_run(&self, run: &str) -> bool {
        self.rows.lock().unwrap().iter().any(|r| r.run == run)
    }

    /// The best row per task for a method: maximum fitness, ties broken by
    /// speedup (matching the engine's best-kernel rule, so a report over a
    /// full run reproduces the run's own best). Rows are returned sorted
    /// by task id.
    pub fn best_per_task(&self, method: &str) -> Vec<DbRow> {
        let rows = self.rows.lock().unwrap();
        let mut best: std::collections::BTreeMap<&str, &DbRow> = Default::default();
        for row in rows.iter().filter(|r| r.method == method) {
            let replace = match best.get(row.task_id.as_str()) {
                Some(cur) => {
                    row.fitness > cur.fitness
                        || (row.fitness == cur.fitness && row.speedup > cur.speedup)
                }
                None => true,
            };
            if replace {
                best.insert(row.task_id.as_str(), row);
            }
        }
        best.into_values().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn row(task: &str, method: &str, fitness: f64, speedup: f64) -> DbRow {
        DbRow {
            run: "r1".to_string(),
            method: method.to_string(),
            idx: 0,
            task_id: task.to_string(),
            genome_id: 7,
            produced_by: "gpt-4.1".to_string(),
            outcome: "correct".to_string(),
            coords: [2, 1, 0],
            fitness,
            speedup,
            time_ms: 0.5,
            baseline_ms: 1.0,
        }
    }

    fn tmp_path(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("kf_dist_{}_{}.jsonl", name, std::process::id()))
    }

    /// Satellite-task test: insert → save → load → best_per_task round
    /// trip through the JSONL file format.
    #[test]
    fn jsonl_roundtrip_and_best_per_task() {
        let db = Database::new();
        db.insert(row("t1", "kernelfoundry", 0.9, 1.8));
        db.insert(row("t1", "kernelfoundry", 0.7, 1.4));
        db.insert(row("t2", "kernelfoundry", 1.0, 2.5));
        db.insert(row("t2", "openevolve", 1.0, 9.9)); // other method
        let path = tmp_path("roundtrip");
        db.save(&path).unwrap();

        let loaded = Database::new();
        assert_eq!(loaded.load(&path).unwrap(), 4);
        assert_eq!(loaded.len(), 4);
        assert_eq!(loaded.rows(), db.rows(), "rows survive the round trip exactly");

        let best = loaded.best_per_task("kernelfoundry");
        assert_eq!(best.len(), 2);
        assert_eq!(best[0].task_id, "t1");
        assert_eq!(best[0].fitness, 0.9);
        assert_eq!(best[1].task_id, "t2");
        assert_eq!(best[1].speedup, 2.5, "openevolve row must not leak in");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn best_per_task_breaks_fitness_ties_by_speedup() {
        let db = Database::new();
        db.insert(row("t", "m", 1.0, 2.0));
        db.insert(row("t", "m", 1.0, 3.0)); // saturated fitness, faster kernel
        db.insert(row("t", "m", 0.6, 9.0)); // fast but lower fitness
        let best = db.best_per_task("m");
        assert_eq!(best.len(), 1);
        assert_eq!(best[0].speedup, 3.0);
    }

    #[test]
    fn load_rejects_malformed_lines() {
        let path = tmp_path("malformed");
        std::fs::write(&path, "{\"not\": \"a row\"}\n").unwrap();
        let db = Database::new();
        let err = db.load(&path).unwrap_err().to_string();
        assert!(err.contains("not a database row"), "{err}");
        std::fs::write(&path, "not json at all\n").unwrap();
        let err = db.load(&path).unwrap_err().to_string();
        assert!(err.contains("json parse error"), "{err}");
        assert_eq!(db.len(), 0, "failed loads must not append rows");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn tolerant_load_truncates_torn_tail_but_rejects_midfile_garbage() {
        let path = tmp_path("tolerant");
        let db = Database::new();
        db.insert(row("t1", "m", 0.9, 1.8));
        db.insert(row("t2", "m", 0.8, 1.2));
        db.save(&path).unwrap();
        // Crash mid-append: a partial JSON prefix with no newline.
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("{\"run\":\"r1\",\"met");
        std::fs::write(&path, &text).unwrap();

        let loaded = Database::new();
        let (n, dropped) = loaded.load_tolerant(&path).unwrap();
        assert_eq!(n, 2, "intact rows load");
        assert_eq!(dropped, 16, "torn bytes counted");
        assert!(loaded.contains_run("r1"));
        assert!(!loaded.contains_run("r9"));
        // The file itself was repaired: a strict load now succeeds too.
        let strict = Database::new();
        assert_eq!(strict.load(&path).unwrap(), 2);

        // Mid-file garbage (followed by a valid line) is NOT a torn
        // tail and must stay a hard error.
        let good = row("t1", "m", 0.9, 1.8).to_json().to_string_compact();
        std::fs::write(&path, format!("not json\n{good}\n")).unwrap();
        let err = Database::new().load_tolerant(&path).unwrap_err().to_string();
        assert!(err.contains("mid-file corruption"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn tolerant_load_handles_degenerate_tails() {
        let path = tmp_path("tails");
        let good = row("t1", "m", 0.9, 1.8).to_json().to_string_compact();

        // One-byte torn tail: the crash wrote exactly the opening brace.
        std::fs::write(&path, format!("{good}\n{{")).unwrap();
        let db = Database::new();
        let (n, dropped) = db.load_tolerant(&path).unwrap();
        assert_eq!(n, 1);
        assert_eq!(dropped, 1, "exactly the lone brace is dropped");
        assert_eq!(std::fs::read_to_string(&path).unwrap(), format!("{good}\n"));

        // A file that is nothing but one torn byte: zero rows, repaired
        // to empty, not an error.
        std::fs::write(&path, "{").unwrap();
        let db = Database::new();
        let (n, dropped) = db.load_tolerant(&path).unwrap();
        assert_eq!((n, dropped), (0, 1));
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "");

        // A trailing blank line is a clean append boundary, not a torn
        // tail: nothing is dropped and the file is left untouched.
        std::fs::write(&path, format!("{good}\n\n")).unwrap();
        let db = Database::new();
        let (n, dropped) = db.load_tolerant(&path).unwrap();
        assert_eq!((n, dropped), (1, 0));
        assert_eq!(std::fs::read_to_string(&path).unwrap(), format!("{good}\n\n"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn from_record_captures_the_contracted_fields() {
        let mut genome = crate::ir::KernelGenome::direct_translation("task_x");
        genome.id = 42;
        genome.produced_by = "sonnet-4.5".to_string();
        let rec = EvalRecord {
            source: String::new(),
            genome,
            outcome: EvalOutcome::Correct,
            coords: [1, 2, 3],
            correctness: None,
            time_ms: 0.25,
            baseline_ms: 1.0,
            speedup: 4.0,
            fitness: 1.0,
            log: String::new(),
            best_params: None,
            param_sweep: Vec::new(),
        };
        let r = DbRow::from_record("run-a", "kernelfoundry", 9, &rec);
        assert_eq!(r.task_id, "task_x");
        assert_eq!(r.genome_id, 42);
        assert_eq!(r.produced_by, "sonnet-4.5");
        assert_eq!(r.coords, [1, 2, 3]);
        assert_eq!(r.outcome, "correct");
        assert!(r.is_correct());
        assert_eq!(r.idx, 9);
        assert_eq!(DbRow::from_json(&r.to_json()), Some(r.clone()));

        // Ids beyond 2^53 must survive the JSON round trip exactly.
        let mut big = r;
        big.genome_id = u64::MAX;
        assert_eq!(DbRow::from_json(&big.to_json()), Some(big.clone()));

        // Non-finite metrics must still produce a loadable row (clamped),
        // never a null that poisons the whole file on load.
        big.baseline_ms = f64::INFINITY;
        big.speedup = f64::NAN;
        let reloaded = DbRow::from_json(&big.to_json()).expect("row stays loadable");
        assert!(reloaded.baseline_ms.is_finite());
        assert_eq!(reloaded.speedup, 0.0);
    }

    #[test]
    fn concurrent_inserts_through_shared_reference() {
        let db = Database::new();
        std::thread::scope(|s| {
            for w in 0..4 {
                let db = &db;
                s.spawn(move || {
                    for i in 0..25 {
                        db.insert(row(&format!("t{w}"), "m", 0.5, i as f64));
                    }
                });
            }
        });
        assert_eq!(db.len(), 100);
        assert_eq!(db.best_per_task("m").len(), 4);
    }
}
