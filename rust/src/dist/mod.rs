//! Distributed evaluation framework (§3.6, App. C, Fig. 4).
//!
//! KernelFoundry's systems contribution is that candidate evaluation — the
//! dominant cost of evolutionary kernel optimization — runs as a
//! *distributed framework with remote access to diverse hardware*. The
//! paper's Fig. 4 topology has four worker types:
//!
//! 1. **generation workers** (LLM inference) — in this reproduction, the
//!    simulated code model runs inline in the coordinator;
//! 2. **compilation workers** — CPU-only machines that render and compile
//!    candidates, rejecting defective ones *before* they ever occupy a GPU;
//! 3. **execution workers** — one (simulated) GPU each, measuring
//!    correctness and runtime;
//! 4. **the database server** — persists every evaluation record for
//!    reproducibility and later reporting.
//!
//! This module implements types 2–4 for a single process: [`WorkerPool`]
//! runs a multi-threaded compile→execute pipeline behind bounded,
//! backpressured queues, and [`Database`] is the append-only JSONL results
//! store served by the `kernelfoundry serve` / `report` subcommands. The
//! physical GPUs are replaced by [`crate::hwsim`] device profiles per the
//! DESIGN.md §2 substitution table; the worker topology, queue discipline,
//! early-reject accounting and database schema are the real thing.
//!
//! Determinism contract: the pool produces, for every submitted genome, an
//! evaluation record whose *outcome class* (compile error / incorrect /
//! correct) is identical to what the inline [`crate::eval::EvalPipeline`]
//! would produce for the same seed — worker scheduling must never perturb
//! per-genome determinism (pinned by `tests/integration.rs`).

mod db;
mod pool;

pub use db::{load_jsonl_tolerant, Database, DbRow};
pub use pool::{PoolMetrics, WorkerPool};

use crate::hwsim::DeviceProfile;

/// Configuration of one evaluation cluster (Fig. 4 topology knobs).
///
/// `Default` matches the single-node demo configuration: 2 compile workers
/// feeding 4 execution workers on the B580 profile through queues of 64.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of compilation workers (CPU-only; no GPU required).
    pub compile_workers: usize,
    /// Number of execution workers (one simulated device each).
    pub exec_workers: usize,
    /// Device profile every execution worker simulates.
    pub device: DeviceProfile,
    /// Capacity of each inter-stage queue. Bounded queues give
    /// backpressure: generation cannot outrun compilation, and
    /// compilation cannot outrun the devices.
    pub queue_capacity: usize,
    /// RNG seed for the execution workers' evaluation pipelines (the same
    /// seed an inline [`crate::eval::EvalPipeline`] would be given).
    pub seed: u64,
}

impl Default for ClusterConfig {
    fn default() -> ClusterConfig {
        ClusterConfig {
            compile_workers: 2,
            exec_workers: 4,
            device: DeviceProfile::b580(),
            queue_capacity: 64,
            seed: 20260710,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_cluster_is_the_demo_topology() {
        let c = ClusterConfig::default();
        assert_eq!(c.compile_workers, 2);
        assert_eq!(c.exec_workers, 4);
        assert_eq!(c.device.name, "b580");
        assert_eq!(c.queue_capacity, 64);
    }
}
