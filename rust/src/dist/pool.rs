//! The compile/execute worker pool (Fig. 4 worker types 2 and 3).
//!
//! ```text
//!            submit queue              exec queue
//!  batch ──▶ (bounded) ──▶ compile ──▶ (bounded) ──▶ exec ──▶ records
//!                          workers                   workers
//!                             │                        one simulated
//!                             └── early reject ──────▶ device each
//!                                 (defective genomes
//!                                  never reach a GPU)
//! ```
//!
//! Compilation workers are CPU-only: they render the genome to source and
//! run the compile stage (syntax + legality against the device limits).
//! Candidates that fail are turned into `CompileError` records on the
//! spot — the paper's point that cheap CPU nodes absorb the defect stream
//! so the scarce GPU workers only ever see compilable kernels. Candidates
//! that pass flow through a *bounded* queue (backpressure) to execution
//! workers, each of which owns a full [`EvalPipeline`] bound to one
//! simulated device.
//!
//! Outcome determinism: the compile stage runs the exact checks the inline
//! pipeline runs (same order, same device limits), and the simulated
//! correctness stage's verdict depends only on the genome's defects — so
//! the outcome class of every record is identical to an inline evaluation
//! regardless of how work is scheduled across workers.

use super::ClusterConfig;
use crate::eval::{
    compile_check, compile_reject_record, EvalOutcome, EvalPipeline, EvalRecord, ExecBackend,
};
use crate::hwsim::baseline_cost;
use crate::ir::{render_sycl, KernelGenome};
use crate::tasks::TaskSpec;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

/// Wall-clock occupancy floor per device-executed candidate, ms.
const OCCUPANCY_MIN_MS: f64 = 0.2;
/// Wall-clock occupancy ceiling per device-executed candidate, ms.
const OCCUPANCY_MAX_MS: f64 = 2.0;

/// Atomic pipeline counters, shared by all workers of a pool.
///
/// Counters accumulate over the pool's lifetime (across
/// [`WorkerPool::evaluate_batch`] calls).
#[derive(Debug, Default)]
pub struct PoolMetrics {
    /// Candidates that passed the compile stage and were forwarded to an
    /// execution worker.
    pub compiled: AtomicU64,
    /// Candidates rejected by a compile worker (never reached a device).
    pub compile_rejected: AtomicU64,
    /// Candidates fully evaluated on a (simulated) device.
    pub executed: AtomicU64,
    /// Executed candidates that were numerically correct.
    pub correct: AtomicU64,
}

/// A multi-threaded compile→execute evaluation cluster in one process.
///
/// Construction is cheap; threads are spawned per
/// [`evaluate_batch`](WorkerPool::evaluate_batch) call and joined before
/// it returns, so the pool has no background resources to shut down.
pub struct WorkerPool {
    cfg: ClusterConfig,
    /// Live pipeline counters (readable while a batch is in flight from
    /// another thread, and after it completes).
    pub metrics: PoolMetrics,
    /// Cooperative cancellation flag (see [`WorkerPool::set_cancel`]).
    cancel: Option<Arc<AtomicBool>>,
}

/// A unit of work entering the compile stage: the genome plus its index
/// in the submitted batch (records are returned in submission order).
type Job = (usize, KernelGenome);

/// A compiled unit of work bound for an execution worker: the genome
/// travels with the source the compile worker already rendered, so
/// execution never redoes the render + compile checks.
type ExecJob = (usize, KernelGenome, String);

impl WorkerPool {
    /// Create a pool for the given cluster configuration.
    pub fn new(cfg: ClusterConfig) -> WorkerPool {
        WorkerPool {
            cfg,
            metrics: PoolMetrics::default(),
            cancel: None,
        }
    }

    /// The pool's cluster configuration.
    pub fn config(&self) -> &ClusterConfig {
        &self.cfg
    }

    /// Attach a cooperative cancellation flag. Once the flag is set,
    /// [`evaluate_batch`](WorkerPool::evaluate_batch) stops feeding new
    /// candidates and returns only the records already produced — fewer
    /// than one per submitted genome. Callers that attach a flag must
    /// treat a short batch as a cancelled batch, not an error.
    pub fn set_cancel(&mut self, flag: Arc<AtomicBool>) {
        self.cancel = Some(flag);
    }

    fn cancelled(&self) -> bool {
        self.cancel
            .as_ref()
            .is_some_and(|c| c.load(Ordering::Relaxed))
    }

    /// Evaluate a batch of candidate genomes through the worker topology,
    /// blocking until every record is in. Records are returned in
    /// submission order, one per genome — compile-rejected candidates get
    /// a `CompileError` record produced by the compile worker itself.
    pub fn evaluate_batch(&self, task: &TaskSpec, genomes: Vec<KernelGenome>) -> Vec<EvalRecord> {
        let n = genomes.len();
        if n == 0 {
            return Vec::new();
        }
        let cfg = &self.cfg;
        let n_compile = cfg.compile_workers.max(1);
        let n_exec = cfg.exec_workers.max(1);
        let cap = cfg.queue_capacity.max(1);
        let limits = cfg.device.limits();
        // Compile workers have no device, but the eager baseline is an
        // analytic model — compute it once and stamp it into reject
        // records, exactly as the inline pipeline would.
        let baseline_ms = baseline_cost(task, &cfg.device);

        // Stage queues. Submission and exec queues are bounded (the
        // backpressure the paper's framework needs so generation cannot
        // flood compilation, nor compilation the devices); the results
        // channel is unbounded so execution workers never block on output.
        let (submit_tx, submit_rx) = mpsc::sync_channel::<Job>(cap);
        let submit_rx = Arc::new(Mutex::new(submit_rx));
        let (exec_tx, exec_rx) = mpsc::sync_channel::<ExecJob>(cap);
        let exec_rx = Arc::new(Mutex::new(exec_rx));
        let (out_tx, out_rx) = mpsc::channel::<(usize, EvalRecord)>();

        let metrics = &self.metrics;
        let mut results: Vec<Option<EvalRecord>> = (0..n).map(|_| None).collect();

        thread::scope(|s| {
            // ---- execution workers (Fig. 4 type 3) -----------------------
            for worker in 0..n_exec {
                let exec_rx = Arc::clone(&exec_rx);
                let out_tx = out_tx.clone();
                let task = task.clone();
                let device = cfg.device.clone();
                let seed = cfg.seed;
                s.spawn(move || {
                    // Each worker owns one device and one pipeline, seeded
                    // identically to an inline EvalPipeline for this
                    // cluster seed — verdicts therefore match the inline
                    // path. Only the measurement-noise stream is made
                    // per-worker, so parallel devices take independent
                    // noisy measurements instead of replaying one stream.
                    let mut pipeline =
                        EvalPipeline::new(task, ExecBackend::HwSim(device), seed);
                    pipeline.reseed_timing_noise(worker as u64 + 1);
                    loop {
                        let job = exec_rx.lock().unwrap().recv();
                        let Ok((idx, genome, source)) = job else { break };
                        let record = pipeline.evaluate_compiled(&genome, source);
                        metrics.executed.fetch_add(1, Ordering::Relaxed);
                        if record.correct() {
                            metrics.correct.fetch_add(1, Ordering::Relaxed);
                        }
                        // Simulated device occupancy: the worker's device
                        // is busy for the measurement session. Scaled so
                        // demos and benches finish in milliseconds while
                        // exec workers remain the pipeline bottleneck —
                        // which is what makes Fig. 4's scaling visible.
                        thread::sleep(device_occupancy(&record));
                        if out_tx.send((idx, record)).is_err() {
                            break;
                        }
                    }
                });
            }

            // ---- compilation workers (Fig. 4 type 2) ---------------------
            for _ in 0..n_compile {
                let submit_rx = Arc::clone(&submit_rx);
                let exec_tx = exec_tx.clone();
                let out_tx = out_tx.clone();
                s.spawn(move || loop {
                    let job = submit_rx.lock().unwrap().recv();
                    let Ok((idx, genome)) = job else { break };
                    // The exact checks (and check order) of the inline
                    // pipeline's compile stage, via the shared helpers.
                    let compile_start = std::time::Instant::now();
                    let source = render_sycl(&genome);
                    let checked = compile_check(&genome, &source, &limits);
                    crate::obs::global().observe_ms(
                        "kf_eval_compile_ms",
                        compile_start.elapsed().as_secs_f64() * 1000.0,
                    );
                    match checked {
                        Err(log) => {
                            metrics.compile_rejected.fetch_add(1, Ordering::Relaxed);
                            let record = compile_reject_record(&genome, source, log, baseline_ms);
                            if out_tx.send((idx, record)).is_err() {
                                break;
                            }
                        }
                        Ok(()) => {
                            metrics.compiled.fetch_add(1, Ordering::Relaxed);
                            // Bounded send: blocks when every device is
                            // busy and the exec queue is full. The rendered
                            // source rides along so execution workers skip
                            // the compile stage entirely.
                            if exec_tx.send((idx, genome, source)).is_err() {
                                break;
                            }
                        }
                    }
                });
            }
            // Workers hold their own clones; drop the originals so the
            // channels close once the last worker exits.
            drop(exec_tx);
            drop(out_tx);

            // ---- feed + collect on this thread ---------------------------
            // Feeding happens against a bounded queue, so a slow pipeline
            // applies backpressure here; collection drains the unbounded
            // results channel until every worker has hung up. A set cancel
            // flag stops the feed between candidates — in-flight work
            // drains, unfed genomes simply never get a record.
            for job in genomes.into_iter().enumerate() {
                if self.cancelled() {
                    break;
                }
                submit_tx
                    .send(job)
                    .expect("compile workers exited before the batch was fed");
            }
            drop(submit_tx);
            for (idx, record) in out_rx {
                results[idx] = Some(record);
            }
        });

        if self.cancel.is_some() {
            // Cancellable pools may legitimately return a partial batch.
            results.into_iter().flatten().collect()
        } else {
            results
                .into_iter()
                .map(|r| r.expect("a worker dropped a candidate without producing a record"))
                .collect()
        }
    }
}

/// Wall-clock time the simulated device is occupied by one evaluation:
/// proportional to the measured kernel time (the benchmark harness keeps
/// the device busy for the whole session), clamped to keep demos fast.
/// Compile rejects never occupy a device — that is the early-reject win.
fn device_occupancy(record: &EvalRecord) -> Duration {
    if record.outcome == EvalOutcome::CompileError {
        return Duration::ZERO;
    }
    let ms = if record.time_ms > 0.0 {
        record.time_ms
    } else {
        record.baseline_ms
    };
    Duration::from_micros((ms.clamp(OCCUPANCY_MIN_MS, OCCUPANCY_MAX_MS) * 1000.0) as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::fitness::FITNESS_COMPILE_FAIL;
    use crate::hwsim::DeviceProfile;
    use crate::ir::{Defect, DefectKind, MemoryPattern};
    use crate::tasks::catalog;

    fn batch(task_id: &str, n: usize, defect_every: usize) -> Vec<KernelGenome> {
        (0..n)
            .map(|i| {
                let mut g = KernelGenome::direct_translation(task_id);
                g.id = i as u64;
                g.mem = MemoryPattern::from_level(i % 4);
                g.params.slm_pad = true;
                if defect_every > 0 && i % defect_every == 0 {
                    g.defects.push(Defect { kind: DefectKind::SyntaxError, severity: 1.0 });
                }
                g
            })
            .collect()
    }

    /// Satellite-task test: defective genomes are rejected in the compile
    /// workers (`compile_rejected` > 0, and rejects never count as
    /// executed), yet every submitted genome still gets a record.
    #[test]
    fn defective_genomes_rejected_before_devices() {
        let task = catalog::find_task("20_LeakyReLU").unwrap();
        let pool = WorkerPool::new(ClusterConfig::default());
        let n = 24;
        let records = pool.evaluate_batch(&task, batch(&task.id, n, 4));
        assert_eq!(records.len(), n, "one record per submitted genome");

        let rejected = pool.metrics.compile_rejected.load(Ordering::Relaxed);
        let compiled = pool.metrics.compiled.load(Ordering::Relaxed);
        let executed = pool.metrics.executed.load(Ordering::Relaxed);
        assert_eq!(rejected, 6, "every 4th of 24 genomes is defective");
        assert_eq!(compiled, (n as u64) - rejected);
        assert_eq!(executed, compiled, "only compiled candidates reach a device");

        for (i, r) in records.iter().enumerate() {
            assert_eq!(r.genome.id, i as u64, "records keep submission order");
            if i % 4 == 0 {
                assert_eq!(r.outcome, EvalOutcome::CompileError, "genome {i}");
                assert_eq!(r.fitness, FITNESS_COMPILE_FAIL);
                assert!(r.log.contains("error"), "{}", r.log);
            } else {
                assert!(r.compiled(), "genome {i} should compile");
            }
        }
    }

    /// Worker count must not change any outcome (scheduling-independence
    /// of the per-genome verdict).
    #[test]
    fn outcomes_invariant_under_worker_topology() {
        let task = catalog::find_task("1_Conv2D_ReLU_BiasAdd").unwrap();
        let genomes = batch(&task.id, 16, 5);
        let narrow = WorkerPool::new(ClusterConfig {
            compile_workers: 1,
            exec_workers: 1,
            device: DeviceProfile::b580(),
            queue_capacity: 2,
            seed: 11,
        });
        let wide = WorkerPool::new(ClusterConfig {
            compile_workers: 4,
            exec_workers: 8,
            device: DeviceProfile::b580(),
            queue_capacity: 64,
            seed: 11,
        });
        let a = narrow.evaluate_batch(&task, genomes.clone());
        let b = wide.evaluate_batch(&task, genomes);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.outcome, y.outcome, "genome {}", x.genome.id);
        }
    }

    #[test]
    fn cancelled_pool_returns_a_partial_batch_without_panicking() {
        let task = catalog::find_task("20_LeakyReLU").unwrap();
        let mut pool = WorkerPool::new(ClusterConfig::default());
        let flag = Arc::new(AtomicBool::new(true)); // cancelled before the feed
        pool.set_cancel(Arc::clone(&flag));
        let records = pool.evaluate_batch(&task, batch(&task.id, 8, 0));
        assert!(records.is_empty(), "nothing fed after cancellation");

        // Clearing the flag restores full batches on the same pool.
        flag.store(false, Ordering::Relaxed);
        let records = pool.evaluate_batch(&task, batch(&task.id, 8, 0));
        assert_eq!(records.len(), 8);
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let task = catalog::find_task("20_LeakyReLU").unwrap();
        let pool = WorkerPool::new(ClusterConfig::default());
        assert!(pool.evaluate_batch(&task, Vec::new()).is_empty());
        assert_eq!(pool.metrics.compiled.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn metrics_accumulate_across_batches() {
        let task = catalog::find_task("20_LeakyReLU").unwrap();
        let pool = WorkerPool::new(ClusterConfig::default());
        pool.evaluate_batch(&task, batch(&task.id, 8, 0));
        pool.evaluate_batch(&task, batch(&task.id, 8, 0));
        assert_eq!(pool.metrics.executed.load(Ordering::Relaxed), 16);
        assert_eq!(pool.metrics.compile_rejected.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn occupancy_skips_rejects_and_clamps() {
        let task = catalog::find_task("20_LeakyReLU").unwrap();
        let mut g = KernelGenome::direct_translation(&task.id);
        g.defects.push(Defect { kind: DefectKind::SyntaxError, severity: 1.0 });
        let limits = DeviceProfile::b580().limits();
        let source = render_sycl(&g);
        let log = match compile_check(&g, &source, &limits) {
            Err(log) => log,
            Ok(()) => panic!("defective genome must not compile"),
        };
        let reject = compile_reject_record(&g, source, log, 1.0);
        assert_eq!(device_occupancy(&reject), Duration::ZERO);

        let mut ok = reject.clone();
        ok.outcome = EvalOutcome::Correct;
        ok.time_ms = 100.0; // clamped to the ceiling
        assert!(device_occupancy(&ok) <= Duration::from_micros(2_000));
        ok.time_ms = 0.0001; // clamped to the floor
        assert!(device_occupancy(&ok) >= Duration::from_micros(200));
    }
}
