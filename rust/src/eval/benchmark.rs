//! Kernel-runtime benchmarking methodology (App. B.2).
//!
//! "First, we run a fixed number of initial trials to determine the rough
//! runtime of the kernel. This initial measurement informs the number of
//! warmup trials and main trials, which are set based on a minimal total
//! *time* rather than a fixed amount of trials. … for very fast kernels
//! the synchronize operation has significant overhead. We reduce this
//! overhead by running an inner loop within the main trials, such that
//! multiple trials are executed before each synchronize."
//!
//! Defaults match App. B.2: minimum warmup time 1 s, minimum warmup
//! iterations 10, inner-loop minimum time 0.01 s, minimum main
//! iterations 10, minimum main measurement time 1 s.

use crate::util::stats::{self, Summary};

/// A timing source the harness can drive: one call = `inner_iters` kernel
/// executions followed by a synchronize; returns wall-clock milliseconds.
/// Implemented by the hwsim NoisyClock and by the PJRT runtime.
pub trait TimingSource {
    fn run_batch(&mut self, inner_iters: usize) -> f64;
}

impl<F: FnMut(usize) -> f64> TimingSource for F {
    fn run_batch(&mut self, inner_iters: usize) -> f64 {
        self(inner_iters)
    }
}

/// App. B.2 configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    /// Initial trials used to estimate rough runtime.
    pub initial_trials: usize,
    /// Minimum total warmup time, ms.
    pub min_warmup_ms: f64,
    pub min_warmup_iters: usize,
    /// Minimum time per inner loop (amortizing synchronize), ms.
    pub min_inner_ms: f64,
    pub min_main_iters: usize,
    /// Minimum total main measurement time, ms.
    pub min_main_ms: f64,
    /// Safety cap on total iterations (keeps simulated benches bounded).
    pub max_total_iters: usize,
}

impl Default for BenchConfig {
    fn default() -> BenchConfig {
        BenchConfig {
            initial_trials: 3,
            min_warmup_ms: 1000.0,
            min_warmup_iters: 10,
            min_inner_ms: 10.0,
            min_main_iters: 10,
            min_main_ms: 1000.0,
            max_total_iters: 100_000,
        }
    }
}

impl BenchConfig {
    /// A fast-running profile for unit tests and large sweeps.
    pub fn quick() -> BenchConfig {
        BenchConfig {
            initial_trials: 2,
            min_warmup_ms: 1.0,
            min_warmup_iters: 2,
            min_inner_ms: 0.5,
            min_main_iters: 5,
            min_main_ms: 2.0,
            max_total_iters: 10_000,
        }
    }
}

/// Result of one benchmark run.
#[derive(Debug, Clone, Copy)]
pub struct BenchResult {
    /// Best estimate of per-iteration kernel time, ms (median of batch
    /// means).
    pub time_ms: f64,
    pub summary: Summary,
    pub warmup_iters: usize,
    pub main_iters: usize,
    pub inner_iters: usize,
}

/// The App. B.2 adaptive benchmark harness.
#[derive(Debug, Clone, Copy, Default)]
pub struct Benchmarker {
    pub config: BenchConfig,
}

impl Benchmarker {
    pub fn new(config: BenchConfig) -> Benchmarker {
        Benchmarker { config }
    }

    pub fn run<T: TimingSource>(&self, source: &mut T) -> BenchResult {
        let c = &self.config;

        // Phase 1: initial trials → rough per-iteration runtime.
        let mut rough = 0.0;
        for _ in 0..c.initial_trials {
            rough += source.run_batch(1);
        }
        let rough_ms = (rough / c.initial_trials as f64).max(1e-6);

        // Phase 2: derive adaptive counts from time budgets.
        let inner_iters = ((c.min_inner_ms / rough_ms).ceil() as usize).clamp(1, 10_000);
        let warmup_iters = ((c.min_warmup_ms / rough_ms).ceil() as usize)
            .max(c.min_warmup_iters)
            .min(c.max_total_iters);
        let main_batches = (((c.min_main_ms / rough_ms).ceil() as usize)
            .max(c.min_main_iters)
            .min(c.max_total_iters)
            / inner_iters)
            .max(c.min_main_iters);

        // Phase 3: warmup (results discarded).
        let mut remaining = warmup_iters;
        while remaining > 0 {
            let batch = remaining.min(inner_iters);
            source.run_batch(batch);
            remaining -= batch;
        }

        // Phase 4: main trials — inner loop before each synchronize.
        let mut samples = Vec::with_capacity(main_batches);
        for _ in 0..main_batches {
            let total = source.run_batch(inner_iters);
            samples.push(total / inner_iters as f64);
        }

        let summary = stats::summarize(&samples);
        BenchResult {
            time_ms: summary.median,
            summary,
            warmup_iters,
            main_iters: main_batches * inner_iters,
            inner_iters,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwsim::{DeviceProfile, NoisyClock};

    struct SimSource {
        clock: NoisyClock,
        true_ms: f64,
        calls: usize,
    }

    impl TimingSource for SimSource {
        fn run_batch(&mut self, inner_iters: usize) -> f64 {
            self.calls += 1;
            self.clock.observe_batch(self.true_ms, inner_iters)
        }
    }

    fn source(true_ms: f64) -> SimSource {
        SimSource {
            clock: NoisyClock::new(7, &DeviceProfile::b580()),
            true_ms,
            calls: 0,
        }
    }

    #[test]
    fn recovers_true_time_for_fast_kernels() {
        // 5 µs kernel: sync overhead (12 µs) dominates naive measurement;
        // the inner loop must recover the true time within ~20 %.
        let mut s = source(0.005);
        let r = Benchmarker::new(BenchConfig::quick()).run(&mut s);
        assert!(
            (r.time_ms - 0.005).abs() / 0.005 < 0.25,
            "measured {} true 0.005",
            r.time_ms
        );
        assert!(r.inner_iters > 1, "fast kernel must batch iterations");
    }

    #[test]
    fn slow_kernels_use_fewer_iterations() {
        let mut fast = source(0.01);
        let mut slow = source(10.0);
        let b = Benchmarker::new(BenchConfig::quick());
        let rf = b.run(&mut fast);
        let rs = b.run(&mut slow);
        assert!(rf.main_iters > rs.main_iters);
        assert!(rf.warmup_iters >= rs.warmup_iters);
        assert_eq!(rs.inner_iters, 1, "slow kernels need no inner loop");
        assert!((rs.time_ms - 10.0).abs() / 10.0 < 0.1);
    }

    #[test]
    fn minimums_respected() {
        let c = BenchConfig::quick();
        let mut s = source(100.0); // much slower than all budgets
        let r = Benchmarker::new(c).run(&mut s);
        assert!(r.warmup_iters >= c.min_warmup_iters);
        assert!(r.main_iters >= c.min_main_iters);
    }

    #[test]
    fn default_config_matches_appendix_b2() {
        let c = BenchConfig::default();
        assert_eq!(c.min_warmup_ms, 1000.0);
        assert_eq!(c.min_warmup_iters, 10);
        assert_eq!(c.min_inner_ms, 10.0);
        assert_eq!(c.min_main_iters, 10);
        assert_eq!(c.min_main_ms, 1000.0);
    }

    #[test]
    fn measurement_is_low_variance() {
        let mut s = source(0.5);
        let r = Benchmarker::new(BenchConfig::quick()).run(&mut s);
        assert!(r.summary.std / r.summary.mean < 0.1, "cv too high");
    }
}
