//! Strict kernel correctness validation (§4 "Metrics").
//!
//! The paper replaces KernelBench's loose absolute tolerance (1e-2) with
//! a relative-precision criterion: ν = |y − ŷ| / (|y| + ε), and declares
//! a kernel correct when ν < 0.01 for at least 99 % of output elements.
//! A second measure is the cosine similarity of the flattened outputs.

/// Relative precision threshold (ν < NU_THRESHOLD counts as exact enough).
pub const NU_THRESHOLD: f64 = 0.01;
/// Required fraction of elements satisfying the ν criterion.
pub const PASS_FRACTION: f64 = 0.99;
/// Division-by-zero guard.
pub const EPSILON: f64 = 1e-8;

/// Outcome of a correctness check.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CorrectnessReport {
    /// Fraction of elements with ν < threshold.
    pub pass_fraction: f64,
    /// Maximum relative error observed.
    pub max_nu: f64,
    /// Mean relative error.
    pub mean_nu: f64,
    /// Cosine similarity of flattened outputs.
    pub cosine: f64,
    /// The §4 verdict: pass_fraction ≥ 99 %.
    pub correct: bool,
}

/// Per-element relative precision ν = |y − ŷ| / (|y| + ε).
pub fn nu_criterion(expected: f64, actual: f64) -> f64 {
    (expected - actual).abs() / (expected.abs() + EPSILON)
}

/// Cosine similarity of two flattened tensors; 0.0 when either is a zero
/// vector or lengths mismatch.
pub fn cosine_similarity(a: &[f32], b: &[f32]) -> f64 {
    if a.len() != b.len() || a.is_empty() {
        return 0.0;
    }
    let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
    for (x, y) in a.iter().zip(b.iter()) {
        dot += *x as f64 * *y as f64;
        na += *x as f64 * *x as f64;
        nb += *y as f64 * *y as f64;
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na.sqrt() * nb.sqrt())
}

/// Full §4 correctness check: expected vs actual output tensors.
pub fn check_correctness(expected: &[f32], actual: &[f32]) -> CorrectnessReport {
    if expected.len() != actual.len() || expected.is_empty() {
        return CorrectnessReport {
            pass_fraction: 0.0,
            max_nu: f64::INFINITY,
            mean_nu: f64::INFINITY,
            cosine: 0.0,
            correct: false,
        };
    }
    let mut passed = 0usize;
    let mut max_nu = 0.0f64;
    let mut sum_nu = 0.0f64;
    for (e, a) in expected.iter().zip(actual.iter()) {
        if !a.is_finite() {
            max_nu = f64::INFINITY;
            sum_nu = f64::INFINITY;
            continue;
        }
        let nu = nu_criterion(*e as f64, *a as f64);
        if nu < NU_THRESHOLD {
            passed += 1;
        }
        max_nu = max_nu.max(nu);
        sum_nu += nu;
    }
    let pass_fraction = passed as f64 / expected.len() as f64;
    CorrectnessReport {
        pass_fraction,
        max_nu,
        mean_nu: sum_nu / expected.len() as f64,
        cosine: cosine_similarity(expected, actual),
        correct: pass_fraction >= PASS_FRACTION,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_outputs_pass() {
        let y = vec![1.0f32, -2.0, 3.5, 0.0];
        let r = check_correctness(&y, &y);
        assert!(r.correct);
        assert_eq!(r.pass_fraction, 1.0);
        assert!((r.cosine - 1.0).abs() < 1e-9);
    }

    #[test]
    fn small_relative_error_passes() {
        let y: Vec<f32> = (1..1000).map(|i| i as f32).collect();
        let yh: Vec<f32> = y.iter().map(|v| v * 1.005).collect(); // 0.5% error
        let r = check_correctness(&y, &yh);
        assert!(r.correct);
        assert!(r.max_nu < NU_THRESHOLD);
    }

    #[test]
    fn large_relative_error_fails() {
        let y: Vec<f32> = (1..1000).map(|i| i as f32).collect();
        let yh: Vec<f32> = y.iter().map(|v| v * 1.05).collect(); // 5% error
        let r = check_correctness(&y, &yh);
        assert!(!r.correct);
    }

    /// The motivating case from §4: small output values pass the loose
    /// KernelBench *absolute* tolerance (1e-2) while being relatively
    /// wrong — the ν-criterion rejects them.
    #[test]
    fn nu_rejects_what_absolute_tolerance_accepts() {
        let y: Vec<f32> = vec![0.001; 500];
        let yh: Vec<f32> = vec![0.006; 500]; // |y−ŷ| = 0.005 < 1e-2 (abs passes)
        assert!((y[0] - yh[0]).abs() < 1e-2);
        let r = check_correctness(&y, &yh);
        assert!(!r.correct, "ν must reject 5× relative error");
        assert!(r.max_nu > 1.0);
    }

    /// Hardware imprecision: up to 1 % of elements may fail (§4 "errors
    /// should be allowed in a small fraction of cases").
    #[test]
    fn one_percent_outliers_tolerated() {
        let mut y: Vec<f32> = vec![1.0; 1000];
        let mut yh = y.clone();
        // 9 bad elements out of 1000 (0.9%).
        for i in 0..9 {
            yh[i * 100] = 2.0;
        }
        let r = check_correctness(&y, &yh);
        assert!(r.correct, "pass fraction {}", r.pass_fraction);
        // 11 bad elements (1.1%) fails.
        y = vec![1.0; 1000];
        yh = y.clone();
        for i in 0..11 {
            yh[i * 90] = 2.0;
        }
        assert!(!check_correctness(&y, &yh).correct);
    }

    #[test]
    fn nan_output_fails() {
        let y = vec![1.0f32; 16];
        let mut yh = y.clone();
        yh[3] = f32::NAN;
        yh[4] = f32::INFINITY;
        let r = check_correctness(&y, &yh);
        assert!(r.pass_fraction < 1.0);
        assert!(r.max_nu.is_infinite());
    }

    #[test]
    fn cosine_detects_angular_divergence() {
        let a = vec![1.0f32, 0.0, 0.0];
        let b = vec![0.0f32, 1.0, 0.0];
        assert!(cosine_similarity(&a, &b).abs() < 1e-9);
        let c = vec![-1.0f32, 0.0, 0.0];
        assert!((cosine_similarity(&a, &c) + 1.0).abs() < 1e-9);
        assert_eq!(cosine_similarity(&a, &[]), 0.0);
        assert_eq!(cosine_similarity(&[0.0, 0.0], &[0.0, 0.0]), 0.0);
    }

    #[test]
    fn mismatched_lengths_fail() {
        assert!(!check_correctness(&[1.0, 2.0], &[1.0]).correct);
        assert!(!check_correctness(&[], &[]).correct);
    }
}
