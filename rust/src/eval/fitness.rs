//! Fitness function (§3.2).
//!
//! ```text
//! f(k) = 0                      if compilation fails
//!        0.1                    if compiles but incorrect
//!        0.5 + 0.5 · s_norm     if correct
//! ```
//! with `s_norm = min(1, speedup / target)` and a default target of 2×
//! over the PyTorch baseline.

pub const FITNESS_COMPILE_FAIL: f64 = 0.0;
pub const FITNESS_INCORRECT: f64 = 0.1;
pub const DEFAULT_TARGET_SPEEDUP: f64 = 2.0;

/// Compute fitness for a correct kernel from its speedup.
pub fn fitness_correct(speedup: f64, target: f64) -> f64 {
    let s_norm = (speedup / target).min(1.0).max(0.0);
    0.5 + 0.5 * s_norm
}

/// Full fitness: compile status + correctness + speedup.
pub fn fitness(compiled: bool, correct: bool, speedup: f64, target: f64) -> f64 {
    if !compiled {
        FITNESS_COMPILE_FAIL
    } else if !correct {
        FITNESS_INCORRECT
    } else {
        fitness_correct(speedup, target)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_fitness_cases() {
        assert_eq!(fitness(false, false, 0.0, 2.0), 0.0);
        assert_eq!(fitness(true, false, 5.0, 2.0), 0.1);
        // Correct, zero speedup: floor of 0.5.
        assert_eq!(fitness(true, true, 0.0, 2.0), 0.5);
        // Correct at target: 1.0.
        assert_eq!(fitness(true, true, 2.0, 2.0), 1.0);
        // Saturates above target.
        assert_eq!(fitness(true, true, 10.0, 2.0), 1.0);
        // Midpoint.
        assert!((fitness(true, true, 1.0, 2.0) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn correctness_dominates_performance() {
        // An incorrect 50× "speedup" (reward hacking) scores below a
        // correct kernel with no speedup at all.
        assert!(fitness(true, false, 50.0, 2.0) < fitness(true, true, 0.1, 2.0));
    }

    #[test]
    fn monotone_in_speedup_below_target() {
        let mut prev = -1.0;
        for i in 0..20 {
            let s = i as f64 * 0.1;
            let f = fitness_correct(s, 2.0);
            assert!(f >= prev);
            prev = f;
        }
    }
}
