//! Compilation & evaluation pipeline (§3.1, §3.4, §4 metrics, App. B).
//!
//! Candidates flow through: compile (legality + render + syntax check) →
//! correctness validation (strict ν-criterion + cosine similarity, §4) →
//! performance measurement (App. B.2 adaptive methodology) → behavioral
//! classification → fitness (§3.2). Templated kernels are detected and
//! every parameter instantiation is evaluated independently (§3.4).

pub mod benchmark;
pub mod correctness;
pub mod fitness;
pub mod pipeline;
pub mod profiler;

pub use benchmark::{BenchConfig, BenchResult, Benchmarker};
pub use correctness::{check_correctness, cosine_similarity, nu_criterion, CorrectnessReport};
pub use fitness::{fitness, FITNESS_COMPILE_FAIL, FITNESS_INCORRECT};
pub use pipeline::{
    compile_check, compile_reject_record, EvalOutcome, EvalPipeline, EvalRecord, ExecBackend,
    RealBackend, RealRun,
};
pub use profiler::{profiler_feedback, ProfileReport};
