//! The compile → validate → benchmark → classify → score pipeline (§3.1).

use super::benchmark::{BenchConfig, Benchmarker};
use super::correctness::{check_correctness, CorrectnessReport};
use super::fitness;
use super::profiler;
use crate::classify;
use crate::hwsim::{baseline_cost, kernel_cost, DeviceProfile, NoisyClock};
use crate::ir::{check_legality, render_sycl, DefectKind, KernelGenome, ParamSet};
use crate::ir::render::syntax_check;
use crate::tasks::TaskSpec;
use crate::util::error;
use crate::util::rng::Rng;

/// Execution backend: the simulated GPU, or a real executor (the PJRT
/// runtime implements [`RealBackend`]).
pub enum ExecBackend {
    HwSim(DeviceProfile),
    Real(Box<dyn RealBackend>),
}

/// A real execution backend: produces reference/actual outputs and a
/// measured time for a genome (see `runtime::PjrtBackend`).
pub trait RealBackend {
    fn device_description(&self) -> String;
    fn baseline_ms(&mut self, task: &TaskSpec) -> error::Result<f64>;
    fn run(&mut self, task: &TaskSpec, genome: &KernelGenome) -> error::Result<RealRun>;
}

/// Outputs + timing from a real backend.
pub struct RealRun {
    pub expected: Vec<f32>,
    pub actual: Vec<f32>,
    pub time_ms: f64,
}

/// Stage at which evaluation stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvalOutcome {
    CompileError,
    Incorrect,
    Correct,
}

/// Full evaluation record for one candidate (stored in the database,
/// fed back into prompts).
#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub genome: KernelGenome,
    pub outcome: EvalOutcome,
    pub coords: [usize; 3],
    pub correctness: Option<CorrectnessReport>,
    pub time_ms: f64,
    pub baseline_ms: f64,
    pub speedup: f64,
    pub fitness: f64,
    /// Rendered kernel source.
    pub source: String,
    /// Console log: compile errors, test output, profiler summary — the
    /// "<last-kernel-log>" slot of the main prompt (App. E.1).
    pub log: String,
    /// Best parameter set if the kernel was templated (§3.4).
    pub best_params: Option<ParamSet>,
    /// All templated instantiations evaluated: (params, time_ms).
    pub param_sweep: Vec<(ParamSet, f64)>,
}

impl EvalRecord {
    pub fn compiled(&self) -> bool {
        self.outcome != EvalOutcome::CompileError
    }

    pub fn correct(&self) -> bool {
        self.outcome == EvalOutcome::Correct
    }
}

/// Compile-stage checks, shared verbatim by the inline pipeline and the
/// distributed compile workers ([`crate::dist::WorkerPool`]) so the two
/// paths can never drift: syntax first, then legality against the device
/// limits. `Err` carries the compiler-style log line.
pub fn compile_check(
    genome: &KernelGenome,
    source: &str,
    limits: &crate::ir::legality::DeviceLimits,
) -> Result<(), String> {
    if let Err(e) = syntax_check(source) {
        return Err(e);
    }
    if let Err(e) = check_legality(genome, limits) {
        return Err(format!("kernel.cpp: error: {e}"));
    }
    Ok(())
}

/// The `CompileError` evaluation record for a candidate rejected by
/// [`compile_check`] — shared by the inline pipeline and the distributed
/// compile workers so reject records are identical wherever they are
/// produced.
pub fn compile_reject_record(
    genome: &KernelGenome,
    source: String,
    log: String,
    baseline_ms: f64,
) -> EvalRecord {
    EvalRecord {
        genome: genome.clone(),
        outcome: EvalOutcome::CompileError,
        coords: genome.intended_coords(),
        correctness: None,
        time_ms: 0.0,
        baseline_ms,
        speedup: 0.0,
        fitness: fitness::FITNESS_COMPILE_FAIL,
        source,
        log,
        best_params: None,
        param_sweep: Vec::new(),
    }
}

/// The evaluation pipeline, bound to one task and one backend.
pub struct EvalPipeline {
    pub task: TaskSpec,
    pub backend: ExecBackend,
    pub bench_config: BenchConfig,
    pub target_speedup: f64,
    seed: u64,
    rng: Rng,
    baseline_ms_cache: Option<f64>,
}

impl EvalPipeline {
    pub fn new(task: TaskSpec, backend: ExecBackend, seed: u64) -> EvalPipeline {
        EvalPipeline {
            task,
            backend,
            bench_config: BenchConfig::quick(),
            target_speedup: fitness::DEFAULT_TARGET_SPEEDUP,
            seed,
            rng: Rng::with_stream(seed, 0xe7a1),
            baseline_ms_cache: None,
        }
    }

    /// The seed this pipeline was constructed with. A distributed
    /// [`crate::dist::WorkerPool`] whose `ClusterConfig::seed` equals this
    /// value produces the same outcome class for every genome as this
    /// pipeline — the hook the service fleet uses to keep pool evaluation
    /// verdict-identical to the engine's inline path.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Re-seed only the timing-noise stream (the measurement-noise RNG
    /// behind [`crate::hwsim::NoisyClock`]), leaving the verdict
    /// derivation — a pure function of (pipeline seed, genome id) —
    /// untouched. The distributed pool calls this with a per-worker
    /// stream so parallel devices produce independent noise realizations
    /// instead of duplicating one stream, without perturbing any
    /// outcome class.
    pub fn reseed_timing_noise(&mut self, stream: u64) {
        self.rng = Rng::with_stream(self.seed, 0xe7a1 ^ stream);
    }

    /// PyTorch-eager baseline time for the task (cached).
    pub fn baseline_ms(&mut self) -> f64 {
        if let Some(b) = self.baseline_ms_cache {
            return b;
        }
        let b = match &mut self.backend {
            ExecBackend::HwSim(dev) => baseline_cost(&self.task, dev),
            ExecBackend::Real(r) => r.baseline_ms(&self.task).unwrap_or(f64::INFINITY),
        };
        self.baseline_ms_cache = Some(b);
        b
    }

    /// Evaluate one candidate genome end-to-end.
    pub fn evaluate(&mut self, genome: &KernelGenome) -> EvalRecord {
        let compile_start = std::time::Instant::now();
        let source = render_sycl(genome);

        // ---- compile stage -------------------------------------------------
        let limits = match &self.backend {
            ExecBackend::HwSim(dev) => dev.limits(),
            ExecBackend::Real(_) => crate::ir::legality::DeviceLimits::default(),
        };
        let compiled = compile_check(genome, &source, &limits);
        crate::obs::global().observe_ms(
            "kf_eval_compile_ms",
            compile_start.elapsed().as_secs_f64() * 1000.0,
        );
        if let Err(log) = compiled {
            let baseline_ms = self.baseline_ms();
            return compile_reject_record(genome, source, log, baseline_ms);
        }

        self.evaluate_compiled(genome, source)
    }

    /// Evaluate a candidate whose compile stage already passed, reusing
    /// its rendered source — the entry point the distributed pool's
    /// execution workers use so they never redo the compile workers'
    /// render + checks. For a compilable genome,
    /// `evaluate(g) == evaluate_compiled(g, render_sycl(g))`.
    pub fn evaluate_compiled(&mut self, genome: &KernelGenome, source: String) -> EvalRecord {
        let exec_start = std::time::Instant::now();
        let record = self.evaluate_compiled_inner(genome, source);
        crate::obs::global()
            .observe_ms("kf_eval_exec_ms", exec_start.elapsed().as_secs_f64() * 1000.0);
        record
    }

    fn evaluate_compiled_inner(&mut self, genome: &KernelGenome, source: String) -> EvalRecord {
        let baseline_ms = self.baseline_ms();

        // ---- behavioral classification (static, on source) ------------------
        let coords = classify::classify(genome, &source);

        // ---- correctness + timing -------------------------------------------
        let (correctness, mut time_ms, mut log) = match &mut self.backend {
            ExecBackend::HwSim(dev) => {
                let dev = dev.clone();
                self.run_simulated(genome, &dev)
            }
            ExecBackend::Real(_) => self.run_real(genome),
        };

        if !correctness.correct {
            log.push_str(&format!(
                "\ncorrectness: FAILED (pass fraction {:.4}, max nu {:.4}, cosine {:.4})",
                correctness.pass_fraction, correctness.max_nu, correctness.cosine
            ));
            return EvalRecord {
                genome: genome.clone(),
                outcome: EvalOutcome::Incorrect,
                coords,
                correctness: Some(correctness),
                time_ms: 0.0,
                baseline_ms,
                speedup: 0.0,
                fitness: fitness::FITNESS_INCORRECT,
                source,
                log,
                best_params: None,
                param_sweep: Vec::new(),
            };
        }

        // ---- templated parameter sweep (§3.4) --------------------------------
        let mut best_params = None;
        let mut param_sweep = Vec::new();
        if let Some(spec) = &genome.template {
            if let ExecBackend::HwSim(dev) = &self.backend {
                let dev = dev.clone();
                let mut best = (genome.params.clone(), time_ms);
                for params in spec.instantiations(&genome.params) {
                    let mut candidate = genome.clone();
                    candidate.params = params.clone();
                    if check_legality(&candidate, &dev.limits()).is_err() {
                        continue;
                    }
                    let t = self.measure_simulated(&candidate, &dev);
                    param_sweep.push((params.clone(), t));
                    if t < best.1 {
                        best = (params, t);
                    }
                }
                log.push_str(&format!(
                    "\ntemplated sweep: {} instantiations, best {:?} at {:.4} ms",
                    param_sweep.len(),
                    (best.0.wg_x, best.0.wg_y, best.0.tile_m, best.0.tile_n, best.0.tile_k),
                    best.1
                ));
                time_ms = best.1;
                best_params = Some(best.0);
            }
        }

        let speedup = baseline_ms / time_ms;
        let f = fitness::fitness(true, true, speedup, self.target_speedup);
        log.push_str(&format!(
            "\ncorrectness: PASSED (cosine {:.5})\nruntime: {:.4} ms | baseline: {:.4} ms | speedup: {:.3}x",
            correctness.cosine, time_ms, baseline_ms, speedup
        ));

        EvalRecord {
            genome: genome.clone(),
            outcome: EvalOutcome::Correct,
            coords,
            correctness: Some(correctness),
            time_ms,
            baseline_ms,
            speedup,
            fitness: f,
            source,
            log,
            best_params,
            param_sweep,
        }
    }

    /// Simulated correctness + timing: synthesize outputs whose error
    /// profile reflects the genome's latent defects, then run them through
    /// the same ν-criterion code the real backend uses.
    ///
    /// The defect-noise stream is derived purely from (pipeline seed,
    /// genome id) — never from mutable pipeline state — so the verdict for
    /// a genome is independent of evaluation order. That is the
    /// determinism contract the distributed pool relies on
    /// (`crate::dist`): worker scheduling cannot perturb outcomes.
    fn run_simulated(
        &mut self,
        genome: &KernelGenome,
        dev: &DeviceProfile,
    ) -> (CorrectnessReport, f64, String) {
        const N: usize = 512;
        let mut expected = Vec::with_capacity(N);
        let mut rng = Rng::with_stream(
            self.seed ^ genome.id.wrapping_mul(0x9e3779b97f4a7c15),
            0x0a7,
        );
        for i in 0..N {
            // Deterministic pseudo-reference values of mixed magnitude.
            expected.push((((i * 37 + 11) % 97) as f32 / 17.0 - 2.0) * 1.7);
        }
        let mut actual = expected.clone();
        let mut log = String::new();
        for d in &genome.defects {
            match d.kind {
                DefectKind::SyntaxError => {} // already rejected at compile
                DefectKind::NumericBug => {
                    for a in actual.iter_mut() {
                        let noise = 1.0 + d.severity * rng.normal().abs().max(0.5);
                        *a *= noise as f32;
                    }
                    log.push_str("test: numeric mismatch against reference\n");
                }
                DefectKind::MissingBarrier => {
                    // A data race corrupts a scattered subset of outputs.
                    let n_bad = (N as f64 * 0.05).max(12.0) as usize;
                    for _ in 0..n_bad {
                        let i = rng.below(N);
                        actual[i] += 10.0 * (rng.f64() as f32 - 0.5);
                    }
                    log.push_str("test: nondeterministic output (possible race)\n");
                }
                DefectKind::OutOfBounds => {
                    for a in actual.iter_mut().take(N / 4) {
                        *a = f32::NAN;
                    }
                    log.push_str("xpu: error: page fault / illegal memory access\n");
                }
            }
        }
        // A race also occurs when SLM is tiled but the genome explicitly
        // carries the MissingBarrier defect — already handled above; the
        // renderer emits the needed barrier otherwise.
        let report = check_correctness(&expected, &actual);
        let time_ms = if report.correct {
            self.measure_simulated(genome, dev)
        } else {
            0.0
        };
        (report, time_ms, log)
    }

    /// Time one genome on the simulator through the App. B.2 harness.
    fn measure_simulated(&mut self, genome: &KernelGenome, dev: &DeviceProfile) -> f64 {
        let cost = kernel_cost(&self.task, genome, dev);
        let mut clock = NoisyClock::new(self.rng.next_u64(), dev);
        let mut source = |iters: usize| clock.observe_batch(cost.time_ms, iters);
        let result = Benchmarker::new(self.bench_config).run(&mut source);
        result.time_ms
    }

    fn run_real(&mut self, genome: &KernelGenome) -> (CorrectnessReport, f64, String) {
        let ExecBackend::Real(backend) = &mut self.backend else {
            unreachable!()
        };
        match backend.run(&self.task, genome) {
            Ok(run) => {
                let report = check_correctness(&run.expected, &run.actual);
                (report, run.time_ms, String::new())
            }
            Err(e) => (
                CorrectnessReport {
                    pass_fraction: 0.0,
                    max_nu: f64::INFINITY,
                    mean_nu: f64::INFINITY,
                    cosine: 0.0,
                    correct: false,
                },
                0.0,
                format!("runtime error: {e}"),
            ),
        }
    }

    /// Profiler feedback for a correct simulated kernel (App. B.3).
    pub fn profile(&self, genome: &KernelGenome) -> Option<profiler::ProfileReport> {
        match &self.backend {
            ExecBackend::HwSim(dev) => {
                let cost = kernel_cost(&self.task, genome, dev);
                Some(profiler::profiler_feedback(&cost, dev))
            }
            ExecBackend::Real(_) => None,
        }
    }

    pub fn device_description(&self) -> String {
        match &self.backend {
            ExecBackend::HwSim(dev) => dev.description.to_string(),
            ExecBackend::Real(r) => r.device_description(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{AlgoStructure, Defect, MemoryPattern, SyncStrategy, TemplateSpec};
    use crate::tasks::catalog;

    fn pipeline(task_id: &str) -> EvalPipeline {
        let task = catalog::find_task(task_id).unwrap();
        EvalPipeline::new(task, ExecBackend::HwSim(DeviceProfile::b580()), 42)
    }

    fn good_genome(task_id: &str) -> KernelGenome {
        let mut g = KernelGenome::direct_translation(task_id);
        g.mem = MemoryPattern::Coalesced;
        g.algo = AlgoStructure::Fused;
        g.sync = SyncStrategy::SubGroup;
        g.fused_ops = 8;
        g.params.vec_width = 8;
        g.params.wg_x = 256;
        g
    }

    #[test]
    fn correct_kernel_full_record() {
        let mut p = pipeline("1_Conv2D_ReLU_BiasAdd");
        let rec = p.evaluate(&good_genome("1_Conv2D_ReLU_BiasAdd"));
        assert_eq!(rec.outcome, EvalOutcome::Correct);
        assert!(rec.fitness >= 0.5);
        assert!(rec.speedup > 1.0, "speedup {}", rec.speedup);
        assert!(rec.log.contains("PASSED"));
        assert_eq!(rec.coords, [1, 1, 2]);
    }

    #[test]
    fn syntax_defect_gives_zero_fitness() {
        let mut p = pipeline("20_LeakyReLU");
        let mut g = good_genome("20_LeakyReLU");
        g.defects.push(Defect { kind: DefectKind::SyntaxError, severity: 1.0 });
        let rec = p.evaluate(&g);
        assert_eq!(rec.outcome, EvalOutcome::CompileError);
        assert_eq!(rec.fitness, 0.0);
        assert!(rec.log.contains("error"));
    }

    #[test]
    fn illegal_genome_fails_compile() {
        let mut p = pipeline("20_LeakyReLU");
        let mut g = good_genome("20_LeakyReLU");
        g.mem = MemoryPattern::TiledSlm;
        g.params.tile_m = 512;
        g.params.tile_n = 512;
        g.params.tile_k = 64; // SLM overflow
        let rec = p.evaluate(&g);
        assert_eq!(rec.outcome, EvalOutcome::CompileError);
        assert!(rec.log.contains("SLM"), "{}", rec.log);
    }

    #[test]
    fn numeric_bug_gives_incorrect() {
        let mut p = pipeline("20_LeakyReLU");
        let mut g = good_genome("20_LeakyReLU");
        g.defects.push(Defect { kind: DefectKind::NumericBug, severity: 0.2 });
        let rec = p.evaluate(&g);
        assert_eq!(rec.outcome, EvalOutcome::Incorrect);
        assert_eq!(rec.fitness, fitness::FITNESS_INCORRECT);
        assert!(rec.speedup == 0.0);
    }

    #[test]
    fn race_and_oob_detected() {
        let mut p = pipeline("20_LeakyReLU");
        for kind in [DefectKind::MissingBarrier, DefectKind::OutOfBounds] {
            let mut g = good_genome("20_LeakyReLU");
            g.mem = MemoryPattern::TiledSlm;
            g.defects.push(Defect { kind, severity: 1.0 });
            let rec = p.evaluate(&g);
            assert_eq!(rec.outcome, EvalOutcome::Incorrect, "{kind:?}");
        }
    }

    #[test]
    fn templated_sweep_picks_best_and_improves() {
        let mut p = pipeline("99_Matmul_GELU_Softmax");
        let mut g = good_genome("99_Matmul_GELU_Softmax");
        g.mem = MemoryPattern::TiledSlm;
        g.params.slm_pad = true;
        // Deliberately bad starting tile; the sweep includes the optimum.
        g.params.tile_m = 4;
        g.params.tile_n = 4;
        g.template = Some(TemplateSpec {
            wg_options: vec![(16, 16), (32, 8)],
            tile_options: vec![(4, 4, 16), (32, 32, 16), (64, 64, 16)],
            vec_options: vec![1, 8],
        });
        let rec = p.evaluate(&g);
        assert_eq!(rec.outcome, EvalOutcome::Correct);
        assert!(!rec.param_sweep.is_empty());
        let best = rec.best_params.unwrap();
        assert_eq!(best.tile_m, 32, "sweep should find the device-optimal tile");
        // Best time across the sweep <= any individual time.
        assert!(rec.param_sweep.iter().all(|(_, t)| *t >= rec.time_ms * 0.98));
    }

    #[test]
    fn baseline_cached() {
        let mut p = pipeline("20_LeakyReLU");
        let b1 = p.baseline_ms();
        let b2 = p.baseline_ms();
        assert_eq!(b1, b2);
        assert!(b1 > 0.0);
    }

    #[test]
    fn profile_summary_present() {
        let p = pipeline("20_LeakyReLU");
        let rep = p.profile(&good_genome("20_LeakyReLU")).unwrap();
        assert!(rep.summary.contains("% of peak bandwidth"));
    }
}
