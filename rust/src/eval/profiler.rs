//! Profiler feedback (App. B.3).
//!
//! For correct kernels, optional profiling provides: execution time,
//! achieved vs theoretical memory bandwidth, compute utilization, and a
//! memory-bound vs compute-bound classification — "structured into
//! natural language summaries (e.g. 'Kernel is memory-bound at 45 % of
//! peak bandwidth. Consider shared memory tiling to improve data
//! reuse.')". Stands in for Intel unitrace / NVIDIA Nsight.

use crate::hwsim::{Bottleneck, DeviceProfile, KernelCost};

/// Structured profile of one kernel run.
#[derive(Debug, Clone)]
pub struct ProfileReport {
    pub time_ms: f64,
    /// Achieved memory bandwidth, GB/s, and fraction of peak.
    pub achieved_bw_gbs: f64,
    pub bw_fraction: f64,
    /// Achieved compute, GFLOP/s, and fraction of peak.
    pub achieved_gflops: f64,
    pub compute_fraction: f64,
    pub bound: Bottleneck,
    /// The natural-language summary injected into prompts.
    pub summary: String,
}

/// Build the profiler report for a measured kernel.
pub fn profiler_feedback(cost: &KernelCost, device: &DeviceProfile) -> ProfileReport {
    let time_s = cost.time_ms / 1e3;
    let achieved_bw_gbs = if time_s > 0.0 {
        cost.bytes_moved as f64 / time_s / 1e9
    } else {
        0.0
    };
    let achieved_gflops = if time_s > 0.0 {
        cost.flops as f64 / time_s / 1e9
    } else {
        0.0
    };
    let bw_fraction = achieved_bw_gbs / device.peak_bw_gbs;
    let compute_fraction = achieved_gflops / device.peak_gflops;

    let advice = match cost.bound {
        Bottleneck::Memory => {
            if bw_fraction < 0.55 {
                "Consider shared memory tiling and vectorized (coalesced) loads to improve data reuse."
            } else if bw_fraction < 0.85 {
                "Access pattern is decent; register blocking and prefetching may close the remaining gap."
            } else {
                "Bandwidth is near peak; only algorithmic changes (fewer passes) can improve further."
            }
        }
        Bottleneck::Compute => {
            "Increase data reuse (larger tiles, register blocking) or reduce redundant arithmetic."
        }
        Bottleneck::SpecialFunction => {
            "Special-function units are saturated; reduce exp/div usage, e.g. exp2-based reformulation."
        }
        Bottleneck::LaunchOverhead => {
            "Launch overhead dominates; fuse the operation chain into fewer kernels."
        }
    };
    let summary = format!(
        "Kernel is {} at {:.0}% of peak bandwidth ({:.1} GB/s) and {:.0}% of peak compute ({:.1} GFLOP/s). {}",
        cost.bound.name(),
        bw_fraction * 100.0,
        achieved_bw_gbs,
        compute_fraction * 100.0,
        achieved_gflops,
        advice
    );

    ProfileReport {
        time_ms: cost.time_ms,
        achieved_bw_gbs,
        bw_fraction,
        achieved_gflops,
        compute_fraction,
        bound: cost.bound,
        summary,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwsim::{baseline_cost, kernel_cost};
    use crate::ir::{KernelGenome, MemoryPattern};
    use crate::tasks::catalog;

    #[test]
    fn memory_bound_kernel_gets_tiling_advice() {
        let task = catalog::find_task("20_LeakyReLU").unwrap();
        let dev = DeviceProfile::b580();
        let g = KernelGenome::direct_translation(&task.id); // scalar access
        let cost = kernel_cost(&task, &g, &dev);
        let rep = profiler_feedback(&cost, &dev);
        assert_eq!(rep.bound, Bottleneck::Memory);
        assert!(rep.summary.contains("memory-bound"));
        assert!(rep.summary.contains("shared memory tiling"), "{}", rep.summary);
        assert!(rep.bw_fraction > 0.0 && rep.bw_fraction < 0.6);
    }

    #[test]
    fn fractions_are_consistent() {
        let task = catalog::find_task("matmul_relu_postop").unwrap();
        let dev = DeviceProfile::b580();
        let mut g = KernelGenome::direct_translation(&task.id);
        g.mem = MemoryPattern::TiledSlm;
        g.algo = crate::ir::AlgoStructure::Fused;
        g.fused_ops = 2;
        let cost = kernel_cost(&task, &g, &dev);
        let rep = profiler_feedback(&cost, &dev);
        // Achieved fractions can't exceed 1.
        assert!(rep.bw_fraction <= 1.0);
        assert!(rep.compute_fraction <= 1.0);
        assert_eq!(rep.bound, Bottleneck::Compute);
        assert!(rep.summary.contains("compute-bound"));
        // Sanity: speedup context.
        assert!(baseline_cost(&task, &dev) > 0.0);
    }
}
