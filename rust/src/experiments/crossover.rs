//! §5.3 hardware-awareness crossover experiment (Table 3 / Table 10).
//!
//! Run KernelFoundry independently on two distinct GPUs (LNL and B580),
//! then benchmark each run's best kernel on the *other* device. The
//! hardware-speedup hws(k^A) = t_A(k^B) / t_A(k^A) quantifies how much
//! the kernel optimized *for* the device beats the transplanted one.

use super::tables::ExperimentScale;
use crate::config::FoundryConfig;
use crate::coordinator::EvolutionEngine;
use crate::eval::ExecBackend;
use crate::hwsim::{kernel_cost, DeviceProfile};
use crate::metrics::{self, aggregate_hws, HwsAggregate};
use crate::tasks::catalog;

/// Per-task crossover outcome (one Table 10 row).
#[derive(Debug, Clone)]
pub struct CrossoverRow {
    pub task_id: String,
    /// Runtimes on LNL: (LNL-optimized kernel, B580-optimized kernel).
    pub lnl_native_ms: f64,
    pub lnl_foreign_ms: f64,
    /// Runtimes on B580: (LNL-optimized kernel, B580-optimized kernel).
    pub b580_foreign_ms: f64,
    pub b580_native_ms: f64,
}

impl CrossoverRow {
    pub fn hws_lnl(&self) -> f64 {
        metrics::hws(self.lnl_native_ms, self.lnl_foreign_ms)
    }

    pub fn hws_b580(&self) -> f64 {
        metrics::hws(self.b580_native_ms, self.b580_foreign_ms)
    }
}

/// Full experiment result.
#[derive(Debug, Clone)]
pub struct CrossoverResult {
    pub rows: Vec<CrossoverRow>,
    pub lnl: HwsAggregate,
    pub b580: HwsAggregate,
}

impl CrossoverResult {
    pub fn markdown(&self) -> String {
        let mut rows: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| {
                vec![
                    r.task_id.clone(),
                    format!("{:.3}", r.lnl_native_ms),
                    format!("{:.3}", r.lnl_foreign_ms),
                    format!("{:.3}", r.hws_lnl()),
                    format!("{:.3}", r.b580_foreign_ms),
                    format!("{:.3}", r.b580_native_ms),
                    format!("{:.3}", r.hws_b580()),
                ]
            })
            .collect();
        rows.push(vec![
            "**aggregate**".into(),
            String::new(),
            String::new(),
            format!(
                "hws1={:.0}% hws1.5={:.0}% avg={:.3} geom={:.3}",
                self.lnl.hws_1 * 100.0,
                self.lnl.hws_15 * 100.0,
                self.lnl.avg,
                self.lnl.geom
            ),
            String::new(),
            String::new(),
            format!(
                "hws1={:.0}% hws1.5={:.0}% avg={:.3} geom={:.3}",
                self.b580.hws_1 * 100.0,
                self.b580.hws_15 * 100.0,
                self.b580.avg,
                self.b580.geom
            ),
        ]);
        metrics::render_table(
            &[
                "Operation",
                "LNL: opt-on-LNL [ms]",
                "LNL: opt-on-B580 [ms]",
                "hws (LNL)",
                "B580: opt-on-LNL [ms]",
                "B580: opt-on-B580 [ms]",
                "hws (B580)",
            ],
            &rows,
        )
    }

    pub fn csv(&self) -> String {
        let rows: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| {
                vec![
                    r.task_id.clone(),
                    format!("{:.4}", r.lnl_native_ms),
                    format!("{:.4}", r.lnl_foreign_ms),
                    format!("{:.4}", r.hws_lnl()),
                    format!("{:.4}", r.b580_foreign_ms),
                    format!("{:.4}", r.b580_native_ms),
                    format!("{:.4}", r.hws_b580()),
                ]
            })
            .collect();
        metrics::render_csv(
            &["task", "lnl_native", "lnl_foreign", "hws_lnl", "b580_foreign", "b580_native", "hws_b580"],
            &rows,
        )
    }
}

/// Run the crossover experiment over the repr. L2 set.
pub fn run_crossover(scale: ExperimentScale) -> CrossoverResult {
    let lnl = DeviceProfile::lnl();
    let b580 = DeviceProfile::b580();
    let mut config = FoundryConfig::paper_defaults();
    config.evolution.population = scale.population(8);
    config.evolution.max_generations = scale.iterations(40);

    let mut rows = Vec::new();
    for task in catalog::kernelbench_l2() {
        // Two independent optimization runs, one per device.
        let run_on = |device: &DeviceProfile, cfg: &FoundryConfig| {
            let mut c = cfg.clone();
            c.device = device.name.to_string();
            let mut engine =
                EvolutionEngine::new(c, task.clone(), ExecBackend::HwSim(device.clone()));
            engine.run(true)
        };
        let report_lnl = run_on(&lnl, &config);
        let report_b580 = run_on(&b580, &config);
        let (Some(best_lnl), Some(best_b580)) = (report_lnl.best, report_b580.best) else {
            continue; // rare with the default ensemble; skip like the paper's correct-only tables
        };

        // Cross-benchmark: noiseless model cost (the measurement the
        // paper does on physical hardware).
        let t = |genome: &crate::ir::KernelGenome, dev: &DeviceProfile| {
            kernel_cost(&task, genome, dev).time_ms
        };
        rows.push(CrossoverRow {
            task_id: task.id.clone(),
            lnl_native_ms: t(&best_lnl.genome, &lnl),
            lnl_foreign_ms: t(&best_b580.genome, &lnl),
            b580_foreign_ms: t(&best_lnl.genome, &b580),
            b580_native_ms: t(&best_b580.genome, &b580),
        });
    }

    let lnl_vals: Vec<f64> = rows.iter().map(|r| r.hws_lnl()).collect();
    let b580_vals: Vec<f64> = rows.iter().map(|r| r.hws_b580()).collect();
    CrossoverResult {
        lnl: aggregate_hws(&lnl_vals),
        b580: aggregate_hws(&b580_vals),
        rows,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crossover_quick_runs_and_shows_hardware_awareness() {
        let result = run_crossover(ExperimentScale::Quick);
        assert!(result.rows.len() >= 15, "only {} tasks completed", result.rows.len());
        // The §5.3 claim: most kernels beat their transplanted
        // counterpart on their home device.
        assert!(
            result.lnl.hws_1 >= 0.4 || result.b580.hws_1 >= 0.4,
            "no hardware awareness: lnl {:?} b580 {:?}",
            result.lnl,
            result.b580
        );
        let md = result.markdown();
        assert!(md.contains("hws"));
    }
}
