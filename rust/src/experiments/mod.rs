//! Experiment harnesses: one function per paper table / figure.
//!
//! Each harness regenerates its table's rows (markdown + CSV) from live
//! runs of the framework; `cargo bench` targets and the CLI subcommands
//! are thin wrappers over these. Columns marked "paper-reported" carry
//! the authors' published numbers (measured on their hardware) for
//! side-by-side display, exactly as the paper prints non-comparable
//! baselines.

pub mod crossover;
pub mod tables;

pub use crossover::{run_crossover, CrossoverResult};
pub use tables::{
    fig3_series, run_method_on_tasks, table1, table11, table2, table4, ExperimentScale, Method,
    MethodRun,
};
