//! Tables 1, 2, 4, 11 and Figure 3 harnesses.

use crate::config::FoundryConfig;
use crate::coordinator::{
    openevolve_like, repeated_prompting, single_objective_evolve, EvolutionEngine, RunReport,
};
use crate::eval::ExecBackend;
use crate::hwsim::{vendor_cost, DeviceProfile};
use crate::metrics::{self, aggregate, aggregate_row, Aggregate, TaskResult};
use crate::tasks::{catalog, TaskSpec};

/// Scale knob: `Quick` for CI smoke runs, `Paper` for the full protocol
/// (40 iterations, paper population sizes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExperimentScale {
    Quick,
    Paper,
}

impl ExperimentScale {
    pub fn from_env() -> ExperimentScale {
        match std::env::var("KF_BENCH_SCALE").as_deref() {
            Ok("quick") => ExperimentScale::Quick,
            _ => ExperimentScale::Paper,
        }
    }

    pub fn iterations(&self, paper: usize) -> usize {
        match self {
            ExperimentScale::Quick => (paper / 4).max(4),
            ExperimentScale::Paper => paper,
        }
    }

    pub fn population(&self, paper: usize) -> usize {
        match self {
            ExperimentScale::Quick => (paper / 2).max(2),
            ExperimentScale::Paper => paper,
        }
    }
}

/// A method under comparison.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    RepeatedPrompting,
    SingleObjectiveEvolve,
    OpenEvolve,
    Ours,
    OursParamOpt,
}

impl Method {
    pub fn label(&self) -> &'static str {
        match self {
            Method::RepeatedPrompting => "Kernelsseum-like (repeated prompting)",
            Method::SingleObjectiveEvolve => "AI CUDA Engineer-like (re-eval)",
            Method::OpenEvolve => "OpenEvolve",
            Method::Ours => "Ours",
            Method::OursParamOpt => "Ours + parameter optim.",
        }
    }
}

/// One method's per-task reports + aggregate.
#[derive(Debug, Clone)]
pub struct MethodRun {
    pub method: Method,
    pub reports: Vec<RunReport>,
    pub results: Vec<TaskResult>,
    pub aggregate: Aggregate,
}

/// Run one method over a task set.
pub fn run_method_on_tasks(
    method: Method,
    tasks: &[TaskSpec],
    config: &FoundryConfig,
    device: &DeviceProfile,
    iterations: usize,
) -> MethodRun {
    let mut reports = Vec::with_capacity(tasks.len());
    for task in tasks {
        let backend = ExecBackend::HwSim(device.clone());
        let report = match method {
            Method::RepeatedPrompting => {
                repeated_prompting(config, task, backend, iterations)
            }
            Method::SingleObjectiveEvolve => {
                single_objective_evolve(config, task, backend, iterations)
            }
            Method::OpenEvolve => openevolve_like(config, task, backend, iterations),
            Method::Ours | Method::OursParamOpt => {
                let mut c = config.clone();
                c.evolution.max_generations = iterations;
                let mut engine = EvolutionEngine::new(c, task.clone(), backend);
                engine.run(method == Method::OursParamOpt)
            }
        };
        reports.push(report);
    }
    let results: Vec<TaskResult> = reports.iter().map(|r| r.task_result()).collect();
    let aggregate = aggregate(&results);
    MethodRun {
        method,
        reports,
        results,
        aggregate,
    }
}

/// Rendered experiment output: headline markdown table + per-task CSV.
pub struct TableOutput {
    pub title: String,
    pub markdown: String,
    pub per_task_csv: String,
}

impl TableOutput {
    pub fn print(&self) {
        println!("\n## {}\n\n{}", self.title, self.markdown);
    }
}

const T1_HEADERS: [&str; 7] = [
    "Method",
    "LLMs",
    "Correct rate",
    "fast_1",
    "fast_2",
    "Avg. speedup",
    "Geom. speedup",
];

/// **Table 1**: baseline comparison on CUDA (A6000 profile) — repr. L1,
/// repr. L2, robust-kbench; Ours uses o3-mini on KernelBench (matching
/// the paper's model constraint) and the GPT-{o3, o4-mini, 4.1} ensemble
/// on robust-kbench.
pub fn table1(scale: ExperimentScale) -> Vec<TableOutput> {
    let device = DeviceProfile::a6000();
    let iters = scale.iterations(40);

    let mut outputs = Vec::new();
    let sets: [(&str, Vec<TaskSpec>, Vec<String>, Option<&str>, usize); 3] = [
        (
            "Table 1a — KernelBench repr. set L1 (n = 20, CUDA, A6000)",
            catalog::kernelbench_l1(),
            vec!["o3-mini".to_string()],
            None,
            scale.population(4),
        ),
        (
            "Table 1b — KernelBench repr. set L2 (n = 20, CUDA, A6000)",
            catalog::kernelbench_l2(),
            vec!["o3-mini".to_string()],
            None,
            scale.population(4),
        ),
        (
            "Table 1c — Robust-kbench (n = 12, CUDA, A6000)",
            catalog::robust_kbench(),
            vec!["gpt-o3".to_string(), "gpt-o4-mini".to_string(), "gpt-4.1".to_string()],
            None,
            scale.population(8),
        ),
    ];

    for (title, tasks, models, first, population) in sets {
        let mut config = FoundryConfig::paper_defaults();
        config.language = "cuda".to_string();
        config.device = "a6000".to_string();
        config.llm.models = models.clone();
        config.llm.first_iteration_model = first.map(String::from);
        config.evolution.population = population;

        let llms = models.join(", ");
        let mut rows: Vec<Vec<String>> = Vec::new();
        let mut csv_rows: Vec<Vec<String>> = Vec::new();

        // Paper-reported reference rows (authors' hardware; not comparable).
        rows.push(paper_row(title));

        let mut per_task: Vec<(Method, Vec<TaskResult>)> = Vec::new();
        for method in [
            Method::RepeatedPrompting,
            Method::SingleObjectiveEvolve,
            Method::Ours,
            Method::OursParamOpt,
        ] {
            let run = run_method_on_tasks(method, &tasks, &config, &device, iters);
            rows.push(aggregate_row(method.label(), &llms, &run.aggregate));
            per_task.push((method, run.results.clone()));
        }

        // Per-task CSV (Tables 7/8 appendix form).
        for (i, task) in tasks.iter().enumerate() {
            let mut row = vec![task.id.clone()];
            for (_, results) in &per_task {
                row.push(format!("{:.3}", results[i].speedup));
            }
            csv_rows.push(row);
        }
        let csv_headers: Vec<&str> = std::iter::once("task")
            .chain(per_task.iter().map(|(m, _)| m.label()))
            .collect();

        outputs.push(TableOutput {
            title: title.to_string(),
            markdown: metrics::render_table(&T1_HEADERS, &rows),
            per_task_csv: metrics::render_csv(&csv_headers, &csv_rows),
        });
    }
    outputs
}

fn paper_row(title: &str) -> Vec<String> {
    // The paper's published aggregate for the corresponding set
    // (original hardware: H100/L40S — displayed for reference only).
    let (label, correct, f1, f2, avg, geom) = if title.contains("L1") {
        ("AI CUDA Engineer (paper-reported, H100)", 1.0, 70, 20, 1.422, 1.222)
    } else if title.contains("L2") {
        ("AI CUDA Engineer (paper-reported, H100)", 1.0, 100, 10, 1.589, 1.524)
    } else {
        ("Robust-kbench (paper-reported, H100)", 1.0, 92, 50, 15.622, 2.591)
    };
    vec![
        label.to_string(),
        "—".to_string(),
        format!("{correct:.2}"),
        format!("{f1} %"),
        format!("{f2} %"),
        format!("{avg:.3}"),
        format!("{geom:.3}"),
    ]
}

/// **Table 2**: SYCL generation on B580 — Ours on the filtered set
/// (n = 111) and Ours vs OpenEvolve on repr. L2 at 10 and 40 iterations.
pub fn table2(scale: ExperimentScale) -> Vec<TableOutput> {
    let device = DeviceProfile::b580();
    let mut config = FoundryConfig::paper_defaults();
    config.llm.models = vec!["gpt-4.1".to_string(), "gpt-5-mini".to_string()];
    config.llm.first_iteration_model = Some("sonnet-4.5".to_string());
    config.evolution.population = scale.population(8);
    let iters40 = scale.iterations(40);
    let iters10 = scale.iterations(10);

    let mut outputs = Vec::new();

    // Block 1: filtered KernelBench, n = 111.
    let filtered = catalog::filtered_kernelbench();
    let ours_filtered =
        run_method_on_tasks(Method::OursParamOpt, &filtered, &config, &device, iters40);
    let mut rows = vec![aggregate_row(
        "Ours (SYCL)",
        "GPT-{4.1, 5-mini}, Sonnet-4.5",
        &ours_filtered.aggregate,
    )];
    rows.push(vec![
        "Robust-kbench (paper-reported, CUDA)".into(),
        "GPT-{o3, o4-mini, 4.1}, Sonnet-3.7".into(),
        "—".into(),
        "—".into(),
        "—".into(),
        "1.49".into(),
        "1.38".into(),
    ]);
    let csv: Vec<Vec<String>> = ours_filtered
        .results
        .iter()
        .map(|r| vec![r.task_id.clone(), format!("{}", r.correct), format!("{:.3}", r.speedup)])
        .collect();
    outputs.push(TableOutput {
        title: format!("Table 2a — KernelBench filtered (n = {}), SYCL, B580", filtered.len()),
        markdown: metrics::render_table(&T1_HEADERS, &rows),
        per_task_csv: metrics::render_csv(&["task", "correct", "speedup"], &csv),
    });

    // Block 2: Ours vs OpenEvolve on repr. L2 at 10 / 40 iterations.
    let l2 = catalog::kernelbench_l2();
    let ours40 = run_method_on_tasks(Method::OursParamOpt, &l2, &config, &device, iters40);
    let open40 = run_method_on_tasks(Method::OpenEvolve, &l2, &config, &device, iters40);
    let mut rows = Vec::new();
    let mut add = |label: &str, agg: &Aggregate| {
        rows.push(aggregate_row(label, "GPT-{4.1, 5-mini}, Sonnet-4.5", agg));
    };
    add("OpenEvolve (40 iters)", &open40.aggregate);
    add("Ours (40 iters + param. optim.)", &ours40.aggregate);
    // 10-iteration columns come from the same runs' series (cumulative
    // best at iteration 10) — matching how the paper reports both.
    let at10 = |run: &MethodRun| -> Aggregate {
        let results: Vec<TaskResult> = run
            .reports
            .iter()
            .map(|r| TaskResult {
                task_id: r.task_id.clone(),
                correct: r.best_at_iteration(iters10.saturating_sub(1)) > 0.0,
                speedup: r.best_at_iteration(iters10.saturating_sub(1)),
                time_ms: 0.0,
            })
            .collect();
        aggregate(&results)
    };
    add("OpenEvolve (10 iters)", &at10(&open40));
    add("Ours (10 iters)", &at10(&ours40));

    let csv: Vec<Vec<String>> = l2
        .iter()
        .enumerate()
        .map(|(i, t)| {
            vec![
                t.id.clone(),
                format!("{:.3}", ours40.results[i].speedup),
                format!("{:.3}", open40.results[i].speedup),
            ]
        })
        .collect();
    outputs.push(TableOutput {
        title: "Table 2b — repr. set L2 (n = 20), SYCL, B580 (per-task = Table 9)".to_string(),
        markdown: metrics::render_table(&T1_HEADERS, &rows),
        per_task_csv: metrics::render_csv(&["task", "ours", "openevolve"], &csv),
    });
    outputs
}

/// **Table 4**: comparison to the oneDNN-like vendor library on B580.
pub fn table4(scale: ExperimentScale) -> TableOutput {
    let device = DeviceProfile::b580();
    let mut config = FoundryConfig::paper_defaults();
    config.evolution.population = scale.population(8);
    let iters = scale.iterations(40);

    let tasks = catalog::onednn_tasks();
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for task in &tasks {
        let backend = ExecBackend::HwSim(device.clone());
        let mut c = config.clone();
        c.evolution.max_generations = iters;
        let mut engine = EvolutionEngine::new(c, task.clone(), backend);
        if task.has_initial_impl {
            // §5.4: concat+LN starts from a provided naive implementation.
            let mut init = crate::ir::KernelGenome::direct_translation(&task.id);
            init.mem = crate::ir::MemoryPattern::Coalesced;
            engine.initial_genome = Some(init);
        }
        let report = engine.run(true);
        // Speedup vs the vendor library, not vs eager.
        let vendor_ms = vendor_cost(task, &device);
        let speedup = report
            .best
            .as_ref()
            .map(|b| vendor_ms / b.time_ms)
            .unwrap_or(0.0);
        rows.push(vec![
            task.id.clone(),
            if task.has_initial_impl { "X" } else { "" }.to_string(),
            if task.user_instructions.is_some() { "X" } else { "" }.to_string(),
            format!("{speedup:.2}"),
        ]);
        csv.push(vec![task.id.clone(), format!("{speedup:.4}")]);
    }
    TableOutput {
        title: "Table 4 — speedup vs oneDNN-like vendor library (SYCL, B580)".to_string(),
        markdown: metrics::render_table(
            &["Operation", "Initial impl.", "User instructions", "Speedup"],
            &rows,
        ),
        per_task_csv: metrics::render_csv(&["task", "speedup_vs_vendor"], &csv),
    }
}

/// **Figure 3**: improvement over iterations (cumulative best speedup),
/// Ours vs OpenEvolve, averaged over the repr. L2 set. Returns CSV.
pub fn fig3_series(scale: ExperimentScale) -> TableOutput {
    let device = DeviceProfile::b580();
    let mut config = FoundryConfig::paper_defaults();
    config.evolution.population = scale.population(8);
    let iters = scale.iterations(40);
    let l2 = catalog::kernelbench_l2();
    let ours = run_method_on_tasks(Method::Ours, &l2, &config, &device, iters);
    let open = run_method_on_tasks(Method::OpenEvolve, &l2, &config, &device, iters);

    let mut csv_rows = Vec::new();
    for i in 0..iters {
        let avg = |run: &MethodRun| {
            let v: Vec<f64> = run.reports.iter().map(|r| r.best_at_iteration(i)).collect();
            crate::util::stats::mean(&v)
        };
        csv_rows.push(vec![
            format!("{i}"),
            format!("{:.4}", avg(&ours)),
            format!("{:.4}", avg(&open)),
        ]);
    }
    let md_rows: Vec<Vec<String>> = csv_rows
        .iter()
        .step_by((iters / 10).max(1))
        .cloned()
        .collect();
    TableOutput {
        title: "Figure 3 — improvement over iterations (cumulative best, mean over repr. L2)"
            .to_string(),
        markdown: metrics::render_table(&["iteration", "ours", "openevolve"], &md_rows),
        per_task_csv: metrics::render_csv(&["iteration", "ours", "openevolve"], &csv_rows),
    }
}

/// **Table 11**: GPT-OSS-20B reproducibility run (repr. L2, SYCL, LNL,
/// population 4). A third or so of the tasks should fail to yield any
/// correct kernel.
pub fn table11(scale: ExperimentScale) -> TableOutput {
    let device = DeviceProfile::lnl();
    let mut config = FoundryConfig::paper_defaults();
    config.llm.models = vec!["gpt-oss-20b".to_string()];
    config.llm.first_iteration_model = None;
    config.evolution.population = scale.population(4);
    let iters = scale.iterations(40);

    let l2 = catalog::kernelbench_l2();
    let run = run_method_on_tasks(Method::Ours, &l2, &config, &device, iters);
    let rows: Vec<Vec<String>> = run
        .results
        .iter()
        .map(|r| {
            vec![
                r.task_id.clone(),
                if r.correct {
                    format!("{:.3}", r.speedup)
                } else {
                    "-".to_string()
                },
            ]
        })
        .collect();
    let failed = run.results.iter().filter(|r| !r.correct).count();
    TableOutput {
        title: format!(
            "Table 11 — GPT-OSS-20B on repr. L2 (SYCL, LNL): {failed}/{} tasks without a correct kernel",
            run.results.len()
        ),
        markdown: metrics::render_table(&["Operation", "Speedup"], &rows),
        per_task_csv: metrics::render_csv(
            &["task", "speedup"],
            &rows,
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_table4_runs() {
        let out = table4(ExperimentScale::Quick);
        assert!(out.markdown.contains("concat_layernorm"));
        assert!(out.per_task_csv.lines().count() == 6); // header + 5 ops
    }

    #[test]
    fn scale_knobs() {
        assert_eq!(ExperimentScale::Quick.iterations(40), 10);
        assert_eq!(ExperimentScale::Paper.iterations(40), 40);
        assert_eq!(ExperimentScale::Quick.population(8), 4);
    }
}
