//! Gradient-informed evolution (§3.3, Fig. 2).
//!
//! From the accumulated transition history we compute, for each occupied
//! cell **b**, three gradient components over the behavioral dimensions:
//!
//! * **Fitness gradient** ∇F (eq. 1): transition fitness deltas weighted
//!   by movement direction and exponential time decay.
//! * **Improvement-rate gradient** ∇R (eq. 2): difference of improvement
//!   probabilities conditioned on moving up vs down a dimension.
//! * **Exploration gradient** ∇E (eq. 3): a pull toward empty and
//!   low-quality cells, weighted by inverse L1 distance and improvement
//!   potential `f_max - f_c`.
//!
//! Combined (eq. 4) as `∇ = α∇F + β∇R + γ∇E` with (α, β, γ) = (0.4, 0.4,
//! 0.2). Gradients feed parent-selection weights and are translated into
//! natural-language mutation hints injected into the generation prompt.

use crate::archive::MapElites;
use crate::classify::Coords;
use crate::transitions::{Outcome, TransitionTracker};

pub const DIMS: usize = 3;

/// Default mixing weights (α, β, γ) from eq. 4.
pub const ALPHA: f64 = 0.4;
pub const BETA: f64 = 0.4;
pub const GAMMA: f64 = 0.2;

/// Exponential time-decay rate per iteration of age for w(t) in eq. 1.
pub const TIME_DECAY: f64 = 0.05;

/// Fitness threshold below which an occupied cell counts as "low quality"
/// for the ∇E target set.
pub const LOW_QUALITY: f64 = 0.5;

/// A per-cell gradient vector over the behavioral dimensions.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct GradientVec {
    pub d: [f64; DIMS],
}

impl GradientVec {
    pub fn magnitude(&self) -> f64 {
        self.d.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn scaled(&self, k: f64) -> GradientVec {
        GradientVec {
            d: [self.d[0] * k, self.d[1] * k, self.d[2] * k],
        }
    }

    pub fn add(&self, other: &GradientVec) -> GradientVec {
        GradientVec {
            d: [
                self.d[0] + other.d[0],
                self.d[1] + other.d[1],
                self.d[2] + other.d[2],
            ],
        }
    }
}

/// All gradient components for one cell.
#[derive(Debug, Clone, Copy, Default)]
pub struct CellGradient {
    pub fitness: GradientVec,
    pub improvement: GradientVec,
    pub exploration: GradientVec,
    pub combined: GradientVec,
}

/// The gradient estimator (Fig. 2's "Gradient Estimator" box).
#[derive(Debug, Clone)]
pub struct GradientEstimator {
    pub alpha: f64,
    pub beta: f64,
    pub gamma: f64,
    pub time_decay: f64,
    pub low_quality: f64,
}

impl Default for GradientEstimator {
    fn default() -> GradientEstimator {
        GradientEstimator {
            alpha: ALPHA,
            beta: BETA,
            gamma: GAMMA,
            time_decay: TIME_DECAY,
            low_quality: LOW_QUALITY,
        }
    }
}

impl GradientEstimator {
    /// Eq. 1: ∇_d F ≈ (1/|T|) Σ_t Δf_t · sign(b_c^d − b_p^d) · w(t).
    pub fn fitness_gradient(
        &self,
        tracker: &TransitionTracker,
        cell: Coords,
        now_iteration: usize,
    ) -> GradientVec {
        // Perf: iterate the buffer in place instead of materializing the
        // per-cell transition Vec (this runs once per occupied cell per
        // selection).
        let mut g = GradientVec::default();
        let mut n = 0usize;
        for t in tracker.iter().filter(|t| t.parent_coords == cell) {
            let age = now_iteration.saturating_sub(t.iteration) as f64;
            let w = (-self.time_decay * age).exp();
            for d in 0..DIMS {
                g.d[d] += t.delta_f() * (t.delta_b(d).signum() as f64) * w;
            }
            n += 1;
        }
        if n == 0 {
            return GradientVec::default();
        }
        g.scaled(1.0 / n as f64)
    }

    /// Eq. 2: ∇_d R ≈ P(improvement | Δb_d > 0) − P(improvement | Δb_d < 0).
    ///
    /// Probabilities are estimated from all buffered transitions (not just
    /// this cell's) so young cells inherit global directional knowledge.
    pub fn improvement_gradient(&self, tracker: &TransitionTracker) -> GradientVec {
        let mut g = GradientVec::default();
        for d in 0..DIMS {
            let (mut up_n, mut up_imp, mut down_n, mut down_imp) = (0usize, 0usize, 0usize, 0usize);
            for t in tracker.iter() {
                let db = t.delta_b(d);
                let imp = t.outcome == Outcome::Improvement;
                if db > 0 {
                    up_n += 1;
                    up_imp += imp as usize;
                } else if db < 0 {
                    down_n += 1;
                    down_imp += imp as usize;
                }
            }
            let p_up = if up_n > 0 { up_imp as f64 / up_n as f64 } else { 0.0 };
            let p_down = if down_n > 0 {
                down_imp as f64 / down_n as f64
            } else {
                0.0
            };
            g.d[d] = p_up - p_down;
        }
        g
    }

    /// Eq. 3: ∇_b E ∝ Σ_{c∈E} (f_max − f_c)/‖c−b‖₁ · (c−b)/‖c−b‖₁ where E
    /// is the set of empty cells (f_c = 0) and low-quality occupied cells.
    pub fn exploration_gradient(&self, archive: &MapElites, cell: Coords) -> GradientVec {
        let f_max = archive.f_max();
        let mut g = GradientVec::default();
        let mut add_target = |c: Coords, f_c: f64| {
            let diff: [f64; DIMS] = [
                c[0] as f64 - cell[0] as f64,
                c[1] as f64 - cell[1] as f64,
                c[2] as f64 - cell[2] as f64,
            ];
            let l1: f64 = diff.iter().map(|x| x.abs()).sum();
            if l1 == 0.0 {
                return;
            }
            let pull = (f_max - f_c).max(0.0) / l1;
            for d in 0..DIMS {
                g.d[d] += pull * diff[d] / l1;
            }
        };
        for c in archive.empty_coords() {
            add_target(c, 0.0);
        }
        for (c, f) in archive.low_quality_coords(self.low_quality) {
            add_target(c, f);
        }
        // Normalize so magnitude is comparable with ∇F / ∇R regardless of
        // how many empty cells remain.
        let m = g.magnitude();
        if m > 1.0 {
            g = g.scaled(1.0 / m);
        }
        g
    }

    /// Eq. 4: combined per-cell gradient.
    pub fn estimate(
        &self,
        tracker: &TransitionTracker,
        archive: &MapElites,
        cell: Coords,
        now_iteration: usize,
    ) -> CellGradient {
        let f = self.fitness_gradient(tracker, cell, now_iteration);
        let r = self.improvement_gradient(tracker);
        let e = self.exploration_gradient(archive, cell);
        let combined = f
            .scaled(self.alpha)
            .add(&r.scaled(self.beta))
            .add(&e.scaled(self.gamma));
        CellGradient {
            fitness: f,
            improvement: r,
            exploration: e,
            combined,
        }
    }

    /// Selection weights over occupied cells: elite fitness modulated by
    /// gradient magnitude ("cells with strong positive gradient
    /// magnitudes receive higher sampling probability", while fitness
    /// keeps effort on productive regions — §3.3 "directing computational
    /// effort toward productive regions").
    pub fn sampling_weights(
        &self,
        tracker: &TransitionTracker,
        archive: &MapElites,
        now_iteration: usize,
    ) -> Vec<(Coords, f64)> {
        // Perf: ∇R (eq. 2) is estimated from the whole buffer and does
        // not depend on the cell — hoist it out of the per-cell loop
        // (EXPERIMENTS.md §Perf: 141 µs → ~40 µs per call on a full
        // 64-cell archive with a 256-deep buffer).
        let r = self.improvement_gradient(tracker);
        archive
            .occupied_coords()
            .into_iter()
            .map(|c| {
                let f = self.fitness_gradient(tracker, c, now_iteration);
                let e = self.exploration_gradient(archive, c);
                let combined = f
                    .scaled(self.alpha)
                    .add(&r.scaled(self.beta))
                    .add(&e.scaled(self.gamma));
                let fitness = archive.get(c).map(|el| el.fitness).unwrap_or(0.0);
                (c, (0.05 + fitness) * (0.5 + combined.magnitude()))
            })
            .collect()
    }
}

/// Gradient-to-prompt translation (§3.3): turn gradient directions into
/// natural-language mutation hints, e.g. a positive gradient in d_mem
/// yields "consider adding shared memory tiling".
pub fn hints_for(cell: Coords, grad: &CellGradient) -> Vec<String> {
    let mut hints = Vec::new();
    let g = &grad.combined;
    const EPS: f64 = 0.05;

    // d_mem
    if g.d[0] > EPS {
        match cell[0] {
            0 => hints.push(
                "Consider coalescing global memory accesses and using vectorized loads (sycl::vec)."
                    .to_string(),
            ),
            1 => hints.push("Consider adding shared memory tiling to improve data reuse.".to_string()),
            _ => hints.push(
                "Implement register blocking for data reuse and prefetch the next tile.".to_string(),
            ),
        }
    } else if g.d[0] < -EPS {
        hints.push(
            "The added memory hierarchy may not pay off here; try a simpler access pattern."
                .to_string(),
        );
    }

    // d_algo
    if g.d[1] > EPS {
        match cell[1] {
            0 => hints.push("Fuse consecutive operations into a single pass over the data.".to_string()),
            1 => hints.push(
                "Reformulate the algorithm (e.g. online normalization / flash-style streaming) to reduce passes."
                    .to_string(),
            ),
            _ => hints.push(
                "Look for an asymptotically better decomposition of the computation.".to_string(),
            ),
        }
    } else if g.d[1] < -EPS {
        hints.push("Algorithmic reformulation is regressing fitness; consider the simpler fused form.".to_string());
    }

    // d_sync
    if g.d[2] > EPS {
        match cell[2] {
            0 => hints.push("Use work-group barriers to coordinate a cooperative computation.".to_string()),
            1 => hints.push(
                "Replace work-group barriers with sub-group primitives (shuffles, reductions)."
                    .to_string(),
            ),
            _ => hints.push("Consider global coordination via atomics for the final reduction.".to_string()),
        }
    } else if g.d[2] < -EPS {
        hints.push("Synchronization overhead appears excessive; reduce barrier or atomic use.".to_string());
    }

    hints
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::archive::{Elite, MapElites};
    use crate::ir::KernelGenome;
    use crate::transitions::Transition;

    fn elite(coords: Coords, fitness: f64) -> Elite {
        Elite {
            genome: KernelGenome::direct_translation("t"),
            coords,
            fitness,
            speedup: 1.0,
            runtime_ms: 1.0,
            iteration: 0,
        }
    }

    fn trans(p: Coords, c: Coords, pf: f64, cf: f64, iter: usize) -> Transition {
        Transition {
            parent_coords: p,
            child_coords: c,
            parent_fitness: pf,
            child_fitness: cf,
            outcome: if cf > pf {
                Outcome::Improvement
            } else {
                Outcome::Regression
            },
            iteration: iter,
        }
    }

    #[test]
    fn fitness_gradient_points_toward_improvement() {
        let est = GradientEstimator::default();
        let mut tr = TransitionTracker::new(64);
        // Moving up d_mem from (0,0,0) improved fitness twice.
        tr.record(trans([0, 0, 0], [1, 0, 0], 0.5, 0.7, 0));
        tr.record(trans([0, 0, 0], [2, 0, 0], 0.5, 0.8, 1));
        // Moving up d_sync hurt.
        tr.record(trans([0, 0, 0], [0, 0, 1], 0.5, 0.3, 2));
        let g = est.fitness_gradient(&tr, [0, 0, 0], 3);
        assert!(g.d[0] > 0.0, "d_mem gradient {:?}", g);
        assert!(g.d[2] < 0.0, "d_sync gradient {:?}", g);
        assert_eq!(g.d[1], 0.0);
    }

    #[test]
    fn time_decay_prioritizes_recent() {
        let est = GradientEstimator::default();
        let mut old = TransitionTracker::new(64);
        let mut new = TransitionTracker::new(64);
        old.record(trans([0, 0, 0], [1, 0, 0], 0.5, 0.9, 0));
        new.record(trans([0, 0, 0], [1, 0, 0], 0.5, 0.9, 99));
        let g_old = est.fitness_gradient(&old, [0, 0, 0], 100);
        let g_new = est.fitness_gradient(&new, [0, 0, 0], 100);
        assert!(g_new.d[0] > g_old.d[0] * 10.0);
    }

    #[test]
    fn improvement_gradient_is_probability_difference() {
        let est = GradientEstimator::default();
        let mut tr = TransitionTracker::new(64);
        // Up-moves on d_algo improve 2/2; down-moves improve 0/1.
        tr.record(trans([0, 1, 0], [0, 2, 0], 0.4, 0.6, 0));
        tr.record(trans([0, 0, 0], [0, 1, 0], 0.4, 0.5, 1));
        tr.record(trans([0, 2, 0], [0, 1, 0], 0.6, 0.4, 2));
        let g = est.improvement_gradient(&tr);
        assert!((g.d[1] - 1.0).abs() < 1e-12);
        // Bounded in [-1, 1] by construction.
        assert!(g.d.iter().all(|x| (-1.0..=1.0).contains(x)));
    }

    #[test]
    fn exploration_gradient_pulls_toward_empty_space() {
        let est = GradientEstimator::default();
        let mut a = MapElites::new(4);
        // Occupy the low corner; everything above is empty.
        a.insert(elite([0, 0, 0], 0.9));
        let g = est.exploration_gradient(&a, [0, 0, 0]);
        assert!(g.d[0] > 0.0 && g.d[1] > 0.0 && g.d[2] > 0.0, "{g:?}");
    }

    #[test]
    fn exploration_gradient_zero_when_full_and_good() {
        let est = GradientEstimator::default();
        let mut a = MapElites::new(2);
        for m in 0..2 {
            for al in 0..2 {
                for s in 0..2 {
                    a.insert(elite([m, al, s], 0.9));
                }
            }
        }
        let g = est.exploration_gradient(&a, [0, 0, 0]);
        assert!(g.magnitude() < 1e-9, "{g:?}");
    }

    #[test]
    fn combined_respects_mixing_weights() {
        let est = GradientEstimator::default();
        let mut tr = TransitionTracker::new(64);
        tr.record(trans([0, 0, 0], [1, 0, 0], 0.5, 0.9, 10));
        let mut a = MapElites::new(4);
        a.insert(elite([0, 0, 0], 0.5));
        let g = est.estimate(&tr, &a, [0, 0, 0], 10);
        let manual = g
            .fitness
            .scaled(ALPHA)
            .add(&g.improvement.scaled(BETA))
            .add(&g.exploration.scaled(GAMMA));
        for d in 0..DIMS {
            assert!((g.combined.d[d] - manual.d[d]).abs() < 1e-12);
        }
    }

    #[test]
    fn hints_match_direction_and_level() {
        let grad = CellGradient {
            combined: GradientVec { d: [0.5, 0.0, -0.5] },
            ..Default::default()
        };
        let hints = hints_for([1, 0, 1], &grad);
        assert!(hints.iter().any(|h| h.contains("shared memory tiling")));
        assert!(hints.iter().any(|h| h.contains("Synchronization overhead")));
    }

    #[test]
    fn sampling_weights_cover_occupied_cells() {
        let est = GradientEstimator::default();
        let tr = TransitionTracker::new(8);
        let mut a = MapElites::new(4);
        a.insert(elite([0, 0, 0], 0.5));
        a.insert(elite([1, 1, 0], 0.6));
        let w = est.sampling_weights(&tr, &a, 0);
        assert_eq!(w.len(), 2);
        assert!(w.iter().all(|(_, weight)| *weight > 0.0));
    }
}
