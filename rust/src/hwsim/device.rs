//! Device profiles for the simulated GPUs.

use crate::ir::legality::DeviceLimits;

/// Static description of a (simulated) GPU.
#[derive(Debug, Clone)]
pub struct DeviceProfile {
    pub name: &'static str,
    /// Marketing-style description included in prompts ("hardware
    /// specification" section of App. E.1).
    pub description: &'static str,
    /// Peak memory bandwidth, GB/s.
    pub peak_bw_gbs: f64,
    /// Peak f32 throughput, GFLOP/s.
    pub peak_gflops: f64,
    /// Special-function (exp/div/rsqrt) throughput, Gop/s.
    pub sfu_gops: f64,
    /// Shared local memory per work-group, bytes.
    pub slm_bytes: u64,
    pub max_work_group: u64,
    pub sub_group_width: u32,
    /// Per-kernel-launch overhead, microseconds.
    pub launch_us: f64,
    /// Per-op framework dispatch overhead for the eager baseline, µs.
    pub eager_dispatch_us: f64,
    /// torch.autograd bookkeeping multiplier on backward baselines
    /// (App. B.2 discussion: backward baseline measured through
    /// torch.autograd.grad carries significant overhead).
    pub autograd_overhead: f64,
    /// Device-optimal tile edge (log2 sweet spot for SLM tiling).
    pub optimal_tile: u32,
    /// Device-optimal work-group size.
    pub optimal_wg: u32,
    /// Preferred vector load width.
    pub preferred_vec: u32,
    /// Parameter sensitivity: σ of the log2-gaussian efficiency curve
    /// around the optima. Smaller = more sensitive to wrong parameters
    /// (integrated GPUs with small caches are less forgiving).
    pub param_sigma: f64,
    /// Multiplicative penalty on SLM-tiled kernels without padding
    /// (bank conflicts).
    pub bank_conflict_penalty: f64,
    /// Relative measurement noise (lognormal sigma).
    pub noise_sigma: f64,
}

impl DeviceProfile {
    /// Intel Arc 140V integrated GPU (Lunar Lake), §4 "LNL".
    pub fn lnl() -> DeviceProfile {
        DeviceProfile {
            name: "lnl",
            description: "Intel Arc 140V (Lunar Lake iGPU): 8 Xe2 cores, 64 EUs, \
                          shared LPDDR5X-8533 (~136 GB/s), 128 KiB SLM/WG, \
                          sub-group width 16, unified memory",
            peak_bw_gbs: 136.0,
            peak_gflops: 3900.0,
            sfu_gops: 244.0,
            slm_bytes: 128 * 1024,
            max_work_group: 1024,
            sub_group_width: 16,
            launch_us: 9.0,
            eager_dispatch_us: 28.0,
            autograd_overhead: 9.0,
            optimal_tile: 16,
            optimal_wg: 128,
            preferred_vec: 4,
            param_sigma: 0.9,
            bank_conflict_penalty: 0.90,
            noise_sigma: 0.030,
        }
    }

    /// Intel Arc B580 discrete GPU (Battlemage), §4 "BMG"/"B580".
    pub fn b580() -> DeviceProfile {
        DeviceProfile {
            name: "b580",
            description: "Intel Arc B580 (Battlemage dGPU): 20 Xe2 cores, 160 EUs, \
                          12 GiB GDDR6 (456 GB/s), 128 KiB SLM/WG, sub-group \
                          width 16, PCIe host transfer",
            peak_bw_gbs: 456.0,
            peak_gflops: 13700.0,
            sfu_gops: 856.0,
            slm_bytes: 128 * 1024,
            max_work_group: 1024,
            sub_group_width: 16,
            launch_us: 6.0,
            eager_dispatch_us: 18.0,
            autograd_overhead: 11.0,
            optimal_tile: 32,
            optimal_wg: 256,
            preferred_vec: 8,
            param_sigma: 1.6,
            bank_conflict_penalty: 0.82,
            noise_sigma: 0.020,
        }
    }

    /// NVIDIA RTX A6000 (Ampere), used for the CUDA baseline comparison.
    pub fn a6000() -> DeviceProfile {
        DeviceProfile {
            name: "a6000",
            description: "NVIDIA RTX A6000 (Ampere): 84 SMs, 48 GiB GDDR6 \
                          (768 GB/s), 100 KiB smem/SM, warp width 32",
            peak_bw_gbs: 768.0,
            peak_gflops: 38700.0,
            sfu_gops: 4840.0,
            slm_bytes: 100 * 1024,
            max_work_group: 1024,
            sub_group_width: 32,
            launch_us: 5.0,
            eager_dispatch_us: 14.0,
            autograd_overhead: 10.0,
            optimal_tile: 32,
            optimal_wg: 256,
            preferred_vec: 4,
            param_sigma: 1.3,
            bank_conflict_penalty: 0.85,
            noise_sigma: 0.020,
        }
    }

    pub fn by_name(name: &str) -> Option<DeviceProfile> {
        match name {
            "lnl" | "arc140v" => Some(DeviceProfile::lnl()),
            "b580" | "bmg" => Some(DeviceProfile::b580()),
            "a6000" => Some(DeviceProfile::a6000()),
            _ => None,
        }
    }

    pub fn all() -> Vec<DeviceProfile> {
        vec![DeviceProfile::lnl(), DeviceProfile::b580(), DeviceProfile::a6000()]
    }

    /// Legality limits slice for the `ir` layer.
    pub fn limits(&self) -> DeviceLimits {
        DeviceLimits {
            max_work_group_size: self.max_work_group,
            slm_bytes: self.slm_bytes,
            sub_group_sizes: &[8, 16, 32],
        }
    }

    /// log2-gaussian efficiency of a parameter value vs the device
    /// optimum: 1.0 at the optimum, falling off with `param_sigma`.
    pub fn param_match(&self, value: u32, optimum: u32) -> f64 {
        let d = (value.max(1) as f64).log2() - (optimum as f64).log2();
        (-d * d / (2.0 * self.param_sigma * self.param_sigma)).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_name() {
        assert_eq!(DeviceProfile::by_name("lnl").unwrap().name, "lnl");
        assert_eq!(DeviceProfile::by_name("bmg").unwrap().name, "b580");
        assert!(DeviceProfile::by_name("h100").is_none());
    }

    #[test]
    fn profiles_are_distinct() {
        let lnl = DeviceProfile::lnl();
        let b580 = DeviceProfile::b580();
        assert!(b580.peak_bw_gbs > 2.0 * lnl.peak_bw_gbs);
        assert_ne!(lnl.optimal_tile, b580.optimal_tile);
        assert_ne!(lnl.optimal_wg, b580.optimal_wg);
        assert!(lnl.param_sigma < b580.param_sigma, "iGPU is less forgiving");
    }

    #[test]
    fn param_match_peaks_at_optimum() {
        let d = DeviceProfile::b580();
        assert!((d.param_match(32, 32) - 1.0).abs() < 1e-12);
        assert!(d.param_match(16, 32) < 1.0);
        assert!(d.param_match(16, 32) > d.param_match(8, 32));
        // Symmetric in log space.
        assert!((d.param_match(16, 32) - d.param_match(64, 32)).abs() < 1e-12);
    }

    #[test]
    fn lnl_more_sensitive_than_b580() {
        let lnl = DeviceProfile::lnl();
        let b580 = DeviceProfile::b580();
        // Same relative parameter error hurts more on the iGPU.
        assert!(lnl.param_match(64, 16) < b580.param_match(128, 32));
    }
}
