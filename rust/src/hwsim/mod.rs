//! Analytical GPU performance model.
//!
//! Substitute for the paper's physical GPUs (Intel Arc 140V "LNL", Intel
//! Arc B580 "BMG", NVIDIA RTX A6000) per the substitution rule in
//! DESIGN.md §2. A roofline model with feature-dependent efficiencies:
//! kernel time is the max of memory, compute and special-function time at
//! efficiencies determined by the genome's behavioral features and
//! parameter match to the device, plus launch/sync overheads and
//! measurement noise.
//!
//! Absolute times are not claimed to match the paper's hardware — the
//! *shape* of the results (who wins, by what factor, where device-specific
//! optima diverge) is what this model reproduces. Device-specific
//! parameter sweet spots (tile size, work-group size, vector width) differ
//! between profiles, which is what makes the §5.3 hardware-awareness
//! crossover experiment non-trivial.

pub mod device;
pub mod model;

pub use device::DeviceProfile;
pub use model::{baseline_cost, kernel_cost, vendor_cost, Bottleneck, KernelCost, NoisyClock};
