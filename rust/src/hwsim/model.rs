//! The roofline + feature cost model.

use super::device::DeviceProfile;
use crate::ir::{AlgoStructure, KernelGenome, MemoryPattern, SyncStrategy};
use crate::tasks::TaskSpec;
use crate::util::rng::Rng;

/// What limits the kernel (App. B.3 "bottleneck identification").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bottleneck {
    Memory,
    Compute,
    SpecialFunction,
    LaunchOverhead,
}

impl Bottleneck {
    pub fn name(&self) -> &'static str {
        match self {
            Bottleneck::Memory => "memory-bound",
            Bottleneck::Compute => "compute-bound",
            Bottleneck::SpecialFunction => "SFU-bound",
            Bottleneck::LaunchOverhead => "launch-overhead-bound",
        }
    }
}

/// Cost breakdown for one kernel execution (true time, before
/// measurement noise).
#[derive(Debug, Clone, Copy)]
pub struct KernelCost {
    pub time_ms: f64,
    pub mem_ms: f64,
    pub comp_ms: f64,
    pub sfu_ms: f64,
    pub launch_ms: f64,
    /// Achieved fraction of peak bandwidth / compute.
    pub mem_eff: f64,
    pub comp_eff: f64,
    pub bound: Bottleneck,
    pub bytes_moved: u64,
    pub flops: u64,
}

/// Efficiency bases per memory-pattern level: fraction of peak bandwidth
/// achievable with this access discipline.
const MEM_EFF_BASE: [f64; 4] = [0.30, 0.70, 0.80, 0.91];

/// Data-reuse bases per memory-pattern level: fraction of peak compute
/// achievable (compute-bound ops need tiling/register blocking for reuse).
const COMP_EFF_BASE: [f64; 4] = [0.14, 0.30, 0.55, 0.74];

/// Memory-traffic reduction from algorithmic reformulation (online
/// normalization reads the data once instead of twice).
const REFORM_BYTES_FACTOR: f64 = 0.65;

/// SFU-load reduction from reformulation (exp2 trick, fewer divisions).
const REFORM_SFU_FACTOR: f64 = 0.55;

/// Extra FLOP reduction from a genuinely novel decomposition.
const NOVEL_FLOPS_FACTOR: f64 = 0.85;

/// Cost a generated kernel on a device.
///
/// The model composes:
/// * bytes moved — depends on fusion coverage and reformulation;
/// * achieved bandwidth — base by `d_mem` level × work-group match ×
///   vector-width match × bank-conflict penalty × prefetch bonus;
/// * achieved compute — base by `d_mem` level (data reuse) × tile match ×
///   register blocking (with an occupancy cliff);
/// * SFU time — reformulation reduces special-function pressure;
/// * synchronization adjustments — sub-group primitives accelerate
///   reduction-like tasks, unnecessary atomics cost;
/// * per-launch overhead × number of kernels (unfused remainder ops run
///   as separate kernels).
pub fn kernel_cost(task: &TaskSpec, genome: &KernelGenome, device: &DeviceProfile) -> KernelCost {
    let p = &genome.params;
    let mem_level = genome.mem.level();

    // ---- fusion coverage & passes -----------------------------------------
    let n_ops = task.n_ops() as u64;
    // `covered`: how many leading ops run inside the (single) generated
    // kernel; the rest run as separate kernels in the genome's style.
    let covered = match genome.algo {
        AlgoStructure::DirectTranslation => 1,
        _ => (genome.fused_ops as usize + 1).min(task.n_ops()),
    };
    let n_launches = n_ops - covered as u64 + 1;
    // Fused-region traffic: inputs of the first covered op + the last
    // covered op's output + downstream parameter streams.
    let fused_region_bytes = {
        let ops = &task.ops[..covered];
        let first_read = ops.first().map(|o| o.bytes_read()).unwrap_or(0);
        let last_write = ops.last().map(|o| o.bytes_written()).unwrap_or(0);
        let params: u64 = ops.iter().skip(1).map(|o| o.param_bytes()).sum();
        (first_read + last_write + params) as f64
    };
    let mut bytes = fused_region_bytes;
    let mut sfu_ops: f64 = task.ops[..covered].iter().map(|o| o.sfu_ops() as f64).sum();
    let mut flops: f64 = task.ops[..covered].iter().map(|o| o.flops() as f64).sum();
    match genome.algo {
        AlgoStructure::Reformulated if task.supports_reformulation() => {
            bytes *= REFORM_BYTES_FACTOR;
            sfu_ops *= REFORM_SFU_FACTOR;
        }
        AlgoStructure::Novel if task.supports_reformulation() => {
            // Asymptotic wins only exist where the math admits them
            // (streaming normalizations etc.) — there is no novel GEMM.
            flops *= NOVEL_FLOPS_FACTOR;
            bytes *= REFORM_BYTES_FACTOR;
            sfu_ops *= REFORM_SFU_FACTOR;
        }
        _ => {}
    }

    // ---- achieved bandwidth -------------------------------------------------
    let mut mem_eff = MEM_EFF_BASE[mem_level];
    let wg_match = device.param_match(p.work_group_size() as u32, device.optimal_wg);
    mem_eff *= 0.75 + 0.25 * wg_match;
    if mem_level >= 1 {
        // Vector width match matters once accesses are vectorized.
        let vec_match = device.param_match(p.vec_width.max(1), device.preferred_vec);
        mem_eff *= 0.88 + 0.12 * vec_match;
    }
    if genome.uses_slm() && !p.slm_pad {
        mem_eff *= device.bank_conflict_penalty;
    }
    if genome.mem == MemoryPattern::MultiLevel && p.prefetch {
        mem_eff = (mem_eff * 1.05).min(0.95);
    }

    // ---- achieved compute ---------------------------------------------------
    // Generated kernels top out below hand-written assembly (PEAK reaches
    // "up to 95% of cuBLAS"; typical LLM GEMMs are further off).
    const GEN_COMP_CAP: f64 = 0.80;
    let mut comp_eff = COMP_EFF_BASE[mem_level];
    if genome.uses_slm() {
        let tile_match = device.param_match(p.tile_m.max(p.tile_n), device.optimal_tile);
        comp_eff *= 0.55 + 0.45 * tile_match;
    }
    comp_eff *= 0.80 + 0.20 * wg_match;
    if p.reg_block > 1 {
        // Register blocking boosts reuse but large factors hit occupancy.
        let boost = 1.0 + 0.09 * (p.reg_block as f64).log2();
        let occupancy = if p.reg_block > 4 { 0.82 } else { 1.0 };
        comp_eff = (comp_eff * boost * occupancy).min(GEN_COMP_CAP);
    }
    if p.unroll > 1 {
        comp_eff = (comp_eff * (1.0 + 0.02 * (p.unroll as f64).log2())).min(GEN_COMP_CAP);
    }
    // Fusion disruption: naively folding a structured op (pool, norm,
    // softmax, reduction, concat) into a compute-bound GEMM/conv core
    // breaks the core's tiling schedule. A genuine algorithmic
    // reformulation (flash-style streaming) is exactly the technique
    // that avoids this — so only plain `Fused` pays.
    let acts_as_plain_fusion = genome.algo == AlgoStructure::Fused
        || (!task.supports_reformulation()
            && matches!(genome.algo, AlgoStructure::Reformulated | AlgoStructure::Novel));
    if acts_as_plain_fusion {
        let covered = (genome.fused_ops as usize + 1).min(task.n_ops());
        let ops = &task.ops[..covered];
        let has_core = ops.iter().any(|o| {
            matches!(
                o,
                crate::tasks::OpSpec::Matmul { .. }
                    | crate::tasks::OpSpec::Conv2d { .. }
                    | crate::tasks::OpSpec::Conv3d { .. }
                    | crate::tasks::OpSpec::ConvTranspose2d { .. }
                    | crate::tasks::OpSpec::ConvTranspose3d { .. }
            )
        });
        let structured = ops
            .iter()
            .filter(|o| !matches!(o, crate::tasks::OpSpec::Elementwise { .. } | crate::tasks::OpSpec::Rope { .. }))
            .count();
        if has_core && structured >= 2 {
            comp_eff *= 0.78;
        }
    }

    // ---- synchronization ------------------------------------------------------
    // Reduction-like tasks (reductions, softmax, norms) leave parallelism
    // on the table without cross-lane coordination.
    let reduction_like = task.ops.iter().any(|o| {
        matches!(
            o,
            crate::tasks::OpSpec::Reduction { .. }
                | crate::tasks::OpSpec::Softmax { .. }
                | crate::tasks::OpSpec::Norm { .. }
                | crate::tasks::OpSpec::Cumsum { .. }
        )
    });
    let mut sync_factor = 1.0; // multiplies total kernel time
    match genome.sync {
        SyncStrategy::None => {
            if reduction_like {
                sync_factor *= 1.35; // serialized final reduction
            }
        }
        SyncStrategy::WorkGroupBarrier => {
            sync_factor *= if reduction_like { 1.08 } else { 1.03 };
        }
        SyncStrategy::SubGroup => {
            sync_factor *= if reduction_like { 1.0 } else { 1.02 };
        }
        SyncStrategy::Global => {
            // Atomics pay off only for very wide reductions; otherwise cost.
            sync_factor *= if reduction_like { 1.04 } else { 1.12 };
        }
    }

    // ---- roofline ---------------------------------------------------------------
    // Fused region: one kernel, roofline max of its aggregate demands.
    let mem_ms = bytes / (device.peak_bw_gbs * mem_eff * 1e6);
    let comp_ms = flops / (device.peak_gflops * comp_eff * 1e6);
    let sfu_ms = sfu_ops / (device.sfu_gops * 1e6);
    let mut body = mem_ms.max(comp_ms).max(sfu_ms) * sync_factor;
    // Remainder ops: separate kernels, each paying its own roofline
    // (memory traffic does NOT overlap with another kernel's compute).
    for op in &task.ops[covered..] {
        let m = (op.bytes_read() + op.bytes_written()) as f64 / (device.peak_bw_gbs * mem_eff * 1e6);
        let c = op.flops() as f64 / (device.peak_gflops * comp_eff * 1e6);
        let s = op.sfu_ops() as f64 / (device.sfu_gops * 1e6);
        body += m.max(c).max(s);
    }
    let launch_ms = n_launches as f64 * device.launch_us * 1e-3;
    let time_ms = body + launch_ms;

    let bound = if launch_ms > body {
        Bottleneck::LaunchOverhead
    } else if mem_ms >= comp_ms && mem_ms >= sfu_ms {
        Bottleneck::Memory
    } else if comp_ms >= sfu_ms {
        Bottleneck::Compute
    } else {
        Bottleneck::SpecialFunction
    };

    let total_bytes = bytes
        + task.ops[covered..]
            .iter()
            .map(|o| (o.bytes_read() + o.bytes_written()) as f64)
            .sum::<f64>();
    let total_flops = flops + task.ops[covered..].iter().map(|o| o.flops() as f64).sum::<f64>();
    KernelCost {
        time_ms,
        mem_ms,
        comp_ms,
        sfu_ms,
        launch_ms,
        mem_eff,
        comp_eff,
        bound,
        bytes_moved: total_bytes as u64,
        flops: total_flops as u64,
    }
}

/// PyTorch-eager-like baseline: per-op dispatch overhead + each op runs
/// as a library kernel (decent but not perfect efficiency, no cross-op
/// fusion). Backward tasks additionally pay the torch.autograd
/// bookkeeping multiplier on dispatch (App. B.2).
pub fn baseline_cost(task: &TaskSpec, device: &DeviceProfile) -> f64 {
    let dispatch_us = if task.backward {
        device.eager_dispatch_us * device.autograd_overhead
    } else {
        device.eager_dispatch_us
    };
    let mut total_ms = 0.0;
    for op in &task.ops {
        let bytes = (op.bytes_read() + op.bytes_written()) as f64;
        // Library kernels: well-coalesced (≈0.72 bw) and well-tiled for
        // GEMM/conv (≈0.70 compute).
        let mem_ms = bytes / (device.peak_bw_gbs * 0.72 * 1e6);
        let comp_ms = op.flops() as f64 / (device.peak_gflops * 0.70 * 1e6);
        let sfu_ms = op.sfu_ops() as f64 / (device.sfu_gops * 1e6);
        total_ms += mem_ms.max(comp_ms).max(sfu_ms) + dispatch_us * 1e-3;
    }
    total_ms
}

/// Vendor-library (oneDNN-like) baseline for §5.4: hand-tuned primitives
/// at near-roofline efficiency with minimal dispatch overhead, fusing
/// only what the library supports as "post-ops" (elementwise epilogues),
/// and never reformulating the algorithm.
pub fn vendor_cost(task: &TaskSpec, device: &DeviceProfile) -> f64 {
    use crate::tasks::OpSpec;
    const VENDOR_DISPATCH_US: f64 = 3.0;
    let mut total_ms = 0.0;
    let mut i = 0;
    while i < task.ops.len() {
        let op = &task.ops[i];
        let mut bytes = (op.bytes_read() + op.bytes_written()) as f64;
        let mut flops = op.flops() as f64;
        let mut sfu = op.sfu_ops() as f64;
        // Post-op fusion: elementwise ops directly after a matmul/conv are
        // folded into the primitive epilogue.
        if matches!(op, OpSpec::Matmul { .. } | OpSpec::Conv2d { .. } | OpSpec::Conv3d { .. }) {
            while i + 1 < task.ops.len() {
                if let OpSpec::Elementwise { elems, flops_per_elem, sfu_per_elem, .. } =
                    task.ops[i + 1]
                {
                    flops += (elems * flops_per_elem) as f64;
                    sfu += (elems * sfu_per_elem) as f64;
                    i += 1;
                } else {
                    break;
                }
            }
        }
        // Reductions at slightly lower efficiency (shape-generic trees);
        // everything else near roofline — oneDNN kernels are often
        // hand-written in assembly.
        let (mem_e, comp_e) = match op {
            OpSpec::Reduction { .. } => (0.84, 0.80),
            // Hand-written assembly GEMM/conv primitives run closest to
            // the roofline of anything in the library.
            OpSpec::Matmul { .. } | OpSpec::Conv2d { .. } | OpSpec::Conv3d { .. } => (0.92, 0.95),
            _ => (0.92, 0.92),
        };
        bytes = bytes.max(1.0);
        let mem_ms = bytes / (device.peak_bw_gbs * mem_e * 1e6);
        let comp_ms = flops / (device.peak_gflops * comp_e * 1e6);
        let sfu_ms = sfu / (device.sfu_gops * 1e6);
        total_ms += mem_ms.max(comp_ms).max(sfu_ms) + VENDOR_DISPATCH_US * 1e-3;
        i += 1;
    }
    total_ms
}

/// Measurement noise source: wraps true kernel time into noisy observed
/// samples, including the synchronize overhead that App. B.2's inner-loop
/// batching amortizes.
#[derive(Debug)]
pub struct NoisyClock {
    rng: Rng,
    /// torch.xpu/cuda.synchronize overhead per sync point, ms.
    pub sync_overhead_ms: f64,
    pub noise_sigma: f64,
}

impl NoisyClock {
    pub fn new(seed: u64, device: &DeviceProfile) -> NoisyClock {
        NoisyClock {
            rng: Rng::with_stream(seed, 0x10c),
            sync_overhead_ms: 0.012,
            noise_sigma: device.noise_sigma,
        }
    }

    /// Observe `inner_iters` kernel executions followed by one
    /// synchronize; returns total wall-clock ms for the batch.
    pub fn observe_batch(&mut self, true_ms: f64, inner_iters: usize) -> f64 {
        let mut total = 0.0;
        for _ in 0..inner_iters {
            total += true_ms * self.rng.lognormal_factor(self.noise_sigma);
        }
        total + self.sync_overhead_ms * self.rng.lognormal_factor(0.3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::KernelGenome;
    use crate::tasks::catalog;

    fn genome_at(task: &TaskSpec, mem: usize, algo: usize, sync: usize) -> KernelGenome {
        let mut g = KernelGenome::direct_translation(&task.id);
        g.mem = MemoryPattern::from_level(mem);
        g.algo = AlgoStructure::from_level(algo);
        g.sync = SyncStrategy::from_level(sync);
        g.fused_ops = task.n_ops() as u32;
        g
    }

    fn find(id: &str) -> TaskSpec {
        catalog::find_task(id).unwrap()
    }

    #[test]
    fn better_memory_pattern_is_faster() {
        let task = find("20_LeakyReLU");
        let dev = DeviceProfile::b580();
        let mut prev = f64::INFINITY;
        for level in 0..4 {
            let mut g = genome_at(&task, level, 0, 0);
            g.params.slm_pad = true;
            g.params.vec_width = dev.preferred_vec;
            let c = kernel_cost(&task, &g, &dev);
            assert!(c.time_ms < prev, "level {level}: {} !< {}", c.time_ms, prev);
            prev = c.time_ms;
        }
    }

    #[test]
    fn fusion_beats_direct_on_l2() {
        let task = find("1_Conv2D_ReLU_BiasAdd");
        let dev = DeviceProfile::b580();
        let direct = kernel_cost(&task, &genome_at(&task, 1, 0, 0), &dev);
        let fused = kernel_cost(&task, &genome_at(&task, 1, 1, 0), &dev);
        assert!(fused.time_ms < direct.time_ms);
    }

    #[test]
    fn l2_speedup_vs_eager_in_paper_range() {
        // A good fused kernel on an L2 task should land in the 1.5–4×
        // speedup band the paper reports.
        let task = find("82_Conv2d_Tanh_Scaling_BiasAdd_Max");
        let dev = DeviceProfile::b580();
        let mut g = genome_at(&task, 2, 1, 1);
        g.params.tile_m = dev.optimal_tile;
        g.params.tile_n = dev.optimal_tile;
        g.params.wg_x = dev.optimal_wg;
        g.params.wg_y = 1;
        g.params.vec_width = dev.preferred_vec;
        g.params.slm_pad = true;
        let spd = baseline_cost(&task, &dev) / kernel_cost(&task, &g, &dev).time_ms;
        assert!((1.3..5.0).contains(&spd), "speedup {spd}");
    }

    #[test]
    fn l1_speedup_is_modest() {
        // Single memory-bound op: eager is already one kernel; wins are
        // bounded (paper L1 avg ≈ 1.2).
        let task = find("20_LeakyReLU");
        let dev = DeviceProfile::a6000();
        // A merely-coalesced kernel roughly ties the library baseline.
        let mut g = genome_at(&task, 1, 0, 0);
        g.params.vec_width = dev.preferred_vec;
        g.params.wg_x = dev.optimal_wg;
        let spd = baseline_cost(&task, &dev) / kernel_cost(&task, &g, &dev).time_ms;
        assert!((0.85..1.25).contains(&spd), "coalesced speedup {spd}");
        // A fully-tuned multi-level kernel wins modestly.
        let mut g3 = genome_at(&task, 3, 0, 0);
        g3.params.vec_width = dev.preferred_vec;
        g3.params.wg_x = dev.optimal_wg;
        g3.params.tile_m = dev.optimal_tile;
        g3.params.tile_n = dev.optimal_tile;
        g3.params.prefetch = true;
        g3.params.slm_pad = true;
        let spd3 = baseline_cost(&task, &dev) / kernel_cost(&task, &g3, &dev).time_ms;
        assert!((1.0..1.6).contains(&spd3), "tuned speedup {spd3}");
    }

    #[test]
    fn backward_tasks_have_inflated_baselines() {
        let fwd = find("mnist_linear_forward");
        let bwd = find("mnist_linear_backward");
        let dev = DeviceProfile::a6000();
        let fwd_per_op = baseline_cost(&fwd, &dev) / fwd.n_ops() as f64;
        let bwd_per_op = baseline_cost(&bwd, &dev) / bwd.n_ops() as f64;
        assert!(bwd_per_op > 3.0 * fwd_per_op);
    }

    #[test]
    fn reformulation_reduces_sfu_and_bytes() {
        let task = find("softmax");
        let dev = DeviceProfile::b580();
        let fused = kernel_cost(&task, &genome_at(&task, 1, 1, 2), &dev);
        let reform = kernel_cost(&task, &genome_at(&task, 1, 2, 2), &dev);
        assert!(reform.bytes_moved < fused.bytes_moved);
        assert!(reform.sfu_ms < fused.sfu_ms);
        assert!(reform.time_ms < fused.time_ms);
    }

    #[test]
    fn vendor_wins_gemm_loses_unfusable() {
        let dev = DeviceProfile::b580();
        // GEMM+ReLU: vendor fuses the post-op and runs near roofline —
        // generated kernels cannot beat it (Table 4: 0.35).
        let gemm = find("matmul_relu_postop");
        let mut g = genome_at(&gemm, 3, 1, 1);
        g.params.tile_m = dev.optimal_tile;
        g.params.tile_n = dev.optimal_tile;
        g.params.wg_x = dev.optimal_wg;
        g.params.reg_block = 4;
        g.params.slm_pad = true;
        let spd = vendor_cost(&gemm, &dev) / kernel_cost(&gemm, &g, &dev).time_ms;
        assert!(spd < 1.0, "generated should lose to vendor GEMM, got {spd}");

        // concat(x, layernorm(x)): vendor runs two primitives, a fused +
        // reformulated (online-stats) generated kernel wins (Table 4: 1.79).
        let cl = find("concat_layernorm");
        let mut g2 = genome_at(&cl, 1, 2, 2);
        g2.params.vec_width = dev.preferred_vec;
        g2.params.wg_x = dev.optimal_wg;
        let spd2 = vendor_cost(&cl, &dev) / kernel_cost(&cl, &g2, &dev).time_ms;
        assert!((1.2..2.6).contains(&spd2), "fused concat+LN should win, got {spd2}");
    }

    #[test]
    fn device_optima_differ_enabling_crossover() {
        // A kernel tuned for LNL's sweet spot loses on B580 to a kernel
        // tuned for B580, and vice versa (§5.3).
        let task = find("99_Matmul_GELU_Softmax");
        let lnl = DeviceProfile::lnl();
        let b580 = DeviceProfile::b580();
        let tuned = |dev: &DeviceProfile| {
            let mut g = genome_at(&task, 2, 2, 2);
            g.params.tile_m = dev.optimal_tile;
            g.params.tile_n = dev.optimal_tile;
            g.params.wg_x = dev.optimal_wg;
            g.params.wg_y = 1;
            g.params.vec_width = dev.preferred_vec;
            g.params.slm_pad = true;
            g
        };
        let k_lnl = tuned(&lnl);
        let k_b580 = tuned(&b580);
        // On LNL the LNL-tuned kernel wins:
        assert!(
            kernel_cost(&task, &k_lnl, &lnl).time_ms < kernel_cost(&task, &k_b580, &lnl).time_ms
        );
        // On B580 the B580-tuned kernel wins:
        assert!(
            kernel_cost(&task, &k_b580, &b580).time_ms
                < kernel_cost(&task, &k_lnl, &b580).time_ms
        );
    }

    #[test]
    fn sync_strategy_matters_for_reductions() {
        let task = find("48_Mean_reduction_over_a_dimension");
        let dev = DeviceProfile::b580();
        let none = kernel_cost(&task, &genome_at(&task, 1, 0, 0), &dev);
        let sub = kernel_cost(&task, &genome_at(&task, 1, 0, 2), &dev);
        assert!(sub.time_ms < none.time_ms);
    }

    #[test]
    fn noisy_clock_amortizes_sync() {
        let dev = DeviceProfile::b580();
        let mut clock = NoisyClock::new(1, &dev);
        let true_ms = 0.010; // fast kernel, comparable to sync overhead
        // Per-iteration sync: overhead dominates.
        let naive: f64 = (0..64).map(|_| clock.observe_batch(true_ms, 1)).sum::<f64>() / 64.0;
        // Inner loop of 32: overhead amortized.
        let batched = clock.observe_batch(true_ms, 32) / 32.0;
        assert!(naive > 1.5 * true_ms);
        assert!((batched - true_ms).abs() / true_ms < 0.25, "batched {batched}");
    }

    #[test]
    fn bottleneck_classification() {
        let dev = DeviceProfile::b580();
        let ew = find("20_LeakyReLU");
        let c = kernel_cost(&ew, &genome_at(&ew, 1, 0, 0), &dev);
        assert_eq!(c.bound, Bottleneck::Memory);
        let mm = find("matmul_relu_postop");
        let c2 = kernel_cost(&mm, &genome_at(&mm, 2, 1, 1), &dev);
        assert_eq!(c2.bound, Bottleneck::Compute);
    }
}
