//! The kernel genome: structured candidate-kernel description.

use crate::util::json::Json;

/// Memory-access pattern — the first behavioral dimension (§3.2).
///
/// Levels mirror the paper's `d_mem` bins exactly.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum MemoryPattern {
    /// 0: scalar, strided, or uncoalesced access.
    Scalar,
    /// 1: coalesced / vectorized (vec4, aligned loads).
    Coalesced,
    /// 2: shared/local memory with explicit tiling.
    TiledSlm,
    /// 3: multi-level hierarchy (SLM + register blocking + prefetch).
    MultiLevel,
}

impl MemoryPattern {
    pub fn level(self) -> usize {
        match self {
            MemoryPattern::Scalar => 0,
            MemoryPattern::Coalesced => 1,
            MemoryPattern::TiledSlm => 2,
            MemoryPattern::MultiLevel => 3,
        }
    }

    pub fn from_level(level: usize) -> MemoryPattern {
        match level {
            0 => MemoryPattern::Scalar,
            1 => MemoryPattern::Coalesced,
            2 => MemoryPattern::TiledSlm,
            _ => MemoryPattern::MultiLevel,
        }
    }
}

/// Algorithmic structure — the second behavioral dimension (§3.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum AlgoStructure {
    /// 0: direct PyTorch translation (one kernel per op).
    DirectTranslation,
    /// 1: fused operations (single pass over data).
    Fused,
    /// 2: reformulated algorithm (online normalization, flash pattern).
    Reformulated,
    /// 3: novel / asymptotically improved algorithm.
    Novel,
}

impl AlgoStructure {
    pub fn level(self) -> usize {
        match self {
            AlgoStructure::DirectTranslation => 0,
            AlgoStructure::Fused => 1,
            AlgoStructure::Reformulated => 2,
            AlgoStructure::Novel => 3,
        }
    }

    pub fn from_level(level: usize) -> AlgoStructure {
        match level {
            0 => AlgoStructure::DirectTranslation,
            1 => AlgoStructure::Fused,
            2 => AlgoStructure::Reformulated,
            _ => AlgoStructure::Novel,
        }
    }
}

/// Parallelism coordination — the third behavioral dimension (§3.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SyncStrategy {
    /// 0: no synchronization (embarrassingly parallel).
    None,
    /// 1: work-group barriers.
    WorkGroupBarrier,
    /// 2: sub-group primitives (shuffles, reductions, broadcasts).
    SubGroup,
    /// 3: global coordination (atomics, multi-pass with sync).
    Global,
}

impl SyncStrategy {
    pub fn level(self) -> usize {
        match self {
            SyncStrategy::None => 0,
            SyncStrategy::WorkGroupBarrier => 1,
            SyncStrategy::SubGroup => 2,
            SyncStrategy::Global => 3,
        }
    }

    pub fn from_level(level: usize) -> SyncStrategy {
        match level {
            0 => SyncStrategy::None,
            1 => SyncStrategy::WorkGroupBarrier,
            2 => SyncStrategy::SubGroup,
            _ => SyncStrategy::Global,
        }
    }
}

/// Hardware-dependent tunable parameters (§3.4).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ParamSet {
    /// Work-group shape (x is the contiguous dimension).
    pub wg_x: u32,
    pub wg_y: u32,
    /// Tile sizes for SLM tiling / register blocking.
    pub tile_m: u32,
    pub tile_n: u32,
    pub tile_k: u32,
    /// Vector load width in elements (1, 2, 4, 8).
    pub vec_width: u32,
    /// Inner-loop unroll factor.
    pub unroll: u32,
    /// Per-thread register-blocking factor (1 = none).
    pub reg_block: u32,
    /// Software prefetching of the next tile.
    pub prefetch: bool,
    /// +1 padding on SLM arrays to avoid bank conflicts.
    pub slm_pad: bool,
}

impl Default for ParamSet {
    fn default() -> ParamSet {
        ParamSet {
            wg_x: 16,
            wg_y: 1,
            tile_m: 16,
            tile_n: 16,
            tile_k: 16,
            vec_width: 1,
            unroll: 1,
            reg_block: 1,
            prefetch: false,
            slm_pad: false,
        }
    }
}

impl ParamSet {
    /// SLM bytes implied by the tiling parameters (two f32 input tiles,
    /// padded if requested) — checked against the device budget.
    pub fn slm_bytes(&self) -> u64 {
        let pad = if self.slm_pad { 1 } else { 0 };
        let tile_a = (self.tile_m as u64) * (self.tile_k as u64 + pad);
        let tile_b = (self.tile_k as u64) * (self.tile_n as u64 + pad);
        (tile_a + tile_b) * 4
    }

    pub fn work_group_size(&self) -> u64 {
        self.wg_x as u64 * self.wg_y as u64
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("wg_x", self.wg_x).set("wg_y", self.wg_y);
        o.set("tile_m", self.tile_m)
            .set("tile_n", self.tile_n)
            .set("tile_k", self.tile_k);
        o.set("vec_width", self.vec_width)
            .set("unroll", self.unroll)
            .set("reg_block", self.reg_block);
        o.set("prefetch", self.prefetch).set("slm_pad", self.slm_pad);
        o
    }

    pub fn from_json(v: &Json) -> Option<ParamSet> {
        Some(ParamSet {
            wg_x: v.get("wg_x")?.as_usize()? as u32,
            wg_y: v.get("wg_y")?.as_usize()? as u32,
            tile_m: v.get("tile_m")?.as_usize()? as u32,
            tile_n: v.get("tile_n")?.as_usize()? as u32,
            tile_k: v.get("tile_k")?.as_usize()? as u32,
            vec_width: v.get("vec_width")?.as_usize()? as u32,
            unroll: v.get("unroll")?.as_usize()? as u32,
            reg_block: v.get("reg_block")?.as_usize()? as u32,
            prefetch: v.get("prefetch")?.as_bool()?,
            slm_pad: v.get("slm_pad")?.as_bool()?,
        })
    }
}

/// A templated kernel's tunable-parameter specification (§3.4): the list
/// of dispatch options the generated `forward` enumerates.
#[derive(Debug, Clone, PartialEq)]
pub struct TemplateSpec {
    /// Candidate (wg_x, wg_y) pairs.
    pub wg_options: Vec<(u32, u32)>,
    /// Candidate (tile_m, tile_n, tile_k) triples.
    pub tile_options: Vec<(u32, u32, u32)>,
    /// Candidate vector widths.
    pub vec_options: Vec<u32>,
}

impl TemplateSpec {
    /// All parameter instantiations the dispatcher enumerates.
    pub fn instantiations(&self, base: &ParamSet) -> Vec<ParamSet> {
        let mut out = Vec::new();
        let wgs = if self.wg_options.is_empty() {
            vec![(base.wg_x, base.wg_y)]
        } else {
            self.wg_options.clone()
        };
        let tiles = if self.tile_options.is_empty() {
            vec![(base.tile_m, base.tile_n, base.tile_k)]
        } else {
            self.tile_options.clone()
        };
        let vecs = if self.vec_options.is_empty() {
            vec![base.vec_width]
        } else {
            self.vec_options.clone()
        };
        for &(wx, wy) in &wgs {
            for &(tm, tn, tk) in &tiles {
                for &vw in &vecs {
                    let mut p = base.clone();
                    p.wg_x = wx;
                    p.wg_y = wy;
                    p.tile_m = tm;
                    p.tile_n = tn;
                    p.tile_k = tk;
                    p.vec_width = vw;
                    out.push(p);
                }
            }
        }
        out
    }

    pub fn n_instantiations(&self) -> usize {
        self.wg_options.len().max(1) * self.tile_options.len().max(1) * self.vec_options.len().max(1)
    }
}

/// Kinds of injected defects — the simulated code model's error channel.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DefectKind {
    /// Source does not compile (syntax error, bad template instantiation).
    SyntaxError,
    /// Wrong numerics of a given relative magnitude (bad index math,
    /// missing edge-case handling).
    NumericBug,
    /// SLM accessed across work-items without a barrier: data race.
    MissingBarrier,
    /// Out-of-bounds access guard missing — fails validation.
    OutOfBounds,
}

/// A defect with severity in (0, 1]; for `NumericBug` the severity scales
/// the relative output error used by the ν-criterion check.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Defect {
    pub kind: DefectKind,
    pub severity: f64,
}

/// A candidate kernel: the unit the evolutionary loop manipulates.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelGenome {
    /// Task this kernel implements.
    pub task_id: String,
    pub mem: MemoryPattern,
    pub algo: AlgoStructure,
    pub sync: SyncStrategy,
    pub params: ParamSet,
    /// Number of fused producer ops folded into this kernel (0 = each op
    /// is its own kernel, as in a direct translation).
    pub fused_ops: u32,
    /// Present when the model emitted a templated kernel (§3.4).
    pub template: Option<TemplateSpec>,
    /// Latent defects injected by the code model's error channel.
    pub defects: Vec<Defect>,
    /// Monotonic id assigned at creation (0 = unassigned).
    pub id: u64,
    /// Id of the parent elite this genome was mutated from (None for a
    /// fresh generation).
    pub parent_id: Option<u64>,
    /// Which model of the ensemble produced it (for reporting).
    pub produced_by: String,
}

impl KernelGenome {
    /// A level-0 "direct PyTorch translation" starting point for a task.
    pub fn direct_translation(task_id: &str) -> KernelGenome {
        KernelGenome {
            task_id: task_id.to_string(),
            mem: MemoryPattern::Scalar,
            algo: AlgoStructure::DirectTranslation,
            sync: SyncStrategy::None,
            params: ParamSet::default(),
            fused_ops: 0,
            template: None,
            defects: Vec::new(),
            id: 0,
            parent_id: None,
            produced_by: String::new(),
        }
    }

    /// The genome's intended behavioral coordinates. The archive uses the
    /// *classifier's* coordinates (derived from rendered source); in a
    /// defect-free render the two agree — covered by tests.
    pub fn intended_coords(&self) -> [usize; 3] {
        [self.mem.level(), self.algo.level(), self.sync.level()]
    }

    /// Whether the genome uses SLM (and therefore requires work-group
    /// coordination to be race-free).
    pub fn uses_slm(&self) -> bool {
        matches!(self.mem, MemoryPattern::TiledSlm | MemoryPattern::MultiLevel)
    }

    pub fn has_defect(&self, kind: DefectKind) -> bool {
        self.defects.iter().any(|d| d.kind == kind)
    }

    /// Structural distance between two genomes (for diversity metrics):
    /// L1 over behavior levels plus a parameter-difference term.
    pub fn distance(&self, other: &KernelGenome) -> f64 {
        let a = self.intended_coords();
        let b = other.intended_coords();
        let behav: usize = a.iter().zip(b.iter()).map(|(x, y)| x.abs_diff(*y)).sum();
        let p = &self.params;
        let q = &other.params;
        let param = (p.wg_x != q.wg_x) as u32
            + (p.wg_y != q.wg_y) as u32
            + (p.tile_m != q.tile_m) as u32
            + (p.tile_n != q.tile_n) as u32
            + (p.tile_k != q.tile_k) as u32
            + (p.vec_width != q.vec_width) as u32
            + (p.unroll != q.unroll) as u32
            + (p.reg_block != q.reg_block) as u32;
        behav as f64 + 0.25 * param as f64
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("task_id", self.task_id.as_str())
            .set("mem", self.mem.level())
            .set("algo", self.algo.level())
            .set("sync", self.sync.level())
            .set("fused_ops", self.fused_ops)
            .set("id", self.id as f64)
            .set("produced_by", self.produced_by.as_str())
            .set("params", self.params.to_json())
            .set("templated", self.template.is_some())
            .set("defects", self.defects.len());
        if let Some(p) = self.parent_id {
            o.set("parent_id", p as f64);
        }
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_roundtrips() {
        for l in 0..4 {
            assert_eq!(MemoryPattern::from_level(l).level(), l);
            assert_eq!(AlgoStructure::from_level(l).level(), l);
            assert_eq!(SyncStrategy::from_level(l).level(), l);
        }
    }

    #[test]
    fn direct_translation_is_origin_cell() {
        let g = KernelGenome::direct_translation("t");
        assert_eq!(g.intended_coords(), [0, 0, 0]);
        assert!(!g.uses_slm());
    }

    #[test]
    fn slm_bytes_accounts_padding() {
        let mut p = ParamSet {
            tile_m: 16,
            tile_n: 16,
            tile_k: 16,
            ..ParamSet::default()
        };
        let unpadded = p.slm_bytes();
        p.slm_pad = true;
        assert!(p.slm_bytes() > unpadded);
        assert_eq!(unpadded, (16 * 16 + 16 * 16) * 4);
    }

    #[test]
    fn template_instantiations_cartesian() {
        let spec = TemplateSpec {
            wg_options: vec![(16, 1), (32, 1)],
            tile_options: vec![(16, 16, 16), (32, 32, 16), (8, 8, 8)],
            vec_options: vec![1, 4],
        };
        let base = ParamSet::default();
        assert_eq!(spec.instantiations(&base).len(), 12);
        assert_eq!(spec.n_instantiations(), 12);
    }

    #[test]
    fn distance_zero_for_identical() {
        let g = KernelGenome::direct_translation("t");
        assert_eq!(g.distance(&g), 0.0);
        let mut h = g.clone();
        h.mem = MemoryPattern::TiledSlm;
        h.params.vec_width = 4;
        assert!(g.distance(&h) > 2.0);
    }

    #[test]
    fn params_json_roundtrip() {
        let p = ParamSet {
            wg_x: 32,
            wg_y: 8,
            tile_m: 64,
            tile_n: 32,
            tile_k: 16,
            vec_width: 4,
            unroll: 2,
            reg_block: 4,
            prefetch: true,
            slm_pad: true,
        };
        let q = ParamSet::from_json(&p.to_json()).unwrap();
        assert_eq!(p, q);
    }
}
