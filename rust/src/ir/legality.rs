//! Genome legality rules — the "does this even launch" checks a real
//! driver/compiler would enforce, evaluated against a device profile at
//! compile time (device limits) and used by the mutation engine to avoid
//! proposing obviously-invalid kernels.

use super::genome::KernelGenome;

/// Device limits relevant to legality (a slice of `hwsim::DeviceProfile`,
/// duplicated here to keep `ir` free of a dependency on `hwsim`).
#[derive(Debug, Clone, Copy)]
pub struct DeviceLimits {
    pub max_work_group_size: u64,
    pub slm_bytes: u64,
    pub sub_group_sizes: &'static [u32],
}

impl Default for DeviceLimits {
    fn default() -> DeviceLimits {
        DeviceLimits {
            max_work_group_size: 1024,
            slm_bytes: 64 * 1024,
            sub_group_sizes: &[8, 16, 32],
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum LegalityError {
    WorkGroupTooLarge { got: u64, max: u64 },
    SlmOverflow { got: u64, max: u64 },
    BadVecWidth(u32),
    BadUnroll(u32),
    BadRegBlock(u32),
    ZeroDim,
    ZeroTile,
}

impl std::fmt::Display for LegalityError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LegalityError::WorkGroupTooLarge { got, max } => {
                write!(f, "work-group size {got} exceeds device maximum {max}")
            }
            LegalityError::SlmOverflow { got, max } => {
                write!(f, "SLM footprint {got} B exceeds device budget {max} B")
            }
            LegalityError::BadVecWidth(w) => {
                write!(f, "vector width {w} is not a power of two in 1..=8")
            }
            LegalityError::BadUnroll(u) => write!(f, "unroll factor {u} out of range 1..=16"),
            LegalityError::BadRegBlock(r) => {
                write!(f, "register blocking {r} out of range 1..=8")
            }
            LegalityError::ZeroDim => write!(f, "work-group dimension is zero"),
            LegalityError::ZeroTile => write!(f, "tile dimension is zero"),
        }
    }
}

impl std::error::Error for LegalityError {}

/// Check a genome against device limits. The first violation is returned
/// (a real compiler stops at the first hard error too).
pub fn check_legality(
    genome: &KernelGenome,
    limits: &DeviceLimits,
) -> Result<(), LegalityError> {
    let p = &genome.params;
    if p.wg_x == 0 || p.wg_y == 0 {
        return Err(LegalityError::ZeroDim);
    }
    if p.tile_m == 0 || p.tile_n == 0 || p.tile_k == 0 {
        return Err(LegalityError::ZeroTile);
    }
    let wg = p.work_group_size();
    if wg > limits.max_work_group_size {
        return Err(LegalityError::WorkGroupTooLarge {
            got: wg,
            max: limits.max_work_group_size,
        });
    }
    if genome.uses_slm() {
        let slm = p.slm_bytes();
        if slm > limits.slm_bytes {
            return Err(LegalityError::SlmOverflow {
                got: slm,
                max: limits.slm_bytes,
            });
        }
    }
    if !p.vec_width.is_power_of_two() || p.vec_width > 8 {
        return Err(LegalityError::BadVecWidth(p.vec_width));
    }
    if p.unroll == 0 || p.unroll > 16 {
        return Err(LegalityError::BadUnroll(p.unroll));
    }
    if p.reg_block == 0 || p.reg_block > 8 {
        return Err(LegalityError::BadRegBlock(p.reg_block));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::genome::{KernelGenome, MemoryPattern};

    #[test]
    fn default_genome_is_legal() {
        let g = KernelGenome::direct_translation("t");
        assert!(check_legality(&g, &DeviceLimits::default()).is_ok());
    }

    #[test]
    fn oversized_work_group_rejected() {
        let mut g = KernelGenome::direct_translation("t");
        g.params.wg_x = 64;
        g.params.wg_y = 64; // 4096 > 1024
        assert!(matches!(
            check_legality(&g, &DeviceLimits::default()),
            Err(LegalityError::WorkGroupTooLarge { .. })
        ));
    }

    #[test]
    fn slm_overflow_only_when_slm_used() {
        let mut g = KernelGenome::direct_translation("t");
        g.params.tile_m = 256;
        g.params.tile_n = 256;
        g.params.tile_k = 64;
        // Scalar kernel: tiles unused, no SLM check.
        assert!(check_legality(&g, &DeviceLimits::default()).is_ok());
        g.mem = MemoryPattern::TiledSlm;
        assert!(matches!(
            check_legality(&g, &DeviceLimits::default()),
            Err(LegalityError::SlmOverflow { .. })
        ));
    }

    #[test]
    fn bad_scalar_params_rejected() {
        let mut g = KernelGenome::direct_translation("t");
        g.params.vec_width = 3;
        assert_eq!(
            check_legality(&g, &DeviceLimits::default()),
            Err(LegalityError::BadVecWidth(3))
        );
        g.params.vec_width = 4;
        g.params.unroll = 0;
        assert_eq!(
            check_legality(&g, &DeviceLimits::default()),
            Err(LegalityError::BadUnroll(0))
        );
        g.params.unroll = 2;
        g.params.reg_block = 9;
        assert_eq!(
            check_legality(&g, &DeviceLimits::default()),
            Err(LegalityError::BadRegBlock(9))
        );
    }
}
