//! Kernel intermediate representation.
//!
//! A [`KernelGenome`] is the structured description of one candidate GPU
//! kernel: which memory-access strategy it uses, how the algorithm is
//! organized, how work-items coordinate, and its hardware-dependent
//! parameters (work-group shape, tile sizes, vector width, unroll factor,
//! register blocking, prefetching, SLM padding).
//!
//! The genome plays the role of the *source code the LLM writes* in the
//! paper: the simulated code model ([`crate::simllm`]) mutates genomes,
//! the renderer ([`render`]) turns them into real SYCL C++ source text,
//! and the behavioral classifier ([`crate::classify`]) re-derives the
//! MAP-Elites coordinates from that text by static pattern matching —
//! exactly the §3.2 pipeline.

pub mod genome;
pub mod legality;
pub mod render;

pub use genome::{
    AlgoStructure, Defect, DefectKind, KernelGenome, MemoryPattern, ParamSet, SyncStrategy,
    TemplateSpec,
};
pub use legality::{check_legality, LegalityError};
pub use render::render_sycl;
