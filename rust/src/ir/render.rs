//! Genome → SYCL C++ source renderer.
//!
//! Produces real SYCL source text whose constructs reflect the genome's
//! features. The behavioral classifier (§3.2) then performs *static
//! pattern matching on this text* — the same mechanism the paper uses on
//! LLM-generated source — so classifier, meta-prompter diagnostics and
//! the archive all operate on genuine kernel source, not on genome
//! internals.

use super::genome::{AlgoStructure, DefectKind, KernelGenome, MemoryPattern, SyncStrategy};

/// Render a genome to SYCL C++ source. A `SyntaxError` defect yields
/// deliberately malformed source (unbalanced braces), which the compile
/// stage rejects — mirroring an LLM emitting non-compiling code.
pub fn render_sycl(genome: &KernelGenome) -> String {
    let mut src = String::with_capacity(4096);
    let p = &genome.params;
    let name = kernel_struct_name(genome);

    src.push_str("#include <sycl/sycl.hpp>\n#include <torch/extension.h>\n#include <c10/xpu/XPUStream.h>\n\n");
    src.push_str(&format!(
        "// task: {} | mem={:?} algo={:?} sync={:?} fused_ops={}\n",
        genome.task_id, genome.mem, genome.algo, genome.sync, genome.fused_ops
    ));

    if genome.template.is_some() {
        src.push_str(&format!(
            "template <int WG_X, int WG_Y, int TILE_M, int TILE_N, int TILE_K>\nstruct {name} {{}};\n\n"
        ));
    } else {
        src.push_str(&format!("struct {name} {{}};\n\n"));
    }

    src.push_str("torch::Tensor forward(torch::Tensor input) {\n");
    src.push_str("  auto out = torch::empty_like(input);\n");
    src.push_str("  sycl::queue& q = c10::xpu::getCurrentXPUStream().queue();\n");
    src.push_str(&format!(
        "  constexpr int WG_X = {}; constexpr int WG_Y = {};\n",
        p.wg_x, p.wg_y
    ));
    if genome.uses_slm() {
        src.push_str(&format!(
            "  constexpr int TILE_M = {}; constexpr int TILE_N = {}; constexpr int TILE_K = {};\n",
            p.tile_m, p.tile_n, p.tile_k
        ));
    }
    src.push_str("  q.submit([&](sycl::handler& cgh) {\n");

    // --- memory hierarchy constructs -------------------------------------
    match genome.mem {
        MemoryPattern::Scalar => {}
        MemoryPattern::Coalesced => { /* vectorized loads appear in the body */ }
        MemoryPattern::TiledSlm | MemoryPattern::MultiLevel => {
            let pad = if p.slm_pad { " + 1" } else { "" };
            src.push_str(&format!(
                "    sycl::local_accessor<float, 2> tile_a(sycl::range<2>(TILE_M, TILE_K{pad}), cgh);\n"
            ));
            src.push_str(&format!(
                "    sycl::local_accessor<float, 2> tile_b(sycl::range<2>(TILE_K, TILE_N{pad}), cgh);\n"
            ));
        }
    }

    src.push_str(&format!(
        "    cgh.parallel_for<{}>(\n      sycl::nd_range<2>(sycl::range<2>(N, M), sycl::range<2>(WG_Y, WG_X)),\n      [=](sycl::nd_item<2> item) {{\n",
        if genome.template.is_some() {
            format!("{name}<WG_X, WG_Y, TILE_M, TILE_N, TILE_K>")
        } else {
            name.clone()
        }
    ));

    // --- body: loads ------------------------------------------------------
    match genome.mem {
        MemoryPattern::Scalar => {
            src.push_str("        // strided scalar loads\n        float v = in[item.get_global_id(0) * stride + item.get_global_id(1)];\n");
        }
        MemoryPattern::Coalesced => {
            src.push_str(&format!(
                "        // coalesced vectorized access\n        sycl::vec<float, {w}> v;\n        v.load(0, sycl::multi_ptr<const float, sycl::access::address_space::global_space>(in + base));\n",
                w = p.vec_width.max(2)
            ));
        }
        MemoryPattern::TiledSlm => {
            src.push_str("        // cooperative tile load into shared local memory\n        tile_a[item.get_local_id(0)][item.get_local_id(1)] = in[gid];\n");
        }
        MemoryPattern::MultiLevel => {
            src.push_str("        // multi-level: SLM tile + register blocking\n        tile_a[item.get_local_id(0)][item.get_local_id(1)] = in[gid];\n");
            src.push_str(&format!(
                "        float reg_acc[{rb}][{rb}] = {{}}; // register blocking\n",
                rb = p.reg_block.max(2)
            ));
            if p.prefetch {
                src.push_str("        sycl::global_ptr<const float>(in + next_tile).prefetch(TILE_K); // prefetch next tile\n");
            }
            if p.vec_width > 1 {
                src.push_str(&format!(
                    "        sycl::vec<float, {w}> vload; vload.load(0, sycl::multi_ptr<const float, sycl::access::address_space::global_space>(in + base));\n",
                    w = p.vec_width
                ));
            }
        }
    }

    // --- synchronization ---------------------------------------------------
    let needs_barrier_for_slm =
        genome.uses_slm() && !genome.has_defect(DefectKind::MissingBarrier);
    match genome.sync {
        SyncStrategy::None => {
            if needs_barrier_for_slm {
                // SLM without declared coordination still renders the barrier
                // needed for tile consistency (classifier credits it to d_mem,
                // not d_sync — see classify::no_double_count).
                src.push_str("        sycl::group_barrier(item.get_group()); // tile consistency\n");
            }
        }
        SyncStrategy::WorkGroupBarrier => {
            src.push_str("        sycl::group_barrier(item.get_group());\n");
        }
        SyncStrategy::SubGroup => {
            src.push_str("        auto sg = item.get_sub_group();\n        float partial = sycl::reduce_over_group(sg, v, sycl::plus<float>());\n        float other = sycl::select_from_group(sg, partial, 0); // sub-group broadcast\n");
            if needs_barrier_for_slm {
                src.push_str("        sycl::group_barrier(item.get_group()); // tile consistency\n");
            }
        }
        SyncStrategy::Global => {
            src.push_str("        sycl::atomic_ref<float, sycl::memory_order::relaxed, sycl::memory_scope::device> gacc(out[0]);\n        gacc.fetch_add(partial); // global coordination, multi-pass\n");
            if needs_barrier_for_slm {
                src.push_str("        sycl::group_barrier(item.get_group());\n");
            }
        }
    }

    // --- algorithmic structure ----------------------------------------------
    match genome.algo {
        AlgoStructure::DirectTranslation => {
            src.push_str("        out[gid] = op(v); // direct translation of the reference op\n");
        }
        AlgoStructure::Fused => {
            src.push_str(&format!(
                "        // fused chain of {} ops in a single pass\n        float t = v;\n",
                genome.fused_ops.max(2)
            ));
            for i in 0..genome.fused_ops.max(2) {
                src.push_str(&format!("        t = fused_stage_{i}(t);\n"));
            }
            src.push_str("        out[gid] = t;\n");
        }
        AlgoStructure::Reformulated => {
            src.push_str(
                "        // reformulated: online normalization (single-pass running max/sum)\n        float running_max = -INFINITY, running_sum = 0.f;\n        for (int k = 0; k < K; ++k) {\n          float x = load(k);\n          float m = sycl::fmax(running_max, x);\n          running_sum = running_sum * sycl::native::exp2((running_max - m) * M_LOG2E_F) + sycl::native::exp2((x - m) * M_LOG2E_F);\n          running_max = m;\n        }\n        out[gid] = finalize(running_max, running_sum);\n",
            );
        }
        AlgoStructure::Novel => {
            src.push_str(
                "        // novel decomposition: hierarchical two-stage algorithm with\n        // asymptotically fewer passes than the reference\n        float s = hierarchical_stage(in, gid);\n        out[gid] = combine(s);\n",
            );
        }
    }

    if p.unroll > 1 {
        src.push_str(&format!("        #pragma unroll {}\n        for (int u = 0; u < {0}; ++u) {{ body(u); }}\n", p.unroll));
    }
    if genome.has_defect(DefectKind::OutOfBounds) {
        src.push_str("        out[gid + WG_X] = v; // NOTE: missing bounds guard\n");
    } else {
        src.push_str("        if (gid < total) { /* bounds guarded */ }\n");
    }

    src.push_str("      });\n  });\n");
    src.push_str("  q.wait();\n  return out;\n}\n\n");

    // --- dispatcher for templated kernels (§3.4) ---------------------------
    if let Some(spec) = &genome.template {
        src.push_str("torch::Tensor forward_dispatch(torch::Tensor input, int wg_x, int wg_y, int tile_m, int tile_n, int tile_k) {\n");
        for inst in spec.instantiations(&genome.params).iter().take(32) {
            src.push_str(&format!(
                "  if (wg_x == {} && wg_y == {} && tile_m == {} && tile_n == {} && tile_k == {}) return forward_templated<{}, {}, {}, {}, {}>(input);\n",
                inst.wg_x, inst.wg_y, inst.tile_m, inst.tile_n, inst.tile_k,
                inst.wg_x, inst.wg_y, inst.tile_m, inst.tile_n, inst.tile_k
            ));
        }
        src.push_str("  TORCH_CHECK(false, \"unsupported parameter combination\");\n}\n\n");
    }

    src.push_str("PYBIND11_MODULE(TORCH_EXTENSION_NAME, m) {\n  m.def(\"forward\", &forward);\n}\n");

    // --- defect channel: syntax errors break the source --------------------
    if genome.has_defect(DefectKind::SyntaxError) {
        // Drop the final closing brace: unbalanced source fails the
        // compile-stage brace check, like a truncated LLM response.
        let cut = src.rfind('}').unwrap();
        src.truncate(cut);
        src.push_str("\n// <truncated generation>\n");
    }
    src
}

fn kernel_struct_name(genome: &KernelGenome) -> String {
    let sanitized: String = genome
        .task_id
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect();
    format!("Kern_{sanitized}")
}

/// Cheap compile-stage syntax validation: balanced braces/parens and the
/// required module plumbing. Returns Err(log) mimicking a compiler error.
pub fn syntax_check(src: &str) -> Result<(), String> {
    let mut brace = 0i64;
    let mut paren = 0i64;
    for (lineno, line) in src.lines().enumerate() {
        for c in line.chars() {
            match c {
                '{' => brace += 1,
                '}' => brace -= 1,
                '(' => paren += 1,
                ')' => paren -= 1,
                _ => {}
            }
            if brace < 0 || paren < 0 {
                return Err(format!(
                    "kernel.cpp:{}: error: unbalanced delimiter near '{}'",
                    lineno + 1,
                    line.trim()
                ));
            }
        }
    }
    if brace != 0 || paren != 0 {
        return Err(format!(
            "kernel.cpp: error: expected '}}' at end of input ({brace} unclosed braces, {paren} unclosed parens)"
        ));
    }
    if !src.contains("PYBIND11_MODULE") {
        return Err("kernel.cpp: error: missing PYBIND11_MODULE interface".to_string());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::genome::{Defect, TemplateSpec};

    fn base() -> KernelGenome {
        KernelGenome::direct_translation("99_Matmul_GELU_Softmax")
    }

    #[test]
    fn clean_render_passes_syntax_check() {
        let mut g = base();
        for mem in 0..4 {
            for algo in 0..4 {
                for sync in 0..4 {
                    g.mem = MemoryPattern::from_level(mem);
                    g.algo = AlgoStructure::from_level(algo);
                    g.sync = SyncStrategy::from_level(sync);
                    let src = render_sycl(&g);
                    syntax_check(&src).unwrap_or_else(|e| {
                        panic!("syntax check failed for {mem}/{algo}/{sync}: {e}")
                    });
                }
            }
        }
    }

    #[test]
    fn syntax_defect_fails_check() {
        let mut g = base();
        g.defects.push(Defect {
            kind: DefectKind::SyntaxError,
            severity: 1.0,
        });
        let src = render_sycl(&g);
        assert!(syntax_check(&src).is_err());
    }

    #[test]
    fn constructs_reflect_features() {
        let mut g = base();
        g.mem = MemoryPattern::TiledSlm;
        g.sync = SyncStrategy::WorkGroupBarrier;
        let src = render_sycl(&g);
        assert!(src.contains("local_accessor"));
        assert!(src.contains("group_barrier"));

        g.mem = MemoryPattern::Coalesced;
        g.sync = SyncStrategy::SubGroup;
        g.params.vec_width = 4;
        let src = render_sycl(&g);
        assert!(src.contains("sycl::vec<float, 4>"));
        assert!(src.contains("get_sub_group"));
        assert!(!src.contains("local_accessor"));
    }

    #[test]
    fn templated_render_emits_dispatcher() {
        let mut g = base();
        g.template = Some(TemplateSpec {
            wg_options: vec![(16, 1), (32, 1)],
            tile_options: vec![(16, 16, 16)],
            vec_options: vec![1],
        });
        let src = render_sycl(&g);
        assert!(src.contains("forward_dispatch"));
        assert!(src.contains("forward_templated<16, 1, 16, 16, 16>"));
        assert!(src.contains("template <int WG_X"));
        syntax_check(&src).unwrap();
    }

    #[test]
    fn slm_padding_rendered() {
        let mut g = base();
        g.mem = MemoryPattern::TiledSlm;
        g.params.slm_pad = true;
        assert!(render_sycl(&g).contains("TILE_K + 1"));
        g.params.slm_pad = false;
        assert!(!render_sycl(&g).contains("TILE_K + 1"));
    }
}
