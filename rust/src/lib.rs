//! # KernelFoundry (reproduction)
//!
//! A Rust + JAX + Pallas reproduction of *KernelFoundry: Hardware-aware
//! evolutionary GPU kernel optimization* (Wiedemann et al., CS.DC 2026).
//!
//! The crate implements the paper's full system — MAP-Elites quality-
//! diversity search with kernel-specific behavioral descriptors,
//! gradient-informed evolution, meta-prompt co-evolution, templated
//! parameter tuning, the distributed evaluation framework, and the
//! rigorous benchmarking methodology — plus a kernel-as-a-service layer
//! (`service`: fleet scheduler, result cache, TCP job API over the §3.6
//! distributed framework) and every substrate it depends on
//! (simulated LLM code model, SYCL-like kernel IR + renderer, hardware
//! performance simulator, KernelBench-like task suites, PJRT runtime for
//! real AOT-compiled Pallas kernels).
//!
//! See `DESIGN.md` for the paper→module map and the substitution table,
//! and `README.md` for the CLI quickstart.

#![warn(missing_docs)]

pub mod archive;
pub mod config;
pub mod coordinator;
pub mod metrics;
pub mod classify;
pub mod dist;
pub mod eval;
pub mod experiments;
pub mod gradient;
pub mod obs;
pub mod prompts;
pub mod report;
pub mod runtime;
pub mod selection;
pub mod service;
pub mod simllm;
pub mod tasks;
pub mod transitions;
pub mod hwsim;
pub mod ir;
pub mod util;

/// The crate version (from Cargo.toml), shown by `kernelfoundry --help`.
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
