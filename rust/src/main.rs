//! KernelFoundry CLI launcher.
//!
//! ```text
//! kernelfoundry run        --task <id> --device b580 --iters 40 [--param-opt]
//! kernelfoundry bench      --table 1|2|3|4|11|fig3  [--out results/]
//! kernelfoundry serve      --compile-workers N --exec-workers M (distributed demo)
//! kernelfoundry daemon     --addr 127.0.0.1:7341 --devices lnl,b580,a6000 (service)
//!                          [--alert-rules rules.txt --alert-log alerts.jsonl]
//! kernelfoundry submit     --addr 127.0.0.1:7341 --task <id> --device b580|all
//! kernelfoundry metrics    --addr 127.0.0.1:7341 [--prometheus] [--scope service|global]
//! kernelfoundry watch      --addr 127.0.0.1:7341 [--interval 1s] [--plain] (live dashboard)
//! kernelfoundry trace      <job-id> --sink trace.jsonl [--follow] (job timeline)
//! kernelfoundry tasks      [--suite l1|l2|rkb|onednn] [--json]
//! kernelfoundry report     --db runs.jsonl [--device d] [--suite s] [--trace t] [--journal j]
//!                          [--search-log s] [--alert-log a] [--html out.html] [--top N] [--json]
//! kernelfoundry report regressions --db runs.jsonl --baseline old.jsonl
//!                          [--max-speedup-drop 0.10] (exits nonzero on regression)
//! ```
//!
//! Every subcommand accepts `--verbose` (debug logging) and `--quiet`
//! (warnings only); the `KF_LOG` environment variable overrides both.

use kernelfoundry::config::FoundryConfig;
use kernelfoundry::coordinator::EvolutionEngine;
use kernelfoundry::dist::{ClusterConfig, Database, DbRow, WorkerPool};
use kernelfoundry::eval::ExecBackend;
use kernelfoundry::experiments::{self, ExperimentScale};
use kernelfoundry::hwsim::DeviceProfile;
use kernelfoundry::report;
use kernelfoundry::service::{
    self, proto, Client, KernelService, Server, ServiceConfig, DEFAULT_LEASE_TTL_SECS,
};
use kernelfoundry::tasks::catalog;
use kernelfoundry::util::cli::{parse_duration_ms, Command, Parsed};
use kernelfoundry::util::json::Json;
use std::path::{Path, PathBuf};
use std::process::ExitCode;
use std::sync::Arc;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((sub, rest)) = args.split_first() else {
        print_usage();
        return ExitCode::FAILURE;
    };
    let result = match sub.as_str() {
        "run" => cmd_run(rest),
        "bench" => cmd_bench(rest),
        "serve" => cmd_serve(rest),
        "daemon" => cmd_daemon(rest),
        "submit" => cmd_submit(rest),
        "metrics" => cmd_metrics(rest),
        "watch" => cmd_watch(rest),
        "trace" => cmd_trace(rest),
        "tasks" => cmd_tasks(rest),
        "report" => cmd_report(rest),
        "--help" | "-h" | "help" => {
            print_usage();
            Ok(())
        }
        other => Err(format!("unknown subcommand '{other}'\n")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(msg) => {
            eprintln!("{msg}");
            ExitCode::FAILURE
        }
    }
}

fn print_usage() {
    println!(
        "kernelfoundry {} — hardware-aware evolutionary GPU kernel optimization (reproduction)\n\n\
         subcommands:\n  run      optimize kernels for one task\n  bench    regenerate a paper table/figure\n  serve    distributed worker-pool demo\n  daemon   long-running kernel-generation service (TCP JSON RPC)\n  submit   client for a running daemon (submit/status/result/cancel/stats/metrics)\n  metrics  fetch a daemon's metrics snapshot (JSON or Prometheus text)\n  watch    live dashboard over a daemon's streaming watch RPC\n  trace    reconstruct a job's lifecycle timeline from a trace sink\n  tasks    list benchmark tasks\n  report   analytics over run artifacts (summary, HTML dashboard, regression gate)\n\nevery subcommand takes --verbose / --quiet (KF_LOG overrides both)\nuse <subcommand> --help for options",
        kernelfoundry::version()
    );
}

/// Attach the logging flags every subcommand shares.
fn with_log_flags(cmd: Command) -> Command {
    cmd.flag("verbose", "debug logging (KF_LOG env overrides)")
        .flag("quiet", "warnings and errors only (KF_LOG env overrides)")
}

/// Apply `--verbose` / `--quiet` to the global log level. `--quiet`
/// wins when both are given; the `KF_LOG` environment variable
/// overrides either (see `util::log`).
fn apply_log_flags(p: &kernelfoundry::util::cli::Parsed) {
    use kernelfoundry::util::log::{set_level, Level};
    if p.has_flag("quiet") {
        set_level(Level::Warn);
    } else if p.has_flag("verbose") {
        set_level(Level::Debug);
    }
}

fn cmd_run(args: &[String]) -> Result<(), String> {
    let cmd = Command::new("run", "run KernelFoundry on one task")
        .opt("task", "99_Matmul_GELU_Softmax", "task id (see `tasks`)")
        .opt("device", "b580", "device profile: lnl | b580 | a6000")
        .opt("iters", "40", "generations")
        .opt("population", "8", "candidates per generation")
        .opt("seed", "20260710", "RNG seed")
        .opt("models", "gpt-4.1,gpt-5-mini", "ensemble model profiles")
        .opt("config", "", "YAML config file (overrides defaults)")
        .opt("search-log", "", "JSONL per-generation search history for `report` ('' = off)")
        .flag("param-opt", "run the templated parameter-optimization phase")
        .flag("cuda", "generate CUDA instead of SYCL");
    let p = with_log_flags(cmd).parse(args)?;
    apply_log_flags(&p);

    let mut config = FoundryConfig::paper_defaults();
    if let Some(path) = p.get("config").filter(|s| !s.is_empty()) {
        let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
        config = FoundryConfig::from_yaml(&text).map_err(|e| e.to_string())?;
    }
    config.evolution.max_generations = p.get_usize("iters").unwrap_or(40);
    config.evolution.population = p.get_usize("population").unwrap_or(8);
    config.seed = p.get_u64("seed").unwrap_or(config.seed);
    config.device = p.get("device").unwrap_or("b580").to_string();
    if p.has_flag("cuda") {
        config.language = "cuda".to_string();
    }
    if let Some(models) = p.get("models") {
        config.llm.models = models.split(',').map(String::from).collect();
    }

    let task_id = p.get("task").unwrap();
    let task = catalog::find_task(task_id).ok_or_else(|| format!("unknown task '{task_id}'"))?;
    let device = DeviceProfile::by_name(&config.device)
        .ok_or_else(|| format!("unknown device '{}'", config.device))?;

    println!(
        "== KernelFoundry: task {} on {} ({} iters x pop {})",
        task.id, device.name, config.evolution.max_generations, config.evolution.population
    );
    let mut engine = EvolutionEngine::new(config, task, ExecBackend::HwSim(device));
    if let Some(path) = p.get("search-log").filter(|s| !s.is_empty()) {
        let log = report::SearchLog::open(Path::new(path))
            .map_err(|e| format!("search log {path}: {e}"))?;
        // Same shape as the service cache key (device at index 1), so
        // `report` folds CLI and daemon histories identically.
        let label = format!(
            "{}|{}|{}|s{}|i{}|p{}",
            engine.task.id,
            engine.config.device,
            engine.config.language,
            engine.config.seed,
            engine.config.evolution.max_generations,
            engine.config.evolution.population,
        );
        engine.attach_search_log(Arc::new(log), &label);
        println!("search log: {path} (inspect with `kernelfoundry report --search-log {path}`)");
    }
    let report = engine.run(p.has_flag("param-opt"));
    println!(
        "evaluations: {} (compile errors {}, incorrect {})",
        report.evaluations, report.compile_errors, report.incorrect
    );
    if let Some(best) = &report.best {
        println!(
            "best kernel: fitness {:.3}, speedup {:.3}x ({:.4} ms vs baseline {:.4} ms), cell {:?}, by {}",
            best.fitness, best.speedup, best.time_ms, best.baseline_ms, best.coords, best.genome.produced_by
        );
        println!("archive: {:?}", report.archive.unwrap());
        println!("\n--- best kernel source ---\n{}", best.source);
    } else {
        println!("no correct kernel found");
    }
    Ok(())
}

fn cmd_bench(args: &[String]) -> Result<(), String> {
    let cmd = Command::new("bench", "regenerate a paper table or figure")
        .opt("table", "1", "which: 1 | 2 | 3 | 4 | 11 | fig3 | all")
        .opt("out", "results", "output directory for CSVs")
        .flag("quick", "reduced-scale run");
    let p = with_log_flags(cmd).parse(args)?;
    apply_log_flags(&p);
    let scale = if p.has_flag("quick") {
        ExperimentScale::Quick
    } else {
        ExperimentScale::from_env()
    };
    let out_dir = Path::new(p.get("out").unwrap());
    std::fs::create_dir_all(out_dir).map_err(|e| e.to_string())?;
    let which = p.get("table").unwrap();

    let save = |name: &str, csv: &str| {
        let path = out_dir.join(name);
        std::fs::write(&path, csv).ok();
        println!("(per-task CSV: {})", path.display());
    };

    if which == "1" || which == "all" {
        for (i, t) in experiments::table1(scale).iter().enumerate() {
            t.print();
            save(&format!("table1_{}.csv", ["l1", "l2", "rkb"][i]), &t.per_task_csv);
        }
    }
    if which == "2" || which == "all" {
        for (i, t) in experiments::table2(scale).iter().enumerate() {
            t.print();
            save(&format!("table2_{}.csv", ["filtered", "l2"][i]), &t.per_task_csv);
        }
    }
    if which == "3" || which == "all" {
        let r = experiments::run_crossover(scale);
        println!(
            "\n## Table 3 / Table 10 — hardware-awareness crossover\n\n{}",
            r.markdown()
        );
        save("table3_crossover.csv", &r.csv());
    }
    if which == "4" || which == "all" {
        let t = experiments::table4(scale);
        t.print();
        save("table4_onednn.csv", &t.per_task_csv);
    }
    if which == "11" || which == "all" {
        let t = experiments::table11(scale);
        t.print();
        save("table11_gptoss.csv", &t.per_task_csv);
    }
    if which == "fig3" || which == "all" {
        let t = experiments::fig3_series(scale);
        t.print();
        save("fig3_iterations.csv", &t.per_task_csv);
    }
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<(), String> {
    let cmd = Command::new("serve", "distributed worker-pool demo")
        .opt("task", "1_Conv2D_ReLU_BiasAdd", "task id")
        .opt("compile-workers", "2", "compilation workers (no GPU)")
        .opt("exec-workers", "4", "execution workers (one device each)")
        .opt("batch", "32", "candidates per batch")
        .opt("device", "b580", "device profile")
        .opt("queue-capacity", "", "inter-stage queue capacity (defaults to the cluster default)")
        .opt("seed", "", "execution-pipeline RNG seed (defaults to the cluster default)")
        .opt("db", "runs.jsonl", "JSONL database every evaluation is persisted to ('' = off)");
    let p = with_log_flags(cmd).parse(args)?;
    apply_log_flags(&p);
    let task = catalog::find_task(p.get("task").unwrap())
        .ok_or_else(|| "unknown task".to_string())?;
    let device = DeviceProfile::by_name(p.get("device").unwrap()).ok_or("unknown device")?;
    // Unset flags fall back to ClusterConfig::default() (the single
    // source of truth for the demo topology) instead of divergent
    // hardcoded values.
    let defaults = ClusterConfig::default();
    // Database server role (Fig. 4 worker type 4). The store is
    // append-only: fold in rows a previous run persisted. Validate the
    // existing file *before* evaluating, so a corrupt database cannot
    // cost the batch (and is never overwritten).
    let db_path = p.get("db").unwrap_or_default().to_string();
    let db = Database::new();
    if !db_path.is_empty() && Path::new(&db_path).exists() {
        db.load(Path::new(&db_path))
            .map_err(|e| format!("existing database not loadable, refusing to overwrite: {e}"))?;
    }
    let pool = WorkerPool::new(ClusterConfig {
        compile_workers: p.get_usize("compile-workers").unwrap_or(defaults.compile_workers),
        exec_workers: p.get_usize("exec-workers").unwrap_or(defaults.exec_workers),
        device,
        queue_capacity: p.get_usize("queue-capacity").unwrap_or(defaults.queue_capacity),
        seed: p.get_u64("seed").unwrap_or(defaults.seed),
    });
    let n = p.get_usize("batch").unwrap_or(32);
    let genomes: Vec<_> = (0..n)
        .map(|i| {
            let mut g = kernelfoundry::ir::KernelGenome::direct_translation(&task.id);
            g.id = i as u64;
            g.mem = kernelfoundry::ir::MemoryPattern::from_level(i % 4);
            g.params.slm_pad = true;
            g
        })
        .collect();
    let start = std::time::Instant::now();
    let records = pool.evaluate_batch(&task, genomes);
    let dt = start.elapsed().as_secs_f64();
    let correct = records.iter().filter(|r| r.correct()).count();
    println!(
        "cluster evaluated {} candidates in {:.2}s ({:.1}/s): {} correct, {} compile-rejected (never reached a GPU worker)",
        records.len(),
        dt,
        records.len() as f64 / dt,
        correct,
        pool.metrics.compile_rejected.load(std::sync::atomic::Ordering::Relaxed),
    );
    if !db_path.is_empty() {
        let idx0 = db.len();
        for (i, rec) in records.iter().enumerate() {
            db.insert(DbRow::from_record("serve", "kernelfoundry", idx0 + i, rec));
        }
        db.save(Path::new(&db_path)).map_err(|e| e.to_string())?;
        println!(
            "database: {} rows -> {db_path} (inspect with `kernelfoundry report --db {db_path}`)",
            db.len()
        );
    }
    Ok(())
}

fn cmd_daemon(args: &[String]) -> Result<(), String> {
    let about = "long-running kernel-generation service (newline-JSON RPC over TCP)";
    let cmd = Command::new("daemon", about)
        .opt("addr", "127.0.0.1:7341", "listen address (port 0 = ephemeral)")
        .opt("devices", "lnl,b580,a6000", "fleet device profiles, comma-separated")
        .opt("compile-workers", "", "compile workers per lane (default: cluster default)")
        .opt("exec-workers", "", "execution workers per lane (default: cluster default)")
        .opt("queue-capacity", "", "job/pool queue capacity (default: cluster default)")
        .opt("db", "", "JSONL path for cache persistence ('' = in-memory only)")
        .opt("journal", "", "JSONL write-ahead job journal; restart replays queued/in-flight jobs ('' = volatile)")
        .opt("lease-ttl", "30", "journal owner-lease TTL in seconds (heartbeat at ttl/3)")
        .opt("trace", "", "JSONL job-lifecycle trace sink for `kernelfoundry trace` ('' = off)")
        .opt("search-log", "", "JSONL per-generation search history for `kernelfoundry report` ('' = off)")
        .opt("alert-rules", "", "SLO rules file for the alert engine ('' = built-in defaults)")
        .opt("alert-log", "", "JSONL the alert engine appends firing/resolved transitions to")
        .opt("alert-interval", "", "alert evaluation cadence, e.g. 250ms | 2s (default 1s)")
        .opt("fault-plan", "", "deterministic fault-injection plan file (chaos testing; '' = off)")
        .opt("max-retries", "", "transient-failure retries per unit before quarantine (default 2)")
        .opt("unit-deadline-ms", "", "wall-clock deadline per unit attempt, e.g. 2000 | 2s ('' = none)")
        .opt("lane-trip-threshold", "", "consecutive transient failures that open a lane's breaker (default 3)")
        .opt("retry-backoff-ms", "", "base retry backoff, e.g. 100 | 250ms (default 100ms)")
        .opt("lane-cooldown-ms", "", "open-lane cooldown before the half-open probe, e.g. 1000 | 2s (default 1s)");
    let p = with_log_flags(cmd).parse(args)?;
    apply_log_flags(&p);
    let mut devices = Vec::new();
    for name in p.get("devices").unwrap().split(',').filter(|s| !s.is_empty()) {
        let device =
            DeviceProfile::by_name(name).ok_or_else(|| format!("unknown device '{name}'"))?;
        devices.push(device);
    }
    let defaults = ClusterConfig::default();
    let mut guard = service::GuardConfig::default();
    if let Some(v) = p.get("max-retries").filter(|s| !s.is_empty()) {
        guard.max_retries = v
            .parse()
            .map_err(|_| format!("--max-retries: invalid count '{v}'"))?;
    }
    if let Some(s) = p.get("unit-deadline-ms").filter(|s| !s.is_empty()) {
        guard.unit_deadline = Some(std::time::Duration::from_millis(
            parse_duration_ms(s).map_err(|e| format!("--unit-deadline-ms: {e}"))? as u64,
        ));
    }
    if let Some(v) = p.get("lane-trip-threshold").filter(|s| !s.is_empty()) {
        guard.trip_threshold = v
            .parse()
            .map_err(|_| format!("--lane-trip-threshold: invalid count '{v}'"))?;
    }
    if let Some(s) = p.get("retry-backoff-ms").filter(|s| !s.is_empty()) {
        guard.retry_backoff = std::time::Duration::from_millis(
            parse_duration_ms(s).map_err(|e| format!("--retry-backoff-ms: {e}"))? as u64,
        );
    }
    if let Some(s) = p.get("lane-cooldown-ms").filter(|s| !s.is_empty()) {
        guard.lane_cooldown = std::time::Duration::from_millis(
            parse_duration_ms(s).map_err(|e| format!("--lane-cooldown-ms: {e}"))? as u64,
        );
    }
    let fault_plan = match p.get("fault-plan").filter(|s| !s.is_empty()) {
        Some(path) => Some(
            service::FaultPlan::load(Path::new(&path)).map_err(|e| format!("--fault-plan: {e}"))?,
        ),
        None => None,
    };
    let cfg = ServiceConfig {
        devices,
        compile_workers: p.get_usize("compile-workers").unwrap_or(defaults.compile_workers),
        exec_workers: p.get_usize("exec-workers").unwrap_or(defaults.exec_workers),
        queue_capacity: p.get_usize("queue-capacity").unwrap_or(defaults.queue_capacity),
        db_path: p.get("db").filter(|s| !s.is_empty()).map(Into::into),
        journal_path: p.get("journal").filter(|s| !s.is_empty()).map(Into::into),
        lease_ttl: std::time::Duration::from_secs(
            p.get_usize("lease-ttl").unwrap_or(DEFAULT_LEASE_TTL_SECS as usize).max(1) as u64,
        ),
        trace_path: p.get("trace").filter(|s| !s.is_empty()).map(Into::into),
        search_log_path: p.get("search-log").filter(|s| !s.is_empty()).map(Into::into),
        alert_rules_path: p.get("alert-rules").filter(|s| !s.is_empty()).map(Into::into),
        alert_log_path: p.get("alert-log").filter(|s| !s.is_empty()).map(Into::into),
        alert_interval: match p.get("alert-interval").filter(|s| !s.is_empty()) {
            Some(s) => std::time::Duration::from_millis(
                parse_duration_ms(s).map_err(|e| format!("--alert-interval: {e}"))? as u64,
            ),
            None => std::time::Duration::from_millis(service::DEFAULT_ALERT_INTERVAL_MS),
        },
        guard,
        fault_plan,
    };
    if cfg.journal_path.is_some() && kernelfoundry::service::failpoint::any_armed() {
        eprintln!(
            "warning: {} is set — crash injection armed (test harness only)",
            kernelfoundry::service::failpoint::ENV_VAR
        );
    }
    let cfg_fault_rules = cfg.fault_plan.as_ref().map(|plan| plan.len()).unwrap_or(0);
    let service = KernelService::start(cfg)?;
    let mut server = Server::start(Arc::clone(&service), p.get("addr").unwrap())
        .map_err(|e| format!("binding {}: {e}", p.get("addr").unwrap()))?;
    println!(
        "kernelfoundry daemon listening on {} (fleet: {})",
        server.addr(),
        service.device_names().join(", ")
    );
    println!("stop with: kernelfoundry submit --addr {} --verb shutdown", server.addr());
    if let Some(trace) = p.get("trace").filter(|s| !s.is_empty()) {
        println!("trace sink: {trace} (inspect with `kernelfoundry trace <job-id> --sink {trace}`)");
    }
    if let Some(slog) = p.get("search-log").filter(|s| !s.is_empty()) {
        println!("search log: {slog} (inspect with `kernelfoundry report --search-log {slog}`)");
    }
    if let Some(plan) = p.get("fault-plan").filter(|s| !s.is_empty()) {
        println!(
            "fault plan: {plan} ({} rule(s)) — chaos injection armed (test harness only)",
            cfg_fault_rules
        );
    }
    let rules = service.alert_rule_names();
    if !rules.is_empty() {
        println!(
            "alert engine: {} rule(s) [{}] (watch with `kernelfoundry watch --addr {}`)",
            rules.len(),
            rules.join(", "),
            server.addr()
        );
    }
    server.wait();
    println!("shutting down: draining queued jobs ...");
    service.stop();
    Ok(())
}

fn cmd_submit(args: &[String]) -> Result<(), String> {
    let cmd = Command::new("submit", "client for a running kernelfoundry daemon")
        .opt("addr", "127.0.0.1:7341", "daemon address")
        .opt("verb", "submit", "submit | status | result | cancel | stats | metrics | shutdown")
        .opt("job", "", "job id (status / result / cancel)")
        .opt("task", "", "catalog task id (see `kernelfoundry tasks --json`)")
        .opt("custom-dir", "", "directory with task.yaml + marked source (inline custom task)")
        .opt("device", "b580", "fleet device name, or 'all' to fan out across the fleet")
        .opt("iters", "8", "generations")
        .opt("population", "4", "candidates per generation")
        .opt("seed", "20260710", "RNG seed (part of the cache key)")
        .opt("priority", "normal", "low | normal | high")
        .opt("timeout", "600", "seconds to wait for completion")
        .flag("cuda", "generate CUDA instead of SYCL")
        .flag("no-wait", "return right after submission instead of polling to completion")
        .flag("json", "print raw JSON responses");
    let p = with_log_flags(cmd).parse(args)?;
    apply_log_flags(&p);
    let addr = p.get("addr").unwrap();
    let mut client =
        Client::connect(addr).map_err(|e| format!("connecting to daemon at {addr}: {e}"))?;
    let raw = p.has_flag("json");

    let simple = |client: &mut Client, req: &proto::Request| -> Result<Json, String> {
        client.request(req).map_err(|e| e.to_string())
    };
    match p.get("verb").unwrap() {
        "stats" => {
            let resp = simple(&mut client, &proto::Request::Stats)?;
            println!("{}", if raw { resp.to_string_compact() } else { resp.to_string_pretty() });
            return Ok(());
        }
        "shutdown" => {
            let resp = simple(&mut client, &proto::Request::Shutdown)?;
            println!("{}", resp.to_string_compact());
            return Ok(());
        }
        "metrics" => {
            let resp = simple(&mut client, &proto::Request::Metrics(None))?;
            if raw {
                println!("{}", resp.to_string_compact());
            } else {
                print!(
                    "{}",
                    resp.get("prometheus").and_then(|v| v.as_str()).unwrap_or("")
                );
            }
            return Ok(());
        }
        verb @ ("status" | "result" | "cancel") => {
            let id = p.get_u64("job").ok_or("--job <id> required for this verb")?;
            let req = match verb {
                "status" => proto::Request::Status(id),
                "result" => proto::Request::Result(id),
                _ => proto::Request::Cancel(id),
            };
            let resp = simple(&mut client, &req)?;
            println!("{}", if raw { resp.to_string_compact() } else { resp.to_string_pretty() });
            return Ok(());
        }
        "submit" => {}
        other => {
            return Err(format!(
                "unknown verb '{other}' (submit | status | result | cancel | stats | metrics | shutdown)"
            ))
        }
    }

    // Build the submit spec: catalog id or inline custom bundle.
    let task_opt = p.get("task").filter(|s| !s.is_empty());
    let custom_opt = p.get("custom-dir").filter(|s| !s.is_empty());
    let task = match (task_opt, custom_opt) {
        (Some(id), None) => service::TaskSource::Catalog(id.to_string()),
        (None, Some(dir)) => {
            let dir = Path::new(dir);
            let (config, source) = kernelfoundry::tasks::custom::read_dir_strings(dir)
                .map_err(|e| format!("custom task bundle {}: {e}", dir.display()))?;
            service::TaskSource::Custom { config, source }
        }
        (Some(_), Some(_)) => return Err("--task and --custom-dir are mutually exclusive".into()),
        (None, None) => return Err("submit needs --task <id> or --custom-dir <dir>".into()),
    };
    let device = match p.get("device").unwrap() {
        "all" => service::DeviceTarget::FanOut,
        d => service::DeviceTarget::Named(d.to_string()),
    };
    let priority = service::JobPriority::parse(p.get("priority").unwrap())
        .ok_or("priority must be low | normal | high")?;
    let spec = service::JobSpec {
        task,
        device,
        language: if p.has_flag("cuda") { "cuda" } else { "sycl" }.to_string(),
        seed: p.get_u64("seed").unwrap_or(service::job::DEFAULT_SEED),
        iters: p.get_usize("iters").unwrap_or(service::job::DEFAULT_ITERS),
        population: p.get_usize("population").unwrap_or(service::job::DEFAULT_POPULATION),
        priority,
    };

    let resp = simple(&mut client, &proto::Request::Submit(spec))?;
    if !proto::response_ok(&resp) {
        return Err(format!(
            "submit rejected: {}",
            resp.get("error").and_then(|e| e.as_str()).unwrap_or("unknown error")
        ));
    }
    let id = resp
        .get("job_id")
        .and_then(|v| v.as_usize())
        .ok_or("daemon returned no job_id")? as u64;
    let state = resp.get("state").and_then(|s| s.as_str()).unwrap_or("queued").to_string();
    if raw {
        println!("{}", resp.to_string_compact());
    } else {
        println!("job {id}: {state}{}", if state == "done" { " (cache hit)" } else { "" });
    }
    if p.has_flag("no-wait") {
        return Ok(());
    }

    // Poll to a terminal state, then fetch the full result.
    let timeout = std::time::Duration::from_secs(p.get_u64("timeout").unwrap_or(600));
    let started = std::time::Instant::now();
    let mut state = state;
    while !matches!(state.as_str(), "done" | "partial" | "failed" | "cancelled") {
        if started.elapsed() > timeout {
            return Err(format!(
                "timed out after {timeout:?} waiting for job {id} (state: {state})"
            ));
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
        let resp = simple(&mut client, &proto::Request::Status(id))?;
        if !proto::response_ok(&resp) {
            return Err(format!(
                "status poll for job {id} failed: {}",
                resp.get("error").and_then(|e| e.as_str()).unwrap_or("unknown error")
            ));
        }
        state = resp.get("state").and_then(|s| s.as_str()).unwrap_or("?").to_string();
    }
    let resp = simple(&mut client, &proto::Request::Result(id))?;
    if raw {
        println!("{}", resp.to_string_compact());
        return Ok(());
    }
    println!("job {id}: {state}");
    if let Some(results) = resp.get("results").and_then(|r| r.as_arr()) {
        for r in results {
            let gets = |k: &str| r.get(k).and_then(|v| v.as_str()).unwrap_or("?").to_string();
            let getf = |k: &str| r.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
            println!(
                "  {:<6} correct={} speedup {:.3}x ({:.4} ms vs baseline {:.4} ms) by {}{}",
                gets("device"),
                r.get("correct").and_then(|v| v.as_bool()).unwrap_or(false),
                getf("speedup"),
                getf("time_ms"),
                getf("baseline_ms"),
                gets("produced_by"),
                if r.get("cached").and_then(|v| v.as_bool()).unwrap_or(false) {
                    " [cached]"
                } else {
                    ""
                },
            );
        }
    }
    if let Some(errors) = resp.get("errors").and_then(|e| e.as_arr()) {
        for e in errors {
            println!(
                "  {:<6} FAILED: {}",
                e.get("device").and_then(|v| v.as_str()).unwrap_or("?"),
                e.get("error").and_then(|v| v.as_str()).unwrap_or("?")
            );
        }
    }
    Ok(())
}

fn cmd_metrics(args: &[String]) -> Result<(), String> {
    let cmd = Command::new("metrics", "fetch a daemon's metrics snapshot")
        .opt("addr", "127.0.0.1:7341", "daemon address")
        .opt("scope", "", "restrict to one registry: service | global ('' = merged)")
        .flag("prometheus", "print the Prometheus text exposition instead of JSON")
        .flag("json", "print the raw compact JSON response");
    let p = with_log_flags(cmd).parse(args)?;
    apply_log_flags(&p);
    let scope = match p.get("scope").filter(|s| !s.is_empty()) {
        None => None,
        Some(s @ ("service" | "global")) => Some(s.to_string()),
        Some(other) => return Err(format!("bad --scope '{other}' (service | global)")),
    };
    let addr = p.get("addr").unwrap();
    let mut client =
        Client::connect(addr).map_err(|e| format!("connecting to daemon at {addr}: {e}"))?;
    let resp = client
        .request(&proto::Request::Metrics(scope))
        .map_err(|e| e.to_string())?;
    if !proto::response_ok(&resp) {
        return Err(format!(
            "metrics request failed: {}",
            resp.get("error").and_then(|e| e.as_str()).unwrap_or("unknown error")
        ));
    }
    if p.has_flag("prometheus") {
        print!(
            "{}",
            resp.get("prometheus").and_then(|v| v.as_str()).unwrap_or("")
        );
    } else if p.has_flag("json") {
        println!("{}", resp.to_string_compact());
    } else {
        println!("{}", resp.to_string_pretty());
    }
    Ok(())
}

fn cmd_watch(args: &[String]) -> Result<(), String> {
    let about = "live dashboard over a daemon's streaming watch RPC";
    let cmd = Command::new("watch", about)
        .opt("addr", "127.0.0.1:7341", "daemon address")
        .opt("interval", "1s", "metrics-frame cadence, e.g. 250ms | 1s | 1m")
        .opt("frames", "0", "exit after N metrics frames (0 = stream until interrupted)")
        .flag("plain", "line-stream mode: one compact JSON frame per line, no dashboard");
    let p = with_log_flags(cmd).parse(args)?;
    apply_log_flags(&p);
    let interval_ms =
        parse_duration_ms(p.get("interval").unwrap()).map_err(|e| format!("--interval: {e}"))?;
    let max_frames = p.get_usize("frames").unwrap_or(0);
    let plain = p.has_flag("plain");
    let addr = p.get("addr").unwrap();
    let mut client =
        Client::connect(addr).map_err(|e| format!("connecting to daemon at {addr}: {e}"))?;
    client
        .send(&proto::Request::Watch(interval_ms as u64))
        .map_err(|e| e.to_string())?;

    let mut rules: Vec<String> = Vec::new();
    let mut recent: std::collections::VecDeque<String> = std::collections::VecDeque::new();
    let mut metrics_frames = 0usize;
    loop {
        let Some(frame) = client.next_frame().map_err(|e| e.to_string())? else {
            if !plain {
                println!("stream closed by daemon");
            }
            return Ok(());
        };
        if plain {
            println!("{}", frame.to_string_compact());
        }
        match frame.get("kind").and_then(|k| k.as_str()) {
            Some("hello") => {
                if !proto::response_ok(&frame) {
                    return Err(format!(
                        "watch rejected: {}",
                        frame.get("error").and_then(|e| e.as_str()).unwrap_or("unknown error")
                    ));
                }
                rules = frame
                    .get("alert_rules")
                    .and_then(|r| r.as_arr())
                    .map(|arr| {
                        arr.iter()
                            .filter_map(|v| v.as_str())
                            .map(String::from)
                            .collect()
                    })
                    .unwrap_or_default();
            }
            Some("metrics") => {
                metrics_frames += 1;
                if !plain {
                    render_dashboard(addr, &frame, &rules, &recent, metrics_frames);
                }
                if max_frames > 0 && metrics_frames >= max_frames {
                    return Ok(());
                }
            }
            Some("trace") => {
                push_recent(
                    &mut recent,
                    format!(
                        "[trace] job {} {} {}",
                        frame.get("job").and_then(|v| v.as_usize()).unwrap_or(0),
                        frame.get("t").and_then(|v| v.as_str()).unwrap_or("?"),
                        frame.get("device").and_then(|v| v.as_str()).unwrap_or("-"),
                    ),
                );
            }
            Some("alert") => {
                push_recent(
                    &mut recent,
                    format!(
                        "[ALERT] {} {} ({} {} {}, value {:.3})",
                        frame.get("rule").and_then(|v| v.as_str()).unwrap_or("?"),
                        frame.get("state").and_then(|v| v.as_str()).unwrap_or("?"),
                        frame.get("metric").and_then(|v| v.as_str()).unwrap_or("?"),
                        frame.get("op").and_then(|v| v.as_str()).unwrap_or("?"),
                        frame.get("threshold").and_then(|v| v.as_f64()).unwrap_or(0.0),
                        frame.get("value").and_then(|v| v.as_f64()).unwrap_or(0.0),
                    ),
                );
            }
            _ => {}
        }
    }
}

/// Keep the rolling "recent events" strip bounded.
fn push_recent(recent: &mut std::collections::VecDeque<String>, line: String) {
    recent.push_back(line);
    while recent.len() > 10 {
        recent.pop_front();
    }
}

/// Redraw the single-screen `watch` dashboard from one metrics frame.
fn render_dashboard(
    addr: &str,
    frame: &Json,
    rules: &[String],
    recent: &std::collections::VecDeque<String>,
    n: usize,
) {
    let dt = frame.get("dt_ms").and_then(|v| v.as_f64()).unwrap_or(0.0);
    // Clear screen + home: a stable single-screen view, not a scroll.
    print!("\x1b[2J\x1b[H");
    println!("kernelfoundry watch — {addr}   frame {n}   window {dt:.0} ms");
    if rules.is_empty() {
        println!("alert rules: (none — start the daemon with --alert-rules/--alert-log)");
    } else {
        println!("alert rules: {}", rules.join(", "));
    }
    let section = |title: &str, key: &str| {
        if let Some(map) = frame.get(key).and_then(|v| v.as_obj()) {
            if !map.is_empty() {
                println!("\n{title}");
                for (name, v) in map {
                    if key == "windows" {
                        let g = |k: &str| v.get(k).and_then(|x| x.as_f64()).unwrap_or(0.0);
                        println!(
                            "  {:<42} n={:<5} p50 {:>8.2}  p90 {:>8.2}  p99 {:>8.2}",
                            name,
                            g("count"),
                            g("p50"),
                            g("p90"),
                            g("p99")
                        );
                    } else {
                        println!("  {:<42} {:>12.3}", name, v.as_f64().unwrap_or(0.0));
                    }
                }
            }
        }
    };
    section("derived", "derived");
    section("gauges", "gauges");
    section("counter rates (/s)", "rates");
    section("windowed latencies (ms)", "windows");
    if !recent.is_empty() {
        println!("\nrecent events");
        for line in recent {
            println!("  {line}");
        }
    }
    use std::io::Write as _;
    std::io::stdout().flush().ok();
}

fn cmd_trace(args: &[String]) -> Result<(), String> {
    let cmd = Command::new("trace", "reconstruct a job's lifecycle timeline from a trace sink")
        .opt("sink", "trace.jsonl", "trace sink path (the daemon's --trace file)")
        .opt("job", "", "job id (alternative to the positional argument)")
        .flag("follow", "tail mode: keep polling the sink, exit on the terminal event")
        .flag("json", "machine-readable output (one array; one object per line with --follow)");
    let p = with_log_flags(cmd).parse(args)?;
    apply_log_flags(&p);
    let job_id = match (p.positional.first(), p.get("job").filter(|s| !s.is_empty())) {
        (Some(pos), _) => pos
            .parse::<u64>()
            .map_err(|_| format!("job id '{pos}' is not a number"))?,
        (None, Some(opt)) => opt
            .parse::<u64>()
            .map_err(|_| format!("job id '{opt}' is not a number"))?,
        (None, None) => return Err("usage: kernelfoundry trace <job-id> --sink <path>".into()),
    };
    let sink = Path::new(p.get("sink").unwrap());
    if p.has_flag("follow") {
        return trace_follow(sink, job_id, p.has_flag("json"));
    }
    if !sink.exists() {
        return Err(format!(
            "trace sink {} does not exist (start the daemon with --trace <path>)",
            sink.display()
        ));
    }
    let timeline = kernelfoundry::obs::TraceSink::timeline(sink, job_id);
    if timeline.is_empty() {
        return Err(format!("no events for job {job_id} in {}", sink.display()));
    }
    if p.has_flag("json") {
        let arr: Vec<Json> = timeline.iter().map(|e| e.to_json()).collect();
        println!("{}", Json::Arr(arr).to_string_compact());
        return Ok(());
    }
    println!(
        "job {job_id} (trace {}) — {} events",
        timeline[0].trace_id,
        timeline.len()
    );
    let t0 = timeline[0].ts_ms;
    let mut prev = t0;
    for ev in &timeline {
        println!(
            "  +{:>9.1} ms  {:<10} {:<8} (+{:.1} ms)",
            ev.ts_ms - t0,
            ev.stage,
            ev.device.as_deref().unwrap_or("-"),
            ev.ts_ms - prev,
        );
        prev = ev.ts_ms;
    }
    println!("total: {:.1} ms submit -> {}", prev - t0, timeline.last().unwrap().stage);
    Ok(())
}

/// `trace --follow`: re-poll the sink (the tolerant JSONL reader means
/// a torn final line from the live daemon never aborts the tail),
/// print events as they land, exit once the job reaches a terminal
/// stage (`responded` / `failed` / `cancelled`).
fn trace_follow(sink: &Path, job_id: u64, json: bool) -> Result<(), String> {
    use kernelfoundry::obs::stage;
    let mut printed = 0usize;
    let mut t0 = 0.0;
    let mut prev = 0.0;
    loop {
        let timeline = if sink.exists() {
            kernelfoundry::obs::TraceSink::timeline(sink, job_id)
        } else {
            Vec::new()
        };
        for ev in &timeline[printed.min(timeline.len())..] {
            if printed == 0 {
                t0 = ev.ts_ms;
                prev = ev.ts_ms;
                if !json {
                    println!(
                        "job {job_id} (trace {}) — following {}",
                        ev.trace_id,
                        sink.display()
                    );
                }
            }
            if json {
                println!("{}", ev.to_json().to_string_compact());
            } else {
                println!(
                    "  +{:>9.1} ms  {:<10} {:<8} (+{:.1} ms)",
                    ev.ts_ms - t0,
                    ev.stage,
                    ev.device.as_deref().unwrap_or("-"),
                    ev.ts_ms - prev,
                );
            }
            prev = ev.ts_ms;
            printed += 1;
        }
        let terminal = timeline.last().is_some_and(|last| {
            matches!(
                last.stage.as_str(),
                stage::RESPONDED | stage::FAILED | stage::CANCELLED
            )
        });
        if terminal {
            if !json {
                let last = timeline.last().unwrap();
                println!("total: {:.1} ms submit -> {}", prev - t0, last.stage);
            }
            return Ok(());
        }
        std::thread::sleep(std::time::Duration::from_millis(200));
    }
}

fn cmd_tasks(args: &[String]) -> Result<(), String> {
    let cmd = Command::new("tasks", "list benchmark tasks")
        .opt("suite", "all", "l1 | l2 | rkb | onednn | custom | all")
        .flag("json", "machine-readable output (one JSON array)");
    let p = with_log_flags(cmd).parse(args)?;
    apply_log_flags(&p);
    let tasks = match p.get("suite").unwrap() {
        "l1" => catalog::kernelbench_l1(),
        "l2" => catalog::kernelbench_l2(),
        "rkb" => catalog::robust_kbench(),
        "onednn" => catalog::onednn_tasks(),
        "custom" => vec![catalog::llama_rope_task()],
        _ => catalog::all_tasks(),
    };
    if p.has_flag("json") {
        let arr: Vec<Json> = tasks.iter().map(|t| t.to_json()).collect();
        println!("{}", Json::Arr(arr).to_string_compact());
        return Ok(());
    }
    println!("{:<55} {:>6} {:>14} {:>12}", "task", "ops", "flops", "suite");
    for t in &tasks {
        println!(
            "{:<55} {:>6} {:>14} {:>12}",
            t.id,
            t.n_ops(),
            t.total_flops(),
            t.suite.name()
        );
    }
    println!("({} tasks)", tasks.len());
    Ok(())
}

fn cmd_report(args: &[String]) -> Result<(), String> {
    let about = "analytics over run artifacts: summary, HTML dashboard, regression gate";
    let cmd = Command::new("report", about)
        .opt("db", "runs.jsonl", "JSONL results database path")
        .opt("baseline", "", "baseline database (`report regressions` only)")
        .opt("method", "kernelfoundry", "method to summarize")
        .opt("top", "0", "show only the N best tasks by speedup (0 = all)")
        .opt("device", "", "keep only rows that ran on this device")
        .opt("suite", "", "keep only tasks of one suite: l1 | l2 | rkb | onednn | custom")
        .opt("trace", "", "job-lifecycle trace sink (adds the latency breakdown)")
        .opt("journal", "", "write-ahead job journal (adds the reliability view)")
        .opt("search-log", "", "per-generation search history (adds the search-health view)")
        .opt("alert-log", "", "SLO alert-transition log (adds the alert timeline)")
        .opt("html", "", "write the self-contained HTML dashboard to this path")
        .opt("max-speedup-drop", "0.10", "regression tolerance, fraction of baseline speedup")
        .flag("allow-missing", "baseline keys absent from the current database do not regress")
        .flag("json", "machine-readable output (one JSON array)");
    let p = with_log_flags(cmd).parse(args)?;
    apply_log_flags(&p);

    let filter = report::RowFilter {
        device: p.get("device").filter(|s| !s.is_empty()).map(String::from),
        suite: p
            .get("suite")
            .filter(|s| !s.is_empty())
            .map(report::views::canonical_suite),
    };
    if p.positional.first().map(String::as_str) == Some("regressions") {
        return report_regressions(&p, &filter);
    }

    let opt_path = |k: &str| p.get(k).filter(|s| !s.is_empty()).map(PathBuf::from);
    let db_path = PathBuf::from(p.get("db").unwrap());
    let trace = opt_path("trace");
    let journal = opt_path("journal");
    let search = opt_path("search-log");
    let alerts = opt_path("alert-log");
    let mut artifacts = report::Artifacts::load(
        Some(&db_path),
        trace.as_deref(),
        journal.as_deref(),
        search.as_deref(),
        alerts.as_deref(),
    )?;
    let n = artifacts.rows.len();
    artifacts.rows.retain(|r| filter.matches(r));

    if let Some(out) = opt_path("html") {
        let html = report::html::render(&artifacts, journal.is_some());
        std::fs::write(&out, &html).map_err(|e| format!("writing {}: {e}", out.display()))?;
        println!("dashboard: {} ({} bytes, self-contained)", out.display(), html.len());
        return Ok(());
    }

    let db = Database::new();
    for row in &artifacts.rows {
        db.insert(row.clone());
    }
    let mut best: Vec<DbRow> = db.best_per_task(p.get("method").unwrap());
    let top = p.get_usize("top").unwrap_or(0);
    if top > 0 {
        // total_cmp: NaN speedups sort deterministically to the bottom
        // instead of leaving the order to chance.
        best.sort_by(|a, b| b.speedup.total_cmp(&a.speedup));
        best.truncate(top);
    }
    if p.has_flag("json") {
        let arr: Vec<Json> = best.iter().map(|r| r.to_json()).collect();
        println!("{}", Json::Arr(arr).to_string_compact());
        return Ok(());
    }
    println!("loaded {n} rows ({} after filters)", artifacts.rows.len());
    for row in &best {
        println!(
            "{:<55} fitness {:.3} speedup {:.3} cell {:?} by {}",
            row.task_id, row.fitness, row.speedup, row.coords, row.produced_by
        );
    }
    if trace.is_some() {
        let lat = report::LatencyView::build(&artifacts.events);
        println!("\nlatency breakdown ({} trace events):", artifacts.events.len());
        if lat.lanes.is_empty() {
            println!("  (no closed stage segments)");
        }
        for l in &lat.lanes {
            println!(
                "  {:<8} {:<12} n={:<4} p50 {:>8.1} ms  p90 {:>8.1} ms  p99 {:>8.1} ms",
                l.device, l.segment, l.n, l.p50, l.p90, l.p99
            );
        }
    }
    if journal.is_some() {
        let rel = report::ReliabilityView::build(&artifacts.journal);
        println!("\nreliability ({} journal records):", artifacts.journal.len());
        println!(
            "  submits {}  dispatches {}  commits {}  fails {}  cancelled {}",
            rel.submits, rel.dispatches, rel.commits, rel.fails, rel.cancelled_units
        );
        println!(
            "  crash-replays {}  lost units {}  sessions {} (unclean {})  lease takeovers {}",
            rel.replayed_dispatches,
            rel.lost_units,
            rel.sessions,
            rel.unclean_sessions(),
            rel.lease_takeovers
        );
    }
    if search.is_some() {
        use kernelfoundry::report::views::SearchRunCurve;
        let health = report::SearchHealthView::build(&artifacts.search);
        println!("\nsearch health ({} runs):", health.runs.len());
        for run in &health.runs {
            println!(
                "  {:<50} gens {:<3} qd {:>7.3}  coverage {:>5.1}%  acceptance {:>5.1}%  best {:.3}x",
                run.run,
                run.generations(),
                SearchRunCurve::final_of(&run.qd_curve),
                SearchRunCurve::final_of(&run.coverage_curve) * 100.0,
                SearchRunCurve::final_of(&run.acceptance_curve) * 100.0,
                SearchRunCurve::final_of(&run.best_speedup_curve),
            );
        }
    }
    if alerts.is_some() {
        println!("\nalert timeline ({} transitions):", artifacts.alerts.len());
        let t0 = artifacts.alerts.first().map(|t| t.ts_ms).unwrap_or(0.0);
        for t in &artifacts.alerts {
            println!(
                "  +{:>9.1} ms  {:<10} {:<24} ({} {} {}, value {:.3})",
                t.ts_ms - t0,
                t.state,
                t.rule,
                t.metric,
                t.op,
                t.threshold,
                t.value,
            );
        }
    }
    Ok(())
}

/// `kernelfoundry report regressions`: compare the current database
/// against a baseline and exit nonzero when any (task, device) best
/// speedup dropped beyond tolerance — the CI gate over real artifacts.
fn report_regressions(p: &Parsed, filter: &report::RowFilter) -> Result<(), String> {
    let baseline_path = p
        .get("baseline")
        .filter(|s| !s.is_empty())
        .ok_or("report regressions needs --baseline <db>")?;
    let load = |path: &str| -> Result<Vec<DbRow>, String> {
        let db = Database::new();
        db.load(Path::new(path)).map_err(|e| e.to_string())?;
        Ok(db.rows())
    };
    let baseline = load(baseline_path)?;
    let current = load(p.get("db").unwrap())?;
    let cfg = report::RegressionConfig {
        max_speedup_drop: p.get_f64("max-speedup-drop").unwrap_or(0.10),
        missing_is_regression: !p.has_flag("allow-missing"),
    };
    let found = report::detect(&baseline, &current, filter, &cfg);
    if p.has_flag("json") {
        let arr: Vec<Json> = found
            .iter()
            .map(|r| {
                let mut o = Json::obj();
                o.set("task_id", r.task_id.as_str())
                    .set("device", r.device.as_str())
                    .set("baseline_speedup", r.baseline_speedup)
                    .set("current_speedup", r.current_speedup)
                    .set("drop_frac", r.drop_frac)
                    .set("missing", r.missing);
                o
            })
            .collect();
        println!("{}", Json::Arr(arr).to_string_compact());
    } else if found.is_empty() {
        println!(
            "no regressions: every (task, device) best is within {:.1}% of baseline",
            cfg.max_speedup_drop * 100.0
        );
    } else {
        println!(
            "{} regression(s) beyond {:.1}% tolerance:",
            found.len(),
            cfg.max_speedup_drop * 100.0
        );
        for r in &found {
            if r.missing {
                println!(
                    "  {:<45} {:<8} baseline {:.3}x -> MISSING",
                    r.task_id, r.device, r.baseline_speedup
                );
            } else {
                println!(
                    "  {:<45} {:<8} baseline {:.3}x -> {:.3}x (-{:.1}%)",
                    r.task_id,
                    r.device,
                    r.baseline_speedup,
                    r.current_speedup,
                    r.drop_frac * 100.0
                );
            }
        }
    }
    if found.is_empty() {
        Ok(())
    } else {
        Err(format!("{} speedup regression(s) detected", found.len()))
    }
}
