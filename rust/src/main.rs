//! KernelFoundry CLI launcher.
//!
//! ```text
//! kernelfoundry run        --task <id> --device b580 --iters 40 [--param-opt]
//! kernelfoundry bench      --table 1|2|3|4|11|fig3  [--out results/]
//! kernelfoundry serve      --compile-workers N --exec-workers M (distributed demo)
//! kernelfoundry tasks      [--suite l1|l2|rkb|onednn]
//! kernelfoundry report     --db runs.jsonl
//! ```

use kernelfoundry::config::FoundryConfig;
use kernelfoundry::coordinator::EvolutionEngine;
use kernelfoundry::dist::{ClusterConfig, Database, DbRow, WorkerPool};
use kernelfoundry::eval::ExecBackend;
use kernelfoundry::experiments::{self, ExperimentScale};
use kernelfoundry::hwsim::DeviceProfile;
use kernelfoundry::tasks::catalog;
use kernelfoundry::util::cli::Command;
use std::path::Path;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((sub, rest)) = args.split_first() else {
        print_usage();
        return ExitCode::FAILURE;
    };
    let result = match sub.as_str() {
        "run" => cmd_run(rest),
        "bench" => cmd_bench(rest),
        "serve" => cmd_serve(rest),
        "tasks" => cmd_tasks(rest),
        "report" => cmd_report(rest),
        "--help" | "-h" | "help" => {
            print_usage();
            Ok(())
        }
        other => Err(format!("unknown subcommand '{other}'\n")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(msg) => {
            eprintln!("{msg}");
            ExitCode::FAILURE
        }
    }
}

fn print_usage() {
    println!(
        "kernelfoundry {} — hardware-aware evolutionary GPU kernel optimization (reproduction)\n\n\
         subcommands:\n  run      optimize kernels for one task\n  bench    regenerate a paper table/figure\n  serve    distributed worker-pool demo\n  tasks    list benchmark tasks\n  report   summarize a results database\n\nuse <subcommand> --help for options",
        kernelfoundry::version()
    );
}

fn cmd_run(args: &[String]) -> Result<(), String> {
    let cmd = Command::new("run", "run KernelFoundry on one task")
        .opt("task", "99_Matmul_GELU_Softmax", "task id (see `tasks`)")
        .opt("device", "b580", "device profile: lnl | b580 | a6000")
        .opt("iters", "40", "generations")
        .opt("population", "8", "candidates per generation")
        .opt("seed", "20260710", "RNG seed")
        .opt("models", "gpt-4.1,gpt-5-mini", "ensemble model profiles")
        .opt("config", "", "YAML config file (overrides defaults)")
        .flag("param-opt", "run the templated parameter-optimization phase")
        .flag("cuda", "generate CUDA instead of SYCL")
        .flag("verbose", "debug logging");
    let p = cmd.parse(args)?;
    if p.has_flag("verbose") {
        kernelfoundry::util::log::set_level(kernelfoundry::util::log::Level::Debug);
    }

    let mut config = FoundryConfig::paper_defaults();
    if let Some(path) = p.get("config").filter(|s| !s.is_empty()) {
        let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
        config = FoundryConfig::from_yaml(&text).map_err(|e| e.to_string())?;
    }
    config.evolution.max_generations = p.get_usize("iters").unwrap_or(40);
    config.evolution.population = p.get_usize("population").unwrap_or(8);
    config.seed = p.get_u64("seed").unwrap_or(config.seed);
    config.device = p.get("device").unwrap_or("b580").to_string();
    if p.has_flag("cuda") {
        config.language = "cuda".to_string();
    }
    if let Some(models) = p.get("models") {
        config.llm.models = models.split(',').map(String::from).collect();
    }

    let task_id = p.get("task").unwrap();
    let task = catalog::find_task(task_id).ok_or_else(|| format!("unknown task '{task_id}'"))?;
    let device = DeviceProfile::by_name(&config.device)
        .ok_or_else(|| format!("unknown device '{}'", config.device))?;

    println!(
        "== KernelFoundry: task {} on {} ({} iters x pop {})",
        task.id, device.name, config.evolution.max_generations, config.evolution.population
    );
    let mut engine = EvolutionEngine::new(config, task, ExecBackend::HwSim(device));
    let report = engine.run(p.has_flag("param-opt"));
    println!(
        "evaluations: {} (compile errors {}, incorrect {})",
        report.evaluations, report.compile_errors, report.incorrect
    );
    if let Some(best) = &report.best {
        println!(
            "best kernel: fitness {:.3}, speedup {:.3}x ({:.4} ms vs baseline {:.4} ms), cell {:?}, by {}",
            best.fitness, best.speedup, best.time_ms, best.baseline_ms, best.coords, best.genome.produced_by
        );
        println!("archive: {:?}", report.archive.unwrap());
        println!("\n--- best kernel source ---\n{}", best.source);
    } else {
        println!("no correct kernel found");
    }
    Ok(())
}

fn cmd_bench(args: &[String]) -> Result<(), String> {
    let cmd = Command::new("bench", "regenerate a paper table or figure")
        .opt("table", "1", "which: 1 | 2 | 3 | 4 | 11 | fig3 | all")
        .opt("out", "results", "output directory for CSVs")
        .flag("quick", "reduced-scale run");
    let p = cmd.parse(args)?;
    let scale = if p.has_flag("quick") {
        ExperimentScale::Quick
    } else {
        ExperimentScale::from_env()
    };
    let out_dir = Path::new(p.get("out").unwrap());
    std::fs::create_dir_all(out_dir).map_err(|e| e.to_string())?;
    let which = p.get("table").unwrap();

    let save = |name: &str, csv: &str| {
        let path = out_dir.join(name);
        std::fs::write(&path, csv).ok();
        println!("(per-task CSV: {})", path.display());
    };

    if which == "1" || which == "all" {
        for (i, t) in experiments::table1(scale).iter().enumerate() {
            t.print();
            save(&format!("table1_{}.csv", ["l1", "l2", "rkb"][i]), &t.per_task_csv);
        }
    }
    if which == "2" || which == "all" {
        for (i, t) in experiments::table2(scale).iter().enumerate() {
            t.print();
            save(&format!("table2_{}.csv", ["filtered", "l2"][i]), &t.per_task_csv);
        }
    }
    if which == "3" || which == "all" {
        let r = experiments::run_crossover(scale);
        println!(
            "\n## Table 3 / Table 10 — hardware-awareness crossover\n\n{}",
            r.markdown()
        );
        save("table3_crossover.csv", &r.csv());
    }
    if which == "4" || which == "all" {
        let t = experiments::table4(scale);
        t.print();
        save("table4_onednn.csv", &t.per_task_csv);
    }
    if which == "11" || which == "all" {
        let t = experiments::table11(scale);
        t.print();
        save("table11_gptoss.csv", &t.per_task_csv);
    }
    if which == "fig3" || which == "all" {
        let t = experiments::fig3_series(scale);
        t.print();
        save("fig3_iterations.csv", &t.per_task_csv);
    }
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<(), String> {
    let cmd = Command::new("serve", "distributed worker-pool demo")
        .opt("task", "1_Conv2D_ReLU_BiasAdd", "task id")
        .opt("compile-workers", "2", "compilation workers (no GPU)")
        .opt("exec-workers", "4", "execution workers (one device each)")
        .opt("batch", "32", "candidates per batch")
        .opt("device", "b580", "device profile")
        .opt("db", "runs.jsonl", "JSONL database every evaluation is persisted to ('' = off)");
    let p = cmd.parse(args)?;
    let task = catalog::find_task(p.get("task").unwrap())
        .ok_or_else(|| "unknown task".to_string())?;
    let device = DeviceProfile::by_name(p.get("device").unwrap()).ok_or("unknown device")?;
    // Database server role (Fig. 4 worker type 4). The store is
    // append-only: fold in rows a previous run persisted. Validate the
    // existing file *before* evaluating, so a corrupt database cannot
    // cost the batch (and is never overwritten).
    let db_path = p.get("db").unwrap_or_default().to_string();
    let db = Database::new();
    if !db_path.is_empty() && Path::new(&db_path).exists() {
        db.load(Path::new(&db_path))
            .map_err(|e| format!("existing database not loadable, refusing to overwrite: {e}"))?;
    }
    let pool = WorkerPool::new(ClusterConfig {
        compile_workers: p.get_usize("compile-workers").unwrap_or(2),
        exec_workers: p.get_usize("exec-workers").unwrap_or(4),
        device,
        queue_capacity: 64,
        seed: 1,
    });
    let n = p.get_usize("batch").unwrap_or(32);
    let genomes: Vec<_> = (0..n)
        .map(|i| {
            let mut g = kernelfoundry::ir::KernelGenome::direct_translation(&task.id);
            g.id = i as u64;
            g.mem = kernelfoundry::ir::MemoryPattern::from_level(i % 4);
            g.params.slm_pad = true;
            g
        })
        .collect();
    let start = std::time::Instant::now();
    let records = pool.evaluate_batch(&task, genomes);
    let dt = start.elapsed().as_secs_f64();
    let correct = records.iter().filter(|r| r.correct()).count();
    println!(
        "cluster evaluated {} candidates in {:.2}s ({:.1}/s): {} correct, {} compile-rejected (never reached a GPU worker)",
        records.len(),
        dt,
        records.len() as f64 / dt,
        correct,
        pool.metrics.compile_rejected.load(std::sync::atomic::Ordering::Relaxed),
    );
    if !db_path.is_empty() {
        let idx0 = db.len();
        for (i, rec) in records.iter().enumerate() {
            db.insert(DbRow::from_record("serve", "kernelfoundry", idx0 + i, rec));
        }
        db.save(Path::new(&db_path)).map_err(|e| e.to_string())?;
        println!(
            "database: {} rows -> {db_path} (inspect with `kernelfoundry report --db {db_path}`)",
            db.len()
        );
    }
    Ok(())
}

fn cmd_tasks(args: &[String]) -> Result<(), String> {
    let cmd = Command::new("tasks", "list benchmark tasks")
        .opt("suite", "all", "l1 | l2 | rkb | onednn | custom | all");
    let p = cmd.parse(args)?;
    let tasks = match p.get("suite").unwrap() {
        "l1" => catalog::kernelbench_l1(),
        "l2" => catalog::kernelbench_l2(),
        "rkb" => catalog::robust_kbench(),
        "onednn" => catalog::onednn_tasks(),
        "custom" => vec![catalog::llama_rope_task()],
        _ => catalog::all_tasks(),
    };
    println!("{:<55} {:>6} {:>14} {:>12}", "task", "ops", "flops", "suite");
    for t in &tasks {
        println!(
            "{:<55} {:>6} {:>14} {:>12}",
            t.id,
            t.n_ops(),
            t.total_flops(),
            t.suite.name()
        );
    }
    println!("({} tasks)", tasks.len());
    Ok(())
}

fn cmd_report(args: &[String]) -> Result<(), String> {
    let cmd = Command::new("report", "summarize a results database")
        .opt("db", "runs.jsonl", "JSONL database path")
        .opt("method", "kernelfoundry", "method to summarize");
    let p = cmd.parse(args)?;
    let db = Database::new();
    let n = db
        .load(Path::new(p.get("db").unwrap()))
        .map_err(|e| e.to_string())?;
    println!("loaded {n} rows");
    let best: Vec<DbRow> = db.best_per_task(p.get("method").unwrap());
    for row in &best {
        println!(
            "{:<55} fitness {:.3} speedup {:.3} cell {:?} by {}",
            row.task_id, row.fitness, row.speedup, row.coords, row.produced_by
        );
    }
    Ok(())
}
