//! Evaluation metrics (§4) and table rendering.
//!
//! * correctness rate — fraction of tasks with a compiling, numerically
//!   correct kernel;
//! * fast_p — fraction of tasks with speedup > p;
//! * average and geometric-mean speedup;
//! * hws / hws_p — the §5.3 hardware-speedup metric for the crossover
//!   experiment.

use crate::util::stats;

/// Per-task outcome of one method, the atom of all result tables.
#[derive(Debug, Clone)]
pub struct TaskResult {
    pub task_id: String,
    pub correct: bool,
    /// Speedup over the baseline (0 when no correct kernel).
    pub speedup: f64,
    /// Best kernel runtime, ms.
    pub time_ms: f64,
}

/// Aggregate metrics for a method over a task set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Aggregate {
    pub n: usize,
    pub correct_rate: f64,
    pub fast_1: f64,
    pub fast_2: f64,
    pub avg_speedup: f64,
    pub geom_speedup: f64,
}

/// fast_p: proportion of tasks with speedup strictly greater than p (§4).
pub fn fast_p(results: &[TaskResult], p: f64) -> f64 {
    if results.is_empty() {
        return 0.0;
    }
    results.iter().filter(|r| r.correct && r.speedup > p).count() as f64 / results.len() as f64
}

/// Aggregate a method's per-task results. Averages follow the paper's
/// convention: speedups are averaged over tasks with a correct kernel.
pub fn aggregate(results: &[TaskResult]) -> Aggregate {
    let speeds: Vec<f64> = results
        .iter()
        .filter(|r| r.correct)
        .map(|r| r.speedup)
        .collect();
    Aggregate {
        n: results.len(),
        correct_rate: if results.is_empty() {
            0.0
        } else {
            results.iter().filter(|r| r.correct).count() as f64 / results.len() as f64
        },
        fast_1: fast_p(results, 1.0),
        fast_2: fast_p(results, 2.0),
        avg_speedup: stats::mean(&speeds),
        geom_speedup: stats::geomean(&speeds),
    }
}

/// §5.3 hardware-speedup: hws(k^A) = t_A(k^B) / t_A(k^A) — how much
/// faster the kernel optimized *for* device A runs on A than the kernel
/// optimized on B does.
pub fn hws(time_native_ms: f64, time_foreign_ms: f64) -> f64 {
    if time_native_ms <= 0.0 {
        return 0.0;
    }
    time_foreign_ms / time_native_ms
}

/// Aggregate hws over tasks: (hws_1, hws_1.5, avg, geom).
#[derive(Debug, Clone, Copy)]
pub struct HwsAggregate {
    pub hws_1: f64,
    pub hws_15: f64,
    pub avg: f64,
    pub geom: f64,
}

pub fn aggregate_hws(values: &[f64]) -> HwsAggregate {
    let n = values.len().max(1) as f64;
    HwsAggregate {
        hws_1: values.iter().filter(|v| **v > 1.0).count() as f64 / n,
        hws_15: values.iter().filter(|v| **v > 1.5).count() as f64 / n,
        avg: stats::mean(values),
        geom: stats::geomean(values),
    }
}

/// Render a markdown table (paper-style rows).
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    out.push_str("| ");
    out.push_str(&headers.join(" | "));
    out.push_str(" |\n|");
    for _ in headers {
        out.push_str("---|");
    }
    out.push('\n');
    for row in rows {
        out.push_str("| ");
        out.push_str(&row.join(" | "));
        out.push_str(" |\n");
    }
    out
}

/// Render a CSV document.
pub fn render_csv(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    out.push_str(&headers.join(","));
    out.push('\n');
    for row in rows {
        out.push_str(&row.join(","));
        out.push('\n');
    }
    out
}

/// Format an aggregate as a paper-style table row.
pub fn aggregate_row(label: &str, llms: &str, agg: &Aggregate) -> Vec<String> {
    vec![
        label.to_string(),
        llms.to_string(),
        format!("{:.2}", agg.correct_rate),
        format!("{:.0} %", agg.fast_1 * 100.0),
        format!("{:.0} %", agg.fast_2 * 100.0),
        format!("{:.3}", agg.avg_speedup),
        format!("{:.3}", agg.geom_speedup),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn r(id: &str, correct: bool, speedup: f64) -> TaskResult {
        TaskResult {
            task_id: id.to_string(),
            correct,
            speedup,
            time_ms: 1.0,
        }
    }

    #[test]
    fn fast_p_counts_strictly_greater() {
        let rs = vec![r("a", true, 1.0), r("b", true, 1.01), r("c", true, 2.5), r("d", false, 9.0)];
        assert_eq!(fast_p(&rs, 1.0), 0.5); // b and c
        assert_eq!(fast_p(&rs, 2.0), 0.25); // c only; incorrect d never counts
    }

    #[test]
    fn aggregate_matches_hand_computation() {
        let rs = vec![r("a", true, 1.0), r("b", true, 4.0), r("c", false, 0.0)];
        let a = aggregate(&rs);
        assert_eq!(a.n, 3);
        assert!((a.correct_rate - 2.0 / 3.0).abs() < 1e-12);
        assert!((a.avg_speedup - 2.5).abs() < 1e-12);
        assert!((a.geom_speedup - 2.0).abs() < 1e-12);
    }

    #[test]
    fn hws_definition() {
        // Native kernel 1 ms, foreign 1.5 ms → hws = 1.5.
        assert!((hws(1.0, 1.5) - 1.5).abs() < 1e-12);
        let agg = aggregate_hws(&[1.5, 0.9, 2.0, 1.2]);
        assert_eq!(agg.hws_1, 0.75);
        assert_eq!(agg.hws_15, 0.25); // strictly greater than 1.5
        assert!((agg.avg - 1.4).abs() < 1e-12);
    }

    #[test]
    fn tables_render() {
        let md = render_table(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | 2 |"));
        let csv = render_csv(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        assert_eq!(csv, "a,b\n1,2\n");
    }

    #[test]
    fn empty_inputs_safe() {
        let a = aggregate(&[]);
        assert_eq!(a.n, 0);
        assert_eq!(a.correct_rate, 0.0);
        assert_eq!(fast_p(&[], 1.0), 0.0);
    }
}
