//! Declarative SLO rules with a debounced alert state machine.
//!
//! A rule states the *healthy* condition (`queue_wait_p99_ms < 500`);
//! the rule **breaches** while that condition is false. Each rule walks
//! an ok → pending → firing → resolved state machine: a breach first
//! parks the rule in `pending`, and only a breach sustained for the
//! rule's `for`-duration promotes it to `firing` (debounce); the first
//! healthy evaluation of a firing rule emits `resolved`. Only the
//! `firing`/`resolved` edges are externally visible — appended to the
//! JSONL alert log, mirrored into the trace sink and published to
//! `watch` streams — so per rule they strictly alternate, the invariant
//! `scripts/check_alerts.py` enforces in CI.
//!
//! Rules load from a zero-dep text file (one rule per line):
//!
//! ```text
//! # name: metric op threshold [for duration]
//! queue-slo: queue_wait_p99_ms < 500 for 2s
//! cache_hit_rate > 0.2
//! lost_jobs == 0
//! ```
//!
//! The engine itself is pure — [`AlertEngine::eval`] takes a metric
//! lookup closure and an explicit clock — so the debounce behaviour is
//! property-testable with a fake clock (`tests/obs_props.rs`).

use crate::dist::load_jsonl_tolerant;
use crate::util::cli::parse_duration_ms;
use crate::util::json::Json;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Comparison operator of a rule's healthy condition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmpOp {
    /// `<`
    Lt,
    /// `<=`
    Le,
    /// `>`
    Gt,
    /// `>=`
    Ge,
    /// `==`
    Eq,
    /// `!=`
    Ne,
}

impl CmpOp {
    /// Parse the operator token; `None` for anything else.
    pub fn parse(tok: &str) -> Option<CmpOp> {
        match tok {
            "<" => Some(CmpOp::Lt),
            "<=" => Some(CmpOp::Le),
            ">" => Some(CmpOp::Gt),
            ">=" => Some(CmpOp::Ge),
            "==" => Some(CmpOp::Eq),
            "!=" => Some(CmpOp::Ne),
            _ => None,
        }
    }

    /// The operator's source token.
    pub fn name(&self) -> &'static str {
        match self {
            CmpOp::Lt => "<",
            CmpOp::Le => "<=",
            CmpOp::Gt => ">",
            CmpOp::Ge => ">=",
            CmpOp::Eq => "==",
            CmpOp::Ne => "!=",
        }
    }

    /// Is `value op threshold` true (the rule healthy)?
    pub fn eval(&self, value: f64, threshold: f64) -> bool {
        match self {
            CmpOp::Lt => value < threshold,
            CmpOp::Le => value <= threshold,
            CmpOp::Gt => value > threshold,
            CmpOp::Ge => value >= threshold,
            CmpOp::Eq => value == threshold,
            CmpOp::Ne => value != threshold,
        }
    }
}

/// One SLO rule: healthy while `metric op threshold` holds; fires after
/// breaching continuously for `for_ms`.
#[derive(Debug, Clone, PartialEq)]
pub struct AlertRule {
    /// Rule name (defaults to the metric name).
    pub name: String,
    /// Metric the rule watches (see `obs::window::lookup_metric`).
    pub metric: String,
    /// Healthy-condition operator.
    pub op: CmpOp,
    /// Healthy-condition threshold.
    pub threshold: f64,
    /// Debounce: breach must persist this long before firing (ms).
    pub for_ms: f64,
}

impl AlertRule {
    /// Render back to the rules-file line form.
    pub fn to_line(&self) -> String {
        let op = self.op.name();
        let mut s = format!("{}: {} {op} {}", self.name, self.metric, self.threshold);
        if self.for_ms > 0.0 {
            s.push_str(&format!(" for {}ms", self.for_ms));
        }
        s
    }
}

/// An ordered set of alert rules.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RuleSet {
    /// The rules, in file order.
    pub rules: Vec<AlertRule>,
}

impl RuleSet {
    /// The built-in SLO set used when no rules file is given: queue wait
    /// bounded, cache pulling its weight, no jobs lost to replay, the
    /// search still accepting candidates, every lane breaker closed and
    /// the retry rate bounded. Rules whose metric is not observable yet
    /// (e.g. `cache_hit_rate` before any lookup) simply stay frozen, so
    /// the defaults are safe on an idle daemon.
    pub fn defaults() -> RuleSet {
        let text = "\
queue-wait: queue_wait_p99_ms < 500 for 2s
cache-hit-rate: cache_hit_rate > 0.2 for 10s
lost-jobs: lost_jobs == 0
search-acceptance: search_acceptance > 0.01 for 10s
lane-open: lanes_open == 0
retry-rate: kf_retry_total_rate < 2 for 5s
";
        RuleSet::parse(text).expect("built-in default rules parse")
    }

    /// Parse a rules file body. Blank lines and `#` comments are
    /// skipped; any malformed line is an error naming the line number.
    pub fn parse(text: &str) -> Result<RuleSet, String> {
        let mut rules = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let rule = Self::parse_rule(line)
                .map_err(|e| format!("alert rules line {}: {e}", lineno + 1))?;
            rules.push(rule);
        }
        Ok(RuleSet { rules })
    }

    /// Load rules from `path`.
    pub fn load(path: &Path) -> Result<RuleSet, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("alert rules {}: {e}", path.display()))?;
        RuleSet::parse(&text)
    }

    fn parse_rule(line: &str) -> Result<AlertRule, String> {
        // Optional leading `name:`.
        let (name, rest) = match line.split_once(':') {
            Some((n, r)) if !n.trim().contains(char::is_whitespace) => {
                (Some(n.trim().to_string()), r.trim())
            }
            _ => (None, line),
        };
        let toks: Vec<&str> = rest.split_whitespace().collect();
        match toks.as_slice() {
            [metric, op, threshold] => Self::build(name, metric, op, threshold, None),
            [metric, op, threshold, kw, dur] if *kw == "for" => {
                Self::build(name, metric, op, threshold, Some(dur))
            }
            _ => Err(format!(
                "expected `[name:] metric op threshold [for duration]`, got {line:?}"
            )),
        }
    }

    fn build(
        name: Option<String>,
        metric: &str,
        op: &str,
        threshold: &str,
        dur: Option<&str>,
    ) -> Result<AlertRule, String> {
        let op = CmpOp::parse(op).ok_or_else(|| format!("bad operator {op:?}"))?;
        let threshold = threshold
            .parse::<f64>()
            .map_err(|_| format!("bad threshold {threshold:?}"))?;
        let for_ms = match dur {
            Some(d) => parse_duration_ms(d)?,
            None => 0.0,
        };
        Ok(AlertRule {
            name: name.unwrap_or_else(|| metric.to_string()),
            metric: metric.to_string(),
            op,
            threshold,
            for_ms,
        })
    }
}

/// Internal per-rule state (pending is the debounce window).
#[derive(Debug, Clone, Copy, PartialEq)]
enum RuleState {
    Ok,
    Pending { since: f64 },
    Firing,
}

/// One externally visible alert edge (`firing` or `resolved`).
#[derive(Debug, Clone, PartialEq)]
pub struct AlertTransition {
    /// Rule name.
    pub rule: String,
    /// `"firing"` or `"resolved"`.
    pub state: String,
    /// Metric the rule watches.
    pub metric: String,
    /// Healthy-condition operator token.
    pub op: String,
    /// Healthy-condition threshold.
    pub threshold: f64,
    /// The metric value that drove the edge.
    pub value: f64,
    /// The rule's debounce duration (ms).
    pub for_ms: f64,
    /// Wall-clock Unix ms of the edge.
    pub ts_ms: f64,
}

impl AlertTransition {
    /// Serialize to the on-disk / on-wire JSON object.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("rule", self.rule.as_str())
            .set("state", self.state.as_str())
            .set("metric", self.metric.as_str())
            .set("op", self.op.as_str())
            .set("threshold", self.threshold)
            .set("value", self.value)
            .set("for_ms", self.for_ms)
            .set("ts_ms", self.ts_ms);
        o
    }

    /// Parse one on-disk JSON object; `None` on schema mismatch.
    pub fn from_json(v: &Json) -> Option<AlertTransition> {
        Some(AlertTransition {
            rule: v.get("rule")?.as_str()?.to_string(),
            state: v.get("state")?.as_str()?.to_string(),
            metric: v.get("metric")?.as_str()?.to_string(),
            op: v.get("op")?.as_str()?.to_string(),
            threshold: v.get("threshold")?.as_f64()?,
            value: v.get("value")?.as_f64()?,
            for_ms: v.get("for_ms")?.as_f64()?,
            ts_ms: v.get("ts_ms")?.as_f64()?,
        })
    }
}

/// The debounced state machine over a rule set. Pure: evaluation takes
/// a metric-lookup closure and an explicit `now_ms`, so tests drive it
/// with a fake clock.
#[derive(Debug)]
pub struct AlertEngine {
    rules: Vec<AlertRule>,
    states: Vec<RuleState>,
}

impl AlertEngine {
    /// Engine with every rule starting in `ok`.
    pub fn new(set: RuleSet) -> AlertEngine {
        let states = vec![RuleState::Ok; set.rules.len()];
        AlertEngine {
            rules: set.rules,
            states,
        }
    }

    /// The engine's rules.
    pub fn rules(&self) -> &[AlertRule] {
        &self.rules
    }

    /// Rules currently firing.
    pub fn firing(&self) -> usize {
        self.states.iter().filter(|s| matches!(s, RuleState::Firing)).count()
    }

    /// Evaluate every rule against `lookup` at time `now_ms`, returning
    /// the `firing`/`resolved` edges this tick produced. A rule whose
    /// metric is unobservable (`lookup` returns `None`) keeps its state
    /// frozen — a measurement gap is not a breach.
    pub fn eval(
        &mut self,
        lookup: impl Fn(&str) -> Option<f64>,
        now_ms: f64,
    ) -> Vec<AlertTransition> {
        let mut out = Vec::new();
        for (rule, state) in self.rules.iter().zip(self.states.iter_mut()) {
            let Some(value) = lookup(&rule.metric) else {
                continue;
            };
            let healthy = rule.op.eval(value, rule.threshold);
            let edge = |state: &str| AlertTransition {
                rule: rule.name.clone(),
                state: state.to_string(),
                metric: rule.metric.clone(),
                op: rule.op.name().to_string(),
                threshold: rule.threshold,
                value,
                for_ms: rule.for_ms,
                ts_ms: now_ms,
            };
            *state = match (*state, healthy) {
                (RuleState::Ok, true) => RuleState::Ok,
                (RuleState::Ok, false) if rule.for_ms <= 0.0 => {
                    out.push(edge("firing"));
                    RuleState::Firing
                }
                (RuleState::Ok, false) => RuleState::Pending { since: now_ms },
                (RuleState::Pending { .. }, true) => RuleState::Ok,
                (RuleState::Pending { since }, false) if now_ms - since >= rule.for_ms => {
                    out.push(edge("firing"));
                    RuleState::Firing
                }
                (s @ RuleState::Pending { .. }, false) => s,
                (RuleState::Firing, true) => {
                    out.push(edge("resolved"));
                    RuleState::Ok
                }
                (RuleState::Firing, false) => RuleState::Firing,
            };
        }
        out
    }
}

/// Append-only JSONL alert log (whole-line writes under a mutex, the
/// same torn-tail-tolerant discipline as every JSONL store here).
pub struct AlertLog {
    path: PathBuf,
    file: Mutex<File>,
}

impl AlertLog {
    /// Open (creating if needed) the log at `path` for appending.
    pub fn open(path: &Path) -> std::io::Result<AlertLog> {
        let file = OpenOptions::new().create(true).append(true).open(path)?;
        Ok(AlertLog {
            path: path.to_path_buf(),
            file: Mutex::new(file),
        })
    }

    /// The log's file path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one transition (best-effort: I/O errors are logged, never
    /// propagated into the ticker).
    pub fn append(&self, t: &AlertTransition) {
        let mut line = t.to_json().to_string_compact();
        line.push('\n');
        let mut file = self.file.lock().unwrap();
        if let Err(e) = file.write_all(line.as_bytes()) {
            crate::log_warn!("alert log {}: {e}", self.path.display());
        }
    }

    /// Load every transition from a log file. A missing file is an
    /// empty history; a torn final line is dropped.
    pub fn load(path: &Path) -> Vec<AlertTransition> {
        if !path.exists() {
            return Vec::new();
        }
        match load_jsonl_tolerant(path, AlertTransition::from_json) {
            Ok((events, _)) => events,
            Err(e) => {
                crate::log_warn!("alert log {}: {e}", path.display());
                Vec::new()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn rule(line: &str) -> AlertRule {
        RuleSet::parse(line).unwrap().rules.remove(0)
    }

    #[test]
    fn rules_file_grammar() {
        let set = RuleSet::parse(
            "# comment\n\nqueue-slo: queue_wait_p99_ms < 500 for 2s\ncache_hit_rate > 0.2\nlost_jobs == 0 # trailing comment\n",
        )
        .unwrap();
        assert_eq!(set.rules.len(), 3);
        assert_eq!(set.rules[0].name, "queue-slo");
        assert_eq!(set.rules[0].for_ms, 2_000.0);
        assert_eq!(set.rules[1].name, "cache_hit_rate", "name defaults to metric");
        assert_eq!(set.rules[1].op, CmpOp::Gt);
        assert_eq!(set.rules[2].for_ms, 0.0);

        for bad in ["metric <", "metric ~ 3", "m < x", "m < 1 for soon"] {
            assert!(RuleSet::parse(bad).is_err(), "{bad:?} should not parse");
        }
        assert!(!RuleSet::defaults().rules.is_empty());
    }

    #[test]
    fn debounce_gates_firing() {
        let mut eng = AlertEngine::new(RuleSet::parse("q < 10 for 100ms").unwrap());
        let breach = |_: &str| Some(50.0);
        let healthy = |_: &str| Some(1.0);
        assert!(eng.eval(breach, 0.0).is_empty(), "breach enters pending");
        assert!(eng.eval(breach, 50.0).is_empty(), "still inside debounce");
        // Recovery inside the debounce window resets without any edge.
        assert!(eng.eval(healthy, 60.0).is_empty());
        assert!(eng.eval(breach, 70.0).is_empty());
        let fired = eng.eval(breach, 200.0);
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].state, "firing");
        assert_eq!(eng.firing(), 1);
        assert!(eng.eval(breach, 250.0).is_empty(), "firing is edge-triggered");
        let resolved = eng.eval(healthy, 300.0);
        assert_eq!(resolved.len(), 1);
        assert_eq!(resolved[0].state, "resolved");
        assert_eq!(eng.firing(), 0);
    }

    #[test]
    fn zero_duration_fires_immediately_and_gaps_freeze() {
        let mut eng = AlertEngine::new(RuleSet::parse("lost_jobs == 0").unwrap());
        let mut metrics: BTreeMap<String, f64> = BTreeMap::new();
        assert!(
            eng.eval(|m| metrics.get(m).copied(), 0.0).is_empty(),
            "unobservable metric freezes the rule"
        );
        metrics.insert("lost_jobs".into(), 2.0);
        let fired = eng.eval(|m| metrics.get(m).copied(), 1.0);
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].state, "firing");
        assert_eq!(fired[0].value, 2.0);
        // A gap while firing stays firing (no spurious resolve).
        metrics.clear();
        assert!(eng.eval(|m| metrics.get(m).copied(), 2.0).is_empty());
        assert_eq!(eng.firing(), 1);
    }

    #[test]
    fn transitions_roundtrip_and_log() {
        let t = AlertTransition {
            rule: "queue-slo".into(),
            state: "firing".into(),
            metric: "queue_wait_p99_ms".into(),
            op: "<".into(),
            threshold: 500.0,
            value: 900.0,
            for_ms: 2_000.0,
            ts_ms: 1_234.5,
        };
        assert_eq!(AlertTransition::from_json(&t.to_json()), Some(t.clone()));

        let mut path = std::env::temp_dir();
        path.push(format!("kf_alert_log_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        {
            let log = AlertLog::open(&path).unwrap();
            log.append(&t);
            let mut r = t.clone();
            r.state = "resolved".into();
            log.append(&r);
        }
        let loaded = AlertLog::load(&path);
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded[0].state, "firing");
        assert_eq!(loaded[1].state, "resolved");
        assert!(AlertLog::load(Path::new("/nonexistent/alerts.jsonl")).is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rule_to_line_roundtrips() {
        for line in ["q: queue_wait_p99_ms < 500 for 2000ms", "lost_jobs == 0"] {
            let r = rule(line);
            assert_eq!(rule(&r.to_line()), r);
        }
    }
}
