//! In-process fan-out bus for live `watch` frames.
//!
//! The daemon publishes job-lifecycle trace events and alert
//! transitions as JSON frames; each open `watch` connection subscribes
//! and drains its own mpsc channel. Publishing is fire-and-forget:
//! a subscriber whose receiver is gone (client disconnected) is pruned
//! on the next publish, and with no subscribers a publish is a no-op —
//! the bus never blocks the job path.

use crate::util::json::Json;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;

/// Multi-subscriber broadcast of JSON frames.
#[derive(Debug, Default)]
pub struct EventBus {
    subs: Mutex<Vec<Sender<Json>>>,
}

impl EventBus {
    /// A bus with no subscribers.
    pub fn new() -> EventBus {
        EventBus::default()
    }

    /// Attach a new subscriber; every frame published after this call
    /// is delivered to the returned receiver until it is dropped.
    pub fn subscribe(&self) -> Receiver<Json> {
        let (tx, rx) = channel();
        self.subs.lock().unwrap().push(tx);
        rx
    }

    /// Broadcast one frame to every live subscriber, pruning dead ones.
    pub fn publish(&self, frame: &Json) {
        let mut subs = self.subs.lock().unwrap();
        subs.retain(|tx| tx.send(frame.clone()).is_ok());
    }

    /// Live subscribers as of the last publish.
    pub fn subscriber_count(&self) -> usize {
        self.subs.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fan_out_and_prune() {
        let bus = EventBus::new();
        let mut frame = Json::obj();
        frame.set("kind", "test").set("n", 1usize);
        bus.publish(&frame); // no subscribers: no-op
        let a = bus.subscribe();
        let b = bus.subscribe();
        bus.publish(&frame);
        assert_eq!(a.try_recv().unwrap().get("n").unwrap().as_usize(), Some(1));
        assert_eq!(b.try_recv().unwrap().get("n").unwrap().as_usize(), Some(1));
        drop(a);
        bus.publish(&frame);
        assert_eq!(bus.subscriber_count(), 1, "dead subscriber pruned");
        assert_eq!(b.try_recv().unwrap().get("n").unwrap().as_usize(), Some(1));
    }
}
