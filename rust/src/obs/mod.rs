//! Observability: metrics registry, trace sink, and the live layer on
//! top of them (rolling windows, SLO alerts, watch-frame bus).
//!
//! The service layer (and the search/eval hot paths underneath it) report
//! into two zero-dependency primitives:
//!
//! - [`registry`] — named counters, gauges and log-bucketed latency
//!   histograms (p50/p90/p99 summaries), snapshottable and renderable in
//!   Prometheus text-exposition format. A process-wide default lives
//!   behind [`registry::global`]; components that need isolated numbers
//!   (one [`Registry`] per `KernelService`, so parallel daemons in one
//!   test process don't bleed into each other's `stats`) instantiate
//!   their own.
//! - [`trace`] — an append-only JSONL trace sink, one timestamped stage
//!   event per job-lifecycle transition
//!   (`submit → queued → dispatched → compiled → executed → committed →
//!   responded`), written with the same whole-line-append discipline as
//!   `service::journal` and read back tolerantly (a torn final line is
//!   dropped). `kernelfoundry trace <job-id>` reconstructs a job's
//!   timeline from this file.
//!
//! Three live-observability modules derive from those primitives:
//!
//! - [`window`] — rolling-window stats from snapshot deltas: counter
//!   rates and windowed p50/p90/p99 via histogram bucket deltas.
//! - [`alerts`] — declarative SLO rules with an ok → pending → firing →
//!   resolved debounced state machine and a JSONL alert log.
//! - [`bus`] — in-process fan-out of live frames (trace events, alert
//!   transitions) to open `watch` RPC streams.
//!
//! DESIGN.md §8 documents the metric naming scheme, the trace-event
//! schema and the exposition format; §10 covers the live layer.

pub mod alerts;
pub mod bus;
pub mod registry;
pub mod trace;
pub mod window;

pub use alerts::{AlertEngine, AlertLog, AlertRule, AlertTransition, CmpOp, RuleSet};
pub use bus::EventBus;
pub use registry::{
    bucket_bounds, global, labeled, Counter, Gauge, Histogram, HistogramSnapshot, Registry,
    Snapshot, HIST_BUCKETS,
};
pub use trace::{now_ms, stage, TraceEvent, TraceSink, FLEET_JOB_ID};
pub use window::{DeltaTracker, WindowDelta, WindowedQuantiles};
