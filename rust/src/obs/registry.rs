//! The metrics registry: counters, gauges, log-bucketed histograms.
//!
//! Everything is lock-free on the hot path (atomics behind `Arc` handles;
//! the registry mutexes are only taken on name lookup and snapshot).
//! Snapshots are plain data, merge commutatively, and render to the
//! Prometheus text-exposition format.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// A monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Increment by one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Increment by `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Raise the counter to `v` if it is currently below it (no-op
    /// otherwise) — for mirroring an external monotone counter into the
    /// registry without ever moving backwards under concurrent raises.
    pub fn set_to(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    /// Current value.
    pub fn value(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-write-wins instantaneous value (stored as `f64` bits).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    /// Set the gauge.
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Current value.
    pub fn value(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Number of finite histogram bucket bounds (the last bucket is +Inf).
pub const HIST_BUCKETS: usize = 28;

/// The shared log-spaced bucket upper bounds, in milliseconds:
/// `0.001 * 2^i` for `i in 0..HIST_BUCKETS` (1 µs … ~134 s). Every
/// histogram in the process uses the same bounds so snapshots merge
/// bucket-for-bucket.
pub fn bucket_bounds() -> &'static [f64] {
    static BOUNDS: OnceLock<Vec<f64>> = OnceLock::new();
    BOUNDS.get_or_init(|| (0..HIST_BUCKETS).map(|i| 0.001 * 2f64.powi(i as i32)).collect())
}

/// A log-bucketed latency histogram (milliseconds).
#[derive(Debug)]
pub struct Histogram {
    /// `HIST_BUCKETS` finite buckets plus one overflow (+Inf) bucket.
    buckets: Vec<AtomicU64>,
    /// Sum of observed values, as `f64` bits (CAS-updated).
    sum: AtomicU64,
}

fn add_f64(cell: &AtomicU64, v: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + v).to_bits();
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(actual) => cur = actual,
        }
    }
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            buckets: (0..=HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum: AtomicU64::new(0.0f64.to_bits()),
        }
    }
}

impl Histogram {
    /// Record one observation (negative / non-finite values clamp to 0).
    pub fn observe(&self, v: f64) {
        let v = if v.is_finite() && v >= 0.0 { v } else { 0.0 };
        let idx = bucket_bounds()
            .iter()
            .position(|b| v <= *b)
            .unwrap_or(HIST_BUCKETS);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        add_f64(&self.sum, v);
    }

    /// Point-in-time copy. The observation count is *derived* from the
    /// bucket counts, so `snapshot.count() == sum(snapshot.buckets)` holds
    /// by construction even when readers race writers.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            sum: f64::from_bits(self.sum.load(Ordering::Relaxed)),
        }
    }
}

/// Plain-data copy of one histogram.
#[derive(Debug, Clone, PartialEq)]
pub struct HistogramSnapshot {
    /// Per-bucket counts, aligned with [`bucket_bounds`] plus a final
    /// overflow bucket.
    pub buckets: Vec<u64>,
    /// Sum of all observed values (ms).
    pub sum: f64,
}

impl HistogramSnapshot {
    /// Total observations — always the sum of the bucket counts.
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Quantile estimate: the upper bound of the bucket containing the
    /// `q`-th observation (log-bucket resolution; the overflow bucket
    /// reports the largest finite bound). Returns 0 for an empty
    /// histogram.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let bounds = bucket_bounds();
        let mut cum = 0u64;
        for (i, c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return bounds[i.min(bounds.len() - 1)];
            }
        }
        bounds[bounds.len() - 1]
    }

    /// Bucket-wise commutative merge (`a.merge(b) == b.merge(a)`).
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        for (mine, theirs) in self.buckets.iter_mut().zip(&other.buckets) {
            *mine += theirs;
        }
        self.sum += other.sum;
    }
}

/// A point-in-time copy of a whole registry.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Snapshot {
    /// Counter values by name.
    pub counters: BTreeMap<String, u64>,
    /// Gauge values by name.
    pub gauges: BTreeMap<String, f64>,
    /// Histogram snapshots by name.
    pub histograms: BTreeMap<String, HistogramSnapshot>,
}

impl Snapshot {
    /// Commutative merge: counters and histogram buckets add; a gauge
    /// present on both sides keeps the maximum (the only commutative
    /// choice — in practice merged registries use disjoint gauge names).
    pub fn merge(&mut self, other: &Snapshot) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.gauges {
            let e = self.gauges.entry(k.clone()).or_insert(f64::NEG_INFINITY);
            *e = e.max(*v);
        }
        for (k, h) in &other.histograms {
            match self.histograms.get_mut(k) {
                Some(mine) => mine.merge(h),
                None => {
                    self.histograms.insert(k.clone(), h.clone());
                }
            }
        }
    }

    /// Render in Prometheus text-exposition format: counters and gauges
    /// as single samples, histograms as cumulative `_bucket{le=...}`
    /// series plus `_sum`/`_count` and p50/p90/p99 summary gauges.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let mut last_type_line = String::new();
        let mut type_line = |out: &mut String, name: &str, kind: &str| {
            let base = name.split('{').next().unwrap_or(name);
            let line = format!("# TYPE {base} {kind}\n");
            if line != last_type_line {
                out.push_str(&line);
                last_type_line = line;
            }
        };
        for (name, v) in &self.counters {
            type_line(&mut out, name, "counter");
            out.push_str(&format!("{name} {v}\n"));
        }
        for (name, v) in &self.gauges {
            type_line(&mut out, name, "gauge");
            out.push_str(&format!("{name} {v}\n"));
        }
        let bounds = bucket_bounds();
        for (name, h) in &self.histograms {
            type_line(&mut out, name, "histogram");
            let mut cum = 0u64;
            for (i, c) in h.buckets.iter().enumerate() {
                cum += c;
                if i < bounds.len() {
                    out.push_str(&format!("{name}_bucket{{le=\"{}\"}} {cum}\n", bounds[i]));
                } else {
                    out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {cum}\n"));
                }
            }
            out.push_str(&format!("{name}_sum {}\n", h.sum));
            out.push_str(&format!("{name}_count {}\n", h.count()));
            for (suffix, q) in [("p50", 0.5), ("p90", 0.9), ("p99", 0.99)] {
                out.push_str(&format!("# TYPE {name}_{suffix} gauge\n"));
                out.push_str(&format!("{name}_{suffix} {}\n", h.quantile(q)));
            }
            last_type_line.clear();
        }
        out
    }
}

/// A named collection of metrics. Instantiable (`KernelService` owns one
/// per daemon so `stats` counts stay exact under parallel in-process
/// daemons); a process-wide default lives behind [`global`].
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    /// Fresh, empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get or create the named counter.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.counters.lock().unwrap();
        m.entry(name.to_string()).or_default().clone()
    }

    /// Get or create the named gauge.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.gauges.lock().unwrap();
        m.entry(name.to_string()).or_default().clone()
    }

    /// Get or create the named histogram.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut m = self.histograms.lock().unwrap();
        m.entry(name.to_string()).or_default().clone()
    }

    /// Current value of a counter (0 if it was never touched).
    pub fn counter_value(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.value())
            .unwrap_or(0)
    }

    /// Record one latency observation into the named histogram.
    pub fn observe_ms(&self, name: &str, ms: f64) {
        self.histogram(name).observe(ms);
    }

    /// Point-in-time copy of every metric.
    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            counters: self
                .counters
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), v.value()))
                .collect(),
            gauges: self
                .gauges
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), v.value()))
                .collect(),
            histograms: self
                .histograms
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), v.snapshot()))
                .collect(),
        }
    }
}

/// The process-wide default registry. Components without a service
/// handle (the evolution engine, the eval pipeline, `dist::pool`, the
/// journal) report here; `KernelService::metrics_text` merges this into
/// its per-daemon snapshot.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

/// Attach a Prometheus label: `labeled("kf_lane_units_done_total",
/// "device", "b580")` → `kf_lane_units_done_total{device="b580"}`.
pub fn labeled(name: &str, label: &str, value: &str) -> String {
    format!("{name}{{{label}=\"{value}\"}}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_roundtrip() {
        let r = Registry::new();
        r.counter("c").add(3);
        r.counter("c").inc();
        r.gauge("g").set(2.5);
        assert_eq!(r.counter_value("c"), 4);
        let snap = r.snapshot();
        assert_eq!(snap.counters["c"], 4);
        assert_eq!(snap.gauges["g"], 2.5);
    }

    #[test]
    fn histogram_buckets_sum_to_count() {
        let h = Histogram::default();
        for v in [0.0, 0.0005, 0.13, 7.2, 1e9] {
            h.observe(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count(), 5);
        assert_eq!(s.buckets.iter().sum::<u64>(), 5);
        // 1e9 ms lands in the overflow bucket.
        assert_eq!(s.buckets[HIST_BUCKETS], 1);
    }

    #[test]
    fn quantiles_are_ordered_and_bounded() {
        let h = Histogram::default();
        for i in 0..100 {
            h.observe(i as f64);
        }
        let s = h.snapshot();
        let (p50, p90, p99) = (s.quantile(0.5), s.quantile(0.9), s.quantile(0.99));
        assert!(p50 <= p90 && p90 <= p99, "{p50} {p90} {p99}");
        assert!(p50 >= 32.0 && p50 <= 64.0, "p50 {p50}");
        assert_eq!(s.quantile(0.0), s.quantile(1e-9));
    }

    #[test]
    fn prometheus_exposition_shape() {
        let r = Registry::new();
        r.counter("kf_cache_hits_total").inc();
        r.gauge("kf_queue_depth").set(3.0);
        r.observe_ms("kf_stage_run_ms", 1.5);
        let text = r.snapshot().to_prometheus();
        assert!(text.contains("# TYPE kf_cache_hits_total counter"));
        assert!(text.contains("kf_cache_hits_total 1"));
        assert!(text.contains("# TYPE kf_queue_depth gauge"));
        assert!(text.contains("# TYPE kf_stage_run_ms histogram"));
        assert!(text.contains("kf_stage_run_ms_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("kf_stage_run_ms_count 1"));
        assert!(text.contains("kf_stage_run_ms_p50"));
        assert!(text.contains("kf_stage_run_ms_p99"));
    }

    #[test]
    fn labeled_metrics_share_one_type_line() {
        let r = Registry::new();
        r.counter(&labeled("kf_lane_units_done_total", "device", "b580")).inc();
        r.counter(&labeled("kf_lane_units_done_total", "device", "lnl")).inc();
        let text = r.snapshot().to_prometheus();
        assert_eq!(text.matches("# TYPE kf_lane_units_done_total counter").count(), 1);
        assert!(text.contains("kf_lane_units_done_total{device=\"b580\"} 1"));
    }

    #[test]
    fn snapshot_merge_is_commutative() {
        let a = Registry::new();
        a.counter("c").add(2);
        a.observe_ms("h", 0.5);
        a.gauge("g").set(1.0);
        let b = Registry::new();
        b.counter("c").add(5);
        b.counter("only_b").inc();
        b.observe_ms("h", 40.0);
        b.gauge("g").set(7.0);
        let (sa, sb) = (a.snapshot(), b.snapshot());
        let mut ab = sa.clone();
        ab.merge(&sb);
        let mut ba = sb.clone();
        ba.merge(&sa);
        assert_eq!(ab, ba);
        assert_eq!(ab.counters["c"], 7);
        assert_eq!(ab.histograms["h"].count(), 2);
        assert_eq!(ab.gauges["g"], 7.0);
    }
}
