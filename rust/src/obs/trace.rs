//! Job-lifecycle span tracing: an append-only JSONL trace sink.
//!
//! Every job carries a trace id; each lifecycle transition appends one
//! timestamped stage event to the sink (whole-line writes under a mutex,
//! exactly like `service::journal`, so the file lives safely next to the
//! journal). `kernelfoundry trace <job-id>` reads the file back —
//! tolerantly, dropping a torn final line — and reconstructs the job's
//! timeline with per-stage durations.

use crate::dist::load_jsonl_tolerant;
use crate::obs::bus::EventBus;
use crate::obs::registry::{global, labeled};
use crate::util::json::Json;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{SystemTime, UNIX_EPOCH};

/// Reserved job id for fleet-health events (alert mirrors): real job
/// ids start at 1, so id 0 never collides with a job timeline.
pub const FLEET_JOB_ID: u64 = 0;

/// The canonical lifecycle stage names, in timeline order.
pub mod stage {
    /// Job accepted by the RPC layer.
    pub const SUBMIT: &str = "submit";
    /// Job entered the bounded job queue (cache miss path).
    pub const QUEUED: &str = "queued";
    /// A fleet lane popped the unit for its device.
    pub const DISPATCHED: &str = "dispatched";
    /// Candidate generation + compilation finished; evaluation begins.
    pub const COMPILED: &str = "compiled";
    /// Evaluation finished (the unit has a verdict).
    pub const EXECUTED: &str = "executed";
    /// The verdict was durably committed (journal marker + cache row).
    pub const COMMITTED: &str = "committed";
    /// The finished result was handed to a client.
    pub const RESPONDED: &str = "responded";
    /// A transient unit failure was journalled and the unit re-enqueued
    /// with backoff (the unit is alive; `queued` follows).
    pub const RETRIED: &str = "retried";
    /// A queued unit was moved off a quarantined lane onto a healthy
    /// peer (the `device` field names the lane it *left*).
    pub const REROUTED: &str = "rerouted";
    /// A unit exhausted its retry budget on one lane and was committed
    /// as a deterministic failure verdict (terminal, like `failed`).
    pub const QUARANTINED: &str = "quarantined";
    /// Terminal failure of a unit.
    pub const FAILED: &str = "failed";
    /// Unit(s) cancelled while queued.
    pub const CANCELLED: &str = "cancelled";

    /// Every stage above, in timeline order.
    pub const ALL: &[&str] = &[
        SUBMIT,
        QUEUED,
        DISPATCHED,
        COMPILED,
        EXECUTED,
        COMMITTED,
        RESPONDED,
        RETRIED,
        REROUTED,
        QUARANTINED,
        FAILED,
        CANCELLED,
    ];
}

/// Wall-clock Unix milliseconds (same convention as `service::journal`).
pub fn now_ms() -> f64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs_f64() * 1e3)
        .unwrap_or(0.0)
}

/// One timestamped lifecycle event.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Stage name (one of [`stage::ALL`]).
    pub stage: String,
    /// The job this event belongs to.
    pub job_id: u64,
    /// The job's trace id (stable across all of the job's events).
    pub trace_id: String,
    /// Device lane, when the stage is device-scoped.
    pub device: Option<String>,
    /// Wall-clock Unix milliseconds (monotone non-decreasing per sink).
    pub ts_ms: f64,
}

impl TraceEvent {
    /// Serialize to the on-disk JSON object.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("t", self.stage.as_str())
            .set("job", self.job_id as usize)
            .set("trace", self.trace_id.as_str())
            .set("ts_ms", self.ts_ms);
        if let Some(d) = &self.device {
            o.set("device", d.as_str());
        }
        o
    }

    /// Parse one on-disk JSON object; `None` on schema mismatch.
    pub fn from_json(v: &Json) -> Option<TraceEvent> {
        Some(TraceEvent {
            stage: v.get("t")?.as_str()?.to_string(),
            job_id: v.get("job")?.as_i64()? as u64,
            trace_id: v.get("trace")?.as_str()?.to_string(),
            device: v.get("device").and_then(|d| d.as_str()).map(str::to_string),
            ts_ms: v.get("ts_ms")?.as_f64()?,
        })
    }
}

struct SinkFile {
    file: File,
    /// Clamp for monotone non-decreasing timestamps within one sink.
    last_ts: f64,
}

/// Append-only JSONL trace sink.
///
/// Writes are whole lines under a mutex (create + append), so concurrent
/// lanes never interleave bytes and a crash can tear at most the final
/// line — which [`TraceSink::load`] drops, like every JSONL store in this
/// repo. Emission is best-effort: an I/O error is logged, never
/// propagated into the job path.
pub struct TraceSink {
    path: PathBuf,
    sink: Mutex<SinkFile>,
    ids: Mutex<std::collections::BTreeMap<u64, String>>,
    /// Optional live fan-out: when attached, every job stage event is
    /// also published as a `{"kind":"trace",...}` frame for `watch`.
    bus: OnceLock<Arc<EventBus>>,
}

impl TraceSink {
    /// Open (creating if needed) the sink at `path` for appending.
    pub fn open(path: &Path) -> std::io::Result<TraceSink> {
        let file = OpenOptions::new().create(true).append(true).open(path)?;
        Ok(TraceSink {
            path: path.to_path_buf(),
            sink: Mutex::new(SinkFile { file, last_ts: 0.0 }),
            ids: Mutex::new(std::collections::BTreeMap::new()),
            bus: OnceLock::new(),
        })
    }

    /// Attach a live event bus: from now on every job stage event also
    /// fans out as a `trace` frame. At most one bus per sink; later
    /// attaches are ignored.
    pub fn attach_bus(&self, bus: Arc<EventBus>) {
        let _ = self.bus.set(bus);
    }

    /// The sink's file path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Mint and remember a trace id for a freshly submitted job.
    pub fn register(&self, job_id: u64) -> String {
        let id = format!("{job_id:08x}-{:x}", now_ms() as u64);
        self.ids.lock().unwrap().insert(job_id, id.clone());
        id
    }

    /// The job's trace id — a deterministic fallback for jobs submitted
    /// before this process (journal replay) covers unregistered ids.
    pub fn trace_id(&self, job_id: u64) -> String {
        self.ids
            .lock()
            .unwrap()
            .get(&job_id)
            .cloned()
            .unwrap_or_else(|| format!("{job_id:08x}-replayed"))
    }

    /// Append one stage event for `job_id` (timestamped now) and fan it
    /// out to an attached bus as a `trace` frame.
    pub fn stage(&self, stage: &str, job_id: u64, device: Option<&str>) {
        let trace_id = self.trace_id(job_id);
        let ev = self.emit(stage, job_id, trace_id, device);
        if let Some(bus) = self.bus.get() {
            let mut frame = ev.to_json();
            frame.set("kind", "trace");
            bus.publish(&frame);
        }
    }

    /// Mirror an alert transition into the sink so the trace file keeps
    /// a fleet-health timeline next to the job timelines. The line is a
    /// regular [`TraceEvent`] (tolerant readers need every line to
    /// parse): stage `alert_firing`/`alert_resolved`, the reserved
    /// [`FLEET_JOB_ID`], and the rule name carried in the trace id as
    /// `alert:<rule>`. Not published to the bus — the alert ticker
    /// publishes its own richer `alert` frame.
    pub fn mirror_alert(&self, state: &str, rule: &str) {
        self.emit(&format!("alert_{state}"), FLEET_JOB_ID, format!("alert:{rule}"), None);
    }

    /// Mirror a lane circuit-breaker transition into the sink, exactly
    /// like [`TraceSink::mirror_alert`]: stage `lane_<state>` (e.g.
    /// `lane_open`, `lane_half_open`, `lane_closed`), the reserved
    /// [`FLEET_JOB_ID`], the lane carried both as `lane:<device>` in the
    /// trace id and in the `device` field.
    pub fn mirror_lane(&self, state: &str, device: &str) {
        self.emit(&format!("lane_{state}"), FLEET_JOB_ID, format!("lane:{device}"), Some(device));
    }

    /// Write one event line under the sink mutex (monotone timestamps,
    /// whole-line append) and bump the trace counters.
    fn emit(
        &self,
        stage: &str,
        job_id: u64,
        trace_id: String,
        device: Option<&str>,
    ) -> TraceEvent {
        let mut guard = self.sink.lock().unwrap();
        let ts_ms = now_ms().max(guard.last_ts);
        guard.last_ts = ts_ms;
        let ev = TraceEvent {
            stage: stage.to_string(),
            job_id,
            trace_id,
            device: device.map(str::to_string),
            ts_ms,
        };
        let mut line = ev.to_json().to_string_compact();
        line.push('\n');
        if let Err(e) = guard.file.write_all(line.as_bytes()) {
            crate::log_warn!("trace sink {}: {e}", self.path.display());
        }
        drop(guard);
        global().counter("kf_trace_events_total").inc();
        global().counter(&labeled("kf_trace_stage_total", "stage", stage)).inc();
        ev
    }

    /// Load every event from a sink file. A missing file is an empty
    /// timeline; a torn final line is dropped.
    pub fn load(path: &Path) -> Vec<TraceEvent> {
        if !path.exists() {
            return Vec::new();
        }
        match load_jsonl_tolerant(path, TraceEvent::from_json) {
            Ok((events, _)) => events,
            Err(e) => {
                crate::log_warn!("trace sink {}: {e}", path.display());
                Vec::new()
            }
        }
    }

    /// One job's events in timestamp order (stable on ties, so equal
    /// timestamps keep append order).
    pub fn timeline(path: &Path, job_id: u64) -> Vec<TraceEvent> {
        let mut events: Vec<TraceEvent> = Self::load(path)
            .into_iter()
            .filter(|e| e.job_id == job_id)
            .collect();
        events.sort_by(|a, b| a.ts_ms.partial_cmp(&b.ts_ms).unwrap_or(std::cmp::Ordering::Equal));
        events
    }
}

/// A global fallback used by components that are handed no sink: events
/// are counted in the registry but not persisted.
static NULL_SINK_WARNED: OnceLock<()> = OnceLock::new();

/// Record a stage transition when no sink is configured: registry
/// counters still advance so `metrics` stays truthful, and the first
/// call logs a hint that `--trace` would persist timelines.
pub fn stage_unsunk(stage: &str, _job_id: u64) {
    NULL_SINK_WARNED.get_or_init(|| {
        crate::log_debug!("no trace sink configured; pass --trace to persist job timelines");
    });
    global().counter("kf_trace_events_total").inc();
    global().counter(&labeled("kf_trace_stage_total", "stage", stage)).inc();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("kf_obs_trace_{name}_{}", std::process::id()));
        p
    }

    #[test]
    fn events_roundtrip_and_order() {
        let path = tmp("roundtrip");
        let _ = std::fs::remove_file(&path);
        let sink = TraceSink::open(&path).unwrap();
        let id = sink.register(7);
        sink.stage(stage::SUBMIT, 7, None);
        sink.stage(stage::QUEUED, 7, None);
        sink.stage(stage::DISPATCHED, 7, Some("b580"));
        sink.stage(stage::COMMITTED, 7, Some("b580"));
        sink.stage(stage::SUBMIT, 8, None); // another job interleaved
        let tl = TraceSink::timeline(&path, 7);
        assert_eq!(tl.len(), 4);
        assert!(tl.iter().all(|e| e.trace_id == id));
        assert_eq!(tl[0].stage, stage::SUBMIT);
        assert_eq!(tl[3].stage, stage::COMMITTED);
        assert!(tl.windows(2).all(|w| w[0].ts_ms <= w[1].ts_ms));
        assert_eq!(tl[2].device.as_deref(), Some("b580"));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn bus_frames_and_alert_mirror() {
        let path = tmp("bus");
        let _ = std::fs::remove_file(&path);
        let sink = TraceSink::open(&path).unwrap();
        let bus = Arc::new(EventBus::new());
        sink.attach_bus(bus.clone());
        let rx = bus.subscribe();
        sink.register(3);
        sink.stage(stage::SUBMIT, 3, None);
        let frame = rx.try_recv().unwrap();
        assert_eq!(frame.get("kind").unwrap().as_str(), Some("trace"));
        assert_eq!(frame.get("t").unwrap().as_str(), Some("submit"));
        sink.mirror_alert("firing", "queue-slo");
        assert!(rx.try_recv().is_err(), "alert mirrors don't publish trace frames");
        let events = TraceSink::load(&path);
        assert_eq!(events.len(), 2, "mirror line parses as a TraceEvent");
        assert_eq!(events[1].stage, "alert_firing");
        assert_eq!(events[1].job_id, FLEET_JOB_ID);
        assert_eq!(events[1].trace_id, "alert:queue-slo");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn missing_and_torn_files_load_safely() {
        let path = tmp("torn");
        let _ = std::fs::remove_file(&path);
        assert!(TraceSink::load(&path).is_empty());
        {
            let sink = TraceSink::open(&path).unwrap();
            sink.register(1);
            sink.stage(stage::SUBMIT, 1, None);
        }
        // Tear the tail mid-record, as a crash would.
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("{\"t\":\"queued\",\"job\":1,\"tr");
        std::fs::write(&path, text).unwrap();
        let events = TraceSink::load(&path);
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].stage, stage::SUBMIT);
        let _ = std::fs::remove_file(&path);
    }
}
