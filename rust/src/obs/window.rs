//! Rolling-window derived stats from registry snapshot deltas.
//!
//! The registry's counters and histograms are cumulative: they only ever
//! grow, which is the right shape for durable metrics but the wrong one
//! for a live dashboard ("what is the queue wait *right now*?"). This
//! module turns two successive [`Snapshot`]s into windowed views:
//!
//! * counter **rates** (delta / elapsed seconds);
//! * windowed **p50/p90/p99** from histogram *bucket deltas* — the
//!   bucket-wise difference of two cumulative histograms is itself a
//!   valid [`HistogramSnapshot`] covering only the window, so the
//!   existing quantile walk is reused unchanged;
//! * the latest gauge values (gauges are already instantaneous).
//!
//! [`DeltaTracker`] holds the previous snapshot and produces one
//! [`WindowDelta`] per tick; the daemon's alert ticker and every `watch`
//! stream each own one tracker. Deriving deltas from the commutative
//! snapshot machinery keeps the window views order-independent across
//! merged registries — the property `tests/obs_props.rs` pins.

use crate::obs::registry::{HistogramSnapshot, Snapshot};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Windowed quantile summary of one histogram over one delta window.
#[derive(Debug, Clone, PartialEq)]
pub struct WindowedQuantiles {
    /// Observations that landed inside the window.
    pub count: u64,
    /// Windowed median (bucket upper bound), ms.
    pub p50: f64,
    /// Windowed 90th percentile, ms.
    pub p90: f64,
    /// Windowed 99th percentile, ms.
    pub p99: f64,
}

impl WindowedQuantiles {
    /// Summarize a delta histogram (all zeros when the window is empty).
    pub fn of(delta: &HistogramSnapshot) -> WindowedQuantiles {
        WindowedQuantiles {
            count: delta.count(),
            p50: delta.quantile(0.5),
            p90: delta.quantile(0.9),
            p99: delta.quantile(0.99),
        }
    }

    /// The `{count, p50, p90, p99}` wire object.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("count", self.count as usize)
            .set("p50", self.p50)
            .set("p90", self.p90)
            .set("p99", self.p99);
        o
    }
}

/// Bucket-wise difference `next - prev` of two cumulative histogram
/// snapshots, saturating at zero so a reset or re-merged source can
/// never produce negative counts. The result is a valid snapshot
/// covering only the window, so [`HistogramSnapshot::quantile`] applies
/// unchanged.
pub fn histogram_delta(prev: &HistogramSnapshot, next: &HistogramSnapshot) -> HistogramSnapshot {
    let buckets = next
        .buckets
        .iter()
        .enumerate()
        .map(|(i, n)| n.saturating_sub(prev.buckets.get(i).copied().unwrap_or(0)))
        .collect();
    HistogramSnapshot {
        buckets,
        sum: (next.sum - prev.sum).max(0.0),
    }
}

/// One rolling-window observation: everything that changed between two
/// snapshots, plus the instantaneous gauge values of the later one.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WindowDelta {
    /// Timestamp of the later snapshot (Unix ms).
    pub ts_ms: f64,
    /// Window length in ms (0 on the first tick of a tracker).
    pub dt_ms: f64,
    /// Counter increments inside the window (only counters that moved).
    pub counter_deltas: BTreeMap<String, u64>,
    /// Counter rates per second (0 when `dt_ms` is 0).
    pub rates: BTreeMap<String, f64>,
    /// Latest gauge values.
    pub gauges: BTreeMap<String, f64>,
    /// Windowed quantiles per histogram with activity in the window.
    pub windows: BTreeMap<String, WindowedQuantiles>,
}

impl WindowDelta {
    /// Compute the delta between two timestamped snapshots.
    pub fn between(prev: &Snapshot, next: &Snapshot, prev_ts: f64, next_ts: f64) -> WindowDelta {
        let dt_ms = (next_ts - prev_ts).max(0.0);
        let dt_s = dt_ms / 1000.0;
        let mut counter_deltas = BTreeMap::new();
        let mut rates = BTreeMap::new();
        for (name, value) in &next.counters {
            let before = prev.counters.get(name).copied().unwrap_or(0);
            let delta = value.saturating_sub(before);
            if delta > 0 {
                counter_deltas.insert(name.clone(), delta);
                rates.insert(name.clone(), if dt_s > 0.0 { delta as f64 / dt_s } else { 0.0 });
            }
        }
        let empty = HistogramSnapshot {
            buckets: Vec::new(),
            sum: 0.0,
        };
        let mut windows = BTreeMap::new();
        for (name, hist) in &next.histograms {
            let before = prev.histograms.get(name).unwrap_or(&empty);
            let delta = histogram_delta(before, hist);
            if delta.count() > 0 {
                windows.insert(name.clone(), WindowedQuantiles::of(&delta));
            }
        }
        WindowDelta {
            ts_ms: next_ts,
            dt_ms,
            counter_deltas,
            rates,
            gauges: next.gauges.clone(),
            windows,
        }
    }

    /// Render as a `watch` stream frame: `{"kind":"metrics", ts_ms,
    /// dt_ms, rates:{}, deltas:{}, gauges:{}, windows:{}, derived:{}}`.
    pub fn to_frame(&self, derived: &BTreeMap<String, f64>) -> Json {
        let map = |m: &BTreeMap<String, f64>| {
            let mut o = Json::obj();
            for (k, v) in m {
                o.set(k, *v);
            }
            o
        };
        let mut deltas = Json::obj();
        for (k, v) in &self.counter_deltas {
            deltas.set(k, *v as usize);
        }
        let mut windows = Json::obj();
        for (k, w) in &self.windows {
            windows.set(k, w.to_json());
        }
        let mut o = Json::obj();
        o.set("kind", "metrics")
            .set("ts_ms", self.ts_ms)
            .set("dt_ms", self.dt_ms)
            .set("rates", map(&self.rates))
            .set("deltas", deltas)
            .set("gauges", map(&self.gauges))
            .set("windows", windows)
            .set("derived", map(derived));
        o
    }
}

/// Stateful delta producer: remembers the previous snapshot and turns
/// each new one into a [`WindowDelta`]. The first tick compares against
/// an empty snapshot with `dt_ms = 0` (cumulative totals as deltas,
/// rates suppressed), so a fresh watcher sees data immediately.
#[derive(Debug, Default)]
pub struct DeltaTracker {
    prev: Option<(f64, Snapshot)>,
}

impl DeltaTracker {
    /// Tracker with no history.
    pub fn new() -> DeltaTracker {
        DeltaTracker::default()
    }

    /// Fold in the next snapshot, producing the window since the last
    /// tick.
    pub fn tick(&mut self, next: Snapshot, now_ms: f64) -> WindowDelta {
        let delta = match &self.prev {
            None => WindowDelta::between(&Snapshot::default(), &next, now_ms, now_ms),
            Some((prev_ts, prev)) => WindowDelta::between(prev, &next, *prev_ts, now_ms),
        };
        self.prev = Some((now_ms, next));
        delta
    }
}

/// Derived SLO metrics computed from a window delta plus the cumulative
/// snapshot behind it — the names the default alert rules reference.
/// A metric whose inputs are absent (e.g. `cache_hit_rate` before any
/// lookup) is omitted rather than invented, so alert rules on it stay
/// frozen instead of flapping on 0/0.
pub fn derived_metrics(delta: &WindowDelta, cumulative: &Snapshot) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    let counter = |name: &str| cumulative.counters.get(name).copied().unwrap_or(0);
    let hits = counter("kf_cache_hits_total");
    let misses = counter("kf_cache_misses_total");
    if hits + misses > 0 {
        out.insert("cache_hit_rate".to_string(), hits as f64 / (hits + misses) as f64);
    }
    // Queue wait: windowed p99 when the window saw samples, else the
    // cumulative p99 (still meaningful early in a run).
    if let Some(w) = delta.windows.get("kf_stage_queued_ms") {
        out.insert("queue_wait_p99_ms".to_string(), w.p99);
    } else if let Some(h) = cumulative.histograms.get("kf_stage_queued_ms") {
        if h.count() > 0 {
            out.insert("queue_wait_p99_ms".to_string(), h.quantile(0.99));
        }
    }
    for (derived, gauge) in [
        ("queue_depth", "kf_queue_depth"),
        ("lost_jobs", "kf_replay_lost_jobs"),
        ("search_acceptance", "kf_search_acceptance_rate"),
        ("lanes_open", "kf_lanes_open"),
    ] {
        if let Some(v) = cumulative.gauges.get(gauge) {
            out.insert(derived.to_string(), *v);
        }
    }
    out
}

/// Resolve one alert-rule metric name against the derived map, the
/// cumulative snapshot and the current window, in that order:
///
/// 1. a derived metric (`queue_wait_p99_ms`, `cache_hit_rate`, ...);
/// 2. a gauge by its registry name;
/// 3. a counter by its registry name (cumulative value);
/// 4. `<histogram>_p50|p90|p99` — windowed quantile (absent when the
///    window saw no samples);
/// 5. `<counter>_rate` — windowed per-second rate.
///
/// `None` means "not observable right now"; the alert engine freezes
/// the rule's state rather than treating the gap as a breach.
pub fn lookup_metric(
    name: &str,
    derived: &BTreeMap<String, f64>,
    delta: &WindowDelta,
    cumulative: &Snapshot,
) -> Option<f64> {
    if let Some(v) = derived.get(name) {
        return Some(*v);
    }
    if let Some(v) = cumulative.gauges.get(name) {
        return Some(*v);
    }
    if let Some(v) = cumulative.counters.get(name) {
        return Some(*v as f64);
    }
    for (suffix, pick) in [("_p50", 0usize), ("_p90", 1), ("_p99", 2)] {
        if let Some(base) = name.strip_suffix(suffix) {
            if let Some(w) = delta.windows.get(base) {
                return Some([w.p50, w.p90, w.p99][pick]);
            }
        }
    }
    if let Some(base) = name.strip_suffix("_rate") {
        if cumulative.counters.contains_key(base) {
            return Some(delta.rates.get(base).copied().unwrap_or(0.0));
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::registry::Registry;

    #[test]
    fn delta_isolates_the_window() {
        let r = Registry::new();
        r.counter("c").add(5);
        r.observe_ms("h", 1.0);
        let mut tracker = DeltaTracker::new();
        let first = tracker.tick(r.snapshot(), 1_000.0);
        assert_eq!(first.dt_ms, 0.0);
        assert_eq!(first.counter_deltas["c"], 5);
        assert_eq!(first.rates["c"], 0.0, "no rate without elapsed time");

        r.counter("c").add(10);
        r.observe_ms("h", 400.0);
        r.gauge("g").set(3.0);
        let second = tracker.tick(r.snapshot(), 3_000.0);
        assert_eq!(second.dt_ms, 2_000.0);
        assert_eq!(second.counter_deltas["c"], 10);
        assert!((second.rates["c"] - 5.0).abs() < 1e-9, "10 in 2s = 5/s");
        assert_eq!(second.gauges["g"], 3.0);
        let w = &second.windows["h"];
        assert_eq!(w.count, 1, "only the window's observation");
        assert!(w.p50 >= 400.0, "windowed median tracks the new sample, got {}", w.p50);

        // An idle window drops out entirely.
        let third = tracker.tick(r.snapshot(), 4_000.0);
        assert!(third.counter_deltas.is_empty());
        assert!(third.windows.is_empty());
    }

    #[test]
    fn histogram_delta_is_the_second_half() {
        let h = crate::obs::Histogram::default();
        for v in [1.0, 2.0] {
            h.observe(v);
        }
        let early = h.snapshot();
        for v in [100.0, 200.0, 300.0] {
            h.observe(v);
        }
        let late = h.snapshot();
        let d = histogram_delta(&early, &late);
        assert_eq!(d.count(), 3);
        assert!((d.sum - 600.0).abs() < 1e-9);
        // Quantiles of the delta ignore the early observations.
        assert!(d.quantile(0.5) >= 100.0);
    }

    #[test]
    fn derived_and_lookup_cover_the_rule_vocabulary() {
        let r = Registry::new();
        r.counter("kf_cache_hits_total").add(1);
        r.counter("kf_cache_misses_total").add(3);
        r.gauge("kf_queue_depth").set(2.0);
        r.gauge("kf_replay_lost_jobs").set(0.0);
        r.observe_ms("kf_stage_queued_ms", 12.0);
        let mut tracker = DeltaTracker::new();
        let delta = tracker.tick(r.snapshot(), 1_000.0);
        let snap = r.snapshot();
        let derived = derived_metrics(&delta, &snap);
        assert!((derived["cache_hit_rate"] - 0.25).abs() < 1e-9);
        assert!(derived["queue_wait_p99_ms"] >= 12.0);
        assert_eq!(derived["queue_depth"], 2.0);
        assert_eq!(derived["lost_jobs"], 0.0);
        assert!(!derived.contains_key("search_acceptance"), "gauge never set");

        let look = |name: &str| lookup_metric(name, &derived, &delta, &snap);
        assert_eq!(look("queue_depth"), Some(2.0));
        assert_eq!(look("kf_cache_misses_total"), Some(3.0));
        assert!(look("kf_stage_queued_ms_p99").unwrap() >= 12.0);
        assert_eq!(look("kf_cache_hits_total_rate"), Some(0.0));
        assert_eq!(look("no_such_metric"), None);
    }

    #[test]
    fn frame_shape_is_stable() {
        let r = Registry::new();
        r.counter("c").add(2);
        let mut tracker = DeltaTracker::new();
        tracker.tick(r.snapshot(), 0.0);
        r.counter("c").add(2);
        let delta = tracker.tick(r.snapshot(), 1_000.0);
        let frame = delta.to_frame(&derived_metrics(&delta, &r.snapshot()));
        assert_eq!(frame.get("kind").unwrap().as_str(), Some("metrics"));
        assert_eq!(frame.get_path("deltas.c").unwrap().as_usize(), Some(2));
        assert_eq!(frame.get_path("rates.c").unwrap().as_f64(), Some(2.0));
        assert!(frame.get("windows").is_some() && frame.get("derived").is_some());
    }
}
