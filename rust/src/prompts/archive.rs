//! Prompt archive (§3.5): evolved prompts live in their own archive with
//! fitness defined by the best kernel performance achieved using each
//! prompt variant.

use super::evolvable::EvolvablePrompt;

/// One archived prompt variant.
#[derive(Debug, Clone)]
pub struct PromptEntry {
    pub id: u64,
    pub prompt: EvolvablePrompt,
    /// Best kernel fitness achieved with this prompt (0 until used).
    pub fitness: f64,
    /// How many generations used this prompt.
    pub uses: usize,
    /// Parent prompt id (None for the seed prompt).
    pub parent: Option<u64>,
}

/// Bounded archive of prompt variants (default capacity 16, Table 6).
#[derive(Debug, Clone)]
pub struct PromptArchive {
    entries: Vec<PromptEntry>,
    capacity: usize,
    next_id: u64,
}

impl PromptArchive {
    pub fn new(capacity: usize) -> PromptArchive {
        let mut a = PromptArchive {
            entries: Vec::new(),
            capacity: capacity.max(1),
            next_id: 0,
        };
        a.add(EvolvablePrompt::default(), None);
        a
    }

    /// Add a prompt variant; evicts the worst (lowest fitness, breaking
    /// ties by fewest uses) when full. Returns the new id.
    pub fn add(&mut self, prompt: EvolvablePrompt, parent: Option<u64>) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        if self.entries.len() >= self.capacity {
            // Never evict the current best.
            let best = self.best_id();
            if let Some((idx, _)) = self
                .entries
                .iter()
                .enumerate()
                .filter(|(_, e)| Some(e.id) != best)
                .min_by(|(_, a), (_, b)| {
                    a.fitness
                        .partial_cmp(&b.fitness)
                        .unwrap()
                        .then(a.uses.cmp(&b.uses))
                })
            {
                self.entries.remove(idx);
            }
        }
        self.entries.push(PromptEntry {
            id,
            prompt,
            fitness: 0.0,
            uses: 0,
            parent,
        });
        id
    }

    /// Credit a prompt with a kernel result (fitness is max over kernels
    /// generated under it).
    pub fn credit(&mut self, id: u64, kernel_fitness: f64) {
        if let Some(e) = self.entries.iter_mut().find(|e| e.id == id) {
            e.fitness = e.fitness.max(kernel_fitness);
        }
    }

    pub fn note_use(&mut self, id: u64) {
        if let Some(e) = self.entries.iter_mut().find(|e| e.id == id) {
            e.uses += 1;
        }
    }

    pub fn get(&self, id: u64) -> Option<&PromptEntry> {
        self.entries.iter().find(|e| e.id == id)
    }

    pub fn best(&self) -> &PromptEntry {
        self.entries
            .iter()
            .max_by(|a, b| a.fitness.partial_cmp(&b.fitness).unwrap())
            .expect("archive never empty")
    }

    fn best_id(&self) -> Option<u64> {
        self.entries
            .iter()
            .max_by(|a, b| a.fitness.partial_cmp(&b.fitness).unwrap())
            .map(|e| e.id)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &PromptEntry> {
        self.entries.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_with_default_prompt() {
        let a = PromptArchive::new(16);
        assert_eq!(a.len(), 1);
        assert_eq!(a.best().fitness, 0.0);
    }

    #[test]
    fn credit_takes_max() {
        let mut a = PromptArchive::new(16);
        let id = a.add(EvolvablePrompt::default(), Some(0));
        a.credit(id, 0.7);
        a.credit(id, 0.5);
        assert_eq!(a.get(id).unwrap().fitness, 0.7);
    }

    #[test]
    fn eviction_spares_best() {
        let mut a = PromptArchive::new(3);
        let b = a.add(EvolvablePrompt::default(), None);
        let c = a.add(EvolvablePrompt::default(), None);
        a.credit(b, 0.9); // best
        a.credit(c, 0.2);
        // Archive full (3 entries); adding evicts the worst non-best.
        let d = a.add(EvolvablePrompt::default(), None);
        assert_eq!(a.len(), 3);
        assert!(a.get(b).is_some(), "best must survive");
        assert!(a.get(d).is_some(), "new entry inserted");
        assert_eq!(a.best().id, b);
    }

    #[test]
    fn uses_tracked() {
        let mut a = PromptArchive::new(4);
        let id = a.best().id;
        a.note_use(id);
        a.note_use(id);
        assert_eq!(a.get(id).unwrap().uses, 2);
    }
}
