//! Main-prompt assembly (App. E.1) and the templated-kernel prompt
//! (App. E.2).

use super::evolvable::EvolvablePrompt;
use crate::eval::EvalRecord;
use crate::ir::KernelGenome;
use crate::tasks::TaskSpec;

/// An assembled prompt: the full text served to the code model, plus the
/// structured context the simulated model consumes (an LLM would parse
/// the same information out of the text — the structured copy avoids a
/// brittle NL parser while the text remains authoritative for the
/// meta-prompter and logs).
#[derive(Debug, Clone)]
pub struct Prompt {
    pub text: String,
    pub task_id: String,
    /// Parent kernel to mutate (None → generate from scratch).
    pub parent: Option<KernelGenome>,
    /// Gradient-derived natural-language mutation hints (§3.3).
    pub hints: Vec<String>,
    /// Current evolvable regions (strategy/pitfall content steers the
    /// model's mutation distribution).
    pub evolvable: EvolvablePrompt,
    /// Console log of the last tested kernel.
    pub last_log: String,
    /// Hardware specification paragraph.
    pub hardware: String,
    /// User instructions from custom tasks (App. C).
    pub user_instructions: Option<String>,
    /// Whether this is the App. E.2 templated-kernel request.
    pub templated_request: bool,
    /// Task properties the model can see from the reference code.
    pub n_ops: usize,
    pub supports_reformulation: bool,
}

/// Builds App. E.1 / E.2 prompts.
pub struct PromptBuilder {
    pub language: String,
    pub reference_language: String,
}

impl Default for PromptBuilder {
    fn default() -> PromptBuilder {
        PromptBuilder {
            language: "SYCL".to_string(),
            reference_language: "PyTorch".to_string(),
        }
    }
}

impl PromptBuilder {
    pub fn cuda() -> PromptBuilder {
        PromptBuilder {
            language: "CUDA".to_string(),
            reference_language: "PyTorch".to_string(),
        }
    }

    /// Assemble the main generation prompt (App. E.1).
    #[allow(clippy::too_many_arguments)]
    pub fn build(
        &self,
        task: &TaskSpec,
        evolvable: &EvolvablePrompt,
        parent: Option<&EvalRecord>,
        top: Option<&EvalRecord>,
        last: Option<&EvalRecord>,
        hints: &[String],
        hardware: &str,
    ) -> Prompt {
        let mut text = String::with_capacity(8192);
        text.push_str(&format!(
            "You are a {lang} programming expert specializing in GPU kernel optimization. \
             Given a reference {ref_lang} implementation, your objective is to create a \
             performant kernel with identical functionality. The code you generate will be \
             pasted into an existing project and loaded using \
             torch.utils.cpp_extension.load().\n\n",
            lang = self.language,
            ref_lang = self.reference_language
        ));

        text.push_str("### Reference code / Task:\n");
        text.push_str(&format!(
            "# task: {} ({} ops{})\n",
            task.id,
            task.n_ops(),
            if task.backward { ", includes backward" } else { "" }
        ));
        for op in &task.ops {
            text.push_str(&format!("#   op: {}\n", op.name()));
        }
        if let Some(instr) = &task.user_instructions {
            text.push_str(&format!("\n### User instructions:\n{instr}\n"));
        }

        if let Some(top) = top {
            text.push_str(&format!(
                "\n### Top performing kernel (runtime: {:.4} ms):\n```cpp\n{}\n```\n",
                top.time_ms, top.source
            ));
        }
        if let Some(last) = last {
            text.push_str(&format!(
                "\n### Last tested kernel (runtime: {:.4} ms):\n```cpp\n{}\n```\n\
                 Console output from running this kernel:\n```\n{}\n```\n",
                last.time_ms, last.source, last.log
            ));
        }
        if let Some(parent) = parent {
            text.push_str(&format!(
                "\n### Parent kernel to improve (archive elite, fitness {:.3}):\n```cpp\n{}\n```\n",
                parent.fitness, parent.source
            ));
        }

        text.push_str(&format!(
            "\n### Hardware specification:\nYour code will run on the following hardware:\n{hardware}\n\
             Please consider the hardware specifications when improving the code.\n"
        ));

        text.push_str(
            "\n### Main Instructions:\n\
             - Provide a functional kernel that matches the reference implementation.\n\
             - Use constructs to efficiently run the code on GPU.\n\
             - Provide the complete code in a code block.\n",
        );

        if !hints.is_empty() {
            text.push_str("\n### Mutation hints (derived from evolutionary gradients):\n");
            for h in hints {
                text.push_str(&format!("- {h}\n"));
            }
        }

        text.push_str("\n### Optimization strategies:\n");
        text.push_str(&evolvable.render());

        text.push_str(
            "\n### Critical Requirements:\n\
             1. The kernel must exactly match the reference's functionality.\n\
             2. The code must compile and run properly on the GPU.\n\
             3. Do not cache or reuse previous results; ensure the code executes fully on each run.\n\
             \n### Response Format:\n1. Analysis … 2. Code …\n",
        );

        Prompt {
            text,
            task_id: task.id.clone(),
            parent: parent.map(|r| r.genome.clone()),
            hints: hints.to_vec(),
            evolvable: evolvable.clone(),
            last_log: last.map(|r| r.log.clone()).unwrap_or_default(),
            hardware: hardware.to_string(),
            user_instructions: task.user_instructions.clone(),
            templated_request: false,
            n_ops: task.n_ops(),
            supports_reformulation: task.supports_reformulation(),
        }
    }

    /// The App. E.2 templated-kernel prompt: asks the model to convert
    /// the best kernel's hardware-dependent constants into template
    /// parameters with dispatch options.
    pub fn build_templated(&self, task: &TaskSpec, best: &EvalRecord, hardware: &str) -> Prompt {
        let text = format!(
            "You are a {lang} programming expert specializing in GPU kernel optimization. \
             Your task is to optimize a given {lang} kernel.\n\n\
             ### Given kernel:\n```cpp\n{src}\n```\n\n\
             To optimize this kernel for specific hardware, please propose a templated kernel \
             with some template parameters that can be tuned (block size, tile sizes, vector \
             width). Write a forward_templated function and a forward dispatcher enumerating \
             suitable parameter options.\n\n### Hardware specification:\n{hardware}\n",
            lang = self.language,
            src = best.source,
        );
        Prompt {
            text,
            task_id: task.id.clone(),
            parent: Some(best.genome.clone()),
            hints: Vec::new(),
            evolvable: EvolvablePrompt::default(),
            last_log: best.log.clone(),
            hardware: hardware.to_string(),
            user_instructions: task.user_instructions.clone(),
            templated_request: true,
            n_ops: task.n_ops(),
            supports_reformulation: task.supports_reformulation(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{EvalOutcome, EvalRecord};
    use crate::tasks::catalog;

    fn record(task_id: &str, fitness: f64) -> EvalRecord {
        let genome = KernelGenome::direct_translation(task_id);
        EvalRecord {
            source: crate::ir::render_sycl(&genome),
            genome,
            outcome: EvalOutcome::Correct,
            coords: [0, 0, 0],
            correctness: None,
            time_ms: 1.25,
            baseline_ms: 2.0,
            speedup: 1.6,
            fitness,
            log: "runtime: 1.25 ms".to_string(),
            best_params: None,
            param_sweep: Vec::new(),
        }
    }

    #[test]
    fn main_prompt_has_all_sections() {
        let task = catalog::find_task("99_Matmul_GELU_Softmax").unwrap();
        let b = PromptBuilder::default();
        let top = record(&task.id, 0.9);
        let last = record(&task.id, 0.4);
        let hints = vec!["Consider adding shared memory tiling.".to_string()];
        let p = b.build(&task, &EvolvablePrompt::default(), Some(&top), Some(&top), Some(&last), &hints, "Intel Arc B580");
        for needle in [
            "SYCL programming expert",
            "Reference code / Task",
            "Top performing kernel",
            "Last tested kernel",
            "Hardware specification",
            "Mutation hints",
            "<<<EVOLVE:strategies>>>",
            "Critical Requirements",
            "shared memory tiling",
            "Intel Arc B580",
        ] {
            assert!(p.text.contains(needle), "missing section: {needle}");
        }
        assert!(p.parent.is_some());
        assert!(p.supports_reformulation);
    }

    #[test]
    fn custom_instructions_included() {
        let task = catalog::find_task("softmax").unwrap(); // oneDNN softmax w/ guidance
        let b = PromptBuilder::default();
        let p = b.build(&task, &EvolvablePrompt::default(), None, None, None, &[], "hw");
        assert!(p.text.contains("User instructions"));
        assert!(p.text.contains("exp2"));
    }

    #[test]
    fn templated_prompt_built() {
        let task = catalog::find_task("99_Matmul_GELU_Softmax").unwrap();
        let b = PromptBuilder::default();
        let best = record(&task.id, 0.95);
        let p = b.build_templated(&task, &best, "hw");
        assert!(p.templated_request);
        assert!(p.text.contains("templated kernel"));
        assert!(p.text.contains("forward_templated"));
    }

    #[test]
    fn cuda_builder_switches_language() {
        let task = catalog::find_task("20_LeakyReLU").unwrap();
        let p = PromptBuilder::cuda().build(&task, &EvolvablePrompt::default(), None, None, None, &[], "A6000");
        assert!(p.text.contains("CUDA programming expert"));
    }
}
