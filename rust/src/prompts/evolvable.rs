//! The four evolvable prompt regions (§3.5) and diff application.

use crate::util::textdiff::{self, DiffError, Hunk};

/// Markers delimiting evolvable regions inside the rendered prompt.
pub const MARK_PHILOSOPHY: (&str, &str) = ("<<<EVOLVE:philosophy>>>", "<<<END:philosophy>>>");
pub const MARK_STRATEGIES: (&str, &str) = ("<<<EVOLVE:strategies>>>", "<<<END:strategies>>>");
pub const MARK_PITFALLS: (&str, &str) = ("<<<EVOLVE:pitfalls>>>", "<<<END:pitfalls>>>");
pub const MARK_ANALYSIS: (&str, &str) = ("<<<EVOLVE:analysis>>>", "<<<END:analysis>>>");

/// The evolvable prompt content. Co-evolves with kernels; stored in the
/// prompt archive with fitness = best kernel produced under it.
#[derive(Debug, Clone, PartialEq)]
pub struct EvolvablePrompt {
    /// (1) High-level principles that shape priorities.
    pub philosophy: String,
    /// (2) Concrete techniques organized by category with canonical
    /// patterns.
    pub strategies: String,
    /// (3) Anti-patterns and frequent mistakes to avoid.
    pub pitfalls: String,
    /// (4) Pre-coding reasoning scaffold.
    pub analysis: String,
}

impl Default for EvolvablePrompt {
    fn default() -> EvolvablePrompt {
        EvolvablePrompt {
            philosophy: "Prioritize correctness first; then optimize the dominant bottleneck \
                         before micro-tuning."
                .to_string(),
            strategies: "\
- [memory] Coalesce global accesses; prefer vectorized loads (sycl::vec) on contiguous data.\n\
- [memory] Use shared local memory tiling for operands that are reused across work-items.\n\
- [algorithm] Fuse chains of elementwise operations into a single pass over the data; \
intermediates must not round-trip through global memory.\n\
- [compute] Keep work-group sizes a multiple of the sub-group width.\n\
- [parallelism] Use sub-group reductions instead of serializing through one work-item."
                .to_string(),
            pitfalls: "\
- Do not cache or reuse previous results between runs.\n\
- Always guard global stores with bounds checks."
                .to_string(),
            analysis: "Before coding: estimate bytes moved and FLOPs, decide whether the kernel \
                       is memory- or compute-bound, and pick the optimization accordingly."
                .to_string(),
        }
    }
}

impl EvolvablePrompt {
    /// A *generic* code-generation prompt with no kernel-specific
    /// optimization strategies — what the non-specialized baselines
    /// (repeated prompting, OpenEvolve) run with: "uses an evolutionary
    /// algorithm but lacks kernel-specific optimization strategies,
    /// meta-prompting, and parameter optimization" (§5.2).
    pub fn generic() -> EvolvablePrompt {
        EvolvablePrompt {
            philosophy: "Write correct code; make it fast where easy.".to_string(),
            strategies: "- Prefer clear, idiomatic code.\n- Avoid unnecessary work.".to_string(),
            pitfalls: "- Do not cache or reuse previous results between runs.".to_string(),
            analysis: "Read the reference carefully before coding.".to_string(),
        }
    }

    /// Render the four regions with their markers (the form embedded in
    /// the full prompt and visible to the meta-prompter).
    pub fn render(&self) -> String {
        format!(
            "{}\n{}\n{}\n\n{}\n{}\n{}\n\n{}\n{}\n{}\n\n{}\n{}\n{}\n",
            MARK_PHILOSOPHY.0,
            self.philosophy,
            MARK_PHILOSOPHY.1,
            MARK_STRATEGIES.0,
            self.strategies,
            MARK_STRATEGIES.1,
            MARK_PITFALLS.0,
            self.pitfalls,
            MARK_PITFALLS.1,
            MARK_ANALYSIS.0,
            self.analysis,
            MARK_ANALYSIS.1,
        )
    }

    /// Parse back from rendered form.
    pub fn parse(text: &str) -> Option<EvolvablePrompt> {
        let grab = |(start, end): (&str, &str)| -> Option<String> {
            let s = text.find(start)? + start.len();
            let e = text[s..].find(end)? + s;
            Some(text[s..e].trim().to_string())
        };
        Some(EvolvablePrompt {
            philosophy: grab(MARK_PHILOSOPHY)?,
            strategies: grab(MARK_STRATEGIES)?,
            pitfalls: grab(MARK_PITFALLS)?,
            analysis: grab(MARK_ANALYSIS)?,
        })
    }

    /// Apply meta-prompter SEARCH/REPLACE hunks, restricted to the
    /// evolvable regions: the diff is applied to the rendered form and
    /// re-parsed; edits touching the markers themselves are rejected.
    pub fn apply_diff(&self, hunks: &[Hunk]) -> Result<EvolvablePrompt, DiffError> {
        for h in hunks {
            if h.search.contains("<<<") || h.replace.contains("<<<") {
                return Err(DiffError::Malformed(
                    "diff may not modify region markers".into(),
                ));
            }
        }
        let rendered = self.render();
        let updated = textdiff::apply_all(&rendered, hunks)?;
        EvolvablePrompt::parse(&updated)
            .ok_or_else(|| DiffError::Malformed("regions unparseable after diff".into()))
    }

    /// Total content length (used to bound prompt growth).
    pub fn len(&self) -> usize {
        self.philosophy.len() + self.strategies.len() + self.pitfalls.len() + self.analysis.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_parse_roundtrip() {
        let p = EvolvablePrompt::default();
        let q = EvolvablePrompt::parse(&p.render()).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn diff_applies_inside_region() {
        let p = EvolvablePrompt::default();
        let diff = "<<<<<<< SEARCH\nPrioritize correctness first\n=======\nPrioritize memory bandwidth utilization\n>>>>>>> REPLACE\n";
        let hunks = textdiff::parse_hunks(diff).unwrap();
        let q = p.apply_diff(&hunks).unwrap();
        assert!(q.philosophy.contains("memory bandwidth utilization"));
        assert_eq!(q.strategies, p.strategies);
    }

    #[test]
    fn diff_cannot_touch_markers() {
        let p = EvolvablePrompt::default();
        let diff = "\
<<<<<<< SEARCH
<<<EVOLVE:pitfalls>>>
=======
gone
>>>>>>> REPLACE
";
        let hunks = textdiff::parse_hunks(diff).unwrap();
        assert!(p.apply_diff(&hunks).is_err());
    }

    #[test]
    fn failed_search_propagates() {
        let p = EvolvablePrompt::default();
        let hunks = textdiff::parse_hunks(
            "<<<<<<< SEARCH\nno such text\n=======\nx\n>>>>>>> REPLACE\n",
        )
        .unwrap();
        assert!(matches!(p.apply_diff(&hunks), Err(DiffError::NotFound(_))));
    }
}
