//! The meta-prompter LLM (§3.5).
//!
//! "This dedicated LLM (distinct from the kernel generator) analyzes
//! generation outcomes and proposes prompt modifications. Given the
//! current evolvable prompt sections together with the generated kernel
//! code and evaluation metrics, the meta-prompter first diagnoses which
//! guidance was missing, misleading, or insufficiently specific … then
//! prescribes targeted updates as SEARCH/REPLACE diffs restricted to the
//! evolvable regions."
//!
//! Our simulated meta-prompter performs the same diagnosis over the
//! recent evaluation records and emits real SEARCH/REPLACE diff text.
//! Injected guidance carries bracketed strategy/pitfall tokens (e.g.
//! `[strategy:online-reformulation]`) which the simulated code model
//! reads back out of the prompt — closing the co-evolution loop through
//! the prompt text itself.

use super::evolvable::EvolvablePrompt;
use crate::eval::{EvalOutcome, EvalRecord};
use crate::tasks::TaskSpec;

/// Strategy/pitfall guidance the meta-prompter can inject. Each entry is
/// (token, region, text); tokens are what the code model keys on.
pub const GUIDANCE: &[(&str, Region, &str)] = &[
    (
        "[pitfall:barrier]",
        Region::Pitfalls,
        "[pitfall:barrier] After cooperatively writing shared local memory tiles, always \
         synchronize with group_barrier before reading them — missing barriers cause \
         nondeterministic output.",
    ),
    (
        "[pitfall:bounds]",
        Region::Pitfalls,
        "[pitfall:bounds] Guard every global store with an explicit bounds check; paddings and \
         non-divisible shapes otherwise fault.",
    ),
    (
        "[pitfall:complete-code]",
        Region::Pitfalls,
        "[pitfall:complete-code] Always emit the complete translation unit including the \
         PYBIND11_MODULE block; truncated responses do not compile.",
    ),
    (
        "[strategy:slm-pad]",
        Region::Strategies,
        "- [memory] [strategy:slm-pad] Avoid bank conflicts by adding +1 padding to shared \
         local memory arrays.",
    ),
    (
        "[strategy:vectorize]",
        Region::Strategies,
        "- [memory] [strategy:vectorize] Use wide vector loads (sycl::vec<float,4/8>) on \
         contiguous data to saturate bandwidth.",
    ),
    (
        "[strategy:tiling]",
        Region::Strategies,
        "- [memory] [strategy:tiling] Stage reused operands in shared local memory tiles sized \
         to the device SLM budget.",
    ),
    (
        "[strategy:reg-block]",
        Region::Strategies,
        "- [compute] [strategy:reg-block] Add register blocking (per-thread accumulator tiles) \
         and prefetch the next tile to overlap memory with compute.",
    ),
    (
        "[strategy:fuse-all]",
        Region::Strategies,
        "- [algorithm] [strategy:fuse-all] Fuse the full operation chain into a single kernel \
         pass; intermediate tensors must never round-trip through global memory.",
    ),
    (
        "[strategy:online-reformulation]",
        Region::Strategies,
        "- [algorithm] [strategy:online-reformulation] Reformulate normalization/softmax with a \
         streaming (online) algorithm using exp2-based rescaling to cut passes and special-\
         function load.",
    ),
    (
        "[strategy:subgroup]",
        Region::Strategies,
        "- [parallelism] [strategy:subgroup] Use sub-group shuffles and reduce_over_group for \
         reductions instead of full work-group barriers.",
    ),
];

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Region {
    Strategies,
    Pitfalls,
    Philosophy,
    Analysis,
}

/// The simulated meta-prompter.
pub struct MetaPrompter {
    /// Max prompt mutations per update (Table 6: 3).
    pub max_mutations: usize,
}

impl Default for MetaPrompter {
    fn default() -> MetaPrompter {
        MetaPrompter { max_mutations: 3 }
    }
}

impl MetaPrompter {
    /// Diagnose recent outcomes and produce a SEARCH/REPLACE diff over
    /// the rendered evolvable regions. Returns `None` when no update is
    /// warranted.
    pub fn propose_diff(
        &self,
        current: &EvolvablePrompt,
        recent: &[EvalRecord],
        task: &TaskSpec,
    ) -> Option<String> {
        if recent.is_empty() {
            return None;
        }
        let mut wanted: Vec<&str> = Vec::new();

        let n = recent.len() as f64;
        let compile_fails =
            recent.iter().filter(|r| r.outcome == EvalOutcome::CompileError).count() as f64;
        let races = recent
            .iter()
            .filter(|r| r.log.contains("nondeterministic") || r.log.contains("race"))
            .count();
        let oob = recent
            .iter()
            .filter(|r| r.log.contains("illegal memory access") || r.log.contains("page fault"))
            .count();

        if compile_fails / n > 0.25 {
            wanted.push("[pitfall:complete-code]");
        }
        if races > 0 {
            wanted.push("[pitfall:barrier]");
        }
        if oob > 0 {
            wanted.push("[pitfall:bounds]");
        }

        // Performance diagnosis over correct kernels.
        let correct: Vec<&EvalRecord> =
            recent.iter().filter(|r| r.outcome == EvalOutcome::Correct).collect();
        if let Some(best) = correct
            .iter()
            .max_by(|a, b| a.fitness.partial_cmp(&b.fitness).unwrap())
        {
            let c = best.coords;
            if c[0] == 0 {
                wanted.push("[strategy:vectorize]");
            } else if c[0] == 1 && task.arithmetic_intensity() > 4.0 {
                wanted.push("[strategy:tiling]");
            } else if c[0] == 2 {
                wanted.push("[strategy:reg-block]");
            }
            if c[1] == 0 && task.n_ops() > 1 {
                wanted.push("[strategy:fuse-all]");
            }
            if c[1] <= 1 && task.supports_reformulation() {
                wanted.push("[strategy:online-reformulation]");
            }
            if c[2] <= 1 && task.ops.iter().any(|o| o.sfu_ops() > 0 || matches!(o, crate::tasks::OpSpec::Reduction { .. })) {
                wanted.push("[strategy:subgroup]");
            }
            if best.genome.uses_slm() && !best.genome.params.slm_pad {
                wanted.push("[strategy:slm-pad]");
            }
        }

        // Drop guidance already present; respect the mutation budget.
        let rendered = current.render();
        wanted.retain(|tok| !rendered.contains(tok));
        wanted.truncate(self.max_mutations);
        if wanted.is_empty() {
            return None;
        }

        // Emit appending diffs: replace the region's final line with
        // itself + the new guidance line.
        let mut diff = String::new();
        let mut strategies_tail = last_line(&current.strategies).to_string();
        let mut pitfalls_tail = last_line(&current.pitfalls).to_string();
        for tok in wanted {
            let (_, region, text) = GUIDANCE.iter().find(|(t, _, _)| t == &tok)?;
            let tail = match region {
                Region::Pitfalls => &mut pitfalls_tail,
                _ => &mut strategies_tail,
            };
            diff.push_str(&format!(
                "<<<<<<< SEARCH\n{tail}\n=======\n{tail}\n{text}\n>>>>>>> REPLACE\n"
            ));
            *tail = last_line(text).to_string();
        }
        Some(diff)
    }
}

fn last_line(s: &str) -> &str {
    s.lines().last().unwrap_or("")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::EvalOutcome;
    use crate::ir::KernelGenome;
    use crate::tasks::catalog;
    use crate::util::textdiff;

    fn rec(task_id: &str, outcome: EvalOutcome, coords: [usize; 3], log: &str) -> EvalRecord {
        let genome = KernelGenome::direct_translation(task_id);
        EvalRecord {
            source: String::new(),
            genome,
            outcome,
            coords,
            correctness: None,
            time_ms: 1.0,
            baseline_ms: 1.0,
            speedup: 1.0,
            fitness: match outcome {
                EvalOutcome::Correct => 0.6,
                EvalOutcome::Incorrect => 0.1,
                EvalOutcome::CompileError => 0.0,
            },
            log: log.to_string(),
            best_params: None,
            param_sweep: Vec::new(),
        }
    }

    #[test]
    fn race_failures_add_barrier_pitfall() {
        let task = catalog::find_task("99_Matmul_GELU_Softmax").unwrap();
        let mp = MetaPrompter::default();
        let cur = EvolvablePrompt::default();
        let recent = vec![
            rec(&task.id, EvalOutcome::Incorrect, [2, 0, 0], "test: nondeterministic output (possible race)"),
            rec(&task.id, EvalOutcome::Correct, [2, 1, 1], ""),
        ];
        let diff = mp.propose_diff(&cur, &recent, &task).unwrap();
        assert!(diff.contains("[pitfall:barrier]"));
        // And the diff actually applies.
        let hunks = textdiff::parse_hunks(&diff).unwrap();
        let updated = cur.apply_diff(&hunks).unwrap();
        assert!(updated.pitfalls.contains("[pitfall:barrier]"));
    }

    #[test]
    fn reformulation_suggested_for_softmax_tasks() {
        let task = catalog::find_task("99_Matmul_GELU_Softmax").unwrap();
        let mp = MetaPrompter::default();
        let cur = EvolvablePrompt::default();
        let recent = vec![rec(&task.id, EvalOutcome::Correct, [1, 1, 2], "")];
        let diff = mp.propose_diff(&cur, &recent, &task).unwrap();
        assert!(diff.contains("[strategy:online-reformulation]"), "{diff}");
    }

    #[test]
    fn no_duplicate_guidance() {
        let task = catalog::find_task("99_Matmul_GELU_Softmax").unwrap();
        let mp = MetaPrompter::default();
        let mut cur = EvolvablePrompt::default();
        let recent = vec![rec(&task.id, EvalOutcome::Correct, [1, 1, 2], "")];
        let diff = mp.propose_diff(&cur, &recent, &task).unwrap();
        let hunks = textdiff::parse_hunks(&diff).unwrap();
        cur = cur.apply_diff(&hunks).unwrap();
        // Second round with the same evidence must not re-propose the
        // same tokens.
        if let Some(diff2) = mp.propose_diff(&cur, &recent, &task) {
            assert!(!diff2.contains("[strategy:online-reformulation]"));
        }
    }

    #[test]
    fn respects_mutation_budget() {
        let task = catalog::find_task("37_Matmul_Swish_Sum_GroupNorm").unwrap();
        let mp = MetaPrompter::default();
        let recent = vec![
            rec(&task.id, EvalOutcome::CompileError, [0, 0, 0], "error: expected '}'"),
            rec(&task.id, EvalOutcome::CompileError, [0, 0, 0], "error: expected '}'"),
            rec(&task.id, EvalOutcome::Incorrect, [2, 0, 0], "race"),
            rec(&task.id, EvalOutcome::Incorrect, [0, 0, 0], "illegal memory access"),
            rec(&task.id, EvalOutcome::Correct, [0, 0, 0], ""),
        ];
        let diff = mp.propose_diff(&EvolvablePrompt::default(), &recent, &task).unwrap();
        let hunks = textdiff::parse_hunks(&diff).unwrap();
        assert!(hunks.len() <= 3, "{} mutations", hunks.len());
    }

    #[test]
    fn empty_history_no_update() {
        let task = catalog::find_task("20_LeakyReLU").unwrap();
        assert!(MetaPrompter::default()
            .propose_diff(&EvolvablePrompt::default(), &[], &task)
            .is_none());
    }
}
