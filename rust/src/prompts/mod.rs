//! Prompt construction engine and meta-prompt evolution (§3.1, §3.5, App. E).
//!
//! The kernel-generation prompt follows App. E.1: task/reference section,
//! example kernels, top-performing kernel, last tested kernel + console
//! log, hardware specification, main instructions, optimization
//! strategies, critical requirements and response format. Four regions
//! are *evolvable* (§3.5) — optimization philosophy, optimization
//! strategies, common pitfalls, analysis guidance — delimited by special
//! markers so the meta-prompter's SEARCH/REPLACE diffs can only touch
//! them.

pub mod archive;
pub mod builder;
pub mod evolvable;
pub mod meta;

pub use archive::PromptArchive;
pub use builder::{Prompt, PromptBuilder};
pub use evolvable::EvolvablePrompt;
pub use meta::MetaPrompter;
