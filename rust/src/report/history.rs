//! The per-generation search-history log: `kf_search_*` telemetry that
//! survives the process.
//!
//! The metrics registry (PR 4) publishes search health as *last-value
//! gauges* — one number per metric, overwritten every generation and
//! gone at exit. [`SearchLog`] persists the same quantities as one
//! [`SearchStatsRow`] per generation per run, written with the repo's
//! standard append-only JSONL discipline (whole-line `O_APPEND` writes
//! under a mutex, torn final line repaired by
//! [`crate::dist::load_jsonl_tolerant`] on reload). The analytics layer
//! ([`super::views::SearchHealthView`]) folds these rows into QD-score,
//! coverage and acceptance *curves*, which is what the surrogate-model
//! and federation roadmap items need to read back.

use crate::dist::load_jsonl_tolerant;
use crate::util::json::Json;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// One generation's archive snapshot for one evolution run.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchStatsRow {
    /// Run identifier. Fleet lanes use the unit's result-cache key, so
    /// search rows join against persisted cache rows on `DbRow::run`;
    /// CLI runs use an equivalent `task|device|language|s<seed>|...`
    /// label.
    pub run: String,
    /// Task the run optimizes.
    pub task_id: String,
    /// Device profile the run targets.
    pub device: String,
    /// Generation index (0-based, one row per generation).
    pub generation: usize,
    /// QD-score: sum of elite fitness over occupied cells.
    pub qd_score: f64,
    /// Occupied cells / total cells.
    pub coverage: f64,
    /// Best elite fitness so far.
    pub best_fitness: f64,
    /// Best elite speedup so far.
    pub best_speedup: f64,
    /// Archive insertions / insertion attempts so far.
    pub acceptance: f64,
    /// Cumulative archive insertions.
    pub insertions: usize,
    /// Cumulative insertion attempts.
    pub attempts: usize,
    /// Occupied archive cells.
    pub occupied: usize,
    /// Candidates evaluated so far in the run.
    pub evaluations: usize,
    /// Wall-clock Unix milliseconds when the row was recorded.
    pub ts_ms: f64,
}

impl SearchStatsRow {
    /// Serialize to the JSONL object form. Non-finite metrics are
    /// clamped (NaN → 0, ±inf → ±MAX) so one bad value can never make
    /// the whole log unloadable.
    pub fn to_json(&self) -> Json {
        fn finite(v: f64) -> f64 {
            if v.is_finite() {
                v
            } else if v.is_nan() {
                0.0
            } else if v > 0.0 {
                f64::MAX
            } else {
                f64::MIN
            }
        }
        let mut o = Json::obj();
        o.set("run", self.run.as_str())
            .set("task_id", self.task_id.as_str())
            .set("device", self.device.as_str())
            .set("gen", self.generation)
            .set("qd_score", finite(self.qd_score))
            .set("coverage", finite(self.coverage))
            .set("best_fitness", finite(self.best_fitness))
            .set("best_speedup", finite(self.best_speedup))
            .set("acceptance", finite(self.acceptance))
            .set("insertions", self.insertions)
            .set("attempts", self.attempts)
            .set("occupied", self.occupied)
            .set("evaluations", self.evaluations)
            .set("ts_ms", finite(self.ts_ms));
        o
    }

    /// Parse a row back from its JSON object form; `None` on schema
    /// mismatch.
    pub fn from_json(v: &Json) -> Option<SearchStatsRow> {
        Some(SearchStatsRow {
            run: v.get("run")?.as_str()?.to_string(),
            task_id: v.get("task_id")?.as_str()?.to_string(),
            device: v.get("device")?.as_str()?.to_string(),
            generation: v.get("gen")?.as_usize()?,
            qd_score: v.get("qd_score")?.as_f64()?,
            coverage: v.get("coverage")?.as_f64()?,
            best_fitness: v.get("best_fitness")?.as_f64()?,
            best_speedup: v.get("best_speedup")?.as_f64()?,
            acceptance: v.get("acceptance")?.as_f64()?,
            insertions: v.get("insertions")?.as_usize()?,
            attempts: v.get("attempts")?.as_usize()?,
            occupied: v.get("occupied")?.as_usize()?,
            evaluations: v.get("evaluations")?.as_usize()?,
            ts_ms: v.get("ts_ms")?.as_f64()?,
        })
    }
}

/// Append-only JSONL writer for [`SearchStatsRow`]s, shared by every
/// engine in the process (CLI run, or one per fleet lane unit).
///
/// Appends are best-effort: an I/O error is logged and swallowed, never
/// propagated into the evolution loop — telemetry must not be able to
/// fail a run.
pub struct SearchLog {
    path: PathBuf,
    file: Mutex<File>,
}

impl SearchLog {
    /// Open (creating if needed) the log at `path` for appending.
    pub fn open(path: &Path) -> std::io::Result<SearchLog> {
        let file = OpenOptions::new().create(true).append(true).open(path)?;
        Ok(SearchLog {
            path: path.to_path_buf(),
            file: Mutex::new(file),
        })
    }

    /// The log's file path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one row as a whole line.
    pub fn append(&self, row: &SearchStatsRow) {
        let mut line = row.to_json().to_string_compact();
        line.push('\n');
        let mut guard = self.file.lock().unwrap();
        if let Err(e) = guard.write_all(line.as_bytes()) {
            crate::log_warn!("search log {}: {e}", self.path.display());
        }
    }

    /// Load every row from a log file. A missing file is an empty
    /// history; a torn final line is dropped (and repaired on disk).
    pub fn load(path: &Path) -> Vec<SearchStatsRow> {
        if !path.exists() {
            return Vec::new();
        }
        match load_jsonl_tolerant(path, SearchStatsRow::from_json) {
            Ok((rows, _)) => rows,
            Err(e) => {
                crate::log_warn!("search log {}: {e}", path.display());
                Vec::new()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(run: &str, generation: usize, qd: f64) -> SearchStatsRow {
        SearchStatsRow {
            run: run.to_string(),
            task_id: "t1".to_string(),
            device: "b580".to_string(),
            generation,
            qd_score: qd,
            coverage: 0.25,
            best_fitness: 0.9,
            best_speedup: 1.8,
            acceptance: 0.5,
            insertions: 4,
            attempts: 8,
            occupied: 3,
            evaluations: 16,
            ts_ms: 1.0e12,
        }
    }

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("kf_search_log_{name}_{}.jsonl", std::process::id()))
    }

    #[test]
    fn rows_roundtrip_through_the_log() {
        let path = tmp("roundtrip");
        let _ = std::fs::remove_file(&path);
        let log = SearchLog::open(&path).unwrap();
        log.append(&row("r1", 0, 1.5));
        log.append(&row("r1", 1, 2.5));
        log.append(&row("r2", 0, 0.5));
        let rows = SearchLog::load(&path);
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0], row("r1", 0, 1.5));
        assert_eq!(rows[2].run, "r2");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn missing_file_and_torn_tail_load_safely() {
        let path = tmp("torn");
        let _ = std::fs::remove_file(&path);
        assert!(SearchLog::load(&path).is_empty());
        {
            let log = SearchLog::open(&path).unwrap();
            log.append(&row("r1", 0, 1.0));
        }
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("{\"run\":\"r1\",\"tas");
        std::fs::write(&path, text).unwrap();
        let rows = SearchLog::load(&path);
        assert_eq!(rows.len(), 1, "intact rows survive a torn tail");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn non_finite_metrics_stay_loadable() {
        let mut r = row("r1", 0, f64::NAN);
        r.best_speedup = f64::INFINITY;
        let back = SearchStatsRow::from_json(&r.to_json()).expect("row stays loadable");
        assert_eq!(back.qd_score, 0.0);
        assert!(back.best_speedup.is_finite());
    }
}
