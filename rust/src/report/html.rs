//! Self-contained HTML run dashboard: all four analytics views in one
//! file with inline CSS and inline SVG sparklines — no external assets,
//! no JavaScript, so the report can be archived as a CI artifact and
//! opened anywhere.

use super::views::{
    Artifacts, LatencyView, ReliabilityView, SearchHealthView, SearchRunCurve, TrajectoryView,
};
use crate::obs::trace::stage;
use std::fmt::Write as _;

/// HTML-escape text content and attribute values.
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '&' => out.push_str("&amp;"),
            '<' => out.push_str("&lt;"),
            '>' => out.push_str("&gt;"),
            '"' => out.push_str("&quot;"),
            '\'' => out.push_str("&#39;"),
            _ => out.push(c),
        }
    }
    out
}

/// An inline SVG sparkline over `values` (auto-scaled to its own
/// min/max; a flat or single-point series renders as a midline).
fn sparkline(values: &[f64]) -> String {
    const W: f64 = 120.0;
    const H: f64 = 24.0;
    const PAD: f64 = 2.0;
    if values.is_empty() {
        return String::from("<span class=\"empty\">—</span>");
    }
    let lo = values.iter().copied().fold(f64::INFINITY, f64::min);
    let hi = values.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let span = if (hi - lo).abs() < 1e-12 { 1.0 } else { hi - lo };
    let n = values.len();
    let points: Vec<String> = values
        .iter()
        .enumerate()
        .map(|(i, v)| {
            let x = if n == 1 {
                W / 2.0
            } else {
                PAD + (W - 2.0 * PAD) * i as f64 / (n - 1) as f64
            };
            let y = H - PAD - (H - 2.0 * PAD) * (v - lo) / span;
            format!("{x:.1},{y:.1}")
        })
        .collect();
    format!(
        "<svg class=\"spark\" viewBox=\"0 0 {W:.0} {H:.0}\" width=\"{W:.0}\" height=\"{H:.0}\" \
         role=\"img\"><polyline points=\"{}\" fill=\"none\" stroke=\"#2a7ae2\" \
         stroke-width=\"1.5\"/></svg>",
        points.join(" ")
    )
}

fn section(out: &mut String, title: &str, body: &str) {
    let _ = write!(out, "<section><h2>{}</h2>{body}</section>\n", esc(title));
}

fn stage_coverage(artifacts: &Artifacts) -> String {
    let mut rows = String::new();
    for s in stage::ALL {
        let count = artifacts.events.iter().filter(|e| e.stage == *s).count();
        let _ = write!(
            rows,
            "<tr><td class=\"stage\">{}</td><td class=\"num\">{count}</td></tr>",
            esc(s)
        );
    }
    format!(
        "<p>Every lifecycle stage with its event count across the trace sink.</p>\
         <table><tr><th>stage</th><th>events</th></tr>{rows}</table>"
    )
}

fn trajectories(view: &TrajectoryView) -> String {
    if view.points.is_empty() {
        return "<p class=\"empty\">no correct rows in the results database</p>".to_string();
    }
    let mut rows = String::new();
    for p in &view.points {
        let curve: Vec<f64> = p.runs.iter().map(|(_, s)| *s).collect();
        let delta = if p.runs.len() >= 2 {
            format!("{:+.3}", p.delta)
        } else {
            "—".to_string()
        };
        let _ = write!(
            rows,
            "<tr><td>{}</td><td>{:?}</td><td>{}</td><td class=\"num\">{:.3}</td>\
             <td class=\"num\">{:.3}×</td><td class=\"num\">{}</td><td>{}</td>\
             <td class=\"num\">{}</td></tr>",
            esc(&p.task_id),
            p.coords,
            esc(&p.device),
            p.best_fitness,
            p.best_speedup,
            delta,
            sparkline(&curve),
            p.n_rows,
        );
    }
    format!(
        "<p>Best kernel per (task, MAP-Elites cell, device); the sparkline tracks \
         per-run best speedup, Δ is the last run-over-run change.</p>\
         <table><tr><th>task</th><th>cell</th><th>device</th><th>fitness</th>\
         <th>speedup</th><th>Δ</th><th>per-run</th><th>rows</th></tr>{rows}</table>"
    )
}

fn latency(view: &LatencyView) -> String {
    if view.lanes.is_empty() {
        return "<p class=\"empty\">no closed stage segments in the trace sink</p>".to_string();
    }
    let mut rows = String::new();
    for l in &view.lanes {
        let _ = write!(
            rows,
            "<tr><td>{}</td><td class=\"stage\">{}</td><td class=\"num\">{}</td>\
             <td class=\"num\">{:.1}</td><td class=\"num\">{:.1}</td>\
             <td class=\"num\">{:.1}</td><td class=\"num\">{:.1}</td>\
             <td class=\"num\">{:.1}</td></tr>",
            esc(&l.device),
            esc(&l.segment),
            l.n,
            l.p50,
            l.p90,
            l.p99,
            l.min,
            l.max,
        );
    }
    format!(
        "<p>Per-stage latency (ms) per device lane: queue-wait (queued→dispatched), \
         compile (dispatched→compiled), exec (compiled→executed), \
         commit (executed→committed).</p>\
         <table><tr><th>device</th><th>segment</th><th>n</th><th>p50</th>\
         <th>p90</th><th>p99</th><th>min</th><th>max</th></tr>{rows}</table>"
    )
}

fn reliability(view: &ReliabilityView, have_journal: bool) -> String {
    if !have_journal {
        return "<p class=\"empty\">no journal supplied (daemon --journal)</p>".to_string();
    }
    let mut rows = String::new();
    let counters: &[(&str, usize)] = &[
        ("jobs submitted", view.submits),
        ("units dispatched", view.dispatches),
        ("units committed", view.commits),
        ("units failed", view.fails),
        ("unit retries", view.retries),
        ("units rerouted", view.reroutes),
        ("units quarantined", view.quarantines),
        ("units cancelled", view.cancelled_units),
        ("crash-replay re-dispatches", view.replayed_dispatches),
        ("lost (in-flight) units", view.lost_units),
        ("owner sessions", view.sessions),
        ("clean releases", view.clean_releases),
        ("unclean sessions (crashes + live)", view.unclean_sessions()),
        ("stale-lease takeovers", view.lease_takeovers),
    ];
    for (name, value) in counters {
        let _ = write!(
            rows,
            "<tr><td>{}</td><td class=\"num\">{value}</td></tr>",
            esc(name)
        );
    }
    format!(
        "<p>Crash/replay/lease accounting folded from the write-ahead journal.</p>\
         <table><tr><th>counter</th><th>count</th></tr>{rows}</table>"
    )
}

fn search_run_row(run: &SearchRunCurve) -> String {
    format!(
        "<tr><td class=\"run\">{}</td><td>{}</td><td>{}</td><td class=\"num\">{}</td>\
         <td class=\"num\">{:.3}</td><td>{}</td>\
         <td class=\"num\">{:.1}%</td><td>{}</td>\
         <td class=\"num\">{:.1}%</td><td>{}</td>\
         <td class=\"num\">{:.3}×</td><td>{}</td></tr>",
        esc(&run.run),
        esc(&run.task_id),
        esc(&run.device),
        run.generations(),
        SearchRunCurve::final_of(&run.qd_curve),
        sparkline(&run.qd_curve),
        SearchRunCurve::final_of(&run.coverage_curve) * 100.0,
        sparkline(&run.coverage_curve),
        SearchRunCurve::final_of(&run.acceptance_curve) * 100.0,
        sparkline(&run.acceptance_curve),
        SearchRunCurve::final_of(&run.best_speedup_curve),
        sparkline(&run.best_speedup_curve),
    )
}

fn alert_timeline(artifacts: &Artifacts) -> String {
    if artifacts.alerts.is_empty() {
        return "<p class=\"empty\">no alert transitions (daemon --alert-log)</p>".to_string();
    }
    let t0 = artifacts.alerts[0].ts_ms;
    let mut rows = String::new();
    for t in &artifacts.alerts {
        let _ = write!(
            rows,
            "<tr><td class=\"num\">{:.1}</td><td class=\"run\">{}</td><td>{}</td>\
             <td class=\"stage\">{} {} {}</td><td class=\"num\">{:.3}</td></tr>",
            t.ts_ms - t0,
            esc(&t.rule),
            esc(&t.state),
            esc(&t.metric),
            esc(&t.op),
            t.threshold,
            t.value,
        );
    }
    format!(
        "<p>SLO alert edges from the daemon's alert engine: `firing` when a rule's \
         condition is breached past its debounce window, `resolved` when it heals.</p>\
         <table><tr><th>+ms</th><th>rule</th><th>state</th><th>condition</th>\
         <th>value</th></tr>{rows}</table>"
    )
}

fn search_health(view: &SearchHealthView) -> String {
    if view.runs.is_empty() {
        return "<p class=\"empty\">no search history supplied (--search-log)</p>".to_string();
    }
    let rows: String = view.runs.iter().map(search_run_row).collect();
    format!(
        "<p>Per-generation MAP-Elites health per run: QD-score, archive coverage, \
         mutation acceptance and best speedup curves.</p>\
         <table><tr><th>run</th><th>task</th><th>device</th><th>gens</th>\
         <th>QD</th><th></th><th>coverage</th><th></th>\
         <th>acceptance</th><th></th><th>best</th><th></th></tr>{rows}</table>"
    )
}

/// Render the full dashboard. `have_journal` distinguishes "journal
/// supplied but empty" from "no journal configured".
pub fn render(artifacts: &Artifacts, have_journal: bool) -> String {
    let trajectory = TrajectoryView::build(&artifacts.rows);
    let lat = LatencyView::build(&artifacts.events);
    let rel = ReliabilityView::build(&artifacts.journal);
    let search = SearchHealthView::build(&artifacts.search);

    let mut out = String::with_capacity(16 * 1024);
    out.push_str(
        "<!DOCTYPE html>\n<html lang=\"en\"><head><meta charset=\"utf-8\">\
         <title>KernelFoundry run report</title>\n<style>\n\
         body{font:14px/1.5 system-ui,sans-serif;margin:2rem auto;max-width:72rem;\
         padding:0 1rem;color:#1c2733}\n\
         h1{font-size:1.5rem}h2{font-size:1.15rem;border-bottom:2px solid #2a7ae2;\
         padding-bottom:.2rem;margin-top:2rem}\n\
         table{border-collapse:collapse;width:100%;margin:.5rem 0}\n\
         th,td{border:1px solid #d5dde5;padding:.25rem .5rem;text-align:left;\
         vertical-align:middle}\n\
         th{background:#eef3f8}\n\
         td.num{text-align:right;font-variant-numeric:tabular-nums}\n\
         td.stage,td.run{font-family:ui-monospace,monospace;font-size:.85em}\n\
         .empty{color:#7a8794}\n\
         .meta{color:#51606e;font-size:.9em}\n\
         svg.spark{display:block}\n\
         </style></head><body>\n<h1>KernelFoundry run report</h1>\n",
    );
    let _ = write!(
        out,
        "<p class=\"meta\">sources: {} database rows · {} trace events · \
         {} journal records · {} search-history rows · {} alert transitions</p>\n",
        artifacts.rows.len(),
        artifacts.events.len(),
        artifacts.journal.len(),
        artifacts.search.len(),
        artifacts.alerts.len(),
    );
    section(&mut out, "Job lifecycle coverage", &stage_coverage(artifacts));
    section(&mut out, "Speedup trajectories", &trajectories(&trajectory));
    section(&mut out, "Latency breakdown", &latency(&lat));
    section(&mut out, "Reliability", &reliability(&rel, have_journal));
    section(&mut out, "Alert timeline", &alert_timeline(artifacts));
    section(&mut out, "Search health", &search_health(&search));
    out.push_str("</body></html>\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::TraceEvent;

    #[test]
    fn empty_artifacts_render_a_complete_page() {
        let html = render(&Artifacts::default(), false);
        assert!(html.starts_with("<!DOCTYPE html>"));
        assert!(html.ends_with("</html>\n"));
        for s in stage::ALL {
            assert!(html.contains(s), "stage {s} missing from the dashboard");
        }
        for title in [
            "Speedup trajectories",
            "Latency breakdown",
            "Reliability",
            "Alert timeline",
            "Search health",
        ] {
            assert!(html.contains(title), "{title} section missing");
        }
        assert!(!html.contains("<script"), "dashboard must carry no JS");
    }

    #[test]
    fn sparkline_is_inline_svg() {
        let svg = sparkline(&[1.0, 3.0, 2.0]);
        assert!(svg.starts_with("<svg"));
        assert!(svg.contains("polyline"));
        assert_eq!(sparkline(&[]), "<span class=\"empty\">—</span>");
    }

    #[test]
    fn content_is_escaped() {
        let mut a = Artifacts::default();
        let bad = "<script>alert(1)</script>";
        for (s, ts) in [("dispatched", 1.0), ("compiled", 2.0)] {
            a.events.push(TraceEvent {
                stage: s.to_string(),
                job_id: 1,
                trace_id: "t".to_string(),
                device: Some(bad.to_string()),
                ts_ms: ts,
            });
        }
        let html = render(&a, false);
        assert!(html.contains("&lt;script&gt;"), "device name must render escaped");
        assert!(!html.contains(bad), "raw device name must not appear");
    }
}
