//! The search observatory: cross-run analytics over the append-only
//! JSONL stores (ROADMAP "cross-run analytics").
//!
//! The repo persists five JSONL sources — results
//! [`crate::dist::Database`] rows, [`crate::obs::TraceSink`] lifecycle
//! events, [`crate::service::Journal`] records, the SLO alert log the
//! daemon's [`crate::obs::AlertEngine`] appends, and the per-generation
//! search history this module's [`SearchLog`] adds — and this subsystem
//! turns them into typed, order-independent views (DESIGN.md §9):
//!
//! * [`views::TrajectoryView`] — best speedup per (task, MAP-Elites
//!   cell, device) with run-over-run deltas;
//! * [`views::LatencyView`] — queue-wait / compile / exec / commit
//!   percentiles per device lane, from trace-event deltas;
//! * [`views::ReliabilityView`] — crash / replay / lost-unit /
//!   lease-takeover counts folded from the journal;
//! * [`views::SearchHealthView`] — QD-score, coverage and acceptance
//!   curves per generation per run.
//!
//! On top of the views: [`regression::detect`] (the
//! `kernelfoundry report regressions --baseline <db>` gate, nonzero
//! exit on regression) and [`html::render`] (a single self-contained
//! HTML dashboard with inline SVG sparklines, no JS).
//!
//! JSONL stays the append-only source of truth; every view is a pure
//! fold over reloaded rows, so the analytics layer can be rebuilt from
//! the artifacts of any past run.

pub mod history;
pub mod html;
pub mod regression;
pub mod views;

pub use history::{SearchLog, SearchStatsRow};
pub use regression::{detect, Regression, RegressionConfig};
pub use views::{
    Artifacts, LatencyView, ReliabilityView, RowFilter, SearchHealthView, TrajectoryView,
};
