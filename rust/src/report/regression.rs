//! Per-device regression detection between two results databases.
//!
//! `kernelfoundry report regressions --baseline <db>` compares the best
//! kernel per (task, device) in the current database against a
//! historical baseline database and reports every key whose speedup
//! dropped by more than a configurable tolerance. The CLI exits nonzero
//! when any regression is found, so the check gates CI the same way
//! `scripts/bench_gate.py` gates service throughput.

use super::views::{row_device, RowFilter};
use crate::dist::DbRow;
use std::collections::BTreeMap;

/// Thresholds for the detector.
#[derive(Debug, Clone, Copy)]
pub struct RegressionConfig {
    /// Maximum tolerated speedup drop, as a fraction of the baseline
    /// (0.10 = a current best more than 10% below baseline regresses).
    pub max_speedup_drop: f64,
    /// Whether a (task, device) present in the baseline but absent from
    /// the current database counts as a regression (default: it does —
    /// a silently vanished result is worse than a slower one).
    pub missing_is_regression: bool,
}

impl Default for RegressionConfig {
    fn default() -> RegressionConfig {
        RegressionConfig {
            max_speedup_drop: 0.10,
            missing_is_regression: true,
        }
    }
}

/// One detected regression.
#[derive(Debug, Clone, PartialEq)]
pub struct Regression {
    /// Task id.
    pub task_id: String,
    /// Device name (`-` when the rows carry none).
    pub device: String,
    /// Best speedup in the baseline database.
    pub baseline_speedup: f64,
    /// Best speedup in the current database (0 when missing).
    pub current_speedup: f64,
    /// Fractional drop: `1 - current / baseline`.
    pub drop_frac: f64,
    /// Whether the key is entirely missing from the current database.
    pub missing: bool,
}

/// Best speedup per (task, device) over correct rows — the key space
/// both sides of the comparison are reduced to.
pub fn best_by_task_device(rows: &[DbRow], filter: &RowFilter) -> BTreeMap<(String, String), f64> {
    let mut best: BTreeMap<(String, String), f64> = BTreeMap::new();
    for row in rows.iter().filter(|r| r.is_correct() && filter.matches(r)) {
        let device = row_device(row).unwrap_or("-").to_string();
        let entry = best.entry((row.task_id.clone(), device)).or_insert(0.0);
        if row.speedup > *entry {
            *entry = row.speedup;
        }
    }
    best
}

/// Compare current against baseline; returns regressions sorted by
/// severity (largest drop first). Keys only in the current database
/// (new tasks/devices) are never regressions.
pub fn detect(
    baseline: &[DbRow],
    current: &[DbRow],
    filter: &RowFilter,
    cfg: &RegressionConfig,
) -> Vec<Regression> {
    let base = best_by_task_device(baseline, filter);
    let cur = best_by_task_device(current, filter);
    let mut out = Vec::new();
    for ((task_id, device), &baseline_speedup) in &base {
        if baseline_speedup <= 0.0 {
            continue;
        }
        match cur.get(&(task_id.clone(), device.clone())) {
            Some(&current_speedup) => {
                let drop_frac = 1.0 - current_speedup / baseline_speedup;
                if drop_frac > cfg.max_speedup_drop {
                    out.push(Regression {
                        task_id: task_id.clone(),
                        device: device.clone(),
                        baseline_speedup,
                        current_speedup,
                        drop_frac,
                        missing: false,
                    });
                }
            }
            None if cfg.missing_is_regression => out.push(Regression {
                task_id: task_id.clone(),
                device: device.clone(),
                baseline_speedup,
                current_speedup: 0.0,
                drop_frac: 1.0,
                missing: true,
            }),
            None => {}
        }
    }
    out.sort_by(|a, b| b.drop_frac.total_cmp(&a.drop_frac));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(run: &str, task: &str, speedup: f64) -> DbRow {
        DbRow {
            run: run.to_string(),
            method: "service".to_string(),
            idx: 0,
            task_id: task.to_string(),
            genome_id: 1,
            produced_by: "gpt-4.1".to_string(),
            outcome: "correct".to_string(),
            coords: [0, 0, 0],
            fitness: 1.0,
            speedup,
            time_ms: 0.5,
            baseline_ms: 1.0,
        }
    }

    #[test]
    fn detects_drops_beyond_tolerance_only() {
        let base = vec![
            row("cat:a|b580|sycl|s1|i2|p2", "a", 2.0),
            row("cat:b|b580|sycl|s1|i2|p2", "b", 2.0),
        ];
        let cur = vec![
            row("cat:a|b580|sycl|s1|i2|p2", "a", 1.0), // 50% drop
            row("cat:b|b580|sycl|s1|i2|p2", "b", 1.9), // 5% drop, tolerated
        ];
        let found = detect(&base, &cur, &RowFilter::default(), &RegressionConfig::default());
        assert_eq!(found.len(), 1);
        assert_eq!(found[0].task_id, "a");
        assert_eq!(found[0].device, "b580");
        assert!((found[0].drop_frac - 0.5).abs() < 1e-12);
    }

    #[test]
    fn missing_keys_regress_unless_disabled() {
        let base = vec![row("cat:a|b580|sycl|s1|i2|p2", "a", 2.0)];
        let cur: Vec<DbRow> = Vec::new();
        let found = detect(&base, &cur, &RowFilter::default(), &RegressionConfig::default());
        assert_eq!(found.len(), 1);
        assert!(found[0].missing);
        let lax = RegressionConfig {
            missing_is_regression: false,
            ..RegressionConfig::default()
        };
        assert!(detect(&base, &cur, &RowFilter::default(), &lax).is_empty());
    }

    #[test]
    fn new_keys_and_improvements_never_regress() {
        let base = vec![row("cat:a|b580|sycl|s1|i2|p2", "a", 2.0)];
        let cur = vec![
            row("cat:a|b580|sycl|s1|i2|p2", "a", 3.0),
            row("cat:new|lnl|sycl|s1|i2|p2", "new", 0.5),
        ];
        assert!(detect(&base, &cur, &RowFilter::default(), &RegressionConfig::default()).is_empty());
    }

    #[test]
    fn identical_databases_pass() {
        let rows = vec![row("cat:a|b580|sycl|s1|i2|p2", "a", 2.0)];
        assert!(detect(&rows, &rows, &RowFilter::default(), &RegressionConfig::default()).is_empty());
    }
}
