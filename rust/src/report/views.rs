//! Typed, order-independent analytics views over the four JSONL
//! sources.
//!
//! Every view is a *fold*: it buckets rows/events by a key and combines
//! within each bucket with commutative operations (min, max, count,
//! per-bucket sort), so the result is independent of file order — the
//! property `tests/report_suite.rs` pins. The one deliberate exception
//! is [`ReliabilityView`]: the journal is a write-ahead log whose
//! *sequence* carries meaning (a lease following a different owner's
//! lease without a release is a takeover), so that view folds in record
//! order.

use super::history::{SearchLog, SearchStatsRow};
use crate::dist::{Database, DbRow};
use crate::obs::alerts::{AlertLog, AlertTransition};
use crate::obs::trace::{stage, TraceEvent, TraceSink};
use crate::service::journal::{Journal, JournalRecord};
use crate::tasks::catalog;
use crate::util::stats::percentile;
use std::collections::BTreeMap;
use std::path::Path;

/// Everything `kernelfoundry report` reads: the four JSONL sources,
/// each optional (an unset source yields empty views, never an error).
#[derive(Default)]
pub struct Artifacts {
    /// Results-database rows (`--db`).
    pub rows: Vec<DbRow>,
    /// Job-lifecycle trace events (`--trace`).
    pub events: Vec<TraceEvent>,
    /// Write-ahead journal records (`--journal`).
    pub journal: Vec<JournalRecord>,
    /// Per-generation search-history rows (`--search-log`).
    pub search: Vec<SearchStatsRow>,
    /// SLO alert transitions (`--alert-log`).
    pub alerts: Vec<AlertTransition>,
}

impl Artifacts {
    /// Load every configured source. A `None` path loads nothing; a
    /// missing trace/journal/search file is an empty source (they are
    /// all optional sidecars); a missing or corrupt database is an
    /// error (it is the primary source).
    pub fn load(
        db: Option<&Path>,
        trace: Option<&Path>,
        journal: Option<&Path>,
        search: Option<&Path>,
        alerts: Option<&Path>,
    ) -> Result<Artifacts, String> {
        let mut a = Artifacts::default();
        if let Some(path) = db {
            let store = Database::new();
            store.load(path).map_err(|e| e.to_string())?;
            a.rows = store.rows();
        }
        if let Some(path) = trace {
            a.events = TraceSink::load(path);
        }
        if let Some(path) = journal {
            if path.exists() {
                a.journal = Journal::load_records(path).map_err(|e| e.to_string())?;
            }
        }
        if let Some(path) = search {
            a.search = SearchLog::load(path);
        }
        if let Some(path) = alerts {
            a.alerts = AlertLog::load(path);
        }
        Ok(a)
    }
}

/// The device a database row ran on. Service cache rows carry the full
/// cache key (`fp|device|language|s..|i..|p..`) in `run`; rows from the
/// `serve` subcommand carry no device.
pub fn row_device(row: &DbRow) -> Option<&str> {
    if row.run.contains('|') {
        row.run.split('|').nth(1)
    } else {
        None
    }
}

/// The suite a row's task belongs to, when the task is in the catalog.
pub fn row_suite(row: &DbRow) -> Option<&'static str> {
    catalog::find_task(&row.task_id).map(|t| t.suite.name())
}

/// Canonicalize a `--suite` filter argument: short CLI names (`l1`,
/// `l2`, `rkb`, `onednn`, `custom`, matching `kernelfoundry tasks`) map
/// to the catalog suite names; full names pass through.
pub fn canonical_suite(arg: &str) -> String {
    match arg {
        "l1" => "kernelbench-l1".to_string(),
        "l2" => "kernelbench-l2".to_string(),
        "rkb" => "robust-kbench".to_string(),
        other => other.to_string(),
    }
}

/// Row filter shared by `report` and the regression detector.
#[derive(Debug, Clone, Default)]
pub struct RowFilter {
    /// Keep only rows that ran on this device (`None` = all).
    pub device: Option<String>,
    /// Keep only rows whose task belongs to this suite (`None` = all).
    pub suite: Option<String>,
}

impl RowFilter {
    /// Whether a row passes the filter. A device filter drops rows
    /// whose device is unknown (no `|`-keyed run); a suite filter drops
    /// rows whose task is not in the catalog.
    pub fn matches(&self, row: &DbRow) -> bool {
        if let Some(want) = &self.device {
            if row_device(row) != Some(want.as_str()) {
                return false;
            }
        }
        if let Some(want) = &self.suite {
            if row_suite(row) != Some(want.as_str()) {
                return false;
            }
        }
        true
    }
}

/// Best fitness/speedup pair under the engine's best-kernel rule
/// (max fitness, ties broken by speedup).
fn better(a: (f64, f64), b: (f64, f64)) -> (f64, f64) {
    if b.0 > a.0 || (b.0 == a.0 && b.1 > a.1) {
        b
    } else {
        a
    }
}

/// One (task, cell, device) trajectory: its all-time best and the
/// per-run bests it moved through.
#[derive(Debug, Clone, PartialEq)]
pub struct TrajectoryPoint {
    /// Task id.
    pub task_id: String,
    /// MAP-Elites cell coordinates.
    pub coords: [usize; 3],
    /// Device name, `-` when the row's run carries none.
    pub device: String,
    /// Best fitness across all runs.
    pub best_fitness: f64,
    /// Best speedup across all runs (paired with `best_fitness` by the
    /// engine's fitness-then-speedup rule).
    pub best_speedup: f64,
    /// Per-run best speedup, sorted by run id.
    pub runs: Vec<(String, f64)>,
    /// Run-over-run delta: last run's best speedup minus the previous
    /// run's (0 with fewer than two runs).
    pub delta: f64,
    /// Correct rows folded into this point.
    pub n_rows: usize,
}

/// Speedup trajectories: best-per-(task, cell, device) over time.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TrajectoryView {
    /// One point per occupied (task, cell, device), sorted by key.
    pub points: Vec<TrajectoryPoint>,
}

impl TrajectoryView {
    /// Fold correct rows into trajectories. Order-independent: every
    /// per-bucket combine is a commutative max, and run ordering comes
    /// from sorting run ids, not file order.
    pub fn build(rows: &[DbRow]) -> TrajectoryView {
        type Key = (String, [usize; 3], String);
        let mut buckets: BTreeMap<Key, (BTreeMap<String, (f64, f64)>, usize)> = BTreeMap::new();
        for row in rows.iter().filter(|r| r.is_correct()) {
            let device = row_device(row).unwrap_or("-").to_string();
            let key = (row.task_id.clone(), row.coords, device);
            let (per_run, n) = buckets.entry(key).or_default();
            let entry = per_run.entry(row.run.clone()).or_insert((f64::NEG_INFINITY, 0.0));
            *entry = better(*entry, (row.fitness, row.speedup));
            *n += 1;
        }
        let points = buckets
            .into_iter()
            .map(|((task_id, coords, device), (per_run, n_rows))| {
                let best = per_run
                    .values()
                    .copied()
                    .fold((f64::NEG_INFINITY, 0.0), better);
                let runs: Vec<(String, f64)> =
                    per_run.into_iter().map(|(run, (_f, s))| (run, s)).collect();
                let delta = if runs.len() >= 2 {
                    runs[runs.len() - 1].1 - runs[runs.len() - 2].1
                } else {
                    0.0
                };
                TrajectoryPoint {
                    task_id,
                    coords,
                    device,
                    best_fitness: best.0,
                    best_speedup: best.1,
                    runs,
                    delta,
                    n_rows,
                }
            })
            .collect();
        TrajectoryView { points }
    }
}

/// The per-stage latency segments derived from trace events:
/// (label, from-stage, to-stage).
pub const STAGE_SEGMENTS: &[(&str, &str, &str)] = &[
    ("queue-wait", stage::QUEUED, stage::DISPATCHED),
    ("compile", stage::DISPATCHED, stage::COMPILED),
    ("exec", stage::COMPILED, stage::EXECUTED),
    ("commit", stage::EXECUTED, stage::COMMITTED),
];

/// Raw per-(device, segment) latency samples, in milliseconds.
///
/// For each job: the segment start is the earliest matching event (the
/// `queued` start is job-scoped; every other stage is scoped to the
/// device lane that emitted it), the end is that device's earliest
/// end-stage event. Earliest-event selection makes the fold
/// order-independent; segments whose endpoints are missing or inverted
/// (merged sinks with skewed clocks) are skipped rather than invented.
pub fn stage_deltas(events: &[TraceEvent]) -> BTreeMap<(String, String), Vec<f64>> {
    // (job) -> queued ts; (job, device) -> stage -> min ts.
    let mut queued: BTreeMap<u64, f64> = BTreeMap::new();
    let mut by_lane: BTreeMap<(u64, String), BTreeMap<&str, f64>> = BTreeMap::new();
    for ev in events {
        if ev.stage == stage::QUEUED {
            let entry = queued.entry(ev.job_id).or_insert(f64::INFINITY);
            *entry = entry.min(ev.ts_ms);
        }
        if let Some(device) = &ev.device {
            let lane = by_lane.entry((ev.job_id, device.clone())).or_default();
            for (_, from, to) in STAGE_SEGMENTS {
                if ev.stage == *from || ev.stage == *to {
                    let entry = lane.entry(if ev.stage == *from { *from } else { *to });
                    let slot = entry.or_insert(f64::INFINITY);
                    *slot = slot.min(ev.ts_ms);
                }
            }
        }
    }
    let mut out: BTreeMap<(String, String), Vec<f64>> = BTreeMap::new();
    for ((job, device), lane) in &by_lane {
        for (label, from, to) in STAGE_SEGMENTS {
            let start = if *from == stage::QUEUED {
                queued.get(job).copied()
            } else {
                lane.get(from).copied()
            };
            let (Some(start), Some(end)) = (start, lane.get(to).copied()) else {
                continue;
            };
            if !start.is_finite() || !end.is_finite() || end < start {
                continue;
            }
            out.entry((device.clone(), label.to_string()))
                .or_default()
                .push(end - start);
        }
    }
    // Deterministic sample order regardless of event order.
    for samples in out.values_mut() {
        samples.sort_by(f64::total_cmp);
    }
    out
}

/// Latency summary of one (device, segment) pair.
#[derive(Debug, Clone, PartialEq)]
pub struct LatencyLane {
    /// Device lane.
    pub device: String,
    /// Segment label (see [`STAGE_SEGMENTS`]).
    pub segment: String,
    /// Samples folded in.
    pub n: usize,
    /// Median, ms.
    pub p50: f64,
    /// 90th percentile, ms.
    pub p90: f64,
    /// 99th percentile, ms.
    pub p99: f64,
    /// Minimum, ms.
    pub min: f64,
    /// Maximum, ms.
    pub max: f64,
}

/// Latency breakdown: queue-wait / compile / exec / commit percentiles
/// per device lane.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LatencyView {
    /// One summary per (device, segment) with at least one sample.
    pub lanes: Vec<LatencyLane>,
}

impl LatencyView {
    /// Summarize [`stage_deltas`] into percentiles.
    pub fn build(events: &[TraceEvent]) -> LatencyView {
        let lanes = stage_deltas(events)
            .into_iter()
            .map(|((device, segment), samples)| LatencyLane {
                device,
                segment,
                n: samples.len(),
                p50: percentile(&samples, 50.0),
                p90: percentile(&samples, 90.0),
                p99: percentile(&samples, 99.0),
                min: samples[0],
                max: samples[samples.len() - 1],
            })
            .collect();
        LatencyView { lanes }
    }
}

/// Reliability counters folded from the write-ahead journal.
///
/// Unlike the other views this fold is order-*dependent* by design: the
/// journal is a log whose sequence carries meaning (ownership changes,
/// dispatch-before-commit), so records are consumed in write order.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ReliabilityView {
    /// `submit` records (jobs accepted).
    pub submits: usize,
    /// `dispatch` records (units handed to a lane).
    pub dispatches: usize,
    /// `commit` records (units durably published).
    pub commits: usize,
    /// `fail` records.
    pub fails: usize,
    /// `retry` records (transient failures re-enqueued with backoff).
    pub retries: usize,
    /// `reroute` records (units moved off a quarantined lane).
    pub reroutes: usize,
    /// `quarantine` records (units committed as deterministic failures
    /// after exhausting a retry budget).
    pub quarantines: usize,
    /// Units cancelled (summed over `cancel` records' device lists).
    pub cancelled_units: usize,
    /// Extra `dispatch` records for a unit already dispatched once —
    /// crash-replay re-runs (at-least-once execution made visible).
    pub replayed_dispatches: usize,
    /// Units dispatched but never committed / failed / cancelled by the
    /// end of the log: in flight at a crash or shutdown.
    pub lost_units: usize,
    /// Distinct owner acquisitions (initial lease per owner session).
    pub sessions: usize,
    /// Clean `release` records.
    pub clean_releases: usize,
    /// A `lease` by a new owner while another owner held the journal
    /// (no intervening `release`): a stale-lease takeover.
    pub lease_takeovers: usize,
}

impl ReliabilityView {
    /// Fold the record stream. `sessions - clean_releases` counts
    /// unclean endings (crashes plus any currently-live owner).
    pub fn build(records: &[JournalRecord]) -> ReliabilityView {
        let mut v = ReliabilityView::default();
        let mut owner: Option<&str> = None;
        // (job, device) -> (dispatches, reached a terminal record,
        // re-dispatch announced by a retry/reroute record).
        let mut units: BTreeMap<(u64, &str), (usize, bool, bool)> = BTreeMap::new();
        for rec in records {
            match rec {
                JournalRecord::Lease { owner: o, .. } => {
                    match owner {
                        Some(cur) if cur == o.as_str() => {} // heartbeat
                        Some(_) => {
                            v.lease_takeovers += 1;
                            v.sessions += 1;
                            owner = Some(o.as_str());
                        }
                        None => {
                            v.sessions += 1;
                            owner = Some(o.as_str());
                        }
                    }
                }
                JournalRecord::Release { owner: o, .. } => {
                    if owner == Some(o.as_str()) {
                        v.clean_releases += 1;
                        owner = None;
                    }
                }
                JournalRecord::Submit { .. } => v.submits += 1,
                JournalRecord::Dispatch { job_id, device } => {
                    v.dispatches += 1;
                    let unit = units.entry((*job_id, device.as_str())).or_default();
                    // A re-dispatch with no announcing retry/reroute
                    // record is a crash replay; announced ones are the
                    // retry machinery working as designed.
                    if unit.0 > 0 && !unit.1 && !unit.2 {
                        v.replayed_dispatches += 1;
                    }
                    unit.0 += 1;
                    unit.1 = false; // a re-dispatch reopens the unit
                    unit.2 = false;
                }
                JournalRecord::Commit { job_id, device, .. } => {
                    v.commits += 1;
                    units.entry((*job_id, device.as_str())).or_default().1 = true;
                }
                JournalRecord::Fail { job_id, device, .. } => {
                    v.fails += 1;
                    units.entry((*job_id, device.as_str())).or_default().1 = true;
                }
                JournalRecord::Retry { job_id, device, .. } => {
                    // The failed attempt stays counted as a dispatch;
                    // the retry reopens the unit (a re-dispatch or a
                    // quarantine must follow).
                    v.retries += 1;
                    let unit = units.entry((*job_id, device.as_str())).or_default();
                    unit.1 = false;
                    unit.2 = true;
                }
                JournalRecord::Reroute { job_id, from, to } => {
                    // Move the unit's lineage to its new lane so the
                    // eventual commit there closes it.
                    v.reroutes += 1;
                    let moved = units.remove(&(*job_id, from.as_str())).unwrap_or_default();
                    let unit = units.entry((*job_id, to.as_str())).or_default();
                    unit.0 += moved.0;
                    unit.1 = false;
                    unit.2 = true;
                }
                JournalRecord::Quarantine { job_id, device, .. } => {
                    v.quarantines += 1;
                    units.entry((*job_id, device.as_str())).or_default().1 = true;
                }
                JournalRecord::Cancel { job_id, devices } => {
                    v.cancelled_units += devices.len();
                    for device in devices {
                        units.entry((*job_id, device.as_str())).or_default().1 = true;
                    }
                }
            }
        }
        v.lost_units = units.values().filter(|(d, done, _)| *d > 0 && !done).count();
        v
    }

    /// Unclean session endings: owner acquisitions never released.
    pub fn unclean_sessions(&self) -> usize {
        self.sessions.saturating_sub(self.clean_releases)
    }
}

/// One run's search-health curves, indexed by generation.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchRunCurve {
    /// Run identifier (the fleet's cache key, or the CLI run label).
    pub run: String,
    /// Task id.
    pub task_id: String,
    /// Device name.
    pub device: String,
    /// QD-score per generation.
    pub qd_curve: Vec<f64>,
    /// Coverage per generation.
    pub coverage_curve: Vec<f64>,
    /// Acceptance rate per generation.
    pub acceptance_curve: Vec<f64>,
    /// Best speedup per generation.
    pub best_speedup_curve: Vec<f64>,
    /// Evaluations at the last generation.
    pub evaluations: usize,
}

impl SearchRunCurve {
    /// Generations recorded.
    pub fn generations(&self) -> usize {
        self.qd_curve.len()
    }

    /// Final value of a curve (0 when empty).
    pub fn final_of(curve: &[f64]) -> f64 {
        curve.last().copied().unwrap_or(0.0)
    }
}

/// Search health: QD-score / coverage / acceptance curves per
/// generation per run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SearchHealthView {
    /// One curve set per run, sorted by run id.
    pub runs: Vec<SearchRunCurve>,
}

impl SearchHealthView {
    /// Fold rows into per-run curves. Order-independent: rows bucket by
    /// run and sort by generation; a duplicated generation (the same
    /// run re-executed after crash replay) keeps the later recording
    /// (max `ts_ms`, ties by max attempts).
    pub fn build(rows: &[SearchStatsRow]) -> SearchHealthView {
        let mut by_run: BTreeMap<String, BTreeMap<usize, SearchStatsRow>> = BTreeMap::new();
        for row in rows {
            let gens = by_run.entry(row.run.clone()).or_default();
            match gens.get(&row.generation) {
                Some(cur)
                    if (cur.ts_ms, cur.attempts) >= (row.ts_ms, row.attempts) => {}
                _ => {
                    gens.insert(row.generation, row.clone());
                }
            }
        }
        let runs = by_run
            .into_iter()
            .map(|(run, gens)| {
                let ordered: Vec<&SearchStatsRow> = gens.values().collect();
                let last = ordered.last().expect("non-empty bucket");
                SearchRunCurve {
                    run,
                    task_id: last.task_id.clone(),
                    device: last.device.clone(),
                    qd_curve: ordered.iter().map(|r| r.qd_score).collect(),
                    coverage_curve: ordered.iter().map(|r| r.coverage).collect(),
                    acceptance_curve: ordered.iter().map(|r| r.acceptance).collect(),
                    best_speedup_curve: ordered.iter().map(|r| r.best_speedup).collect(),
                    evaluations: last.evaluations,
                }
            })
            .collect();
        SearchHealthView { runs }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn db_row(run: &str, task: &str, coords: [usize; 3], fitness: f64, speedup: f64) -> DbRow {
        DbRow {
            run: run.to_string(),
            method: "service".to_string(),
            idx: 0,
            task_id: task.to_string(),
            genome_id: 1,
            produced_by: "gpt-4.1".to_string(),
            outcome: "correct".to_string(),
            coords,
            fitness,
            speedup,
            time_ms: 0.5,
            baseline_ms: 1.0,
        }
    }

    fn ev(stage_name: &str, job: u64, device: Option<&str>, ts: f64) -> TraceEvent {
        TraceEvent {
            stage: stage_name.to_string(),
            job_id: job,
            trace_id: "t".to_string(),
            device: device.map(str::to_string),
            ts_ms: ts,
        }
    }

    #[test]
    fn trajectory_extracts_device_and_run_deltas() {
        let rows = vec![
            db_row("cat:t|b580|sycl|s1|i2|p2", "t", [0, 0, 0], 0.8, 1.5),
            db_row("cat:t|b580|sycl|s2|i2|p2", "t", [0, 0, 0], 0.9, 2.0),
            db_row("serve", "t", [0, 0, 0], 0.7, 1.2), // no device
        ];
        let v = TrajectoryView::build(&rows);
        assert_eq!(v.points.len(), 2, "device-less rows bucket separately");
        let b580 = v.points.iter().find(|p| p.device == "b580").unwrap();
        assert_eq!(b580.best_speedup, 2.0);
        assert_eq!(b580.runs.len(), 2);
        assert!((b580.delta - 0.5).abs() < 1e-12, "run-over-run delta");
        let bare = v.points.iter().find(|p| p.device == "-").unwrap();
        assert_eq!(bare.best_speedup, 1.2);
    }

    #[test]
    fn trajectory_skips_incorrect_rows() {
        let mut bad = db_row("r", "t", [0, 0, 0], 0.2, 0.0);
        bad.outcome = "compile_error".to_string();
        assert!(TrajectoryView::build(&[bad]).points.is_empty());
    }

    #[test]
    fn latency_segments_per_device() {
        let events = vec![
            ev(stage::SUBMIT, 1, None, 0.0),
            ev(stage::QUEUED, 1, None, 1.0),
            ev(stage::DISPATCHED, 1, Some("b580"), 4.0),
            ev(stage::COMPILED, 1, Some("b580"), 6.0),
            ev(stage::EXECUTED, 1, Some("b580"), 16.0),
            ev(stage::COMMITTED, 1, Some("b580"), 17.0),
            ev(stage::DISPATCHED, 1, Some("lnl"), 2.0),
            ev(stage::COMPILED, 1, Some("lnl"), 3.0),
        ];
        let v = LatencyView::build(&events);
        let lane = |d: &str, s: &str| v.lanes.iter().find(|l| l.device == d && l.segment == s);
        assert_eq!(lane("b580", "queue-wait").unwrap().p50, 3.0);
        assert_eq!(lane("b580", "compile").unwrap().p50, 2.0);
        assert_eq!(lane("b580", "exec").unwrap().p50, 10.0);
        assert_eq!(lane("b580", "commit").unwrap().p50, 1.0);
        assert_eq!(lane("lnl", "queue-wait").unwrap().p50, 1.0);
        assert_eq!(lane("lnl", "compile").unwrap().p50, 1.0);
        assert!(lane("lnl", "exec").is_none(), "open segments are skipped");
    }

    #[test]
    fn reliability_counts_takeovers_replays_and_losses() {
        let lease = |o: &str, ts: f64| JournalRecord::Lease {
            owner: o.to_string(),
            ts_ms: ts,
        };
        let dispatch = |job: u64| JournalRecord::Dispatch {
            job_id: job,
            device: "b580".to_string(),
        };
        let records = vec![
            lease("a", 1.0),
            lease("a", 2.0), // heartbeat, not a session
            dispatch(1),
            dispatch(2),
            lease("b", 3.0), // stale takeover: no release from "a"
            dispatch(1),     // replayed after the crash
            JournalRecord::Fail {
                job_id: 1,
                device: "b580".to_string(),
                error: "x".to_string(),
            },
            JournalRecord::Release {
                owner: "b".to_string(),
                ts_ms: 4.0,
            },
        ];
        let v = ReliabilityView::build(&records);
        assert_eq!(v.sessions, 2);
        assert_eq!(v.lease_takeovers, 1);
        assert_eq!(v.clean_releases, 1);
        assert_eq!(v.unclean_sessions(), 1);
        assert_eq!(v.replayed_dispatches, 1);
        assert_eq!(v.fails, 1);
        assert_eq!(v.lost_units, 1, "job 2 never reached a terminal record");
    }

    #[test]
    fn reliability_folds_retry_reroute_and_quarantine_lineage() {
        let dispatch = |job: u64, device: &str| JournalRecord::Dispatch {
            job_id: job,
            device: device.to_string(),
        };
        let result = |device: &str| crate::service::DeviceResult {
            device: device.to_string(),
            task_id: "20_LeakyReLU".to_string(),
            correct: true,
            fitness: 0.9,
            speedup: 1.5,
            time_ms: 0.4,
            baseline_ms: 0.6,
            coords: [0, 0, 0],
            genome_id: 1,
            produced_by: "m".to_string(),
            source: String::new(),
            evaluations: 4,
            compile_errors: 0,
            incorrect: 0,
            cached: false,
            wall_ms: 5.0,
        };
        let records = vec![
            // Job 1: fails transiently, retries, commits on re-dispatch.
            dispatch(1, "b580"),
            JournalRecord::Retry {
                job_id: 1,
                device: "b580".to_string(),
                attempt: 1,
                error: "flaky".to_string(),
            },
            dispatch(1, "b580"),
            JournalRecord::Commit {
                job_id: 1,
                device: "b580".to_string(),
                result: result("b580"),
            },
            // Job 2: exhausts its budget and is quarantined (terminal).
            dispatch(2, "b580"),
            JournalRecord::Quarantine {
                job_id: 2,
                device: "b580".to_string(),
                error: "dead".to_string(),
                attempts: 3,
            },
            // Job 3: rerouted off b580 before dispatch, commits on lnl.
            JournalRecord::Reroute {
                job_id: 3,
                from: "b580".to_string(),
                to: "lnl".to_string(),
            },
            dispatch(3, "lnl"),
            JournalRecord::Commit {
                job_id: 3,
                device: "lnl".to_string(),
                result: result("lnl"),
            },
        ];
        let v = ReliabilityView::build(&records);
        assert_eq!(v.retries, 1);
        assert_eq!(v.quarantines, 1);
        assert_eq!(v.reroutes, 1);
        assert_eq!(v.commits, 2);
        assert_eq!(
            v.replayed_dispatches, 0,
            "a retry re-dispatch is deliberate, not a crash replay"
        );
        assert_eq!(v.lost_units, 0, "every fault path reached a terminal record");
    }

    #[test]
    fn search_health_orders_generations_and_dedupes_replays() {
        let mk = |generation: usize, qd: f64, ts: f64| SearchStatsRow {
            run: "r".to_string(),
            task_id: "t".to_string(),
            device: "b580".to_string(),
            generation,
            qd_score: qd,
            coverage: 0.1,
            best_fitness: 0.5,
            best_speedup: 1.1,
            acceptance: 0.5,
            insertions: 1,
            attempts: 2,
            occupied: 1,
            evaluations: 4,
            ts_ms: ts,
        };
        // Shuffled generations + a replayed generation 0 (later ts wins).
        let rows = vec![mk(1, 2.0, 10.0), mk(0, 1.0, 5.0), mk(0, 1.5, 20.0)];
        let v = SearchHealthView::build(&rows);
        assert_eq!(v.runs.len(), 1);
        assert_eq!(v.runs[0].qd_curve, vec![1.5, 2.0]);
        assert_eq!(v.runs[0].generations(), 2);
    }

    #[test]
    fn row_filters_by_device_and_suite() {
        let service_row = db_row("cat:20_LeakyReLU|lnl|sycl|s1|i2|p2", "20_LeakyReLU", [0; 3], 0.5, 1.0);
        let f = RowFilter {
            device: Some("lnl".to_string()),
            suite: Some(canonical_suite("l1")),
        };
        assert!(f.matches(&service_row));
        let other_dev = RowFilter {
            device: Some("b580".to_string()),
            ..RowFilter::default()
        };
        assert!(!other_dev.matches(&service_row));
        let wrong_suite = RowFilter {
            suite: Some(canonical_suite("onednn")),
            ..RowFilter::default()
        };
        assert!(!wrong_suite.matches(&service_row));
    }
}
