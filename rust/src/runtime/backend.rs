//! [`PjrtBackend`]: the real execution backend for the evaluation
//! pipeline — genomes map to AOT-compiled kernel variants, outputs are
//! validated against the reference artifact with the paper's ν-criterion
//! and timed with the App. B.2 harness.

use super::manifest::{ArtifactInfo, Manifest};
use super::pjrt::PjrtRuntime;
use crate::eval::{BenchConfig, Benchmarker, RealBackend, RealRun};
use crate::ir::{AlgoStructure, KernelGenome};
use crate::tasks::TaskSpec;
use crate::util::error::{Error, Result};
use std::collections::HashMap;

/// Real backend over the artifact library.
pub struct PjrtBackend {
    pub manifest: Manifest,
    runtime: PjrtRuntime,
    bench: Benchmarker,
    baseline_cache: HashMap<String, f64>,
}

impl PjrtBackend {
    pub fn new(manifest: Manifest) -> Result<PjrtBackend> {
        Ok(PjrtBackend {
            manifest,
            runtime: PjrtRuntime::cpu()?,
            bench: Benchmarker::new(BenchConfig::quick()),
            baseline_cache: HashMap::new(),
        })
    }

    /// Map a genome to the artifact variant it denotes. The genome's
    /// algorithmic level selects the variant family; its parameters pick
    /// the nearest available instantiation — the same role the §3.4
    /// dispatcher plays for templated kernels.
    pub fn resolve(&self, task: &str, genome: &KernelGenome) -> Result<&ArtifactInfo> {
        let variants = self.manifest.variants_for(task);
        if variants.is_empty() {
            return Err(Error::msg(format!("no variants for task {task}")));
        }
        let fused = !matches!(genome.algo, AlgoStructure::DirectTranslation);
        let reformulated = matches!(
            genome.algo,
            AlgoStructure::Reformulated | AlgoStructure::Novel
        );
        let chosen = match task {
            "llama_rope" => {
                let family = if fused { "rope_fused" } else { "rope_naive" };
                let cands: Vec<&ArtifactInfo> = variants
                    .iter()
                    .copied()
                    .filter(|a| a.name.starts_with(family))
                    .collect();
                pick_nearest(cands, "bs", genome.params.tile_m as usize)
            }
            "softmax_real" => {
                let family = if reformulated { "online" } else { "twopass" };
                let cands: Vec<&ArtifactInfo> = variants
                    .iter()
                    .copied()
                    .filter(|a| a.param_str("algo") == Some(family))
                    .collect();
                pick_nearest(cands, "br", genome.params.tile_m as usize)
            }
            "matmul_real" => pick_nearest(variants.clone(), "bm", genome.params.tile_m as usize),
            "fused_chain_real" => variants
                .iter()
                .copied()
                .find(|a| a.param_usize("fused").unwrap_or(0) == if fused { 1 } else { 0 }),
            "concat_layernorm_real" | "sum_reduction_real" => {
                pick_nearest(variants.clone(), "br", genome.params.tile_m as usize)
            }
            "block_fwd" => variants.first().copied(),
            _ => variants.first().copied(),
        };
        chosen.ok_or_else(|| Error::msg(format!("no matching variant for task {task}")))
    }

    fn time_artifact(&mut self, art: &ArtifactInfo) -> Result<f64> {
        // Warm the caches before entering the harness.
        self.runtime.load(art)?;
        let _ = self.runtime.execute(art)?;
        let runtime = &mut self.runtime;
        let mut err: Option<Error> = None;
        let mut source = |iters: usize| -> f64 {
            match runtime.time_batch(art, iters) {
                Ok(ms) => ms,
                Err(e) => {
                    err = Some(e);
                    f64::INFINITY
                }
            }
        };
        let result = self.bench.run(&mut source);
        if let Some(e) = err {
            return Err(e);
        }
        Ok(result.time_ms)
    }
}

/// Nearest-parameter variant selection (the §3.4 dispatch rule).
fn pick_nearest<'a>(
    cands: Vec<&'a ArtifactInfo>,
    key: &str,
    target: usize,
) -> Option<&'a ArtifactInfo> {
    cands.into_iter().min_by_key(|a| {
        a.param_usize(key)
            .map(|v| v.abs_diff(target))
            .unwrap_or(usize::MAX)
    })
}

impl RealBackend for PjrtBackend {
    fn device_description(&self) -> String {
        format!("PJRT CPU backend: {}", self.runtime.platform())
    }

    fn baseline_ms(&mut self, task: &TaskSpec) -> Result<f64> {
        if let Some(t) = self.baseline_cache.get(&task.id) {
            return Ok(*t);
        }
        let reference = self
            .manifest
            .reference_for(&task.id)
            .ok_or_else(|| Error::msg(format!("no reference artifact for {}", task.id)))?
            .clone();
        let t = self.time_artifact(&reference)?;
        self.baseline_cache.insert(task.id.clone(), t);
        Ok(t)
    }

    fn run(&mut self, task: &TaskSpec, genome: &KernelGenome) -> Result<RealRun> {
        let reference = self
            .manifest
            .reference_for(&task.id)
            .ok_or_else(|| Error::msg(format!("no reference artifact for {}", task.id)))?
            .clone();
        let variant = self.resolve(&task.id, genome)?.clone();
        let expected: Vec<f32> = self.runtime.execute(&reference)?.concat();
        let actual: Vec<f32> = self.runtime.execute(&variant)?.concat();
        let time_ms = self.time_artifact(&variant)?;
        Ok(RealRun {
            expected,
            actual,
            time_ms,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::MemoryPattern;
    use crate::tasks::{OpSpec, Suite, TaskSpec};
    use std::path::Path;

    fn backend() -> Option<PjrtBackend> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            Some(PjrtBackend::new(Manifest::load(&dir).unwrap()).unwrap())
        } else {
            None
        }
    }

    fn rope_task() -> TaskSpec {
        TaskSpec::new(
            "llama_rope",
            Suite::Custom,
            vec![OpSpec::Rope { elems: 2 * 4 * 128 * 64 }],
        )
    }

    #[test]
    fn resolve_picks_family_and_nearest_params() {
        let Some(b) = backend() else { return };
        let mut g = KernelGenome::direct_translation("llama_rope");
        g.params.tile_m = 30;
        let naive = b.resolve("llama_rope", &g).unwrap();
        assert!(naive.name.starts_with("rope_naive"));
        assert_eq!(naive.param_usize("bs"), Some(32));
        g.algo = AlgoStructure::Fused;
        g.params.tile_m = 60;
        let fusedv = b.resolve("llama_rope", &g).unwrap();
        assert_eq!(fusedv.name, "rope_fused_bs64");
    }

    #[test]
    fn real_run_is_correct_and_timed() {
        let Some(mut b) = backend() else { return };
        let task = rope_task();
        let mut g = KernelGenome::direct_translation(&task.id);
        g.algo = AlgoStructure::Fused;
        g.mem = MemoryPattern::Coalesced;
        g.params.tile_m = 32;
        let run = b.run(&task, &g).unwrap();
        let rep = crate::eval::check_correctness(&run.expected, &run.actual);
        assert!(rep.correct, "{rep:?}");
        assert!(run.time_ms > 0.0);
        let baseline = b.baseline_ms(&task).unwrap();
        assert!(baseline > 0.0);
    }
}
