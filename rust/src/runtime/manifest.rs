//! Artifact manifest: the python→rust interchange contract.

use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Input tensor spec: deterministic normal values from `seed`.
#[derive(Debug, Clone, PartialEq)]
pub struct InputSpec {
    pub shape: Vec<usize>,
    pub seed: u64,
}

impl InputSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled artifact.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: PathBuf,
    pub task: String,
    /// "reference" (baseline + expected outputs) or "variant".
    pub role: String,
    pub params: Json,
    pub inputs: Vec<InputSpec>,
}

impl ArtifactInfo {
    pub fn param_usize(&self, key: &str) -> Option<usize> {
        self.params.get(key).and_then(|v| v.as_usize())
    }

    pub fn param_str(&self, key: &str) -> Option<&str> {
        self.params.get(key).and_then(|v| v.as_str())
    }
}

/// The parsed manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub fingerprint: String,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
}

#[derive(Debug)]
pub enum ManifestError {
    Io(std::io::Error),
    Json(json::ParseError),
    Structure(String),
}

impl std::fmt::Display for ManifestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ManifestError::Io(e) => write!(f, "io: {e}"),
            ManifestError::Json(e) => write!(f, "json: {e}"),
            ManifestError::Structure(s) => write!(f, "manifest structure: {s}"),
        }
    }
}

impl std::error::Error for ManifestError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ManifestError::Io(e) => Some(e),
            ManifestError::Json(e) => Some(e),
            ManifestError::Structure(_) => None,
        }
    }
}

impl From<std::io::Error> for ManifestError {
    fn from(e: std::io::Error) -> ManifestError {
        ManifestError::Io(e)
    }
}

impl From<json::ParseError> for ManifestError {
    fn from(e: json::ParseError) -> ManifestError {
        ManifestError::Json(e)
    }
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest, ManifestError> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest, ManifestError> {
        let doc = json::parse(text)?;
        let arts = doc
            .get("artifacts")
            .and_then(|a| a.as_obj())
            .ok_or_else(|| ManifestError::Structure("missing 'artifacts'".into()))?;
        let mut artifacts = BTreeMap::new();
        for (name, v) in arts {
            let get_str = |k: &str| -> Result<String, ManifestError> {
                v.get(k)
                    .and_then(|x| x.as_str())
                    .map(String::from)
                    .ok_or_else(|| ManifestError::Structure(format!("{name}: missing '{k}'")))
            };
            let inputs = v
                .get("inputs")
                .and_then(|x| x.as_arr())
                .ok_or_else(|| ManifestError::Structure(format!("{name}: missing inputs")))?
                .iter()
                .map(|i| {
                    let shape = i
                        .get("shape")
                        .and_then(|s| s.as_arr())
                        .map(|s| s.iter().filter_map(|d| d.as_usize()).collect())
                        .unwrap_or_default();
                    InputSpec {
                        shape,
                        seed: i.get("seed").and_then(|s| s.as_i64()).unwrap_or(1) as u64,
                    }
                })
                .collect();
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    name: name.clone(),
                    file: dir.join(get_str("file")?),
                    task: get_str("task")?,
                    role: get_str("role")?,
                    params: v.get("params").cloned().unwrap_or(Json::obj()),
                    inputs,
                },
            );
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            fingerprint: doc
                .get("fingerprint")
                .and_then(|f| f.as_str())
                .unwrap_or("")
                .to_string(),
            artifacts,
        })
    }

    /// The reference artifact for a task.
    pub fn reference_for(&self, task: &str) -> Option<&ArtifactInfo> {
        self.artifacts
            .values()
            .find(|a| a.task == task && a.role == "reference")
    }

    /// All variant artifacts for a task.
    pub fn variants_for(&self, task: &str) -> Vec<&ArtifactInfo> {
        self.artifacts
            .values()
            .filter(|a| a.task == task && a.role == "variant")
            .collect()
    }

    /// All distinct task names with a reference artifact.
    pub fn tasks(&self) -> Vec<String> {
        let mut v: Vec<String> = self
            .artifacts
            .values()
            .filter(|a| a.role == "reference")
            .map(|a| a.task.clone())
            .collect();
        v.sort();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "fingerprint": "abc",
      "artifacts": {
        "rope_ref": {"file": "rope_ref.hlo.txt", "task": "llama_rope", "role": "reference",
                      "params": {}, "inputs": [{"shape": [2,4,128,64], "seed": 1}]},
        "rope_fused_bs32": {"file": "rope_fused_bs32.hlo.txt", "task": "llama_rope",
                      "role": "variant", "params": {"bs": 32},
                      "inputs": [{"shape": [2,4,128,64], "seed": 1}]}
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.fingerprint, "abc");
        assert_eq!(m.artifacts.len(), 2);
        let r = m.reference_for("llama_rope").unwrap();
        assert_eq!(r.name, "rope_ref");
        assert_eq!(r.inputs[0].elements(), 2 * 4 * 128 * 64);
        let vs = m.variants_for("llama_rope");
        assert_eq!(vs.len(), 1);
        assert_eq!(vs[0].param_usize("bs"), Some(32));
        assert_eq!(m.tasks(), vec!["llama_rope".to_string()]);
    }

    #[test]
    fn real_manifest_if_built() {
        // Exercised against the actual artifacts when present.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.artifacts.len() >= 20);
            for task in ["llama_rope", "softmax_real", "matmul_real", "block_fwd"] {
                assert!(m.reference_for(task).is_some(), "missing reference for {task}");
            }
        }
    }
}
