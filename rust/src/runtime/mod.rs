//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! Python never runs at request time: `make artifacts` lowers the JAX/
//! Pallas kernels once to HLO *text* (see aot.py for why text, not
//! serialized protos), and this module compiles them on the PJRT CPU
//! client (`xla` crate) with a compile-once executable cache.

pub mod backend;
pub mod manifest;
pub mod pjrt;

pub use backend::PjrtBackend;
pub use manifest::{ArtifactInfo, Manifest};
pub use pjrt::PjrtRuntime;
