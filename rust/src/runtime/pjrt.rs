//! PJRT CPU client wrapper: compile-once executable cache + timed
//! execution of HLO-text artifacts.
//!
//! The real implementation needs the `xla` crate and is gated behind the
//! off-by-default `pjrt` cargo feature (the default build environment is
//! fully offline — see Cargo.toml). Without the feature a stub
//! [`PjrtRuntime`] with the identical API compiles in; every entry point
//! returns an error at run time, so artifact-driven tests, benches and
//! examples skip cleanly when `make artifacts` has not run.

#[cfg(feature = "pjrt")]
pub use real::PjrtRuntime;
#[cfg(not(feature = "pjrt"))]
pub use stub::PjrtRuntime;

#[cfg(feature = "pjrt")]
mod real {
    use crate::runtime::manifest::{ArtifactInfo, InputSpec};
    use crate::util::error::{Context, Result};
    use crate::util::rng::Rng;
    use std::collections::HashMap;
    use std::time::Instant;

    /// The runtime: one PJRT client, cached executables and cached input
    /// literals (inputs are deterministic per spec, so they are generated
    /// once and reused across timing iterations — no host churn on the hot
    /// path).
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
        executables: HashMap<String, xla::PjRtLoadedExecutable>,
        inputs: HashMap<String, Vec<xla::Literal>>,
    }

    impl PjrtRuntime {
        pub fn cpu() -> Result<PjrtRuntime> {
            Ok(PjrtRuntime {
                client: xla::PjRtClient::cpu().context("creating PJRT CPU client")?,
                executables: HashMap::new(),
                inputs: HashMap::new(),
            })
        }

        pub fn platform(&self) -> String {
            format!(
                "{} ({} devices)",
                self.client.platform_name(),
                self.client.device_count()
            )
        }

        /// Compile an artifact (cached).
        pub fn load(&mut self, art: &ArtifactInfo) -> Result<()> {
            if self.executables.contains_key(&art.name) {
                return Ok(());
            }
            let path = art
                .file
                .to_str()
                .context("artifact path not utf-8")?
                .to_string();
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {path}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", art.name))?;
            self.executables.insert(art.name.clone(), exe);
            Ok(())
        }

        /// Deterministic input literals for an artifact (cached).
        pub fn inputs_for(&mut self, art: &ArtifactInfo) -> Result<&[xla::Literal]> {
            if !self.inputs.contains_key(&art.name) {
                let lits: Result<Vec<xla::Literal>> =
                    art.inputs.iter().map(make_input).collect();
                self.inputs.insert(art.name.clone(), lits?);
            }
            Ok(self.inputs.get(&art.name).unwrap())
        }

        /// Execute once, returning every output tensor flattened to f32.
        pub fn execute(&mut self, art: &ArtifactInfo) -> Result<Vec<Vec<f32>>> {
            self.load(art)?;
            self.inputs_for(art)?;
            let exe = self.executables.get(&art.name).unwrap();
            let inputs = self.inputs.get(&art.name).unwrap();
            let result = exe
                .execute::<xla::Literal>(inputs)
                .with_context(|| format!("executing {}", art.name))?[0][0]
                .to_literal_sync()?;
            // aot.py lowers with return_tuple=True: decompose the tuple.
            let parts = result.to_tuple()?;
            parts
                .into_iter()
                .map(|lit| lit.to_vec::<f32>().map_err(Into::into))
                .collect()
        }

        /// Run `iters` executions and return total wall-clock milliseconds
        /// (outputs are materialized on the last iteration as the sync
        /// point, mirroring the inner-loop-then-synchronize pattern of
        /// App. B.2).
        pub fn time_batch(&mut self, art: &ArtifactInfo, iters: usize) -> Result<f64> {
            self.load(art)?;
            self.inputs_for(art)?;
            let exe = self.executables.get(&art.name).unwrap();
            let inputs = self.inputs.get(&art.name).unwrap();
            let start = Instant::now();
            let mut last = None;
            for _ in 0..iters {
                last = Some(exe.execute::<xla::Literal>(inputs)?);
            }
            if let Some(bufs) = last {
                let _ = bufs[0][0].to_literal_sync()?; // sync
            }
            Ok(start.elapsed().as_secs_f64() * 1e3)
        }

        pub fn loaded_count(&self) -> usize {
            self.executables.len()
        }
    }

    /// Deterministic standard-normal tensor from the spec's seed.
    fn make_input(spec: &InputSpec) -> Result<xla::Literal> {
        let n = spec.elements();
        let mut rng = Rng::with_stream(0x5eed ^ spec.seed, spec.seed.wrapping_mul(2654435761) | 1);
        let data: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let lit = xla::Literal::vec1(&data);
        let dims: Vec<i64> = spec.shape.iter().map(|d| *d as i64).collect();
        lit.reshape(&dims).map_err(Into::into)
    }

    #[cfg(test)]
    mod tests {
        use super::*;
        use crate::runtime::manifest::Manifest;
        use std::path::Path;

        fn manifest() -> Option<Manifest> {
            let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
            if dir.join("manifest.json").exists() {
                Some(Manifest::load(&dir).unwrap())
            } else {
                None
            }
        }

        #[test]
        fn inputs_are_deterministic() {
            let a = make_input(&InputSpec { shape: vec![4, 8], seed: 3 }).unwrap();
            let b = make_input(&InputSpec { shape: vec![4, 8], seed: 3 }).unwrap();
            assert_eq!(a.to_vec::<f32>().unwrap(), b.to_vec::<f32>().unwrap());
            let c = make_input(&InputSpec { shape: vec![4, 8], seed: 4 }).unwrap();
            assert_ne!(a.to_vec::<f32>().unwrap(), c.to_vec::<f32>().unwrap());
        }

        /// Full PJRT round trip on the real artifacts (skipped when
        /// `make artifacts` has not run).
        #[test]
        fn executes_rope_variants_identically() {
            let Some(m) = manifest() else { return };
            let mut rt = PjrtRuntime::cpu().unwrap();
            let reference = m.reference_for("llama_rope").unwrap();
            let ref_out = rt.execute(reference).unwrap();
            assert_eq!(ref_out.len(), 2, "rope returns (q, k)");
            for variant in m.variants_for("llama_rope") {
                let out = rt.execute(variant).unwrap();
                assert_eq!(out.len(), 2, "{}", variant.name);
                for (o, r) in out.iter().zip(ref_out.iter()) {
                    assert_eq!(o.len(), r.len());
                    let rep = crate::eval::check_correctness(r, o);
                    assert!(rep.correct, "{} vs reference: {:?}", variant.name, rep);
                }
            }
            assert!(rt.loaded_count() >= 2);
        }

        #[test]
        fn timing_is_positive_and_scales() {
            let Some(m) = manifest() else { return };
            let mut rt = PjrtRuntime::cpu().unwrap();
            let art = m.reference_for("softmax_real").unwrap();
            let _ = rt.time_batch(art, 2).unwrap(); // warm caches
            // Minimum over trials makes this robust to parallel-test load.
            let t1 = (0..5)
                .map(|_| rt.time_batch(art, 1).unwrap())
                .fold(f64::INFINITY, f64::min);
            let t16 = (0..3)
                .map(|_| rt.time_batch(art, 16).unwrap())
                .fold(f64::INFINITY, f64::min);
            assert!(t1 > 0.0);
            assert!(
                t16 > t1 * 4.0,
                "16 iters ({t16} ms) should cost well over 4x 1 iter ({t1} ms)"
            );
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub {
    use crate::runtime::manifest::ArtifactInfo;
    use crate::util::error::{Error, Result};

    const DISABLED: &str =
        "PJRT runtime disabled: rebuild with `--features pjrt` (requires the vendored `xla` crate)";

    /// Stub runtime compiled in when the `pjrt` feature is off. Keeps the
    /// exact API of the real runtime so every consumer compiles; all
    /// operations fail with a clear message, and artifact-gated tests and
    /// examples skip before ever calling in.
    pub struct PjrtRuntime {
        _private: (),
    }

    impl PjrtRuntime {
        /// Always fails in the stub build.
        pub fn cpu() -> Result<PjrtRuntime> {
            Err(Error::msg(DISABLED))
        }

        /// Stub platform description.
        pub fn platform(&self) -> String {
            "pjrt-disabled".to_string()
        }

        /// Always fails in the stub build.
        pub fn load(&mut self, _art: &ArtifactInfo) -> Result<()> {
            Err(Error::msg(DISABLED))
        }

        /// Always fails in the stub build.
        pub fn execute(&mut self, _art: &ArtifactInfo) -> Result<Vec<Vec<f32>>> {
            Err(Error::msg(DISABLED))
        }

        /// Always fails in the stub build.
        pub fn time_batch(&mut self, _art: &ArtifactInfo, _iters: usize) -> Result<f64> {
            Err(Error::msg(DISABLED))
        }

        /// No executables are ever loaded by the stub.
        pub fn loaded_count(&self) -> usize {
            0
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn stub_reports_disabled() {
            let err = PjrtRuntime::cpu().unwrap_err();
            assert!(err.to_string().contains("pjrt"), "{err}");
        }
    }
}
