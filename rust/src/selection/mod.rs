//! Parent selection strategies (§3.2).
//!
//! Four strategies with configurable mixing ratios:
//! * **Uniform** — random over occupied cells (max behavioral diversity).
//! * **Fitness-proportionate** — weighted by elite fitness.
//! * **Curiosity-driven** — weighted by gradient magnitude (estimated
//!   improvement potential).
//! * **Island-based** — K independent sub-populations over disjoint
//!   archive regions with periodic migration every M generations.

use crate::archive::MapElites;
use crate::classify::Coords;
use crate::gradient::GradientEstimator;
use crate::transitions::TransitionTracker;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    Uniform,
    FitnessProportionate,
    Curiosity,
    Island,
}

impl Strategy {
    pub fn parse(s: &str) -> Option<Strategy> {
        match s {
            "uniform" => Some(Strategy::Uniform),
            "fitness" | "fitness-proportionate" => Some(Strategy::FitnessProportionate),
            "curiosity" | "curiosity-driven" => Some(Strategy::Curiosity),
            "island" | "island-based" => Some(Strategy::Island),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Uniform => "uniform",
            Strategy::FitnessProportionate => "fitness-proportionate",
            Strategy::Curiosity => "curiosity-driven",
            Strategy::Island => "island-based",
        }
    }
}

/// Island bookkeeping for island-based selection.
#[derive(Debug, Clone)]
pub struct IslandState {
    /// Number of islands K.
    pub k: usize,
    /// Migration period M (generations).
    pub migration_period: usize,
    /// Round-robin cursor so islands take turns producing offspring.
    cursor: usize,
    generations: usize,
}

impl IslandState {
    pub fn new(k: usize, migration_period: usize) -> IslandState {
        IslandState {
            k: k.max(1),
            migration_period: migration_period.max(1),
            cursor: 0,
            generations: 0,
        }
    }

    /// Islands partition the archive by flat cell index modulo K.
    pub fn island_of(&self, coords: Coords, bins: usize) -> usize {
        crate::classify::cell_index(coords, bins) % self.k
    }

    pub fn advance_generation(&mut self) {
        self.generations += 1;
        self.cursor = (self.cursor + 1) % self.k;
    }

    /// During a migration generation, islands may sample from anywhere.
    pub fn migration_open(&self) -> bool {
        self.generations > 0 && self.generations % self.migration_period == 0
    }

    pub fn active_island(&self) -> usize {
        self.cursor
    }
}

/// Parent selector combining the four strategies.
pub struct Selector {
    pub strategy: Strategy,
    pub estimator: GradientEstimator,
    pub islands: IslandState,
}

impl Selector {
    pub fn new(strategy: Strategy) -> Selector {
        Selector {
            strategy,
            estimator: GradientEstimator::default(),
            islands: IslandState::new(4, 5),
        }
    }

    /// Sample one parent cell from the archive. Returns `None` when the
    /// archive is empty (first generation runs from scratch).
    pub fn select(
        &self,
        archive: &MapElites,
        tracker: &TransitionTracker,
        iteration: usize,
        rng: &mut Rng,
    ) -> Option<Coords> {
        let occupied = archive.occupied_coords();
        if occupied.is_empty() {
            return None;
        }
        let coords = match self.strategy {
            Strategy::Uniform => *rng.choose(&occupied),
            Strategy::FitnessProportionate => {
                let weights: Vec<f64> = occupied
                    .iter()
                    .map(|c| archive.get(*c).map(|e| e.fitness).unwrap_or(0.0))
                    .collect();
                occupied[rng.choose_weighted(&weights)]
            }
            Strategy::Curiosity => {
                let weighted = self.estimator.sampling_weights(tracker, archive, iteration);
                let weights: Vec<f64> = weighted.iter().map(|(_, w)| *w).collect();
                weighted[rng.choose_weighted(&weights)].0
            }
            Strategy::Island => {
                let island = self.islands.active_island();
                let bins = archive.bins();
                let local: Vec<Coords> = if self.islands.migration_open() {
                    occupied.clone()
                } else {
                    let filtered: Vec<Coords> = occupied
                        .iter()
                        .copied()
                        .filter(|c| self.islands.island_of(*c, bins) == island)
                        .collect();
                    if filtered.is_empty() {
                        occupied.clone()
                    } else {
                        filtered
                    }
                };
                *rng.choose(&local)
            }
        };
        Some(coords)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::archive::Elite;
    use crate::ir::KernelGenome;

    fn archive_with(cells: &[(Coords, f64)]) -> MapElites {
        let mut a = MapElites::new(4);
        for (c, f) in cells {
            a.insert(Elite {
                genome: KernelGenome::direct_translation("t"),
                coords: *c,
                fitness: *f,
                speedup: 1.0,
                runtime_ms: 1.0,
                iteration: 0,
            });
        }
        a
    }

    #[test]
    fn empty_archive_selects_none() {
        let sel = Selector::new(Strategy::Uniform);
        let a = MapElites::new(4);
        let tr = TransitionTracker::new(8);
        assert!(sel.select(&a, &tr, 0, &mut Rng::new(1)).is_none());
    }

    #[test]
    fn uniform_hits_every_cell() {
        let sel = Selector::new(Strategy::Uniform);
        let a = archive_with(&[([0, 0, 0], 0.2), ([1, 1, 1], 0.8), ([3, 3, 3], 0.5)]);
        let tr = TransitionTracker::new(8);
        let mut rng = Rng::new(2);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..200 {
            seen.insert(sel.select(&a, &tr, 0, &mut rng).unwrap());
        }
        assert_eq!(seen.len(), 3);
    }

    #[test]
    fn fitness_proportionate_prefers_high_fitness() {
        let sel = Selector::new(Strategy::FitnessProportionate);
        let a = archive_with(&[([0, 0, 0], 0.1), ([1, 1, 1], 0.9)]);
        let tr = TransitionTracker::new(8);
        let mut rng = Rng::new(3);
        let mut high = 0;
        let n = 2000;
        for _ in 0..n {
            if sel.select(&a, &tr, 0, &mut rng).unwrap() == [1, 1, 1] {
                high += 1;
            }
        }
        let frac = high as f64 / n as f64;
        assert!((0.82..0.98).contains(&frac), "frac {frac}");
    }

    #[test]
    fn curiosity_always_selects_occupied() {
        let sel = Selector::new(Strategy::Curiosity);
        let a = archive_with(&[([0, 0, 0], 0.5), ([2, 1, 0], 0.6)]);
        let tr = TransitionTracker::new(8);
        let mut rng = Rng::new(4);
        for _ in 0..50 {
            let c = sel.select(&a, &tr, 0, &mut rng).unwrap();
            assert!(a.get(c).is_some());
        }
    }

    #[test]
    fn island_partition_is_stable_and_total() {
        let isl = IslandState::new(4, 5);
        let mut counts = [0usize; 4];
        for idx in 0..64 {
            let c = crate::classify::coords_of(idx, 4);
            counts[isl.island_of(c, 4)] += 1;
        }
        assert_eq!(counts.iter().sum::<usize>(), 64);
        assert!(counts.iter().all(|&c| c == 16));
    }

    #[test]
    fn migration_opens_periodically() {
        let mut isl = IslandState::new(3, 4);
        let mut open = Vec::new();
        for g in 1..=8 {
            isl.advance_generation();
            if isl.migration_open() {
                open.push(g);
            }
        }
        assert_eq!(open, vec![4, 8]);
    }

    #[test]
    fn island_selection_restricted_outside_migration() {
        let sel = Selector::new(Strategy::Island);
        // Two cells on different islands.
        let a = archive_with(&[([0, 0, 0], 0.5), ([0, 0, 1], 0.5)]);
        let tr = TransitionTracker::new(8);
        let mut rng = Rng::new(5);
        // Active island is 0 (cursor 0): cell [0,0,0] has index 0 → island 0;
        // cell [0,0,1] index 1 → island 1. Selection must stay on island 0.
        for _ in 0..50 {
            assert_eq!(sel.select(&a, &tr, 0, &mut rng).unwrap(), [0, 0, 0]);
        }
    }
}
