//! TCP transport for the newline-JSON RPC: [`Server`] (the daemon side)
//! and [`Client`] (the `submit` subcommand / test side).
//!
//! The server accepts connections on a `std::net::TcpListener` and
//! spawns one handler thread per connection; each handler reads one
//! JSON request per line and writes one JSON response per line, so a
//! client can hold a single connection open for its whole
//! submit-poll-fetch conversation. A `shutdown` request stops the
//! accept loop (after acknowledging); the daemon then drains and joins
//! the fleet via [`KernelService::stop`].
//!
//! The request path is hardened against misbehaving peers: each
//! connection carries an idle read timeout ([`READ_IDLE_TIMEOUT`]) and
//! a cap on the length of a single request line ([`MAX_LINE_BYTES`]),
//! so a client that connects and goes silent cannot pin a handler
//! thread forever and a client that streams an unterminated line
//! cannot balloon the server's memory. [`Server::start_with_limits`]
//! exposes both knobs for tests.

use super::proto::{self, Request};
use super::KernelService;
use crate::obs::window::{derived_metrics, DeltaTracker};
use crate::util::error::{Context, Error};
use crate::util::json::{self, Json};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// Default per-connection idle read timeout: a connected client that
/// sends nothing for this long is dropped (its handler thread exits).
pub const READ_IDLE_TIMEOUT: Duration = Duration::from_secs(300);

/// Default cap on one request line (1 MiB). A line that reaches this
/// many bytes without a terminating newline draws one error response
/// and the connection is closed — the stream cannot be resynchronized
/// mid-line.
pub const MAX_LINE_BYTES: usize = 1 << 20;

struct ServerState {
    shutdown: AtomicBool,
    addr: SocketAddr,
}

/// The daemon's TCP front end.
pub struct Server {
    state: Arc<ServerState>,
    handle: Option<thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (use port 0 for an ephemeral port) and start the
    /// accept loop on a background thread, with the default connection
    /// limits ([`READ_IDLE_TIMEOUT`], [`MAX_LINE_BYTES`]).
    pub fn start(service: Arc<KernelService>, addr: &str) -> std::io::Result<Server> {
        Server::start_with_limits(service, addr, Some(READ_IDLE_TIMEOUT), MAX_LINE_BYTES)
    }

    /// [`Server::start`] with explicit connection limits: `read_timeout`
    /// is the per-connection idle read timeout (`None` = wait forever,
    /// the pre-hardening behavior) and `max_line` caps one request line
    /// in bytes. Tests use tiny values to pin the guard behavior.
    pub fn start_with_limits(
        service: Arc<KernelService>,
        addr: &str,
        read_timeout: Option<Duration>,
        max_line: usize,
    ) -> std::io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let state = Arc::new(ServerState {
            shutdown: AtomicBool::new(false),
            addr: listener.local_addr()?,
        });
        let accept_state = Arc::clone(&state);
        let handle = thread::spawn(move || {
            for stream in listener.incoming() {
                if accept_state.shutdown.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let service = Arc::clone(&service);
                let conn_state = Arc::clone(&accept_state);
                thread::spawn(move || {
                    handle_connection(stream, service, conn_state, read_timeout, max_line)
                });
            }
        });
        Ok(Server {
            state,
            handle: Some(handle),
        })
    }

    /// The bound address (resolves port 0 to the actual port).
    pub fn addr(&self) -> SocketAddr {
        self.state.addr
    }

    /// Whether a shutdown has been requested.
    pub fn is_shutting_down(&self) -> bool {
        self.state.shutdown.load(Ordering::SeqCst)
    }

    /// Request the accept loop to stop (same path as the RPC `shutdown`
    /// verb) without joining it.
    pub fn shutdown(&self) {
        trigger_shutdown(&self.state);
    }

    /// Block until the accept loop exits (i.e. until shutdown).
    pub fn wait(&mut self) {
        if let Some(handle) = self.handle.take() {
            handle.join().ok();
        }
    }
}

/// Flip the shutdown flag and poke the listener with a dummy
/// connection so the blocking `accept` observes it.
fn trigger_shutdown(state: &ServerState) {
    state.shutdown.store(true, Ordering::SeqCst);
    let _ = TcpStream::connect(state.addr);
}

/// Outcome of reading one request line under the connection limits.
enum LineRead {
    /// A complete line, newline stripped.
    Line(String),
    /// Clean EOF, a read error, or the idle timeout: close silently.
    Closed,
    /// The line hit the byte cap before its newline arrived.
    TooLong,
}

/// Read one `\n`-terminated line without ever buffering more than
/// `max_line` bytes of it. `BufReader::read_line` would grow its
/// `String` without bound; this reads through `fill_buf`/`consume` so
/// an attacker streaming an endless line costs one internal buffer,
/// not the whole heap.
fn read_bounded_line(reader: &mut BufReader<TcpStream>, max_line: usize) -> LineRead {
    let mut buf = Vec::new();
    loop {
        let (used, newline, overflowed) = {
            let available = match reader.fill_buf() {
                Ok(chunk) => chunk,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) {
                        crate::obs::global().counter("kf_rpc_read_timeouts_total").inc();
                    }
                    return LineRead::Closed;
                }
            };
            if available.is_empty() {
                return LineRead::Closed;
            }
            match available.iter().position(|&b| b == b'\n') {
                Some(pos) => {
                    let over = buf.len() + pos > max_line;
                    if !over {
                        buf.extend_from_slice(&available[..pos]);
                    }
                    (pos + 1, true, over)
                }
                None => {
                    let over = buf.len() + available.len() > max_line;
                    if !over {
                        buf.extend_from_slice(available);
                    }
                    (available.len(), false, over)
                }
            }
        };
        reader.consume(used);
        if overflowed {
            return LineRead::TooLong;
        }
        if newline {
            return LineRead::Line(String::from_utf8_lossy(&buf).into_owned());
        }
    }
}

fn handle_connection(
    stream: TcpStream,
    service: Arc<KernelService>,
    state: Arc<ServerState>,
    read_timeout: Option<Duration>,
    max_line: usize,
) {
    crate::obs::global().counter("kf_rpc_connections_total").inc();
    let _ = stream.set_read_timeout(read_timeout);
    let Ok(read_half) = stream.try_clone() else { return };
    let mut writer = stream;
    let mut reader = BufReader::new(read_half);
    loop {
        let line = match read_bounded_line(&mut reader, max_line) {
            LineRead::Closed => break,
            LineRead::TooLong => {
                // One diagnostic, then hang up: past the cap the stream
                // has no line boundary left to resynchronize on.
                crate::obs::global().counter("kf_rpc_oversized_lines_total").inc();
                let resp =
                    proto::error_response(&format!("request line exceeds {max_line} bytes"));
                let mut wire = resp.to_string_compact();
                wire.push('\n');
                let _ = writer.write_all(wire.as_bytes());
                break;
            }
            LineRead::Line(line) => line,
        };
        if line.trim().is_empty() {
            continue;
        }
        let mut stop = false;
        let response = match json::parse(&line) {
            Err(e) => {
                crate::obs::global().counter("kf_rpc_bad_requests_total").inc();
                proto::error_response(&format!("bad request json: {e}"))
            }
            Ok(v) => match Request::from_json(&v) {
                Err(e) => {
                    crate::obs::global().counter("kf_rpc_bad_requests_total").inc();
                    proto::error_response(&e)
                }
                Ok(Request::Watch(interval_ms)) => {
                    // Streaming verb: this connection becomes a frame
                    // stream until the client hangs up.
                    crate::obs::global().counter("kf_rpc_watch_streams_total").inc();
                    stream_watch(&mut writer, &service, &state, interval_ms);
                    return;
                }
                Ok(req) => {
                    stop = matches!(req, Request::Shutdown);
                    service.handle(&req)
                }
            },
        };
        let mut wire = response.to_string_compact();
        wire.push('\n');
        if writer.write_all(wire.as_bytes()).is_err() {
            break;
        }
        if stop {
            trigger_shutdown(&state);
            break;
        }
    }
}

/// Write one newline-terminated frame; false when the client is gone.
fn send_frame(writer: &mut TcpStream, frame: &Json) -> bool {
    let mut wire = frame.to_string_compact();
    wire.push('\n');
    writer.write_all(wire.as_bytes()).is_ok()
}

/// Serve one `watch` stream: a `hello` frame, an immediate `metrics`
/// frame (cumulative totals, so the watcher has data before the first
/// interval elapses), then periodic metric-delta frames interleaved
/// with live `trace`/`alert` frames from the service bus, until the
/// client disconnects or the server shuts down.
fn stream_watch(
    writer: &mut TcpStream,
    service: &Arc<KernelService>,
    state: &ServerState,
    interval_ms: u64,
) {
    let interval = Duration::from_millis(interval_ms.clamp(20, 60_000));
    // Subscribe before the first snapshot so no frame can fall between.
    let rx = service.watch_bus().subscribe();
    let rules: Vec<Json> = service.alert_rule_names().into_iter().map(Json::from).collect();
    let mut hello = Json::obj();
    hello
        .set("ok", true)
        .set("kind", "hello")
        .set("interval_ms", interval.as_millis() as usize)
        .set("alert_rules", Json::Arr(rules));
    let mut tracker = DeltaTracker::new();
    let mut metrics_frame = || {
        let snap = service.merged_snapshot();
        let delta = tracker.tick(snap.clone(), crate::obs::now_ms());
        let derived = derived_metrics(&delta, &snap);
        delta.to_frame(&derived)
    };
    if !send_frame(writer, &hello) || !send_frame(writer, &metrics_frame()) {
        return;
    }
    let mut next_tick = Instant::now() + interval;
    loop {
        if state.shutdown.load(Ordering::SeqCst) {
            return;
        }
        let wait = next_tick.saturating_duration_since(Instant::now());
        match rx.recv_timeout(wait) {
            Ok(frame) => {
                if !send_frame(writer, &frame) {
                    return;
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                if !send_frame(writer, &metrics_frame()) {
                    return;
                }
                next_tick = Instant::now() + interval;
            }
            Err(RecvTimeoutError::Disconnected) => return,
        }
    }
}

/// A blocking RPC client holding one connection.
pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    /// Connect to a daemon at `addr` (e.g. `127.0.0.1:7341`).
    pub fn connect(addr: &str) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client {
            writer: stream,
            reader,
        })
    }

    /// Send one request object and read the response line.
    pub fn request_json(&mut self, req: &Json) -> Result<Json, Error> {
        let mut wire = req.to_string_compact();
        wire.push('\n');
        self.writer
            .write_all(wire.as_bytes())
            .context("sending request")?;
        let mut line = String::new();
        let n = self
            .reader
            .read_line(&mut line)
            .context("reading response")?;
        if n == 0 {
            return Err(Error::msg("server closed the connection"));
        }
        json::parse(line.trim()).context("parsing response")
    }

    /// Send a typed request.
    pub fn request(&mut self, req: &Request) -> Result<Json, Error> {
        self.request_json(&req.to_json())
    }

    /// Send a request without reading a response — for streaming verbs
    /// (`watch`), where the server answers with frames instead.
    pub fn send(&mut self, req: &Request) -> Result<(), Error> {
        let mut wire = req.to_json().to_string_compact();
        wire.push('\n');
        self.writer.write_all(wire.as_bytes()).context("sending request")
    }

    /// Read the next frame from a stream; `Ok(None)` on clean EOF
    /// (server shut down or closed the stream).
    pub fn next_frame(&mut self) -> Result<Option<Json>, Error> {
        let mut line = String::new();
        loop {
            line.clear();
            let n = self.reader.read_line(&mut line).context("reading frame")?;
            if n == 0 {
                return Ok(None);
            }
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            return json::parse(trimmed).context("parsing frame").map(Some);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwsim::DeviceProfile;
    use crate::service::{JobSpec, ServiceConfig};

    fn serve() -> (Arc<KernelService>, Server) {
        let service = KernelService::start(ServiceConfig {
            devices: vec![DeviceProfile::b580()],
            compile_workers: 1,
            exec_workers: 2,
            queue_capacity: 8,
            ..ServiceConfig::default()
        })
        .unwrap();
        let server = Server::start(Arc::clone(&service), "127.0.0.1:0").unwrap();
        (service, server)
    }

    #[test]
    fn rejects_garbage_and_unknown_verbs_without_dying() {
        let (service, mut server) = serve();
        let mut client = Client::connect(&server.addr().to_string()).unwrap();
        let resp = client
            .request_json(&json::parse(r#"{"verb":"warp"}"#).unwrap())
            .unwrap();
        assert!(!proto::response_ok(&resp));
        // The same connection still serves valid requests afterwards.
        let resp = client.request(&Request::Stats).unwrap();
        assert!(proto::response_ok(&resp));
        server.shutdown();
        server.wait();
        service.stop();
    }

    #[test]
    fn shutdown_verb_stops_the_accept_loop() {
        let (service, mut server) = serve();
        let mut client = Client::connect(&server.addr().to_string()).unwrap();
        let resp = client.request(&Request::Shutdown).unwrap();
        assert!(proto::response_ok(&resp));
        server.wait(); // returns because the accept loop exited
        assert!(server.is_shutting_down());
        service.stop();
    }

    #[test]
    fn watch_streams_hello_and_periodic_metrics() {
        let (service, mut server) = serve();
        let mut client = Client::connect(&server.addr().to_string()).unwrap();
        client.send(&Request::Watch(25)).unwrap();
        let hello = client.next_frame().unwrap().unwrap();
        assert_eq!(hello.get("kind").unwrap().as_str(), Some("hello"));
        assert!(proto::response_ok(&hello));
        let first = client.next_frame().unwrap().unwrap();
        assert_eq!(first.get("kind").unwrap().as_str(), Some("metrics"));
        // A second periodic frame arrives with no bus activity at all.
        let second = client.next_frame().unwrap().unwrap();
        assert_eq!(second.get("kind").unwrap().as_str(), Some("metrics"));
        drop(client);
        // The server keeps serving ordinary requests after the watcher
        // hangs up.
        let mut other = Client::connect(&server.addr().to_string()).unwrap();
        assert!(proto::response_ok(&other.request(&Request::Stats).unwrap()));
        server.shutdown();
        server.wait();
        service.stop();
    }

    #[test]
    fn oversized_request_line_is_rejected_and_server_survives() {
        let service = KernelService::start(ServiceConfig {
            devices: vec![DeviceProfile::b580()],
            compile_workers: 1,
            exec_workers: 2,
            queue_capacity: 8,
            ..ServiceConfig::default()
        })
        .unwrap();
        let mut server =
            Server::start_with_limits(Arc::clone(&service), "127.0.0.1:0", None, 256).unwrap();

        // Stream a 600-byte line against a 256-byte cap: the server
        // answers with one error and closes this connection.
        let mut raw = TcpStream::connect(server.addr()).unwrap();
        let mut big = vec![b'x'; 600];
        big.push(b'\n');
        raw.write_all(&big).unwrap();
        let mut reader = BufReader::new(raw.try_clone().unwrap());
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        assert!(resp.contains("exceeds 256 bytes"), "{resp}");
        let mut rest = String::new();
        assert_eq!(
            reader.read_line(&mut rest).unwrap(),
            0,
            "connection must be closed after an oversized line"
        );

        // The listener itself is unharmed: a fresh client still works.
        let mut client = Client::connect(&server.addr().to_string()).unwrap();
        assert!(proto::response_ok(&client.request(&Request::Stats).unwrap()));
        server.shutdown();
        server.wait();
        service.stop();
    }

    #[test]
    fn idle_connection_is_dropped_after_the_read_timeout() {
        let service = KernelService::start(ServiceConfig {
            devices: vec![DeviceProfile::b580()],
            compile_workers: 1,
            exec_workers: 2,
            queue_capacity: 8,
            ..ServiceConfig::default()
        })
        .unwrap();
        let mut server = Server::start_with_limits(
            Arc::clone(&service),
            "127.0.0.1:0",
            Some(Duration::from_millis(50)),
            MAX_LINE_BYTES,
        )
        .unwrap();

        // Connect and send nothing: the handler must hang up on us
        // instead of pinning its thread forever.
        let idle = TcpStream::connect(server.addr()).unwrap();
        let mut reader = BufReader::new(idle);
        let mut line = String::new();
        assert_eq!(
            reader.read_line(&mut line).unwrap(),
            0,
            "idle connection must be closed by the server"
        );

        // An active client beats the timeout easily.
        let mut client = Client::connect(&server.addr().to_string()).unwrap();
        assert!(proto::response_ok(&client.request(&Request::Stats).unwrap()));
        server.shutdown();
        server.wait();
        service.stop();
    }

    #[test]
    fn submit_over_tcp_reaches_the_service() {
        let (service, mut server) = serve();
        let mut client = Client::connect(&server.addr().to_string()).unwrap();
        let mut spec = JobSpec::catalog("20_LeakyReLU", "b580");
        spec.iters = 2;
        spec.population = 2;
        let resp = client.request(&Request::Submit(spec)).unwrap();
        assert!(proto::response_ok(&resp), "{resp}");
        let id = resp.get("job_id").unwrap().as_usize().unwrap() as u64;
        let job = service.wait(id, std::time::Duration::from_secs(30)).unwrap();
        assert!(job.state().finished());
        server.shutdown();
        server.wait();
        service.stop();
    }
}
