//! The result cache: a warm daemon answers repeat requests without
//! re-evolving.
//!
//! Entries are keyed by `(task fingerprint, device, language, seed,
//! generation budget)` — everything that determines an evolution run's
//! outcome. Catalog tasks fingerprint as their id; inline custom tasks
//! (App. C) fingerprint as an FNV-1a hash over their config + source
//! text, so two users submitting byte-identical bundles share one cache
//! line. Hits and misses are counted for the `stats` verb, and correct
//! results are write-through persisted as [`DbRow`]s via the existing
//! [`Database`] JSONL store (Fig. 4 worker type 4), so a restarted
//! daemon pointed at the same `--db` file restores its cache metrics
//! (kernel sources are not persisted — restored hits carry metrics
//! only).

use super::job::{DeviceResult, JobSpec, TaskSource};
use crate::coordinator::engine::hash_str_pub;
use crate::dist::{Database, DbRow};
use crate::obs::Registry;
use crate::util::error::Error;
use crate::util::json::Json;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// `method` column of persisted cache rows (distinguishes them from
/// `serve`-subcommand rows sharing a database file).
pub const CACHE_METHOD: &str = "service";

/// Stable fingerprint of a job's task: the catalog id, or a content
/// hash of the inline custom bundle.
pub fn task_fingerprint(task: &TaskSource) -> String {
    match task {
        TaskSource::Catalog(id) => format!("cat:{id}"),
        TaskSource::Custom { config, source } => {
            format!("fp:{:016x}", hash_str_pub(&format!("{config}\u{0}{source}")))
        }
    }
}

/// The full cache key for one (spec × device) unit.
pub fn cache_key(spec: &JobSpec, device: &str) -> String {
    format!(
        "{}|{}|{}|s{}|i{}|p{}",
        task_fingerprint(&spec.task),
        device,
        spec.language,
        spec.seed,
        spec.iters,
        spec.population
    )
}

/// The shared result cache with hit/miss metrics and optional JSONL
/// persistence.
pub struct ResultCache {
    entries: Mutex<HashMap<String, DeviceResult>>,
    /// Lookups that found an entry.
    pub hits: AtomicU64,
    /// Lookups that found nothing.
    pub misses: AtomicU64,
    db: Option<(Database, PathBuf)>,
    /// Owning service's metrics registry (set once via
    /// [`ResultCache::attach_obs`]); hits/misses mirror into
    /// `kf_cache_hits_total` / `kf_cache_misses_total` when present.
    obs: OnceLock<Arc<Registry>>,
}

impl ResultCache {
    /// A purely in-memory cache (daemon without `--db`).
    pub fn in_memory() -> ResultCache {
        ResultCache {
            entries: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            db: None,
            obs: OnceLock::new(),
        }
    }

    /// Attach the owning service's metrics registry (idempotent; the
    /// first registry wins). From then on every hit/miss also advances
    /// the registry counters the `metrics` verb exposes.
    pub fn attach_obs(&self, obs: &Arc<Registry>) {
        if self.obs.set(Arc::clone(obs)).is_ok() {
            // Materialize both series immediately so the exposition
            // always carries them, even before the first lookup.
            obs.counter("kf_cache_hits_total");
            obs.counter("kf_cache_misses_total");
        }
    }

    fn count(&self, hit: bool) {
        if hit {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
        if let Some(obs) = self.obs.get() {
            let name = if hit { "kf_cache_hits_total" } else { "kf_cache_misses_total" };
            obs.counter(name).inc();
        }
    }

    /// A cache persisted through the JSONL database at `path`. An
    /// existing file is loaded and its `service` rows prewarm the cache
    /// (metrics only — sources are not persisted). The load is
    /// crash-tolerant: a torn final line (daemon died mid-append) is
    /// truncated away and the rest is kept; mid-file corruption is
    /// still an error rather than silently overwritten, matching the
    /// `serve` subcommand's discipline.
    pub fn with_database(path: &Path) -> Result<ResultCache, Error> {
        let db = Database::new();
        let mut entries = HashMap::new();
        if path.exists() {
            db.load_tolerant(path)?;
            for row in db.rows() {
                if row.method != CACHE_METHOD {
                    continue;
                }
                let device = row.run.split('|').nth(1).unwrap_or("").to_string();
                entries.insert(
                    row.run.clone(),
                    DeviceResult {
                        device,
                        task_id: row.task_id.clone(),
                        correct: row.is_correct(),
                        fitness: row.fitness,
                        speedup: row.speedup,
                        time_ms: row.time_ms,
                        baseline_ms: row.baseline_ms,
                        coords: row.coords,
                        genome_id: row.genome_id,
                        produced_by: row.produced_by.clone(),
                        source: String::new(),
                        evaluations: 0,
                        compile_errors: 0,
                        incorrect: 0,
                        cached: true,
                        wall_ms: 0.0,
                    },
                );
            }
        }
        Ok(ResultCache {
            entries: Mutex::new(entries),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            db: Some((db, path.to_path_buf())),
            obs: OnceLock::new(),
        })
    }

    /// Number of cached entries.
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    /// Whether the cache holds no entries.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Look up a key, counting the hit or miss. A hit returns a clone
    /// with `cached` set.
    pub fn lookup(&self, key: &str) -> Option<DeviceResult> {
        let entries = self.entries.lock().unwrap();
        match entries.get(key) {
            Some(r) => {
                self.count(true);
                let mut r = r.clone();
                r.cached = true;
                Some(r)
            }
            None => {
                self.count(false);
                None
            }
        }
    }

    /// Counter-free lookup used by journal replay: a hit is returned
    /// marked `cached`, but neither the hit nor the miss counter moves —
    /// replaying a restart must not skew the serving metrics.
    pub fn peek(&self, key: &str) -> Option<DeviceResult> {
        self.entries.lock().unwrap().get(key).map(|r| {
            let mut r = r.clone();
            r.cached = true;
            r
        })
    }

    /// Insert a freshly-computed result, write-through persisting
    /// correct results when a database is configured. Persistence is a
    /// single-row O(1) append (the store is append-only JSONL — a full
    /// `Database::save` would rewrite the ever-growing file on every
    /// insert); errors are logged, not fatal — the in-memory cache stays
    /// authoritative for this daemon's lifetime.
    pub fn insert(&self, key: &str, result: DeviceResult) {
        if let Some((db, path)) = &self.db {
            if result.correct {
                let row = slot_row(key, &result, db.len());
                if let Err(e) = append_row(path, &row) {
                    crate::log_warn!("cache persistence failed: {e}");
                }
                db.insert(row);
            }
        }
        self.entries.lock().unwrap().insert(key.to_string(), result);
    }

    /// Idempotently restore a journal-committed result during replay:
    /// the in-memory entry is (re)established, and — when a database is
    /// configured, the result is correct, and the slot's row is missing
    /// (the daemon crashed after the journal commit marker but before
    /// the row append) — the row is repaired by appending it now.
    /// [`Database::contains_run`] guards the append, so the slot ends
    /// with exactly one row no matter how many times the same journal
    /// is replayed.
    pub fn restore(&self, key: &str, result: DeviceResult) {
        if let Some((db, path)) = &self.db {
            if result.correct && !db.contains_run(key) {
                let row = slot_row(key, &result, db.len());
                if let Err(e) = append_row(path, &row) {
                    crate::log_warn!("cache slot repair failed: {e}");
                }
                db.insert(row);
            }
        }
        // Overwrite any prewarmed metrics-only entry: the journal's
        // commit record is at least as rich.
        self.entries.lock().unwrap().insert(key.to_string(), result);
    }

    /// Cache metrics for the `stats` verb.
    pub fn stats_json(&self) -> Json {
        let hits = self.hits.load(Ordering::Relaxed);
        let misses = self.misses.load(Ordering::Relaxed);
        let total = hits + misses;
        let mut o = Json::obj();
        o.set("entries", self.len())
            .set("hits", hits as f64)
            .set("misses", misses as f64)
            .set(
                "hit_rate",
                if total == 0 { 0.0 } else { hits as f64 / total as f64 },
            );
        o
    }
}

/// The persisted row for one commit slot (shared by the write-through
/// insert and the replay-time repair, so both produce identical rows).
fn slot_row(key: &str, result: &DeviceResult, idx: usize) -> DbRow {
    DbRow {
        run: key.to_string(),
        method: CACHE_METHOD.to_string(),
        idx,
        task_id: result.task_id.clone(),
        genome_id: result.genome_id,
        produced_by: result.produced_by.clone(),
        outcome: "correct".to_string(),
        coords: result.coords,
        fitness: result.fitness,
        speedup: result.speedup,
        time_ms: result.time_ms,
        baseline_ms: result.baseline_ms,
    }
}

/// Append one row to the JSONL store as a single O_APPEND write (a
/// whole line per write call, so concurrent lane appends do not
/// interleave mid-row).
fn append_row(path: &Path, row: &DbRow) -> std::io::Result<()> {
    use std::io::Write;
    let mut line = row.to_json().to_string_compact();
    line.push('\n');
    let mut file = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    file.write_all(line.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::job::JobSpec;

    fn result(device: &str, speedup: f64) -> DeviceResult {
        DeviceResult {
            device: device.to_string(),
            task_id: "20_LeakyReLU".to_string(),
            correct: true,
            fitness: 0.9,
            speedup,
            time_ms: 0.4,
            baseline_ms: 1.0,
            coords: [1, 2, 0],
            genome_id: 17,
            produced_by: "gpt-4.1".to_string(),
            source: "kernel source".to_string(),
            evaluations: 16,
            compile_errors: 2,
            incorrect: 3,
            cached: false,
            wall_ms: 12.0,
        }
    }

    fn tmp_path(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("kf_service_{}_{}.jsonl", name, std::process::id()))
    }

    #[test]
    fn key_separates_every_component() {
        let base = JobSpec::catalog("20_LeakyReLU", "b580");
        let k = |f: &dyn Fn(&mut JobSpec)| {
            let mut s = base.clone();
            f(&mut s);
            cache_key(&s, "b580")
        };
        let k0 = cache_key(&base, "b580");
        assert_ne!(k0, cache_key(&base, "lnl"), "device in key");
        assert_ne!(k0, k(&|s| s.language = "cuda".to_string()), "language in key");
        assert_ne!(k0, k(&|s| s.seed = 1), "seed in key");
        assert_ne!(k0, k(&|s| s.iters = 9), "iters in key");
        assert_ne!(k0, k(&|s| s.population = 5), "population in key");
        assert_ne!(
            k0,
            k(&|s| s.task = TaskSource::Catalog("1_Conv2D_ReLU_BiasAdd".to_string())),
            "task in key"
        );
        // Priority is scheduling-only: it must NOT split the cache.
        assert_eq!(k0, k(&|s| s.priority = super::super::job::JobPriority::High));
    }

    #[test]
    fn custom_fingerprint_is_content_addressed() {
        let a = TaskSource::Custom {
            config: "name: x\n".to_string(),
            source: "src".to_string(),
        };
        let b = TaskSource::Custom {
            config: "name: x\n".to_string(),
            source: "src".to_string(),
        };
        let c = TaskSource::Custom {
            config: "name: y\n".to_string(),
            source: "src".to_string(),
        };
        assert_eq!(task_fingerprint(&a), task_fingerprint(&b));
        assert_ne!(task_fingerprint(&a), task_fingerprint(&c));
    }

    #[test]
    fn hit_and_miss_counters() {
        let cache = ResultCache::in_memory();
        assert!(cache.lookup("k").is_none());
        cache.insert("k", result("b580", 2.0));
        let hit = cache.lookup("k").unwrap();
        assert!(hit.cached, "hits are marked cached");
        assert_eq!(hit.source, "kernel source", "in-memory hits keep the source");
        assert_eq!(cache.hits.load(Ordering::Relaxed), 1);
        assert_eq!(cache.misses.load(Ordering::Relaxed), 1);
        let stats = cache.stats_json();
        assert_eq!(stats.get("entries").unwrap().as_usize(), Some(1));
        assert_eq!(stats.get("hit_rate").unwrap().as_f64(), Some(0.5));
    }

    #[test]
    fn attached_registry_mirrors_hit_and_miss_counters() {
        let cache = ResultCache::in_memory();
        let obs = Arc::new(Registry::new());
        cache.attach_obs(&obs);
        cache.attach_obs(&Arc::new(Registry::new())); // idempotent: first wins
        assert!(cache.lookup("k").is_none());
        cache.insert("k", result("b580", 2.0));
        cache.lookup("k").unwrap();
        assert_eq!(obs.counter_value("kf_cache_hits_total"), 1);
        assert_eq!(obs.counter_value("kf_cache_misses_total"), 1);
    }

    #[test]
    fn persists_and_prewarms_through_database() {
        let path = tmp_path("prewarm");
        std::fs::remove_file(&path).ok();
        {
            let cache = ResultCache::with_database(&path).unwrap();
            cache.insert("fp:abc|b580|sycl|s1|i2|p2", result("b580", 1.7));
        }
        let warm = ResultCache::with_database(&path).unwrap();
        assert_eq!(warm.len(), 1);
        let hit = warm.lookup("fp:abc|b580|sycl|s1|i2|p2").unwrap();
        assert!(hit.cached);
        assert_eq!(hit.device, "b580", "device recovered from the key");
        assert_eq!(hit.speedup, 1.7);
        assert_eq!(hit.source, "", "sources are not persisted");
        std::fs::remove_file(&path).ok();
    }

    /// Satellite-task test: a daemon killed mid-append leaves a partial
    /// trailing JSONL line; reload must drop (and truncate) it rather
    /// than panic or refuse, while mid-file corruption stays an error.
    #[test]
    fn reload_tolerates_and_truncates_a_torn_trailing_line() {
        let path = tmp_path("torn");
        std::fs::remove_file(&path).ok();
        {
            let cache = ResultCache::with_database(&path).unwrap();
            cache.insert("cat:a|b580|sycl|s1|i2|p2", result("b580", 1.5));
            cache.insert("cat:b|b580|sycl|s1|i2|p2", result("b580", 2.5));
        }
        // Crash mid-append: a partial JSON prefix, no trailing newline.
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"{\"run\":\"cat:c|b580").unwrap();
        drop(f);

        let warm = ResultCache::with_database(&path).unwrap();
        assert_eq!(warm.len(), 2, "torn last line dropped, intact rows kept");
        // The torn bytes were truncated from the file, so a fresh
        // append starts on a clean line boundary and survives reload.
        warm.insert("cat:c|b580|sycl|s1|i2|p2", result("b580", 3.5));
        let warm2 = ResultCache::with_database(&path).unwrap();
        assert_eq!(warm2.len(), 3);

        // Mid-file corruption is not a torn tail: still a hard error.
        let tail = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, format!("garbage line\n{tail}")).unwrap();
        assert!(ResultCache::with_database(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    /// `restore` is the replay-time half of the slot-commit protocol:
    /// it must repair a missing row exactly once and never duplicate an
    /// existing one, however many times the journal is replayed.
    #[test]
    fn restore_repairs_missing_rows_exactly_once() {
        let path = tmp_path("restore");
        std::fs::remove_file(&path).ok();
        let key = "cat:x|b580|sycl|s1|i2|p2";
        let rows_in_file = |p: &Path| {
            std::fs::read_to_string(p)
                .unwrap_or_default()
                .lines()
                .filter(|l| l.contains(key))
                .count()
        };
        {
            // Crash-after-marker case: the row is missing → repaired once.
            let cache = ResultCache::with_database(&path).unwrap();
            cache.restore(key, result("b580", 1.7));
            cache.restore(key, result("b580", 1.7));
            assert_eq!(rows_in_file(&path), 1, "repair appends exactly one row");
            assert_eq!(cache.len(), 1);
        }
        {
            // Crash-after-row case: the row already exists → no append.
            let cache = ResultCache::with_database(&path).unwrap();
            cache.restore(key, result("b580", 1.7));
            assert_eq!(rows_in_file(&path), 1, "existing slot row never duplicated");
        }
        // Incorrect results are restored in memory but never persisted.
        let cache = ResultCache::with_database(&path).unwrap();
        let mut bad = result("b580", 0.0);
        bad.correct = false;
        cache.restore("cat:y|b580|sycl|s1|i2|p2", bad);
        assert_eq!(cache.len(), 2);
        assert!(!std::fs::read_to_string(&path).unwrap().contains("cat:y|"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn peek_hits_without_moving_the_counters() {
        let cache = ResultCache::in_memory();
        assert!(cache.peek("k").is_none());
        cache.insert("k", result("b580", 2.0));
        let hit = cache.peek("k").unwrap();
        assert!(hit.cached, "peeked hits are marked cached");
        assert_eq!(cache.hits.load(Ordering::Relaxed), 0, "peek counts nothing");
        assert_eq!(cache.misses.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn incorrect_results_cached_in_memory_but_not_persisted() {
        let path = tmp_path("incorrect");
        std::fs::remove_file(&path).ok();
        {
            let cache = ResultCache::with_database(&path).unwrap();
            let mut r = result("b580", 0.0);
            r.correct = false;
            cache.insert("k", r);
            assert!(cache.lookup("k").is_some(), "negative results hit in memory");
        }
        let warm = ResultCache::with_database(&path).unwrap();
        assert!(warm.is_empty(), "negative results do not survive restart");
        std::fs::remove_file(&path).ok();
    }
}
