//! Crash-injection fail points for the durability test harness.
//!
//! A fail point is a named place in the journal/commit path where the
//! process can be made to `abort()` — simulating a daemon crash at the
//! worst possible instant. Points are armed through the `KF_FAILPOINT`
//! environment variable (comma-separated names), so the
//! `tests/durability_crash.rs` suite can spawn the real `kernelfoundry`
//! binary, kill it mid-protocol and assert that restart + replay heal
//! the damage. With the variable unset every [`hit`] call is a no-op
//! branch on a cached set — nothing to configure, (almost) nothing to
//! pay in production.
//!
//! The armed set is read once per process: fail points model a crash,
//! and a crashed process does not change its mind.

use std::collections::HashSet;
use std::sync::OnceLock;

/// Environment variable holding the comma-separated armed point names.
pub const ENV_VAR: &str = "KF_FAILPOINT";

/// Every fail point the service layer declares, in protocol order.
/// Documented here so tests never arm a typo that silently tests
/// nothing.
pub const POINTS: &[&str] = &[
    // After the journal `submit` record is durable but before the job
    // reaches the in-memory table/queue (client may never get a receipt).
    "submit.after_journal",
    // After a lane journals `dispatch` but before it starts the unit.
    "dispatch.after_journal",
    // A unit finished, but neither the commit marker nor the result row
    // exists yet (the unit must be re-executed on replay).
    "commit.before_marker",
    // The journal commit marker is durable but the result row is not
    // (replay must repair the row exactly once).
    "commit.after_marker",
    // Marker and row are both durable but the in-memory job table never
    // heard about it (pure replay-idempotence window).
    "commit.after_row",
    // The journal `retry` record is durable but the unit was never
    // re-enqueued (replay must requeue it with its budget intact).
    "retry.after_journal",
    // The journal `quarantine` record is durable but the in-memory job
    // table never saw the terminal failure.
    "quarantine.after_journal",
];

fn armed() -> &'static HashSet<String> {
    static ARMED: OnceLock<HashSet<String>> = OnceLock::new();
    ARMED.get_or_init(|| match std::env::var(ENV_VAR) {
        Ok(v) => v
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(str::to_string)
            .collect(),
        Err(_) => HashSet::new(),
    })
}

/// Whether any fail point is armed in this process (daemon startup logs
/// it, so a stray `KF_FAILPOINT` in a real deployment is visible).
pub fn any_armed() -> bool {
    !armed().is_empty()
}

/// Abort the process if `point` was armed via `KF_FAILPOINT`.
///
/// `abort()` rather than `exit()`: no destructors, no flushes beyond
/// what already hit the kernel — the closest portable stand-in for
/// power loss.
pub fn hit(point: &str) {
    if armed().contains(point) {
        eprintln!("KF_FAILPOINT '{point}' hit: aborting process (crash injection)");
        std::process::abort();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn declared_points_are_unique_and_namespaced() {
        let mut seen = HashSet::new();
        for p in POINTS {
            assert!(seen.insert(*p), "duplicate fail point {p}");
            assert!(p.contains('.'), "fail point {p} must be namespaced");
        }
    }

    #[test]
    fn unarmed_hit_is_a_no_op() {
        // The test runner never sets KF_FAILPOINT (the crash suite arms
        // it only in spawned child processes), so this must not abort.
        hit("commit.after_marker");
        hit("not.a.point");
        assert!(!any_armed());
    }
}
