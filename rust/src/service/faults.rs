//! Deterministic, seedable fault injection for the fleet — the chaos
//! half of the fault-tolerance layer (DESIGN.md §11).
//!
//! [`failpoint`](super::failpoint) kills the whole process at a named
//! code point; that is the right tool for crash-recovery tests but
//! cannot exercise *recoverable* failure — a lane whose compiler
//! flakes, an executor that hangs past its deadline, a device that is
//! simply gone. A [`FaultPlan`] injects exactly those: it is loaded
//! from a small text file (`daemon --fault-plan`), consulted by every
//! lane at its compile and execute steps, and is a pure function of
//! `(rule, device, task, job seed, attempt)` — so a committed plan
//! reproduces the same fault schedule on every run, which is what makes
//! the retry / deadline / circuit-breaker / quarantine machinery
//! testable offline.
//!
//! # Plan grammar
//!
//! One directive per line; blank lines and `#` comments are skipped.
//!
//! ```text
//! seed <u64>                            # optional, for p= rules
//! <device|*> <compile|exec|*> fail  [times=N] [task=ID] [p=F]
//! <device|*> <compile|exec|*> hang <dur> [times=N] [task=ID] [p=F]
//! <device|*> <compile|exec|*> dead  [task=ID] [p=F]
//! ```
//!
//! * `fail` — the step errors transiently. `times=N` (default 1) makes
//!   the first N attempts of each unit fail, so retry N of a unit
//!   succeeds: the canonical transient fault.
//! * `hang` — the step blocks for `<dur>` (`250ms`, `2s`, or bare ms),
//!   cooperatively: a cancelled deadline aborts the hang early. A hang
//!   that outlives nobody's deadline resolves and the unit continues —
//!   hangs model slowness; deadlines decide whether slowness is fatal.
//! * `dead` — every attempt fails: a permanently dead lane (the retry
//!   budget then quarantines the unit, and repeated failures trip the
//!   lane's circuit breaker).
//! * `task=ID` scopes a rule to one task id; `p=F` makes the rule
//!   probabilistic with a deterministic per-attempt coin derived from
//!   the plan seed (same plan ⇒ same coin flips).
//!
//! The first matching rule wins.

use crate::util::error::Error;
use std::path::Path;
use std::time::Duration;

/// The lane step a fault attaches to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultStep {
    /// Candidate generation + compile checks.
    Compile,
    /// Device execution of the evolution run.
    Exec,
}

impl FaultStep {
    /// Grammar name of the step.
    pub fn name(self) -> &'static str {
        match self {
            FaultStep::Compile => "compile",
            FaultStep::Exec => "exec",
        }
    }
}

/// What a matched rule injects at the step.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultAction {
    /// Fail the step transiently with this injected error message.
    Fail(String),
    /// Block the step for the duration (cooperatively cancellable).
    Hang(Duration),
}

/// The step-match half of a rule: a concrete step or `*`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum StepMatch {
    Any,
    Only(FaultStep),
}

/// The injected behavior of one rule.
#[derive(Debug, Clone, PartialEq)]
enum FaultKind {
    /// Fail the first `times` attempts of each unit.
    Fail { times: u32 },
    /// Hang the first `times` attempts of each unit for `dur`.
    Hang { dur: Duration, times: u32 },
    /// Fail every attempt, forever.
    Dead,
}

/// One parsed plan line.
#[derive(Debug, Clone, PartialEq)]
struct FaultRule {
    /// Device name, or `None` for `*`.
    device: Option<String>,
    step: StepMatch,
    kind: FaultKind,
    /// Optional task-id scope.
    task: Option<String>,
    /// Optional probabilistic gate in `[0, 1]`.
    prob: Option<f64>,
}

/// A deterministic, seedable fault-injection plan (see module docs for
/// the grammar).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    seed: u64,
    rules: Vec<FaultRule>,
}

impl FaultPlan {
    /// Parse a plan from its text form.
    pub fn parse(text: &str) -> Result<FaultPlan, Error> {
        let mut plan = FaultPlan::default();
        for (idx, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let err =
                |msg: &str| Error::msg(format!("fault plan line {}: {msg}: {raw:?}", idx + 1));
            let words: Vec<&str> = line.split_whitespace().collect();
            if words[0] == "seed" {
                let v = words.get(1).and_then(|w| w.parse::<u64>().ok());
                plan.seed = v.ok_or_else(|| err("expected `seed <u64>`"))?;
                continue;
            }
            if words.len() < 3 {
                return Err(err("expected `<device> <step> <action> [k=v ...]`"));
            }
            let device = match words[0] {
                "*" => None,
                d => Some(d.to_string()),
            };
            let step = match words[1] {
                "*" => StepMatch::Any,
                "compile" => StepMatch::Only(FaultStep::Compile),
                "exec" => StepMatch::Only(FaultStep::Exec),
                _ => return Err(err("step must be `compile`, `exec` or `*`")),
            };
            let (mut kind, opts_from) = match words[2] {
                "fail" => (FaultKind::Fail { times: 1 }, 3),
                "dead" => (FaultKind::Dead, 3),
                "hang" => {
                    let dur = words
                        .get(3)
                        .and_then(|w| parse_duration(w))
                        .ok_or_else(|| err("expected `hang <duration>` (e.g. 250ms, 2s)"))?;
                    (FaultKind::Hang { dur, times: 1 }, 4)
                }
                _ => return Err(err("action must be `fail`, `hang <dur>` or `dead`")),
            };
            let mut task = None;
            let mut prob = None;
            for opt in &words[opts_from..] {
                let (key, value) = opt
                    .split_once('=')
                    .ok_or_else(|| err("options must be `key=value`"))?;
                match key {
                    "times" => {
                        let n = value.parse::<u32>().map_err(|_| err("times must be a u32"))?;
                        match &mut kind {
                            FaultKind::Fail { times } | FaultKind::Hang { times, .. } => *times = n,
                            FaultKind::Dead => {
                                return Err(err("`dead` takes no times= (it is forever)"))
                            }
                        }
                    }
                    "task" => task = Some(value.to_string()),
                    "p" => {
                        let p = value.parse::<f64>().map_err(|_| err("p must be a float"))?;
                        if !(0.0..=1.0).contains(&p) {
                            return Err(err("p must be in [0, 1]"));
                        }
                        prob = Some(p);
                    }
                    _ => return Err(err("unknown option (want times=, task=, p=)")),
                }
            }
            plan.rules.push(FaultRule {
                device,
                step,
                kind,
                task,
                prob,
            });
        }
        Ok(plan)
    }

    /// Load and parse a plan file.
    pub fn load(path: &Path) -> Result<FaultPlan, Error> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::msg(format!("reading fault plan {}: {e}", path.display())))?;
        FaultPlan::parse(&text)
    }

    /// Number of rules in the plan.
    pub fn len(&self) -> usize {
        self.rules.len()
    }

    /// Whether the plan has no rules.
    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }

    /// Consult the plan at one lane step. Returns the action of the
    /// first matching rule, or `None` for a clean step. Deterministic:
    /// the answer depends only on the arguments and the plan itself.
    pub fn check(
        &self,
        device: &str,
        step: FaultStep,
        task: &str,
        job_seed: u64,
        attempt: u32,
    ) -> Option<FaultAction> {
        for rule in &self.rules {
            if let Some(d) = &rule.device {
                if d != device {
                    continue;
                }
            }
            match rule.step {
                StepMatch::Any => {}
                StepMatch::Only(s) if s == step => {}
                StepMatch::Only(_) => continue,
            }
            if let Some(t) = &rule.task {
                if t != task {
                    continue;
                }
            }
            let armed = match &rule.kind {
                FaultKind::Dead => true,
                FaultKind::Fail { times } | FaultKind::Hang { times, .. } => attempt < *times,
            };
            if !armed {
                continue;
            }
            if let Some(p) = rule.prob {
                if coin(self.seed, device, task, job_seed, attempt) >= p {
                    continue;
                }
            }
            return Some(match &rule.kind {
                FaultKind::Fail { .. } => FaultAction::Fail(format!(
                    "injected fault: {} step failed on {device} (attempt {attempt})",
                    step.name()
                )),
                FaultKind::Hang { dur, .. } => FaultAction::Hang(*dur),
                FaultKind::Dead => FaultAction::Fail(format!(
                    "injected fault: lane {device} is dead ({} step, attempt {attempt})",
                    step.name()
                )),
            });
        }
        None
    }
}

/// Deterministic per-attempt coin in `[0, 1)` for `p=` rules: FNV-1a
/// over the full fault coordinate, so the same plan seed replays the
/// same flips.
fn coin(seed: u64, device: &str, task: &str, job_seed: u64, attempt: u32) -> f64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |bytes: &[u8]| {
        for b in bytes {
            h ^= *b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    eat(&seed.to_le_bytes());
    eat(device.as_bytes());
    eat(&[0]);
    eat(task.as_bytes());
    eat(&job_seed.to_le_bytes());
    eat(&attempt.to_le_bytes());
    (h >> 11) as f64 / (1u64 << 53) as f64
}

/// Parse `250ms`, `2s`, or a bare millisecond count.
fn parse_duration(word: &str) -> Option<Duration> {
    if let Some(ms) = word.strip_suffix("ms") {
        return ms.parse::<u64>().ok().map(Duration::from_millis);
    }
    if let Some(s) = word.strip_suffix('s') {
        return s.parse::<u64>().ok().map(Duration::from_secs);
    }
    word.parse::<u64>().ok().map(Duration::from_millis)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grammar_parses_every_action_and_option() {
        let plan = FaultPlan::parse(
            "# chaos\nseed 42\n\nb580 compile fail times=2\nlnl exec hang 250ms times=3\n* * dead task=20_LeakyReLU\nb580 exec fail p=0.5\n",
        )
        .unwrap();
        assert_eq!(plan.len(), 4);
        assert_eq!(plan.seed, 42);
        assert!(!plan.is_empty());

        for bad in [
            "b580 compile explode",
            "b580 sideways fail",
            "b580 compile hang",
            "b580 compile hang soonish",
            "b580 compile fail times=x",
            "b580 compile fail p=2.0",
            "b580 compile dead times=3",
            "b580 compile fail frobnicate=1",
            "seed notanumber",
            "b580 fail",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn counted_fail_clears_after_its_budget() {
        let plan = FaultPlan::parse("b580 compile fail times=2").unwrap();
        for attempt in 0..2 {
            let hit = plan.check("b580", FaultStep::Compile, "t", 1, attempt);
            assert!(matches!(hit, Some(FaultAction::Fail(_))), "attempt {attempt}");
        }
        assert_eq!(plan.check("b580", FaultStep::Compile, "t", 1, 2), None);
        // Wrong device / wrong step never match.
        assert_eq!(plan.check("lnl", FaultStep::Compile, "t", 1, 0), None);
        assert_eq!(plan.check("b580", FaultStep::Exec, "t", 1, 0), None);
    }

    #[test]
    fn dead_matches_every_attempt_and_wildcards_match_everything() {
        let plan = FaultPlan::parse("* * dead").unwrap();
        for attempt in [0, 1, 17, 4096] {
            for step in [FaultStep::Compile, FaultStep::Exec] {
                let hit = plan.check("anything", step, "any_task", 9, attempt);
                match hit {
                    Some(FaultAction::Fail(msg)) => assert!(msg.contains("dead"), "{msg}"),
                    other => panic!("expected dead fail, got {other:?}"),
                }
            }
        }
    }

    #[test]
    fn hang_carries_its_duration_and_task_scope_filters() {
        let plan = FaultPlan::parse("lnl exec hang 2s task=20_LeakyReLU").unwrap();
        let hit = plan.check("lnl", FaultStep::Exec, "20_LeakyReLU", 3, 0);
        assert_eq!(hit, Some(FaultAction::Hang(Duration::from_secs(2))));
        assert_eq!(plan.check("lnl", FaultStep::Exec, "other_task", 3, 0), None);
        assert_eq!(
            plan.check("lnl", FaultStep::Exec, "20_LeakyReLU", 3, 1),
            None,
            "times=1 default"
        );
    }

    #[test]
    fn probabilistic_rules_are_deterministic_and_roughly_calibrated() {
        let plan = FaultPlan::parse("seed 7\nb580 exec fail p=0.5 times=1000000").unwrap();
        let flips: Vec<bool> = (0..400)
            .map(|j| plan.check("b580", FaultStep::Exec, "t", j, 0).is_some())
            .collect();
        let again: Vec<bool> = (0..400)
            .map(|j| plan.check("b580", FaultStep::Exec, "t", j, 0).is_some())
            .collect();
        assert_eq!(flips, again, "same plan replays the same coin flips");
        let hits = flips.iter().filter(|b| **b).count();
        assert!((100..=300).contains(&hits), "p=0.5 over 400 flips hit {hits}");
        // A different seed flips a different schedule.
        let other = FaultPlan::parse("seed 8\nb580 exec fail p=0.5 times=1000000").unwrap();
        let other_flips: Vec<bool> = (0..400)
            .map(|j| other.check("b580", FaultStep::Exec, "t", j, 0).is_some())
            .collect();
        assert_ne!(flips, other_flips);
    }

    #[test]
    fn first_matching_rule_wins() {
        let plan = FaultPlan::parse("b580 compile fail\n* * dead").unwrap();
        match plan.check("b580", FaultStep::Compile, "t", 1, 0) {
            Some(FaultAction::Fail(msg)) => assert!(!msg.contains("dead"), "{msg}"),
            other => panic!("{other:?}"),
        }
        // The catch-all still covers everything else.
        assert!(plan.check("lnl", FaultStep::Exec, "t", 1, 5).is_some());
    }
}
