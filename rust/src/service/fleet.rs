//! The heterogeneous fleet scheduler: one lane per device profile.
//!
//! Each lane owns one (simulated) device and runs a worker thread that
//! pops units routed to its device from the shared [`JobQueue`]. A
//! popped unit becomes a full §3.1 evolution run: the lane builds an
//! [`EvolutionEngine`] for the job's task and its own device, plus a
//! [`WorkerPool`] (Fig. 4 compile→execute cluster) seeded to be
//! verdict-identical to the engine's inline pipeline, and drives
//! [`EvolutionEngine::run_distributed`]. Heterogeneity is the point:
//! lanes for `lnl`, `b580` and `a6000` run simultaneously, so a routed
//! job occupies one device while a fan-out job compares all of them —
//! the paper's "remote access to diverse hardware" (§3.6).
//!
//! Per-lane counters (busy time, units, pipeline totals) feed the
//! `stats` verb's utilization report.

use super::cache::{cache_key, ResultCache};
use super::failpoint;
use super::job::{DeviceResult, JobState, JobTable, TaskSource};
use super::journal::{Journal, JournalRecord};
use super::queue::{JobQueue, QueuedUnit};
use super::ServiceConfig;
use crate::config::FoundryConfig;
use crate::coordinator::EvolutionEngine;
use crate::dist::{ClusterConfig, WorkerPool};
use crate::eval::ExecBackend;
use crate::hwsim::DeviceProfile;
use crate::obs::trace::stage;
use crate::obs::{labeled, Registry, TraceSink};
use crate::report::SearchLog;
use crate::tasks::{catalog, custom};
use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Instant;

/// Per-lane counters, accumulated over the lane's lifetime.
#[derive(Debug, Default)]
pub struct LaneStats {
    /// Wall-clock microseconds the lane spent executing units.
    pub busy_us: AtomicU64,
    /// Units completed with a result.
    pub units_done: AtomicU64,
    /// Units that failed.
    pub units_failed: AtomicU64,
    /// Candidates executed on the lane's device across all units.
    pub executed: AtomicU64,
    /// Candidates early-rejected by the lane's compile workers.
    pub compile_rejected: AtomicU64,
}

/// One device lane: the profile plus its live counters.
pub struct LaneInfo {
    /// The lane's device profile.
    pub device: DeviceProfile,
    /// The lane's counters.
    pub stats: Arc<LaneStats>,
}

/// The fleet: every lane plus the worker threads driving them.
pub struct Fleet {
    lanes: Vec<LaneInfo>,
    handles: Mutex<Vec<thread::JoinHandle<()>>>,
    started: Instant,
}

impl Fleet {
    /// Spawn one lane thread per configured device. Lanes run until the
    /// queue shuts down (draining remaining units first).
    #[allow(clippy::too_many_arguments)]
    pub fn spawn(
        cfg: &ServiceConfig,
        queue: &Arc<JobQueue>,
        jobs: &Arc<JobTable>,
        cache: &Arc<ResultCache>,
        journal: Option<&Arc<Journal>>,
        obs: &Arc<Registry>,
        trace: Option<&Arc<TraceSink>>,
        search_log: Option<&Arc<SearchLog>>,
    ) -> Fleet {
        let mut lanes = Vec::new();
        let mut handles = Vec::new();
        for device in &cfg.devices {
            let stats = Arc::new(LaneStats::default());
            lanes.push(LaneInfo {
                device: device.clone(),
                stats: Arc::clone(&stats),
            });
            let device = device.clone();
            let queue = Arc::clone(queue);
            let jobs = Arc::clone(jobs);
            let cache = Arc::clone(cache);
            let journal = journal.map(Arc::clone);
            let obs = Arc::clone(obs);
            let trace = trace.map(Arc::clone);
            let search_log = search_log.map(Arc::clone);
            let compile_workers = cfg.compile_workers;
            let exec_workers = cfg.exec_workers;
            let queue_capacity = cfg.queue_capacity;
            handles.push(thread::spawn(move || {
                lane_main(
                    device,
                    compile_workers,
                    exec_workers,
                    queue_capacity,
                    queue,
                    jobs,
                    cache,
                    journal,
                    obs,
                    trace,
                    search_log,
                    stats,
                )
            }));
        }
        Fleet {
            lanes,
            handles: Mutex::new(handles),
            started: Instant::now(),
        }
    }

    /// Device names in lane order.
    pub fn device_names(&self) -> Vec<String> {
        self.lanes.iter().map(|l| l.device.name.to_string()).collect()
    }

    /// Whether a lane exists for the named device.
    pub fn has_device(&self, name: &str) -> bool {
        self.lanes.iter().any(|l| l.device.name == name)
    }

    /// Number of lanes.
    pub fn len(&self) -> usize {
        self.lanes.len()
    }

    /// Whether the fleet has no lanes.
    pub fn is_empty(&self) -> bool {
        self.lanes.is_empty()
    }

    /// Per-device utilization report for the `stats` verb: busy time,
    /// unit counts and pipeline totals, with `utilization` = busy
    /// wall-clock over fleet uptime.
    pub fn stats_json(&self) -> Json {
        let uptime_us = self.started.elapsed().as_micros().max(1) as f64;
        let rows: Vec<Json> = self
            .lanes
            .iter()
            .map(|lane| {
                let busy_us = lane.stats.busy_us.load(Ordering::Relaxed) as f64;
                let mut o = Json::obj();
                o.set("device", lane.device.name)
                    .set("units_done", lane.stats.units_done.load(Ordering::Relaxed) as f64)
                    .set(
                        "units_failed",
                        lane.stats.units_failed.load(Ordering::Relaxed) as f64,
                    )
                    .set("executed", lane.stats.executed.load(Ordering::Relaxed) as f64)
                    .set(
                        "compile_rejected",
                        lane.stats.compile_rejected.load(Ordering::Relaxed) as f64,
                    )
                    .set("busy_ms", busy_us / 1000.0)
                    .set("utilization", (busy_us / uptime_us).min(1.0));
                o
            })
            .collect();
        Json::Arr(rows)
    }

    /// Join every lane thread (call after the queue has shut down).
    pub fn join(&self) {
        for handle in self.handles.lock().unwrap().drain(..) {
            handle.join().ok();
        }
    }
}

/// One lane's worker loop: pop → run → record, until shutdown.
#[allow(clippy::too_many_arguments)]
fn lane_main(
    device: DeviceProfile,
    compile_workers: usize,
    exec_workers: usize,
    queue_capacity: usize,
    queue: Arc<JobQueue>,
    jobs: Arc<JobTable>,
    cache: Arc<ResultCache>,
    journal: Option<Arc<Journal>>,
    obs: Arc<Registry>,
    trace: Option<Arc<TraceSink>>,
    search_log: Option<Arc<SearchLog>>,
    stats: Arc<LaneStats>,
) {
    while let Some(unit) = queue.pop_for(device.name) {
        if let Some(jnl) = &journal {
            let rec = JournalRecord::Dispatch {
                job_id: unit.job_id,
                device: device.name.to_string(),
            };
            if let Err(e) = jnl.append(&rec) {
                crate::log_warn!("journal dispatch failed: {e}");
            }
            failpoint::hit("dispatch.after_journal");
        }
        if let Some(t) = &trace {
            t.stage(stage::DISPATCHED, unit.job_id, Some(device.name));
        }
        // Queue-wait latency: submit → this lane picking the unit up.
        if let Some(job) = jobs.get(unit.job_id) {
            obs.observe_ms(
                "kf_stage_queued_ms",
                job.submitted_at.elapsed().as_secs_f64() * 1000.0,
            );
        }
        jobs.set_unit_state(unit.job_id, device.name, JobState::Generating);
        let t0 = Instant::now();
        // catch_unwind: a panicking unit must fail *that job*, not kill
        // the lane — a dead lane would silently remove the device from
        // the fleet while its queued units hang forever.
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_unit(
                &unit,
                &device,
                compile_workers,
                exec_workers,
                queue_capacity,
                &jobs,
                &obs,
                trace.as_ref(),
                search_log.as_ref(),
                &stats,
            )
        }))
        .unwrap_or_else(|_| Err("unit execution panicked (lane recovered)".to_string()));
        stats
            .busy_us
            .fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
        obs.observe_ms("kf_stage_run_ms", t0.elapsed().as_secs_f64() * 1000.0);
        match outcome {
            Ok(result) => {
                if let Some(t) = &trace {
                    t.stage(stage::EXECUTED, unit.job_id, Some(device.name));
                }
                // Slot-commit protocol: the journal Commit marker is
                // written *before* the cache row. A crash between the
                // two is repaired idempotently at replay (the marker's
                // result is re-inserted only if its row is missing), so
                // no interleaving of crash points can publish a
                // duplicate or torn verdict row.
                if let Some(jnl) = &journal {
                    failpoint::hit("commit.before_marker");
                    let rec = JournalRecord::Commit {
                        job_id: unit.job_id,
                        device: device.name.to_string(),
                        result: result.clone(),
                    };
                    if let Err(e) = jnl.append(&rec) {
                        crate::log_warn!("journal commit failed: {e}");
                    }
                    failpoint::hit("commit.after_marker");
                }
                cache.insert(&cache_key(&unit.spec, device.name), result.clone());
                failpoint::hit("commit.after_row");
                if let Some(t) = &trace {
                    t.stage(stage::COMMITTED, unit.job_id, Some(device.name));
                }
                obs.counter("kf_units_committed_total").inc();
                obs.counter(&labeled("kf_lane_units_done_total", "device", device.name))
                    .inc();
                stats.units_done.fetch_add(1, Ordering::Relaxed);
                jobs.complete_unit(unit.job_id, device.name, result);
            }
            Err(msg) => {
                if let Some(t) = &trace {
                    t.stage(stage::FAILED, unit.job_id, Some(device.name));
                }
                obs.counter("kf_units_failed_total").inc();
                obs.counter(&labeled("kf_lane_units_failed_total", "device", device.name))
                    .inc();
                if let Some(jnl) = &journal {
                    let rec = JournalRecord::Fail {
                        job_id: unit.job_id,
                        device: device.name.to_string(),
                        error: msg.clone(),
                    };
                    if let Err(e) = jnl.append(&rec) {
                        crate::log_warn!("journal fail failed: {e}");
                    }
                }
                stats.units_failed.fetch_add(1, Ordering::Relaxed);
                jobs.fail_unit(unit.job_id, device.name, msg);
            }
        }
    }
}

/// Execute one unit: resolve the task, build engine + pool for this
/// lane's device, run the evolution loop, summarize.
#[allow(clippy::too_many_arguments)]
fn run_unit(
    unit: &QueuedUnit,
    device: &DeviceProfile,
    compile_workers: usize,
    exec_workers: usize,
    queue_capacity: usize,
    jobs: &JobTable,
    obs: &Arc<Registry>,
    trace: Option<&Arc<TraceSink>>,
    search_log: Option<&Arc<SearchLog>>,
    stats: &LaneStats,
) -> Result<DeviceResult, String> {
    let task = match &unit.spec.task {
        TaskSource::Catalog(id) => {
            catalog::find_task(id).ok_or_else(|| format!("unknown task '{id}'"))?
        }
        TaskSource::Custom { config, source } => custom::load_strings(config, source)
            .map_err(|e| format!("custom task: {e}"))?
            .spec,
    };
    let mut config = FoundryConfig::paper_defaults();
    config.seed = unit.spec.seed;
    config.device = device.name.to_string();
    config.language = unit.spec.language.clone();
    config.evolution.max_generations = unit.spec.iters;
    config.evolution.population = unit.spec.population;

    let mut engine = EvolutionEngine::new(config, task, ExecBackend::HwSim(device.clone()));
    // Search-history rows are labeled with the unit's cache key, so a
    // run's per-generation curves join its persisted result row.
    if let Some(log) = search_log {
        engine.attach_search_log(Arc::clone(log), &cache_key(&unit.spec, device.name));
    }
    // The lane's Fig. 4 cluster, seeded so every verdict matches the
    // engine's inline pipeline (see `EvalPipeline::seed`).
    let pool = WorkerPool::new(ClusterConfig {
        compile_workers,
        exec_workers,
        device: device.clone(),
        queue_capacity,
        seed: engine.pipeline.seed(),
    });

    // Engine + Fig. 4 cluster are built: generation is set up and the
    // compile workers are live — the unit's `compiled` trace point.
    if let Some(t) = trace {
        t.stage(stage::COMPILED, unit.job_id, Some(device.name));
    }
    jobs.set_unit_state(unit.job_id, device.name, JobState::Evaluating);
    let t0 = Instant::now();
    let report = engine.run_distributed(&pool);
    let wall_ms = t0.elapsed().as_secs_f64() * 1000.0;
    obs.observe_ms("kf_unit_evolution_ms", wall_ms);

    stats
        .executed
        .fetch_add(pool.metrics.executed.load(Ordering::Relaxed), Ordering::Relaxed);
    stats.compile_rejected.fetch_add(
        pool.metrics.compile_rejected.load(Ordering::Relaxed),
        Ordering::Relaxed,
    );
    Ok(DeviceResult::from_report(device.name, &report, wall_ms))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::job::{Job, JobPriority, JobSpec, JobUnit};

    type Fixture = (ServiceConfig, Arc<JobQueue>, Arc<JobTable>, Arc<ResultCache>);

    fn fleet_fixture(devices: Vec<DeviceProfile>) -> Fixture {
        let cfg = ServiceConfig {
            devices,
            compile_workers: 1,
            exec_workers: 2,
            queue_capacity: 8,
            ..ServiceConfig::default()
        };
        (
            cfg,
            Arc::new(JobQueue::new(8)),
            Arc::new(JobTable::new()),
            Arc::new(ResultCache::in_memory()),
        )
    }

    /// A lane executes a queued unit end-to-end: job table completion,
    /// cache population and stats accounting.
    #[test]
    fn lane_runs_a_unit_to_completion() {
        let (cfg, queue, jobs, cache) = fleet_fixture(vec![DeviceProfile::b580()]);
        let obs = Arc::new(Registry::new());
        let fleet = Fleet::spawn(&cfg, &queue, &jobs, &cache, None, &obs, None, None);
        assert!(fleet.has_device("b580"));
        assert!(!fleet.has_device("lnl"));

        let mut spec = JobSpec::catalog("20_LeakyReLU", "b580");
        spec.iters = 2;
        spec.population = 2;
        jobs.insert(Job {
            id: 1,
            spec: spec.clone(),
            submitted_at: Instant::now(),
            units: vec![JobUnit {
                device: "b580".to_string(),
                state: JobState::Queued,
                result: None,
                error: None,
            }],
        });
        queue
            .push(vec![QueuedUnit {
                job_id: 1,
                device: "b580".to_string(),
                priority: JobPriority::Normal,
                seq: 0,
                spec: spec.clone(),
            }])
            .unwrap();

        let deadline = Instant::now() + std::time::Duration::from_secs(30);
        while !jobs.get(1).unwrap().state().finished() {
            assert!(Instant::now() < deadline, "unit did not finish in time");
            thread::sleep(std::time::Duration::from_millis(5));
        }
        let job = jobs.get(1).unwrap();
        assert_eq!(job.state(), JobState::Done);
        let result = job.units[0].result.as_ref().expect("unit result");
        assert_eq!(result.device, "b580");
        assert_eq!(result.evaluations, 4, "2 gens x pop 2");
        assert!(!result.cached);
        assert_eq!(cache.len(), 1, "completed unit populated the cache");
        assert_eq!(fleet.lanes[0].stats.units_done.load(Ordering::Relaxed), 1);
        assert!(fleet.lanes[0].stats.busy_us.load(Ordering::Relaxed) > 0);
        assert_eq!(obs.counter_value("kf_units_committed_total"), 1);
        assert_eq!(
            obs.counter_value(&labeled("kf_lane_units_done_total", "device", "b580")),
            1
        );
        assert_eq!(obs.histogram("kf_stage_run_ms").snapshot().count(), 1);

        queue.shutdown();
        fleet.join();
    }

    /// A run-time failure (task unknown at execution) marks the unit —
    /// and hence the job — failed instead of wedging the lane.
    #[test]
    fn lane_survives_a_failing_unit() {
        let (cfg, queue, jobs, cache) = fleet_fixture(vec![DeviceProfile::b580()]);
        let obs = Arc::new(Registry::new());
        let fleet = Fleet::spawn(&cfg, &queue, &jobs, &cache, None, &obs, None, None);
        let spec = JobSpec::catalog("no_such_task", "b580");
        jobs.insert(Job {
            id: 1,
            spec: spec.clone(),
            submitted_at: Instant::now(),
            units: vec![JobUnit {
                device: "b580".to_string(),
                state: JobState::Queued,
                result: None,
                error: None,
            }],
        });
        queue
            .push(vec![QueuedUnit {
                job_id: 1,
                device: "b580".to_string(),
                priority: JobPriority::Normal,
                seq: 0,
                spec,
            }])
            .unwrap();
        let deadline = Instant::now() + std::time::Duration::from_secs(10);
        while !jobs.get(1).unwrap().state().finished() {
            assert!(Instant::now() < deadline, "unit did not finish in time");
            thread::sleep(std::time::Duration::from_millis(5));
        }
        let job = jobs.get(1).unwrap();
        assert_eq!(job.state(), JobState::Failed);
        assert!(job.units[0].error.as_ref().unwrap().contains("unknown task"));
        assert_eq!(fleet.lanes[0].stats.units_failed.load(Ordering::Relaxed), 1);
        queue.shutdown();
        fleet.join();
    }
}
