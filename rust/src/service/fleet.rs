//! The heterogeneous fleet scheduler: one supervised lane per device.
//!
//! Each lane owns one (simulated) device and runs a worker thread that
//! pops units routed to its device from the shared [`JobQueue`]. A
//! popped unit becomes a full §3.1 evolution run: the lane builds an
//! [`EvolutionEngine`] for the job's task and its own device, plus a
//! [`WorkerPool`] (Fig. 4 compile→execute cluster) seeded to be
//! verdict-identical to the engine's inline pipeline, and drives
//! [`EvolutionEngine::run_distributed`]. Heterogeneity is the point:
//! lanes for `lnl`, `b580` and `a6000` run simultaneously, so a routed
//! job occupies one device while a fan-out job compares all of them —
//! the paper's "remote access to diverse hardware" (§3.6).
//!
//! On top of the execution loop sits the fault-tolerance layer:
//!
//! * **Retries with backoff.** A *transient* unit failure (injected
//!   fault, exceeded deadline, panic) is journalled as a `retry` record
//!   and re-enqueued with exponential backoff and deterministic jitter
//!   ([`backoff_delay`]). Deterministic errors (unknown task, bad custom
//!   config) fail immediately — retrying them would only repeat the
//!   verdict.
//! * **Poison quarantine.** A unit that exhausts its retry budget on
//!   one lane is committed as a deterministic failure verdict (journal
//!   `quarantine` record, terminal like `fail`), so a poison genome can
//!   never wedge the fleet.
//! * **Lane supervision.** Each lane runs a [`CircuitBreaker`]:
//!   consecutive transient failures trip it open, the open lane sheds
//!   its *fresh* queued units — routed units reroute to a healthy peer
//!   (journal `reroute`), fan-out units degrade to the surviving subset
//!   (the job reports `partial`) — and after a cooldown the lane probes
//!   half-open with a single unit. Mid-retry units stay pinned to their
//!   lane so the retry budget, and hence the quarantine verdict, stays
//!   deterministic.
//! * **Deadlines.** With a configured unit deadline, a fleet-wide
//!   supervisor thread sweeps the [`InFlight`] table and cooperatively
//!   cancels overdue attempts (engine generation loop, worker-pool feed
//!   and injected hangs all poll the token).
//!
//! Per-lane counters (busy time, units, retries, quarantines, pipeline
//! totals) feed the `stats` verb's utilization report.

use super::cache::{cache_key, ResultCache};
use super::failpoint;
use super::faults::{FaultAction, FaultPlan, FaultStep};
use super::job::{DeviceResult, DeviceTarget, JobState, JobTable, TaskSource};
use super::journal::{Journal, JournalRecord};
use super::queue::{JobQueue, QueuedUnit};
use super::supervisor::{
    backoff_delay, CancelToken, CircuitBreaker, GuardConfig, InFlight, LaneHealth, LaneState,
};
use super::ServiceConfig;
use crate::config::FoundryConfig;
use crate::coordinator::EvolutionEngine;
use crate::dist::{ClusterConfig, WorkerPool};
use crate::eval::ExecBackend;
use crate::hwsim::DeviceProfile;
use crate::obs::trace::stage;
use crate::obs::{labeled, Registry, TraceSink};
use crate::report::SearchLog;
use crate::tasks::{catalog, custom};
use crate::util::json::Json;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// How often an open lane re-checks its queue for units to shed and its
/// cooldown for the half-open probe.
const OPEN_POLL: Duration = Duration::from_millis(20);
/// How often a half-open lane polls for a probe unit.
const HALF_OPEN_POLL: Duration = Duration::from_millis(10);
/// Deadline-supervisor sweep interval.
const SWEEP: Duration = Duration::from_millis(5);

/// Per-lane counters, accumulated over the lane's lifetime.
#[derive(Debug, Default)]
pub struct LaneStats {
    /// Wall-clock microseconds the lane spent executing units.
    pub busy_us: AtomicU64,
    /// Units completed with a result.
    pub units_done: AtomicU64,
    /// Units that failed.
    pub units_failed: AtomicU64,
    /// Transient failures that were re-enqueued with backoff.
    pub retries: AtomicU64,
    /// Units committed as deterministic failures after exhausting their
    /// retry budget on this lane.
    pub quarantined: AtomicU64,
    /// Queued units this lane shed to a healthy peer while open.
    pub rerouted_away: AtomicU64,
    /// Candidates executed on the lane's device across all units.
    pub executed: AtomicU64,
    /// Candidates early-rejected by the lane's compile workers.
    pub compile_rejected: AtomicU64,
}

/// One device lane: the profile plus its live counters and health.
pub struct LaneInfo {
    /// The lane's device profile.
    pub device: DeviceProfile,
    /// The lane's counters.
    pub stats: Arc<LaneStats>,
    /// The lane's published circuit-breaker state.
    pub health: LaneHealth,
}

/// The fleet: every lane plus the worker threads driving them.
pub struct Fleet {
    lanes: Vec<LaneInfo>,
    handles: Mutex<Vec<thread::JoinHandle<()>>>,
    supervisor: Mutex<Option<thread::JoinHandle<()>>>,
    sup_stop: Arc<AtomicBool>,
    started: Instant,
}

/// Everything one lane thread needs, bundled so the loop helpers stay
/// readable.
struct LaneCtx {
    device: DeviceProfile,
    compile_workers: usize,
    exec_workers: usize,
    queue_capacity: usize,
    guard: GuardConfig,
    faults: Option<Arc<FaultPlan>>,
    queue: Arc<JobQueue>,
    jobs: Arc<JobTable>,
    cache: Arc<ResultCache>,
    journal: Option<Arc<Journal>>,
    obs: Arc<Registry>,
    trace: Option<Arc<TraceSink>>,
    search_log: Option<Arc<SearchLog>>,
    stats: Arc<LaneStats>,
    health: LaneHealth,
    /// `(device, health)` of every lane, in fleet order, for reroutes.
    peers: Arc<Vec<(String, LaneHealth)>>,
    inflight: Arc<InFlight>,
}

/// A unit attempt's failure, split by whether a retry could change the
/// outcome.
struct UnitError {
    message: String,
    /// `true` for flaky-hardware failures (injected faults, deadlines,
    /// panics); `false` for deterministic job errors (unknown task).
    transient: bool,
}

impl UnitError {
    fn transient(message: String) -> UnitError {
        UnitError {
            message,
            transient: true,
        }
    }

    fn permanent(message: String) -> UnitError {
        UnitError {
            message,
            transient: false,
        }
    }
}

impl Fleet {
    /// Spawn one lane thread per configured device (plus the deadline
    /// supervisor when `cfg.guard.unit_deadline` is set). Lanes run
    /// until the queue shuts down (draining remaining units first).
    #[allow(clippy::too_many_arguments)]
    pub fn spawn(
        cfg: &ServiceConfig,
        queue: &Arc<JobQueue>,
        jobs: &Arc<JobTable>,
        cache: &Arc<ResultCache>,
        journal: Option<&Arc<Journal>>,
        obs: &Arc<Registry>,
        trace: Option<&Arc<TraceSink>>,
        search_log: Option<&Arc<SearchLog>>,
    ) -> Fleet {
        // Pre-register the retry counter at zero so rate-based alert
        // rules over it resolve even before the first retry.
        obs.counter("kf_retry_total");
        let faults = cfg
            .fault_plan
            .clone()
            .filter(|p| !p.is_empty())
            .map(Arc::new);
        let inflight = Arc::new(InFlight::new());
        let lanes: Vec<LaneInfo> = cfg
            .devices
            .iter()
            .map(|device| LaneInfo {
                device: device.clone(),
                stats: Arc::new(LaneStats::default()),
                health: LaneHealth::new(),
            })
            .collect();
        let peers: Arc<Vec<(String, LaneHealth)>> = Arc::new(
            lanes
                .iter()
                .map(|l| (l.device.name.to_string(), l.health.clone()))
                .collect(),
        );
        let mut handles = Vec::new();
        for lane in &lanes {
            obs.gauge(&labeled("kf_lane_state", "device", lane.device.name))
                .set(LaneState::Closed.as_u8() as f64);
            let ctx = LaneCtx {
                device: lane.device.clone(),
                compile_workers: cfg.compile_workers,
                exec_workers: cfg.exec_workers,
                queue_capacity: cfg.queue_capacity,
                guard: cfg.guard.clone(),
                faults: faults.clone(),
                queue: Arc::clone(queue),
                jobs: Arc::clone(jobs),
                cache: Arc::clone(cache),
                journal: journal.map(Arc::clone),
                obs: Arc::clone(obs),
                trace: trace.map(Arc::clone),
                search_log: search_log.map(Arc::clone),
                stats: Arc::clone(&lane.stats),
                health: lane.health.clone(),
                peers: Arc::clone(&peers),
                inflight: Arc::clone(&inflight),
            };
            handles.push(thread::spawn(move || lane_main(ctx)));
        }
        let sup_stop = Arc::new(AtomicBool::new(false));
        let supervisor = cfg.guard.unit_deadline.map(|_| {
            let inflight = Arc::clone(&inflight);
            let stop = Arc::clone(&sup_stop);
            let obs = Arc::clone(obs);
            thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    thread::sleep(SWEEP);
                    for (job_id, device) in inflight.expire(Instant::now()) {
                        crate::log_warn!(
                            "unit deadline exceeded: job {job_id} on {device} (attempt cancelled)"
                        );
                        obs.counter("kf_deadline_exceeded_total").inc();
                        obs.counter(&labeled(
                            "kf_lane_deadline_exceeded_total",
                            "device",
                            &device,
                        ))
                        .inc();
                    }
                }
            })
        });
        Fleet {
            lanes,
            handles: Mutex::new(handles),
            supervisor: Mutex::new(supervisor),
            sup_stop,
            started: Instant::now(),
        }
    }

    /// Device names in lane order.
    pub fn device_names(&self) -> Vec<String> {
        self.lanes.iter().map(|l| l.device.name.to_string()).collect()
    }

    /// Whether a lane exists for the named device.
    pub fn has_device(&self, name: &str) -> bool {
        self.lanes.iter().any(|l| l.device.name == name)
    }

    /// Number of lanes.
    pub fn len(&self) -> usize {
        self.lanes.len()
    }

    /// Whether the fleet has no lanes.
    pub fn is_empty(&self) -> bool {
        self.lanes.is_empty()
    }

    /// Lanes whose circuit breaker is currently open (quarantined).
    pub fn open_lanes(&self) -> usize {
        self.lanes
            .iter()
            .filter(|l| l.health.get() == LaneState::Open)
            .count()
    }

    /// Per-device utilization report for the `stats` verb: breaker
    /// state, busy time, unit/retry counts and pipeline totals, with
    /// `utilization` = busy wall-clock over fleet uptime.
    pub fn stats_json(&self) -> Json {
        let uptime_us = self.started.elapsed().as_micros().max(1) as f64;
        let rows: Vec<Json> = self
            .lanes
            .iter()
            .map(|lane| {
                let busy_us = lane.stats.busy_us.load(Ordering::Relaxed) as f64;
                let mut o = Json::obj();
                o.set("device", lane.device.name)
                    .set("state", lane.health.get().name())
                    .set("units_done", lane.stats.units_done.load(Ordering::Relaxed) as f64)
                    .set(
                        "units_failed",
                        lane.stats.units_failed.load(Ordering::Relaxed) as f64,
                    )
                    .set("retries", lane.stats.retries.load(Ordering::Relaxed) as f64)
                    .set(
                        "quarantined",
                        lane.stats.quarantined.load(Ordering::Relaxed) as f64,
                    )
                    .set(
                        "rerouted_away",
                        lane.stats.rerouted_away.load(Ordering::Relaxed) as f64,
                    )
                    .set("executed", lane.stats.executed.load(Ordering::Relaxed) as f64)
                    .set(
                        "compile_rejected",
                        lane.stats.compile_rejected.load(Ordering::Relaxed) as f64,
                    )
                    .set("busy_ms", busy_us / 1000.0)
                    .set("utilization", (busy_us / uptime_us).min(1.0));
                o
            })
            .collect();
        Json::Arr(rows)
    }

    /// Join every lane thread, then stop and join the deadline
    /// supervisor (call after the queue has shut down).
    pub fn join(&self) {
        for handle in self.handles.lock().unwrap().drain(..) {
            handle.join().ok();
        }
        self.sup_stop.store(true, Ordering::Relaxed);
        if let Some(handle) = self.supervisor.lock().unwrap().take() {
            handle.join().ok();
        }
    }
}

impl LaneCtx {
    fn journal_append(&self, rec: &JournalRecord) {
        if let Some(jnl) = &self.journal {
            if let Err(e) = jnl.append(rec) {
                crate::log_warn!("journal append failed: {e}");
            }
        }
    }

    fn trace_stage(&self, stage: &str, job_id: u64) {
        if let Some(t) = &self.trace {
            t.stage(stage, job_id, Some(self.device.name));
        }
    }

    /// Publish a breaker transition: health mirror for peers, the
    /// `kf_lane_state` gauge and a `lane_<state>` trace mirror. No-op
    /// when the state did not change.
    fn publish_state(&self, state: LaneState) {
        if self.health.get() == state {
            return;
        }
        self.health.set(state);
        self.obs
            .gauge(&labeled("kf_lane_state", "device", self.device.name))
            .set(state.as_u8() as f64);
        if let Some(t) = &self.trace {
            t.mirror_lane(state.name(), self.device.name);
        }
        if state == LaneState::Open {
            crate::log_warn!(
                "lane {} circuit breaker opened (cooldown {:?})",
                self.device.name,
                self.guard.lane_cooldown
            );
        }
    }
}

/// One lane's supervised worker loop, driven by the breaker state:
/// closed lanes block on the queue, open lanes shed queued units and
/// wait out the cooldown, half-open lanes probe with single units.
fn lane_main(ctx: LaneCtx) {
    let mut breaker = CircuitBreaker::new(ctx.guard.trip_threshold, ctx.guard.lane_cooldown);
    loop {
        match breaker.state() {
            LaneState::Closed => match ctx.queue.pop_for(ctx.device.name) {
                Some(unit) => process_unit(&ctx, &mut breaker, unit),
                None => return,
            },
            LaneState::HalfOpen => match ctx.queue.try_pop_for(ctx.device.name) {
                Some(unit) => process_unit(&ctx, &mut breaker, unit),
                None => {
                    if ctx.queue.is_shutdown() && !ctx.queue.has_units_for(ctx.device.name) {
                        return;
                    }
                    thread::sleep(HALF_OPEN_POLL);
                }
            },
            LaneState::Open => {
                if ctx.queue.is_shutdown() {
                    // Drain mode: a shutting-down fleet must not strand
                    // mid-retry units behind a cooldown.
                    breaker.force_close();
                    ctx.publish_state(LaneState::Closed);
                    continue;
                }
                shed_queued(&ctx);
                if breaker.try_half_open(Instant::now()) {
                    ctx.publish_state(LaneState::HalfOpen);
                    continue;
                }
                thread::sleep(OPEN_POLL);
            }
        }
    }
}

/// Dispatch → run → commit/retry/quarantine/fail for one popped unit.
fn process_unit(ctx: &LaneCtx, breaker: &mut CircuitBreaker, unit: QueuedUnit) {
    let device = ctx.device.name;
    ctx.journal_append(&JournalRecord::Dispatch {
        job_id: unit.job_id,
        device: device.to_string(),
    });
    failpoint::hit("dispatch.after_journal");
    ctx.trace_stage(stage::DISPATCHED, unit.job_id);
    // Queue-wait latency: submit → this lane picking the unit up. Only
    // the first attempt counts — retries would fold backoff waits in.
    if unit.attempt == 0 {
        if let Some(job) = ctx.jobs.get(unit.job_id) {
            ctx.obs.observe_ms(
                "kf_stage_queued_ms",
                job.submitted_at.elapsed().as_secs_f64() * 1000.0,
            );
        }
    }
    ctx.jobs.set_unit_state(unit.job_id, device, JobState::Generating);
    let t0 = Instant::now();
    let outcome = run_attempt(ctx, &unit);
    ctx.stats
        .busy_us
        .fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
    ctx.obs
        .observe_ms("kf_stage_run_ms", t0.elapsed().as_secs_f64() * 1000.0);
    match outcome {
        Ok(result) => {
            breaker.on_success();
            ctx.publish_state(LaneState::Closed);
            commit_unit(ctx, &unit, result);
        }
        Err(err) if err.transient => {
            if breaker.on_failure(Instant::now()) {
                ctx.obs.counter("kf_lane_trips_total").inc();
                ctx.obs
                    .counter(&labeled("kf_lane_trips_total", "device", device))
                    .inc();
            }
            ctx.publish_state(breaker.state());
            retry_or_quarantine(ctx, unit, err.message);
        }
        Err(err) => {
            // Deterministic job error (unknown task, bad custom config):
            // the lane is healthy, the job is not — neither trips nor
            // resets the breaker, and a retry would repeat the verdict.
            fail_unit(ctx, &unit, err.message);
        }
    }
}

/// Register the attempt with the deadline table (when configured) and
/// run it, converting panics into transient failures — a panicking unit
/// must fail *that job*, not kill the lane.
fn run_attempt(ctx: &LaneCtx, unit: &QueuedUnit) -> Result<DeviceResult, UnitError> {
    let token = CancelToken::new();
    if let Some(d) = ctx.guard.unit_deadline {
        ctx.inflight
            .begin(unit.job_id, ctx.device.name, Instant::now() + d, token.clone());
    }
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        run_unit(ctx, unit, &token)
    }))
    .unwrap_or_else(|_| {
        Err(UnitError::transient(
            "unit execution panicked (lane recovered)".to_string(),
        ))
    });
    if ctx.guard.unit_deadline.is_some() {
        ctx.inflight.end(unit.job_id, ctx.device.name);
    }
    outcome
}

/// The transient error an attempt reports when its cancel token fired.
fn deadline_error(ctx: &LaneCtx) -> UnitError {
    let ms = ctx.guard.unit_deadline.map(|d| d.as_millis()).unwrap_or(0);
    UnitError::transient(format!(
        "unit deadline {ms}ms exceeded on {}",
        ctx.device.name
    ))
}

/// Consult the fault plan at one step of an attempt. `Fail` becomes a
/// transient error; `Hang` sleeps cooperatively — the attempt survives
/// a hang that ends before the deadline (a hang models a stalled
/// device; the deadline decides fatality).
fn inject(
    ctx: &LaneCtx,
    unit: &QueuedUnit,
    step: FaultStep,
    task_id: &str,
    token: &CancelToken,
) -> Result<(), UnitError> {
    let Some(plan) = &ctx.faults else {
        return Ok(());
    };
    match plan.check(ctx.device.name, step, task_id, unit.spec.seed, unit.attempt) {
        None => Ok(()),
        Some(FaultAction::Fail(msg)) => {
            ctx.obs.counter("kf_faults_injected_total").inc();
            Err(UnitError::transient(msg))
        }
        Some(FaultAction::Hang(dur)) => {
            ctx.obs.counter("kf_faults_injected_total").inc();
            if token.sleep_cooperative(dur) {
                Ok(())
            } else {
                Err(deadline_error(ctx))
            }
        }
    }
}

/// Execute one unit attempt: resolve the task, build engine + pool for
/// this lane's device (both wired to the cancel token), run the
/// evolution loop, summarize.
fn run_unit(
    ctx: &LaneCtx,
    unit: &QueuedUnit,
    token: &CancelToken,
) -> Result<DeviceResult, UnitError> {
    let device = &ctx.device;
    let task_id = match &unit.spec.task {
        TaskSource::Catalog(id) => id.clone(),
        TaskSource::Custom { .. } => "custom".to_string(),
    };
    let task = match &unit.spec.task {
        TaskSource::Catalog(id) => catalog::find_task(id)
            .ok_or_else(|| UnitError::permanent(format!("unknown task '{id}'")))?,
        TaskSource::Custom { config, source } => {
            custom::load_strings(config, source)
                .map_err(|e| UnitError::permanent(format!("custom task: {e}")))?
                .spec
        }
    };
    inject(ctx, unit, FaultStep::Compile, &task_id, token)?;
    let mut config = FoundryConfig::paper_defaults();
    config.seed = unit.spec.seed;
    config.device = device.name.to_string();
    config.language = unit.spec.language.clone();
    config.evolution.max_generations = unit.spec.iters;
    config.evolution.population = unit.spec.population;

    let mut engine = EvolutionEngine::new(config, task, ExecBackend::HwSim(device.clone()));
    engine.attach_cancel(token.flag());
    // Search-history rows are labeled with the unit's cache key, so a
    // run's per-generation curves join its persisted result row.
    if let Some(log) = &ctx.search_log {
        engine.attach_search_log(Arc::clone(log), &cache_key(&unit.spec, device.name));
    }
    // The lane's Fig. 4 cluster, seeded so every verdict matches the
    // engine's inline pipeline (see `EvalPipeline::seed`).
    let mut pool = WorkerPool::new(ClusterConfig {
        compile_workers: ctx.compile_workers,
        exec_workers: ctx.exec_workers,
        device: device.clone(),
        queue_capacity: ctx.queue_capacity,
        seed: engine.pipeline.seed(),
    });
    pool.set_cancel(token.flag());

    // Engine + Fig. 4 cluster are built: generation is set up and the
    // compile workers are live — the unit's `compiled` trace point.
    ctx.trace_stage(stage::COMPILED, unit.job_id);
    ctx.jobs
        .set_unit_state(unit.job_id, device.name, JobState::Evaluating);
    inject(ctx, unit, FaultStep::Exec, &task_id, token)?;
    let t0 = Instant::now();
    let report = engine.run_distributed(&pool);
    let wall_ms = t0.elapsed().as_secs_f64() * 1000.0;
    ctx.obs.observe_ms("kf_unit_evolution_ms", wall_ms);
    if token.is_cancelled() {
        // The deadline fired mid-run; the loop bailed early, so the
        // partial report must not be committed as a verdict.
        return Err(deadline_error(ctx));
    }

    ctx.stats
        .executed
        .fetch_add(pool.metrics.executed.load(Ordering::Relaxed), Ordering::Relaxed);
    ctx.stats.compile_rejected.fetch_add(
        pool.metrics.compile_rejected.load(Ordering::Relaxed),
        Ordering::Relaxed,
    );
    Ok(DeviceResult::from_report(device.name, &report, wall_ms))
}

/// Slot-commit a finished unit: journal Commit marker *before* the
/// cache row, so a crash between the two is repaired idempotently at
/// replay (the marker's result is re-inserted only if its row is
/// missing) and no interleaving of crash points can publish a duplicate
/// or torn verdict row.
fn commit_unit(ctx: &LaneCtx, unit: &QueuedUnit, result: DeviceResult) {
    let device = ctx.device.name;
    ctx.trace_stage(stage::EXECUTED, unit.job_id);
    if ctx.journal.is_some() {
        failpoint::hit("commit.before_marker");
        ctx.journal_append(&JournalRecord::Commit {
            job_id: unit.job_id,
            device: device.to_string(),
            result: result.clone(),
        });
        failpoint::hit("commit.after_marker");
    }
    ctx.cache.insert(&cache_key(&unit.spec, device), result.clone());
    failpoint::hit("commit.after_row");
    ctx.trace_stage(stage::COMMITTED, unit.job_id);
    ctx.obs.counter("kf_units_committed_total").inc();
    ctx.obs
        .counter(&labeled("kf_lane_units_done_total", "device", device))
        .inc();
    ctx.stats.units_done.fetch_add(1, Ordering::Relaxed);
    ctx.jobs.complete_unit(unit.job_id, device, result);
}

/// Terminally fail a unit (journal Fail, trace, counters, job table).
fn fail_unit(ctx: &LaneCtx, unit: &QueuedUnit, error: String) {
    let device = ctx.device.name;
    ctx.trace_stage(stage::FAILED, unit.job_id);
    ctx.obs.counter("kf_units_failed_total").inc();
    ctx.obs
        .counter(&labeled("kf_lane_units_failed_total", "device", device))
        .inc();
    ctx.journal_append(&JournalRecord::Fail {
        job_id: unit.job_id,
        device: device.to_string(),
        error: error.clone(),
    });
    ctx.stats.units_failed.fetch_add(1, Ordering::Relaxed);
    ctx.jobs.fail_unit(unit.job_id, device, error);
}

/// After a transient failure: re-enqueue with backoff while the retry
/// budget lasts, else quarantine the unit as a deterministic failure
/// verdict. The journal record in each path is durable *before* the
/// in-memory effect (`retry.after_journal` / `quarantine.after_journal`
/// crash points), mirroring the slot-commit protocol.
fn retry_or_quarantine(ctx: &LaneCtx, unit: QueuedUnit, error: String) {
    let device = ctx.device.name;
    let attempts = unit.attempt + 1;
    if attempts > ctx.guard.max_retries {
        ctx.journal_append(&JournalRecord::Quarantine {
            job_id: unit.job_id,
            device: device.to_string(),
            error: error.clone(),
            attempts,
        });
        failpoint::hit("quarantine.after_journal");
        ctx.trace_stage(stage::QUARANTINED, unit.job_id);
        ctx.obs.counter("kf_units_quarantined_total").inc();
        ctx.obs
            .counter(&labeled("kf_lane_quarantined_total", "device", device))
            .inc();
        ctx.obs.counter("kf_units_failed_total").inc();
        ctx.obs
            .counter(&labeled("kf_lane_units_failed_total", "device", device))
            .inc();
        ctx.stats.quarantined.fetch_add(1, Ordering::Relaxed);
        ctx.stats.units_failed.fetch_add(1, Ordering::Relaxed);
        crate::log_warn!(
            "unit quarantined: job {} after {attempts} attempts on {device}: {error}",
            unit.job_id
        );
        ctx.jobs.fail_unit(
            unit.job_id,
            device,
            format!("quarantined after {attempts} attempts on {device}: {error}"),
        );
        return;
    }
    ctx.journal_append(&JournalRecord::Retry {
        job_id: unit.job_id,
        device: device.to_string(),
        attempt: attempts,
        error: error.clone(),
    });
    failpoint::hit("retry.after_journal");
    ctx.trace_stage(stage::RETRIED, unit.job_id);
    ctx.obs.counter("kf_retry_total").inc();
    ctx.obs
        .counter(&labeled("kf_lane_retries_total", "device", device))
        .inc();
    ctx.stats.retries.fetch_add(1, Ordering::Relaxed);
    let delay = backoff_delay(ctx.guard.retry_backoff, attempts, unit.job_id, device);
    ctx.obs
        .observe_ms("kf_retry_backoff_ms", delay.as_secs_f64() * 1000.0);
    crate::log_warn!(
        "unit retry: job {} on {device}, attempt {attempts} of {} in {delay:?}: {error}",
        unit.job_id,
        ctx.guard.max_retries + 1
    );
    ctx.jobs.set_unit_state(unit.job_id, device, JobState::Queued);
    ctx.trace_stage(stage::QUEUED, unit.job_id);
    let mut retried = unit;
    retried.attempt = attempts;
    retried.not_before = Some(Instant::now() + delay);
    ctx.queue.requeue(retried);
}

/// An open lane sheds its *fresh* queued units (attempt 0): routed
/// units move to the first healthy peer in fleet order (journal
/// `reroute`); fan-out units degrade — their job reports `partial` for
/// the surviving subset. Mid-retry units stay pinned so the quarantine
/// verdict stays deterministic (the half-open probe runs them).
fn shed_queued(ctx: &LaneCtx) {
    let device = ctx.device.name;
    for unit in ctx.queue.drain_fresh_for(device) {
        let fan_out = matches!(unit.spec.device, DeviceTarget::FanOut);
        let target = if fan_out {
            // A fan-out unit exists to measure *this* device — there is
            // no substitute lane; degrade instead.
            None
        } else {
            ctx.peers
                .iter()
                .find(|(name, health)| name.as_str() != device && health.accepts_reroutes())
                .map(|(name, _)| name.clone())
        };
        let rerouted = match &target {
            Some(to) => {
                ctx.journal_append(&JournalRecord::Reroute {
                    job_id: unit.job_id,
                    from: device.to_string(),
                    to: to.clone(),
                });
                ctx.jobs.reroute_unit(unit.job_id, device, to)
            }
            None => false,
        };
        if rerouted {
            let to = target.expect("rerouted implies a target");
            ctx.trace_stage(stage::REROUTED, unit.job_id);
            ctx.obs.counter("kf_units_rerouted_total").inc();
            ctx.obs
                .counter(&labeled("kf_lane_rerouted_total", "device", device))
                .inc();
            ctx.stats.rerouted_away.fetch_add(1, Ordering::Relaxed);
            crate::log_warn!(
                "lane {device} open: rerouting job {} unit to {to}",
                unit.job_id
            );
            let mut moved = unit;
            moved.device = to;
            ctx.queue.requeue(moved);
        } else {
            let why = if fan_out {
                "fan-out degraded to surviving devices"
            } else {
                "no healthy lane to take the unit"
            };
            let msg = format!("lane {device} open (circuit breaker): {why}");
            fail_unit(ctx, &unit, msg);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::job::{Job, JobSpec, JobUnit};

    type Fixture = (ServiceConfig, Arc<JobQueue>, Arc<JobTable>, Arc<ResultCache>);

    fn fleet_fixture(devices: Vec<DeviceProfile>) -> Fixture {
        let cfg = ServiceConfig {
            devices,
            compile_workers: 1,
            exec_workers: 2,
            queue_capacity: 8,
            ..ServiceConfig::default()
        };
        (
            cfg,
            Arc::new(JobQueue::new(8)),
            Arc::new(JobTable::new()),
            Arc::new(ResultCache::in_memory()),
        )
    }

    fn insert_routed_job(jobs: &JobTable, queue: &JobQueue, id: u64, spec: &JobSpec, device: &str) {
        jobs.insert(Job {
            id,
            spec: spec.clone(),
            submitted_at: Instant::now(),
            units: vec![JobUnit {
                device: device.to_string(),
                state: JobState::Queued,
                result: None,
                error: None,
            }],
        });
        queue.push(vec![QueuedUnit::fresh(id, device, spec.clone())]).unwrap();
    }

    fn wait_finished(jobs: &JobTable, id: u64, secs: u64) {
        let deadline = Instant::now() + Duration::from_secs(secs);
        while !jobs.get(id).unwrap().state().finished() {
            assert!(Instant::now() < deadline, "job {id} did not finish in time");
            thread::sleep(Duration::from_millis(5));
        }
    }

    /// A lane executes a queued unit end-to-end: job table completion,
    /// cache population and stats accounting.
    #[test]
    fn lane_runs_a_unit_to_completion() {
        let (cfg, queue, jobs, cache) = fleet_fixture(vec![DeviceProfile::b580()]);
        let obs = Arc::new(Registry::new());
        let fleet = Fleet::spawn(&cfg, &queue, &jobs, &cache, None, &obs, None, None);
        assert!(fleet.has_device("b580"));
        assert!(!fleet.has_device("lnl"));

        let mut spec = JobSpec::catalog("20_LeakyReLU", "b580");
        spec.iters = 2;
        spec.population = 2;
        insert_routed_job(&jobs, &queue, 1, &spec, "b580");
        wait_finished(&jobs, 1, 30);
        let job = jobs.get(1).unwrap();
        assert_eq!(job.state(), JobState::Done);
        let result = job.units[0].result.as_ref().expect("unit result");
        assert_eq!(result.device, "b580");
        assert_eq!(result.evaluations, 4, "2 gens x pop 2");
        assert!(!result.cached);
        assert_eq!(cache.len(), 1, "completed unit populated the cache");
        assert_eq!(fleet.lanes[0].stats.units_done.load(Ordering::Relaxed), 1);
        assert!(fleet.lanes[0].stats.busy_us.load(Ordering::Relaxed) > 0);
        assert_eq!(fleet.open_lanes(), 0);
        assert_eq!(obs.counter_value("kf_units_committed_total"), 1);
        assert_eq!(
            obs.counter_value(&labeled("kf_lane_units_done_total", "device", "b580")),
            1
        );
        assert_eq!(obs.histogram("kf_stage_run_ms").snapshot().count(), 1);

        queue.shutdown();
        fleet.join();
    }

    /// A deterministic failure (task unknown at execution) marks the
    /// unit — and hence the job — failed immediately: no retries, no
    /// breaker trip, and the lane survives.
    #[test]
    fn lane_survives_a_failing_unit() {
        let (cfg, queue, jobs, cache) = fleet_fixture(vec![DeviceProfile::b580()]);
        let obs = Arc::new(Registry::new());
        let fleet = Fleet::spawn(&cfg, &queue, &jobs, &cache, None, &obs, None, None);
        let spec = JobSpec::catalog("no_such_task", "b580");
        insert_routed_job(&jobs, &queue, 1, &spec, "b580");
        wait_finished(&jobs, 1, 10);
        let job = jobs.get(1).unwrap();
        assert_eq!(job.state(), JobState::Failed);
        assert!(job.units[0].error.as_ref().unwrap().contains("unknown task"));
        assert_eq!(fleet.lanes[0].stats.units_failed.load(Ordering::Relaxed), 1);
        assert_eq!(fleet.lanes[0].stats.retries.load(Ordering::Relaxed), 0);
        assert_eq!(obs.counter_value("kf_retry_total"), 0);
        assert_eq!(fleet.open_lanes(), 0);
        queue.shutdown();
        fleet.join();
    }

    /// Injected transient failures retry with backoff and the unit
    /// still commits exactly one verdict.
    #[test]
    fn transient_failures_retry_then_commit_exactly_once() {
        let (mut cfg, queue, jobs, cache) = fleet_fixture(vec![DeviceProfile::b580()]);
        cfg.guard.retry_backoff = Duration::from_millis(10);
        cfg.fault_plan =
            Some(FaultPlan::parse("seed 1\nb580 compile fail times=2").expect("plan"));
        let obs = Arc::new(Registry::new());
        let fleet = Fleet::spawn(&cfg, &queue, &jobs, &cache, None, &obs, None, None);
        let mut spec = JobSpec::catalog("20_LeakyReLU", "b580");
        spec.iters = 2;
        spec.population = 2;
        insert_routed_job(&jobs, &queue, 1, &spec, "b580");
        wait_finished(&jobs, 1, 30);
        let job = jobs.get(1).unwrap();
        assert_eq!(job.state(), JobState::Done, "{:?}", job.units[0].error);
        assert_eq!(cache.len(), 1, "exactly one verdict row");
        assert_eq!(fleet.lanes[0].stats.retries.load(Ordering::Relaxed), 2);
        assert_eq!(fleet.lanes[0].stats.units_done.load(Ordering::Relaxed), 1);
        assert_eq!(obs.counter_value("kf_retry_total"), 2);
        assert_eq!(obs.counter_value("kf_faults_injected_total"), 2);
        assert!(obs.histogram("kf_retry_backoff_ms").snapshot().count() == 2);
        queue.shutdown();
        fleet.join();
    }

    /// A permanently failing unit exhausts its retry budget and is
    /// quarantined with a deterministic failure verdict.
    #[test]
    fn poison_unit_is_quarantined_after_its_budget() {
        let (mut cfg, queue, jobs, cache) = fleet_fixture(vec![DeviceProfile::b580()]);
        cfg.guard.max_retries = 1;
        cfg.guard.retry_backoff = Duration::from_millis(10);
        // High trip threshold: this test isolates the retry budget from
        // the breaker.
        cfg.guard.trip_threshold = 10;
        cfg.fault_plan = Some(FaultPlan::parse("b580 * dead").expect("plan"));
        let obs = Arc::new(Registry::new());
        let fleet = Fleet::spawn(&cfg, &queue, &jobs, &cache, None, &obs, None, None);
        let mut spec = JobSpec::catalog("20_LeakyReLU", "b580");
        spec.iters = 2;
        spec.population = 2;
        insert_routed_job(&jobs, &queue, 1, &spec, "b580");
        wait_finished(&jobs, 1, 30);
        let job = jobs.get(1).unwrap();
        assert_eq!(job.state(), JobState::Failed);
        let error = job.units[0].error.as_ref().unwrap();
        assert!(error.contains("quarantined after 2 attempts"), "{error}");
        assert_eq!(cache.len(), 0, "no verdict row for a quarantined unit");
        assert_eq!(fleet.lanes[0].stats.quarantined.load(Ordering::Relaxed), 1);
        assert_eq!(fleet.lanes[0].stats.retries.load(Ordering::Relaxed), 1);
        assert_eq!(obs.counter_value("kf_units_quarantined_total"), 1);
        queue.shutdown();
        fleet.join();
    }

    /// A tripped lane quarantines itself: fresh routed units reroute to
    /// a healthy peer and fan-out units degrade to the surviving subset
    /// (the job reports `partial`).
    #[test]
    fn open_lane_reroutes_routed_units_and_degrades_fan_out() {
        let (mut cfg, queue, jobs, cache) =
            fleet_fixture(vec![DeviceProfile::b580(), DeviceProfile::lnl()]);
        cfg.guard.max_retries = 0;
        cfg.guard.trip_threshold = 1;
        cfg.guard.retry_backoff = Duration::from_millis(10);
        // Long cooldown: b580 stays open for the whole test.
        cfg.guard.lane_cooldown = Duration::from_secs(60);
        cfg.fault_plan = Some(FaultPlan::parse("b580 * dead").expect("plan"));
        let obs = Arc::new(Registry::new());
        let fleet = Fleet::spawn(&cfg, &queue, &jobs, &cache, None, &obs, None, None);

        let mut spec = JobSpec::catalog("20_LeakyReLU", "b580");
        spec.iters = 2;
        spec.population = 2;
        // Job 1 trips the breaker (max_retries 0 → quarantined at once).
        insert_routed_job(&jobs, &queue, 1, &spec, "b580");
        wait_finished(&jobs, 1, 30);
        assert_eq!(jobs.get(1).unwrap().state(), JobState::Failed);
        let deadline = Instant::now() + Duration::from_secs(10);
        while fleet.open_lanes() != 1 {
            assert!(Instant::now() < deadline, "lane never opened");
            thread::sleep(Duration::from_millis(5));
        }

        // Job 2, routed to the open lane, is rerouted to lnl and done.
        insert_routed_job(&jobs, &queue, 2, &spec, "b580");
        wait_finished(&jobs, 2, 30);
        let job2 = jobs.get(2).unwrap();
        assert_eq!(job2.state(), JobState::Done, "{:?}", job2.units[0].error);
        assert_eq!(job2.units[0].device, "lnl");
        assert_eq!(job2.units[0].result.as_ref().unwrap().device, "lnl");

        // Job 3, fan-out: the b580 unit degrades, the lnl unit runs →
        // the job lands on `partial` naming the dead lane.
        let mut fan_spec = spec.clone();
        fan_spec.device = DeviceTarget::FanOut;
        jobs.insert(Job {
            id: 3,
            spec: fan_spec.clone(),
            submitted_at: Instant::now(),
            units: ["b580", "lnl"]
                .iter()
                .map(|d| JobUnit {
                    device: d.to_string(),
                    state: JobState::Queued,
                    result: None,
                    error: None,
                })
                .collect(),
        });
        queue
            .push(vec![
                QueuedUnit::fresh(3, "b580", fan_spec.clone()),
                QueuedUnit::fresh(3, "lnl", fan_spec.clone()),
            ])
            .unwrap();
        wait_finished(&jobs, 3, 30);
        let job3 = jobs.get(3).unwrap();
        assert_eq!(job3.state(), JobState::Partial);
        let b580_unit = job3.units.iter().find(|u| u.device == "b580").unwrap();
        assert!(
            b580_unit.error.as_ref().unwrap().contains("fan-out degraded"),
            "{:?}",
            b580_unit.error
        );
        assert!(job3.units.iter().any(|u| u.result.is_some()));
        assert!(fleet.lanes[0].stats.rerouted_away.load(Ordering::Relaxed) >= 1);
        assert!(obs.counter_value("kf_units_rerouted_total") >= 1);
        queue.shutdown();
        fleet.join();
    }

    /// A hung attempt is cancelled by the deadline supervisor and the
    /// retry succeeds — hangs cost a deadline, not the fleet.
    #[test]
    fn hung_unit_hits_its_deadline_and_retries_clean() {
        let (mut cfg, queue, jobs, cache) = fleet_fixture(vec![DeviceProfile::b580()]);
        cfg.guard.unit_deadline = Some(Duration::from_millis(250));
        cfg.guard.retry_backoff = Duration::from_millis(10);
        cfg.guard.trip_threshold = 10;
        cfg.fault_plan = Some(FaultPlan::parse("b580 exec hang 60s times=1").expect("plan"));
        let obs = Arc::new(Registry::new());
        let fleet = Fleet::spawn(&cfg, &queue, &jobs, &cache, None, &obs, None, None);
        let mut spec = JobSpec::catalog("20_LeakyReLU", "b580");
        spec.iters = 2;
        spec.population = 2;
        insert_routed_job(&jobs, &queue, 1, &spec, "b580");
        wait_finished(&jobs, 1, 30);
        let job = jobs.get(1).unwrap();
        assert_eq!(job.state(), JobState::Done, "{:?}", job.units[0].error);
        assert!(obs.counter_value("kf_deadline_exceeded_total") >= 1);
        assert_eq!(fleet.lanes[0].stats.retries.load(Ordering::Relaxed), 1);
        queue.shutdown();
        fleet.join();
    }
}
