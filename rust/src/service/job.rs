//! Job model for the kernel-generation service: specs, priorities,
//! lifecycle states, per-device results and the shared job table.
//!
//! A submitted job is split into one *unit* per target device (one unit
//! for a routed job, one per fleet lane for a fan-out job). Units move
//! through the §3.6 lifecycle `queued → generating → evaluating →
//! done/failed` independently; the job-level state is the aggregate over
//! its units.

use crate::coordinator::RunReport;
use crate::util::json::Json;
use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

/// Default generations per service job (a serving budget, deliberately
/// smaller than the paper's 40-generation benchmark budget).
pub const DEFAULT_ITERS: usize = 8;
/// Default population per generation for service jobs.
pub const DEFAULT_POPULATION: usize = 4;
/// Default RNG seed for service jobs (the repo-wide demo seed).
pub const DEFAULT_SEED: u64 = 20260710;

/// Scheduling priority of a job. Higher priorities are popped first;
/// within a priority class units are served in submission order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum JobPriority {
    /// Background work (cache warming, speculative fan-outs).
    Low,
    /// The default.
    Normal,
    /// Interactive requests.
    High,
}

impl JobPriority {
    /// Wire name of the priority.
    pub fn name(&self) -> &'static str {
        match self {
            JobPriority::Low => "low",
            JobPriority::Normal => "normal",
            JobPriority::High => "high",
        }
    }

    /// Parse a wire name (`low` | `normal` | `high`).
    pub fn parse(s: &str) -> Option<JobPriority> {
        match s {
            "low" => Some(JobPriority::Low),
            "normal" => Some(JobPriority::Normal),
            "high" => Some(JobPriority::High),
            _ => None,
        }
    }
}

/// Lifecycle state of a job unit (and, aggregated, of a job).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Waiting in the fleet queue.
    Queued,
    /// Picked up by a lane; engine + pool are being constructed and the
    /// code model is producing the first candidates.
    Generating,
    /// The evolution loop is running candidates through the lane's
    /// worker pool.
    Evaluating,
    /// Finished with a result (which may or may not contain a correct
    /// kernel — see [`DeviceResult::correct`]).
    Done,
    /// Aborted with an error (unknown task at run time, etc.).
    Failed,
    /// Removed from the queue before any lane picked it up.
    Cancelled,
    /// Aggregate-only state: some units finished, others failed (a
    /// fan-out job degraded to the surviving lanes). Individual units
    /// are never `Partial`.
    Partial,
}

impl JobState {
    /// Wire name of the state.
    pub fn name(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Generating => "generating",
            JobState::Evaluating => "evaluating",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
            JobState::Partial => "partial",
        }
    }

    /// Whether the state is terminal (done / failed / cancelled /
    /// partial).
    pub fn finished(&self) -> bool {
        matches!(
            self,
            JobState::Done | JobState::Failed | JobState::Cancelled | JobState::Partial
        )
    }
}

/// What kernel-generation problem a job solves: a catalog task id, or an
/// inline custom task in the App. C marker format (the paper's flexible
/// user input layer, shipped over the wire instead of read from disk).
#[derive(Debug, Clone, PartialEq)]
pub enum TaskSource {
    /// A task id resolvable via [`crate::tasks::catalog::find_task`].
    Catalog(String),
    /// An inline custom task bundle parsed by
    /// [`crate::tasks::custom::load_strings`].
    Custom {
        /// The `task.yaml` config text.
        config: String,
        /// The marker-annotated source text (`### KF:REFERENCE ###` …).
        source: String,
    },
}

/// Which fleet device(s) a job runs on.
#[derive(Debug, Clone, PartialEq)]
pub enum DeviceTarget {
    /// Route to the named device's lane.
    Named(String),
    /// Fan out: one unit per fleet device, for cross-hardware comparison.
    FanOut,
}

/// A complete job specification — everything the `submit` verb carries.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    /// The problem to solve.
    pub task: TaskSource,
    /// Target device(s).
    pub device: DeviceTarget,
    /// Kernel language (`sycl` | `cuda`).
    pub language: String,
    /// Base RNG seed (part of the cache key).
    pub seed: u64,
    /// Generations to run.
    pub iters: usize,
    /// Candidates per generation.
    pub population: usize,
    /// Scheduling priority.
    pub priority: JobPriority,
}

impl JobSpec {
    /// A spec for a catalog task on one device with service defaults.
    pub fn catalog(task_id: &str, device: &str) -> JobSpec {
        JobSpec {
            task: TaskSource::Catalog(task_id.to_string()),
            device: DeviceTarget::Named(device.to_string()),
            language: "sycl".to_string(),
            seed: DEFAULT_SEED,
            iters: DEFAULT_ITERS,
            population: DEFAULT_POPULATION,
            priority: JobPriority::Normal,
        }
    }

    /// Serialize to the wire object form (the body of a `submit`
    /// request, minus the `verb` key the caller adds).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        match &self.task {
            TaskSource::Catalog(id) => {
                o.set("task", id.as_str());
            }
            TaskSource::Custom { config, source } => {
                let mut c = Json::obj();
                c.set("config", config.as_str()).set("source", source.as_str());
                o.set("custom", c);
            }
        }
        match &self.device {
            DeviceTarget::Named(d) => {
                o.set("device", d.as_str());
            }
            DeviceTarget::FanOut => {
                o.set("device", "all");
            }
        }
        o.set("language", self.language.as_str())
            .set("seed", self.seed as f64)
            .set("iters", self.iters)
            .set("population", self.population)
            .set("priority", self.priority.name());
        o
    }

    /// Parse from the wire object form; unknown keys are ignored, absent
    /// optional keys take the service defaults.
    pub fn from_json(v: &Json) -> Result<JobSpec, String> {
        let task = if let Some(id) = v.get("task").and_then(|t| t.as_str()) {
            TaskSource::Catalog(id.to_string())
        } else if let Some(c) = v.get("custom") {
            let config = c
                .get("config")
                .and_then(|x| x.as_str())
                .ok_or("custom task needs a 'config' string")?;
            let source = c
                .get("source")
                .and_then(|x| x.as_str())
                .ok_or("custom task needs a 'source' string")?;
            TaskSource::Custom {
                config: config.to_string(),
                source: source.to_string(),
            }
        } else {
            return Err(
                "submit needs either 'task' (catalog id) or 'custom' {config, source}".into(),
            );
        };
        let device = match v.get("device").and_then(|d| d.as_str()) {
            None => DeviceTarget::Named("b580".to_string()),
            Some("all") => DeviceTarget::FanOut,
            Some(d) => DeviceTarget::Named(d.to_string()),
        };
        let priority = match v.get("priority").and_then(|p| p.as_str()) {
            None => JobPriority::Normal,
            Some(p) => JobPriority::parse(p)
                .ok_or_else(|| format!("unknown priority '{p}' (low | normal | high)"))?,
        };
        Ok(JobSpec {
            task,
            device,
            language: v
                .get("language")
                .and_then(|l| l.as_str())
                .unwrap_or("sycl")
                .to_string(),
            seed: v
                .get("seed")
                .and_then(|s| s.as_i64())
                .map(|s| s as u64)
                .unwrap_or(DEFAULT_SEED),
            iters: v.get("iters").and_then(|i| i.as_usize()).unwrap_or(DEFAULT_ITERS),
            population: v
                .get("population")
                .and_then(|p| p.as_usize())
                .unwrap_or(DEFAULT_POPULATION),
            priority,
        })
    }
}

/// The outcome of one job unit: the best kernel one device's evolution
/// run produced (or the evidence that none was found).
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceResult {
    /// Device the unit ran on.
    pub device: String,
    /// Task the kernel implements.
    pub task_id: String,
    /// Whether a numerically-correct kernel was found.
    pub correct: bool,
    /// §3.2 fitness of the best kernel (0 if none).
    pub fitness: f64,
    /// Speedup of the best kernel over the eager baseline.
    pub speedup: f64,
    /// Measured best-kernel time, ms.
    pub time_ms: f64,
    /// Eager baseline time, ms.
    pub baseline_ms: f64,
    /// Behavioral coordinates of the best kernel.
    pub coords: [usize; 3],
    /// Genome id of the best kernel within its run.
    pub genome_id: u64,
    /// Ensemble model that produced the best kernel.
    pub produced_by: String,
    /// Rendered best-kernel source (empty when restored from a persisted
    /// cache row, which stores metrics only).
    pub source: String,
    /// Total candidates evaluated by the run.
    pub evaluations: usize,
    /// Compile-rejected candidates.
    pub compile_errors: usize,
    /// Incorrect candidates.
    pub incorrect: usize,
    /// Whether this result was served from the cache.
    pub cached: bool,
    /// Wall-clock time of the evolution run, ms (0 for cache hits).
    pub wall_ms: f64,
}

impl DeviceResult {
    /// Build from a finished evolution run.
    pub fn from_report(device: &str, report: &RunReport, wall_ms: f64) -> DeviceResult {
        let best = report.best.as_ref();
        DeviceResult {
            device: device.to_string(),
            task_id: report.task_id.clone(),
            correct: best.is_some(),
            fitness: best.map(|b| b.fitness).unwrap_or(0.0),
            speedup: report.best_speedup(),
            time_ms: best.map(|b| b.time_ms).unwrap_or(0.0),
            baseline_ms: best.map(|b| b.baseline_ms).unwrap_or(0.0),
            coords: best.map(|b| b.coords).unwrap_or([0, 0, 0]),
            genome_id: best.map(|b| b.genome.id).unwrap_or(0),
            produced_by: best.map(|b| b.genome.produced_by.clone()).unwrap_or_default(),
            source: best.map(|b| b.source.clone()).unwrap_or_default(),
            evaluations: report.evaluations,
            compile_errors: report.compile_errors,
            incorrect: report.incorrect,
            cached: false,
            wall_ms,
        }
    }

    /// Serialize to the wire object form. `with_source` controls whether
    /// the (potentially large) kernel source is included.
    ///
    /// Non-finite metrics are clamped like [`crate::dist::DbRow`]'s: the
    /// same objects land in the job journal, where an unparseable value
    /// would corrupt the recovery log.
    pub fn to_json(&self, with_source: bool) -> Json {
        fn finite(v: f64) -> f64 {
            if v.is_finite() {
                v
            } else if v.is_nan() {
                0.0
            } else if v > 0.0 {
                f64::MAX
            } else {
                f64::MIN
            }
        }
        let mut o = Json::obj();
        o.set("device", self.device.as_str())
            .set("task_id", self.task_id.as_str())
            .set("correct", self.correct)
            .set("fitness", finite(self.fitness))
            .set("speedup", finite(self.speedup))
            .set("time_ms", finite(self.time_ms))
            .set("baseline_ms", finite(self.baseline_ms))
            .set("coords", self.coords.to_vec())
            .set("genome_id", self.genome_id.to_string())
            .set("produced_by", self.produced_by.as_str())
            .set("evaluations", self.evaluations)
            .set("compile_errors", self.compile_errors)
            .set("incorrect", self.incorrect)
            .set("cached", self.cached)
            .set("wall_ms", finite(self.wall_ms));
        if with_source {
            o.set("source", self.source.as_str());
        }
        o
    }

    /// Parse back from the wire object form (journal replay reads the
    /// `commit` records written via `to_json(false)`). An absent
    /// `source` restores as empty — like a persisted cache row, a
    /// replayed result carries metrics only.
    pub fn from_json(v: &Json) -> Option<DeviceResult> {
        let coords_arr = v.get("coords")?.as_arr()?;
        if coords_arr.len() != 3 {
            return None;
        }
        Some(DeviceResult {
            device: v.get("device")?.as_str()?.to_string(),
            task_id: v.get("task_id")?.as_str()?.to_string(),
            correct: v.get("correct")?.as_bool()?,
            fitness: v.get("fitness")?.as_f64()?,
            speedup: v.get("speedup")?.as_f64()?,
            time_ms: v.get("time_ms")?.as_f64()?,
            baseline_ms: v.get("baseline_ms")?.as_f64()?,
            coords: [
                coords_arr[0].as_usize()?,
                coords_arr[1].as_usize()?,
                coords_arr[2].as_usize()?,
            ],
            genome_id: v.get("genome_id")?.as_str()?.parse().ok()?,
            produced_by: v.get("produced_by")?.as_str()?.to_string(),
            source: v
                .get("source")
                .and_then(|s| s.as_str())
                .unwrap_or("")
                .to_string(),
            evaluations: v.get("evaluations")?.as_usize()?,
            compile_errors: v.get("compile_errors")?.as_usize()?,
            incorrect: v.get("incorrect")?.as_usize()?,
            cached: v.get("cached")?.as_bool()?,
            wall_ms: v.get("wall_ms")?.as_f64()?,
        })
    }
}

/// One (job × device) execution unit.
#[derive(Debug, Clone)]
pub struct JobUnit {
    /// Device name this unit is routed to.
    pub device: String,
    /// Current lifecycle state.
    pub state: JobState,
    /// Result once the unit is done (set immediately for cache hits).
    pub result: Option<DeviceResult>,
    /// Error message if the unit failed.
    pub error: Option<String>,
}

/// A submitted job: spec + per-device units.
#[derive(Debug, Clone)]
pub struct Job {
    /// Service-assigned job id (monotonic, starting at 1).
    pub id: u64,
    /// The submitted spec.
    pub spec: JobSpec,
    /// When the job was accepted.
    pub submitted_at: Instant,
    /// One unit per target device.
    pub units: Vec<JobUnit>,
}

impl Job {
    /// Aggregate state over the units: active beats queued beats
    /// terminal; among terminal states, failed-with-done is `partial`
    /// (a degraded fan-out still delivered results), all-failed beats
    /// cancelled beats done.
    pub fn state(&self) -> JobState {
        let any = |s: JobState| self.units.iter().any(|u| u.state == s);
        if any(JobState::Evaluating) {
            JobState::Evaluating
        } else if any(JobState::Generating) {
            JobState::Generating
        } else if any(JobState::Queued) {
            JobState::Queued
        } else if any(JobState::Failed) {
            if any(JobState::Done) {
                JobState::Partial
            } else {
                JobState::Failed
            }
        } else if any(JobState::Cancelled) {
            JobState::Cancelled
        } else {
            JobState::Done
        }
    }

    /// Units in a terminal state.
    pub fn units_finished(&self) -> usize {
        self.units.iter().filter(|u| u.state.finished()).count()
    }

    /// Serialize for the `status` / `result` verbs. `with_results`
    /// includes the per-device result objects (kernel source included);
    /// `status` omits them to stay small for polling loops.
    pub fn to_json(&self, with_results: bool) -> Json {
        let mut o = Json::obj();
        o.set("ok", true)
            .set("job_id", self.id as usize)
            .set("state", self.state().name())
            .set("priority", self.spec.priority.name())
            .set(
                "devices",
                self.units.iter().map(|u| u.device.clone()).collect::<Vec<_>>(),
            )
            .set("units_total", self.units.len())
            .set("units_finished", self.units_finished());
        if with_results {
            let results: Vec<Json> = self
                .units
                .iter()
                .filter_map(|u| u.result.as_ref().map(|r| r.to_json(true)))
                .collect();
            o.set("results", Json::Arr(results));
            let errors: Vec<Json> = self
                .units
                .iter()
                .filter_map(|u| {
                    u.error.as_ref().map(|e| {
                        let mut eo = Json::obj();
                        eo.set("device", u.device.as_str()).set("error", e.as_str());
                        eo
                    })
                })
                .collect();
            if !errors.is_empty() {
                o.set("errors", Json::Arr(errors));
            }
        }
        o
    }
}

/// Counts of jobs by aggregate state (the `stats` verb's `jobs` block).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct JobCounts {
    /// Jobs accepted over the service lifetime.
    pub submitted: usize,
    /// Jobs currently queued (no unit picked up yet).
    pub queued: usize,
    /// Jobs with at least one unit generating/evaluating.
    pub running: usize,
    /// Jobs fully done.
    pub done: usize,
    /// Jobs that failed.
    pub failed: usize,
    /// Jobs that were cancelled.
    pub cancelled: usize,
    /// Fan-out jobs that degraded: some units done, some failed.
    pub partial: usize,
}

impl JobCounts {
    /// Serialize to the wire object form.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("submitted", self.submitted)
            .set("queued", self.queued)
            .set("running", self.running)
            .set("done", self.done)
            .set("failed", self.failed)
            .set("cancelled", self.cancelled)
            .set("partial", self.partial);
        o
    }
}

/// The shared job table: every accepted job by id, updatable through a
/// shared reference by the API handlers and the fleet lanes.
#[derive(Debug, Default)]
pub struct JobTable {
    jobs: Mutex<HashMap<u64, Job>>,
}

impl JobTable {
    /// Create an empty table.
    pub fn new() -> JobTable {
        JobTable::default()
    }

    /// Register a job (must happen *before* its units are queued, so a
    /// lane can never observe a unit whose job is unknown).
    pub fn insert(&self, job: Job) {
        self.jobs.lock().unwrap().insert(job.id, job);
    }

    /// Remove a job (submit rollback when the queue rejects the units).
    pub fn remove(&self, id: u64) {
        self.jobs.lock().unwrap().remove(&id);
    }

    /// Snapshot of one job.
    pub fn get(&self, id: u64) -> Option<Job> {
        self.jobs.lock().unwrap().get(&id).cloned()
    }

    /// Number of jobs ever accepted.
    pub fn len(&self) -> usize {
        self.jobs.lock().unwrap().len()
    }

    /// Whether the table is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Move one unit of a job to a new lifecycle state.
    pub fn set_unit_state(&self, id: u64, device: &str, state: JobState) {
        let mut jobs = self.jobs.lock().unwrap();
        if let Some(job) = jobs.get_mut(&id) {
            if let Some(unit) = job.units.iter_mut().find(|u| u.device == device) {
                unit.state = state;
            }
        }
    }

    /// Complete one unit with its result.
    pub fn complete_unit(&self, id: u64, device: &str, result: DeviceResult) {
        let mut jobs = self.jobs.lock().unwrap();
        if let Some(job) = jobs.get_mut(&id) {
            if let Some(unit) = job.units.iter_mut().find(|u| u.device == device) {
                unit.state = JobState::Done;
                unit.result = Some(result);
            }
        }
    }

    /// Fail one unit with an error message.
    pub fn fail_unit(&self, id: u64, device: &str, error: String) {
        let mut jobs = self.jobs.lock().unwrap();
        if let Some(job) = jobs.get_mut(&id) {
            if let Some(unit) = job.units.iter_mut().find(|u| u.device == device) {
                unit.state = JobState::Failed;
                unit.error = Some(error);
            }
        }
    }

    /// Mark the named units of a job cancelled (those the queue removed).
    pub fn cancel_units(&self, id: u64, devices: &[String]) {
        let mut jobs = self.jobs.lock().unwrap();
        if let Some(job) = jobs.get_mut(&id) {
            for unit in job.units.iter_mut() {
                if devices.iter().any(|d| d == &unit.device) {
                    unit.state = JobState::Cancelled;
                }
            }
        }
    }

    /// Job counts by aggregate state.
    pub fn counts(&self) -> JobCounts {
        let jobs = self.jobs.lock().unwrap();
        let mut c = JobCounts {
            submitted: jobs.len(),
            ..JobCounts::default()
        };
        for job in jobs.values() {
            match job.state() {
                JobState::Queued => c.queued += 1,
                JobState::Generating | JobState::Evaluating => c.running += 1,
                JobState::Done => c.done += 1,
                JobState::Failed => c.failed += 1,
                JobState::Cancelled => c.cancelled += 1,
                JobState::Partial => c.partial += 1,
            }
        }
        c
    }

    /// Move one live unit of a job from one device to another (the
    /// circuit breaker rerouting off a quarantined lane). Returns
    /// whether a unit was moved — false if the unit is already
    /// terminal, already moved, or the job owns a unit on `to` (fan-out
    /// units degrade in place instead of rerouting).
    pub fn reroute_unit(&self, id: u64, from: &str, to: &str) -> bool {
        let mut jobs = self.jobs.lock().unwrap();
        let Some(job) = jobs.get_mut(&id) else {
            return false;
        };
        if job.units.iter().any(|u| u.device == to) {
            return false;
        }
        if let Some(unit) = job.units.iter_mut().find(|u| u.device == from) {
            if !unit.state.finished() {
                unit.device = to.to_string();
                unit.state = JobState::Queued;
                return true;
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unit(device: &str, state: JobState) -> JobUnit {
        JobUnit {
            device: device.to_string(),
            state,
            result: None,
            error: None,
        }
    }

    fn job(id: u64, units: Vec<JobUnit>) -> Job {
        Job {
            id,
            spec: JobSpec::catalog("20_LeakyReLU", "b580"),
            submitted_at: Instant::now(),
            units,
        }
    }

    #[test]
    fn spec_json_roundtrip_catalog() {
        let mut spec = JobSpec::catalog("20_LeakyReLU", "lnl");
        spec.priority = JobPriority::High;
        spec.seed = 7;
        spec.iters = 3;
        let back = JobSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn spec_json_roundtrip_custom_and_fanout() {
        let spec = JobSpec {
            task: TaskSource::Custom {
                config: "name: t\nworkload:\n  - op: rope\n".to_string(),
                source: "### KF:REFERENCE ###\nref\n### KF:END ###".to_string(),
            },
            device: DeviceTarget::FanOut,
            language: "cuda".to_string(),
            seed: 3,
            iters: 2,
            population: 2,
            priority: JobPriority::Low,
        };
        let back = JobSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn spec_defaults_fill_absent_keys() {
        let v = crate::util::json::parse(r#"{"task": "20_LeakyReLU"}"#).unwrap();
        let spec = JobSpec::from_json(&v).unwrap();
        assert_eq!(spec.device, DeviceTarget::Named("b580".to_string()));
        assert_eq!(spec.language, "sycl");
        assert_eq!(spec.seed, DEFAULT_SEED);
        assert_eq!(spec.iters, DEFAULT_ITERS);
        assert_eq!(spec.population, DEFAULT_POPULATION);
        assert_eq!(spec.priority, JobPriority::Normal);
    }

    #[test]
    fn spec_rejects_missing_task_and_bad_priority() {
        let v = crate::util::json::parse(r#"{"device": "b580"}"#).unwrap();
        assert!(JobSpec::from_json(&v).is_err());
        let v = crate::util::json::parse(r#"{"task": "t", "priority": "urgent"}"#).unwrap();
        assert!(JobSpec::from_json(&v).is_err());
    }

    #[test]
    fn job_state_aggregation_precedence() {
        let j = job(1, vec![unit("a", JobState::Done), unit("b", JobState::Evaluating)]);
        assert_eq!(j.state(), JobState::Evaluating);
        let j = job(2, vec![unit("a", JobState::Queued), unit("b", JobState::Done)]);
        assert_eq!(j.state(), JobState::Queued);
        let j = job(3, vec![unit("a", JobState::Done), unit("b", JobState::Failed)]);
        assert_eq!(j.state(), JobState::Partial, "done + failed degrades, not fails");
        let j = job(4, vec![unit("a", JobState::Done), unit("b", JobState::Done)]);
        assert_eq!(j.state(), JobState::Done);
        let j = job(5, vec![unit("a", JobState::Cancelled), unit("b", JobState::Done)]);
        assert_eq!(j.state(), JobState::Cancelled);
        let j = job(6, vec![unit("a", JobState::Failed), unit("b", JobState::Failed)]);
        assert_eq!(j.state(), JobState::Failed);
        let j = job(7, vec![unit("a", JobState::Failed), unit("b", JobState::Evaluating)]);
        assert_eq!(j.state(), JobState::Evaluating, "active units still beat terminal");
        assert!(JobState::Partial.finished());
    }

    #[test]
    fn reroute_moves_only_live_unoccupied_units() {
        let t = JobTable::new();
        t.insert(job(1, vec![unit("a6000", JobState::Queued)]));
        assert!(t.reroute_unit(1, "a6000", "lnl"));
        let u = &t.get(1).unwrap().units[0];
        assert_eq!((u.device.as_str(), u.state), ("lnl", JobState::Queued));
        // Already moved: the unit is no longer on a6000.
        assert!(!t.reroute_unit(1, "a6000", "b580"));

        // Fan-out job owning a unit on the target: refuse.
        t.insert(job(
            2,
            vec![unit("a6000", JobState::Queued), unit("lnl", JobState::Queued)],
        ));
        assert!(!t.reroute_unit(2, "a6000", "lnl"));

        // Terminal units stay put.
        t.insert(job(3, vec![unit("a6000", JobState::Failed)]));
        assert!(!t.reroute_unit(3, "a6000", "lnl"));
    }

    #[test]
    fn table_unit_transitions_and_counts() {
        let t = JobTable::new();
        t.insert(job(1, vec![unit("b580", JobState::Queued)]));
        t.insert(job(2, vec![unit("b580", JobState::Queued)]));
        assert_eq!(t.counts().queued, 2);

        t.set_unit_state(1, "b580", JobState::Evaluating);
        let c = t.counts();
        assert_eq!(c.running, 1);
        assert_eq!(c.queued, 1);

        t.fail_unit(1, "b580", "boom".to_string());
        t.cancel_units(2, &["b580".to_string()]);
        let c = t.counts();
        assert_eq!((c.failed, c.cancelled), (1, 1));
        assert_eq!(t.get(1).unwrap().units[0].error.as_deref(), Some("boom"));
    }

    #[test]
    fn priority_ordering() {
        assert!(JobPriority::High > JobPriority::Normal);
        assert!(JobPriority::Normal > JobPriority::Low);
        assert_eq!(JobPriority::parse("high"), Some(JobPriority::High));
        assert_eq!(JobPriority::parse("urgent"), None);
    }
}
