//! The durable job journal: a write-ahead log of job lifecycle
//! transitions plus owner leases, so a restarted (or crashed) daemon
//! replays queued and in-flight jobs instead of losing them.
//!
//! # Record stream
//!
//! The journal is append-only JSONL — the same zero-dependency
//! machinery as [`crate::dist::Database`], written with whole-line
//! `O_APPEND` writes and reloaded through
//! [`crate::dist::load_jsonl_tolerant`] (a torn final line from a crash
//! mid-append is truncated away, never fatal). Record kinds, tagged by
//! `"t"`:
//!
//! | record     | written when                                       |
//! |------------|----------------------------------------------------|
//! | `lease`    | daemon start + every heartbeat (ttl/3)             |
//! | `release`  | clean shutdown                                     |
//! | `submit`   | before a job enters the table/queue                |
//! | `dispatch` | a lane popped the unit, before executing it        |
//! | `commit`   | a unit finished, *before* its result-cache row     |
//! | `fail`     | a unit errored                                     |
//! | `cancel`   | units removed from the queue (or submit rollback)  |
//! | `retry`    | a transient unit failure, before re-enqueueing     |
//! | `reroute`  | a queued unit moved off a quarantined lane         |
//! | `quarantine` | a unit exhausted its retry budget (terminal)     |
//!
//! # The slot-commit protocol
//!
//! Every (job × device) unit owns one result slot, identified by its
//! [`super::cache::cache_key`]. The lane orders writes as: journal
//! `commit` marker **first**, result-cache row second. Replay treats
//! the journal as truth and repairs the row iff it is missing
//! ([`super::cache::ResultCache::restore`] checks
//! [`crate::dist::Database::contains_run`] before appending) — so a
//! crash anywhere in the window yields *exactly one* row per slot, and
//! a row can never exist without its journal entry.
//!
//! # Replay semantics
//!
//! [`replay`] folds the record stream into a [`ReplayState`] with an
//! idempotent transition function (replaying a log twice equals
//! replaying it once — pinned by `tests/prop_invariants.rs`). Units
//! that were queued or dispatched-but-uncommitted are re-enqueued:
//! execution is *at-least-once*, and the determinism contract (verdicts
//! are a pure function of seed + genome id) makes the re-run
//! publication-equivalent. Committed results are restored without
//! re-execution, metrics intact, source omitted (commit markers carry
//! the metrics form, like persisted cache rows).
//!
//! # Owner leases
//!
//! A journal file has at most one live writer. [`Journal::open`]
//! refuses to open a journal whose last `lease` record is from another
//! owner and younger than the TTL; a heartbeat thread (driven by
//! [`Journal::heartbeat`]) refreshes the lease at ttl/3. When a daemon
//! dies, its lease goes stale after the TTL and a second daemon pointed
//! at the same journal adopts the queue by replaying it. The lease is
//! advisory (no OS file locking — the journal must behave identically
//! on filesystems without it); the TTL is the fencing interval.

use super::job::{DeviceResult, JobSpec};
use crate::dist::load_jsonl_tolerant;
use crate::util::error::{Context, Error};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, SystemTime, UNIX_EPOCH};

/// Milliseconds since the Unix epoch, as stored in lease records.
pub fn now_ms() -> f64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs_f64() * 1000.0)
        .unwrap_or(0.0)
}

/// One unit of a `submit` record: the target device plus whether the
/// unit was served from the cache at submit time (a cached unit is
/// never queued, so replay restores it from the cache, not the queue).
#[derive(Debug, Clone, PartialEq)]
pub struct SubmitUnit {
    /// Target device name.
    pub device: String,
    /// Whether the unit was a cache hit at submit time.
    pub cached: bool,
}

/// One journal record (see the module docs for the write points).
#[derive(Debug, Clone, PartialEq)]
pub enum JournalRecord {
    /// Ownership claim/heartbeat by a daemon.
    Lease {
        /// Owner identity (`kf-<pid>-<entropy>`).
        owner: String,
        /// Heartbeat timestamp, Unix ms.
        ts_ms: f64,
    },
    /// Clean ownership release at shutdown.
    Release {
        /// Owner identity giving up the journal.
        owner: String,
        /// Release timestamp, Unix ms.
        ts_ms: f64,
    },
    /// A job was accepted (written before it enters the table/queue).
    Submit {
        /// Service-assigned job id.
        job_id: u64,
        /// The full job spec (enough to re-run every unit).
        spec: JobSpec,
        /// Per-device units with their submit-time cache disposition.
        units: Vec<SubmitUnit>,
    },
    /// A lane popped a unit (execution may or may not have finished).
    Dispatch {
        /// Job the unit belongs to.
        job_id: u64,
        /// The lane's device.
        device: String,
    },
    /// A unit finished: the slot-commit marker, written *before* the
    /// result-cache row.
    Commit {
        /// Job the unit belongs to.
        job_id: u64,
        /// The lane's device.
        device: String,
        /// The unit's result in metrics form (source omitted).
        result: DeviceResult,
    },
    /// A unit errored terminally.
    Fail {
        /// Job the unit belongs to.
        job_id: u64,
        /// The lane's device.
        device: String,
        /// The error message.
        error: String,
    },
    /// Units were cancelled (removed from the queue before dispatch,
    /// or rolled back when the queue rejected the submit).
    Cancel {
        /// Job the units belong to.
        job_id: u64,
        /// Devices of the cancelled units.
        devices: Vec<String>,
    },
    /// A unit failed transiently and is being re-enqueued (written
    /// before the unit goes back on the queue, so a crash in the window
    /// replays the unit as queued — at-least-once, never lost).
    Retry {
        /// Job the unit belongs to.
        job_id: u64,
        /// The lane's device.
        device: String,
        /// Attempt count *after* this failure (1 = first retry pending).
        attempt: u32,
        /// The transient error that triggered the retry.
        error: String,
    },
    /// A queued unit was moved off a quarantined (circuit-open) lane to
    /// a healthy one. Replay re-enqueues the unit on `to`.
    Reroute {
        /// Job the unit belongs to.
        job_id: u64,
        /// The lane the unit was queued on.
        from: String,
        /// The healthy lane it was moved to.
        to: String,
    },
    /// A unit exhausted its retry budget on one lane: a terminal,
    /// deterministic failure verdict (the poison-genome quarantine).
    Quarantine {
        /// Job the unit belongs to.
        job_id: u64,
        /// The lane's device.
        device: String,
        /// The last error observed.
        error: String,
        /// Total attempts consumed (initial try + retries).
        attempts: u32,
    },
}

impl JournalRecord {
    /// Serialize to the JSONL object form.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        match self {
            JournalRecord::Lease { owner, ts_ms } => {
                o.set("t", "lease").set("owner", owner.as_str()).set("ts_ms", *ts_ms);
            }
            JournalRecord::Release { owner, ts_ms } => {
                o.set("t", "release").set("owner", owner.as_str()).set("ts_ms", *ts_ms);
            }
            JournalRecord::Submit { job_id, spec, units } => {
                let us: Vec<Json> = units
                    .iter()
                    .map(|u| {
                        let mut uo = Json::obj();
                        uo.set("device", u.device.as_str()).set("cached", u.cached);
                        uo
                    })
                    .collect();
                o.set("t", "submit")
                    .set("job_id", *job_id as usize)
                    .set("spec", spec.to_json())
                    .set("units", Json::Arr(us));
            }
            JournalRecord::Dispatch { job_id, device } => {
                o.set("t", "dispatch")
                    .set("job_id", *job_id as usize)
                    .set("device", device.as_str());
            }
            JournalRecord::Commit { job_id, device, result } => {
                o.set("t", "commit")
                    .set("job_id", *job_id as usize)
                    .set("device", device.as_str())
                    .set("result", result.to_json(false));
            }
            JournalRecord::Fail { job_id, device, error } => {
                o.set("t", "fail")
                    .set("job_id", *job_id as usize)
                    .set("device", device.as_str())
                    .set("error", error.as_str());
            }
            JournalRecord::Cancel { job_id, devices } => {
                o.set("t", "cancel")
                    .set("job_id", *job_id as usize)
                    .set("devices", devices.clone());
            }
            JournalRecord::Retry { job_id, device, attempt, error } => {
                o.set("t", "retry")
                    .set("job_id", *job_id as usize)
                    .set("device", device.as_str())
                    .set("attempt", *attempt as usize)
                    .set("error", error.as_str());
            }
            JournalRecord::Reroute { job_id, from, to } => {
                o.set("t", "reroute")
                    .set("job_id", *job_id as usize)
                    .set("from", from.as_str())
                    .set("to", to.as_str());
            }
            JournalRecord::Quarantine { job_id, device, error, attempts } => {
                o.set("t", "quarantine")
                    .set("job_id", *job_id as usize)
                    .set("device", device.as_str())
                    .set("error", error.as_str())
                    .set("attempts", *attempts as usize);
            }
        }
        o
    }

    /// Parse a record back from its JSON object form.
    pub fn from_json(v: &Json) -> Option<JournalRecord> {
        let t = v.get("t")?.as_str()?;
        let job_id = v.get("job_id").and_then(|x| x.as_usize()).map(|x| x as u64);
        let device = v.get("device").and_then(|x| x.as_str()).map(str::to_string);
        match t {
            "lease" | "release" => {
                let owner = v.get("owner")?.as_str()?.to_string();
                let ts_ms = v.get("ts_ms")?.as_f64()?;
                Some(if t == "lease" {
                    JournalRecord::Lease { owner, ts_ms }
                } else {
                    JournalRecord::Release { owner, ts_ms }
                })
            }
            "submit" => {
                let spec = JobSpec::from_json(v.get("spec")?).ok()?;
                let units = v
                    .get("units")?
                    .as_arr()?
                    .iter()
                    .map(|u| {
                        Some(SubmitUnit {
                            device: u.get("device")?.as_str()?.to_string(),
                            cached: u.get("cached")?.as_bool()?,
                        })
                    })
                    .collect::<Option<Vec<_>>>()?;
                Some(JournalRecord::Submit { job_id: job_id?, spec, units })
            }
            "dispatch" => Some(JournalRecord::Dispatch { job_id: job_id?, device: device? }),
            "commit" => Some(JournalRecord::Commit {
                job_id: job_id?,
                device: device?,
                result: DeviceResult::from_json(v.get("result")?)?,
            }),
            "fail" => Some(JournalRecord::Fail {
                job_id: job_id?,
                device: device?,
                error: v.get("error")?.as_str()?.to_string(),
            }),
            "cancel" => Some(JournalRecord::Cancel {
                job_id: job_id?,
                devices: v
                    .get("devices")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_str().map(str::to_string))
                    .collect::<Option<Vec<_>>>()?,
            }),
            "retry" => Some(JournalRecord::Retry {
                job_id: job_id?,
                device: device?,
                attempt: v.get("attempt")?.as_usize()? as u32,
                error: v.get("error")?.as_str()?.to_string(),
            }),
            "reroute" => Some(JournalRecord::Reroute {
                job_id: job_id?,
                from: v.get("from")?.as_str()?.to_string(),
                to: v.get("to")?.as_str()?.to_string(),
            }),
            "quarantine" => Some(JournalRecord::Quarantine {
                job_id: job_id?,
                device: device?,
                error: v.get("error")?.as_str()?.to_string(),
                attempts: v.get("attempts")?.as_usize()? as u32,
            }),
            _ => None,
        }
    }
}

/// Replayed state of one (job × device) unit.
#[derive(Debug, Clone, PartialEq)]
pub enum ReplayUnitState {
    /// Served from the cache at submit time; replay restores it from
    /// the (prewarmed) cache, or re-enqueues if the cache line is gone.
    CachedDone,
    /// Submitted but never dispatched: re-enqueue.
    Queued,
    /// Dispatched but never committed: re-enqueue (at-least-once).
    Dispatched,
    /// Committed with this result: restore without re-execution.
    Committed(DeviceResult),
    /// Failed terminally with this error.
    Failed(String),
    /// Cancelled before dispatch.
    Cancelled,
}

/// One replayed unit: target device plus its folded lifecycle state.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplayUnit {
    /// Target device name.
    pub device: String,
    /// Folded lifecycle state.
    pub state: ReplayUnitState,
    /// Highest retry attempt journaled for the unit (0 = never
    /// retried). Re-enqueued units carry this forward so a crash
    /// mid-retry cannot reset the retry budget.
    pub attempts: u32,
}

/// One replayed job.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplayJob {
    /// The job id from the `submit` record.
    pub id: u64,
    /// The submitted spec.
    pub spec: JobSpec,
    /// Per-device units.
    pub units: Vec<ReplayUnit>,
}

/// The result of folding a journal's record stream: jobs by id plus
/// the most recent lease holder (if the journal was not cleanly
/// released).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ReplayState {
    /// Replayed jobs, ordered by id.
    pub jobs: BTreeMap<u64, ReplayJob>,
    /// Last unreleased lease: (owner, heartbeat ts in Unix ms).
    pub lease: Option<(String, f64)>,
}

impl ReplayState {
    /// Apply one record. The transition function is idempotent in the
    /// fold sense: `replay(log ++ log) == replay(log)` for any log this
    /// daemon writes (duplicate submits are no-ops, dispatch only moves
    /// `Queued → Dispatched`, terminal states are sticky-overwritten
    /// with the same value).
    pub fn apply(&mut self, rec: &JournalRecord) {
        match rec {
            JournalRecord::Lease { owner, ts_ms } => {
                self.lease = Some((owner.clone(), *ts_ms));
            }
            JournalRecord::Release { owner, .. } => {
                if self.lease.as_ref().is_some_and(|(o, _)| o == owner) {
                    self.lease = None;
                }
            }
            JournalRecord::Submit { job_id, spec, units } => {
                self.jobs.entry(*job_id).or_insert_with(|| ReplayJob {
                    id: *job_id,
                    spec: spec.clone(),
                    units: units
                        .iter()
                        .map(|u| ReplayUnit {
                            device: u.device.clone(),
                            state: if u.cached {
                                ReplayUnitState::CachedDone
                            } else {
                                ReplayUnitState::Queued
                            },
                            attempts: 0,
                        })
                        .collect(),
                });
            }
            JournalRecord::Dispatch { job_id, device } => {
                if let Some(unit) = self.unit_mut(*job_id, device) {
                    if unit.state == ReplayUnitState::Queued {
                        unit.state = ReplayUnitState::Dispatched;
                    }
                }
            }
            JournalRecord::Commit { job_id, device, result } => {
                if let Some(unit) = self.unit_mut(*job_id, device) {
                    if !matches!(
                        unit.state,
                        ReplayUnitState::Failed(_) | ReplayUnitState::Cancelled
                    ) {
                        unit.state = ReplayUnitState::Committed(result.clone());
                    }
                }
            }
            JournalRecord::Fail { job_id, device, error } => {
                if let Some(unit) = self.unit_mut(*job_id, device) {
                    if !matches!(
                        unit.state,
                        ReplayUnitState::Committed(_) | ReplayUnitState::Cancelled
                    ) {
                        unit.state = ReplayUnitState::Failed(error.clone());
                    }
                }
            }
            JournalRecord::Cancel { job_id, devices } => {
                for device in devices {
                    if let Some(unit) = self.unit_mut(*job_id, device) {
                        if matches!(
                            unit.state,
                            ReplayUnitState::Queued | ReplayUnitState::Dispatched
                        ) {
                            unit.state = ReplayUnitState::Cancelled;
                        }
                    }
                }
            }
            JournalRecord::Retry { job_id, device, attempt, .. } => {
                if let Some(unit) = self.unit_mut(*job_id, device) {
                    if matches!(
                        unit.state,
                        ReplayUnitState::Queued | ReplayUnitState::Dispatched
                    ) {
                        unit.state = ReplayUnitState::Queued;
                        // max() keeps the fold idempotent: replaying the
                        // same retry twice cannot inflate the budget.
                        unit.attempts = unit.attempts.max(*attempt);
                    }
                }
            }
            JournalRecord::Reroute { job_id, from, to } => {
                // Move the unit iff it is still live on `from` and `to`
                // is unoccupied (fan-out jobs own one unit per device
                // and are never rerouted; the guard makes a duplicate
                // replay a no-op, keeping the fold idempotent).
                let occupied = self
                    .jobs
                    .get(job_id)
                    .is_some_and(|j| j.units.iter().any(|u| u.device == *to));
                if !occupied {
                    if let Some(unit) = self.unit_mut(*job_id, from) {
                        if matches!(
                            unit.state,
                            ReplayUnitState::Queued | ReplayUnitState::Dispatched
                        ) {
                            unit.device = to.clone();
                            unit.state = ReplayUnitState::Queued;
                        }
                    }
                }
            }
            JournalRecord::Quarantine { job_id, device, error, attempts } => {
                if let Some(unit) = self.unit_mut(*job_id, device) {
                    if !matches!(
                        unit.state,
                        ReplayUnitState::Committed(_) | ReplayUnitState::Cancelled
                    ) {
                        unit.state = ReplayUnitState::Failed(format!(
                            "quarantined after {attempts} attempts: {error}"
                        ));
                        unit.attempts = unit.attempts.max(*attempts);
                    }
                }
            }
        }
    }

    fn unit_mut(&mut self, job_id: u64, device: &str) -> Option<&mut ReplayUnit> {
        self.jobs
            .get_mut(&job_id)?
            .units
            .iter_mut()
            .find(|u| u.device == device)
    }

    /// The highest job id seen (0 when empty) — the restart point for
    /// the service's id counter.
    pub fn max_job_id(&self) -> u64 {
        self.jobs.keys().next_back().copied().unwrap_or(0)
    }
}

/// Fold a record stream into its replay state.
pub fn replay(records: &[JournalRecord]) -> ReplayState {
    let mut state = ReplayState::default();
    for rec in records {
        state.apply(rec);
    }
    state
}

/// An open, owned journal: an append handle plus the owner identity.
pub struct Journal {
    path: PathBuf,
    file: Mutex<File>,
    owner: String,
    written: AtomicU64,
}

impl Journal {
    /// Read a journal's records tolerantly (no ownership taken). A
    /// missing file is an empty journal; a torn final line is truncated
    /// away; mid-file corruption is an error.
    pub fn load_records(path: &Path) -> Result<Vec<JournalRecord>, Error> {
        if !path.exists() {
            return Ok(Vec::new());
        }
        let (records, _dropped) = load_jsonl_tolerant(path, JournalRecord::from_json)?;
        Ok(records)
    }

    /// Open a journal for writing as `owner`, enforcing the lease
    /// protocol: if the last `lease` record belongs to another owner
    /// and is younger than `lease_ttl`, the journal is held and the
    /// open fails; a stale lease (dead daemon) is taken over. On
    /// success the journal's prior records are returned for replay and
    /// an initial lease record is appended.
    pub fn open(
        path: &Path,
        owner: &str,
        lease_ttl: Duration,
    ) -> Result<(Journal, Vec<JournalRecord>), Error> {
        let records = Journal::load_records(path)?;
        let state = replay(&records);
        if let Some((holder, ts_ms)) = &state.lease {
            let age_ms = now_ms() - ts_ms;
            let ttl_ms = lease_ttl.as_secs_f64() * 1000.0;
            if holder != owner && age_ms < ttl_ms {
                return Err(Error::msg(format!(
                    "journal {} is held by '{holder}' (lease {age_ms:.0} ms old, ttl \
                     {ttl_ms:.0} ms); a stale lease is taken over automatically once \
                     the holder stops heartbeating for --lease-ttl",
                    path.display()
                )));
            }
        }
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("opening journal {}", path.display()))?;
        let journal = Journal {
            path: path.to_path_buf(),
            file: Mutex::new(file),
            owner: owner.to_string(),
            written: AtomicU64::new(0),
        };
        journal.append(&JournalRecord::Lease {
            owner: owner.to_string(),
            ts_ms: now_ms(),
        })?;
        Ok((journal, records))
    }

    /// The journal file path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// This journal's owner identity.
    pub fn owner(&self) -> &str {
        &self.owner
    }

    /// Records appended by this handle (not counting prior sessions).
    pub fn records_written(&self) -> u64 {
        self.written.load(Ordering::Relaxed)
    }

    /// Append one record as a single whole-line write (concurrent lane
    /// appends cannot interleave mid-line; a crash can only tear the
    /// final line, which reload truncates).
    pub fn append(&self, rec: &JournalRecord) -> Result<(), Error> {
        let mut line = rec.to_json().to_string_compact();
        line.push('\n');
        let mut file = self.file.lock().unwrap();
        file.write_all(line.as_bytes())
            .with_context(|| format!("appending to journal {}", self.path.display()))?;
        self.written.fetch_add(1, Ordering::Relaxed);
        crate::obs::global().counter("kf_journal_records_total").inc();
        Ok(())
    }

    /// Refresh this owner's lease (called every ttl/3 by the service's
    /// heartbeat thread).
    pub fn heartbeat(&self) -> Result<(), Error> {
        self.append(&JournalRecord::Lease {
            owner: self.owner.clone(),
            ts_ms: now_ms(),
        })
    }

    /// Release the lease cleanly (shutdown): a successor may open the
    /// journal immediately, without waiting out the TTL.
    pub fn release(&self) -> Result<(), Error> {
        self.append(&JournalRecord::Release {
            owner: self.owner.clone(),
            ts_ms: now_ms(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp_path(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("kf_journal_{}_{}.jsonl", name, std::process::id()))
    }

    fn sample_result(device: &str) -> DeviceResult {
        DeviceResult {
            device: device.to_string(),
            task_id: "20_LeakyReLU".to_string(),
            correct: true,
            fitness: 0.91,
            speedup: 1.7,
            time_ms: 0.4,
            baseline_ms: 0.68,
            coords: [1, 2, 0],
            genome_id: 17,
            produced_by: "gpt-4.1".to_string(),
            source: String::new(),
            evaluations: 6,
            compile_errors: 1,
            incorrect: 2,
            cached: false,
            wall_ms: 12.0,
        }
    }

    fn submit(job_id: u64, device: &str, cached: bool) -> JournalRecord {
        JournalRecord::Submit {
            job_id,
            spec: JobSpec::catalog("20_LeakyReLU", device),
            units: vec![SubmitUnit { device: device.to_string(), cached }],
        }
    }

    #[test]
    fn every_record_kind_roundtrips_through_json() {
        let records = vec![
            JournalRecord::Lease { owner: "kf-1-aa".to_string(), ts_ms: 123.5 },
            JournalRecord::Release { owner: "kf-1-aa".to_string(), ts_ms: 130.0 },
            submit(3, "b580", false),
            submit(4, "lnl", true),
            JournalRecord::Dispatch { job_id: 3, device: "b580".to_string() },
            JournalRecord::Commit {
                job_id: 3,
                device: "b580".to_string(),
                result: sample_result("b580"),
            },
            JournalRecord::Fail {
                job_id: 3,
                device: "b580".to_string(),
                error: "boom".to_string(),
            },
            JournalRecord::Cancel { job_id: 3, devices: vec!["b580".to_string()] },
            JournalRecord::Retry {
                job_id: 5,
                device: "b580".to_string(),
                attempt: 2,
                error: "injected fault: exec step failed".to_string(),
            },
            JournalRecord::Reroute {
                job_id: 5,
                from: "a6000".to_string(),
                to: "lnl".to_string(),
            },
            JournalRecord::Quarantine {
                job_id: 5,
                device: "b580".to_string(),
                error: "injected fault: exec step failed".to_string(),
                attempts: 3,
            },
        ];
        for rec in records {
            let back = JournalRecord::from_json(&rec.to_json());
            assert_eq!(back.as_ref(), Some(&rec), "round trip for {rec:?}");
        }
    }

    #[test]
    fn replay_folds_retry_reroute_and_quarantine() {
        // Job 1: dispatch → transient failure → retry → (crash here
        // replays as queued with the budget preserved).
        // Job 2: retried twice, then quarantined — terminal and sticky.
        // Job 3: queued on a quarantined lane, rerouted to a healthy one.
        let recs = vec![
            submit(1, "b580", false),
            JournalRecord::Dispatch { job_id: 1, device: "b580".to_string() },
            JournalRecord::Retry {
                job_id: 1,
                device: "b580".to_string(),
                attempt: 1,
                error: "transient".to_string(),
            },
            submit(2, "b580", false),
            JournalRecord::Dispatch { job_id: 2, device: "b580".to_string() },
            JournalRecord::Retry {
                job_id: 2,
                device: "b580".to_string(),
                attempt: 1,
                error: "transient".to_string(),
            },
            JournalRecord::Dispatch { job_id: 2, device: "b580".to_string() },
            JournalRecord::Quarantine {
                job_id: 2,
                device: "b580".to_string(),
                error: "transient".to_string(),
                attempts: 2,
            },
            submit(3, "a6000", false),
            JournalRecord::Reroute {
                job_id: 3,
                from: "a6000".to_string(),
                to: "lnl".to_string(),
            },
        ];
        let state = replay(&recs);
        assert_eq!(state.jobs[&1].units[0].state, ReplayUnitState::Queued);
        assert_eq!(state.jobs[&1].units[0].attempts, 1, "retry budget survives replay");
        assert_eq!(
            state.jobs[&2].units[0].state,
            ReplayUnitState::Failed("quarantined after 2 attempts: transient".to_string())
        );
        assert_eq!(state.jobs[&3].units[0].device, "lnl");
        assert_eq!(state.jobs[&3].units[0].state, ReplayUnitState::Queued);

        // Idempotence of the new kinds: a second application of the
        // same retry / reroute / quarantine records changes nothing.
        let mut state2 = state.clone();
        for rec in &recs {
            state2.apply(rec);
        }
        // Jobs 2 and 3 fold to the same place; job 1's retry re-queues
        // the (already queued) unit without inflating attempts.
        assert_eq!(state2, state);

        // A quarantined unit is sticky against late dispatch/commit.
        let mut state3 = state.clone();
        state3.apply(&JournalRecord::Dispatch { job_id: 2, device: "b580".to_string() });
        state3.apply(&JournalRecord::Commit {
            job_id: 2,
            device: "b580".to_string(),
            result: sample_result("b580"),
        });
        assert_eq!(
            state3.jobs[&2].units[0].state,
            ReplayUnitState::Failed("quarantined after 2 attempts: transient".to_string())
        );
    }

    #[test]
    fn replay_folds_the_lifecycle() {
        let recs = vec![
            submit(1, "b580", false),
            JournalRecord::Dispatch { job_id: 1, device: "b580".to_string() },
            JournalRecord::Commit {
                job_id: 1,
                device: "b580".to_string(),
                result: sample_result("b580"),
            },
            submit(2, "b580", false),
            JournalRecord::Cancel { job_id: 2, devices: vec!["b580".to_string()] },
            submit(3, "b580", false),
            JournalRecord::Dispatch { job_id: 3, device: "b580".to_string() },
        ];
        let state = replay(&recs);
        assert_eq!(state.jobs.len(), 3);
        assert!(matches!(
            state.jobs[&1].units[0].state,
            ReplayUnitState::Committed(_)
        ));
        assert_eq!(state.jobs[&2].units[0].state, ReplayUnitState::Cancelled);
        assert_eq!(state.jobs[&3].units[0].state, ReplayUnitState::Dispatched);
        assert_eq!(state.max_job_id(), 3);

        // Terminal states are sticky: a late dispatch/cancel replayed
        // after a commit must not resurrect the unit.
        let mut state2 = state.clone();
        state2.apply(&JournalRecord::Dispatch { job_id: 1, device: "b580".to_string() });
        state2.apply(&JournalRecord::Cancel { job_id: 1, devices: vec!["b580".to_string()] });
        assert_eq!(state2, state);
    }

    #[test]
    fn open_appends_lease_and_blocks_second_owner_until_stale_or_released() {
        let path = tmp_path("lease");
        std::fs::remove_file(&path).ok();
        let (j1, prior) = Journal::open(&path, "owner-a", Duration::from_secs(60)).unwrap();
        assert!(prior.is_empty());
        assert_eq!(j1.records_written(), 1, "initial lease appended");

        // A live lease blocks a different owner...
        let err = Journal::open(&path, "owner-b", Duration::from_secs(60))
            .err()
            .expect("held journal must refuse a second owner")
            .to_string();
        assert!(err.contains("held by 'owner-a'"), "{err}");

        // ...until released cleanly, after which takeover is immediate.
        j1.release().unwrap();
        let (j2, prior) = Journal::open(&path, "owner-b", Duration::from_secs(60)).unwrap();
        assert_eq!(prior.len(), 2, "lease + release replayed");
        drop(j2);

        // A stale lease (no release, heartbeats stopped) is taken over
        // once older than the TTL.
        std::thread::sleep(Duration::from_millis(30));
        let res = Journal::open(&path, "owner-c", Duration::from_millis(10));
        assert!(res.is_ok(), "stale lease must be adoptable: {:?}", res.err().map(|e| e.to_string()));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_records_truncates_a_torn_tail() {
        let path = tmp_path("torn");
        std::fs::remove_file(&path).ok();
        {
            let (j, _) = Journal::open(&path, "o", Duration::from_secs(60)).unwrap();
            j.append(&submit(1, "b580", false)).unwrap();
        }
        // Crash mid-append: partial bytes of a dispatch record.
        use std::io::Write as _;
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"{\"t\":\"dispatch\",\"job").unwrap();
        drop(f);

        let records = Journal::load_records(&path).unwrap();
        assert_eq!(records.len(), 2, "lease + submit survive, torn tail dropped");
        // The file was repaired in place: re-opening appends cleanly.
        let (j, prior) = Journal::open(&path, "o", Duration::from_secs(60)).unwrap();
        assert_eq!(prior.len(), 2);
        j.append(&JournalRecord::Dispatch { job_id: 1, device: "b580".to_string() }).unwrap();
        assert_eq!(Journal::load_records(&path).unwrap().len(), 4);
        std::fs::remove_file(&path).ok();
    }
}
