//! Kernel-as-a-service: the long-running generation daemon (§3.6, Fig. 4).
//!
//! The paper's systems claim is a *distributed framework with remote
//! access to diverse hardware* plus *a flexible user input layer* for
//! kernel generation beyond fixed benchmark suites. The batch CLI
//! (`run` / `serve`) exercises one device profile per process and
//! forgets everything at exit; this subsystem is the serving layer every
//! later scaling PR builds on:
//!
//! * [`job`] — job ids, priorities, the `queued → generating →
//!   evaluating → done/failed` lifecycle, and the shared job table;
//! * [`queue`] — a bounded multi-producer priority queue (backpressure
//!   at the intake, mirroring the `dist` pipeline's queue discipline);
//! * [`fleet`] — one lane per heterogeneous device profile, each
//!   driving [`crate::coordinator::EvolutionEngine::run_distributed`]
//!   over its own [`crate::dist::WorkerPool`]; jobs route to one device
//!   or fan out across all of them for cross-hardware comparison;
//! * [`cache`] — results keyed by (task fingerprint, device, language,
//!   seed, budget), persisted through [`crate::dist::Database`], so a
//!   warm daemon answers repeat requests without re-evolving;
//! * [`proto`] / [`api`] — a newline-JSON RPC over
//!   `std::net::TcpListener` with `submit` (catalog ids *or* inline
//!   App. C custom tasks), `status`, `result`, `cancel`, `stats` and
//!   `shutdown` verbs.
//!
//! [`KernelService`] ties the pieces together; `kernelfoundry daemon` /
//! `kernelfoundry submit` are the CLI entry points.

pub mod api;
pub mod cache;
pub mod failpoint;
pub mod faults;
pub mod fleet;
pub mod job;
pub mod journal;
pub mod proto;
pub mod queue;
pub mod supervisor;

pub use api::{Client, Server};
pub use cache::ResultCache;
pub use faults::{FaultAction, FaultPlan, FaultStep};
pub use fleet::Fleet;
pub use job::{
    DeviceResult, DeviceTarget, Job, JobCounts, JobPriority, JobSpec, JobState, JobTable,
    TaskSource,
};
pub use journal::{Journal, JournalRecord};
pub use proto::Request;
pub use queue::{JobQueue, QueuedUnit, QueueError};
pub use supervisor::{CircuitBreaker, GuardConfig, LaneState};

use crate::dist::ClusterConfig;
use crate::hwsim::DeviceProfile;
use crate::obs::alerts::{AlertEngine, AlertLog, RuleSet};
use crate::obs::trace::stage;
use crate::obs::window::{derived_metrics, lookup_metric, DeltaTracker};
use crate::obs::{labeled, EventBus, Registry, Snapshot, TraceSink};
use crate::report::SearchLog;
use crate::tasks::{catalog, custom};
use crate::util::json::Json;
use journal::ReplayUnitState;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Configuration of one service instance.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Fleet devices, one lane each (deduplicated by name at start).
    pub devices: Vec<DeviceProfile>,
    /// Compile workers per lane pool (Fig. 4 type 2).
    pub compile_workers: usize,
    /// Execution workers per lane pool (Fig. 4 type 3).
    pub exec_workers: usize,
    /// Capacity of the intake job queue *and* of each lane pool's
    /// inter-stage queues. Clamped up to the fleet width at start so a
    /// fan-out submit is never permanently unsatisfiable.
    pub queue_capacity: usize,
    /// JSONL path for cache persistence (`None` = in-memory only).
    ///
    /// There is deliberately no service-level RNG seed: every job
    /// carries its own `JobSpec::seed` (part of the cache key), so a
    /// daemon-wide seed would be a dead knob.
    pub db_path: Option<PathBuf>,
    /// JSONL path of the write-ahead job journal (`None` = volatile:
    /// queued and in-flight jobs are lost on restart, the pre-durability
    /// behavior). With a journal, restart replays them — see [`journal`].
    pub journal_path: Option<PathBuf>,
    /// Owner-lease TTL for the journal. The daemon heartbeats at ttl/3;
    /// a second daemon pointed at the same journal may take over only
    /// once the last heartbeat is older than this (or after a clean
    /// release). Ignored without `journal_path`.
    pub lease_ttl: Duration,
    /// JSONL path of the job-lifecycle trace sink (`None` = tracing
    /// off). Each lifecycle transition of every job appends one
    /// timestamped stage event; `kernelfoundry trace <job-id>` rebuilds
    /// a job's timeline from this file. Lives naturally next to the
    /// journal (same append-only whole-line discipline).
    pub trace_path: Option<PathBuf>,
    /// JSONL path of the per-generation search-history log (`None` =
    /// history off). Every fleet-lane evolution run appends one
    /// [`crate::report::SearchStatsRow`] per generation, keyed by the
    /// unit's cache key; `kernelfoundry report --search-log` folds the
    /// rows into QD-score / coverage / acceptance curves.
    pub search_log_path: Option<PathBuf>,
    /// SLO rules file for the alert engine (`None` = the built-in
    /// [`RuleSet::defaults`]). The engine only runs at all when this or
    /// `alert_log_path` is set.
    pub alert_rules_path: Option<PathBuf>,
    /// JSONL path the alert engine appends `firing`/`resolved`
    /// transitions to (`None` = transitions only reach the trace sink
    /// and live `watch` streams).
    pub alert_log_path: Option<PathBuf>,
    /// Cadence of the daemon-side alert ticker.
    pub alert_interval: Duration,
    /// Fault-tolerance knobs for the fleet lanes: retry budget,
    /// per-unit deadline, circuit-breaker thresholds and backoff
    /// parameters (see [`supervisor::GuardConfig`]).
    pub guard: GuardConfig,
    /// Deterministic fault-injection plan (`--fault-plan`; `None` =
    /// no injected faults — production). See [`faults::FaultPlan`].
    pub fault_plan: Option<FaultPlan>,
}

/// Default journal owner-lease TTL (seconds).
pub const DEFAULT_LEASE_TTL_SECS: u64 = 30;

/// Default alert-ticker cadence (ms).
pub const DEFAULT_ALERT_INTERVAL_MS: u64 = 1000;

impl Default for ServiceConfig {
    fn default() -> ServiceConfig {
        let cluster = ClusterConfig::default();
        ServiceConfig {
            devices: DeviceProfile::all(),
            compile_workers: cluster.compile_workers,
            exec_workers: cluster.exec_workers,
            queue_capacity: cluster.queue_capacity,
            db_path: None,
            journal_path: None,
            lease_ttl: Duration::from_secs(DEFAULT_LEASE_TTL_SECS),
            trace_path: None,
            search_log_path: None,
            alert_rules_path: None,
            alert_log_path: None,
            alert_interval: Duration::from_millis(DEFAULT_ALERT_INTERVAL_MS),
            guard: GuardConfig::default(),
            fault_plan: None,
        }
    }
}

/// Counters describing what journal replay restored at service start
/// (the `stats` verb's `journal` block; all zero without a journal).
#[derive(Debug, Clone, Copy, Default)]
pub struct ReplayStats {
    /// Journal records read at start.
    pub records: usize,
    /// Jobs restored into the job table.
    pub jobs: usize,
    /// Units restored in a terminal state (results served from the
    /// journal/cache without re-execution).
    pub restored_results: usize,
    /// Units re-enqueued for execution: queued at crash time, or
    /// dispatched but never committed (at-least-once re-run).
    pub requeued_units: usize,
    /// Jobs with at least one unit that could not be restored or
    /// re-enqueued (its device left the fleet across the restart; the
    /// unit is surfaced as failed, never dropped silently). The restart
    /// e2e pins this to zero.
    pub lost_jobs: usize,
}

/// What `submit` returns: the assigned id plus whether the whole job
/// was served from the cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SubmitReceipt {
    /// Assigned job id.
    pub job_id: u64,
    /// Job state right after submission (`Done` when fully cached).
    pub state: JobState,
    /// Whether every unit was a cache hit.
    pub cached: bool,
}

/// Replay helper: re-enqueue one journaled unit, or surface it as
/// failed (never drop it silently) when its device left the fleet
/// across the restart.
fn requeue_unit(
    job_id: u64,
    spec: &JobSpec,
    device: String,
    attempts: u32,
    cfg: &ServiceConfig,
    to_queue: &mut Vec<QueuedUnit>,
    stats: &mut ReplayStats,
    lost: &mut bool,
) -> job::JobUnit {
    if cfg.devices.iter().any(|d| d.name == device) {
        stats.requeued_units += 1;
        let mut unit = QueuedUnit::fresh(job_id, &device, spec.clone());
        // A crash mid-retry must not reset the unit's retry budget:
        // replay carries the journaled attempt count forward.
        unit.attempt = attempts;
        to_queue.push(unit);
        job::JobUnit {
            device,
            state: JobState::Queued,
            result: None,
            error: None,
        }
    } else {
        *lost = true;
        job::JobUnit {
            device: device.clone(),
            state: JobState::Failed,
            result: None,
            error: Some(format!(
                "device '{device}' left the fleet across a restart; resubmit to re-run"
            )),
        }
    }
}

/// Spawn the daemon-side alert ticker: every `alert_interval` it takes
/// a merged snapshot, folds it into the rolling window, evaluates the
/// SLO rules, and fans each `firing`/`resolved` edge out to the alert
/// log, the trace sink (as an `alert_*` mirror event) and the watch
/// bus. Holds only a `Weak` service reference so it can never keep a
/// stopped daemon alive.
fn spawn_alert_ticker(
    service: &Arc<KernelService>,
    mut engine: AlertEngine,
    log: Option<AlertLog>,
) -> thread::JoinHandle<()> {
    let weak = Arc::downgrade(service);
    let stop = Arc::clone(&service.alert_stop);
    let interval = service.cfg.alert_interval.max(Duration::from_millis(10));
    thread::spawn(move || {
        let mut tracker = DeltaTracker::new();
        let mut last: Option<Instant> = None;
        loop {
            if stop.load(Ordering::Relaxed) {
                return;
            }
            thread::sleep(Duration::from_millis(5));
            if last.is_some_and(|l| l.elapsed() < interval) {
                continue;
            }
            last = Some(Instant::now());
            let Some(svc) = weak.upgrade() else { return };
            let snap = svc.merged_snapshot();
            let now = crate::obs::now_ms();
            let delta = tracker.tick(snap.clone(), now);
            let derived = derived_metrics(&delta, &snap);
            let edges = engine.eval(|m| lookup_metric(m, &derived, &delta, &snap), now);
            svc.obs.gauge("kf_alerts_firing").set(engine.firing() as f64);
            for t in &edges {
                crate::log_warn!(
                    "alert {}: {} ({} {} {}, value {})",
                    t.state,
                    t.rule,
                    t.metric,
                    t.op,
                    t.threshold,
                    t.value
                );
                svc.obs
                    .counter(&labeled("kf_alert_transitions_total", "state", &t.state))
                    .inc();
                if let Some(log) = &log {
                    log.append(t);
                }
                if let Some(sink) = &svc.trace {
                    sink.mirror_alert(&t.state, &t.rule);
                }
                let mut frame = t.to_json();
                frame.set("kind", "alert");
                svc.watch_bus.publish(&frame);
            }
        }
    })
}

/// The service orchestrator: queue + job table + cache + fleet, plus
/// the optional write-ahead [`Journal`] that makes restarts lossless.
pub struct KernelService {
    cfg: ServiceConfig,
    queue: Arc<JobQueue>,
    jobs: Arc<JobTable>,
    cache: Arc<ResultCache>,
    fleet: Fleet,
    journal: Option<Arc<Journal>>,
    /// Per-daemon metrics registry (merged with [`crate::obs::global`]
    /// for the `metrics` verb, so two in-process daemons never bleed
    /// into each other's exact `stats` counts).
    obs: Arc<Registry>,
    trace: Option<Arc<TraceSink>>,
    replay_stats: ReplayStats,
    heartbeat_stop: Arc<AtomicBool>,
    heartbeat: Mutex<Option<thread::JoinHandle<()>>>,
    /// Live fan-out of trace/alert frames to open `watch` streams.
    watch_bus: Arc<EventBus>,
    /// Names of the loaded alert rules (empty when alerts are off).
    alert_rules: Vec<String>,
    alert_stop: Arc<AtomicBool>,
    alert_ticker: Mutex<Option<thread::JoinHandle<()>>>,
    next_id: AtomicU64,
    started: Instant,
}

impl KernelService {
    /// Validate the configuration, prewarm the cache from `db_path` (if
    /// set), replay the journal (if set) and spawn the fleet lanes.
    pub fn start(mut cfg: ServiceConfig) -> Result<Arc<KernelService>, String> {
        let mut seen = Vec::new();
        cfg.devices.retain(|d| {
            if seen.iter().any(|s| *s == d.name) {
                false
            } else {
                seen.push(d.name);
                true
            }
        });
        if cfg.devices.is_empty() {
            return Err("service needs at least one fleet device".to_string());
        }
        let obs = Arc::new(Registry::new());
        let trace = match &cfg.trace_path {
            None => None,
            Some(path) => Some(Arc::new(
                TraceSink::open(path)
                    .map_err(|e| format!("trace sink {}: {e}", path.display()))?,
            )),
        };
        let search_log = match &cfg.search_log_path {
            None => None,
            Some(path) => Some(Arc::new(
                SearchLog::open(path)
                    .map_err(|e| format!("search log {}: {e}", path.display()))?,
            )),
        };
        let cache = match &cfg.db_path {
            None => ResultCache::in_memory(),
            Some(path) => ResultCache::with_database(path).map_err(|e| e.to_string())?,
        };
        cache.attach_obs(&obs);

        // Live layer: the watch bus fans trace/alert frames out to open
        // `watch` streams; the alert engine runs only when asked for.
        let watch_bus = Arc::new(EventBus::new());
        if let Some(t) = &trace {
            t.attach_bus(Arc::clone(&watch_bus));
        }
        let mut alert_rules = Vec::new();
        let mut alert_setup = None;
        if cfg.alert_rules_path.is_some() || cfg.alert_log_path.is_some() {
            let rules = match &cfg.alert_rules_path {
                Some(path) => RuleSet::load(path)?,
                None => RuleSet::defaults(),
            };
            alert_rules = rules.rules.iter().map(|r| r.name.clone()).collect();
            let log = match &cfg.alert_log_path {
                None => None,
                Some(path) => Some(
                    AlertLog::open(path)
                        .map_err(|e| format!("alert log {}: {e}", path.display()))?,
                ),
            };
            alert_setup = Some((AlertEngine::new(rules), log));
        }

        // Acquire the journal lease and fold its records into the state
        // every queued/in-flight job was in when the last owner stopped.
        let mut journal = None;
        let mut replay_stats = ReplayStats::default();
        let mut restored_jobs = Vec::new();
        let mut to_queue = Vec::new();
        let mut next_id = 0u64;
        if let Some(path) = &cfg.journal_path {
            let owner = format!("kf-{}-{:x}", std::process::id(), journal::now_ms() as u64);
            let (jnl, records) =
                Journal::open(path, &owner, cfg.lease_ttl).map_err(|e| e.to_string())?;
            let state = journal::replay(&records);
            replay_stats.records = records.len();
            replay_stats.jobs = state.jobs.len();
            next_id = state.max_job_id();
            for (id, rj) in state.jobs {
                let mut units = Vec::new();
                let mut lost = false;
                for ru in rj.units {
                    let key = cache::cache_key(&rj.spec, &ru.device);
                    let attempts = ru.attempts;
                    units.push(match ru.state {
                        ReplayUnitState::Committed(result) => {
                            // Exactly-once slot repair: the commit marker
                            // is authoritative; (re)write the cache row
                            // only if the crash lost it.
                            cache.restore(&key, result.clone());
                            replay_stats.restored_results += 1;
                            job::JobUnit {
                                device: ru.device,
                                state: JobState::Done,
                                result: Some(result),
                                error: None,
                            }
                        }
                        ReplayUnitState::CachedDone => match cache.peek(&key) {
                            Some(hit) => {
                                replay_stats.restored_results += 1;
                                job::JobUnit {
                                    device: ru.device,
                                    state: JobState::Done,
                                    result: Some(hit),
                                    error: None,
                                }
                            }
                            // Cache hit at submit time, but the cache did
                            // not survive the restart: re-run (the unit
                            // was never journaled with its result).
                            None => requeue_unit(
                                id,
                                &rj.spec,
                                ru.device,
                                attempts,
                                &cfg,
                                &mut to_queue,
                                &mut replay_stats,
                                &mut lost,
                            ),
                        },
                        // Queued at crash time, or dispatched but never
                        // committed: at-least-once re-run. Determinism
                        // (verdict = f(seed, genome)) makes the re-run
                        // publication-equivalent to the lost attempt.
                        ReplayUnitState::Queued | ReplayUnitState::Dispatched => requeue_unit(
                            id,
                            &rj.spec,
                            ru.device,
                            attempts,
                            &cfg,
                            &mut to_queue,
                            &mut replay_stats,
                            &mut lost,
                        ),
                        ReplayUnitState::Failed(error) => job::JobUnit {
                            device: ru.device,
                            state: JobState::Failed,
                            result: None,
                            error: Some(error),
                        },
                        ReplayUnitState::Cancelled => job::JobUnit {
                            device: ru.device,
                            state: JobState::Cancelled,
                            result: None,
                            error: None,
                        },
                    });
                }
                if lost {
                    replay_stats.lost_jobs += 1;
                }
                restored_jobs.push(Job {
                    id,
                    spec: rj.spec,
                    submitted_at: Instant::now(),
                    units,
                });
            }
            journal = Some(Arc::new(jnl));
        }

        // A fan-out submit enqueues one unit per device atomically; a
        // capacity below the fleet width would reject `--device all`
        // forever with a misleading "retry later". Replayed units must
        // likewise always fit, however many the journal restored.
        cfg.queue_capacity = cfg.queue_capacity.max(cfg.devices.len()).max(to_queue.len());
        let queue = Arc::new(JobQueue::new(cfg.queue_capacity));
        let jobs = Arc::new(JobTable::new());
        let cache = Arc::new(cache);
        for job in restored_jobs {
            jobs.insert(job);
        }
        if !to_queue.is_empty() {
            // Replayed units re-enter the queue like fresh ones; their
            // timelines record the re-queueing (before the push, so a
            // lane can never emit `dispatched` ahead of it).
            if let Some(t) = &trace {
                for unit in &to_queue {
                    t.stage(stage::QUEUED, unit.job_id, None);
                }
            }
            queue
                .push(to_queue)
                .map_err(|e| format!("re-enqueueing replayed units: {e}"))?;
        }
        let fleet = Fleet::spawn(
            &cfg,
            &queue,
            &jobs,
            &cache,
            journal.as_ref(),
            &obs,
            trace.as_ref(),
            search_log.as_ref(),
        );

        // Heartbeat: refresh the owner lease at ttl/3 so a standby
        // daemon can distinguish "owner is alive" from "owner is gone".
        let heartbeat_stop = Arc::new(AtomicBool::new(false));
        let mut heartbeat = None;
        if let Some(jnl) = &journal {
            let jnl = Arc::clone(jnl);
            let stop = Arc::clone(&heartbeat_stop);
            let interval = (cfg.lease_ttl / 3).max(Duration::from_millis(10));
            heartbeat = Some(thread::spawn(move || {
                let mut last = Instant::now();
                while !stop.load(Ordering::Relaxed) {
                    thread::sleep(Duration::from_millis(10));
                    if last.elapsed() >= interval {
                        if let Err(e) = jnl.heartbeat() {
                            crate::log_warn!("journal heartbeat failed: {e}");
                        }
                        last = Instant::now();
                    }
                }
            }));
        }

        let service = Arc::new(KernelService {
            cfg,
            queue,
            jobs,
            cache,
            fleet,
            journal,
            obs,
            trace,
            replay_stats,
            heartbeat_stop,
            heartbeat: Mutex::new(heartbeat),
            watch_bus,
            alert_rules,
            alert_stop: Arc::new(AtomicBool::new(false)),
            alert_ticker: Mutex::new(None),
            next_id: AtomicU64::new(next_id),
            started: Instant::now(),
        });
        if let Some((engine, log)) = alert_setup {
            let handle = spawn_alert_ticker(&service, engine, log);
            *service.alert_ticker.lock().unwrap() = Some(handle);
        }
        Ok(service)
    }

    /// The service configuration (post-dedup).
    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// The fleet's device names.
    pub fn device_names(&self) -> Vec<String> {
        self.fleet.device_names()
    }

    /// Submit a job: validate the spec, resolve target devices, serve
    /// cache hits immediately and queue the rest.
    pub fn submit(&self, spec: JobSpec) -> Result<SubmitReceipt, String> {
        match &spec.task {
            TaskSource::Catalog(id) => {
                catalog::find_task(id).ok_or_else(|| format!("unknown task '{id}'"))?;
            }
            TaskSource::Custom { config, source } => {
                custom::load_strings(config, source).map_err(|e| format!("custom task: {e}"))?;
            }
        }
        if spec.iters == 0 || spec.population == 0 {
            return Err("iters and population must be >= 1".to_string());
        }
        let devices = match &spec.device {
            DeviceTarget::FanOut => self.fleet.device_names(),
            DeviceTarget::Named(d) => {
                if !self.fleet.has_device(d) {
                    return Err(format!(
                        "device '{d}' not in fleet ({})",
                        self.fleet.device_names().join(", ")
                    ));
                }
                vec![d.clone()]
            }
        };

        let id = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        self.obs.counter("kf_jobs_submitted_total").inc();
        if let Some(t) = &self.trace {
            t.register(id);
            t.stage(stage::SUBMIT, id, None);
        }
        let mut units = Vec::new();
        let mut to_queue = Vec::new();
        for device in &devices {
            let key = cache::cache_key(&spec, device);
            match self.cache.lookup(&key) {
                Some(hit) => units.push(job::JobUnit {
                    device: device.clone(),
                    state: JobState::Done,
                    result: Some(hit),
                    error: None,
                }),
                None => {
                    units.push(job::JobUnit {
                        device: device.clone(),
                        state: JobState::Queued,
                        result: None,
                        error: None,
                    });
                    to_queue.push(QueuedUnit::fresh(id, device, spec.clone()));
                }
            }
        }
        let cached = to_queue.is_empty();

        // Journal first: once the Submit record is durable, a crash
        // anywhere past this line replays the job instead of losing it.
        if let Some(jnl) = &self.journal {
            let rec = JournalRecord::Submit {
                job_id: id,
                spec: spec.clone(),
                units: units
                    .iter()
                    .map(|u| journal::SubmitUnit {
                        device: u.device.clone(),
                        cached: u.state == JobState::Done,
                    })
                    .collect(),
            };
            jnl.append(&rec).map_err(|e| format!("journal: {e}"))?;
            failpoint::hit("submit.after_journal");
        }

        // Register before queueing: a lane must never pop a unit whose
        // job is not yet in the table.
        let job = Job {
            id,
            spec,
            submitted_at: Instant::now(),
            units,
        };
        let state = job.state();
        self.jobs.insert(job);
        if !cached {
            // Trace `queued` before the push: once a unit is in the
            // queue a lane can pop it immediately, and its `dispatched`
            // event must never precede `queued` in the sink.
            if let Some(t) = &self.trace {
                t.stage(stage::QUEUED, id, None);
            }
            if let Err(e) = self.queue.push(to_queue) {
                self.jobs.remove(id);
                // Compensating record: without it, replay would
                // resurrect a job the caller was told to retry.
                if let Some(jnl) = &self.journal {
                    let rec = JournalRecord::Cancel {
                        job_id: id,
                        devices,
                    };
                    if let Err(je) = jnl.append(&rec) {
                        crate::log_warn!("journal cancel-on-reject failed: {je}");
                    }
                }
                self.obs.counter("kf_jobs_rejected_total").inc();
                if let Some(t) = &self.trace {
                    t.stage(stage::CANCELLED, id, None);
                }
                return Err(e.to_string());
            }
        } else {
            // A fully cached job never visits a lane; its timeline
            // still records a terminal `committed` (the results are
            // durable) so no finished job lacks one.
            self.obs.counter("kf_jobs_cached_total").inc();
            if let Some(t) = &self.trace {
                t.stage(stage::COMMITTED, id, None);
            }
        }
        Ok(SubmitReceipt {
            job_id: id,
            state,
            cached,
        })
    }

    /// Snapshot of one job.
    pub fn status(&self, id: u64) -> Option<Job> {
        self.jobs.get(id)
    }

    /// Cancel a job whose units are all still queued. Units a lane has
    /// already picked up cannot be recalled.
    pub fn cancel(&self, id: u64) -> Result<JobState, String> {
        let job = self.jobs.get(id).ok_or_else(|| format!("no such job {id}"))?;
        let state = job.state();
        if state.finished() {
            return Err(format!("job {id} already {}", state.name()));
        }
        let removed = self.queue.cancel(id);
        if removed.is_empty() {
            return Err(format!("job {id} is already running"));
        }
        self.jobs.cancel_units(id, &removed);
        self.obs.counter("kf_jobs_cancelled_total").inc();
        if let Some(t) = &self.trace {
            t.stage(stage::CANCELLED, id, None);
        }
        if let Some(jnl) = &self.journal {
            let rec = JournalRecord::Cancel {
                job_id: id,
                devices: removed,
            };
            if let Err(e) = jnl.append(&rec) {
                crate::log_warn!("journal cancel failed: {e}");
            }
        }
        Ok(self
            .jobs
            .get(id)
            .map(|j| j.state())
            .unwrap_or(JobState::Cancelled))
    }

    /// Sample the instantaneous service state (queue depth, job counts,
    /// cache entries, uptime) into the per-daemon registry. Both `stats`
    /// and the `metrics` verb render from this one synchronized set of
    /// values instead of each re-deriving its own.
    fn sync_registry(&self) {
        self.obs.gauge("kf_queue_depth").set(self.queue.len() as f64);
        self.obs.gauge("kf_queue_capacity").set(self.queue.capacity() as f64);
        self.obs
            .gauge("kf_uptime_ms")
            .set(self.started.elapsed().as_secs_f64() * 1000.0);
        if let Some(counts) = self.jobs.counts().to_json().as_obj() {
            for (k, v) in counts {
                if let Some(x) = v.as_f64() {
                    self.obs.gauge(&format!("kf_jobs_{k}")).set(x);
                }
            }
        }
        if let Some(entries) = self.cache.stats_json().get("entries").and_then(|v| v.as_f64()) {
            self.obs.gauge("kf_cache_entries").set(entries);
        }
        self.obs
            .gauge("kf_replay_lost_jobs")
            .set(self.replay_stats.lost_jobs as f64);
        self.obs
            .gauge("kf_lanes_open")
            .set(self.fleet.open_lanes() as f64);
    }

    /// The full metrics registry — per-daemon counters merged with the
    /// process-wide [`crate::obs::global`] registry (search telemetry,
    /// eval-stage timings, journal/pool counters) — rendered in
    /// Prometheus text-exposition format. The `metrics` RPC verb and
    /// `kernelfoundry metrics` return exactly this string.
    pub fn metrics_text(&self) -> String {
        self.merged_snapshot().to_prometheus()
    }

    /// One synchronized snapshot of everything this daemon can see: the
    /// per-daemon registry (after [`Self::sync_registry`]) merged with
    /// the process-wide global registry. The `metrics` verb, the alert
    /// ticker and every `watch` stream all derive from this.
    pub fn merged_snapshot(&self) -> Snapshot {
        self.sync_registry();
        let mut snap = self.obs.snapshot();
        snap.merge(&crate::obs::global().snapshot());
        snap
    }

    /// Scoped exposition: `Some("service")` = this daemon's registry
    /// only, `Some("global")` = the process-wide registry only,
    /// anything else = the merged view of [`Self::metrics_text`].
    pub fn metrics_text_scoped(&self, scope: Option<&str>) -> String {
        match scope {
            Some("service") => {
                self.sync_registry();
                self.obs.snapshot().to_prometheus()
            }
            Some("global") => crate::obs::global().snapshot().to_prometheus(),
            _ => self.metrics_text(),
        }
    }

    /// The live frame bus `watch` streams subscribe to.
    pub fn watch_bus(&self) -> &Arc<EventBus> {
        &self.watch_bus
    }

    /// Names of the loaded alert rules (empty when alerts are off).
    pub fn alert_rule_names(&self) -> Vec<String> {
        self.alert_rules.clone()
    }

    /// Service-wide counters: jobs, queue depth, cache metrics, per-
    /// device fleet utilization.
    pub fn stats(&self) -> Json {
        self.sync_registry();
        let mut queue_o = Json::obj();
        queue_o
            .set("depth", self.obs.gauge("kf_queue_depth").value())
            .set("capacity", self.obs.gauge("kf_queue_capacity").value());
        let mut journal_o = Json::obj();
        match &self.journal {
            None => {
                journal_o.set("enabled", false);
            }
            Some(jnl) => {
                journal_o
                    .set("enabled", true)
                    .set("owner", jnl.owner())
                    .set("records_written", jnl.records_written() as usize)
                    .set("replayed_records", self.replay_stats.records)
                    .set("replayed_jobs", self.replay_stats.jobs)
                    .set("restored_results", self.replay_stats.restored_results)
                    .set("requeued_units", self.replay_stats.requeued_units)
                    .set("lost_jobs", self.replay_stats.lost_jobs);
            }
        }
        let mut o = Json::obj();
        o.set("ok", true)
            .set("uptime_ms", self.started.elapsed().as_secs_f64() * 1000.0)
            .set("jobs", self.jobs.counts().to_json())
            .set("queue", queue_o)
            .set("cache", self.cache.stats_json())
            .set("fleet", self.fleet.stats_json())
            .set("journal", journal_o);
        o
    }

    /// Dispatch one parsed RPC request to a wire response. `Shutdown`
    /// only acknowledges — the transport layer owns the actual stop.
    pub fn handle(&self, req: &Request) -> Json {
        let t0 = Instant::now();
        let resp = self.handle_inner(req);
        self.obs
            .observe_ms("kf_rpc_handle_ms", t0.elapsed().as_secs_f64() * 1000.0);
        resp
    }

    fn handle_inner(&self, req: &Request) -> Json {
        match req {
            Request::Submit(spec) => match self.submit(spec.clone()) {
                Ok(receipt) => {
                    let mut o = Json::obj();
                    o.set("ok", true)
                        .set("job_id", receipt.job_id as usize)
                        .set("state", receipt.state.name())
                        .set("cached", receipt.cached);
                    o
                }
                Err(e) => proto::error_response(&e),
            },
            Request::Status(id) => match self.jobs.get(*id) {
                Some(job) => job.to_json(false),
                None => proto::error_response(&format!("no such job {id}")),
            },
            Request::Result(id) => match self.jobs.get(*id) {
                Some(job) => {
                    let state = job.state();
                    if state.finished() {
                        // The job's span ends when a client actually
                        // receives the finished result.
                        self.obs.observe_ms(
                            "kf_job_submit_to_responded_ms",
                            job.submitted_at.elapsed().as_secs_f64() * 1000.0,
                        );
                        if let Some(t) = &self.trace {
                            t.stage(stage::RESPONDED, *id, None);
                        }
                        job.to_json(true)
                    } else {
                        proto::error_response(&format!(
                            "job {id} not finished (state: {})",
                            state.name()
                        ))
                    }
                }
                None => proto::error_response(&format!("no such job {id}")),
            },
            Request::Cancel(id) => match self.cancel(*id) {
                Ok(state) => {
                    let mut o = Json::obj();
                    o.set("ok", true)
                        .set("job_id", *id as usize)
                        .set("state", state.name());
                    o
                }
                Err(e) => proto::error_response(&e),
            },
            Request::Stats => self.stats(),
            Request::Metrics(scope) => {
                let mut o = Json::obj();
                o.set("ok", true)
                    .set("prometheus", self.metrics_text_scoped(scope.as_deref()));
                o
            }
            Request::Watch(_) => proto::error_response(
                "watch is a streaming verb served by the TCP transport; use `kernelfoundry watch`",
            ),
            Request::Shutdown => {
                let mut o = Json::obj();
                o.set("ok", true).set("state", "shutting_down");
                o
            }
        }
    }

    /// Stop the service: shut the queue (lanes drain remaining units),
    /// join every lane thread, then release the journal lease so a
    /// successor can take over without waiting out the TTL.
    pub fn stop(&self) {
        self.queue.shutdown();
        self.fleet.join();
        self.heartbeat_stop.store(true, Ordering::Relaxed);
        if let Some(handle) = self.heartbeat.lock().unwrap().take() {
            let _ = handle.join();
        }
        self.alert_stop.store(true, Ordering::Relaxed);
        if let Some(handle) = self.alert_ticker.lock().unwrap().take() {
            let _ = handle.join();
        }
        if let Some(jnl) = &self.journal {
            if let Err(e) = jnl.release() {
                crate::log_warn!("journal lease release failed: {e}");
            }
        }
    }

    /// Block until the job reaches a terminal state or the timeout
    /// elapses; returns the final snapshot. Used by direct (non-TCP)
    /// callers: benches and tests.
    pub fn wait(&self, id: u64, timeout: std::time::Duration) -> Option<Job> {
        let deadline = Instant::now() + timeout;
        loop {
            let job = self.jobs.get(id)?;
            if job.state().finished() {
                return Some(job);
            }
            if Instant::now() >= deadline {
                return Some(job);
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn quick_service(devices: Vec<DeviceProfile>) -> Arc<KernelService> {
        KernelService::start(ServiceConfig {
            devices,
            compile_workers: 1,
            exec_workers: 2,
            queue_capacity: 16,
            ..ServiceConfig::default()
        })
        .unwrap()
    }

    fn tiny_spec(task: &str, device: &str) -> JobSpec {
        let mut spec = JobSpec::catalog(task, device);
        spec.iters = 2;
        spec.population = 2;
        spec
    }

    #[test]
    fn submit_validates_task_device_and_budget() {
        let svc = quick_service(vec![DeviceProfile::b580()]);
        let err = svc.submit(tiny_spec("no_such_task", "b580")).unwrap_err();
        assert!(err.contains("unknown task"), "{err}");
        let err = svc.submit(tiny_spec("20_LeakyReLU", "h100")).unwrap_err();
        assert!(err.contains("not in fleet"), "{err}");
        let mut zero = tiny_spec("20_LeakyReLU", "b580");
        zero.iters = 0;
        assert!(svc.submit(zero).is_err());
        svc.stop();
    }

    #[test]
    fn identical_resubmission_is_served_from_cache() {
        let svc = quick_service(vec![DeviceProfile::b580()]);
        let first = svc.submit(tiny_spec("20_LeakyReLU", "b580")).unwrap();
        assert!(!first.cached);
        let job = svc.wait(first.job_id, Duration::from_secs(30)).unwrap();
        assert_eq!(job.state(), JobState::Done);

        let second = svc.submit(tiny_spec("20_LeakyReLU", "b580")).unwrap();
        assert!(second.cached, "identical resubmission must hit the cache");
        assert_eq!(second.state, JobState::Done);
        let cached_job = svc.status(second.job_id).unwrap();
        assert!(cached_job.units[0].result.as_ref().unwrap().cached);
        assert_eq!(svc.cache.hits.load(Ordering::Relaxed), 1);

        // A different seed is a different cache line.
        let mut other = tiny_spec("20_LeakyReLU", "b580");
        other.seed = 1;
        let third = svc.submit(other).unwrap();
        assert!(!third.cached);
        svc.wait(third.job_id, Duration::from_secs(30));
        svc.stop();
    }

    #[test]
    fn fan_out_returns_one_unit_per_device() {
        let svc = quick_service(vec![DeviceProfile::lnl(), DeviceProfile::b580()]);
        let mut spec = tiny_spec("20_LeakyReLU", "b580");
        spec.device = DeviceTarget::FanOut;
        let receipt = svc.submit(spec).unwrap();
        let job = svc.wait(receipt.job_id, Duration::from_secs(60)).unwrap();
        assert_eq!(job.state(), JobState::Done);
        assert_eq!(job.units.len(), 2);
        let mut devices: Vec<&str> =
            job.units.iter().map(|u| u.result.as_ref().unwrap().device.as_str()).collect();
        devices.sort();
        assert_eq!(devices, vec!["b580", "lnl"]);
        svc.stop();
    }

    #[test]
    fn duplicate_fleet_devices_are_deduplicated() {
        let svc = quick_service(vec![DeviceProfile::b580(), DeviceProfile::b580()]);
        assert_eq!(svc.device_names(), vec!["b580".to_string()]);
        svc.stop();
    }

    #[test]
    fn queue_capacity_clamped_to_fleet_width() {
        let svc = KernelService::start(ServiceConfig {
            devices: vec![DeviceProfile::lnl(), DeviceProfile::b580(), DeviceProfile::a6000()],
            compile_workers: 1,
            exec_workers: 1,
            queue_capacity: 1,
            ..ServiceConfig::default()
        })
        .unwrap();
        assert_eq!(svc.config().queue_capacity, 3, "fan-out must always fit");
        svc.stop();
    }

    #[test]
    fn cancel_of_a_dispatched_job_reports_coherent_status() {
        let svc = quick_service(vec![DeviceProfile::b580()]);
        let mut spec = JobSpec::catalog("1_Conv2D_ReLU_BiasAdd", "b580");
        spec.iters = 12;
        spec.population = 6;
        let receipt = svc.submit(spec).unwrap();

        // Wait for the lane to pick the unit up, then try to cancel:
        // a dispatched unit cannot be recalled, and the error must say
        // so instead of pretending the job was stopped.
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let job = svc.status(receipt.job_id).unwrap();
            if job.state() != JobState::Queued {
                break;
            }
            assert!(Instant::now() < deadline, "unit never left the queue");
            thread::sleep(Duration::from_millis(2));
        }
        // Either "already running" (mid-flight) or "already done" (the
        // lane won the race) is coherent; silently claiming success or
        // leaving a half-cancelled job is the regression.
        let err = svc.cancel(receipt.job_id).unwrap_err();
        assert!(err.contains("already"), "{err}");
        let job = svc.wait(receipt.job_id, Duration::from_secs(60)).unwrap();
        assert_eq!(job.state(), JobState::Done, "cancel must not corrupt the run");
        assert!(job.units[0].result.is_some());
        svc.stop();
    }

    #[test]
    fn metrics_text_is_prometheus_shaped() {
        let svc = quick_service(vec![DeviceProfile::b580()]);
        let receipt = svc.submit(tiny_spec("20_LeakyReLU", "b580")).unwrap();
        svc.wait(receipt.job_id, Duration::from_secs(30));
        let resp = svc.handle(&Request::Metrics(None));
        assert!(proto::response_ok(&resp));
        let text = resp.get("prometheus").unwrap().as_str().unwrap();
        assert!(text.contains("# TYPE kf_queue_depth gauge"), "{text}");
        assert!(text.contains("kf_queue_capacity"), "{text}");
        assert!(text.contains("kf_jobs_submitted_total 1"), "{text}");
        assert!(text.contains("kf_cache_misses_total"), "{text}");
        assert!(text.contains("kf_rpc_handle_ms_bucket"), "{text}");
        svc.stop();
    }

    #[test]
    fn metrics_scopes_isolate_registries() {
        let svc = quick_service(vec![DeviceProfile::b580()]);
        let receipt = svc.submit(tiny_spec("20_LeakyReLU", "b580")).unwrap();
        svc.wait(receipt.job_id, Duration::from_secs(30));
        let service_text = svc.metrics_text_scoped(Some("service"));
        assert!(service_text.contains("kf_jobs_submitted_total 1"), "{service_text}");
        assert!(service_text.contains("kf_queue_depth"), "{service_text}");
        let global_text = svc.metrics_text_scoped(Some("global"));
        assert!(
            !global_text.contains("kf_queue_depth"),
            "per-daemon gauges must not leak into the global scope: {global_text}"
        );
        let merged = svc.metrics_text_scoped(None);
        assert!(merged.contains("kf_queue_depth"), "{merged}");
        svc.stop();
    }

    #[test]
    fn watch_verb_is_transport_only() {
        let svc = quick_service(vec![DeviceProfile::b580()]);
        let resp = svc.handle(&Request::Watch(100));
        assert!(!proto::response_ok(&resp), "{resp}");
        svc.stop();
    }

    #[test]
    fn alert_ticker_logs_firing_and_resolved() {
        let mut dir = std::env::temp_dir();
        dir.push(format!("kf_svc_alerts_{}", std::process::id()));
        let _ = std::fs::create_dir_all(&dir);
        let rules = dir.join("rules.txt");
        let log = dir.join("alerts.jsonl");
        let _ = std::fs::remove_file(&log);
        // Healthy only while nothing was ever submitted: one submit
        // breaches it forever, so the e2e of firing→resolved lives in
        // tests/watch_e2e.rs; here we pin firing + the log shape.
        std::fs::write(&rules, "no-jobs: kf_jobs_submitted_total < 1\n").unwrap();
        let svc = KernelService::start(ServiceConfig {
            devices: vec![DeviceProfile::b580()],
            compile_workers: 1,
            exec_workers: 2,
            queue_capacity: 8,
            alert_rules_path: Some(rules),
            alert_log_path: Some(log.clone()),
            alert_interval: Duration::from_millis(20),
            ..ServiceConfig::default()
        })
        .unwrap();
        assert_eq!(svc.alert_rule_names(), vec!["no-jobs".to_string()]);
        let receipt = svc.submit(tiny_spec("20_LeakyReLU", "b580")).unwrap();
        svc.wait(receipt.job_id, Duration::from_secs(30));
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let fired = crate::obs::alerts::AlertLog::load(&log)
                .iter()
                .any(|t| t.rule == "no-jobs" && t.state == "firing");
            if fired {
                break;
            }
            assert!(Instant::now() < deadline, "alert never fired");
            thread::sleep(Duration::from_millis(5));
        }
        svc.stop();
        let _ = std::fs::remove_file(&log);
    }

    #[test]
    fn stats_covers_jobs_queue_cache_and_fleet() {
        let svc = quick_service(vec![DeviceProfile::b580()]);
        let receipt = svc.submit(tiny_spec("20_LeakyReLU", "b580")).unwrap();
        svc.wait(receipt.job_id, Duration::from_secs(30));
        let stats = svc.stats();
        assert!(proto::response_ok(&stats));
        assert_eq!(stats.get_path("jobs.submitted").unwrap().as_usize(), Some(1));
        assert_eq!(stats.get_path("queue.capacity").unwrap().as_usize(), Some(16));
        assert_eq!(stats.get_path("cache.entries").unwrap().as_usize(), Some(1));
        let fleet = stats.get("fleet").unwrap().as_arr().unwrap();
        assert_eq!(fleet.len(), 1);
        assert_eq!(fleet[0].get("device").unwrap().as_str(), Some("b580"));
        svc.stop();
    }
}
