//! Kernel-as-a-service: the long-running generation daemon (§3.6, Fig. 4).
//!
//! The paper's systems claim is a *distributed framework with remote
//! access to diverse hardware* plus *a flexible user input layer* for
//! kernel generation beyond fixed benchmark suites. The batch CLI
//! (`run` / `serve`) exercises one device profile per process and
//! forgets everything at exit; this subsystem is the serving layer every
//! later scaling PR builds on:
//!
//! * [`job`] — job ids, priorities, the `queued → generating →
//!   evaluating → done/failed` lifecycle, and the shared job table;
//! * [`queue`] — a bounded multi-producer priority queue (backpressure
//!   at the intake, mirroring the `dist` pipeline's queue discipline);
//! * [`fleet`] — one lane per heterogeneous device profile, each
//!   driving [`crate::coordinator::EvolutionEngine::run_distributed`]
//!   over its own [`crate::dist::WorkerPool`]; jobs route to one device
//!   or fan out across all of them for cross-hardware comparison;
//! * [`cache`] — results keyed by (task fingerprint, device, language,
//!   seed, budget), persisted through [`crate::dist::Database`], so a
//!   warm daemon answers repeat requests without re-evolving;
//! * [`proto`] / [`api`] — a newline-JSON RPC over
//!   `std::net::TcpListener` with `submit` (catalog ids *or* inline
//!   App. C custom tasks), `status`, `result`, `cancel`, `stats` and
//!   `shutdown` verbs.
//!
//! [`KernelService`] ties the pieces together; `kernelfoundry daemon` /
//! `kernelfoundry submit` are the CLI entry points.

pub mod api;
pub mod cache;
pub mod fleet;
pub mod job;
pub mod proto;
pub mod queue;

pub use api::{Client, Server};
pub use cache::ResultCache;
pub use fleet::Fleet;
pub use job::{
    DeviceResult, DeviceTarget, Job, JobCounts, JobPriority, JobSpec, JobState, JobTable,
    TaskSource,
};
pub use proto::Request;
pub use queue::{JobQueue, QueuedUnit, QueueError};

use crate::dist::ClusterConfig;
use crate::hwsim::DeviceProfile;
use crate::tasks::{catalog, custom};
use crate::util::json::Json;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Configuration of one service instance.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Fleet devices, one lane each (deduplicated by name at start).
    pub devices: Vec<DeviceProfile>,
    /// Compile workers per lane pool (Fig. 4 type 2).
    pub compile_workers: usize,
    /// Execution workers per lane pool (Fig. 4 type 3).
    pub exec_workers: usize,
    /// Capacity of the intake job queue *and* of each lane pool's
    /// inter-stage queues. Clamped up to the fleet width at start so a
    /// fan-out submit is never permanently unsatisfiable.
    pub queue_capacity: usize,
    /// JSONL path for cache persistence (`None` = in-memory only).
    ///
    /// There is deliberately no service-level RNG seed: every job
    /// carries its own `JobSpec::seed` (part of the cache key), so a
    /// daemon-wide seed would be a dead knob.
    pub db_path: Option<PathBuf>,
}

impl Default for ServiceConfig {
    fn default() -> ServiceConfig {
        let cluster = ClusterConfig::default();
        ServiceConfig {
            devices: DeviceProfile::all(),
            compile_workers: cluster.compile_workers,
            exec_workers: cluster.exec_workers,
            queue_capacity: cluster.queue_capacity,
            db_path: None,
        }
    }
}

/// What `submit` returns: the assigned id plus whether the whole job
/// was served from the cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SubmitReceipt {
    /// Assigned job id.
    pub job_id: u64,
    /// Job state right after submission (`Done` when fully cached).
    pub state: JobState,
    /// Whether every unit was a cache hit.
    pub cached: bool,
}

/// The service orchestrator: queue + job table + cache + fleet.
pub struct KernelService {
    cfg: ServiceConfig,
    queue: Arc<JobQueue>,
    jobs: Arc<JobTable>,
    cache: Arc<ResultCache>,
    fleet: Fleet,
    next_id: AtomicU64,
    started: Instant,
}

impl KernelService {
    /// Validate the configuration, prewarm the cache from `db_path` (if
    /// set) and spawn the fleet lanes.
    pub fn start(mut cfg: ServiceConfig) -> Result<Arc<KernelService>, String> {
        let mut seen = Vec::new();
        cfg.devices.retain(|d| {
            if seen.iter().any(|s| *s == d.name) {
                false
            } else {
                seen.push(d.name);
                true
            }
        });
        if cfg.devices.is_empty() {
            return Err("service needs at least one fleet device".to_string());
        }
        // A fan-out submit enqueues one unit per device atomically; a
        // capacity below the fleet width would reject `--device all`
        // forever with a misleading "retry later".
        cfg.queue_capacity = cfg.queue_capacity.max(cfg.devices.len());
        let cache = match &cfg.db_path {
            None => ResultCache::in_memory(),
            Some(path) => ResultCache::with_database(path).map_err(|e| e.to_string())?,
        };
        let queue = Arc::new(JobQueue::new(cfg.queue_capacity));
        let jobs = Arc::new(JobTable::new());
        let cache = Arc::new(cache);
        let fleet = Fleet::spawn(&cfg, &queue, &jobs, &cache);
        Ok(Arc::new(KernelService {
            cfg,
            queue,
            jobs,
            cache,
            fleet,
            next_id: AtomicU64::new(0),
            started: Instant::now(),
        }))
    }

    /// The service configuration (post-dedup).
    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// The fleet's device names.
    pub fn device_names(&self) -> Vec<String> {
        self.fleet.device_names()
    }

    /// Submit a job: validate the spec, resolve target devices, serve
    /// cache hits immediately and queue the rest.
    pub fn submit(&self, spec: JobSpec) -> Result<SubmitReceipt, String> {
        match &spec.task {
            TaskSource::Catalog(id) => {
                catalog::find_task(id).ok_or_else(|| format!("unknown task '{id}'"))?;
            }
            TaskSource::Custom { config, source } => {
                custom::load_strings(config, source).map_err(|e| format!("custom task: {e}"))?;
            }
        }
        if spec.iters == 0 || spec.population == 0 {
            return Err("iters and population must be >= 1".to_string());
        }
        let devices = match &spec.device {
            DeviceTarget::FanOut => self.fleet.device_names(),
            DeviceTarget::Named(d) => {
                if !self.fleet.has_device(d) {
                    return Err(format!(
                        "device '{d}' not in fleet ({})",
                        self.fleet.device_names().join(", ")
                    ));
                }
                vec![d.clone()]
            }
        };

        let id = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        let mut units = Vec::new();
        let mut to_queue = Vec::new();
        for device in &devices {
            let key = cache::cache_key(&spec, device);
            match self.cache.lookup(&key) {
                Some(hit) => units.push(job::JobUnit {
                    device: device.clone(),
                    state: JobState::Done,
                    result: Some(hit),
                    error: None,
                }),
                None => {
                    units.push(job::JobUnit {
                        device: device.clone(),
                        state: JobState::Queued,
                        result: None,
                        error: None,
                    });
                    to_queue.push(QueuedUnit {
                        job_id: id,
                        device: device.clone(),
                        priority: spec.priority,
                        seq: 0,
                        spec: spec.clone(),
                    });
                }
            }
        }
        let cached = to_queue.is_empty();

        // Register before queueing: a lane must never pop a unit whose
        // job is not yet in the table.
        let job = Job {
            id,
            spec,
            submitted_at: Instant::now(),
            units,
        };
        let state = job.state();
        self.jobs.insert(job);
        if !cached {
            if let Err(e) = self.queue.push(to_queue) {
                self.jobs.remove(id);
                return Err(e.to_string());
            }
        }
        Ok(SubmitReceipt {
            job_id: id,
            state,
            cached,
        })
    }

    /// Snapshot of one job.
    pub fn status(&self, id: u64) -> Option<Job> {
        self.jobs.get(id)
    }

    /// Cancel a job whose units are all still queued. Units a lane has
    /// already picked up cannot be recalled.
    pub fn cancel(&self, id: u64) -> Result<JobState, String> {
        let job = self.jobs.get(id).ok_or_else(|| format!("no such job {id}"))?;
        let state = job.state();
        if state.finished() {
            return Err(format!("job {id} already {}", state.name()));
        }
        let removed = self.queue.cancel(id);
        if removed.is_empty() {
            return Err(format!("job {id} is already running"));
        }
        self.jobs.cancel_units(id, &removed);
        Ok(self
            .jobs
            .get(id)
            .map(|j| j.state())
            .unwrap_or(JobState::Cancelled))
    }

    /// Service-wide counters: jobs, queue depth, cache metrics, per-
    /// device fleet utilization.
    pub fn stats(&self) -> Json {
        let mut queue_o = Json::obj();
        queue_o
            .set("depth", self.queue.len())
            .set("capacity", self.queue.capacity());
        let mut o = Json::obj();
        o.set("ok", true)
            .set("uptime_ms", self.started.elapsed().as_secs_f64() * 1000.0)
            .set("jobs", self.jobs.counts().to_json())
            .set("queue", queue_o)
            .set("cache", self.cache.stats_json())
            .set("fleet", self.fleet.stats_json());
        o
    }

    /// Dispatch one parsed RPC request to a wire response. `Shutdown`
    /// only acknowledges — the transport layer owns the actual stop.
    pub fn handle(&self, req: &Request) -> Json {
        match req {
            Request::Submit(spec) => match self.submit(spec.clone()) {
                Ok(receipt) => {
                    let mut o = Json::obj();
                    o.set("ok", true)
                        .set("job_id", receipt.job_id as usize)
                        .set("state", receipt.state.name())
                        .set("cached", receipt.cached);
                    o
                }
                Err(e) => proto::error_response(&e),
            },
            Request::Status(id) => match self.jobs.get(*id) {
                Some(job) => job.to_json(false),
                None => proto::error_response(&format!("no such job {id}")),
            },
            Request::Result(id) => match self.jobs.get(*id) {
                Some(job) => {
                    let state = job.state();
                    if state.finished() {
                        job.to_json(true)
                    } else {
                        proto::error_response(&format!(
                            "job {id} not finished (state: {})",
                            state.name()
                        ))
                    }
                }
                None => proto::error_response(&format!("no such job {id}")),
            },
            Request::Cancel(id) => match self.cancel(*id) {
                Ok(state) => {
                    let mut o = Json::obj();
                    o.set("ok", true)
                        .set("job_id", *id as usize)
                        .set("state", state.name());
                    o
                }
                Err(e) => proto::error_response(&e),
            },
            Request::Stats => self.stats(),
            Request::Shutdown => {
                let mut o = Json::obj();
                o.set("ok", true).set("state", "shutting_down");
                o
            }
        }
    }

    /// Stop the service: shut the queue (lanes drain remaining units)
    /// and join every lane thread.
    pub fn stop(&self) {
        self.queue.shutdown();
        self.fleet.join();
    }

    /// Block until the job reaches a terminal state or the timeout
    /// elapses; returns the final snapshot. Used by direct (non-TCP)
    /// callers: benches and tests.
    pub fn wait(&self, id: u64, timeout: std::time::Duration) -> Option<Job> {
        let deadline = Instant::now() + timeout;
        loop {
            let job = self.jobs.get(id)?;
            if job.state().finished() {
                return Some(job);
            }
            if Instant::now() >= deadline {
                return Some(job);
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn quick_service(devices: Vec<DeviceProfile>) -> Arc<KernelService> {
        KernelService::start(ServiceConfig {
            devices,
            compile_workers: 1,
            exec_workers: 2,
            queue_capacity: 16,
            db_path: None,
        })
        .unwrap()
    }

    fn tiny_spec(task: &str, device: &str) -> JobSpec {
        let mut spec = JobSpec::catalog(task, device);
        spec.iters = 2;
        spec.population = 2;
        spec
    }

    #[test]
    fn submit_validates_task_device_and_budget() {
        let svc = quick_service(vec![DeviceProfile::b580()]);
        let err = svc.submit(tiny_spec("no_such_task", "b580")).unwrap_err();
        assert!(err.contains("unknown task"), "{err}");
        let err = svc.submit(tiny_spec("20_LeakyReLU", "h100")).unwrap_err();
        assert!(err.contains("not in fleet"), "{err}");
        let mut zero = tiny_spec("20_LeakyReLU", "b580");
        zero.iters = 0;
        assert!(svc.submit(zero).is_err());
        svc.stop();
    }

    #[test]
    fn identical_resubmission_is_served_from_cache() {
        let svc = quick_service(vec![DeviceProfile::b580()]);
        let first = svc.submit(tiny_spec("20_LeakyReLU", "b580")).unwrap();
        assert!(!first.cached);
        let job = svc.wait(first.job_id, Duration::from_secs(30)).unwrap();
        assert_eq!(job.state(), JobState::Done);

        let second = svc.submit(tiny_spec("20_LeakyReLU", "b580")).unwrap();
        assert!(second.cached, "identical resubmission must hit the cache");
        assert_eq!(second.state, JobState::Done);
        let cached_job = svc.status(second.job_id).unwrap();
        assert!(cached_job.units[0].result.as_ref().unwrap().cached);
        assert_eq!(svc.cache.hits.load(Ordering::Relaxed), 1);

        // A different seed is a different cache line.
        let mut other = tiny_spec("20_LeakyReLU", "b580");
        other.seed = 1;
        let third = svc.submit(other).unwrap();
        assert!(!third.cached);
        svc.wait(third.job_id, Duration::from_secs(30));
        svc.stop();
    }

    #[test]
    fn fan_out_returns_one_unit_per_device() {
        let svc = quick_service(vec![DeviceProfile::lnl(), DeviceProfile::b580()]);
        let mut spec = tiny_spec("20_LeakyReLU", "b580");
        spec.device = DeviceTarget::FanOut;
        let receipt = svc.submit(spec).unwrap();
        let job = svc.wait(receipt.job_id, Duration::from_secs(60)).unwrap();
        assert_eq!(job.state(), JobState::Done);
        assert_eq!(job.units.len(), 2);
        let mut devices: Vec<&str> =
            job.units.iter().map(|u| u.result.as_ref().unwrap().device.as_str()).collect();
        devices.sort();
        assert_eq!(devices, vec!["b580", "lnl"]);
        svc.stop();
    }

    #[test]
    fn duplicate_fleet_devices_are_deduplicated() {
        let svc = quick_service(vec![DeviceProfile::b580(), DeviceProfile::b580()]);
        assert_eq!(svc.device_names(), vec!["b580".to_string()]);
        svc.stop();
    }

    #[test]
    fn queue_capacity_clamped_to_fleet_width() {
        let svc = KernelService::start(ServiceConfig {
            devices: vec![DeviceProfile::lnl(), DeviceProfile::b580(), DeviceProfile::a6000()],
            compile_workers: 1,
            exec_workers: 1,
            queue_capacity: 1,
            db_path: None,
        })
        .unwrap();
        assert_eq!(svc.config().queue_capacity, 3, "fan-out must always fit");
        svc.stop();
    }

    #[test]
    fn stats_covers_jobs_queue_cache_and_fleet() {
        let svc = quick_service(vec![DeviceProfile::b580()]);
        let receipt = svc.submit(tiny_spec("20_LeakyReLU", "b580")).unwrap();
        svc.wait(receipt.job_id, Duration::from_secs(30));
        let stats = svc.stats();
        assert!(proto::response_ok(&stats));
        assert_eq!(stats.get_path("jobs.submitted").unwrap().as_usize(), Some(1));
        assert_eq!(stats.get_path("queue.capacity").unwrap().as_usize(), Some(16));
        assert_eq!(stats.get_path("cache.entries").unwrap().as_usize(), Some(1));
        let fleet = stats.get("fleet").unwrap().as_arr().unwrap();
        assert_eq!(fleet.len(), 1);
        assert_eq!(fleet[0].get("device").unwrap().as_str(), Some("b580"));
        svc.stop();
    }
}
