//! The newline-JSON RPC wire protocol.
//!
//! One request per line, one response per line, over a plain TCP
//! stream — no external dependencies, inspectable with `nc`. Every
//! request is a JSON object with a `verb` key:
//!
//! ```text
//! {"verb":"submit","task":"20_LeakyReLU","device":"b580","iters":8}
//! {"verb":"submit","custom":{"config":"<task.yaml>","source":"<marked source>"},"device":"all"}
//! {"verb":"status","job_id":1}
//! {"verb":"result","job_id":1}
//! {"verb":"cancel","job_id":1}
//! {"verb":"stats"}
//! {"verb":"metrics"}
//! {"verb":"metrics","scope":"service"}
//! {"verb":"watch","interval_ms":1000}
//! {"verb":"shutdown"}
//! ```
//!
//! Every response carries `"ok": true|false`; failures add an `"error"`
//! string. `watch` is the one streaming verb: instead of a single
//! response line, the server emits newline-JSON frames (metric deltas,
//! trace events, alert transitions) until the client disconnects — see
//! `DESIGN.md` §10. All other verbs get exactly one response line; see
//! `DESIGN.md` §6 for full request/response examples.

use super::job::JobSpec;
use crate::util::json::Json;

/// A parsed RPC request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Submit a job; responds with the job id and initial state.
    Submit(JobSpec),
    /// Poll a job's lifecycle state (cheap: no results attached).
    Status(u64),
    /// Fetch a finished job's per-device results (kernel sources
    /// included).
    Result(u64),
    /// Cancel a still-queued job.
    Cancel(u64),
    /// Service-wide counters: jobs, queue, cache, per-device fleet
    /// utilization.
    Stats,
    /// Full metrics registry in Prometheus text-exposition format
    /// (returned as the `prometheus` string field of the response).
    /// The optional scope restricts the exposition to the daemon's own
    /// registry (`"service"`) or the process-wide one (`"global"`);
    /// `None` merges both, the historical behaviour.
    Metrics(Option<String>),
    /// Stream live frames (metric deltas every `interval_ms`, trace
    /// events, alert transitions) until the client disconnects.
    Watch(u64),
    /// Stop the daemon (drains queued work, then exits).
    Shutdown,
}

/// Default `watch` metrics-frame cadence (ms).
pub const DEFAULT_WATCH_INTERVAL_MS: u64 = 1000;

impl Request {
    /// Parse a request object.
    pub fn from_json(v: &Json) -> Result<Request, String> {
        let verb = v
            .get("verb")
            .and_then(|x| x.as_str())
            .ok_or("request needs a 'verb' string")?;
        let job_id = || {
            v.get("job_id")
                .and_then(|x| x.as_usize())
                .map(|x| x as u64)
                .ok_or_else(|| format!("verb '{verb}' needs a numeric 'job_id'"))
        };
        match verb {
            "submit" => Ok(Request::Submit(JobSpec::from_json(v)?)),
            "status" => Ok(Request::Status(job_id()?)),
            "result" => Ok(Request::Result(job_id()?)),
            "cancel" => Ok(Request::Cancel(job_id()?)),
            "stats" => Ok(Request::Stats),
            "metrics" => {
                let scope = v.get("scope").and_then(|x| x.as_str()).map(str::to_string);
                match scope.as_deref() {
                    None | Some("service") | Some("global") => Ok(Request::Metrics(scope)),
                    Some(other) => Err(format!("bad metrics scope '{other}' (service | global)")),
                }
            }
            "watch" => {
                let interval_ms = match v.get("interval_ms") {
                    None => DEFAULT_WATCH_INTERVAL_MS,
                    Some(x) => x
                        .as_usize()
                        .map(|x| x as u64)
                        .ok_or("watch 'interval_ms' must be a non-negative number")?,
                };
                Ok(Request::Watch(interval_ms))
            }
            "shutdown" => Ok(Request::Shutdown),
            other => Err(format!(
                "unknown verb '{other}' (submit | status | result | cancel | stats | metrics | watch | shutdown)"
            )),
        }
    }

    /// Serialize to the wire object form (used by the `submit` client
    /// and tests).
    pub fn to_json(&self) -> Json {
        let with_id = |verb: &str, id: u64| {
            let mut o = Json::obj();
            o.set("verb", verb).set("job_id", id as usize);
            o
        };
        match self {
            Request::Submit(spec) => {
                let mut o = spec.to_json();
                o.set("verb", "submit");
                o
            }
            Request::Status(id) => with_id("status", *id),
            Request::Result(id) => with_id("result", *id),
            Request::Cancel(id) => with_id("cancel", *id),
            Request::Stats => {
                let mut o = Json::obj();
                o.set("verb", "stats");
                o
            }
            Request::Metrics(scope) => {
                let mut o = Json::obj();
                o.set("verb", "metrics");
                if let Some(s) = scope {
                    o.set("scope", s.as_str());
                }
                o
            }
            Request::Watch(interval_ms) => {
                let mut o = Json::obj();
                o.set("verb", "watch").set("interval_ms", *interval_ms as usize);
                o
            }
            Request::Shutdown => {
                let mut o = Json::obj();
                o.set("verb", "shutdown");
                o
            }
        }
    }
}

/// A failure response: `{"ok": false, "error": msg}`.
pub fn error_response(msg: &str) -> Json {
    let mut o = Json::obj();
    o.set("ok", false).set("error", msg);
    o
}

/// Whether a response object reports success.
pub fn response_ok(v: &Json) -> bool {
    v.get("ok").and_then(|x| x.as_bool()).unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn verbs_roundtrip() {
        let reqs = vec![
            Request::Submit(JobSpec::catalog("20_LeakyReLU", "b580")),
            Request::Status(3),
            Request::Result(4),
            Request::Cancel(5),
            Request::Stats,
            Request::Metrics(None),
            Request::Metrics(Some("service".to_string())),
            Request::Watch(250),
            Request::Shutdown,
        ];
        for req in reqs {
            let wire = req.to_json().to_string_compact();
            let back = Request::from_json(&json::parse(&wire).unwrap()).unwrap();
            assert_eq!(back, req, "{wire}");
        }
    }

    #[test]
    fn rejects_malformed_requests() {
        let cases = [
            (r#"{}"#, "verb"),
            (r#"{"verb":"warp"}"#, "unknown verb"),
            // The unknown-verb error enumerates the full verb set.
            (r#"{"verb":"warp"}"#, "metrics"),
            (r#"{"verb":"warp"}"#, "watch"),
            (r#"{"verb":"metrics","scope":"galaxy"}"#, "scope"),
            (r#"{"verb":"watch","interval_ms":"fast"}"#, "interval_ms"),
            (r#"{"verb":"status"}"#, "job_id"),
            (r#"{"verb":"cancel","job_id":"three"}"#, "job_id"),
            (r#"{"verb":"submit"}"#, "task"),
        ];
        for (wire, needle) in cases {
            let err = Request::from_json(&json::parse(wire).unwrap()).unwrap_err();
            assert!(err.contains(needle), "{wire} -> {err}");
        }
    }

    #[test]
    fn error_response_shape() {
        let e = error_response("nope");
        assert!(!response_ok(&e));
        assert_eq!(e.get("error").unwrap().as_str(), Some("nope"));
    }
}
