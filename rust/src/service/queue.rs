//! The bounded, multi-producer, priority job queue feeding the fleet.
//!
//! API handler threads push (job × device) units; fleet lanes block in
//! [`JobQueue::pop_for`] until a unit routed to *their* device is
//! available. The queue is bounded — a full queue rejects the submit
//! instead of letting the intake outrun the fleet, the same backpressure
//! discipline the [`crate::dist`] worker pipeline applies between its
//! stages. Higher priorities pop first; within a priority class units
//! pop in submission order.

use super::job::{JobPriority, JobSpec};
use std::fmt;
use std::sync::{Condvar, Mutex};

/// One queued (job × device) execution unit.
#[derive(Debug, Clone)]
pub struct QueuedUnit {
    /// The job this unit belongs to.
    pub job_id: u64,
    /// Device lane this unit is routed to.
    pub device: String,
    /// Scheduling priority (copied from the spec for cheap comparison).
    pub priority: JobPriority,
    /// Queue-assigned submission sequence number (FIFO tie-break).
    pub seq: u64,
    /// The full job spec (the lane resolves the task and runs it).
    pub spec: JobSpec,
}

/// Why a push was rejected.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueueError {
    /// The queue is at capacity; retry later or raise `--queue-capacity`.
    Full {
        /// The configured capacity that was hit.
        capacity: usize,
    },
    /// The service is shutting down and accepts no new work.
    ShuttingDown,
}

impl fmt::Display for QueueError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            QueueError::Full { capacity } => {
                write!(f, "job queue full (capacity {capacity}); retry later")
            }
            QueueError::ShuttingDown => write!(f, "service is shutting down"),
        }
    }
}

impl std::error::Error for QueueError {}

#[derive(Debug, Default)]
struct QueueState {
    units: Vec<QueuedUnit>,
    next_seq: u64,
    shutdown: bool,
}

/// The bounded multi-producer priority queue.
#[derive(Debug)]
pub struct JobQueue {
    state: Mutex<QueueState>,
    available: Condvar,
    capacity: usize,
}

impl JobQueue {
    /// Create a queue holding at most `capacity` units (min 1).
    pub fn new(capacity: usize) -> JobQueue {
        JobQueue {
            state: Mutex::new(QueueState::default()),
            available: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// The configured capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Units currently queued.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().units.len()
    }

    /// Whether no units are queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Enqueue a batch of units atomically (all-or-nothing, so a fan-out
    /// job is never half-queued). Rejects with [`QueueError::Full`] when
    /// the batch does not fit.
    pub fn push(&self, units: Vec<QueuedUnit>) -> Result<(), QueueError> {
        if units.is_empty() {
            return Ok(());
        }
        let mut state = self.state.lock().unwrap();
        if state.shutdown {
            return Err(QueueError::ShuttingDown);
        }
        if state.units.len() + units.len() > self.capacity {
            return Err(QueueError::Full {
                capacity: self.capacity,
            });
        }
        for mut unit in units {
            unit.seq = state.next_seq;
            state.next_seq += 1;
            state.units.push(unit);
        }
        self.available.notify_all();
        Ok(())
    }

    /// Block until a unit routed to `device` is available and pop the
    /// best one (highest priority, then lowest sequence number). Returns
    /// `None` once the queue has shut down and holds no more work for
    /// this device — queued units are drained before lanes exit.
    pub fn pop_for(&self, device: &str) -> Option<QueuedUnit> {
        let mut state = self.state.lock().unwrap();
        loop {
            let mut best: Option<usize> = None;
            for (i, u) in state.units.iter().enumerate() {
                if u.device != device {
                    continue;
                }
                best = match best {
                    None => Some(i),
                    Some(b) => {
                        let cur = &state.units[b];
                        if (u.priority, std::cmp::Reverse(u.seq))
                            > (cur.priority, std::cmp::Reverse(cur.seq))
                        {
                            Some(i)
                        } else {
                            Some(b)
                        }
                    }
                };
            }
            if let Some(i) = best {
                return Some(state.units.remove(i));
            }
            if state.shutdown {
                return None;
            }
            state = self.available.wait(state).unwrap();
        }
    }

    /// Remove every still-queued unit of a job; returns the device names
    /// of the removed units (empty when all units were already popped).
    pub fn cancel(&self, job_id: u64) -> Vec<String> {
        let mut state = self.state.lock().unwrap();
        let mut removed = Vec::new();
        state.units.retain(|u| {
            if u.job_id == job_id {
                removed.push(u.device.clone());
                false
            } else {
                true
            }
        });
        removed
    }

    /// Stop accepting work and wake every blocked lane so it can drain
    /// the remaining units and exit.
    pub fn shutdown(&self) {
        self.state.lock().unwrap().shutdown = true;
        self.available.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::job::JobSpec;

    fn unit(job_id: u64, device: &str, priority: JobPriority) -> QueuedUnit {
        QueuedUnit {
            job_id,
            device: device.to_string(),
            priority,
            seq: 0,
            spec: JobSpec::catalog("20_LeakyReLU", device),
        }
    }

    #[test]
    fn pops_by_priority_then_fifo() {
        let q = JobQueue::new(8);
        q.push(vec![unit(1, "b580", JobPriority::Normal)]).unwrap();
        q.push(vec![unit(2, "b580", JobPriority::Low)]).unwrap();
        q.push(vec![unit(3, "b580", JobPriority::High)]).unwrap();
        q.push(vec![unit(4, "b580", JobPriority::Normal)]).unwrap();
        let order: Vec<u64> = (0..4).map(|_| q.pop_for("b580").unwrap().job_id).collect();
        assert_eq!(order, vec![3, 1, 4, 2]);
    }

    #[test]
    fn routes_by_device() {
        let q = JobQueue::new(8);
        q.push(vec![unit(1, "lnl", JobPriority::Normal)]).unwrap();
        q.push(vec![unit(2, "b580", JobPriority::Normal)]).unwrap();
        assert_eq!(q.pop_for("b580").unwrap().job_id, 2);
        assert_eq!(q.pop_for("lnl").unwrap().job_id, 1);
        assert!(q.is_empty());
    }

    #[test]
    fn bounded_and_all_or_nothing() {
        let q = JobQueue::new(2);
        q.push(vec![unit(1, "b580", JobPriority::Normal)]).unwrap();
        // A 2-unit fan-out does not fit next to the queued unit: rejected
        // atomically, nothing partially enqueued.
        let err = q
            .push(vec![
                unit(2, "lnl", JobPriority::Normal),
                unit(2, "b580", JobPriority::Normal),
            ])
            .unwrap_err();
        assert_eq!(err, QueueError::Full { capacity: 2 });
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn cancel_removes_only_queued_units_of_the_job() {
        let q = JobQueue::new(8);
        q.push(vec![
            unit(1, "lnl", JobPriority::Normal),
            unit(1, "b580", JobPriority::Normal),
        ])
        .unwrap();
        q.push(vec![unit(2, "b580", JobPriority::Normal)]).unwrap();
        let popped = q.pop_for("lnl").unwrap(); // job 1's lnl unit is now running
        assert_eq!(popped.job_id, 1);
        let removed = q.cancel(1);
        assert_eq!(removed, vec!["b580".to_string()]);
        assert_eq!(q.pop_for("b580").unwrap().job_id, 2, "job 2 unaffected");
    }

    #[test]
    fn shutdown_unblocks_poppers_and_rejects_pushes() {
        let q = std::sync::Arc::new(JobQueue::new(4));
        let q2 = std::sync::Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop_for("b580"));
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.shutdown();
        assert!(h.join().unwrap().is_none());
        assert_eq!(
            q.push(vec![unit(1, "b580", JobPriority::Normal)]).unwrap_err(),
            QueueError::ShuttingDown
        );
    }

    #[test]
    fn shutdown_drains_remaining_units() {
        let q = JobQueue::new(4);
        q.push(vec![unit(1, "b580", JobPriority::Normal)]).unwrap();
        q.shutdown();
        assert_eq!(q.pop_for("b580").unwrap().job_id, 1);
        assert!(q.pop_for("b580").is_none());
    }
}
