//! The bounded, multi-producer, priority job queue feeding the fleet.
//!
//! API handler threads push (job × device) units; fleet lanes block in
//! [`JobQueue::pop_for`] until a unit routed to *their* device is
//! available. The queue is bounded — a full queue rejects the submit
//! instead of letting the intake outrun the fleet, the same backpressure
//! discipline the [`crate::dist`] worker pipeline applies between its
//! stages. Higher priorities pop first; within a priority class units
//! pop in submission order.

use super::job::{JobPriority, JobSpec};
use std::fmt;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// One queued (job × device) execution unit.
#[derive(Debug, Clone)]
pub struct QueuedUnit {
    /// The job this unit belongs to.
    pub job_id: u64,
    /// Device lane this unit is routed to.
    pub device: String,
    /// Scheduling priority (copied from the spec for cheap comparison).
    pub priority: JobPriority,
    /// Queue-assigned submission sequence number (FIFO tie-break).
    pub seq: u64,
    /// The full job spec (the lane resolves the task and runs it).
    pub spec: JobSpec,
    /// Attempts already spent on this unit (0 = never dispatched; a
    /// retry re-enters the queue with the count advanced).
    pub attempt: u32,
    /// Earliest pop time — retry backoff lives *in* the queue, so
    /// delayed units still count against depth and stay cancellable.
    pub not_before: Option<Instant>,
}

impl QueuedUnit {
    /// A fresh, immediately-eligible unit (attempt 0, no delay).
    pub fn fresh(job_id: u64, device: &str, spec: JobSpec) -> QueuedUnit {
        QueuedUnit {
            job_id,
            device: device.to_string(),
            priority: spec.priority,
            seq: 0,
            spec,
            attempt: 0,
            not_before: None,
        }
    }

    /// Whether the unit may pop at `now`.
    fn due(&self, now: Instant) -> bool {
        self.not_before.map(|t| t <= now).unwrap_or(true)
    }
}

/// Why a push was rejected.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueueError {
    /// The queue is at capacity; retry later or raise `--queue-capacity`.
    Full {
        /// The configured capacity that was hit.
        capacity: usize,
    },
    /// The service is shutting down and accepts no new work.
    ShuttingDown,
}

impl fmt::Display for QueueError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            QueueError::Full { capacity } => {
                write!(f, "job queue full (capacity {capacity}); retry later")
            }
            QueueError::ShuttingDown => write!(f, "service is shutting down"),
        }
    }
}

impl std::error::Error for QueueError {}

#[derive(Debug, Default)]
struct QueueState {
    units: Vec<QueuedUnit>,
    next_seq: u64,
    shutdown: bool,
}

/// The bounded multi-producer priority queue.
#[derive(Debug)]
pub struct JobQueue {
    state: Mutex<QueueState>,
    available: Condvar,
    capacity: usize,
}

impl JobQueue {
    /// Create a queue holding at most `capacity` units (min 1).
    pub fn new(capacity: usize) -> JobQueue {
        JobQueue {
            state: Mutex::new(QueueState::default()),
            available: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// The configured capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Units currently queued.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().units.len()
    }

    /// Whether no units are queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Enqueue a batch of units atomically (all-or-nothing, so a fan-out
    /// job is never half-queued). Rejects with [`QueueError::Full`] when
    /// the batch does not fit.
    pub fn push(&self, units: Vec<QueuedUnit>) -> Result<(), QueueError> {
        if units.is_empty() {
            return Ok(());
        }
        let mut state = self.state.lock().unwrap();
        if state.shutdown {
            return Err(QueueError::ShuttingDown);
        }
        if state.units.len() + units.len() > self.capacity {
            return Err(QueueError::Full {
                capacity: self.capacity,
            });
        }
        for mut unit in units {
            unit.seq = state.next_seq;
            state.next_seq += 1;
            state.units.push(unit);
        }
        self.available.notify_all();
        Ok(())
    }

    /// Re-admit a unit that already held queue capacity (a retry after a
    /// transient failure, or a unit rerouted off a quarantined lane).
    /// Bypasses the capacity check — re-admission never grows the total
    /// unit count past what [`JobQueue::push`] admitted — and is allowed
    /// during shutdown so the drain can finish a unit's retry budget.
    pub fn requeue(&self, mut unit: QueuedUnit) {
        let mut state = self.state.lock().unwrap();
        unit.seq = state.next_seq;
        state.next_seq += 1;
        state.units.push(unit);
        self.available.notify_all();
    }

    /// The best currently-due unit for `device`: highest priority, then
    /// lowest sequence number; units whose `not_before` is in the future
    /// are skipped. Returns the index and, when nothing is due, the
    /// earliest `not_before` among this device's delayed units.
    fn best_for(
        state: &QueueState,
        device: &str,
        now: Instant,
    ) -> (Option<usize>, Option<Instant>) {
        let mut best: Option<usize> = None;
        let mut earliest: Option<Instant> = None;
        for (i, u) in state.units.iter().enumerate() {
            if u.device != device {
                continue;
            }
            if !u.due(now) {
                let due = u.not_before.unwrap();
                earliest = Some(earliest.map_or(due, |e| e.min(due)));
                continue;
            }
            best = match best {
                None => Some(i),
                Some(b) => {
                    let cur = &state.units[b];
                    if (u.priority, std::cmp::Reverse(u.seq))
                        > (cur.priority, std::cmp::Reverse(cur.seq))
                    {
                        Some(i)
                    } else {
                        Some(b)
                    }
                }
            };
        }
        (best, earliest)
    }

    /// Block until a unit routed to `device` is due and pop the best one
    /// (highest priority, then lowest sequence number; backoff-delayed
    /// units wait out their `not_before`). Returns `None` once the queue
    /// has shut down and holds no more work for this device — queued
    /// units (including pending retries) are drained before lanes exit.
    pub fn pop_for(&self, device: &str) -> Option<QueuedUnit> {
        let mut state = self.state.lock().unwrap();
        loop {
            let now = Instant::now();
            let (best, earliest) = Self::best_for(&state, device, now);
            if let Some(i) = best {
                return Some(state.units.remove(i));
            }
            match earliest {
                Some(due) => {
                    // Only delayed units remain: sleep until the first
                    // comes due (a push wakes us earlier). Shutdown does
                    // not shortcut this — pending retries drain too.
                    let wait = due.saturating_duration_since(now);
                    let (s, _) = self.available.wait_timeout(state, wait).unwrap();
                    state = s;
                }
                None => {
                    if state.shutdown {
                        return None;
                    }
                    state = self.available.wait(state).unwrap();
                }
            }
        }
    }

    /// Non-blocking [`JobQueue::pop_for`]: the best due unit, or `None`
    /// right away. Half-open lanes probe with this so they can re-check
    /// their breaker between polls.
    pub fn try_pop_for(&self, device: &str) -> Option<QueuedUnit> {
        let mut state = self.state.lock().unwrap();
        let (best, _) = Self::best_for(&state, device, Instant::now());
        best.map(|i| state.units.remove(i))
    }

    /// Whether any unit (due or delayed) is queued for `device`.
    pub fn has_units_for(&self, device: &str) -> bool {
        self.state.lock().unwrap().units.iter().any(|u| u.device == device)
    }

    /// Whether [`JobQueue::shutdown`] was called.
    pub fn is_shutdown(&self) -> bool {
        self.state.lock().unwrap().shutdown
    }

    /// Remove and return every *fresh* (attempt 0) unit routed to
    /// `device`. An open lane sheds its queued backlog with this —
    /// fresh units get rerouted or degraded, while units already
    /// mid-retry on this lane stay queued for the half-open probe (their
    /// failure history belongs to this lane's quarantine budget).
    pub fn drain_fresh_for(&self, device: &str) -> Vec<QueuedUnit> {
        let mut state = self.state.lock().unwrap();
        let mut shed = Vec::new();
        state.units.retain(|u| {
            if u.device == device && u.attempt == 0 {
                shed.push(u.clone());
                false
            } else {
                true
            }
        });
        shed
    }

    /// Remove every still-queued unit of a job; returns the device names
    /// of the removed units (empty when all units were already popped).
    pub fn cancel(&self, job_id: u64) -> Vec<String> {
        let mut state = self.state.lock().unwrap();
        let mut removed = Vec::new();
        state.units.retain(|u| {
            if u.job_id == job_id {
                removed.push(u.device.clone());
                false
            } else {
                true
            }
        });
        removed
    }

    /// Stop accepting work and wake every blocked lane so it can drain
    /// the remaining units and exit.
    pub fn shutdown(&self) {
        self.state.lock().unwrap().shutdown = true;
        self.available.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::job::JobSpec;

    fn unit(job_id: u64, device: &str, priority: JobPriority) -> QueuedUnit {
        QueuedUnit {
            job_id,
            device: device.to_string(),
            priority,
            seq: 0,
            spec: JobSpec::catalog("20_LeakyReLU", device),
            attempt: 0,
            not_before: None,
        }
    }

    #[test]
    fn pops_by_priority_then_fifo() {
        let q = JobQueue::new(8);
        q.push(vec![unit(1, "b580", JobPriority::Normal)]).unwrap();
        q.push(vec![unit(2, "b580", JobPriority::Low)]).unwrap();
        q.push(vec![unit(3, "b580", JobPriority::High)]).unwrap();
        q.push(vec![unit(4, "b580", JobPriority::Normal)]).unwrap();
        let order: Vec<u64> = (0..4).map(|_| q.pop_for("b580").unwrap().job_id).collect();
        assert_eq!(order, vec![3, 1, 4, 2]);
    }

    #[test]
    fn routes_by_device() {
        let q = JobQueue::new(8);
        q.push(vec![unit(1, "lnl", JobPriority::Normal)]).unwrap();
        q.push(vec![unit(2, "b580", JobPriority::Normal)]).unwrap();
        assert_eq!(q.pop_for("b580").unwrap().job_id, 2);
        assert_eq!(q.pop_for("lnl").unwrap().job_id, 1);
        assert!(q.is_empty());
    }

    #[test]
    fn bounded_and_all_or_nothing() {
        let q = JobQueue::new(2);
        q.push(vec![unit(1, "b580", JobPriority::Normal)]).unwrap();
        // A 2-unit fan-out does not fit next to the queued unit: rejected
        // atomically, nothing partially enqueued.
        let err = q
            .push(vec![
                unit(2, "lnl", JobPriority::Normal),
                unit(2, "b580", JobPriority::Normal),
            ])
            .unwrap_err();
        assert_eq!(err, QueueError::Full { capacity: 2 });
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn cancel_removes_only_queued_units_of_the_job() {
        let q = JobQueue::new(8);
        q.push(vec![
            unit(1, "lnl", JobPriority::Normal),
            unit(1, "b580", JobPriority::Normal),
        ])
        .unwrap();
        q.push(vec![unit(2, "b580", JobPriority::Normal)]).unwrap();
        let popped = q.pop_for("lnl").unwrap(); // job 1's lnl unit is now running
        assert_eq!(popped.job_id, 1);
        let removed = q.cancel(1);
        assert_eq!(removed, vec!["b580".to_string()]);
        assert_eq!(q.pop_for("b580").unwrap().job_id, 2, "job 2 unaffected");
    }

    #[test]
    fn shutdown_unblocks_poppers_and_rejects_pushes() {
        let q = std::sync::Arc::new(JobQueue::new(4));
        let q2 = std::sync::Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop_for("b580"));
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.shutdown();
        assert!(h.join().unwrap().is_none());
        assert_eq!(
            q.push(vec![unit(1, "b580", JobPriority::Normal)]).unwrap_err(),
            QueueError::ShuttingDown
        );
    }

    #[test]
    fn shutdown_drains_remaining_units() {
        let q = JobQueue::new(4);
        q.push(vec![unit(1, "b580", JobPriority::Normal)]).unwrap();
        q.shutdown();
        assert_eq!(q.pop_for("b580").unwrap().job_id, 1);
        assert!(q.pop_for("b580").is_none());
    }

    #[test]
    fn delayed_units_wait_out_their_backoff_even_through_shutdown() {
        let q = JobQueue::new(4);
        let mut u = unit(1, "b580", JobPriority::Normal);
        u.attempt = 1;
        u.not_before = Some(std::time::Instant::now() + std::time::Duration::from_millis(40));
        q.requeue(u);
        assert!(q.try_pop_for("b580").is_none(), "not due yet");
        assert!(q.has_units_for("b580"), "delayed unit still counts as queued");
        q.shutdown();
        // pop_for drains the pending retry instead of dropping it.
        let t = std::time::Instant::now();
        let popped = q.pop_for("b580").expect("drains the delayed retry");
        assert_eq!(popped.attempt, 1);
        assert!(t.elapsed() >= std::time::Duration::from_millis(25), "waited for the backoff");
        assert!(q.pop_for("b580").is_none(), "then exits");
    }

    #[test]
    fn requeue_bypasses_capacity_and_try_pop_respects_priority() {
        let q = JobQueue::new(1);
        q.push(vec![unit(1, "b580", JobPriority::Normal)]).unwrap();
        assert!(q.push(vec![unit(2, "b580", JobPriority::Normal)]).is_err(), "full");
        let mut retry = unit(3, "b580", JobPriority::High);
        retry.attempt = 2;
        q.requeue(retry);
        assert_eq!(q.len(), 2, "re-admission is exempt from the capacity check");
        assert_eq!(q.try_pop_for("b580").unwrap().job_id, 3, "priority still wins");
        assert_eq!(q.try_pop_for("b580").unwrap().job_id, 1);
        assert!(q.try_pop_for("b580").is_none());
    }

    #[test]
    fn drain_fresh_sheds_only_never_attempted_units_of_the_device() {
        let q = JobQueue::new(8);
        q.push(vec![unit(1, "b580", JobPriority::Normal)]).unwrap();
        q.push(vec![unit(2, "lnl", JobPriority::Normal)]).unwrap();
        let mut retrying = unit(3, "b580", JobPriority::Normal);
        retrying.attempt = 1;
        q.requeue(retrying);
        let shed = q.drain_fresh_for("b580");
        assert_eq!(shed.len(), 1);
        assert_eq!(shed[0].job_id, 1);
        assert!(q.has_units_for("lnl"), "other devices untouched");
        assert_eq!(
            q.try_pop_for("b580").unwrap().job_id,
            3,
            "mid-retry unit stays for the half-open probe"
        );
    }
}
