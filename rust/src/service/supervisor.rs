//! Lane supervision primitives: circuit breaker, cooperative cancel
//! tokens, deadline tracking and retry backoff (DESIGN.md §11).
//!
//! The state machine each lane runs (one [`CircuitBreaker`] per lane):
//!
//! ```text
//!            trip_threshold consecutive
//!            transient failures
//!   CLOSED ────────────────────────────▶ OPEN
//!     ▲  ▲                                │ lane sheds its queued
//!     │  │ probe                          │ units (reroute fan-in,
//!     │  │ succeeds                       │ degrade fan-out) and
//!     │  │                                │ waits out the cooldown
//!     │  │         cooldown elapsed       ▼
//!     │  └──────────────────────────── HALF-OPEN
//!     │                                   │ one probe unit runs
//!     └──────────── probe fails ──────────┘ (failure re-opens)
//! ```
//!
//! Failures that count toward the trip threshold are *infrastructure*
//! failures (injected faults, deadlines, panics) — a bad job spec says
//! nothing about lane health and neither counts nor resets.

use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Slice length for cooperative sleeps and supervisor scans: short
/// enough that deadlines and cancellations land promptly, long enough
/// to cost nothing.
const TICK: Duration = Duration::from_millis(5);

/// Per-lane fault-tolerance knobs, carried in `ServiceConfig`.
#[derive(Debug, Clone, PartialEq)]
pub struct GuardConfig {
    /// Transient-failure retries per unit before it is quarantined
    /// (total attempts = `max_retries + 1`).
    pub max_retries: u32,
    /// Wall-clock deadline per unit attempt; `None` disables the
    /// deadline supervisor.
    pub unit_deadline: Option<Duration>,
    /// Consecutive transient failures that trip a lane's breaker open.
    pub trip_threshold: u32,
    /// Base retry backoff; attempt `n` waits `base * 2^(n-1)` ± 25%
    /// deterministic jitter.
    pub retry_backoff: Duration,
    /// How long an open lane waits before probing half-open.
    pub lane_cooldown: Duration,
}

impl Default for GuardConfig {
    fn default() -> GuardConfig {
        GuardConfig {
            max_retries: 2,
            unit_deadline: None,
            trip_threshold: 3,
            retry_backoff: Duration::from_millis(100),
            lane_cooldown: Duration::from_millis(1000),
        }
    }
}

/// Circuit-breaker position of one lane.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LaneState {
    /// Healthy: the lane pulls and runs units normally.
    Closed,
    /// Probing: one unit runs; its outcome closes or re-opens the lane.
    HalfOpen,
    /// Quarantined: the lane sheds queued units and waits out the
    /// cooldown.
    Open,
}

impl LaneState {
    /// Stable wire/gauge encoding (`kf_lane_state`): closed=0,
    /// half-open=1, open=2.
    pub fn as_u8(self) -> u8 {
        match self {
            LaneState::Closed => 0,
            LaneState::HalfOpen => 1,
            LaneState::Open => 2,
        }
    }

    /// Decode the gauge encoding (unknown values read as closed).
    pub fn from_u8(v: u8) -> LaneState {
        match v {
            1 => LaneState::HalfOpen,
            2 => LaneState::Open,
            _ => LaneState::Closed,
        }
    }

    /// Human/state-file name.
    pub fn name(self) -> &'static str {
        match self {
            LaneState::Closed => "closed",
            LaneState::HalfOpen => "half_open",
            LaneState::Open => "open",
        }
    }
}

/// The shareable mirror of a lane's breaker state: the lane thread
/// writes it on every transition; stats, metrics and peer lanes
/// (choosing reroute targets) read it lock-free.
#[derive(Debug, Clone, Default)]
pub struct LaneHealth(Arc<AtomicU8>);

impl LaneHealth {
    /// A new mirror, starting closed.
    pub fn new() -> LaneHealth {
        LaneHealth::default()
    }

    /// Current state.
    pub fn get(&self) -> LaneState {
        LaneState::from_u8(self.0.load(Ordering::Relaxed))
    }

    /// Publish a transition.
    pub fn set(&self, state: LaneState) {
        self.0.store(state.as_u8(), Ordering::Relaxed);
    }

    /// Whether the lane can accept rerouted work (anything not open).
    pub fn accepts_reroutes(&self) -> bool {
        self.get() != LaneState::Open
    }
}

/// The closed→open→half-open breaker guarding one lane. Owned by the
/// lane thread; every transition is mirrored into a [`LaneHealth`] by
/// the caller. Methods take `now` so tests drive a fake clock.
#[derive(Debug)]
pub struct CircuitBreaker {
    threshold: u32,
    cooldown: Duration,
    consecutive: u32,
    state: LaneState,
    opened_at: Option<Instant>,
}

impl CircuitBreaker {
    /// A closed breaker tripping after `threshold` consecutive
    /// transient failures and cooling down for `cooldown` once open.
    pub fn new(threshold: u32, cooldown: Duration) -> CircuitBreaker {
        CircuitBreaker {
            threshold: threshold.max(1),
            cooldown,
            consecutive: 0,
            state: LaneState::Closed,
            opened_at: None,
        }
    }

    /// Current position.
    pub fn state(&self) -> LaneState {
        self.state
    }

    /// A unit succeeded: the streak resets and a half-open probe
    /// success closes the lane.
    pub fn on_success(&mut self) {
        self.consecutive = 0;
        self.state = LaneState::Closed;
        self.opened_at = None;
    }

    /// A transient (infrastructure) failure. Returns `true` when this
    /// failure transitions the lane to open — either the streak reached
    /// the threshold, or a half-open probe failed.
    pub fn on_failure(&mut self, now: Instant) -> bool {
        self.consecutive = self.consecutive.saturating_add(1);
        let trip = match self.state {
            LaneState::Open => false,
            LaneState::HalfOpen => true,
            LaneState::Closed => self.consecutive >= self.threshold,
        };
        if trip {
            self.state = LaneState::Open;
            self.opened_at = Some(now);
        }
        trip
    }

    /// While open: transition to half-open once the cooldown has
    /// elapsed. Returns `true` on the transition.
    pub fn try_half_open(&mut self, now: Instant) -> bool {
        if self.state != LaneState::Open {
            return false;
        }
        let ready = self
            .opened_at
            .map(|t| now.duration_since(t) >= self.cooldown)
            .unwrap_or(true);
        if ready {
            self.state = LaneState::HalfOpen;
        }
        ready
    }

    /// Drain mode (service shutdown): force the breaker closed so the
    /// lane can finish its remaining queued units — every unit still
    /// reaches a terminal verdict through the retry/quarantine budget.
    pub fn force_close(&mut self) {
        self.consecutive = 0;
        self.state = LaneState::Closed;
        self.opened_at = None;
    }
}

/// A shareable cooperative-cancellation flag for one unit attempt: the
/// deadline supervisor sets it; the lane's engine loop, worker pool and
/// injected hangs poll it.
#[derive(Debug, Clone, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    /// A fresh, uncancelled token.
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Request cancellation (idempotent).
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Relaxed);
    }

    /// Whether cancellation was requested.
    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }

    /// The raw flag, for engine/pool hooks that poll an `AtomicBool`.
    pub fn flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.0)
    }

    /// Sleep up to `dur`, waking early on cancellation. Returns `true`
    /// when the full duration elapsed uncancelled, `false` when the
    /// sleep was cut short — injected hangs use this so a deadline
    /// never has to wait out the hang.
    pub fn sleep_cooperative(&self, dur: Duration) -> bool {
        let deadline = Instant::now() + dur;
        loop {
            if self.is_cancelled() {
                return false;
            }
            let now = Instant::now();
            if now >= deadline {
                return true;
            }
            std::thread::sleep(TICK.min(deadline - now));
        }
    }
}

/// One registered in-flight unit attempt.
#[derive(Debug)]
struct InFlightEntry {
    token: CancelToken,
    deadline: Instant,
    fired: bool,
}

/// The fleet-wide table of in-flight unit attempts with deadlines. Lane
/// threads register an attempt before running it and deregister after;
/// the deadline supervisor thread sweeps the table and cancels overdue
/// tokens. Units without a deadline are never registered.
#[derive(Debug, Default)]
pub struct InFlight {
    entries: Mutex<Vec<((u64, String), InFlightEntry)>>,
}

impl InFlight {
    /// An empty table.
    pub fn new() -> InFlight {
        InFlight::default()
    }

    /// Register an attempt of `(job_id, device)` due at `deadline`.
    pub fn begin(&self, job_id: u64, device: &str, deadline: Instant, token: CancelToken) {
        self.entries.lock().unwrap().push((
            (job_id, device.to_string()),
            InFlightEntry {
                token,
                deadline,
                fired: false,
            },
        ));
    }

    /// Deregister an attempt (the lane finished it, however it ended).
    pub fn end(&self, job_id: u64, device: &str) {
        let mut entries = self.entries.lock().unwrap();
        if let Some(i) = entries
            .iter()
            .position(|(k, _)| k.0 == job_id && k.1 == device)
        {
            entries.remove(i);
        }
    }

    /// Cancel every overdue attempt, returning the `(job, device)`
    /// pairs whose deadline fired on *this* sweep (each fires once).
    pub fn expire(&self, now: Instant) -> Vec<(u64, String)> {
        let mut fired = Vec::new();
        let mut entries = self.entries.lock().unwrap();
        for (key, entry) in entries.iter_mut() {
            if !entry.fired && now >= entry.deadline {
                entry.fired = true;
                entry.token.cancel();
                fired.push(key.clone());
            }
        }
        fired
    }

    /// Attempts currently registered (for stats/tests).
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    /// Whether no attempt is in flight.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The delay before retry number `attempt` (1-based) of a unit:
/// exponential in the attempt with deterministic ±25% jitter derived
/// from `(job_id, device, attempt)`, so lanes desynchronize their
/// retries without a random source.
pub fn backoff_delay(base: Duration, attempt: u32, job_id: u64, device: &str) -> Duration {
    let exp = base.saturating_mul(1u32 << (attempt.saturating_sub(1)).min(6));
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in job_id
        .to_le_bytes()
        .iter()
        .chain(device.as_bytes())
        .chain(&attempt.to_le_bytes())
    {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    // Jitter factor in [0.75, 1.25).
    let jitter = 0.75 + (h >> 11) as f64 / (1u64 << 53) as f64 * 0.5;
    Duration::from_secs_f64(exp.as_secs_f64() * jitter)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breaker_walks_the_full_state_machine() {
        let t0 = Instant::now();
        let mut b = CircuitBreaker::new(2, Duration::from_millis(100));
        assert_eq!(b.state(), LaneState::Closed);

        assert!(!b.on_failure(t0), "below threshold: still closed");
        b.on_success();
        assert!(!b.on_failure(t0), "success reset the streak");
        assert!(b.on_failure(t0), "second consecutive failure trips");
        assert_eq!(b.state(), LaneState::Open);
        assert!(!b.on_failure(t0), "failures while open do not re-trip");

        assert!(!b.try_half_open(t0 + Duration::from_millis(50)), "cooldown pending");
        assert_eq!(b.state(), LaneState::Open);
        assert!(b.try_half_open(t0 + Duration::from_millis(150)));
        assert_eq!(b.state(), LaneState::HalfOpen);

        assert!(b.on_failure(t0), "failed probe re-opens immediately");
        assert_eq!(b.state(), LaneState::Open);
        assert!(b.try_half_open(t0 + Duration::from_secs(1)));
        b.on_success();
        assert_eq!(b.state(), LaneState::Closed, "successful probe closes");

        b.on_failure(t0);
        b.force_close();
        assert_eq!(b.state(), LaneState::Closed, "drain mode force-closes");
    }

    #[test]
    fn lane_health_mirrors_and_gates_reroutes() {
        let h = LaneHealth::new();
        assert_eq!(h.get(), LaneState::Closed);
        assert!(h.accepts_reroutes());
        h.set(LaneState::Open);
        assert_eq!(h.get(), LaneState::Open);
        assert!(!h.accepts_reroutes());
        h.set(LaneState::HalfOpen);
        assert!(h.accepts_reroutes());
        assert_eq!(LaneState::from_u8(LaneState::Open.as_u8()), LaneState::Open);
        assert_eq!(LaneState::Open.name(), "open");
    }

    #[test]
    fn cancel_token_cuts_a_cooperative_sleep_short() {
        let token = CancelToken::new();
        assert!(token.sleep_cooperative(Duration::from_millis(1)), "uncancelled: full sleep");
        let peer = token.clone();
        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            peer.cancel();
        });
        let t = Instant::now();
        assert!(
            !token.sleep_cooperative(Duration::from_secs(30)),
            "cancellation aborts the hang"
        );
        assert!(t.elapsed() < Duration::from_secs(10), "woke long before the full duration");
        assert!(token.is_cancelled());
        handle.join().unwrap();
    }

    #[test]
    fn inflight_expire_fires_each_deadline_once() {
        let table = InFlight::new();
        let now = Instant::now();
        let a = CancelToken::new();
        let b = CancelToken::new();
        table.begin(1, "b580", now + Duration::from_millis(10), a.clone());
        table.begin(2, "lnl", now + Duration::from_secs(60), b.clone());
        assert_eq!(table.len(), 2);

        assert!(table.expire(now).is_empty(), "nothing due yet");
        let fired = table.expire(now + Duration::from_millis(20));
        assert_eq!(fired, vec![(1, "b580".to_string())]);
        assert!(a.is_cancelled() && !b.is_cancelled());
        assert!(
            table.expire(now + Duration::from_millis(30)).is_empty(),
            "a deadline fires exactly once"
        );

        table.end(1, "b580");
        table.end(2, "lnl");
        assert!(table.is_empty());
    }

    #[test]
    fn backoff_is_exponential_bounded_and_deterministic() {
        let base = Duration::from_millis(100);
        let d1 = backoff_delay(base, 1, 7, "b580");
        let d2 = backoff_delay(base, 2, 7, "b580");
        let d3 = backoff_delay(base, 3, 7, "b580");
        // Each step stays inside its ±25% jitter envelope.
        let envelope = |d: Duration, ms: f64| {
            let v = d.as_secs_f64() * 1000.0;
            assert!((ms * 0.75..ms * 1.25).contains(&v), "{v} vs {ms}");
        };
        envelope(d1, 100.0);
        envelope(d2, 200.0);
        envelope(d3, 400.0);
        assert_eq!(d1, backoff_delay(base, 1, 7, "b580"), "deterministic");
        assert_ne!(
            backoff_delay(base, 1, 7, "b580"),
            backoff_delay(base, 1, 8, "b580"),
            "different jobs desynchronize"
        );
        // The exponent saturates instead of overflowing.
        let huge = backoff_delay(base, 60, 7, "b580");
        assert!(huge <= Duration::from_secs(9), "capped at base * 2^6 * 1.25");
    }
}
