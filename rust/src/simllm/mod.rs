//! Simulated LLM code models.
//!
//! Substitute for the paper's frontier-LLM inference backend (DESIGN.md
//! §2). The framework interacts with code models only through
//! [`CodeModel::generate`], which consumes an assembled [`Prompt`] and
//! returns candidate kernels. [`SimLlm`] is a prompt-sensitive stochastic
//! mutator over [`KernelGenome`]s:
//!
//! * gradient-derived **mutation hints** in the prompt bias which feature
//!   is mutated (followed with profile-dependent probability);
//! * **strategy/pitfall tokens** injected by the meta-prompter unlock or
//!   bias specific transformations and reduce matching defect rates —
//!   guidance flows through the prompt text, closing the §3.5 loop;
//! * the **last kernel's console log** enables error-repair behaviour
//!   (syntax errors fixed, SLM overflows shrunk, missing barriers added);
//! * per-model **capability profiles** set defect rates, hint adherence,
//!   exploration temperature and parameter insight, emulating the paper's
//!   model ensembles (o3-mini vs GPT-4.1/5-mini vs Sonnet-4.5 vs
//!   GPT-OSS-20B).

pub mod mutate;
pub mod profile;

pub use mutate::SimLlm;
pub use profile::CapabilityProfile;

use crate::ir::KernelGenome;
use crate::prompts::Prompt;

/// The code-model interface (the paper's "LLM inference backend").
pub trait CodeModel {
    fn name(&self) -> &str;
    /// Generate `n` candidate kernels for the prompt.
    fn generate(&mut self, prompt: &Prompt, n: usize) -> Vec<KernelGenome>;
}

/// A weighted ensemble of models with optional first-iteration override
/// (App. B.4: "we chose to prompt a powerful language model in the first
/// iteration … after the first iteration, we use an ensemble of GPT 5
/// mini and GPT 4.1 (equal weights)").
pub struct Ensemble {
    pub members: Vec<(SimLlm, f64)>,
    pub first_iteration: Option<SimLlm>,
    rng: crate::util::rng::Rng,
}

impl Ensemble {
    pub fn new(members: Vec<(SimLlm, f64)>, first_iteration: Option<SimLlm>, seed: u64) -> Ensemble {
        assert!(!members.is_empty());
        Ensemble {
            members,
            first_iteration,
            rng: crate::util::rng::Rng::with_stream(seed, 0xe5b1e),
        }
    }

    /// Convenience: single-model ensemble.
    pub fn single(model: SimLlm, seed: u64) -> Ensemble {
        Ensemble::new(vec![(model, 1.0)], None, seed)
    }

    /// Generate candidates, routing to the first-iteration model when
    /// `iteration == 0` and to a weighted member otherwise.
    pub fn generate(&mut self, prompt: &Prompt, n: usize, iteration: usize) -> Vec<KernelGenome> {
        if iteration == 0 {
            if let Some(first) = &mut self.first_iteration {
                return first.generate(prompt, n);
            }
        }
        let weights: Vec<f64> = self.members.iter().map(|(_, w)| *w).collect();
        let idx = self.rng.choose_weighted(&weights);
        self.members[idx].0.generate(prompt, n)
    }

    pub fn model_names(&self) -> Vec<String> {
        self.members
            .iter()
            .map(|(m, _)| m.name().to_string())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prompts::{EvolvablePrompt, PromptBuilder};
    use crate::tasks::catalog;

    #[test]
    fn ensemble_first_iteration_override() {
        let strong = SimLlm::new(CapabilityProfile::sonnet_4_5(), 1);
        let weak = SimLlm::new(CapabilityProfile::gpt_oss_20b(), 2);
        let mut e = Ensemble::new(vec![(weak, 1.0)], Some(strong), 3);
        let task = catalog::find_task("20_LeakyReLU").unwrap();
        let p = PromptBuilder::default().build(&task, &EvolvablePrompt::default(), None, None, None, &[], "hw");
        // Iteration 0 uses the strong model; candidates should rarely be
        // defective.
        let c0 = e.generate(&p, 16, 0);
        assert_eq!(c0.len(), 16);
        let defects0: usize = c0.iter().map(|g| g.defects.len()).sum();
        let c5 = e.generate(&p, 16, 5);
        let defects5: usize = c5.iter().map(|g| g.defects.len()).sum();
        assert!(defects0 < defects5, "strong {defects0} !< weak {defects5}");
        assert!(c0.iter().all(|g| g.produced_by == "sonnet-4.5"));
        assert!(c5.iter().all(|g| g.produced_by == "gpt-oss-20b"));
    }
}
