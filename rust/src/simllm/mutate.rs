//! The prompt-sensitive genome mutation engine behind [`SimLlm`].

use super::profile::CapabilityProfile;
use super::CodeModel;
use crate::ir::{
    AlgoStructure, Defect, DefectKind, KernelGenome, MemoryPattern, SyncStrategy, TemplateSpec,
};
use crate::prompts::Prompt;
use crate::util::rng::Rng;

/// Directed transformations the model can apply, mirroring the mutation
/// hints the gradient layer can emit (§3.3) and the strategy tokens the
/// meta-prompter can inject (§3.5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mutation {
    Vectorize,
    TileSlm,
    RegisterBlock,
    SimplifyMemory,
    Fuse,
    Reformulate,
    NovelAlgorithm,
    SimplifyAlgo,
    BarrierSync,
    SubGroupSync,
    GlobalSync,
    RelaxSync,
    ParamJitter,
    TogglePad,
    TogglePrefetch,
}

const SENSIBLE_WG: [u32; 5] = [32, 64, 128, 256, 512];
const SENSIBLE_TILE: [u32; 4] = [8, 16, 32, 64];
const SENSIBLE_VEC: [u32; 4] = [1, 2, 4, 8];

/// The simulated LLM.
pub struct SimLlm {
    pub profile: CapabilityProfile,
    rng: Rng,
}

impl SimLlm {
    pub fn new(profile: CapabilityProfile, seed: u64) -> SimLlm {
        SimLlm {
            profile,
            rng: Rng::with_stream(seed, 0x11a),
        }
    }

    // ---- prompt reading ----------------------------------------------------

    /// Map a natural-language mutation hint to a transformation by
    /// keyword matching — the inverse of `gradient::hints_for`.
    fn parse_hint(hint: &str) -> Option<Mutation> {
        let h = hint.to_lowercase();
        if h.contains("coalesc") || h.contains("vectorized loads") || h.contains("vector loads") {
            Some(Mutation::Vectorize)
        } else if h.contains("shared memory tiling") || h.contains("local memory tiling") {
            Some(Mutation::TileSlm)
        } else if h.contains("register blocking") || h.contains("prefetch") {
            Some(Mutation::RegisterBlock)
        } else if h.contains("simpler access pattern") {
            Some(Mutation::SimplifyMemory)
        } else if h.contains("fuse") {
            Some(Mutation::Fuse)
        } else if h.contains("reformulate") || h.contains("online") || h.contains("streaming") {
            Some(Mutation::Reformulate)
        } else if h.contains("asymptotically") || h.contains("decomposition") {
            Some(Mutation::NovelAlgorithm)
        } else if h.contains("simpler fused form") || h.contains("regressing") {
            Some(Mutation::SimplifyAlgo)
        } else if h.contains("sub-group") || h.contains("subgroup") || h.contains("shuffles") {
            Some(Mutation::SubGroupSync)
        } else if h.contains("work-group barriers") {
            Some(Mutation::BarrierSync)
        } else if h.contains("atomic") && !h.contains("reduce barrier") {
            Some(Mutation::GlobalSync)
        } else if h.contains("synchronization overhead") || h.contains("reduce barrier") {
            Some(Mutation::RelaxSync)
        } else {
            None
        }
    }

    /// Transformations favoured by the strategy tokens currently present
    /// in the evolvable regions. Plain-language strategy lines (the seed
    /// prompt's kernel-specific guidance) are also keyword-matched — the
    /// model reads the strategy text itself, not just meta-evolved tags,
    /// which is what separates KernelFoundry's prompt from the generic
    /// baselines' (§5.2).
    fn strategy_mutations(prompt: &Prompt) -> Vec<Mutation> {
        let s = &prompt.evolvable.strategies;
        let mut out = Vec::new();
        let lower = s.to_lowercase();
        if lower.contains("vectorized loads") || lower.contains("sycl::vec") {
            out.push(Mutation::Vectorize);
        }
        if lower.contains("memory tiling") || lower.contains("local memory tiling") {
            out.push(Mutation::TileSlm);
        }
        if lower.contains("register blocking") {
            out.push(Mutation::RegisterBlock);
        }
        if lower.contains("sub-group reductions") || lower.contains("reduce_over_group") {
            out.push(Mutation::SubGroupSync);
        }
        if lower.contains("single pass") || lower.contains("fuse") {
            out.push(Mutation::Fuse);
        }
        if s.contains("[strategy:vectorize]") {
            out.push(Mutation::Vectorize);
        }
        if s.contains("[strategy:tiling]") {
            out.push(Mutation::TileSlm);
        }
        if s.contains("[strategy:reg-block]") {
            out.push(Mutation::RegisterBlock);
        }
        if s.contains("[strategy:fuse-all]") {
            out.push(Mutation::Fuse);
        }
        if s.contains("[strategy:online-reformulation]") {
            out.push(Mutation::Reformulate);
        }
        if s.contains("[strategy:subgroup]") {
            out.push(Mutation::SubGroupSync);
        }
        if s.contains("[strategy:slm-pad]") {
            out.push(Mutation::TogglePad);
        }
        out
    }

    // ---- generation ----------------------------------------------------------

    fn fresh_genome(&mut self, prompt: &Prompt) -> KernelGenome {
        let mut g = KernelGenome::direct_translation(&prompt.task_id);
        // Competent models start from a coalesced translation.
        if self.rng.bool(self.profile.param_insight) {
            g.mem = MemoryPattern::Coalesced;
            g.params.vec_width = *self.rng.choose(&[2, 4, 8]);
        }
        if self.rng.bool(self.profile.param_insight) {
            g.params.wg_x = *self.rng.choose(&SENSIBLE_WG);
        } else {
            g.params.wg_x = 1 << self.rng.range(3, 9) as u32;
        }
        g
    }

    fn apply_mutation(&mut self, g: &mut KernelGenome, m: Mutation, prompt: &Prompt) {
        match m {
            Mutation::Vectorize => {
                if g.mem == MemoryPattern::Scalar {
                    g.mem = MemoryPattern::Coalesced;
                }
                g.params.vec_width = if self.rng.bool(self.profile.param_insight) {
                    *self.rng.choose(&[4, 8])
                } else {
                    *self.rng.choose(&SENSIBLE_VEC)
                };
            }
            Mutation::TileSlm => {
                g.mem = MemoryPattern::TiledSlm;
                let t = *self.rng.choose(&SENSIBLE_TILE);
                g.params.tile_m = t;
                g.params.tile_n = t;
                g.params.tile_k = *self.rng.choose(&[8u32, 16, 32]);
            }
            Mutation::RegisterBlock => {
                if g.uses_slm() {
                    g.mem = MemoryPattern::MultiLevel;
                    g.params.reg_block = *self.rng.choose(&[2u32, 4]);
                    g.params.prefetch = self.rng.bool(0.6);
                } else {
                    // Can't register-block without a tile hierarchy; tile first.
                    self.apply_mutation(g, Mutation::TileSlm, prompt);
                }
            }
            Mutation::SimplifyMemory => {
                g.mem = MemoryPattern::from_level(g.mem.level().saturating_sub(1));
            }
            Mutation::Fuse => {
                if prompt.n_ops > 1 {
                    if g.algo == AlgoStructure::DirectTranslation {
                        g.algo = AlgoStructure::Fused;
                    }
                    // Extend fusion coverage.
                    g.fused_ops = (g.fused_ops + 1 + self.rng.below(prompt.n_ops) as u32)
                        .min(prompt.n_ops as u32);
                }
            }
            Mutation::Reformulate => {
                if prompt.supports_reformulation {
                    let boosted = prompt
                        .evolvable
                        .strategies
                        .contains("[strategy:online-reformulation]")
                        || prompt
                            .user_instructions
                            .as_deref()
                            .map(|u| {
                                let u = u.to_lowercase();
                                u.contains("online") || u.contains("exp2") || u.contains("flash")
                            })
                            .unwrap_or(false);
                    let p_success = if boosted {
                        0.9
                    } else {
                        self.profile.reformulation_skill
                    };
                    if self.rng.bool(p_success) {
                        g.algo = AlgoStructure::Reformulated;
                        g.fused_ops = prompt.n_ops as u32;
                    } else if self.rng.bool(0.5) {
                        // Botched reformulation: numeric bug.
                        g.defects.push(Defect { kind: DefectKind::NumericBug, severity: 0.2 });
                        g.algo = AlgoStructure::Reformulated;
                    }
                }
            }
            Mutation::NovelAlgorithm => {
                if self.rng.bool(self.profile.reformulation_skill * 0.3) {
                    g.algo = AlgoStructure::Novel;
                } else {
                    g.defects.push(Defect { kind: DefectKind::NumericBug, severity: 0.3 });
                    g.algo = AlgoStructure::Novel;
                }
            }
            Mutation::SimplifyAlgo => {
                g.algo = AlgoStructure::from_level(g.algo.level().saturating_sub(1));
            }
            Mutation::BarrierSync => g.sync = SyncStrategy::WorkGroupBarrier,
            Mutation::SubGroupSync => g.sync = SyncStrategy::SubGroup,
            Mutation::GlobalSync => g.sync = SyncStrategy::Global,
            Mutation::RelaxSync => {
                g.sync = SyncStrategy::from_level(g.sync.level().saturating_sub(1));
            }
            Mutation::ParamJitter => match self.rng.below(5) {
                0 => g.params.wg_x = *self.rng.choose(&SENSIBLE_WG),
                1 => {
                    let t = *self.rng.choose(&SENSIBLE_TILE);
                    g.params.tile_m = t;
                    g.params.tile_n = t;
                }
                2 => g.params.vec_width = *self.rng.choose(&SENSIBLE_VEC),
                3 => g.params.unroll = *self.rng.choose(&[1u32, 2, 4, 8]),
                _ => g.params.reg_block = *self.rng.choose(&[1u32, 2, 4]),
            },
            Mutation::TogglePad => g.params.slm_pad = true,
            Mutation::TogglePrefetch => g.params.prefetch = !g.params.prefetch,
        }
    }

    /// Inject defects per profile rates, attenuated by pitfall guidance
    /// and console-log feedback (the "LLM read the error" channel).
    fn inject_defects(&mut self, g: &mut KernelGenome, prompt: &Prompt) {
        let pitfalls = &prompt.evolvable.pitfalls;
        let log = &prompt.last_log.to_lowercase();
        let fix = self.profile.fix_from_log;

        let mut syntax = self.profile.syntax_error_rate;
        if pitfalls.contains("[pitfall:complete-code]") {
            syntax *= 0.5;
        }
        if log.contains("unbalanced") || log.contains("expected '}'") {
            syntax *= 1.0 - fix;
        }

        let mut numeric = self.profile.numeric_bug_rate;
        if log.contains("numeric mismatch") {
            numeric *= 1.0 - fix;
        }

        let mut race = self.profile.race_rate;
        if pitfalls.contains("[pitfall:barrier]") {
            race *= 0.15;
        }
        if log.contains("race") || log.contains("nondeterministic") {
            race *= 1.0 - fix;
            if g.uses_slm() && g.sync == SyncStrategy::None && self.rng.bool(fix) {
                g.sync = SyncStrategy::WorkGroupBarrier; // the model adds the barrier
            }
        }

        let mut oob = self.profile.oob_rate;
        if pitfalls.contains("[pitfall:bounds]") {
            oob *= 0.2;
        }
        if log.contains("illegal memory access") || log.contains("page fault") {
            oob *= 1.0 - fix;
        }

        if self.rng.bool(syntax) {
            g.defects.push(Defect { kind: DefectKind::SyntaxError, severity: 1.0 });
        }
        if self.rng.bool(numeric) {
            g.defects.push(Defect {
                kind: DefectKind::NumericBug,
                severity: 0.02 + 0.4 * self.rng.f64(),
            });
        }
        if g.uses_slm() && self.rng.bool(race) {
            g.defects.push(Defect { kind: DefectKind::MissingBarrier, severity: 1.0 });
        }
        if self.rng.bool(oob) {
            g.defects.push(Defect { kind: DefectKind::OutOfBounds, severity: 1.0 });
        }
    }

    /// Deterministic per-(model, task) roll for systematic task
    /// misunderstanding (App. G failure mode): when it fires, nearly
    /// every kernel this model writes for the task carries the same
    /// numeric misimplementation, so sampling never converges.
    fn misunderstands_task(&self, task_id: &str) -> bool {
        let mut h = 0xcbf29ce484222325u64;
        for b in self.profile.name.bytes().chain(task_id.bytes()) {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        // splitmix64 finalizer: FNV's raw bits are poorly mixed for
        // short strings.
        h ^= h >> 30;
        h = h.wrapping_mul(0xbf58476d1ce4e5b9);
        h ^= h >> 27;
        h = h.wrapping_mul(0x94d049bb133111eb);
        h ^= h >> 31;
        let u = (h >> 11) as f64 / (1u64 << 53) as f64;
        u < self.profile.systematic_failure_rate
    }

    /// Shrink tiles in response to an SLM-overflow compile error.
    fn repair_from_log(&mut self, g: &mut KernelGenome, prompt: &Prompt) {
        if prompt.last_log.contains("SLM footprint")
            && self.rng.bool(self.profile.fix_from_log)
        {
            g.params.tile_m = (g.params.tile_m / 2).max(8);
            g.params.tile_n = (g.params.tile_n / 2).max(8);
            g.params.tile_k = (g.params.tile_k / 2).max(8);
        }
        if prompt.last_log.contains("work-group size")
            && self.rng.bool(self.profile.fix_from_log)
        {
            g.params.wg_x = g.params.wg_x.min(256);
            g.params.wg_y = 1;
        }
    }

    /// Produce the App. E.2 templated kernel: wrap the parent's params in
    /// a dispatch grid. Insight determines how well-chosen the options are.
    fn make_template(&mut self, g: &mut KernelGenome) {
        let around = |v: u32| -> Vec<u32> {
            let mut opts = vec![v.max(8) / 2, v.max(8), v.max(8) * 2];
            opts.dedup();
            opts
        };
        let tiles = if self.rng.bool(self.profile.param_insight) {
            around(g.params.tile_m)
                .into_iter()
                .map(|t| (t, t, g.params.tile_k))
                .collect()
        } else {
            vec![(g.params.tile_m, g.params.tile_n, g.params.tile_k)]
        };
        g.template = Some(TemplateSpec {
            wg_options: around(g.params.wg_x).into_iter().map(|w| (w, g.params.wg_y)).collect(),
            tile_options: tiles,
            vec_options: vec![g.params.vec_width, 4, 8],
        });
    }
}

impl CodeModel for SimLlm {
    fn name(&self) -> &str {
        self.profile.name
    }

    fn generate(&mut self, prompt: &Prompt, n: usize) -> Vec<KernelGenome> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let mut g = match &prompt.parent {
                Some(parent) => {
                    let mut g = parent.clone();
                    g.defects.clear(); // each generation is fresh code
                    g.parent_id = Some(parent.id);
                    g
                }
                None => self.fresh_genome(prompt),
            };
            g.produced_by = self.profile.name.to_string();
            g.template = None;

            if prompt.templated_request {
                self.make_template(&mut g);
                self.inject_defects(&mut g, prompt);
                out.push(g);
                continue;
            }

            // 1. Follow gradient hints.
            let mut directed = false;
            for hint in &prompt.hints {
                if let Some(m) = Self::parse_hint(hint) {
                    if self.rng.bool(self.profile.hint_follow) {
                        self.apply_mutation(&mut g, m, prompt);
                        directed = true;
                    }
                }
            }
            // 2. Follow meta-evolved strategy guidance.
            for m in Self::strategy_mutations(prompt) {
                if self.rng.bool(self.profile.hint_follow * 0.5) {
                    self.apply_mutation(&mut g, m, prompt);
                    directed = true;
                }
            }
            // 3. Undirected exploration (always at least one mutation if
            //    nothing was directed). The mutation repertoire depends
            //    on the prompt: kernel-specific strategy guidance (the
            //    "[memory]/[algorithm]/[parallelism]" sections of the
            //    KernelFoundry prompt) puts the deep optimizations on the
            //    menu; a generic prompt (the OpenEvolve / repeated-
            //    prompting baselines) leaves the model mostly fiddling
            //    with parameters and shallow transforms — the paper's
            //    "lacks kernel-specific optimization strategies".
            if !directed || self.rng.bool(self.profile.explore_temp) {
                let guided = prompt.evolvable.strategies.contains("[memory]")
                    || prompt.evolvable.strategies.contains("[algorithm]");
                let m = if guided {
                    *self.rng.choose(&[
                        Mutation::Vectorize,
                        Mutation::TileSlm,
                        Mutation::RegisterBlock,
                        Mutation::SimplifyMemory,
                        Mutation::Fuse,
                        Mutation::Fuse, // fusion is the most natural guided move
                        Mutation::Reformulate,
                        Mutation::NovelAlgorithm,
                        Mutation::SimplifyAlgo,
                        Mutation::BarrierSync,
                        Mutation::SubGroupSync,
                        Mutation::GlobalSync,
                        Mutation::RelaxSync,
                        Mutation::ParamJitter,
                        Mutation::ParamJitter,
                        Mutation::TogglePad,
                        Mutation::TogglePrefetch,
                    ])
                } else {
                    *self.rng.choose(&[
                        Mutation::Vectorize,
                        Mutation::TileSlm,
                        Mutation::SimplifyMemory,
                        Mutation::Fuse,
                        Mutation::SimplifyAlgo,
                        Mutation::BarrierSync,
                        Mutation::GlobalSync,
                        Mutation::RelaxSync,
                        Mutation::ParamJitter,
                        Mutation::ParamJitter,
                        Mutation::ParamJitter,
                        Mutation::TogglePrefetch,
                    ])
                };
                self.apply_mutation(&mut g, m, prompt);
            }

            self.repair_from_log(&mut g, prompt);
            self.inject_defects(&mut g, prompt);
            // A systematic misunderstanding is persistent: no amount of
            // resampling fixes it ("even after 40 iterations", App. G).
            if self.misunderstands_task(&prompt.task_id) {
                g.defects.push(Defect {
                    kind: DefectKind::NumericBug,
                    severity: 0.15 + 0.3 * self.rng.f64(),
                });
            }
            out.push(g);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prompts::{EvolvablePrompt, PromptBuilder};
    use crate::tasks::catalog;
    use crate::util::textdiff;

    fn prompt_for(task_id: &str) -> Prompt {
        let task = catalog::find_task(task_id).unwrap();
        PromptBuilder::default().build(&task, &EvolvablePrompt::default(), None, None, None, &[], "hw")
    }

    #[test]
    fn generates_requested_count() {
        let mut m = SimLlm::new(CapabilityProfile::gpt_4_1(), 1);
        let p = prompt_for("99_Matmul_GELU_Softmax");
        assert_eq!(m.generate(&p, 8).len(), 8);
    }

    #[test]
    fn hints_steer_mutations() {
        let mut m = SimLlm::new(CapabilityProfile::sonnet_4_5(), 2);
        let task = catalog::find_task("99_Matmul_GELU_Softmax").unwrap();
        let mut p = PromptBuilder::default().build(&task, &EvolvablePrompt::default(), None, None, None, &[], "hw");
        p.hints = vec!["Consider adding shared memory tiling to improve data reuse.".to_string()];
        let kids = m.generate(&p, 64);
        let tiled = kids.iter().filter(|g| g.uses_slm()).count();
        // hint_follow = 0.88: most children should be tiled.
        assert!(tiled > 40, "only {tiled}/64 followed the tiling hint");
    }

    #[test]
    fn strategy_token_unlocks_reformulation() {
        let task = catalog::find_task("99_Matmul_GELU_Softmax").unwrap();
        let base = EvolvablePrompt::default();
        // Without the token, a weak model almost never reformulates
        // correctly.
        let p_plain = PromptBuilder::default().build(&task, &base, None, None, None, &[], "hw");
        let mut weak = SimLlm::new(CapabilityProfile::gpt_4_1(), 3);
        let plain_reform = weak
            .generate(&p_plain, 128)
            .iter()
            .filter(|g| g.algo == AlgoStructure::Reformulated && g.defects.is_empty())
            .count();
        // With the meta-evolved token, reformulation is frequent and clean.
        let diff = "<<<<<<< SEARCH\n- [parallelism] Use sub-group reductions instead of serializing through one work-item.\n=======\n- [parallelism] Use sub-group reductions instead of serializing through one work-item.\n- [algorithm] [strategy:online-reformulation] Use a streaming online softmax with exp2 rescaling.\n>>>>>>> REPLACE\n";
        let evolved = base.apply_diff(&textdiff::parse_hunks(diff).unwrap()).unwrap();
        let p_tok = PromptBuilder::default().build(&task, &evolved, None, None, None, &[], "hw");
        let mut weak2 = SimLlm::new(CapabilityProfile::gpt_4_1(), 3);
        let tok_reform = weak2
            .generate(&p_tok, 128)
            .iter()
            .filter(|g| g.algo == AlgoStructure::Reformulated && g.defects.is_empty())
            .count();
        assert!(
            tok_reform > plain_reform * 2,
            "token {tok_reform} vs plain {plain_reform}"
        );
    }

    #[test]
    fn barrier_pitfall_reduces_races() {
        let task = catalog::find_task("7_Matmul_with_small_K_dimension_").unwrap();
        let mut parent = KernelGenome::direct_translation(&task.id);
        parent.mem = MemoryPattern::TiledSlm;
        let mk_prompt = |pitfalls: &str| {
            let mut ev = EvolvablePrompt::default();
            ev.pitfalls = pitfalls.to_string();
            let mut p = PromptBuilder::default().build(&task, &ev, None, None, None, &[], "hw");
            p.parent = Some(parent.clone());
            p
        };
        let mut weak = SimLlm::new(CapabilityProfile::gpt_oss_20b(), 5);
        let races_plain = weak
            .generate(&mk_prompt("be careful"), 200)
            .iter()
            .filter(|g| g.has_defect(DefectKind::MissingBarrier))
            .count();
        let mut weak2 = SimLlm::new(CapabilityProfile::gpt_oss_20b(), 5);
        let races_guided = weak2
            .generate(&mk_prompt("[pitfall:barrier] sync SLM"), 200)
            .iter()
            .filter(|g| g.has_defect(DefectKind::MissingBarrier))
            .count();
        assert!(
            (races_guided as f64) < races_plain as f64 * 0.5,
            "guided {races_guided} vs plain {races_plain}"
        );
    }

    #[test]
    fn log_feedback_repairs_slm_overflow() {
        let task = catalog::find_task("7_Matmul_with_small_K_dimension_").unwrap();
        let mut parent = KernelGenome::direct_translation(&task.id);
        parent.mem = MemoryPattern::TiledSlm;
        parent.params.tile_m = 256;
        parent.params.tile_n = 256;
        let mut p = PromptBuilder::default().build(&task, &EvolvablePrompt::default(), None, None, None, &[], "hw");
        p.parent = Some(parent);
        p.last_log = "kernel.cpp: error: SLM footprint 524288 B exceeds device budget 131072 B".to_string();
        let mut m = SimLlm::new(CapabilityProfile::gpt_o3(), 6);
        let kids = m.generate(&p, 64);
        let shrunk = kids.iter().filter(|g| g.params.tile_m < 256).count();
        assert!(shrunk > 48, "only {shrunk}/64 shrank tiles after overflow error");
    }

    #[test]
    fn templated_request_produces_dispatch_options() {
        let task = catalog::find_task("99_Matmul_GELU_Softmax").unwrap();
        let best = KernelGenome::direct_translation(&task.id);
        let rec = crate::eval::EvalRecord {
            source: String::new(),
            genome: best,
            outcome: crate::eval::EvalOutcome::Correct,
            coords: [2, 1, 1],
            correctness: None,
            time_ms: 1.0,
            baseline_ms: 2.0,
            speedup: 2.0,
            fitness: 1.0,
            log: String::new(),
            best_params: None,
            param_sweep: Vec::new(),
        };
        let p = PromptBuilder::default().build_templated(&task, &rec, "hw");
        let mut m = SimLlm::new(CapabilityProfile::gpt_o3(), 7);
        let kids = m.generate(&p, 4);
        assert!(kids.iter().all(|g| g.template.is_some()));
        assert!(kids[0].template.as_ref().unwrap().n_instantiations() > 1);
    }

    #[test]
    fn children_inherit_parent_lineage() {
        let task = catalog::find_task("20_LeakyReLU").unwrap();
        let mut parent = KernelGenome::direct_translation(&task.id);
        parent.id = 42;
        let mut p = PromptBuilder::default().build(&task, &EvolvablePrompt::default(), None, None, None, &[], "hw");
        p.parent = Some(parent);
        let mut m = SimLlm::new(CapabilityProfile::gpt_4_1(), 8);
        for g in m.generate(&p, 8) {
            assert_eq!(g.parent_id, Some(42));
            assert_eq!(g.produced_by, "gpt-4.1");
        }
    }
}
