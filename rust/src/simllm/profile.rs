//! Capability profiles for the simulated code models.
//!
//! Rates are calibrated so the reproduction shows the paper's qualitative
//! model ordering: frontier reasoning models (o3, Sonnet-4.5) rarely emit
//! broken kernels and follow guidance well; mid-tier models are decent;
//! GPT-OSS-20B "led to failure in generating correct kernels in 7 out of
//! 20 cases" (App. G) — i.e. a high persistent defect floor.

/// Stochastic capability description of one model.
#[derive(Debug, Clone)]
pub struct CapabilityProfile {
    pub name: &'static str,
    /// Probability a generation is syntactically broken (truncated, bad
    /// template).
    pub syntax_error_rate: f64,
    /// Probability of a numeric bug (bad index math, wrong epsilon).
    pub numeric_bug_rate: f64,
    /// Probability of omitting a required barrier in SLM kernels.
    pub race_rate: f64,
    /// Probability of a missing bounds guard.
    pub oob_rate: f64,
    /// Probability of following a given mutation hint / strategy token.
    pub hint_follow: f64,
    /// Exploration temperature: probability of applying a second, random
    /// mutation on top of the directed one.
    pub explore_temp: f64,
    /// Skill at algorithmic reformulation (P of succeeding when trying
    /// to move d_algo to level 2+ unprompted).
    pub reformulation_skill: f64,
    /// Quality of hardware-parameter guesses: P of picking a sensible
    /// power-of-two near typical optima instead of an arbitrary value.
    pub param_insight: f64,
    /// How strongly console-log feedback suppresses repeat defects.
    pub fix_from_log: f64,
    /// Probability that the model systematically misunderstands a given
    /// task (deterministic per (model, task)): all its kernels for that
    /// task carry the same numeric misimplementation, so no amount of
    /// sampling converges — the App. G failure mode ("the model's lower
    /// capabilities led to failure in generating correct kernels in 7
    /// out of 20 cases, even after 40 iterations").
    pub systematic_failure_rate: f64,
}

impl CapabilityProfile {
    pub fn o3_mini() -> CapabilityProfile {
        CapabilityProfile {
            name: "o3-mini",
            syntax_error_rate: 0.06,
            numeric_bug_rate: 0.10,
            race_rate: 0.10,
            oob_rate: 0.05,
            hint_follow: 0.70,
            explore_temp: 0.35,
            reformulation_skill: 0.45,
            param_insight: 0.60,
            fix_from_log: 0.75,
            systematic_failure_rate: 0.0,
        }
    }

    pub fn gpt_o3() -> CapabilityProfile {
        CapabilityProfile {
            name: "gpt-o3",
            syntax_error_rate: 0.03,
            numeric_bug_rate: 0.06,
            race_rate: 0.06,
            oob_rate: 0.03,
            hint_follow: 0.85,
            explore_temp: 0.30,
            reformulation_skill: 0.65,
            param_insight: 0.75,
            fix_from_log: 0.90,
            systematic_failure_rate: 0.0,
        }
    }

    pub fn gpt_o4_mini() -> CapabilityProfile {
        CapabilityProfile {
            name: "gpt-o4-mini",
            syntax_error_rate: 0.05,
            numeric_bug_rate: 0.09,
            race_rate: 0.08,
            oob_rate: 0.04,
            hint_follow: 0.75,
            explore_temp: 0.35,
            reformulation_skill: 0.50,
            param_insight: 0.65,
            fix_from_log: 0.80,
            systematic_failure_rate: 0.01,
        }
    }

    pub fn gpt_4_1() -> CapabilityProfile {
        CapabilityProfile {
            name: "gpt-4.1",
            syntax_error_rate: 0.05,
            numeric_bug_rate: 0.09,
            race_rate: 0.09,
            oob_rate: 0.05,
            hint_follow: 0.72,
            explore_temp: 0.40,
            reformulation_skill: 0.40,
            param_insight: 0.60,
            fix_from_log: 0.80,
            systematic_failure_rate: 0.01,
        }
    }

    pub fn gpt_5_mini() -> CapabilityProfile {
        CapabilityProfile {
            name: "gpt-5-mini",
            syntax_error_rate: 0.04,
            numeric_bug_rate: 0.08,
            race_rate: 0.07,
            oob_rate: 0.04,
            hint_follow: 0.78,
            explore_temp: 0.38,
            reformulation_skill: 0.50,
            param_insight: 0.68,
            fix_from_log: 0.85,
            systematic_failure_rate: 0.01,
        }
    }

    pub fn sonnet_4_5() -> CapabilityProfile {
        CapabilityProfile {
            name: "sonnet-4.5",
            syntax_error_rate: 0.02,
            numeric_bug_rate: 0.05,
            race_rate: 0.05,
            oob_rate: 0.02,
            hint_follow: 0.88,
            explore_temp: 0.32,
            reformulation_skill: 0.70,
            param_insight: 0.78,
            fix_from_log: 0.92,
            systematic_failure_rate: 0.0,
        }
    }

    /// App. G reproducibility model: weak enough that ~1/3 of tasks never
    /// converge to a correct kernel.
    pub fn gpt_oss_20b() -> CapabilityProfile {
        CapabilityProfile {
            name: "gpt-oss-20b",
            syntax_error_rate: 0.30,
            numeric_bug_rate: 0.35,
            race_rate: 0.30,
            oob_rate: 0.15,
            hint_follow: 0.35,
            explore_temp: 0.55,
            reformulation_skill: 0.10,
            param_insight: 0.25,
            fix_from_log: 0.30,
            systematic_failure_rate: 0.35,
        }
    }

    pub fn by_name(name: &str) -> Option<CapabilityProfile> {
        match name {
            "o3-mini" => Some(Self::o3_mini()),
            "gpt-o3" | "o3" => Some(Self::gpt_o3()),
            "gpt-o4-mini" | "o4-mini" => Some(Self::gpt_o4_mini()),
            "gpt-4.1" => Some(Self::gpt_4_1()),
            "gpt-5-mini" => Some(Self::gpt_5_mini()),
            "sonnet-4.5" => Some(Self::sonnet_4_5()),
            "gpt-oss-20b" => Some(Self::gpt_oss_20b()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_covers_all() {
        for n in [
            "o3-mini",
            "gpt-o3",
            "gpt-o4-mini",
            "gpt-4.1",
            "gpt-5-mini",
            "sonnet-4.5",
            "gpt-oss-20b",
        ] {
            assert_eq!(CapabilityProfile::by_name(n).unwrap().name, n);
        }
        assert!(CapabilityProfile::by_name("gpt-7").is_none());
    }

    #[test]
    fn capability_ordering() {
        let strong = CapabilityProfile::sonnet_4_5();
        let weak = CapabilityProfile::gpt_oss_20b();
        assert!(strong.syntax_error_rate < weak.syntax_error_rate);
        assert!(strong.hint_follow > weak.hint_follow);
        assert!(strong.reformulation_skill > weak.reformulation_skill);
    }
}
