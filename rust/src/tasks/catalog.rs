//! The benchmark task suites (§4, App. D, App. F).
//!
//! Task names follow the paper's per-task appendix tables exactly:
//! Table 8 (representative KernelBench L1 + L2 sets), Table 7
//! (robust-kbench), Table 4 (oneDNN ops). Tensor shapes are
//! KernelBench-typical sizes.

use super::{FilterFlags, OpSpec, Suite, TaskSpec};

const MB: u64 = 1 << 20;

fn ew(elems: u64, flops: u64, sfu: u64, name: &'static str) -> OpSpec {
    OpSpec::Elementwise { elems, flops_per_elem: flops, sfu_per_elem: sfu, name }
}

/// The 20-task representative KernelBench L1 subset (Table 8, level 1).
pub fn kernelbench_l1() -> Vec<TaskSpec> {
    let mk = |id: &str, ops: Vec<OpSpec>| TaskSpec::new(id, Suite::KernelBenchL1, ops);
    vec![
        mk("20_LeakyReLU", vec![ew(16 * MB, 2, 0, "leaky_relu")]),
        mk("21_Sigmoid", vec![ew(16 * MB, 3, 1, "sigmoid")]),
        mk("25_Swish", vec![ew(16 * MB, 4, 1, "swish")]),
        mk("30_Softsign", vec![ew(16 * MB, 3, 1, "softsign")]),
        mk(
            "33_BatchNorm",
            vec![OpSpec::Norm { elems: 16 * MB, groups: 64, name: "batchnorm" }],
        ),
        mk(
            "44_Average_Pooling_1D",
            vec![OpSpec::Pool { elems_out: 4 * MB, win: 4, name: "avgpool1d" }],
        ),
        mk(
            "48_Mean_reduction_over_a_dimension",
            vec![OpSpec::Reduction { elems: 16 * MB, outputs: 64 * 256, name: "mean_reduce" }],
        ),
        mk(
            "4_Matrix_vector_multiplication_",
            vec![OpSpec::Matmul { m: 256, n: 1, k: 131072 }],
        ),
        mk(
            "53_Min_reduction_over_a_dimension",
            vec![OpSpec::Reduction { elems: 16 * MB, outputs: 64 * 256, name: "min_reduce" }],
        ),
        mk("5_Matrix_scalar_multiplication", vec![ew(16 * MB, 1, 0, "scalar_mul")]),
        mk(
            "64_conv_transposed_1D",
            vec![OpSpec::ConvTranspose2d { n: 16, c_in: 32, c_out: 64, h: 1, w: 16384, kh: 1, kw: 3 }],
        ),
        mk(
            "67_conv_standard_1D",
            vec![OpSpec::Conv2d { n: 16, c_in: 32, c_out: 64, h: 1, w: 16384, kh: 1, kw: 3 }],
        ),
        mk(
            "72_ConvTranspose3d_BatchNorm_AvgPool_AvgPool",
            vec![
                OpSpec::ConvTranspose3d { n: 4, c_in: 16, c_out: 32, d: 16, h: 32, w: 32, k: 3 },
                OpSpec::Norm { elems: 4 * 32 * 16 * 32 * 32, groups: 32, name: "batchnorm" },
                OpSpec::Pool { elems_out: (4 * 32 * 16 * 32 * 32) / 8, win: 8, name: "avgpool" },
                OpSpec::Pool { elems_out: (4 * 32 * 16 * 32 * 32) / 64, win: 8, name: "avgpool" },
            ],
        ),
        mk(
            "76_conv_standard_1D_dilated_strided",
            vec![OpSpec::Conv2d { n: 16, c_in: 32, c_out: 64, h: 1, w: 8192, kh: 1, kw: 3 }],
        ),
        mk(
            "7_Matmul_with_small_K_dimension_",
            vec![OpSpec::Matmul { m: 16384, n: 16384, k: 32 }],
        ),
        mk(
            "82_conv_depthwise_2D_square_input_square_kernel",
            vec![OpSpec::Conv2d { n: 16, c_in: 64, c_out: 64, h: 256, w: 256, kh: 3, kw: 3 }],
        ),
        mk(
            "86_conv_depthwise_separable_2D",
            vec![
                OpSpec::Conv2d { n: 16, c_in: 64, c_out: 64, h: 128, w: 128, kh: 3, kw: 3 },
                OpSpec::Conv2d { n: 16, c_in: 64, c_out: 128, h: 128, w: 128, kh: 1, kw: 1 },
            ],
        ),
        mk(
            "87_conv_pointwise_2D",
            vec![OpSpec::Conv2d { n: 16, c_in: 64, c_out: 128, h: 256, w: 256, kh: 1, kw: 1 }],
        ),
        mk("89_cumsum", vec![OpSpec::Cumsum { rows: 4096, cols: 4096 }]),
        mk(
            "99_TripletMarginLoss",
            vec![
                ew(3 * 4 * MB, 4, 0, "pairwise_dist"),
                OpSpec::Reduction { elems: 4 * MB, outputs: 128, name: "loss_reduce" },
            ],
        ),
    ]
}

/// The 20-task representative KernelBench L2 subset (Tables 8–10).
pub fn kernelbench_l2() -> Vec<TaskSpec> {
    let mk = |id: &str, ops: Vec<OpSpec>| TaskSpec::new(id, Suite::KernelBenchL2, ops);
    let conv = |c_in: u64, c_out: u64, hw: u64, k: u64| OpSpec::Conv2d {
        n: 16, c_in, c_out, h: hw, w: hw, kh: k, kw: k,
    };
    let act = |elems: u64, name: &'static str| match name {
        "relu" => ew(elems, 1, 0, "relu"),
        "tanh" | "sigmoid" | "gelu" | "mish" | "swish" | "hardswish" | "hardtanh" | "softmax_act" => {
            ew(elems, 4, 1, name)
        }
        _ => ew(elems, 2, 0, name),
    };
    vec![
        mk(
            "16_ConvTranspose2d_Mish_Add_Hardtanh_Scaling",
            vec![
                OpSpec::ConvTranspose2d { n: 16, c_in: 32, c_out: 64, h: 64, w: 64, kh: 4, kw: 4 },
                act(16 * 64 * 64 * 64, "mish"),
                ew(16 * 64 * 64 * 64, 1, 0, "add"),
                act(16 * 64 * 64 * 64, "hardtanh"),
                ew(16 * 64 * 64 * 64, 1, 0, "scale"),
            ],
        ),
        mk(
            "17_Conv2d_InstanceNorm_Divide",
            vec![
                conv(32, 64, 64, 3),
                OpSpec::Norm { elems: 16 * 64 * 62 * 62, groups: 16 * 64, name: "instancenorm" },
                ew(16 * 64 * 62 * 62, 1, 1, "divide"),
            ],
        ),
        mk(
            "1_Conv2D_ReLU_BiasAdd",
            vec![conv(3, 16, 128, 3), act(16 * 16 * 126 * 126, "relu"), ew(16 * 16 * 126 * 126, 1, 0, "bias_add")],
        ),
        mk(
            "21_Conv2d_Add_Scale_Sigmoid_GroupNorm",
            vec![
                conv(32, 64, 64, 3),
                ew(16 * 64 * 62 * 62, 1, 0, "add"),
                ew(16 * 64 * 62 * 62, 1, 0, "scale"),
                act(16 * 64 * 62 * 62, "sigmoid"),
                OpSpec::Norm { elems: 16 * 64 * 62 * 62, groups: 16 * 8, name: "groupnorm" },
            ],
        ),
        mk(
            "24_Conv3d_Min_Softmax",
            vec![
                OpSpec::Conv3d { n: 4, c_in: 16, c_out: 32, d: 16, h: 32, w: 32, k: 3 },
                OpSpec::Reduction { elems: 4 * 32 * 14 * 30 * 30, outputs: 4 * 32 * 30 * 30, name: "min_reduce" },
                OpSpec::Softmax { rows: 4 * 30 * 30, cols: 32 },
            ],
        ),
        mk(
            "32_Conv2d_Scaling_Min",
            vec![
                conv(32, 64, 64, 3),
                ew(16 * 64 * 62 * 62, 1, 0, "scale"),
                OpSpec::Reduction { elems: 16 * 64 * 62 * 62, outputs: 16 * 62 * 62, name: "min_reduce" },
            ],
        ),
        mk(
            "35_Conv2d_Subtract_HardSwish_MaxPool_Mish",
            vec![
                conv(32, 64, 64, 3),
                ew(16 * 64 * 62 * 62, 1, 0, "subtract"),
                act(16 * 64 * 62 * 62, "hardswish"),
                OpSpec::Pool { elems_out: 16 * 64 * 31 * 31, win: 4, name: "maxpool" },
                act(16 * 64 * 31 * 31, "mish"),
            ],
        ),
        mk(
            "37_Matmul_Swish_Sum_GroupNorm",
            vec![
                OpSpec::Matmul { m: 2048, n: 1024, k: 512 },
                act(2048 * 1024, "swish"),
                OpSpec::Reduction { elems: 2048 * 1024, outputs: 2048, name: "sum_reduce" },
                OpSpec::Norm { elems: 2048 * 1024, groups: 2048 * 8, name: "groupnorm" },
            ],
        ),
        mk(
            "46_Conv2d_Subtract_Tanh_Subtract_AvgPool",
            vec![
                conv(32, 64, 64, 3),
                ew(16 * 64 * 62 * 62, 1, 0, "subtract"),
                act(16 * 64 * 62 * 62, "tanh"),
                ew(16 * 64 * 62 * 62, 1, 0, "subtract"),
                OpSpec::Pool { elems_out: 16 * 64 * 31 * 31, win: 4, name: "avgpool" },
            ],
        ),
        mk(
            "47_Conv3d_Mish_Tanh",
            vec![
                OpSpec::Conv3d { n: 4, c_in: 16, c_out: 32, d: 16, h: 32, w: 32, k: 3 },
                act(4 * 32 * 14 * 30 * 30, "mish"),
                act(4 * 32 * 14 * 30 * 30, "tanh"),
            ],
        ),
        mk(
            "50_ConvTranspose3d_Scaling_AvgPool_BiasAdd_Scaling",
            vec![
                OpSpec::ConvTranspose3d { n: 4, c_in: 16, c_out: 32, d: 32, h: 64, w: 64, k: 3 },
                ew(4 * 32 * 32 * 64 * 64, 1, 0, "scale"),
                OpSpec::Pool { elems_out: (4 * 32 * 32 * 64 * 64) / 8, win: 8, name: "avgpool" },
                ew((4 * 32 * 32 * 64 * 64) / 8, 1, 0, "bias_add"),
                ew((4 * 32 * 32 * 64 * 64) / 8, 1, 0, "scale"),
            ],
        ),
        mk(
            "59_Matmul_Swish_Scaling",
            vec![
                OpSpec::Matmul { m: 2048, n: 1024, k: 512 },
                act(2048 * 1024, "swish"),
                ew(2048 * 1024, 1, 0, "scale"),
            ],
        ),
        mk(
            "5_ConvTranspose2d_Subtract_Tanh",
            vec![
                OpSpec::ConvTranspose2d { n: 16, c_in: 32, c_out: 16, h: 64, w: 64, kh: 4, kw: 4 },
                ew(16 * 16 * 64 * 64, 1, 0, "subtract"),
                act(16 * 16 * 64 * 64, "tanh"),
            ],
        ),
        mk(
            "67_Conv2d_GELU_GlobalAvgPool",
            vec![
                conv(32, 64, 64, 3),
                act(16 * 64 * 62 * 62, "gelu"),
                OpSpec::Reduction { elems: 16 * 64 * 62 * 62, outputs: 16 * 64, name: "global_avgpool" },
            ],
        ),
        mk(
            "70_Gemm_Sigmoid_Scaling_ResidualAdd",
            vec![
                OpSpec::Matmul { m: 1024, n: 2048, k: 512 },
                act(1024 * 2048, "sigmoid"),
                ew(1024 * 2048, 1, 0, "scale"),
                ew(1024 * 2048, 1, 0, "residual_add"),
            ],
        ),
        mk(
            "73_Conv2d_BatchNorm_Scaling",
            vec![
                conv(32, 64, 64, 3),
                OpSpec::Norm { elems: 16 * 64 * 62 * 62, groups: 64, name: "batchnorm" },
                ew(16 * 64 * 62 * 62, 1, 0, "scale"),
            ],
        ),
        mk(
            "82_Conv2d_Tanh_Scaling_BiasAdd_Max",
            vec![
                conv(32, 64, 64, 3),
                act(16 * 64 * 62 * 62, "tanh"),
                ew(16 * 64 * 62 * 62, 1, 0, "scale"),
                ew(16 * 64 * 62 * 62, 1, 0, "bias_add"),
                OpSpec::Pool { elems_out: 16 * 64 * 31 * 31, win: 4, name: "maxpool" },
            ],
        ),
        mk(
            "85_Conv2d_GroupNorm_Scale_MaxPool_Clamp",
            vec![
                conv(32, 64, 64, 3),
                OpSpec::Norm { elems: 16 * 64 * 62 * 62, groups: 16 * 8, name: "groupnorm" },
                ew(16 * 64 * 62 * 62, 1, 0, "scale"),
                OpSpec::Pool { elems_out: 16 * 64 * 31 * 31, win: 4, name: "maxpool" },
                ew(16 * 64 * 31 * 31, 2, 0, "clamp"),
            ],
        ),
        mk(
            "97_Matmul_BatchNorm_BiasAdd_Divide_Swish",
            vec![
                OpSpec::Matmul { m: 2048, n: 1024, k: 512 },
                OpSpec::Norm { elems: 2048 * 1024, groups: 1024, name: "batchnorm" },
                ew(2048 * 1024, 1, 0, "bias_add"),
                ew(2048 * 1024, 1, 1, "divide"),
                act(2048 * 1024, "swish"),
            ],
        ),
        mk(
            "99_Matmul_GELU_Softmax",
            vec![
                OpSpec::Matmul { m: 1024, n: 1024, k: 512 },
                act(1024 * 1024, "gelu"),
                OpSpec::Softmax { rows: 1024, cols: 1024 },
            ],
        ),
    ]
}

/// The 12 robust-kbench tasks with published best kernels (Table 7).
pub fn robust_kbench() -> Vec<TaskSpec> {
    let mk = |id: &str, ops: Vec<OpSpec>, backward: bool| {
        let mut t = TaskSpec::new(id, Suite::RobustKBench, ops);
        t.backward = backward;
        t
    };
    vec![
        mk(
            "layernorm_forward",
            vec![OpSpec::Norm { elems: 64 * MB, groups: 64 * 1024, name: "layernorm" }],
            false,
        ),
        mk(
            "llama_ffw",
            vec![
                OpSpec::Matmul { m: 2048, n: 5504, k: 2048 },
                ew(2048 * 5504, 4, 1, "silu_gate"),
                OpSpec::Matmul { m: 2048, n: 2048, k: 5504 },
            ],
            false,
        ),
        mk(
            "llama_rmsnorm_forward",
            vec![OpSpec::Norm { elems: 2048 * 2048, groups: 2048, name: "rmsnorm" }],
            false,
        ),
        mk(
            "mnist_conv_relu_pool_forward",
            vec![
                OpSpec::Conv2d { n: 256, c_in: 1, c_out: 32, h: 28, w: 28, kh: 3, kw: 3 },
                ew(256 * 32 * 26 * 26, 1, 0, "relu"),
                OpSpec::Pool { elems_out: 256 * 32 * 13 * 13, win: 4, name: "maxpool" },
            ],
            false,
        ),
        mk(
            "mnist_cross_entropy_backward",
            vec![ew(256 * 10, 4, 1, "ce_grad"), OpSpec::Reduction { elems: 256 * 10, outputs: 256, name: "grad_reduce" }],
            true,
        ),
        mk(
            "mnist_cross_entropy_forward",
            vec![OpSpec::Softmax { rows: 256, cols: 10 }, OpSpec::Reduction { elems: 256 * 10, outputs: 1, name: "nll" }],
            false,
        ),
        mk(
            "mnist_linear_backward",
            vec![
                OpSpec::Matmul { m: 784, n: 128, k: 256 },
                OpSpec::Matmul { m: 256, n: 784, k: 128 },
            ],
            true,
        ),
        mk("mnist_linear_forward", vec![OpSpec::Matmul { m: 256, n: 128, k: 784 }], false),
        mk(
            "mnist_linear_relu_backward",
            vec![
                ew(256 * 128, 1, 0, "relu_grad"),
                OpSpec::Matmul { m: 784, n: 128, k: 256 },
                OpSpec::Matmul { m: 256, n: 784, k: 128 },
            ],
            true,
        ),
        mk(
            "mnist_linear_relu_forward",
            vec![OpSpec::Matmul { m: 256, n: 128, k: 784 }, ew(256 * 128, 1, 0, "relu")],
            false,
        ),
        mk(
            "mnist_pool_backward",
            vec![OpSpec::Pool { elems_out: 256 * 32 * 26 * 26, win: 4, name: "maxpool_grad" }],
            true,
        ),
        mk(
            "resnet_block",
            vec![
                OpSpec::Conv2d { n: 16, c_in: 64, c_out: 64, h: 56, w: 56, kh: 3, kw: 3 },
                OpSpec::Norm { elems: 16 * 64 * 56 * 56, groups: 64, name: "batchnorm" },
                ew(16 * 64 * 56 * 56, 1, 0, "relu"),
                OpSpec::Conv2d { n: 16, c_in: 64, c_out: 64, h: 56, w: 56, kh: 3, kw: 3 },
                OpSpec::Norm { elems: 16 * 64 * 56 * 56, groups: 64, name: "batchnorm" },
                ew(16 * 64 * 56 * 56, 2, 0, "residual_relu"),
            ],
            false,
        ),
    ]
}

/// §5.4 oneDNN comparison operations (Table 4).
pub fn onednn_tasks() -> Vec<TaskSpec> {
    let mut concat_ln = TaskSpec::new(
        "concat_layernorm",
        Suite::OneDnn,
        vec![
            OpSpec::Norm { elems: 8 * MB, groups: 8192, name: "layernorm" },
            OpSpec::Concat { elems_out: 16 * MB },
        ],
    );
    concat_ln.has_initial_impl = true;

    let mut softmax = TaskSpec::new(
        "softmax",
        Suite::OneDnn,
        vec![OpSpec::Softmax { rows: 16384, cols: 1024 }],
    );
    softmax.user_instructions = Some(
        "Reduce the load on special function units: use the exp2-based \
         online softmax formulation inspired by Flash Attention 4, keeping \
         a running maximum and rescaling the running sum."
            .to_string(),
    );

    vec![
        concat_ln,
        TaskSpec::new(
            "matmul_relu_postop",
            Suite::OneDnn,
            vec![OpSpec::Matmul { m: 4096, n: 4096, k: 4096 }, ew(4096 * 4096, 1, 0, "relu")],
        ),
        TaskSpec::new(
            "maxpool_linear",
            Suite::OneDnn,
            vec![
                OpSpec::Pool { elems_out: 4 * MB, win: 4, name: "maxpool" },
                OpSpec::Matmul { m: 4096, n: 512, k: 1024 },
            ],
        ),
        TaskSpec::new(
            "sum_reduction",
            Suite::OneDnn,
            vec![OpSpec::Reduction { elems: 64 * MB, outputs: 1024, name: "sum_reduce" }],
        ),
        softmax,
    ]
}

/// §5.5 Llama 3.2 rotary-positional-embedding case-study task.
pub fn llama_rope_task() -> TaskSpec {
    let mut t = TaskSpec::new(
        "llama_rope",
        Suite::Custom,
        vec![OpSpec::Rope { elems: 2 * 2048 * 32 * 64 }],
    );
    t.user_instructions = Some(
        "Optimize apply_rotary_pos_emb (unsqueeze + rotate-half) for the \
         Llama 3.2 1B attention block. Reduced precision is acceptable as \
         long as a full model forward pass yields identical results."
            .to_string(),
    );
    t
}

/// The 40-task representative subset (20 L1 + 20 L2) used in most
/// experiments.
pub fn representative_set() -> Vec<TaskSpec> {
    let mut v = kernelbench_l1();
    v.extend(kernelbench_l2());
    v
}

/// The filtered KernelBench set (111 tasks: 80 L1, 31 L2) used in
/// Table 2's first block. The 40 named representative tasks are included;
/// the remainder are procedurally generated shape/op variants marked
/// clean under the App. D criteria (the paper's additional 71 tasks are
/// KernelBench problems we do not have verbatim — see DESIGN.md §2).
pub fn filtered_kernelbench() -> Vec<TaskSpec> {
    let mut v = representative_set();
    let acts: [(&'static str, u64, u64); 6] = [
        ("relu", 1, 0),
        ("gelu", 4, 1),
        ("tanh", 3, 1),
        ("elu", 3, 1),
        ("softplus", 3, 1),
        ("hardsigmoid", 2, 0),
    ];
    // 60 extra L1 variants: activations, reductions, matmuls, convs.
    for i in 0..60u64 {
        let id = format!("L1_extra_{i:02}");
        let ops = match i % 5 {
            0 => {
                let (name, f, s) = acts[(i / 5) as usize % acts.len()];
                vec![ew((4 + (i % 4)) * 4 * MB, f, s, name)]
            }
            1 => vec![OpSpec::Matmul {
                m: 512 << (i % 3),
                n: 512 << ((i / 3) % 3),
                k: 256 << (i % 4),
            }],
            2 => vec![OpSpec::Reduction {
                elems: (8 + (i % 8)) * MB,
                outputs: 1 << (4 + i % 8),
                name: "sum_reduce",
            }],
            3 => vec![OpSpec::Conv2d {
                n: 8,
                c_in: 16 << (i % 3),
                c_out: 32,
                h: 64 << (i % 2),
                w: 64 << (i % 2),
                kh: 1 + 2 * (i % 3),
                kw: 1 + 2 * (i % 3),
            }],
            _ => vec![OpSpec::Norm {
                elems: (4 + (i % 6)) * 4 * MB,
                groups: 1 << (6 + i % 6),
                name: if i % 2 == 0 { "layernorm" } else { "rmsnorm" },
            }],
        };
        v.push(TaskSpec::new(&id, Suite::KernelBenchL1, ops));
    }
    // 11 extra L2 fusion variants.
    for i in 0..11u64 {
        let id = format!("L2_extra_{i:02}");
        let elems = (2 + (i % 4)) * 4 * MB;
        let (name, f, s) = acts[i as usize % acts.len()];
        let mut ops = vec![
            OpSpec::Matmul { m: 1024, n: 1024, k: 256 << (i % 3) },
            ew(1024 * 1024, f, s, name),
        ];
        if i % 2 == 0 {
            ops.push(OpSpec::Norm { elems, groups: 1024, name: "layernorm" });
        }
        if i % 3 == 0 {
            ops.push(OpSpec::Softmax { rows: 1024, cols: 1024 });
        }
        v.push(TaskSpec::new(&id, Suite::KernelBenchL2, ops));
    }
    v
}

/// Example compromised tasks (for App. D filtering tests): each trips one
/// of the Lange et al. criteria.
pub fn compromised_examples() -> Vec<TaskSpec> {
    let mut a = TaskSpec::new("comp_small_range", Suite::KernelBenchL1, vec![ew(MB, 1, 0, "clip")]);
    a.flags = FilterFlags { small_range: true, ..FilterFlags::clean() };
    let mut b = TaskSpec::new("comp_axis_std", Suite::KernelBenchL1, vec![ew(MB, 1, 0, "mul")]);
    b.flags = FilterFlags { small_axis_std: true, ..FilterFlags::clean() };
    let mut c = TaskSpec::new(
        "comp_slow_baseline",
        Suite::KernelBenchL2,
        vec![ew(MB, 1, 0, "chain")],
    );
    c.flags = FilterFlags { inefficient_baseline: true, ..FilterFlags::clean() };
    vec![a, b, c]
}

/// Look up any task across all suites by id.
pub fn find_task(id: &str) -> Option<TaskSpec> {
    all_tasks().into_iter().find(|t| t.id == id)
}

pub fn all_tasks() -> Vec<TaskSpec> {
    let mut v = filtered_kernelbench();
    v.extend(robust_kbench());
    v.extend(onednn_tasks());
    v.push(llama_rope_task());
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_sizes_match_paper() {
        assert_eq!(kernelbench_l1().len(), 20);
        assert_eq!(kernelbench_l2().len(), 20);
        assert_eq!(robust_kbench().len(), 12);
        assert_eq!(onednn_tasks().len(), 5);
        assert_eq!(representative_set().len(), 40);
        assert_eq!(filtered_kernelbench().len(), 111);
    }

    #[test]
    fn task_ids_unique() {
        let all = all_tasks();
        let mut ids: Vec<&str> = all.iter().map(|t| t.id.as_str()).collect();
        let n = ids.len();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), n);
    }

    #[test]
    fn l2_tasks_are_fusion_chains() {
        for t in kernelbench_l2() {
            assert!(t.n_ops() >= 2, "{} has {} ops", t.id, t.n_ops());
            assert!(t.fused_bytes() < t.eager_bytes(), "{}", t.id);
        }
    }

    #[test]
    fn backward_flags_match_table7() {
        let rkb = robust_kbench();
        let backward: Vec<&str> = rkb
            .iter()
            .filter(|t| t.backward)
            .map(|t| t.id.as_str())
            .collect();
        assert_eq!(
            backward,
            vec![
                "mnist_cross_entropy_backward",
                "mnist_linear_backward",
                "mnist_linear_relu_backward",
                "mnist_pool_backward"
            ]
        );
    }

    #[test]
    fn onednn_custom_inputs() {
        let tasks = onednn_tasks();
        let concat = tasks.iter().find(|t| t.id == "concat_layernorm").unwrap();
        assert!(concat.has_initial_impl);
        let softmax = tasks.iter().find(|t| t.id == "softmax").unwrap();
        assert!(softmax.user_instructions.as_ref().unwrap().contains("exp2"));
    }

    #[test]
    fn representative_tasks_clean_under_filters() {
        for t in representative_set() {
            assert!(!t.flags.compromised_strict(), "{}", t.id);
        }
        for t in compromised_examples() {
            assert!(t.flags.compromised_strict(), "{}", t.id);
        }
    }

    #[test]
    fn find_task_by_id() {
        assert!(find_task("99_Matmul_GELU_Softmax").is_some());
        assert!(find_task("llama_rope").is_some());
        assert!(find_task("nonexistent").is_none());
    }
}
