//! Custom task input layer (App. C).
//!
//! "Tasks are defined by a set of files with special markers … a config
//! file in YAML format containing hyperparameters; a python module with a
//! build function and correctness and performance tests defined in the
//! pytest framework; and a language-specific file for the generated code.
//! Special markers are used to define sections for the reference code,
//! optional user instructions, and optional initial kernel
//! implementations passed to the model."
//!
//! This module parses that exact format. The pytest hooks are represented
//! by the test command recorded in the config (executed by the evaluation
//! pipeline's custom-task path).

use super::{OpSpec, Suite, TaskSpec};
use crate::util::json::Json;
use crate::util::yamlite;
use std::path::Path;

/// Section markers in the language-specific source file.
pub const MARK_REFERENCE: &str = "### KF:REFERENCE ###";
pub const MARK_INSTRUCTIONS: &str = "### KF:INSTRUCTIONS ###";
pub const MARK_INITIAL: &str = "### KF:INITIAL_KERNEL ###";
pub const MARK_END: &str = "### KF:END ###";

/// A parsed custom task bundle.
#[derive(Debug, Clone)]
pub struct CustomTask {
    pub spec: TaskSpec,
    pub config: Json,
    pub reference_code: String,
    pub initial_kernel: Option<String>,
    /// pytest invocation for user-defined correctness/perf tests.
    pub test_command: Option<String>,
}

#[derive(Debug)]
pub enum CustomTaskError {
    Io(std::io::Error),
    Config(String),
    Yaml(yamlite::YamlError),
    Marker(String),
}

impl std::fmt::Display for CustomTaskError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CustomTaskError::Io(e) => write!(f, "io error: {e}"),
            CustomTaskError::Config(s) => write!(f, "config error: {s}"),
            CustomTaskError::Yaml(e) => write!(f, "yaml error: {e}"),
            CustomTaskError::Marker(s) => write!(f, "marker error: {s}"),
        }
    }
}

impl std::error::Error for CustomTaskError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CustomTaskError::Io(e) => Some(e),
            CustomTaskError::Yaml(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CustomTaskError {
    fn from(e: std::io::Error) -> CustomTaskError {
        CustomTaskError::Io(e)
    }
}

impl From<yamlite::YamlError> for CustomTaskError {
    fn from(e: yamlite::YamlError) -> CustomTaskError {
        CustomTaskError::Yaml(e)
    }
}

/// Read a bundle's raw strings from a directory: the `task.yaml` config
/// plus the first marker-annotated source file found (`task.py` /
/// `kernel.cpp` / `kernel.cu`). Shared by [`load_dir`] and the service
/// `submit` client, which ships the strings over the wire unparsed.
pub fn read_dir_strings(dir: &Path) -> Result<(String, String), CustomTaskError> {
    let config_text = std::fs::read_to_string(dir.join("task.yaml"))?;
    let source_path = ["task.py", "kernel.cpp", "kernel.cu"]
        .iter()
        .map(|f| dir.join(f))
        .find(|p| p.exists())
        .ok_or_else(|| {
            CustomTaskError::Marker("no task.py / kernel.cpp / kernel.cu found".into())
        })?;
    let source_text = std::fs::read_to_string(source_path)?;
    Ok((config_text, source_text))
}

/// Load a custom task from a directory containing `task.yaml` and a
/// marker-annotated source file (`task.py` / `kernel.cpp`).
pub fn load_dir(dir: &Path) -> Result<CustomTask, CustomTaskError> {
    let (config_text, source_text) = read_dir_strings(dir)?;
    load_strings(&config_text, &source_text)
}

/// Parse from in-memory strings (used by tests and the example).
pub fn load_strings(config_text: &str, source_text: &str) -> Result<CustomTask, CustomTaskError> {
    let config = yamlite::parse(config_text)?;
    let id = config
        .get("name")
        .and_then(|v| v.as_str())
        .ok_or_else(|| CustomTaskError::Config("missing 'name'".into()))?
        .to_string();

    let reference_code = extract_section(source_text, MARK_REFERENCE)
        .ok_or_else(|| CustomTaskError::Marker(format!("missing {MARK_REFERENCE} section")))?;
    let instructions = extract_section(source_text, MARK_INSTRUCTIONS);
    let initial_kernel = extract_section(source_text, MARK_INITIAL);

    let ops = parse_workload(&config)?;
    let mut spec = TaskSpec::new(&id, Suite::Custom, ops);
    spec.user_instructions = instructions;
    spec.has_initial_impl = initial_kernel.is_some();
    if let Some(b) = config.get("backward").and_then(|v| v.as_bool()) {
        spec.backward = b;
    }

    let test_command = config
        .get_path("tests.command")
        .and_then(|v| v.as_str())
        .map(String::from);

    Ok(CustomTask {
        spec,
        config,
        reference_code,
        initial_kernel,
        test_command,
    })
}

/// Extract the text between a marker and the next marker / MARK_END.
fn extract_section(source: &str, marker: &str) -> Option<String> {
    let start = source.find(marker)? + marker.len();
    let rest = &source[start..];
    let end = [MARK_REFERENCE, MARK_INSTRUCTIONS, MARK_INITIAL, MARK_END]
        .iter()
        .filter_map(|m| rest.find(m))
        .min()
        .unwrap_or(rest.len());
    let text = rest[..end].trim();
    if text.is_empty() {
        None
    } else {
        Some(text.to_string())
    }
}

/// Workload description from the config (so the hardware simulator can
/// cost custom tasks):
///
/// ```yaml
/// workload:
///   - op: matmul
///     m: 1024
///     n: 1024
///     k: 512
///   - op: elementwise
///     elems: 1048576
///     flops_per_elem: 4
///     sfu_per_elem: 1
/// ```
fn parse_workload(config: &Json) -> Result<Vec<OpSpec>, CustomTaskError> {
    let items = config
        .get("workload")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| CustomTaskError::Config("missing 'workload' list".into()))?;
    let geti = |o: &Json, k: &str, default: u64| -> u64 {
        o.get(k).and_then(|v| v.as_i64()).map(|v| v as u64).unwrap_or(default)
    };
    let mut ops = Vec::new();
    for item in items {
        let kind = item
            .get("op")
            .and_then(|v| v.as_str())
            .ok_or_else(|| CustomTaskError::Config("workload item missing 'op'".into()))?;
        let op = match kind {
            "matmul" => OpSpec::Matmul {
                m: geti(item, "m", 1024),
                n: geti(item, "n", 1024),
                k: geti(item, "k", 1024),
            },
            "elementwise" => OpSpec::Elementwise {
                elems: geti(item, "elems", 1 << 20),
                flops_per_elem: geti(item, "flops_per_elem", 1),
                sfu_per_elem: geti(item, "sfu_per_elem", 0),
                name: "custom_elementwise",
            },
            "softmax" => OpSpec::Softmax {
                rows: geti(item, "rows", 1024),
                cols: geti(item, "cols", 1024),
            },
            "norm" => OpSpec::Norm {
                elems: geti(item, "elems", 1 << 20),
                groups: geti(item, "groups", 1024),
                name: "custom_norm",
            },
            "reduction" => OpSpec::Reduction {
                elems: geti(item, "elems", 1 << 20),
                outputs: geti(item, "outputs", 1),
                name: "custom_reduce",
            },
            "rope" => OpSpec::Rope {
                elems: geti(item, "elems", 1 << 20),
            },
            other => {
                return Err(CustomTaskError::Config(format!("unknown op kind '{other}'")))
            }
        };
        ops.push(op);
    }
    if ops.is_empty() {
        return Err(CustomTaskError::Config("empty workload".into()));
    }
    Ok(ops)
}

#[cfg(test)]
mod tests {
    use super::*;

    const CONFIG: &str = "\
name: rope_task
backward: false
workload:
  - op: rope
    elems: 8388608
tests:
  command: pytest python/tests/test_rope.py -q
evolution:
  max_generations: 10
";

    const SOURCE: &str = "\
### KF:REFERENCE ###
def apply_rotary_pos_emb(q, k, cos, sin):
    return (q * cos) + (rotate_half(q) * sin), (k * cos) + (rotate_half(k) * sin)
### KF:INSTRUCTIONS ###
Optimize for Intel B580; reduced precision allowed.
### KF:INITIAL_KERNEL ###
// naive elementwise rope kernel
### KF:END ###
";

    #[test]
    fn parses_full_bundle() {
        let t = load_strings(CONFIG, SOURCE).unwrap();
        assert_eq!(t.spec.id, "rope_task");
        assert_eq!(t.spec.suite, Suite::Custom);
        assert!(t.reference_code.contains("apply_rotary_pos_emb"));
        assert_eq!(
            t.spec.user_instructions.as_deref(),
            Some("Optimize for Intel B580; reduced precision allowed.")
        );
        assert!(t.initial_kernel.is_some());
        assert!(t.spec.has_initial_impl);
        assert_eq!(
            t.test_command.as_deref(),
            Some("pytest python/tests/test_rope.py -q")
        );
        assert_eq!(t.spec.ops.len(), 1);
    }

    #[test]
    fn instructions_and_initial_optional() {
        let src = "### KF:REFERENCE ###\nref code\n### KF:END ###\n";
        let t = load_strings(CONFIG, src).unwrap();
        assert!(t.spec.user_instructions.is_none());
        assert!(t.initial_kernel.is_none());
    }

    #[test]
    fn missing_reference_fails() {
        let src = "### KF:INSTRUCTIONS ###\nhello\n### KF:END ###\n";
        assert!(load_strings(CONFIG, src).is_err());
    }

    #[test]
    fn bad_workload_fails() {
        let cfg = "name: x\nworkload:\n  - op: warpdrive\n";
        let src = "### KF:REFERENCE ###\nref\n### KF:END ###\n";
        assert!(load_strings(cfg, src).is_err());
        let cfg2 = "name: x\n";
        assert!(load_strings(cfg2, src).is_err());
    }
}
