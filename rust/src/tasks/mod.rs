//! Task model: the problems kernels are generated for.
//!
//! A [`TaskSpec`] corresponds to one KernelBench / robust-kbench / custom
//! task: an operation chain with concrete tensor shapes, a workload
//! accounting model (bytes moved, FLOPs, special-function ops) used by the
//! hardware simulator, and metadata driving task filtering (App. D) and
//! the custom input layer (App. C).

pub mod catalog;
pub mod custom;

use crate::util::json::Json;

/// Benchmark family a task belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Suite {
    /// KernelBench level 1: single operators.
    KernelBenchL1,
    /// KernelBench level 2: fusion patterns.
    KernelBenchL2,
    /// robust-kbench (includes forward-backward operations).
    RobustKBench,
    /// §5.4 oneDNN comparison ops.
    OneDnn,
    /// User-provided custom task (App. C format).
    Custom,
}

impl Suite {
    pub fn name(&self) -> &'static str {
        match self {
            Suite::KernelBenchL1 => "kernelbench-l1",
            Suite::KernelBenchL2 => "kernelbench-l2",
            Suite::RobustKBench => "robust-kbench",
            Suite::OneDnn => "onednn",
            Suite::Custom => "custom",
        }
    }
}

/// One logical operation in a task's op chain, with enough shape
/// information to account for its memory traffic and compute.
#[derive(Debug, Clone, PartialEq)]
pub enum OpSpec {
    /// Dense matmul  (m×k)·(k×n).
    Matmul { m: u64, n: u64, k: u64 },
    /// 2-D convolution over NCHW input.
    Conv2d { n: u64, c_in: u64, c_out: u64, h: u64, w: u64, kh: u64, kw: u64 },
    /// 3-D convolution.
    Conv3d { n: u64, c_in: u64, c_out: u64, d: u64, h: u64, w: u64, k: u64 },
    /// Transposed convolution (same accounting as conv with swapped channels).
    ConvTranspose2d { n: u64, c_in: u64, c_out: u64, h: u64, w: u64, kh: u64, kw: u64 },
    ConvTranspose3d { n: u64, c_in: u64, c_out: u64, d: u64, h: u64, w: u64, k: u64 },
    /// Elementwise op over `elems` elements; `flops_per_elem` arithmetic
    /// ops and `sfu_per_elem` special-function ops (exp/tanh/erf/div).
    Elementwise { elems: u64, flops_per_elem: u64, sfu_per_elem: u64, name: &'static str },
    /// Reduction of `elems` inputs down to `outputs` values.
    Reduction { elems: u64, outputs: u64, name: &'static str },
    /// Row-wise softmax over `rows` rows of `cols` (2 passes + exp).
    Softmax { rows: u64, cols: u64 },
    /// Normalization (layernorm / instancenorm / batchnorm / rmsnorm /
    /// groupnorm) over `elems` with `groups` statistics groups.
    Norm { elems: u64, groups: u64, name: &'static str },
    /// Pooling with window `win` over `elems` outputs.
    Pool { elems_out: u64, win: u64, name: &'static str },
    /// Concatenation producing `elems_out` elements.
    Concat { elems_out: u64 },
    /// Cumulative sum along rows.
    Cumsum { rows: u64, cols: u64 },
    /// Rotary positional embedding applied to q/k of `elems` elements.
    Rope { elems: u64 },
}

pub const F32: u64 = 4;

impl OpSpec {
    /// Bytes read from global memory when the op runs standalone.
    pub fn bytes_read(&self) -> u64 {
        match self {
            OpSpec::Matmul { m, n, k } => (m * k + k * n) * F32,
            OpSpec::Conv2d { n, c_in, c_out, h, w, kh, kw } => {
                (n * c_in * h * w + c_out * c_in * kh * kw) * F32
            }
            OpSpec::Conv3d { n, c_in, c_out, d, h, w, k } => {
                (n * c_in * d * h * w + c_out * c_in * k * k * k) * F32
            }
            OpSpec::ConvTranspose2d { n, c_in, c_out, h, w, kh, kw } => {
                (n * c_in * h * w + c_in * c_out * kh * kw) * F32
            }
            OpSpec::ConvTranspose3d { n, c_in, c_out, d, h, w, k } => {
                (n * c_in * d * h * w + c_in * c_out * k * k * k) * F32
            }
            OpSpec::Elementwise { elems, .. } => elems * F32,
            OpSpec::Reduction { elems, .. } => elems * F32,
            OpSpec::Softmax { rows, cols } => 2 * rows * cols * F32, // two passes
            OpSpec::Norm { elems, .. } => 2 * elems * F32,           // stats + normalize
            OpSpec::Pool { elems_out, win, .. } => elems_out * win * F32,
            OpSpec::Concat { elems_out } => elems_out * F32,
            OpSpec::Cumsum { rows, cols } => rows * cols * F32,
            OpSpec::Rope { elems } => (elems + elems / 2) * F32, // x + cos/sin tables
        }
    }

    /// Bytes written to global memory when the op runs standalone.
    pub fn bytes_written(&self) -> u64 {
        match self {
            OpSpec::Matmul { m, n, .. } => m * n * F32,
            OpSpec::Conv2d { n, c_out, h, w, .. } => n * c_out * h * w * F32,
            OpSpec::Conv3d { n, c_out, d, h, w, .. } => n * c_out * d * h * w * F32,
            OpSpec::ConvTranspose2d { n, c_out, h, w, .. } => n * c_out * h * w * F32,
            OpSpec::ConvTranspose3d { n, c_out, d, h, w, .. } => n * c_out * d * h * w * F32,
            OpSpec::Elementwise { elems, .. } => elems * F32,
            OpSpec::Reduction { outputs, .. } => outputs * F32,
            OpSpec::Softmax { rows, cols } => rows * cols * F32,
            OpSpec::Norm { elems, .. } => elems * F32,
            OpSpec::Pool { elems_out, .. } => elems_out * F32,
            OpSpec::Concat { elems_out } => elems_out * F32,
            OpSpec::Cumsum { rows, cols } => rows * cols * F32,
            OpSpec::Rope { elems } => elems * F32,
        }
    }

    /// Floating-point operations.
    pub fn flops(&self) -> u64 {
        match self {
            OpSpec::Matmul { m, n, k } => 2 * m * n * k,
            OpSpec::Conv2d { n, c_in, c_out, h, w, kh, kw } => 2 * n * c_out * h * w * c_in * kh * kw,
            OpSpec::Conv3d { n, c_in, c_out, d, h, w, k } => {
                2 * n * c_out * d * h * w * c_in * k * k * k
            }
            OpSpec::ConvTranspose2d { n, c_in, c_out, h, w, kh, kw } => {
                2 * n * c_out * h * w * c_in * kh * kw
            }
            OpSpec::ConvTranspose3d { n, c_in, c_out, d, h, w, k } => {
                2 * n * c_out * d * h * w * c_in * k * k * k
            }
            OpSpec::Elementwise { elems, flops_per_elem, .. } => elems * flops_per_elem,
            OpSpec::Reduction { elems, .. } => *elems,
            OpSpec::Softmax { rows, cols } => 4 * rows * cols,
            OpSpec::Norm { elems, .. } => 6 * elems,
            OpSpec::Pool { elems_out, win, .. } => elems_out * win,
            OpSpec::Concat { .. } => 0,
            OpSpec::Cumsum { rows, cols } => rows * cols,
            OpSpec::Rope { elems } => 4 * elems,
        }
    }

    /// Special-function-unit operations (exp, tanh, erf, rsqrt, div).
    pub fn sfu_ops(&self) -> u64 {
        match self {
            OpSpec::Softmax { rows, cols } => rows * cols + rows, // exp per element + div per row
            OpSpec::Norm { elems, groups, .. } => groups + elems, // rsqrt + div
            OpSpec::Elementwise { elems, sfu_per_elem, .. } => elems * sfu_per_elem,
            OpSpec::Rope { elems } => *elems, // sin/cos application
            _ => 0,
        }
    }

    /// Bytes of this op's inputs that are *parameters / second streams*
    /// (weights, tables) rather than the activation produced by a
    /// predecessor — the traffic a fused kernel must still pay.
    pub fn param_bytes(&self) -> u64 {
        match self {
            OpSpec::Matmul { n, k, .. } => k * n * F32,
            OpSpec::Conv2d { c_in, c_out, kh, kw, .. } => c_out * c_in * kh * kw * F32,
            OpSpec::Conv3d { c_in, c_out, k, .. } => c_out * c_in * k * k * k * F32,
            OpSpec::ConvTranspose2d { c_in, c_out, kh, kw, .. } => c_in * c_out * kh * kw * F32,
            OpSpec::ConvTranspose3d { c_in, c_out, k, .. } => c_in * c_out * k * k * k * F32,
            OpSpec::Rope { elems } => elems / 2 * F32, // cos/sin tables
            // Pure activation transforms: nothing extra to read when fused.
            _ => 0,
        }
    }

    /// Whether this op admits an algorithmic reformulation (online
    /// normalization / flash-style streaming), enabling d_algo = 2.
    pub fn supports_reformulation(&self) -> bool {
        matches!(
            self,
            OpSpec::Softmax { .. } | OpSpec::Norm { .. } | OpSpec::Cumsum { .. }
        )
    }

    pub fn name(&self) -> &'static str {
        match self {
            OpSpec::Matmul { .. } => "matmul",
            OpSpec::Conv2d { .. } => "conv2d",
            OpSpec::Conv3d { .. } => "conv3d",
            OpSpec::ConvTranspose2d { .. } => "conv_transpose2d",
            OpSpec::ConvTranspose3d { .. } => "conv_transpose3d",
            OpSpec::Elementwise { name, .. } => name,
            OpSpec::Reduction { name, .. } => name,
            OpSpec::Softmax { .. } => "softmax",
            OpSpec::Norm { name, .. } => name,
            OpSpec::Pool { name, .. } => name,
            OpSpec::Concat { .. } => "concat",
            OpSpec::Cumsum { .. } => "cumsum",
            OpSpec::Rope { .. } => "rope",
        }
    }

    /// Compute-bound ops benefit from tiling; memory-bound ops benefit
    /// mostly from coalescing/fusion. Arithmetic intensity in FLOP/byte.
    pub fn arithmetic_intensity(&self) -> f64 {
        let bytes = (self.bytes_read() + self.bytes_written()) as f64;
        if bytes == 0.0 {
            0.0
        } else {
            self.flops() as f64 / bytes
        }
    }
}

/// App. D filtering flags (Lange et al. criteria 1–5).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FilterFlags {
    /// (1) small output value range.
    pub small_range: bool,
    /// (2) small output standard deviation.
    pub small_std: bool,
    /// (3) small output StD across some axis.
    pub small_axis_std: bool,
    /// (4) small impact of inputs on the output.
    pub input_insensitive: bool,
    /// (5) baseline inefficiencies.
    pub inefficient_baseline: bool,
}

impl FilterFlags {
    pub fn clean() -> FilterFlags {
        FilterFlags::default()
    }

    /// Compromised under the strict (1)–(5) criteria (robust-kbench set).
    pub fn compromised_strict(&self) -> bool {
        self.small_range
            || self.small_std
            || self.small_axis_std
            || self.input_insensitive
            || self.inefficient_baseline
    }

    /// Compromised under the relaxed criteria the paper argues for
    /// (App. D): only (1), (2) and (4).
    pub fn compromised_relaxed(&self) -> bool {
        self.small_range || self.small_std || self.input_insensitive
    }
}

/// A complete task specification.
#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub id: String,
    pub suite: Suite,
    /// The operation chain; length > 1 for fusion (L2) tasks.
    pub ops: Vec<OpSpec>,
    /// robust-kbench backward tasks measure through torch.autograd on the
    /// baseline side (App. B.2), which inflates baseline time.
    pub backward: bool,
    pub flags: FilterFlags,
    /// Free-form user instructions (custom tasks, §5.4 softmax guidance).
    pub user_instructions: Option<String>,
    /// Whether the task ships an initial kernel implementation to start
    /// from (custom tasks, §5.4 concat+layernorm).
    pub has_initial_impl: bool,
}

impl TaskSpec {
    pub fn new(id: &str, suite: Suite, ops: Vec<OpSpec>) -> TaskSpec {
        TaskSpec {
            id: id.to_string(),
            suite,
            ops,
            backward: false,
            flags: FilterFlags::clean(),
            user_instructions: None,
            has_initial_impl: false,
        }
    }

    /// Total standalone (op-by-op) memory traffic in bytes — what the
    /// eager baseline moves.
    pub fn eager_bytes(&self) -> u64 {
        self.ops
            .iter()
            .map(|o| o.bytes_read() + o.bytes_written())
            .sum()
    }

    /// Memory traffic of a perfectly fused single-pass kernel: external
    /// inputs of the first op + the final output + the parameter traffic
    /// (weights, tables) of downstream ops. Intermediate activations stay
    /// in registers/SLM and cost nothing.
    pub fn fused_bytes(&self) -> u64 {
        let first_read = self.ops.first().map(|o| o.bytes_read()).unwrap_or(0);
        let last_write = self.ops.last().map(|o| o.bytes_written()).unwrap_or(0);
        let params: u64 = self.ops.iter().skip(1).map(|o| o.param_bytes()).sum();
        first_read + last_write + params
    }

    pub fn total_flops(&self) -> u64 {
        self.ops.iter().map(|o| o.flops()).sum()
    }

    pub fn total_sfu(&self) -> u64 {
        self.ops.iter().map(|o| o.sfu_ops()).sum()
    }

    pub fn n_ops(&self) -> usize {
        self.ops.len()
    }

    pub fn supports_reformulation(&self) -> bool {
        self.ops.iter().any(|o| o.supports_reformulation())
    }

    /// Dominant arithmetic intensity, used by hwsim and by the simulated
    /// model's "analysis" of likely bottlenecks.
    pub fn arithmetic_intensity(&self) -> f64 {
        self.total_flops() as f64 / self.eager_bytes().max(1) as f64
    }

    /// SFU pressure: special-function ops per byte moved.
    pub fn sfu_intensity(&self) -> f64 {
        self.total_sfu() as f64 / self.eager_bytes().max(1) as f64
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("id", self.id.as_str())
            .set("suite", self.suite.name())
            .set("n_ops", self.n_ops())
            .set("backward", self.backward)
            .set("flops", self.total_flops() as f64)
            .set("eager_bytes", self.eager_bytes() as f64)
            .set("fused_bytes", self.fused_bytes() as f64)
            .set("sfu_ops", self.total_sfu() as f64);
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_workload_accounting() {
        let m = OpSpec::Matmul { m: 64, n: 64, k: 64 };
        assert_eq!(m.flops(), 2 * 64 * 64 * 64);
        assert_eq!(m.bytes_read(), 2 * 64 * 64 * 4);
        assert_eq!(m.bytes_written(), 64 * 64 * 4);
        assert!(m.arithmetic_intensity() > 10.0);
    }

    #[test]
    fn elementwise_is_memory_bound() {
        let e = OpSpec::Elementwise { elems: 1 << 20, flops_per_elem: 2, sfu_per_elem: 0, name: "relu" };
        assert!(e.arithmetic_intensity() < 1.0);
    }

    #[test]
    fn fusion_reduces_traffic() {
        let elems = 1u64 << 20;
        let chain = TaskSpec::new(
            "fused",
            Suite::KernelBenchL2,
            vec![
                OpSpec::Elementwise { elems, flops_per_elem: 1, sfu_per_elem: 0, name: "bias" },
                OpSpec::Elementwise { elems, flops_per_elem: 4, sfu_per_elem: 1, name: "gelu" },
                OpSpec::Elementwise { elems, flops_per_elem: 1, sfu_per_elem: 0, name: "scale" },
            ],
        );
        // Eager: 3 × (read + write); fused: 1 × (read + write).
        assert_eq!(chain.eager_bytes(), 3 * 2 * elems * F32);
        assert_eq!(chain.fused_bytes(), 2 * elems * F32);
    }

    #[test]
    fn fused_bytes_keeps_parameter_traffic() {
        // matmul -> norm: the norm re-reads stats but its input comes from
        // the matmul; weight traffic of the matmul is preserved.
        let t = TaskSpec::new(
            "mm_norm",
            Suite::KernelBenchL2,
            vec![
                OpSpec::Matmul { m: 128, n: 128, k: 128 },
                OpSpec::Norm { elems: 128 * 128, groups: 128, name: "layernorm" },
            ],
        );
        assert!(t.fused_bytes() < t.eager_bytes());
        assert!(t.fused_bytes() >= t.ops[0].bytes_read());
    }

    #[test]
    fn softmax_supports_reformulation() {
        let t = TaskSpec::new(
            "softmax",
            Suite::KernelBenchL1,
            vec![OpSpec::Softmax { rows: 1024, cols: 1024 }],
        );
        assert!(t.supports_reformulation());
        assert!(t.total_sfu() > 0);
    }

    #[test]
    fn filter_flags_strict_vs_relaxed() {
        let f = FilterFlags {
            small_axis_std: true,
            ..FilterFlags::clean()
        };
        assert!(f.compromised_strict());
        assert!(!f.compromised_relaxed()); // criterion (3) relaxed away

        let g = FilterFlags {
            inefficient_baseline: true,
            ..FilterFlags::clean()
        };
        assert!(g.compromised_strict());
        assert!(!g.compromised_relaxed()); // criterion (5) relaxed away
    }
}
