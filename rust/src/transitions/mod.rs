//! Transition tracking (§3.3, Fig. 2).
//!
//! A circular buffer of recent parent→child transitions. Each record
//! stores the parent and child behavioral coordinates, the fitness delta,
//! the transition outcome (improvement / neutral / regression), and a
//! timestamp + iteration number for temporal weighting.

use crate::archive::InsertOutcome;
use crate::classify::Coords;

/// Outcome of a transition, as the paper defines it: *improvement* when
/// the child becomes an elite or discovers a new cell, *neutral* when it
/// is competitive but does not update the archive, *regression* when
/// fitness decreases.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    Improvement,
    Neutral,
    Regression,
}

impl Outcome {
    /// Derive the outcome from the archive insertion result and the
    /// fitness delta.
    pub fn from_insertion(insert: InsertOutcome, delta_f: f64) -> Outcome {
        match insert {
            InsertOutcome::NewCell | InsertOutcome::Improved => Outcome::Improvement,
            InsertOutcome::Neutral => {
                if delta_f < 0.0 {
                    Outcome::Regression
                } else {
                    Outcome::Neutral
                }
            }
            InsertOutcome::Rejected => Outcome::Regression,
        }
    }
}

/// One parent→child transition record.
#[derive(Debug, Clone, Copy)]
pub struct Transition {
    pub parent_coords: Coords,
    pub child_coords: Coords,
    pub parent_fitness: f64,
    pub child_fitness: f64,
    pub outcome: Outcome,
    /// Iteration at which the transition happened (for time decay).
    pub iteration: usize,
}

impl Transition {
    pub fn delta_f(&self) -> f64 {
        self.child_fitness - self.parent_fitness
    }

    /// Signed movement along behavioral dimension `d`.
    pub fn delta_b(&self, d: usize) -> i64 {
        self.child_coords[d] as i64 - self.parent_coords[d] as i64
    }
}

/// Fixed-capacity circular buffer of transitions.
#[derive(Debug, Clone)]
pub struct TransitionTracker {
    buf: Vec<Transition>,
    capacity: usize,
    head: usize,
    total_recorded: usize,
}

impl TransitionTracker {
    pub fn new(capacity: usize) -> TransitionTracker {
        assert!(capacity > 0);
        TransitionTracker {
            buf: Vec::with_capacity(capacity),
            capacity,
            head: 0,
            total_recorded: 0,
        }
    }

    pub fn record(&mut self, t: Transition) {
        if self.buf.len() < self.capacity {
            self.buf.push(t);
        } else {
            self.buf[self.head] = t;
            self.head = (self.head + 1) % self.capacity;
        }
        self.total_recorded += 1;
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn total_recorded(&self) -> usize {
        self.total_recorded
    }

    pub fn iter(&self) -> impl Iterator<Item = &Transition> {
        self.buf.iter()
    }

    /// Transitions originating from a given cell — the set `T` in eq. 1.
    pub fn from_cell(&self, coords: Coords) -> Vec<&Transition> {
        self.buf
            .iter()
            .filter(|t| t.parent_coords == coords)
            .collect()
    }

    /// Fraction of recorded (in-buffer) transitions that improved.
    pub fn improvement_rate(&self) -> f64 {
        if self.buf.is_empty() {
            return 0.0;
        }
        self.buf
            .iter()
            .filter(|t| t.outcome == Outcome::Improvement)
            .count() as f64
            / self.buf.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(parent: Coords, child: Coords, pf: f64, cf: f64, iter: usize) -> Transition {
        Transition {
            parent_coords: parent,
            child_coords: child,
            parent_fitness: pf,
            child_fitness: cf,
            outcome: if cf > pf {
                Outcome::Improvement
            } else if cf == pf {
                Outcome::Neutral
            } else {
                Outcome::Regression
            },
            iteration: iter,
        }
    }

    #[test]
    fn circular_overwrite() {
        let mut tr = TransitionTracker::new(3);
        for i in 0..5 {
            tr.record(t([0, 0, 0], [1, 0, 0], 0.1, 0.2, i));
        }
        assert_eq!(tr.len(), 3);
        assert_eq!(tr.total_recorded(), 5);
        // Oldest two (iterations 0, 1) were evicted.
        let iters: Vec<usize> = tr.iter().map(|x| x.iteration).collect();
        assert!(iters.contains(&4) && iters.contains(&3) && iters.contains(&2));
    }

    #[test]
    fn from_cell_filters() {
        let mut tr = TransitionTracker::new(8);
        tr.record(t([0, 0, 0], [1, 0, 0], 0.1, 0.3, 0));
        tr.record(t([1, 1, 1], [1, 2, 1], 0.3, 0.4, 1));
        tr.record(t([0, 0, 0], [0, 1, 0], 0.1, 0.05, 2));
        assert_eq!(tr.from_cell([0, 0, 0]).len(), 2);
        assert_eq!(tr.from_cell([1, 1, 1]).len(), 1);
        assert_eq!(tr.from_cell([2, 2, 2]).len(), 0);
    }

    #[test]
    fn outcome_from_insertion_matches_paper() {
        assert_eq!(
            Outcome::from_insertion(InsertOutcome::NewCell, 0.1),
            Outcome::Improvement
        );
        assert_eq!(
            Outcome::from_insertion(InsertOutcome::Improved, 0.1),
            Outcome::Improvement
        );
        assert_eq!(
            Outcome::from_insertion(InsertOutcome::Neutral, 0.0),
            Outcome::Neutral
        );
        assert_eq!(
            Outcome::from_insertion(InsertOutcome::Neutral, -0.01),
            Outcome::Regression
        );
        assert_eq!(
            Outcome::from_insertion(InsertOutcome::Rejected, -0.5),
            Outcome::Regression
        );
    }

    #[test]
    fn deltas() {
        let x = t([1, 2, 0], [0, 2, 3], 0.5, 0.7, 0);
        assert_eq!(x.delta_b(0), -1);
        assert_eq!(x.delta_b(1), 0);
        assert_eq!(x.delta_b(2), 3);
        assert!((x.delta_f() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn improvement_rate() {
        let mut tr = TransitionTracker::new(8);
        tr.record(t([0; 3], [1, 0, 0], 0.1, 0.3, 0)); // improvement
        tr.record(t([0; 3], [1, 0, 0], 0.3, 0.1, 1)); // regression
        assert!((tr.improvement_rate() - 0.5).abs() < 1e-12);
    }
}
