//! Tiny declarative CLI argument parser (replacement for `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with generated `--help` text.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

#[derive(Debug, Default)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub args: Vec<ArgSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Command {
        Command {
            name,
            about,
            args: Vec::new(),
        }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Command {
        self.args.push(ArgSpec {
            name,
            help,
            takes_value: false,
            default: None,
        });
        self
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Command {
        self.args.push(ArgSpec {
            name,
            help,
            takes_value: true,
            default: Some(default),
        });
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for a in &self.args {
            let val = if a.takes_value { " <value>" } else { "" };
            let def = a
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  --{}{val}\t{}{def}\n", a.name, a.help));
        }
        s
    }

    /// Parse a raw arg list (not including argv[0] / subcommand token).
    pub fn parse(&self, raw: &[String]) -> Result<Parsed, String> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: Vec<String> = Vec::new();
        let mut positional: Vec<String> = Vec::new();
        for spec in &self.args {
            if let Some(d) = spec.default {
                values.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < raw.len() {
            let tok = &raw[i];
            if tok == "--help" || tok == "-h" {
                return Err(self.help_text());
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (key, inline) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .args
                    .iter()
                    .find(|a| a.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.help_text()))?;
                if spec.takes_value {
                    let v = if let Some(v) = inline {
                        v
                    } else {
                        i += 1;
                        raw.get(i)
                            .cloned()
                            .ok_or_else(|| format!("option --{key} needs a value"))?
                    };
                    values.insert(key, v);
                } else {
                    flags.push(key);
                }
            } else {
                positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(Parsed {
            values,
            flags,
            positional,
        })
    }
}

#[derive(Debug, Default)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

/// Parse a human duration into milliseconds: `"250"` / `"250ms"` are
/// milliseconds, `"2s"`/`"1.5s"` seconds, `"1m"` minutes. Used by the
/// alert rules `for` clause and the `watch`/daemon interval flags.
pub fn parse_duration_ms(s: &str) -> Result<f64, String> {
    let s = s.trim();
    let (num, scale) = if let Some(n) = s.strip_suffix("ms") {
        (n, 1.0)
    } else if let Some(n) = s.strip_suffix('s') {
        (n, 1_000.0)
    } else if let Some(n) = s.strip_suffix('m') {
        (n, 60_000.0)
    } else {
        (s, 1.0)
    };
    match num.trim().parse::<f64>() {
        Ok(v) if v.is_finite() && v >= 0.0 => Ok(v * scale),
        _ => Err(format!("bad duration {s:?} (want e.g. 250ms, 2s, 1m)")),
    }
}

impl Parsed {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key)?.parse().ok()
    }

    pub fn get_usize(&self, key: &str) -> Option<usize> {
        self.get(key)?.parse().ok()
    }

    pub fn get_u64(&self, key: &str) -> Option<u64> {
        self.get(key)?.parse().ok()
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("run", "run an experiment")
            .opt("iters", "40", "iteration count")
            .opt("device", "b580", "target device")
            .flag("verbose", "chatty output")
    }

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let p = cmd().parse(&s(&["--iters", "10"])).unwrap();
        assert_eq!(p.get_usize("iters"), Some(10));
        assert_eq!(p.get("device"), Some("b580"));
        assert!(!p.has_flag("verbose"));
    }

    #[test]
    fn equals_form_flags_and_positionals() {
        let p = cmd()
            .parse(&s(&["--device=lnl", "--verbose", "task_01"]))
            .unwrap();
        assert_eq!(p.get("device"), Some("lnl"));
        assert!(p.has_flag("verbose"));
        assert_eq!(p.positional, vec!["task_01".to_string()]);
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cmd().parse(&s(&["--nope"])).is_err());
    }

    #[test]
    fn durations_parse_in_every_unit() {
        assert_eq!(parse_duration_ms("250"), Ok(250.0));
        assert_eq!(parse_duration_ms("250ms"), Ok(250.0));
        assert_eq!(parse_duration_ms("2s"), Ok(2_000.0));
        assert_eq!(parse_duration_ms("1.5s"), Ok(1_500.0));
        assert_eq!(parse_duration_ms("1m"), Ok(60_000.0));
        assert!(parse_duration_ms("soon").is_err());
        assert!(parse_duration_ms("-5s").is_err());
    }

    #[test]
    fn help_lists_options() {
        let h = cmd().help_text();
        assert!(h.contains("--iters"));
        assert!(h.contains("default: 40"));
    }
}
