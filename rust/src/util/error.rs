//! Minimal dynamic error type (replacement for `anyhow`, unavailable
//! offline).
//!
//! [`Error`] boxes any `std::error::Error + Send + Sync` root cause and
//! carries a stack of human-readable context messages, printed outermost
//! first (`"loading manifest: io: No such file"`), mirroring how `anyhow`
//! renders its context chain. The [`Context`] extension trait adds
//! `.context(..)` / `.with_context(..)` to both `Result` and `Option`.

use std::error::Error as StdError;
use std::fmt;

/// Crate-standard result alias (the `anyhow::Result` stand-in).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A boxed dynamic error with context messages.
pub struct Error {
    /// Context messages, outermost first.
    context: Vec<String>,
    /// The root cause.
    root: Box<dyn StdError + Send + Sync + 'static>,
}

/// Plain-message root cause for [`Error::msg`].
#[derive(Debug)]
struct MsgError(String);

impl fmt::Display for MsgError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MsgError {}

impl Error {
    /// Construct an error from a message (the `anyhow!` stand-in).
    pub fn msg(m: impl fmt::Display) -> Error {
        Error {
            context: Vec::new(),
            root: Box::new(MsgError(m.to_string())),
        }
    }

    /// Attach an outer context message.
    pub fn wrap(mut self, c: impl fmt::Display) -> Error {
        self.context.insert(0, c.to_string());
        self
    }

    /// The root cause, for downcasting-free inspection.
    pub fn root_cause(&self) -> &(dyn StdError + 'static) {
        &*self.root
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Context messages, then the root's own Display. The root's
        // `source()` chain is deliberately NOT appended: wrapped error
        // enums (ManifestError, CustomTaskError, ...) already embed their
        // cause in their Display, and appending it again would print the
        // cause twice.
        for c in &self.context {
            write!(f, "{c}: ")?;
        }
        write!(f, "{}", self.root)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(self, f)
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error {
            context: Vec::new(),
            root: Box::new(e),
        }
    }
}

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    /// Wrap with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn message_errors_display() {
        let e = Error::msg(format!("no variants for task {}", "t1"));
        assert_eq!(e.to_string(), "no variants for task t1");
    }

    #[test]
    fn std_errors_convert_via_question_mark() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn context_chains_outermost_first() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r
            .context("reading manifest")
            .map_err(|e| e.wrap("loading artifacts"))
            .unwrap_err();
        assert_eq!(e.to_string(), "loading artifacts: reading manifest: gone");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("value missing").unwrap_err();
        assert_eq!(e.to_string(), "value missing");
        let w: Option<u32> = Some(7);
        assert_eq!(w.with_context(|| "unused").unwrap(), 7);
    }

    #[test]
    fn debug_matches_display() {
        let e = Error::msg("boom").wrap("outer");
        assert_eq!(format!("{e}"), format!("{e:?}"));
    }
}
